"""Batched serving example: continuous batching with the two-level request
scheduler and the paper's Address Allocation Unit managing KV pages.

Run:  PYTHONPATH=src python examples/serve_batched.py
"""
from repro.configs import get_smoke
from repro.serving import ServeConfig, ServingEngine


def main() -> None:
    cfg = get_smoke("tinyllama-1.1b")
    engine = ServingEngine(cfg, sc=ServeConfig(max_len=64, active_slots=4,
                                               total_pages=24))
    requests = [engine.submit(prompt=[1, 2, 3, 4][: 1 + i % 4],
                              max_new_tokens=4 + 3 * (i % 3))
                for i in range(10)]
    out = engine.run()

    print(f"served {len(requests)} requests on {engine.sc.active_slots} "
          f"active slots / {engine.sc.total_pages} KV pages")
    print(f"preemptions: {engine.sched.preemptions}, "
          f"pages in use after drain: {engine.aau.used_count}")
    for r in requests[:5]:
        print(f"  req {r.rid}: {out[r.rid]}")
    assert all(len(out[r.rid]) >= 1 for r in requests)
    engine.aau.check_invariants()


if __name__ == "__main__":
    main()
