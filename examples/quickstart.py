"""Quickstart: the paper's pipeline end to end on the Listing-1 kernel, plus
the same machinery planning a TPU layer stream.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
from repro.core import (
    form_register_intervals, parse_asm, prefetch_schedule, renumber_registers,
)
from repro.core.plan import LayerNode, Tile, plan_layer_stream
from repro.sim import baseline_config, design_config, simulate
from repro.workloads import WORKLOADS, listing1_program

MB = 2 ** 20


def compiler_walkthrough() -> None:
    print("=== paper §4.3 walk-through: Listing 1 ===")
    prog = listing1_program()
    analysis = form_register_intervals(prog, n_cap=4)
    print(f"register-intervals (cap=4): {len(analysis.intervals)}")
    for iv in analysis.intervals:
        print(f"  interval {iv.iid}: blocks={iv.blocks} "
              f"working-set={sorted(iv.working_set)}")

    before = prefetch_schedule(analysis, num_banks=4, scheme="grouped")
    print("bank conflicts before renumbering:",
          [op.conflicts for op in before])
    rr = renumber_registers(analysis, num_banks=4, scheme="grouped")
    after = prefetch_schedule(rr.analysis, num_banks=4, scheme="grouped")
    print("bank conflicts after renumbering: ",
          [op.conflicts for op in after])


def performance_model() -> None:
    print("\n=== LTRF on a slow 8x register file (config #7, DWM 6.3x) ===")
    w = WORKLOADS["srad"]
    base = simulate(w, baseline_config()).ipc
    for design in ("BL", "RFC", "LTRF", "LTRF_conf", "Ideal"):
        r = simulate(w, design_config(design, table2_config=7))
        print(f"  {design:10s} normalized IPC = {r.ipc / base:.2f}")


def tpu_plan() -> None:
    print("\n=== the same interval analysis planning a TPU layer stream ===")
    layers = [LayerNode(f"block{i}",
                        [Tile(f"w{i}_attn", 24 * MB), Tile(f"w{i}_mlp", 48 * MB)])
              for i in range(8)]
    plan = plan_layer_stream(layers, vmem_budget=96 * MB, num_slots=2)
    print(f"{plan.num_intervals} HBM->VMEM prefetch rounds "
          f"(budget 96MB, max round {plan.max_interval_bytes() / MB:.0f}MB)")
    for p in plan.prefetches[:3]:
        print(f"  round {p.interval_id}: layers={p.layer_names} "
              f"bytes={p.bytes / MB:.0f}MB slots={p.slots}")


if __name__ == "__main__":
    compiler_walkthrough()
    performance_model()
    tpu_plan()
