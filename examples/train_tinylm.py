"""End-to-end training driver example: a ~100M-param llama-style model for a
few hundred steps with checkpointing, failure injection + exact-replay
recovery, and int8 error-feedback gradient compression.

Run:  PYTHONPATH=src python examples/train_tinylm.py [--steps 200]
(CPU: ~100M params is heavy; --tiny uses the smoke config for a fast demo.)
"""
import argparse

from repro.launch.train import train


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--tiny", action="store_true",
                    help="smoke config (fast CPU demo)")
    args = ap.parse_args()

    if args.tiny:
        arch, batch, seq = "tinyllama-1.1b", 8, 64
        smoke = True
    else:
        # ~100M params: qwen3-0.6b trunk at reduced depth would need a custom
        # config; we train the full qwen3-0.6b config at short sequence
        arch, batch, seq = "qwen3-0.6b", 4, 128
        smoke = False

    out = train(arch, smoke=smoke, steps=args.steps, batch=batch, seq=seq,
                ckpt_every=50, compress=True,
                inject_failures={args.steps // 2: 1})
    print(f"finished step {out['final_step']} "
          f"(restarts={out['restarts']}, wall={out['wall_s']:.1f}s)")
    print(f"loss: first={out['losses'][0]:.4f} last={out['losses'][-1]:.4f}")
    assert out["restarts"] == 1, "failure injection should have triggered once"


if __name__ == "__main__":
    main()
