"""Paper-table/figure reproductions from the SM performance model + compiler.

One function per artifact; all results cached to experiments/paper/ as JSON
(simulations are deterministic, so the cache is sound).  `python -m
benchmarks.run` prints every table as CSV.

Every figure enumerates its simulation grid up front and hands it to the
sweep orchestrator (`benchmarks.orchestrator`): jobs are deduplicated
against the in-process/on-disk caches and the misses run across a process
pool, so the full artifact set costs one pass over the unique design points.
"""
from __future__ import annotations

import json
import math
import pathlib

from benchmarks.orchestrator import default_runner
from repro.core.plan_cache import (
    cached_intervals, cached_prefetch_ops, cached_renumber,
)
from repro.core.prefetch import code_size_overhead, conflict_distribution
from repro.sim import (
    SimConfig, baseline_config, design_config, max_tolerable_latency,
)
from repro.sim.designs import BASE_RF_KB, TOLERANCE_MULTS
from repro.workloads import get_workload, workload_names

OUT = pathlib.Path(__file__).resolve().parent.parent / "experiments" / "paper"

RUNNER = default_runner()
_sim = RUNNER.sim

# Suite selector: None = the synthetic default; "traced" runs every figure
# over the lifted real kernels (artifacts gain a _traced suffix so the two
# result sets never mix).  Set via `python -m benchmarks.run --suite traced`.
_SUITE: str | None = None


def set_suite(suite: str | None) -> None:
    global _SUITE
    _SUITE = suite


def _workloads():
    return {n: get_workload(n) for n in workload_names(_SUITE)}


gm = lambda xs: math.exp(sum(math.log(max(x, 1e-9)) for x in xs) / len(xs))


def _cached(name: str, fn):
    if _SUITE:
        name = f"{name}_{_SUITE}"
    OUT.mkdir(parents=True, exist_ok=True)
    p = OUT / f"{name}.json"
    if p.exists():
        return json.loads(p.read_text())
    out = fn()
    p.write_text(json.dumps(out, indent=1))
    return out


# Design points a fault-tolerant prefill could not complete in this process
# (FailureRecords from repro.serving.sweep) — the annotated "missing points"
# of a degraded sweep.  Figure code that still sim()s one of them recomputes
# inline (and surfaces the underlying error); `sweep_health()` reports them.
MISSING_POINTS: list = []


def _prefill(jobs) -> None:
    report = RUNNER.prefill([(w if isinstance(w, str) else w.name, cfg)
                             for w, cfg in jobs])
    if not report.ok:
        MISSING_POINTS.extend(report.failed)


def sweep_health() -> dict:
    """Degradation summary across every figure sweep run so far: the missing
    design points (per-job FailureRecords), the shared runner's
    retry/quarantine counters, and its metrics snapshot (cache hit/miss +
    latency distributions, stamped with the last sweep's ``run_id``).
    `benchmarks.run` prints a warning when ``ok`` is false — and exits
    non-zero under ``--strict`` — so a degraded artifact set never passes
    silently."""
    return {
        "ok": not MISSING_POINTS and not RUNNER.stats["quarantined"],
        "run_id": RUNNER.last_run_id,
        "missing_points": [f.to_dict() for f in MISSING_POINTS],
        "runner_stats": dict(RUNNER.stats),
        "metrics": RUNNER.metrics_snapshot(),
    }


def _prefill_tolerance(pairs, num_warps: int = 64, loss: float = 0.05) -> None:
    """Warm the cache for `max_tolerable_latency` without over-simulating.

    The metric walks latency multipliers in order and stops at the first
    failing point, so simulating the full grid up front would waste work on
    designs that die early.  Instead run one parallel *wave* per multiplier,
    dropping (workload, design) pairs exactly when the sequential search
    would — the cache ends up holding precisely the simulations the metric
    then replays."""
    def cfg_for(design, m):
        return design_config(design, mrf_latency_mult=float(m),
                             rf_size_kb=BASE_RF_KB, num_warps=num_warps)

    _prefill([(n, cfg_for(d, 1.0)) for n, d in pairs])
    alive = {(n, d): RUNNER.sim(n, cfg_for(d, 1.0)).ipc for n, d in pairs}
    for m in TOLERANCE_MULTS[1:]:
        if not alive:
            break
        _prefill([(n, cfg_for(d, m)) for n, d in alive])
        alive = {(n, d): ref for (n, d), ref in alive.items()
                 if RUNNER.sim(n, cfg_for(d, m)).ipc >= (1 - loss) * ref}


# ---------------------------------------------------------------------------

def fig04_hit_rates():
    """Fig 4: HW (RFC) and SW (SHRF) register-cache hit rates."""
    def run():
        WL = _workloads()
        _prefill([(n, design_config(d, table2_config=7))
                  for n in WL for d in ("RFC", "SHRF")])
        rows = []
        for name, w in WL.items():
            rfc = _sim(w, design_config("RFC", table2_config=7))
            shrf = _sim(w, design_config("SHRF", table2_config=7))
            rows.append({"workload": name, "rfc_hit": rfc.hit_rate,
                         "shrf_guaranteed_hit": shrf.hit_rate,
                         "shrf_prefetch_per_instr":
                             shrf.prefetch_ops / max(shrf.instructions, 1)})
        return rows
    return _cached("fig04_hit_rates", run)


def fig14_ipc():
    """Fig 14: normalized IPC of all designs at Table-2 configs #6/#7."""
    DESIGNS = ("BL", "RFC", "SHRF", "LTRF", "LTRF_conf", "Ideal")

    def run():
        WL = _workloads()
        _prefill([(n, baseline_config()) for n in WL]
                 + [(n, design_config(d, table2_config=tc))
                    for tc in (6, 7) for n in WL for d in DESIGNS])
        rows = []
        for tc in (6, 7):
            for name, w in WL.items():
                base = _sim(w, baseline_config()).ipc
                row = {"config": tc, "workload": name,
                       "register_sensitive": w.register_sensitive}
                for d in DESIGNS:
                    row[d] = _sim(w, design_config(d, table2_config=tc)).ipc / base
                rows.append(row)
        return rows
    return _cached("fig14_ipc", run)


def fig15_tolerable_latency():
    """Fig 15: max MRF latency with <=5% IPC loss, per design."""
    DESIGNS = ("BL", "RFC", "SHRF", "LTRF", "LTRF_conf")

    def run():
        WL = _workloads()
        _prefill_tolerance([(n, d) for n in WL for d in DESIGNS])
        rows = []
        for name, w in WL.items():
            row = {"workload": name}
            for d in DESIGNS:
                row[d] = max_tolerable_latency(w, d, sim=_sim)
            rows.append(row)
        return rows
    return _cached("fig15_tolerable", run)


def fig16_conflicts():
    """Fig 6/16: bank-conflict distribution, LTRF vs LTRF_conf, caps 8/16/32."""
    def run():
        WL = _workloads()
        rows = []
        for cap in (8, 16, 32):
            for name, w in WL.items():
                an = cached_intervals(w.program, cap)
                pre = list(cached_prefetch_ops(an, num_banks=16).values())
                rr = cached_renumber(w.program, cap, num_banks=16)
                post = list(cached_prefetch_ops(rr.analysis, num_banks=16).values())
                rows.append({
                    "cap": cap, "workload": name,
                    "ltrf_dist": conflict_distribution(pre),
                    "conf_dist": conflict_distribution(post),
                    "ltrf_max": max(o.conflicts for o in pre),
                    "conf_max": max(o.conflicts for o in post),
                })
        return rows
    return _cached("fig16_conflicts", run)


def fig17_cap_sensitivity():
    """Fig 17: IPC vs interval register cap at several MRF latencies."""
    def run():
        WL = _workloads()
        grid = [(cap, mult, d) for cap in (8, 16, 32)
                for mult in (2.0, 4.0, 6.3) for d in ("LTRF", "LTRF_conf")]
        _prefill([(n, baseline_config()) for n in WL]
                 + [(n, design_config(d, mrf_latency_mult=mult, interval_cap=cap))
                    for cap, mult, d in grid for n in WL])
        rows = []
        for cap, mult, d in grid:
            vals = []
            for w in WL.values():
                base = _sim(w, baseline_config()).ipc
                r = _sim(w, design_config(
                    d, mrf_latency_mult=mult, interval_cap=cap))
                vals.append(r.ipc / base)
            rows.append({"cap": cap, "mult": mult, "design": d,
                         "geomean_ipc": gm(vals)})
        return rows
    return _cached("fig17_cap", run)


def fig17_bank_ablation():
    """Fig 17-style §4.3 ablation: bank arbitration + register renumbering.

    Under ``bank_model="arbitrated"`` (operand reads/writebacks contend for
    register banks), compares LTRF with the full ICG renumbering pipeline
    against the same design with the coloring pass ablated
    (``renumber="identity"``) and the BL reference, at Table-2 config #7.
    Reports per-workload bank-conflict rate (extra serialization rounds per
    1k instructions) and IPC normalized to the §6 baseline, plus a geomean
    summary row.  Runs over the synthetic suite by default and the lifted
    real kernels with ``--suite traced``."""
    VARIANTS = (("BL", "icg", "BL"),
                ("LTRF_conf", "icg", "LTRF"),
                ("LTRF_conf", "identity", "LTRF_norenumber"))

    def run():
        WL = _workloads()

        def cfg_for(d, rn):
            return design_config(d, table2_config=7,
                                 bank_model="arbitrated", renumber=rn)

        _prefill([(n, baseline_config()) for n in WL]
                 + [(n, cfg_for(d, rn)) for n in WL for d, rn, _ in VARIANTS])
        rows = []
        gmeans = {tag: [] for _, _, tag in VARIANTS}
        for name, w in WL.items():
            base = _sim(w, baseline_config()).ipc
            row = {"workload": name}
            for d, rn, tag in VARIANTS:
                r = _sim(w, cfg_for(d, rn))
                row[f"{tag}_ipc"] = r.ipc / base
                row[f"{tag}_conflicts_per_kinstr"] = \
                    1000 * r.bank_conflict_rate
                row[f"{tag}_conflict_cycles"] = r.bank_conflict_cycles
                gmeans[tag].append(r.ipc / base)
            rows.append(row)
        rows.append({"workload": "geomean",
                     **{f"{tag}_ipc": gm(v) for tag, v in gmeans.items()}})
        return rows
    return _cached("fig17_bank", run)


def fig17_interval_strategy():
    """Interval-formation-strategy ablation (the ISSUE-5 compile-pipeline axis).

    Compares the paper's interval algorithm against the capacity-clamped
    strategy (working sets bounded by the RFC's entries-per-warp) and naive
    fixed-length intervals, on the paper's full compile pipeline
    (LTRF_conf) at Table-2 config #7 with an oversized ``interval_cap`` so
    the clamp is live.  Reports per-workload IPC normalized to the §6
    baseline plus prefetch-stall cycles per kilo-instruction — the metric
    the strategies shape — and a geomean summary row.  Runs over the
    synthetic suite by default and the lifted real kernels with
    ``--suite traced``."""
    from benchmarks.sweep_subset import INTERVAL_SWEEP_CAP

    STRATEGIES = (("paper", "LTRF"),
                  ("capacity", "LTRF_capacity"),
                  ("fixed:8", "LTRF_fixed8"))

    def run():
        WL = _workloads()

        def cfg_for(strategy):
            return design_config("LTRF_conf", table2_config=7,
                                 interval_cap=INTERVAL_SWEEP_CAP,
                                 interval_strategy=strategy)

        _prefill([(n, baseline_config()) for n in WL]
                 + [(n, cfg_for(s)) for n in WL for s, _ in STRATEGIES])
        rows = []
        gmeans = {tag: [] for _, tag in STRATEGIES}
        for name, w in WL.items():
            base = _sim(w, baseline_config()).ipc
            row = {"workload": name}
            for s, tag in STRATEGIES:
                r = _sim(w, cfg_for(s))
                row[f"{tag}_ipc"] = r.ipc / base
                row[f"{tag}_stall_per_kinstr"] = \
                    1000 * r.prefetch_stall_cycles / max(r.instructions, 1)
                row[f"{tag}_prefetch_ops"] = r.prefetch_ops
                gmeans[tag].append(r.ipc / base)
            rows.append(row)
        rows.append({"workload": "geomean",
                     **{f"{tag}_ipc": gm(v) for tag, v in gmeans.items()}})
        return rows
    return _cached("fig17_interval_strategy", run)


def fig18_active_warps():
    """Fig 18: IPC vs number of active warps."""
    def run():
        WL = _workloads()
        grid = [(slots, d) for slots in (4, 8, 16) for d in ("LTRF", "LTRF_conf")]
        _prefill([(n, baseline_config()) for n in WL]
                 + [(n, design_config(d, table2_config=7, active_slots=slots))
                    for slots, d in grid for n in WL])
        rows = []
        for slots, d in grid:
            vals = []
            for w in WL.values():
                base = _sim(w, baseline_config()).ipc
                r = _sim(w, design_config(d, table2_config=7,
                                          active_slots=slots))
                vals.append(r.ipc / base)
            rows.append({"active_slots": slots, "design": d,
                         "geomean_ipc": gm(vals)})
        return rows
    return _cached("fig18_warps", run)


def fig19_strands():
    """Fig 19: strand-bounded (SHRF-style) vs register-interval prefetch."""
    def run():
        WL = _workloads()
        grid = [(mult, d) for mult in (1.0, 2.0, 3.0, 5.3, 6.3)
                for d in ("BL", "RFC", "SHRF", "LTRF", "LTRF_conf")]
        _prefill([(n, baseline_config()) for n in WL]
                 + [(n, design_config(d, mrf_latency_mult=mult, rf_size_kb=256))
                    for mult, d in grid for n in WL])
        rows = []
        for mult, d in grid:
            vals = []
            for w in WL.values():
                base = _sim(w, baseline_config()).ipc
                r = _sim(w, design_config(d, mrf_latency_mult=mult,
                                          rf_size_kb=256))
                vals.append(r.ipc / base)
            rows.append({"mult": mult, "design": d, "geomean_ipc": gm(vals)})
        return rows
    return _cached("fig19_strands", run)


def fig21_cycle_breakdown():
    """Cycle-attribution stack (the ISSUE-7 observability figure).

    Where every simulated cycle goes — issue vs the six stall categories of
    `repro.obs.attribution` — for BL vs LTRF vs LTRF_conf at Table-2
    config #7, per workload plus an aggregate row per design.  This is the
    stacked-bar view of the paper's latency-tolerance mechanism: BL's
    exposed ``mem_stall`` cycles turn into (mostly hidden)
    ``prefetch_stall`` + ``issue`` under LTRF.  Fractions sum to 1.0 per
    row by the engine's attribution invariant."""
    from benchmarks.sweep_subset import BREAKDOWN_DESIGNS
    from repro.obs import (
        CYCLE_CATEGORIES, breakdown_fractions, merge_breakdowns,
    )

    def run():
        WL = _workloads()
        _prefill([(n, design_config(d, table2_config=7))
                  for n in WL for d in BREAKDOWN_DESIGNS])
        rows = []
        agg = {d: [] for d in BREAKDOWN_DESIGNS}
        for name, w in WL.items():
            for d in BREAKDOWN_DESIGNS:
                r = _sim(w, design_config(d, table2_config=7))
                agg[d].append(r.cycle_breakdown)
                rows.append({"workload": name, "design": d,
                             "cycles": r.cycles,
                             **breakdown_fractions(r.cycle_breakdown)})
        for d in BREAKDOWN_DESIGNS:
            total = merge_breakdowns(agg[d])
            rows.append({"workload": "aggregate", "design": d,
                         "cycles": sum(total.values()),
                         **breakdown_fractions(total)})
        assert all(abs(sum(r[c] for c in CYCLE_CATEGORIES) - 1.0) < 1e-9
                   for r in rows)
        return rows
    return _cached("fig21_breakdown", run)


def fig20_warps_per_sm():
    """Fig 20: latency tolerance vs total warps per SM."""
    def run():
        WL = _workloads()
        for n in (16, 32, 64, 128):
            _prefill_tolerance([(name, d) for name in WL
                                for d in ("BL", "LTRF")], num_warps=n)
        rows = []
        for n in (16, 32, 64, 128):
            for d in ("BL", "LTRF"):
                tols = [max_tolerable_latency(w, d, num_warps=n, sim=_sim)
                        for w in WL.values()]
                rows.append({"warps": n, "design": d,
                             "avg_tolerable": sum(tols) / len(tols)})
        return rows
    return _cached("fig20_wpsm", run)


def fig20_gpu_scale():
    """Fig 20 (GPU scale): whole-GPU IPC vs warps-per-SM x scheduler policy.

    Runs the multi-SM model (`repro.sim.gpu`) at 4 SMs: for each
    warps-per-SM point and scheduler policy, normalized whole-GPU IPC of
    BL/LTRF at Table-2 config #7 against the whole-GPU baseline.  Per-SM
    jobs are prefilled through the orchestrator, so the sweep parallelizes
    across SMs and replays from the sim cache."""
    NUM_SMS = 4
    WPS = (8, 16, 32, 64)
    SCHEDS = ("two_level", "gto", "lrr")
    DESIGNS = ("BL", "LTRF")

    def run():
        from repro.sim.gpu import gpu_jobs, simulate_gpu
        WL = _workloads()

        def gcfg(d, wps, sched):
            return design_config(d, table2_config=7,
                                 num_warps=wps * NUM_SMS, num_sms=NUM_SMS,
                                 scheduler=sched)

        def bcfg(wps):
            return baseline_config(num_warps=wps * NUM_SMS, num_sms=NUM_SMS)

        jobs = []
        for n in WL:
            for wps in WPS:
                jobs += gpu_jobs(n, bcfg(wps))
                for sched in SCHEDS:
                    for d in DESIGNS:
                        jobs += gpu_jobs(n, gcfg(d, wps, sched))
        _prefill(jobs)
        rows = []
        for wps in WPS:
            for sched in SCHEDS:
                for d in DESIGNS:
                    vals = []
                    for w in WL.values():
                        base = simulate_gpu(w, bcfg(wps), sim=_sim).ipc
                        g = simulate_gpu(w, gcfg(d, wps, sched), sim=_sim)
                        vals.append(g.ipc / base)
                    rows.append({"num_sms": NUM_SMS, "warps_per_sm": wps,
                                 "scheduler": sched, "design": d,
                                 "geomean_ipc": gm(vals)})
        return rows
    return _cached("fig20_gpu", run)


def table4_interval_length():
    """Table 4: real vs optimal register-interval length (dyn instructions)."""
    def run():
        WL = _workloads()
        cfg = SimConfig(design="LTRF", interval_cap=16)
        _prefill([(n, cfg) for n in WL])
        rows = []
        for name, w in WL.items():
            r = _sim(w, cfg)
            real_len = r.instructions / max(r.prefetch_ops, 1)
            # optimal: consecutive dynamic instructions touching <= cap regs,
            # measured on the dynamic trace of one warp
            opt_len = _optimal_interval_length(w, cap=16)
            rows.append({"workload": name, "real": real_len,
                         "optimal": opt_len,
                         "ratio": real_len / max(opt_len, 1e-9)})
        return rows
    return _cached("table4_intervals", run)


def _optimal_interval_length(w, cap: int) -> float:
    """Greedy best-case: walk one warp's dynamic trace, cutting only when the
    running register set exceeds the cap."""
    prog = w.program  # the BL pipeline runs the program unmodified
    # deterministic single-warp trace
    label, idx = prog.entry, 0
    counters: dict[str, int] = {}
    visits: dict[tuple[str, int], int] = {}
    trace = []
    steps = 0
    order = prog.order
    oidx = {l: i for i, l in enumerate(order)}
    while steps < 30_000:
        steps += 1
        bb = prog.blocks[label]
        if idx >= len(bb.instrs):
            i = oidx[label]
            if i + 1 >= len(order):
                break
            label, idx = order[i + 1], 0
            continue
        ins = bb.instrs[idx]
        if ins.op == "exit":
            break
        trace.append(ins)
        if ins.op == "bra":
            taken = True
            if ins.psrcs:
                trips = w.trips.get(ins.target)
                if trips is not None:
                    c = counters.get(ins.target, 0) + 1
                    taken = c < trips
                    counters[ins.target] = 0 if not taken else c
                else:
                    k = (label, idx)
                    v = visits.get(k, 0)
                    visits[k] = v + 1
                    taken = bool((v * 17 + 31) & 1)
            if taken:
                label, idx = ins.target, 0
                continue
        idx += 1
    # greedy segmentation
    segs = []
    cur: set[int] = set()
    cur_len = 0
    for ins in trace:
        regs = set(ins.regs)
        if len(cur | regs) > cap and cur:
            segs.append(cur_len)
            cur, cur_len = set(), 0
        cur |= regs
        cur_len += 1
    if cur_len:
        segs.append(cur_len)
    return sum(segs) / max(len(segs), 1)


def table_code_size():
    """§5.3: code-size overhead of prefetch bit-vectors."""
    def run():
        WL = _workloads()
        rows = []
        for name, w in WL.items():
            an = cached_intervals(w.program, 16)
            rows.append({
                "workload": name,
                "bitvec_only": code_size_overhead(an),
                "with_instr": code_size_overhead(an, explicit_instr=True),
            })
        return rows
    return _cached("table_code_size", run)


def table_mrf_traffic():
    """§5.2/§5.3 power proxy: MRF access reduction, LTRF vs BL."""
    def run():
        WL = _workloads()
        _prefill([(n, design_config(d, table2_config=7))
                  for n in WL for d in ("BL", "LTRF", "LTRF_plus")])
        rows = []
        for name, w in WL.items():
            bl = _sim(w, design_config("BL", table2_config=7))
            lt = _sim(w, design_config("LTRF", table2_config=7))
            lp = _sim(w, design_config("LTRF_plus", table2_config=7))
            rows.append({"workload": name,
                         "bl_mrf": bl.mrf_accesses,
                         "ltrf_mrf": lt.mrf_accesses,
                         "ltrf_plus_mrf": lp.mrf_accesses,
                         "reduction": bl.mrf_accesses / max(lt.mrf_accesses, 1),
                         "plus_reduction": bl.mrf_accesses / max(lp.mrf_accesses, 1)})
        return rows
    return _cached("table_mrf_traffic", run)


def table_power():
    """§5.3/§1 power claims: same-tech -23%, DWM-8x -46%."""
    def run():
        WL = _workloads()
        from repro.sim.power import power_comparison
        _prefill([(n, cfg) for n in WL
                  for cfg in (baseline_config(),
                              design_config("LTRF", table2_config=7),
                              design_config("LTRF", mrf_latency_mult=1.0,
                                            rf_size_kb=256))])
        return [power_comparison(w, sim=_sim) for w in WL.values()]
    return _cached("table_power", run)


ALL_FIGS = {
    "fig04_hit_rates": fig04_hit_rates,
    "fig14_ipc": fig14_ipc,
    "fig15_tolerable": fig15_tolerable_latency,
    "fig16_conflicts": fig16_conflicts,
    "fig17_cap": fig17_cap_sensitivity,
    "fig17_bank": fig17_bank_ablation,
    "fig17_interval": fig17_interval_strategy,
    "fig18_warps": fig18_active_warps,
    "fig19_strands": fig19_strands,
    "fig20_wpsm": fig20_warps_per_sm,
    "fig20_gpu": fig20_gpu_scale,
    "fig21_breakdown": fig21_cycle_breakdown,
    "table4_intervals": table4_interval_length,
    "table_code_size": table_code_size,
    "table_mrf_traffic": table_mrf_traffic,
    "table_power": table_power,
}
