"""Parallel sweep orchestrator — thin delegate to the sweep service.

The actual implementation lives in `repro.serving.sweep`: a fault-tolerant
future-per-job dispatcher (worker-crash recovery, bounded retries with
exponential backoff, per-job wall-clock timeouts) over a checksummed,
quarantine-capable on-disk result store.  This module keeps the historical
``benchmarks.orchestrator`` entry point alive for the benchmark harness and
existing scripts; new code should import from `repro.serving` directly.
"""
from __future__ import annotations

from repro.serving.sweep import (
    FAILURE_KINDS, ROOT, SIMCACHE, FailureRecord, Job, ResultStore,
    SimRunner, SweepConfig, SweepReport, _run_job, default_processes,
    default_runner, job_label, sim_key,
)

__all__ = [
    "FAILURE_KINDS", "ROOT", "SIMCACHE", "FailureRecord", "Job",
    "ResultStore", "SimRunner", "SweepConfig", "SweepReport",
    "default_processes", "default_runner", "job_label", "sim_key",
]
