"""Parallel sweep orchestrator for the SM performance model.

The paper-figure sweeps are thousands of independent, deterministic
simulations; this module gives them three fast-path layers:

* an **in-process memo** keyed by (workload, SimConfig) — figure functions
  freely re-request the same normalization baselines without re-simulating;
* an **on-disk artifact cache** under ``experiments/paper/simcache/`` so a
  re-run of the benchmark harness replays results instead of simulations;
* a **process-pool prefill** (`SimRunner.prefill`) that executes the missing
  jobs of a sweep across cores before the figure code consumes them.

Results are exact `SimResult` counters — simulations are deterministic, so
both cache layers are sound (the golden-equivalence suite pins the engine).
"""
from __future__ import annotations

import hashlib
import json
import os
import pathlib
from concurrent.futures import ProcessPoolExecutor
from dataclasses import asdict

from repro.sim import SimConfig, SimResult, simulate
from repro.sim.engine import ENGINE_REV
from repro.sim.gpu import GpuResult, aggregate, per_sm_configs
from repro.workloads import get_workload

ROOT = pathlib.Path(__file__).resolve().parent.parent
SIMCACHE = ROOT / "experiments" / "paper" / "simcache"

Job = tuple[str, SimConfig]


def sim_key(workload: str, cfg: SimConfig) -> str:
    """Stable on-disk key for one simulation job.

    ENGINE_REV is part of the key: when the engine's counters intentionally
    change, old cache entries become unreachable instead of silently mixing
    two engine behaviors into one sweep."""
    payload = json.dumps([ENGINE_REV, workload, asdict(cfg)], sort_keys=True)
    return hashlib.sha1(payload.encode()).hexdigest()[:20]


def _run_job(job: Job) -> tuple[str, SimConfig, dict]:
    name, cfg = job
    # get_workload resolves lazy suites (e.g. traced kernels) in pool workers
    res = simulate(get_workload(name), cfg)
    return name, cfg, asdict(res)


def default_processes() -> int:
    env = os.environ.get("REPRO_SIM_PROCS")
    if env:
        return max(1, int(env))
    return max(1, os.cpu_count() or 1)


class SimRunner:
    """Memoizing, optionally parallel and disk-backed simulation runner."""

    def __init__(self, processes: int | None = None,
                 disk_cache: bool = True,
                 cache_dir: pathlib.Path | None = None) -> None:
        self.processes = processes if processes is not None else default_processes()
        self.disk_cache = disk_cache
        self.cache_dir = cache_dir or SIMCACHE
        self._memo: dict[Job, SimResult] = {}
        self.stats = {"memo_hits": 0, "disk_hits": 0, "computed": 0}

    # -- cache layers ------------------------------------------------------
    def _disk_path(self, job: Job) -> pathlib.Path:
        return self.cache_dir / f"{sim_key(*job)}.json"

    def _disk_load(self, job: Job) -> SimResult | None:
        if not self.disk_cache:
            return None
        p = self._disk_path(job)
        if not p.exists():
            return None
        try:
            return SimResult(**json.loads(p.read_text()))
        except (ValueError, TypeError):
            return None  # corrupt/stale entry: recompute

    def _disk_store(self, job: Job, res: SimResult) -> None:
        if not self.disk_cache:
            return
        self.cache_dir.mkdir(parents=True, exist_ok=True)
        p = self._disk_path(job)
        tmp = p.with_suffix(f".tmp{os.getpid()}")
        tmp.write_text(json.dumps(asdict(res)))
        tmp.replace(p)  # atomic: concurrent runs race benignly

    def _lookup(self, job: Job) -> SimResult | None:
        res = self._memo.get(job)
        if res is not None:
            self.stats["memo_hits"] += 1
            return res
        res = self._disk_load(job)
        if res is not None:
            self.stats["disk_hits"] += 1
            self._memo[job] = res
        return res

    # -- public API --------------------------------------------------------
    def sim(self, workload, cfg: SimConfig) -> SimResult:
        """One simulation through the memo/disk cache (inline on miss)."""
        name = workload if isinstance(workload, str) else workload.name
        job = (name, cfg)
        res = self._lookup(job)
        if res is None:
            self.stats["computed"] += 1
            res = simulate(get_workload(name), cfg)
            self._memo[job] = res
            self._disk_store(job, res)
        return res

    def sim_gpu(self, workload, cfg: SimConfig) -> GpuResult:
        """One whole-GPU simulation: the per-SM jobs go through the memo /
        disk cache (and the pool, if several SMs miss), then aggregate.

        GPU sweeps therefore reuse the compile cache across SMs (the per-SM
        configs only differ in warp share / seed / DRAM interval, none of
        which key the compiler passes) and replay per-SM results from disk.
        """
        name = workload if isinstance(workload, str) else workload.name
        jobs = [(name, c) for c in per_sm_configs(cfg)]
        self.prefill(jobs)
        return aggregate(cfg, [self.sim(*job) for job in jobs], name)

    def prefill_gpu(self, jobs: list[Job]) -> None:
        """Expand whole-GPU jobs into their per-SM jobs and prefill those."""
        self.prefill([(name, c) for name, cfg in jobs
                      for c in per_sm_configs(cfg)])

    def prefill(self, jobs: list[Job]) -> None:
        """Execute all cache-missing jobs, across the process pool."""
        misses: list[Job] = []
        seen: set[Job] = set()
        for job in jobs:
            if job in seen:
                continue
            seen.add(job)
            if self._lookup(job) is None:
                misses.append(job)
        if not misses:
            return
        if self.processes <= 1 or len(misses) == 1:
            for job in misses:
                self.sim(*job)
            return
        self.stats["computed"] += len(misses)
        chunk = max(1, len(misses) // (self.processes * 4))
        with ProcessPoolExecutor(max_workers=self.processes) as pool:
            for name, cfg, d in pool.map(_run_job, misses, chunksize=chunk):
                res = SimResult(**d)
                self._memo[(name, cfg)] = res
                self._disk_store((name, cfg), res)


_DEFAULT: SimRunner | None = None


def default_runner() -> SimRunner:
    """Process-wide shared runner (memo survives across figure functions)."""
    global _DEFAULT
    if _DEFAULT is None:
        _DEFAULT = SimRunner()
    return _DEFAULT
