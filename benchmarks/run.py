"""Benchmark harness entry point: one artifact per paper table/figure,
plus kernel microbenches and the dry-run/roofline summaries.

Prints ``name,metric,value`` CSV rows (plus per-workload detail rows).
Heavy artifacts are cached under experiments/paper/.  ``--strict`` turns a
degraded sweep (failed or quarantined design points — see
`repro.serving.sweep`) from a stderr warning into a non-zero exit.
"""
from __future__ import annotations

import json
import pathlib
import sys
import time

ROOT = pathlib.Path(__file__).resolve().parent.parent


def _emit(name: str, rows) -> None:
    if isinstance(rows, dict):
        rows = [rows]
    for row in rows:
        flat = ",".join(f"{k}={_fmt(v)}" for k, v in row.items())
        print(f"{name},{flat}")


def _fmt(v):
    if isinstance(v, float):
        return f"{v:.4g}"
    if isinstance(v, dict):
        return "|".join(f"{k}:{_fmt(x)}" for k, x in v.items())
    return v


def bench_paper_figures(strict: bool = False) -> None:
    from benchmarks.paper_figs import ALL_FIGS, sweep_health
    for name, fn in ALL_FIGS.items():
        t0 = time.time()
        rows = fn()
        _emit(name, rows)
        print(f"# {name}: {time.time() - t0:.1f}s", file=sys.stderr)
    health = sweep_health()
    if not health["ok"]:
        # degraded sweep: some design points failed/quarantined (see
        # repro.serving.sweep) — the full story (failure records keyed by
        # run_id + the runner's metrics snapshot) goes through the metrics
        # layer rather than an eyeball-only print
        snap = health["metrics"]
        print(f"# WARNING: sweep degraded [run_id {health['run_id']}]: "
              f"{len(health['missing_points'])} missing point(s), "
              f"jobs_failed={snap.get('sweep_jobs_failed', 0)} "
              f"quarantined={snap.get('sweep_quarantined_total', 0)} "
              f"retries={snap.get('sweep_retries_total', 0)}",
              file=sys.stderr)
        for mp in health["missing_points"]:
            print(f"#   missing: {mp['job']} [{mp['kind']}] {mp['detail']}",
                  file=sys.stderr)
        if strict:
            sys.exit(f"# --strict: refusing to pass a degraded sweep "
                     f"(run_id {health['run_id']})")


def bench_sim_sweep(suite: str | None = None, strict: bool = False) -> None:
    """Time the tracked paper-figure sweep subset and refresh BENCH_sim.json
    (see benchmarks.bench_sim; pass REPRO_SIM_PROCS to bound the pool)."""
    from benchmarks.bench_sim import run_bench
    report = run_bench(smoke="--smoke" in sys.argv, suite=suite)
    _emit("sim", {k: v for k, v in report.items() if not isinstance(v, dict)})
    sweep_report = report["sim_cache"]["sweep_report"]
    if not sweep_report["ok"]:
        print(f"# WARNING: sim sweep degraded "
              f"[run_id {sweep_report['run_id']}]: "
              f"{len(sweep_report['failed'])} failed, "
              f"{len(sweep_report['quarantined'])} quarantined",
              file=sys.stderr)
        if strict:
            sys.exit(f"# --strict: refusing to pass a degraded sim sweep "
                     f"(run_id {sweep_report['run_id']})")


def bench_kernels() -> None:
    """Interpret-mode micro-bench: wall time is NOT TPU perf — this verifies
    the kernels execute and reports call latencies for regression tracking."""
    import jax
    import jax.numpy as jnp
    from repro.kernels.flash_attention.ops import flash_attention
    from repro.kernels.ltrf_matmul.ops import ltrf_matmul
    from repro.kernels.ssd_scan.ops import ssd_scan

    def timed(fn, *args, n=3, **kw):
        fn(*args, **kw)  # warmup/compile
        t0 = time.time()
        for _ in range(n):
            jax.block_until_ready(fn(*args, **kw))
        return (time.time() - t0) / n * 1e6

    x = jax.random.normal(jax.random.PRNGKey(0), (256, 512), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(1), (512, 256), jnp.float32)
    us = timed(ltrf_matmul, x, w, bm=128, bk=128, bn=128, interpret=True)
    _emit("kernels", {"name": "ltrf_matmul_256x512x256", "us_per_call": us})

    q = jax.random.normal(jax.random.PRNGKey(0), (1, 4, 256, 64))
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 2, 256, 64))
    v = jax.random.normal(jax.random.PRNGKey(2), (1, 2, 256, 64))
    us = timed(flash_attention, q, k, v, bq=128, bk=128, interpret=True)
    _emit("kernels", {"name": "flash_attention_b1h4s256", "us_per_call": us})

    xs = jax.random.normal(jax.random.PRNGKey(0), (1, 128, 2, 8)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(jax.random.PRNGKey(1), (1, 128, 2)))
    A = -jnp.exp(jnp.linspace(0.0, 1.0, 2))
    Bm = jax.random.normal(jax.random.PRNGKey(2), (1, 128, 8)) * 0.3
    Cm = jax.random.normal(jax.random.PRNGKey(3), (1, 128, 8)) * 0.3
    us = timed(ssd_scan, xs, dt, A, Bm, Cm, chunk=32, interpret=True)
    _emit("kernels", {"name": "ssd_scan_s128", "us_per_call": us})


def bench_dryrun_summary() -> None:
    d = ROOT / "experiments" / "dryrun"
    if not d.exists():
        print("# dry-run JSONs missing; run python -m repro.launch.dryrun --all",
              file=sys.stderr)
        return
    for p in sorted(d.glob("*.json")):
        r = json.loads(p.read_text())
        if not r.get("runnable", True):
            _emit("dryrun", {"arch": r["arch"], "shape": r["shape"],
                             "mesh": r["mesh"], "status": "defined-skip"})
            continue
        mem = r.get("memory", {}).get("total_hbm_bytes", 0) / 2 ** 30
        _emit("dryrun", {
            "arch": r["arch"], "shape": r["shape"], "mesh": r["mesh"],
            "status": "ok" if r.get("ok") else "FAIL",
            "mem_gib": mem,
            "coll_mib": r.get("collectives", {}).get("total_bytes", 0) / 2 ** 20,
            "compile_s": r.get("compile_s", -1),
        })


def bench_roofline_summary() -> None:
    d = ROOT / "experiments" / "roofline"
    if not d.exists():
        print("# roofline JSONs missing; run python -m benchmarks.roofline --all",
              file=sys.stderr)
        return
    for p in sorted(d.glob("*.json")):
        r = json.loads(p.read_text())
        if "skipped" in r or "error" in r:
            continue
        t = r["terms_seconds"]
        _emit("roofline", {
            "arch": r["arch"], "shape": r["shape"],
            "compute_ms": t["compute_s"] * 1e3,
            "memory_ms": t["memory_s"] * 1e3,
            "collective_ms": t["collective_s"] * 1e3,
            "dominant": r["dominant"].replace("_s", ""),
            "useful_flop_ratio": r["useful_flop_ratio"],
            "roofline_fraction": r["roofline_fraction"],
        })


def main() -> None:
    args = [a for a in sys.argv[1:] if a != "--smoke"]
    strict = "--strict" in args
    if strict:
        # fail the process (CI job) when any sweep is degraded — failed or
        # quarantined design points — instead of only warning on stderr
        args = [a for a in args if a != "--strict"]
    suite = None
    if "--suite" in args:
        i = args.index("--suite")
        if i + 1 >= len(args):
            sys.exit("--suite requires a value (synth|traced|all)")
        suite = args[i + 1]
        if suite not in ("synth", "traced", "all"):
            sys.exit(f"unknown suite {suite!r} (expected synth|traced|all)")
        del args[i:i + 2]
    only = args[0] if args else None
    if suite:
        # run the figure set over another workload suite (e.g. the lifted
        # real kernels: --suite traced); artifacts gain a suffix
        from benchmarks import paper_figs
        paper_figs.set_suite(suite)
    benches = {
        "paper": lambda: bench_paper_figures(strict=strict),
        "sim": lambda: bench_sim_sweep(suite=suite, strict=strict),
        "kernels": bench_kernels,
        "dryrun": bench_dryrun_summary,
        "roofline": bench_roofline_summary,
    }
    for name, fn in benches.items():
        if only and name != only:
            continue
        fn()


if __name__ == "__main__":
    main()
