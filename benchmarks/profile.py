"""Single-run profiler: cycle breakdown + Chrome trace for one simulation.

The interactive front door to the observability layer (`repro.obs`): run
one workload on one design and get either (or both of)

* ``--breakdown`` — the cycle-attribution table on stderr-free stdout:
  every simulated cycle in exactly one category (issue / alu_dep /
  mem_stall / prefetch_stall / bank_conflict / scheduler_idle / drain),
  as counts and fractions, plus the headline counters;
* ``--trace-out trace.json`` — a per-warp Chrome trace-event file.  Open
  it in ``chrome://tracing`` or https://ui.perfetto.dev: one track per
  warp (instruction + prefetch spans, activate/swap_out instants) plus a
  scheduler track carrying the per-cycle stall attribution.  Timestamps
  are simulated cycles rendered as microseconds.

With neither flag it prints the one-line summary.  Examples::

    python -m benchmarks.profile --workload srad --design LTRF --breakdown
    python -m benchmarks.profile --workload backprop --design BL \
        --table2 6 --breakdown
    python -m benchmarks.profile --workload srad --design LTRF_conf \
        --num-warps 8 --trace-out /tmp/srad_ltrf.json
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys

from repro.obs import breakdown_fractions, trace_simulation
from repro.sim import design_config
from repro.workloads import get_workload, workload_names


def profile_run(workload: str, design: str, table2_config: int = 7,
                num_warps: int = 64,
                trace_out: pathlib.Path | None = None):
    """Simulate one (workload, design) point; returns (SimResult, event
    count or 0).  Tracing is only enabled when `trace_out` is given — the
    plain path runs the engine exactly as the sweeps do."""
    w = get_workload(workload)
    cfg = design_config(design, table2_config=table2_config,
                        num_warps=num_warps)
    if trace_out is None:
        from repro.sim import simulate
        return simulate(w, cfg), 0
    res, sink = trace_simulation(w, cfg)
    sink.write(trace_out)
    return res, len(sink.events)


def _print_breakdown(res) -> None:
    frac = breakdown_fractions(res.cycle_breakdown)
    width = max(len(c) for c in res.cycle_breakdown)
    print(f"{'category':<{width}} {'cycles':>10} {'frac':>7}")
    for cat, n in res.cycle_breakdown.items():
        bar = "#" * round(40 * frac[cat])
        print(f"{cat:<{width}} {n:>10} {frac[cat]:>6.1%} {bar}")
    print(f"{'total':<{width}} {res.cycles:>10}")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--workload", required=True,
                    help=f"one of: {', '.join(workload_names('all'))}")
    ap.add_argument("--design", required=True,
                    help="design point, e.g. BL, RFC, SHRF, LTRF, "
                         "LTRF_conf, LTRF_plus, Ideal")
    ap.add_argument("--table2", type=int, default=7,
                    help="Table-2 RF technology config (default 7: DWM)")
    ap.add_argument("--num-warps", type=int, default=64)
    ap.add_argument("--trace-out", type=pathlib.Path, default=None,
                    metavar="FILE.json",
                    help="write a Chrome trace-event file of the run "
                         "(chrome://tracing / Perfetto)")
    ap.add_argument("--breakdown", action="store_true",
                    help="print the cycle-attribution table")
    ap.add_argument("--json", action="store_true",
                    help="emit the summary as JSON instead of text")
    args = ap.parse_args(argv)

    res, events = profile_run(args.workload, args.design,
                              table2_config=args.table2,
                              num_warps=args.num_warps,
                              trace_out=args.trace_out)
    if args.json:
        out = {"workload": args.workload, "design": args.design,
               "table2_config": args.table2, "num_warps": args.num_warps,
               "cycles": res.cycles, "instructions": res.instructions,
               "ipc": round(res.ipc, 4),
               "cycle_breakdown": dict(res.cycle_breakdown),
               "cycle_fractions": {
                   c: round(v, 4) for c, v in
                   breakdown_fractions(res.cycle_breakdown).items()}}
        if args.trace_out is not None:
            out["trace_out"] = str(args.trace_out)
            out["trace_events"] = events
        print(json.dumps(out, indent=1))
        return 0
    print(f"{args.workload}/{args.design} tc{args.table2} "
          f"warps={args.num_warps}: {res.cycles} cycles, "
          f"{res.instructions} instructions, ipc={res.ipc:.3f}")
    if args.breakdown:
        _print_breakdown(res)
    if args.trace_out is not None:
        print(f"wrote {events} trace events to {args.trace_out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
