import os
if "--xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""§Perf hillclimb harness: hypothesis -> change -> measure -> validate.

Evaluates named *variants* of the three chosen cells against the same
compiled-artifact metrics the roofline uses (decomposed unrolled probes for
FLOPs / collective bytes, plus a full-cell compile for the per-device HBM
number), and appends every iteration to experiments/perf/log.jsonl.

Variants are combinations of:
  * n_micro         — gradient-accumulation depth (collective volume scales
                      with it under FSDP; activation memory scales inversely)
  * fsdp            — False = ZeRO-1: params TP-only + optimizer state
                      sharded over data (tests whether XLA hoists the
                      per-micro grad all-reduce out of the accumulation loop)
  * accum_dtype     — fp32 vs bf16 accumulation buffers
  * capacity_factor — MoE dispatch capacity
  * q_block         — attention q-tile
"""
import argparse
import dataclasses
import json
import pathlib
import time

import jax
import jax.numpy as jnp

from benchmarks.roofline import (
    HBM_BW, ICI_BW, PEAK_FLOPS, _add, _mul, _probe_metrics, _sub,
    analytic_bytes, model_flops, probe_opt,
)
from repro.configs import SHAPES, get_arch, input_specs
from repro.distributed.sharding import default_rules, shardings_for
from repro.launch.hlo_stats import _eval_shape_with_axes, _mem_analysis
from repro.launch.mesh import make_production_mesh
from repro.models.lm import init_params
from repro.optim.adamw import init_opt_state, opt_state_axes
from repro.runtime.train_step import batch_axes_for, build_train_step

OUT = pathlib.Path(__file__).resolve().parent.parent / "experiments" / "perf"


def _shardings(cfg, shape, mesh, fsdp: bool, layout: str = "2d"):
    p_rules = default_rules(mesh, fsdp=fsdp, layout=layout)
    o_rules = default_rules(mesh, fsdp=True, layout=layout)
    key = jax.random.PRNGKey(0)
    specs = input_specs(cfg, shape)
    b_sh = shardings_for(p_rules, batch_axes_for(cfg, "train"), specs)
    p_shapes, p_axes = _eval_shape_with_axes(lambda k: init_params(cfg, k), key)
    p_sh = shardings_for(p_rules, p_axes, p_shapes)
    o_shapes = jax.eval_shape(init_opt_state, p_shapes)
    o_sh = shardings_for(o_rules, opt_state_axes(p_axes), o_shapes)
    return p_rules, specs, b_sh, p_shapes, p_sh, o_shapes, o_sh


def probe_train(cfg, shape, mesh, fsdp: bool, n_micro: int, accum_dtype,
                layout: str = "2d"):
    rules, specs, b_sh, p_shapes, p_sh, o_shapes, o_sh = _shardings(
        cfg, shape, mesh, fsdp, layout)
    fn = build_train_step(cfg, rules, n_micro=n_micro,
                          accum_dtype=accum_dtype)
    lowered = jax.jit(fn, in_shardings=({"params": p_sh, "opt": o_sh}, b_sh),
                      donate_argnums=(0,)).lower(
        {"params": p_shapes, "opt": o_shapes}, specs)
    compiled = lowered.compile()
    return compiled


def measure_variant(arch_id: str, shape_name: str, *, n_micro: int,
                    fsdp: bool = True, accum_dtype="float32",
                    capacity_factor: float | None = None,
                    q_block: int | None = None, layout: str = "2d",
                    remat: str | None = None, moe_groups: int | None = None,
                    tag: str = "") -> dict:
    """Full measurement: decomposed probes for flops/coll + full-cell memory."""
    cfg = get_arch(arch_id)
    over = {}
    if capacity_factor is not None:
        over["capacity_factor"] = capacity_factor
    if q_block is not None:
        over["q_block"] = q_block
    if remat is not None:
        over["remat"] = remat
    if moe_groups is not None:
        over["moe_groups"] = moe_groups
    if over:
        cfg = dataclasses.replace(cfg, **over)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh()
    n_dev = int(mesh.devices.size)
    adt = jnp.bfloat16 if accum_dtype == "bfloat16" else jnp.float32

    t0 = time.time()
    # (1) full-cell compile: per-device HBM + raw collective count
    compiled = probe_train(cfg, shape, mesh, fsdp, n_micro, adt, layout)
    mem = _mem_analysis(compiled)

    # (2) decomposed probes at the microbatch size for flops/coll totals
    micro_shape = dataclasses.replace(
        shape, global_batch=max(shape.global_batch // n_micro, 1))
    rules = default_rules(mesh, fsdp=fsdp, layout=layout)

    def unrolled(L, ae=None):
        c = dataclasses.replace(cfg, n_layers=L, scan_layers=False,
                                **({"attn_every": ae} if ae else {}))
        comp = probe_train(c, micro_shape, mesh, fsdp, 1, adt, layout)
        return _probe_metrics(comp)

    if cfg.family == "hybrid":
        p1, p2, p1s = unrolled(1, 999), unrolled(2, 999), unrolled(1, 1)
        layer = _sub(p2, p1)
        shared = _sub(p1s, p1)
        opt1 = probe_opt(dataclasses.replace(cfg, n_layers=1), mesh, rules)
        base = _sub(_sub(p1, layer), opt1)
        per_micro = _add(_add(_mul(layer, cfg.n_layers),
                              _mul(shared, cfg.n_layers // cfg.attn_every)),
                         base)
    else:
        p1, p2 = unrolled(1), unrolled(2)
        layer = _sub(p2, p1)
        opt1 = probe_opt(dataclasses.replace(cfg, n_layers=1), mesh, rules)
        base = _sub(_sub(p1, layer), opt1)
        per_micro = _add(_mul(layer, cfg.n_layers), base)
    opt_full = probe_opt(cfg, mesh, rules)
    total = _add(_mul(per_micro, n_micro), opt_full)

    terms = {
        "compute_s": total["flops"] / PEAK_FLOPS,
        "memory_s": analytic_bytes(cfg, shape, n_dev, n_micro) / HBM_BW,
        "collective_s": total["coll"] / ICI_BW,
    }
    dominant = max(terms, key=terms.get)
    mf = model_flops(cfg, shape)
    bound = max(terms.values()) or 1e-12
    rec = {
        "arch": arch_id, "shape": shape_name, "tag": tag,
        "variant": {"n_micro": n_micro, "fsdp": fsdp, "layout": layout,
                    "accum_dtype": accum_dtype, "remat": remat,
                    "capacity_factor": capacity_factor, "q_block": q_block,
                    "moe_groups": moe_groups},
        "hbm_gib": mem.get("total_hbm_bytes", 0) / 2 ** 30,
        "terms_seconds": terms,
        "dominant": dominant,
        "roofline_fraction": (mf / n_dev / PEAK_FLOPS) / bound,
        "useful_flop_ratio": mf / max(total["flops"] * n_dev, 1e-9),
        "measure_s": round(time.time() - t0, 1),
    }
    OUT.mkdir(parents=True, exist_ok=True)
    with open(OUT / "log.jsonl", "a") as f:
        f.write(json.dumps(rec) + "\n")
    t = terms
    print(f"[{tag or 'variant'}] {arch_id}x{shape_name} n_micro={n_micro} "
          f"fsdp={fsdp} accum={accum_dtype}: hbm={rec['hbm_gib']:.2f}GiB "
          f"comp={t['compute_s']*1e3:.0f}ms coll={t['collective_s']*1e3:.0f}ms "
          f"mem={t['memory_s']*1e3:.1f}ms frac={rec['roofline_fraction']:.3f}",
          flush=True)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--n-micro", type=int, required=True)
    ap.add_argument("--no-fsdp", action="store_true")
    ap.add_argument("--accum", default="float32")
    ap.add_argument("--capacity", type=float)
    ap.add_argument("--q-block", type=int)
    ap.add_argument("--layout", default="2d", choices=["2d", "fsdp_pure", "ep_only", "ep_dp"])
    ap.add_argument("--remat", choices=["none", "block", "full"])
    ap.add_argument("--moe-groups", type=int)
    ap.add_argument("--tag", default="")
    a = ap.parse_args()
    measure_variant(a.arch, a.shape, n_micro=a.n_micro, fsdp=not a.no_fsdp,
                    accum_dtype=a.accum, capacity_factor=a.capacity,
                    q_block=a.q_block, layout=a.layout, remat=a.remat,
                    moe_groups=a.moe_groups, tag=a.tag)


if __name__ == "__main__":
    main()
