"""BENCH_sim.json emitter: perf tracking for the paper-figure sweep.

Times the canonical sweep subset (`benchmarks.sweep_subset`) through the
orchestrator fast path (compile cache + event-heap engine + process pool)
and records simulated-instructions/sec plus sweep wall-clock, compared
against the committed pre-change baseline
(``experiments/paper/BENCH_baseline.json``).  Every throughput number is
stamped with its host context (``cpu_count``, effective worker count, a
``serial_fallback`` verdict, and per-worker-normalized throughput) so a
run on a 1-CPU container is never mistaken for a perf regression against
a multi-core run.  Full runs also A/B the vectorized batch engine
(`repro.sim.batch`) against the event-heap engine on the same jobs in the
same invocation, recording bit-identity and the honest speedup under
``batch_engine``.  The timing run always
*computes* (the on-disk sim cache is bypassed) so successive runs stay
comparable; results are still written to the cache afterwards for the
figure harness to reuse, and a replay pass through the disk cache records
SimRunner hit/miss counters in the report — a cache-layer regression shows
up as ``replay_all_hits: false`` in the artifact.

Every full run also executes a multi-SM scheduler-sensitivity mini-sweep
(`benchmarks.sweep_subset.gpu_sweep_jobs`) through the orchestrator's GPU
path and records per-config whole-GPU IPC + RF power under ``gpu_sweep``
in the report, so multi-SM/scheduler drift shows up in the tracked
artifact.  ``--gpu-smoke`` runs just that sweep (the CI GPU-scale step;
``--smoke`` stays a minimal 2x2 so CI never pays the GPU sweep twice).
Likewise the §4.3 bank-arbitration/renumbering ablation
(`benchmarks.sweep_subset.bank_sweep_jobs`) lands under ``bank_sweep`` —
including the two acceptance verdicts (ICG renumbering strictly reduces
aggregate bank-conflict cycles, and never loses IPC per workload) — and
``--bank-smoke`` runs it standalone for CI.  The interval-formation
ablation (`benchmarks.sweep_subset.interval_sweep_jobs`) lands under
``interval_sweep`` — paper vs capacity vs fixed interval strategies across
all designs on the high-register-pressure workloads, with the ISSUE-5
acceptance verdicts (capacity strictly reduces aggregate prefetch-stall
cycles on LTRF_conf, with no per-workload IPC regression) — and
``--interval-smoke`` runs it standalone for CI.  The cycle-attribution
sweep (`benchmarks.sweep_subset.breakdown_sweep_jobs`) lands under
``cycle_breakdown`` — BL vs LTRF vs LTRF_conf at Table-2 config #7, with
per-design aggregate breakdowns/fractions and the ISSUE-7 verdicts (every
breakdown sums exactly to its run's cycles; the LTRF designs strictly
shrink BL's exposed mem-stall cycles and total cycles) — and ``--obs-smoke``
runs the observability acceptance smoke (invariant + Chrome-trace artifact
+ metrics snapshot) standalone for CI.  The analytical fast tier
(`repro.sim.analytic`) is differentially validated under ``analytic_tier``
— pooled and per-group Spearman rank correlation, per-point relative cycle
error and Pareto-frontier recall vs engine results from the *same*
invocation, a real hybrid-tier confirmation sweep, and the 100x throughput
gate — and ``--analytic-smoke`` runs the reduced-domain version standalone
for CI, writing ``BENCH_analytic_smoke.json``.  Full runs also fold the
sweep's `SweepReport` and the runner's metrics snapshot into ``sim_cache``
in the artifact, keyed by the sweep's deterministic ``run_id``.

Usage::

    python -m benchmarks.bench_sim              # full tracked sweep
    python -m benchmarks.bench_sim --smoke      # 2 workloads x 2 designs (CI)
    python -m benchmarks.bench_sim --gpu-smoke  # GPU mini-sweep only (CI)
    python -m benchmarks.bench_sim --bank-smoke # bank/renumbering ablation
                                                # only (CI)
    python -m benchmarks.bench_sim --interval-smoke  # interval-strategy
                                                # ablation only (CI)
    python -m benchmarks.bench_sim --chaos-smoke  # sweep under injected
                                                # faults: crash + hang +
                                                # transient + corrupt (CI)
    python -m benchmarks.bench_sim --obs-smoke  # cycle-attribution
                                                # invariant + Chrome trace
                                                # + metrics snapshot (CI)
    python -m benchmarks.bench_sim --batch-smoke  # vectorized batch engine
                                                # vs event-heap A/B:
                                                # bit-identity + speedup (CI)
    python -m benchmarks.bench_sim --analytic-smoke  # analytical fast tier
                                                # vs engine: Spearman rho +
                                                # frontier recall + 100x
                                                # throughput gates (CI)
    python -m benchmarks.bench_sim --fit-calibration  # re-fit the analytic
                                                # tier's coefficients on this
                                                # host and persist them
    python -m benchmarks.bench_sim --suite traced   # sweep the lifted
                                                # real kernels (untracked)
    python -m benchmarks.bench_sim --baseline   # re-measure the golden
                                                # (seed) engine serially and
                                                # rewrite the baseline file
"""
from __future__ import annotations

import argparse
import json
import os
import pathlib
import sys
import tempfile
import time

from benchmarks.orchestrator import SimRunner, default_processes
from benchmarks.sweep_subset import (
    BREAKDOWN_DESIGNS, INTERVAL_SWEEP_CAP, INTERVAL_VERDICT_DESIGN,
    SWEEP_DESIGNS, bank_sweep_jobs, breakdown_sweep_jobs, gpu_sweep_jobs,
    interval_sweep_jobs, run_tier_sweep, screening_jobs, sweep_jobs,
)
from repro.workloads import get_workload

ROOT = pathlib.Path(__file__).resolve().parent.parent
BASELINE_PATH = ROOT / "experiments" / "paper" / "BENCH_baseline.json"
OUT_PATH = ROOT / "BENCH_sim.json"
TRACE_OUT_PATH = ROOT / "BENCH_obs_trace.json"

SMOKE_WORKLOADS = ("srad", "kmeans")
SMOKE_DESIGNS = ("BL", "LTRF")


def host_facts(effective_processes: int) -> dict:
    """The host context a throughput number is meaningless without.

    ``sim_instr_per_s`` is a *pool* throughput: the same code on a 16-core
    runner and on a 1-CPU container legitimately differs by an order of
    magnitude.  Recording cpu_count + the effective worker count (and
    flagging the silent `default_processes()` -> 1 degradation) keeps a
    cross-host comparison from reading as a perf regression."""
    cpus = os.cpu_count() or 1
    return {
        "cpu_count": cpus,
        "effective_processes": effective_processes,
        "serial_fallback": effective_processes <= 1,
    }


def measure_fast_path(jobs, processes=None) -> dict:
    # batch=False pins the event-heap engine: this measurement is the A/B
    # *reference* for `measure_batch_engine`, so the sweep service's CPU
    # auto-batch policy must never silently fold batch throughput into it
    runner = SimRunner(processes=processes, disk_cache=False, batch=False)
    t0 = time.time()
    sweep_report = runner.prefill(jobs)
    wall = time.time() - t0
    total_instr = sum(runner.sim(*job).instructions for job in jobs)
    # persist into the shared sim cache for the figure harness, then replay
    # through the cache layers: every job must come back as a memo/disk hit —
    # computed > 0 here means the cache key or a layer broke
    replay = SimRunner(processes=1)
    for job, res in runner._memo.items():
        replay._disk_store(job, res)
    replay.prefill(jobs)
    # the SweepReport and the runner's metrics snapshot ride along in the
    # tracked artifact (instead of a bare stderr print), so degraded sweeps
    # and latency distributions are joinable by run_id after the fact
    stats = {
        "timing_run": dict(runner.stats),
        "replay": dict(replay.stats),
        "replay_all_hits": replay.stats["computed"] == 0,
        "sweep_report": sweep_report.to_dict(),
        "metrics": runner.metrics_snapshot(),
    }
    host = host_facts(runner.processes)
    per_s = total_instr / max(wall, 1e-9)
    return {
        "engine": "fast-path",
        "processes": runner.processes,
        "host": host,
        "sims": len(jobs),
        "unique_sims": len(set(jobs)),
        "wall_s": round(wall, 2),
        "sim_instructions": total_instr,
        "sim_instr_per_s": round(per_s, 1),
        # normalized per pool worker: the number that IS comparable across
        # hosts with different core counts
        "sim_instr_per_s_per_worker": round(per_s / runner.processes, 1),
        "throughput_verdict": ("serial_fallback" if host["serial_fallback"]
                               else "parallel"),
        "sim_cache": stats,
    }


def measure_batch_engine(jobs, reference=None,
                         event_instr_per_s: float | None = None) -> dict:
    """Same-host, same-run A/B of the vectorized batch engine
    (BENCH_sim.json's ``batch_engine`` section).

    Runs every batch-supported job through `repro.sim.batch.run_batch` and
    records wall/throughput next to the event-heap fast path measured in
    the *same invocation* — never against a number copied from another
    host.  ``reference`` (job -> SimResult from the event-heap run) gates
    the bit-identity verdict; a single diverging counter fails it.

    The 10x speedup target assumes a backend that can actually execute the
    lockstep tick in parallel (GPU/TPU, or XLA CPU with many cores).  The
    BATCH_REV 2 fused tick (struct-of-arrays families + the legacy XLA:CPU
    runtime) lifted the serial-CPU floor past the event heap, so the
    verdict is measured, not presumed — and ``wall_s`` no longer folds XLA
    compilation into throughput: ``compile_s`` (one-time, persisted by the
    XLA compile cache across runs) and steady-state ``run_s`` are split
    out, with ``sim_instr_per_s`` computed from the steady state and the
    compile-inclusive ratio reported alongside."""
    from repro.sim import SimBudgetExceeded
    from repro.sim.batch import (BATCH_REV, batch_supported, reset_run_stats,
                                 run_batch)

    uniq = list(dict.fromkeys(jobs))
    supported = [j for j in uniq if batch_supported(j[1])]
    stats = reset_run_stats()
    t0 = time.time()
    outs = run_batch([(get_workload(n), cfg) for n, cfg in supported],
                     fallback=False)
    wall = time.time() - t0
    compile_s, run_s = stats["compile_s"], stats["run_s"]
    ticks = stats["ticks"]
    by_job = dict(zip(supported, outs))
    total_instr = sum(by_job[j].instructions for j in jobs if j in by_job
                      and not isinstance(by_job[j], SimBudgetExceeded))
    per_s = total_instr / max(run_s, 1e-9)            # steady state
    per_s_incl = total_instr / max(wall, 1e-9)        # compile included
    bit_identical = None
    if reference is not None:
        bit_identical = all(by_job[j] == reference[j] for j in supported)
    try:
        import jax
        platform = jax.devices()[0].platform
    except Exception:  # noqa: BLE001 - jax unavailable or broken
        platform = "unavailable"
    host = host_facts(1)  # the lockstep engine is one XLA client
    host["jax_platform"] = platform
    speedup = (round(per_s / event_instr_per_s, 3)
               if event_instr_per_s else None)
    speedup_incl = (round(per_s_incl / event_instr_per_s, 3)
                    if event_instr_per_s else None)
    if speedup is None:
        verdict = "no_event_heap_reference"
    elif speedup >= 10:
        verdict = "meets_10x_target"
    elif speedup >= 1:
        verdict = "beats_event_heap_below_10x"
    elif platform == "cpu" and (os.cpu_count() or 1) <= 2:
        verdict = "below_target_dispatch_bound_serial_host"
    else:
        verdict = "below_target"
    return {
        "engine": "batch-vectorized",
        "batch_rev": BATCH_REV,
        "host": host,
        "sims": len(supported),
        "unsupported_sims": len(uniq) - len(supported),
        "wall_s": round(wall, 2),
        "compile_s": round(compile_s, 2),
        "run_s": round(run_s, 2),
        "fused_loop_ticks": ticks,
        "sim_instructions": total_instr,
        "sim_instr_per_s": round(per_s, 1),
        "sim_instr_per_s_incl_compile": round(per_s_incl, 1),
        "bit_identical_to_event_heap": bit_identical,
        "event_heap_sim_instr_per_s": event_instr_per_s,
        "speedup_vs_event_heap": speedup,
        "speedup_vs_event_heap_incl_compile": speedup_incl,
        "meets_10x_target": bool(speedup is not None and speedup >= 10),
        "verdict": verdict,
    }


BATCH_SMOKE_OUT_PATH = ROOT / "BENCH_batch_smoke.json"


def measure_batch_smoke(out_path: pathlib.Path = BATCH_SMOKE_OUT_PATH) -> dict:
    """The batch-engine acceptance smoke (CI's ``--batch-smoke`` step).

    A small design x workload matrix runs through both engines in the same
    process; the batch results must be *bit-identical* (SimResult equality
    covers every counter and the cycle breakdown), and a budget-capped job
    must freeze at the identical cycle the event-heap engine raises
    `SimBudgetExceeded`.  Wall-clock for both engines plus the speedup
    ratio land in ``BENCH_batch_smoke.json`` (uploaded as a CI artifact).

    Bit-identity always gates the exit code.  The speedup >= 1 verdict is
    computed on the *steady-state* batch wall (XLA compile split out as
    ``batch_compile_s`` — it is a one-time cost amortized by the
    persistent compile cache) and, since the BATCH_REV 2 fused tick beat
    the event heap on the tracked serial-CPU host (see ``batch_engine``
    in BENCH_sim.json), it is enforced on serial CPU hosts too."""
    from dataclasses import replace as _replace

    from repro.sim import SimBudgetExceeded, design_config, simulate
    from repro.sim.batch import reset_run_stats, run_batch

    jobs = []
    for wname in SMOKE_WORKLOADS:
        for design in ("BL", "RFC", "LTRF", "LTRF_plus", "Ideal"):
            for nw in (8, 16):
                jobs.append((wname, design_config(design, table2_config=7,
                                                  num_warps=nw)))
    pairs = [(get_workload(n), cfg) for n, cfg in jobs]
    stats = reset_run_stats()
    t0 = time.time()
    outs = run_batch(pairs, fallback=False)
    batch_wall = time.time() - t0
    batch_compile_s, batch_run_s = stats["compile_s"], stats["run_s"]
    t0 = time.time()
    ref = [simulate(w, cfg) for w, cfg in pairs]
    event_wall = time.time() - t0
    total_instr = sum(r.instructions for r in ref)
    # watchdog parity: capped run must freeze at the identical cycle the
    # event-heap engine raises at
    wd_w, wd_cfg = pairs[0]
    wd_cfg = _replace(wd_cfg, max_cycles=200)
    wd_batch = run_batch([(wd_w, wd_cfg)], fallback=False)[0]
    try:
        simulate(wd_w, wd_cfg)
        wd_event = None
    except SimBudgetExceeded as e:
        wd_event = e
    speedup = round(max(event_wall, 1e-9) / max(batch_run_s, 1e-9), 3)
    speedup_incl = round(max(event_wall, 1e-9) / max(batch_wall, 1e-9), 3)
    try:
        import jax
        platform = jax.devices()[0].platform
    except Exception:  # noqa: BLE001
        platform = "unavailable"
    verdicts = {
        "batch_bit_identical": outs == ref,
        "watchdog_budget_parity": (
            isinstance(wd_batch, SimBudgetExceeded)
            and wd_event is not None
            and wd_batch.args == wd_event.args),
        "speedup_ge_1": speedup >= 1.0,
    }
    gating = {k: v for k, v in verdicts.items() if isinstance(v, bool)}
    report = {
        "sims": len(jobs),
        "host": {**host_facts(1), "jax_platform": platform},
        "batch_wall_s": round(batch_wall, 2),
        "batch_compile_s": round(batch_compile_s, 2),
        "batch_run_s": round(batch_run_s, 2),
        "event_heap_wall_s": round(event_wall, 2),
        "sim_instructions": total_instr,
        "speedup_vs_event_heap": speedup,
        "speedup_vs_event_heap_incl_compile": speedup_incl,
        "verdicts": verdicts,
        "all_verdicts_pass": all(gating.values()),
    }
    out_path.write_text(json.dumps(report, indent=1) + "\n")
    print(f"# wrote {out_path}", file=sys.stderr)
    return report


ANALYTIC_SMOKE_OUT_PATH = ROOT / "BENCH_analytic_smoke.json"
# The trust gates the differential harness enforces (ISSUE 9 acceptance):
# the analytical tier is only usable for screening if its *ranking* of
# design points tracks the engine's, its Pareto frontier never misses an
# engine-frontier point, and it is actually orders of magnitude faster.
ANALYTIC_RHO_MIN = 0.9        # pooled Spearman rho vs engine cycles
ANALYTIC_RECALL_MIN = 1.0     # engine frontier points recalled by hybrid
ANALYTIC_SPEEDUP_MIN = 100.0  # analytic vs engine sim-instr/s, same host
ANALYTIC_SMOKE_WORKLOADS = ("srad", "kmeans", "bfs", "sgemm")


def measure_analytic_tier(jobs=None, engine_results=None,
                          engine_instr_per_s: float | None = None,
                          processes=None, top_k: int = 3) -> dict:
    """The differential accuracy harness for the analytical fast tier
    (BENCH_sim.json's ``analytic_tier`` section; CI's ``--analytic-smoke``).

    Prices every analytic-supported job with `repro.sim.analytic.estimate`
    and compares against cycle-accurate engine results *from the same
    invocation*: pooled + per-(workload, rf-size) Spearman rank correlation,
    per-point relative cycle error, and — the number that decides whether
    hybrid screening can be trusted — frontier recall: in every group, the
    engine's true Pareto frontier over (cycles, MRF accesses) must be a
    subset of what the analytic tier selects for confirmation (its own
    estimated frontier plus the ``top_k`` best-cycle points, exactly the
    `SimRunner._prefill_hybrid` selection rule).  A hybrid prefill then runs
    for real and must engine-confirm every selected point.  Throughput is
    measured warm (estimates per second with hot plan caches — the
    steady-state screening rate) and cold, and compared against an engine
    rate measured fresh on this host in this invocation."""
    from repro.sim.analytic import (ANALYTIC_REV, CALIB_REV,
                                    analytic_supported, pareto_frontier,
                                    spearman_rho)

    if jobs is None:
        jobs = sweep_jobs()
    uniq = list(dict.fromkeys(jobs))
    supported = [j for j in uniq if analytic_supported(j[1])]

    # engine reference: reuse the invocation's results when given (the full
    # bench passes the fast-path sweep), else compute through the cache
    runner = SimRunner(processes=processes)
    if engine_results is None:
        runner.prefill(supported, tier="engine")
        engine_results = {j: runner.sim(*j) for j in supported}
    if engine_instr_per_s is None:
        # fresh serial engine sample on this host (cache bypassed), so the
        # speedup verdict never compares against another machine's number
        sample = supported[::max(1, len(supported) // 4)][:4]
        timing = SimRunner(processes=1, disk_cache=False)
        t0 = time.time()
        sample_instr = sum(timing.sim(*j).instructions for j in sample)
        engine_instr_per_s = sample_instr / max(time.time() - t0, 1e-9)

    # analytic timing: cold = first pass this invocation (may compile),
    # warm = re-estimated with hot plan/profile caches (the steady-state
    # screening throughput a million-point sweep would see)
    fast = SimRunner(processes=1, disk_cache=False)
    t0 = time.time()
    ests = {j: fast.estimate(*j) for j in supported}
    cold_wall = time.time() - t0
    fast._analytic_memo.clear()
    t0 = time.time()
    ests = {j: fast.estimate(*j) for j in supported}
    warm_wall = time.time() - t0
    total_instr = sum(e.instructions for e in ests.values())
    warm_per_s = total_instr / max(warm_wall, 1e-9)
    speedup = warm_per_s / max(engine_instr_per_s, 1e-9)

    # pooled + per-group rank accuracy and relative error
    est_c = [float(ests[j].cycles) for j in supported]
    eng_c = [float(engine_results[j].cycles) for j in supported]
    pooled_rho = spearman_rho(est_c, eng_c)
    rel = sorted(abs(e - g) / max(g, 1.0) for e, g in zip(est_c, eng_c))
    groups: dict[tuple, list] = {}
    for j in supported:
        groups.setdefault((j[0], j[1].rf_size_kb), []).append(j)
    group_rhos = []
    frontier_total = frontier_hit = 0
    group_rows = []
    for (wname, rf_kb), members in sorted(groups.items()):
        ec = [float(engine_results[j].cycles) for j in members]
        ea = [float(ests[j].cycles) for j in members]
        rho = spearman_rho(ea, ec)
        if len(members) >= 3:
            group_rhos.append(rho)
        eng_front = set(pareto_frontier(
            [(float(engine_results[j].cycles),
              float(engine_results[j].mrf_accesses)) for j in members]))
        est_pts = [(float(ests[j].cycles),
                    float(ests[j].est_mrf_accesses)) for j in members]
        picked = set(pareto_frontier(est_pts))
        picked.update(sorted(range(len(members)),
                             key=lambda i: est_pts[i][0])[:top_k])
        hit = len(eng_front & picked)
        frontier_total += len(eng_front)
        frontier_hit += hit
        group_rows.append({"workload": wname, "rf_size_kb": rf_kb,
                           "points": len(members), "rho": round(rho, 4),
                           "engine_frontier": len(eng_front),
                           "recalled": hit})
    recall = frontier_hit / max(frontier_total, 1)

    # the hybrid tier for real: every selected point must come back with an
    # engine verdict through the ordinary cache/retry machinery
    hyb = SimRunner(processes=processes, cache_dir=runner.cache_dir)
    hyb_rep = hyb.prefill(supported, tier="hybrid", top_k=top_k)

    verdicts = {
        "spearman_rho_ge_min": pooled_rho >= ANALYTIC_RHO_MIN,
        "frontier_recall_pinned": recall >= ANALYTIC_RECALL_MIN,
        "throughput_ge_100x_engine": speedup >= ANALYTIC_SPEEDUP_MIN,
        "hybrid_confirms_selection":
            hyb_rep.ok and len(hyb_rep.frontier_jobs) > 0
            and hyb_rep.frontier_confirmed == len(hyb_rep.frontier_jobs),
    }
    return {
        "analytic_rev": ANALYTIC_REV,
        "calib_rev": CALIB_REV,
        "calibration": runner.calibration().source,
        "sims": len(supported),
        "unsupported_sims": len(uniq) - len(supported),
        "groups": len(groups),
        "host": host_facts(1),
        "pooled_spearman_rho": round(pooled_rho, 4),
        "group_rho_mean": round(sum(group_rhos) / max(len(group_rhos), 1), 4),
        "group_rho_min": round(min(group_rhos), 4) if group_rhos else None,
        "rel_err": {
            "mean": round(sum(rel) / max(len(rel), 1), 4),
            "p50": round(rel[len(rel) // 2], 4) if rel else None,
            "p90": round(rel[int(len(rel) * 0.9)], 4) if rel else None,
            "max": round(rel[-1], 4) if rel else None,
        },
        "frontier": {"top_k": top_k, "engine_points": frontier_total,
                     "recalled": frontier_hit, "recall": round(recall, 4)},
        "throughput": {
            "cold_wall_s": round(cold_wall, 3),
            "warm_wall_s": round(warm_wall, 4),
            "sim_instructions": total_instr,
            "analytic_instr_per_s": round(warm_per_s, 1),
            "engine_instr_per_s": round(engine_instr_per_s, 1),
            "speedup_vs_engine": round(speedup, 1),
        },
        "hybrid_report": hyb_rep.to_dict(),
        "per_group": group_rows,
        "thresholds": {"rho_min": ANALYTIC_RHO_MIN,
                       "recall_min": ANALYTIC_RECALL_MIN,
                       "speedup_min": ANALYTIC_SPEEDUP_MIN},
        "verdicts": verdicts,
        "all_verdicts_pass": all(verdicts.values()),
    }


def measure_analytic_smoke(
        out_path: pathlib.Path = ANALYTIC_SMOKE_OUT_PATH) -> dict:
    """The fast-lane differential smoke (CI's ``--analytic-smoke`` step).

    The full tracked-domain harness shrunk to four workloads at Table-2
    config #7 so a cold CI container finishes in well under 30 s; same
    metrics, same trust gates, written to ``BENCH_analytic_smoke.json``
    (uploaded as a CI artifact).  The full-domain numbers land in
    BENCH_sim.json's ``analytic_tier`` section on full bench runs."""
    jobs = sweep_jobs(workloads=ANALYTIC_SMOKE_WORKLOADS,
                      table2_configs=(7,))
    report = measure_analytic_tier(jobs, processes=1)
    report["sweep"] = (f"analytic_smoke({len(ANALYTIC_SMOKE_WORKLOADS)} "
                       "workloads x 7 designs + baselines, tc7)")
    out_path.write_text(json.dumps(report, indent=1) + "\n")
    print(f"# wrote {out_path}", file=sys.stderr)
    return report


SCREENING_SMOKE_OUT_PATH = ROOT / "BENCH_screening_smoke.json"
# Trust gates for the screening-scale hybrid run (ROADMAP item 1's
# "actually run the screening grid"): the whole 3.7k-point grid must be
# priced, every point the hybrid tier selects for confirmation must come
# back engine-confirmed, and the end-to-end sweep must stay inside a
# wall-clock budget a nightly CI lane can afford.
SCREENING_MIN_POINTS = 3500       # the tracked grid is 3752 unique points
SCREENING_MIN_CONFIRMED = 42      # >= top_k per workload group (14 x 3)
SCREENING_MAX_WALL_S = 1800.0


def measure_screening(processes=None, top_k: int = 3) -> dict:
    """Run the 3752-point ``sweep_subset.screening_jobs`` grid through the
    hybrid tier (BENCH_sim.json's ``analytic_screening`` section; CI's
    ``--screening-smoke`` step).

    This is the screening workload the analytical tier exists for: every
    grid point is priced by the closed-form model, the estimated Pareto
    frontier (plus the ``top_k`` best-cycle points per workload) is
    confirmed by the cycle-accurate engine through the ordinary sweep
    machinery, and the verdicts assert the confirmation counts and the
    wall-clock budget — a grid ~19x the tracked engine sweep, completed in
    a fraction of its wall."""
    from repro.sim.analytic import analytic_supported

    jobs = list(dict.fromkeys(screening_jobs()))
    supported = [j for j in jobs if analytic_supported(j[1])]
    runner = SimRunner(processes=processes, disk_cache=False)
    t0 = time.time()
    runner, report = run_tier_sweep(jobs, "hybrid", runner=runner,
                                    top_k=top_k)
    wall = time.time() - t0
    n_frontier = len(report.frontier_jobs)
    verdicts = {
        "grid_at_screening_scale": len(jobs) >= SCREENING_MIN_POINTS,
        "all_points_screened": report.ok
            and report.analytic_points == len(supported),
        "frontier_all_confirmed": n_frontier >= SCREENING_MIN_CONFIRMED
            and report.frontier_confirmed == n_frontier,
        "wall_within_budget": wall <= SCREENING_MAX_WALL_S,
    }
    return {
        "sweep": "screening_jobs(rf 256/2048KB x tolerance mults x "
                 "two_level/gto x 7 designs x 14 workloads)",
        "tier": "hybrid",
        "host": host_facts(runner.processes),
        "points": len(jobs),
        "analytic_supported": len(supported),
        "analytic_points": report.analytic_points,
        "frontier_selected": n_frontier,
        "frontier_confirmed": report.frontier_confirmed,
        "wall_s": round(wall, 2),
        "points_per_s": round(len(jobs) / max(wall, 1e-9), 1),
        "sweep_report": report.to_dict(),
        "thresholds": {"min_points": SCREENING_MIN_POINTS,
                       "min_confirmed": SCREENING_MIN_CONFIRMED,
                       "max_wall_s": SCREENING_MAX_WALL_S},
        "verdicts": verdicts,
        "all_verdicts_pass": all(verdicts.values()),
    }


def measure_screening_smoke(
        out_path: pathlib.Path = SCREENING_SMOKE_OUT_PATH) -> dict:
    """CI's ``--screening-smoke``: the full screening grid + trust gates,
    written to ``BENCH_screening_smoke.json`` (uploaded as an artifact)."""
    report = measure_screening(processes=1)
    out_path.write_text(json.dumps(report, indent=1) + "\n")
    print(f"# wrote {out_path}", file=sys.stderr)
    return report


def measure_gpu_sweep(processes=None, num_sms: int = 2,
                      warps_per_sm: int = 16) -> dict:
    """Multi-SM scheduler-sensitivity mini-sweep through the orchestrator.

    Small enough to run on every full benchmark invocation (and as the CI
    GPU-scale smoke step); the per-config whole-GPU IPCs and §5.3 RF-power
    proxy land in BENCH_sim.json so scheduler/multi-SM behavioural drift
    is visible in the tracked artifact."""
    from repro.sim.power import gpu_rf_power

    runner = SimRunner(processes=processes, disk_cache=False)
    jobs = gpu_sweep_jobs(num_sms=num_sms, warps_per_sm=warps_per_sm)
    t0 = time.time()
    runner.prefill_gpu(jobs)
    rows = []
    for name, cfg in jobs:
        res = runner.sim_gpu(name, cfg)
        # gpu_sweep_jobs pins Table-2 config #7: the DWM 8x design point
        rows.append({"workload": name, "design": cfg.design,
                     "scheduler": cfg.scheduler,
                     "ipc": round(res.ipc, 4),
                     "instructions": res.instructions,
                     "sm_imbalance": round(res.sm_imbalance, 4),
                     "rf_power": round(gpu_rf_power(res, "dwm",
                                                    cap_mult=8).total, 4)})
    wall = time.time() - t0
    return {"num_sms": num_sms, "warps_per_sm": warps_per_sm,
            "gpu_sims": len(jobs), "per_sm_sims": len(jobs) * num_sms,
            "wall_s": round(wall, 2), "results": rows}


def measure_bank_sweep(processes=None, suite: str | None = None) -> dict:
    """The §4.3 bank-arbitration/renumbering ablation (BENCH_sim.json's
    ``bank_sweep`` section; CI's ``--bank-smoke`` step).

    Runs BL, LTRF_conf(icg) and LTRF_conf(identity) under
    ``bank_model="arbitrated"`` over the tracked workload suite and records
    per-config bank-conflict counters + IPC, plus the two aggregate verdicts
    the ISSUE-4 acceptance pins: ICG renumbering must show strictly fewer
    bank-conflict cycles in aggregate and per-workload IPC >= identity."""
    runner = SimRunner(processes=processes, disk_cache=False)
    jobs = bank_sweep_jobs(suite=suite)
    t0 = time.time()
    runner.prefill(jobs)
    rows = []
    for name, cfg in jobs:
        res = runner.sim(name, cfg)
        rows.append({"workload": name, "design": cfg.design,
                     "renumber": cfg.renumber,
                     "ipc": round(res.ipc, 4),
                     "bank_conflicts": res.bank_conflicts,
                     "bank_conflict_cycles": res.bank_conflict_cycles,
                     "conflicts_per_kinstr":
                         round(1000 * res.bank_conflict_rate, 3)})
    wall = time.time() - t0
    icg = {r["workload"]: r for r in rows
           if r["design"] == "LTRF_conf" and r["renumber"] == "icg"}
    ident = {r["workload"]: r for r in rows
             if r["design"] == "LTRF_conf" and r["renumber"] == "identity"}
    icg_cycles = sum(r["bank_conflict_cycles"] for r in icg.values())
    ident_cycles = sum(r["bank_conflict_cycles"] for r in ident.values())
    return {
        "bank_model": "arbitrated",
        "sims": len(jobs),
        "wall_s": round(wall, 2),
        "icg_conflict_cycles": icg_cycles,
        "identity_conflict_cycles": ident_cycles,
        "icg_strictly_fewer_conflict_cycles": icg_cycles < ident_cycles,
        "icg_ipc_ge_identity_all_workloads": all(
            icg[n]["ipc"] >= ident[n]["ipc"] for n in icg),
        "results": rows,
    }


def measure_interval_sweep(processes=None, suite: str | None = None) -> dict:
    """The interval-formation-strategy ablation (BENCH_sim.json's
    ``interval_sweep`` section; CI's ``--interval-smoke`` step).

    Runs paper/capacity/fixed interval formation across all 7 designs over
    the high-register-pressure workloads at an oversized ``interval_cap``
    and records per-config IPC + prefetch-stall counters, plus the ISSUE-5
    acceptance verdicts computed on the paper's full compile pipeline
    (LTRF_conf): the capacity strategy must show strictly fewer aggregate
    prefetch-stall cycles than the paper strategy with no per-workload IPC
    regression.  Also records that the knob is a no-op on the designs with
    no interval prefetch (BL/RFC/Ideal) and on strand-bounded SHRF."""
    runner = SimRunner(processes=processes, disk_cache=False)
    jobs = interval_sweep_jobs(suite=suite)
    t0 = time.time()
    runner.prefill(jobs)
    rows = []
    for name, cfg in jobs:
        res = runner.sim(name, cfg)
        rows.append({"workload": name, "design": cfg.design,
                     "strategy": cfg.interval_strategy,
                     "ipc": round(res.ipc, 4),
                     "prefetch_ops": res.prefetch_ops,
                     "prefetch_stall_cycles": res.prefetch_stall_cycles,
                     "mrf_accesses": res.mrf_accesses})
    wall = time.time() - t0
    vd = INTERVAL_VERDICT_DESIGN
    paper = {r["workload"]: r for r in rows
             if r["design"] == vd and r["strategy"] == "paper"}
    capacity = {r["workload"]: r for r in rows
                if r["design"] == vd and r["strategy"] == "capacity"}
    paper_stalls = sum(r["prefetch_stall_cycles"] for r in paper.values())
    capacity_stalls = sum(r["prefetch_stall_cycles"] for r in capacity.values())
    per_wl: dict[tuple[str, str], set] = {}
    for r in rows:
        if r["design"] in ("BL", "RFC", "SHRF", "Ideal"):
            per_wl.setdefault((r["design"], r["workload"]), set()).add(
                (r["ipc"], r["prefetch_ops"], r["prefetch_stall_cycles"],
                 r["mrf_accesses"]))
    noop = all(len(v) == 1 for v in per_wl.values())
    return {
        "interval_cap": INTERVAL_SWEEP_CAP,
        "verdict_design": vd,
        "sims": len(jobs),
        "wall_s": round(wall, 2),
        "paper_stall_cycles": paper_stalls,
        "capacity_stall_cycles": capacity_stalls,
        "capacity_strictly_fewer_stall_cycles":
            capacity_stalls < paper_stalls,
        "capacity_no_ipc_regression_all_workloads": all(
            capacity[n]["ipc"] >= paper[n]["ipc"] for n in paper),
        "strategy_noop_on_uncached_designs": noop,
        "results": rows,
    }


def measure_breakdown_sweep(processes=None, suite: str | None = None,
                            workloads=None) -> dict:
    """The cycle-attribution sweep (BENCH_sim.json's ``cycle_breakdown``
    section).

    Runs BL vs LTRF vs LTRF_conf at Table-2 config #7 over the tracked
    workload suite and records each run's ``SimResult.cycle_breakdown``
    plus per-design aggregate totals and fractions.  Verdicts pin the
    ISSUE-7 acceptance story: every breakdown sums exactly to the run's
    cycles, and the LTRF designs convert the baseline's exposed-latency
    stalls into prefetch the scheduler mostly hides — aggregate
    ``mem_stall`` (and ``bank_conflict``) cycles strictly shrink vs BL,
    and even after paying ``prefetch_stall`` the total cycle count is
    strictly lower (the paper's net latency-tolerance win)."""
    from repro.obs import breakdown_fractions, merge_breakdowns

    runner = SimRunner(processes=processes, disk_cache=False)
    jobs = breakdown_sweep_jobs(workloads=workloads, suite=suite)
    t0 = time.time()
    runner.prefill(jobs)
    rows = []
    for name, cfg in jobs:
        res = runner.sim(name, cfg)
        rows.append({"workload": name, "design": cfg.design,
                     "cycles": res.cycles, "ipc": round(res.ipc, 4),
                     "breakdown": dict(res.cycle_breakdown)})
    wall = time.time() - t0
    agg = {d: merge_breakdowns(r["breakdown"] for r in rows
                               if r["design"] == d)
           for d in BREAKDOWN_DESIGNS}
    frac = {d: {c: round(v, 4) for c, v in breakdown_fractions(bd).items()}
            for d, bd in agg.items()}

    ltrf_designs = tuple(d for d in BREAKDOWN_DESIGNS if d != "BL")
    verdicts = {
        "breakdown_sums_to_cycles": all(
            sum(r["breakdown"].values()) == r["cycles"] for r in rows),
        "ltrf_fewer_mem_stall_cycles": all(
            agg[d]["mem_stall"] < agg["BL"]["mem_stall"]
            for d in ltrf_designs),
        "ltrf_fewer_total_cycles": all(
            sum(agg[d].values()) < sum(agg["BL"].values())
            for d in ltrf_designs),
    }
    return {
        "table2_config": 7,
        "designs": list(BREAKDOWN_DESIGNS),
        "sims": len(jobs),
        "wall_s": round(wall, 2),
        "aggregate": agg,
        "aggregate_fractions": frac,
        "verdicts": verdicts,
        "all_verdicts_pass": all(verdicts.values()),
        "results": rows,
    }


def measure_obs_smoke(processes=None,
                      trace_out: pathlib.Path = TRACE_OUT_PATH) -> dict:
    """The observability acceptance smoke (CI's ``--obs-smoke`` step).

    Runs the cycle-attribution sweep on the two smoke workloads, re-runs
    one job with the per-warp tracer enabled and writes the Chrome trace
    to ``trace_out`` (uploaded as a CI artifact; load it in
    chrome://tracing or Perfetto), and samples the sweep-service metrics
    registry.  Verdicts: every breakdown sums to its run's cycles, the
    trace round-trips through JSON with warp tracks present, the traced
    run's counters are bit-identical to the untraced run, and the metrics
    snapshot/Prometheus exposition carry the sweep's run_id and counters.
    The CLI exits non-zero on any failed verdict."""
    from repro.obs import trace_simulation

    small = measure_breakdown_sweep(processes=processes,
                                    workloads=SMOKE_WORKLOADS)

    # traced re-run of one job: must not perturb a single counter.  A
    # scaled-down warp count keeps the uploaded artifact small while still
    # exercising multi-warp tracks + prefetch/stall spans.
    from repro.sim import design_config

    trace_wl, trace_design = "srad", "LTRF"
    cfg = design_config(trace_design, table2_config=7, num_warps=8)
    runner = SimRunner(processes=1, disk_cache=False)
    untraced = runner.sim(trace_wl, cfg)
    traced_res, sink = trace_simulation(get_workload(trace_wl), cfg)
    sink.write(trace_out)
    chrome = json.loads(trace_out.read_text())
    events = chrome.get("traceEvents", [])
    warp_tracks = {e["tid"] for e in events
                   if e.get("ph") == "M" and e.get("name") == "thread_name"
                   and e["args"]["name"].startswith("warp ")}

    # sweep-service metrics: the smoke sweep above already drove a runner;
    # sample a fresh one so counters are exactly this sweep's
    mrunner = SimRunner(processes=1, disk_cache=False)
    rep = mrunner.prefill(breakdown_sweep_jobs(workloads=SMOKE_WORKLOADS))
    snap = mrunner.metrics_snapshot()
    prom = mrunner.metrics.to_prometheus()

    verdicts = {
        "breakdown_sums_to_cycles":
            small["verdicts"]["breakdown_sums_to_cycles"],
        "trace_parses": bool(events),
        "trace_has_warp_tracks": len(warp_tracks) >= 2,
        "trace_counters_identical": traced_res == untraced,
        "untraced_has_no_sink": runner.sim(trace_wl, cfg) == untraced,
        "metrics_carry_run_id":
            snap["run_id"] == rep.run_id != "",
        "metrics_count_jobs":
            snap["sweep_jobs_total"] == rep.total,
        "prometheus_exposition":
            "sweep_jobs_total" in prom and "sweep_job_latency_s_count" in prom,
    }
    return {
        "trace_workload": f"{trace_wl}/{trace_design}",
        "trace_out": str(trace_out),
        "trace_events": len(events),
        "trace_warp_tracks": len(warp_tracks),
        # suite-level LTRF-vs-BL verdicts are meaningless on two compute-
        # bound smoke workloads; only the invariant verdict gates the smoke
        "cycle_breakdown": {k: small[k] for k in
                            ("aggregate", "aggregate_fractions")},
        "metrics": snap,
        "verdicts": verdicts,
        "all_verdicts_pass": all(verdicts.values()),
    }


def measure_chaos_sweep(processes: int | None = None) -> dict:
    """The fault-tolerance acceptance sweep (CI's ``--chaos-smoke`` step).

    Runs a 56-job sweep into a throwaway cache dir under a deterministic
    fault plan (`repro.serving.faults`) injecting one worker crash, one
    worker hang, one twice-firing transient raise, and one corrupt cache
    write — then replays the sweep with faults off so the torn cache entry
    hits the quarantine path.  The report carries pass/fail verdicts; the
    CLI exits non-zero if any verdict fails, so a fault-tolerance
    regression fails the CI step rather than hiding in the artifact."""
    from repro.serving.faults import ENV_PLAN
    from repro.serving.sweep import SweepConfig
    from repro.sim import SimConfig

    procs = max(2, processes if processes is not None
                else min(default_processes(), 4))
    workloads = ("kmeans", "bfs", "nw", "srad")
    transient_job = "bfs/BL/seed0"
    crash_job = "kmeans/LTRF/seed1"      # runs early: recycle happens first
    hang_job = "srad/LTRF/seed6"         # runs late: hits its own timeout
    corrupt_job = "nw/BL/seed3"
    jobs = [(n, SimConfig(design=d, num_warps=4, seed=s))
            for n in workloads for d in ("BL", "LTRF") for s in range(7)]

    tmp = pathlib.Path(tempfile.mkdtemp(prefix="chaos_smoke_"))
    plan_path = tmp / "fault_plan.json"
    plan_path.write_text(json.dumps({"faults": [
        {"match": transient_job, "action": "raise", "times": 2},
        {"match": crash_job, "action": "exit", "times": 1},
        {"match": hang_job, "action": "hang", "seconds": 120, "times": 1},
        {"match": corrupt_job, "stage": "store", "action": "corrupt",
         "times": 1},
    ]}))
    cache_dir = tmp / "simcache"
    sweep_cfg = SweepConfig(max_attempts=3, backoff_base_s=0.05,
                            job_timeout_s=10.0)
    saved = os.environ.get(ENV_PLAN)
    t0 = time.time()
    try:
        os.environ[ENV_PLAN] = str(plan_path)
        chaos = SimRunner(processes=procs, cache_dir=cache_dir,
                          sweep=sweep_cfg)
        rep = chaos.prefill(jobs)
    finally:
        if saved is None:
            os.environ.pop(ENV_PLAN, None)
        else:
            os.environ[ENV_PLAN] = saved
    # replay with faults off: the torn entry must quarantine, not replay
    replay = SimRunner(processes=procs, cache_dir=cache_dir, sweep=sweep_cfg)
    rep2 = replay.prefill(jobs)
    wall = time.time() - t0

    kinds = rep.retry_kinds
    verdicts = {
        "chaos_sweep_completed": rep.ok and rep.completed == rep.total,
        "transient_retried_with_backoff":
            kinds.get(transient_job, []).count("transient") == 2,
        "crash_recovered_via_pool_recycle":
            rep.pool_recycles >= 1 and "crash" in kinds.get(crash_job, []),
        "hang_recovered":  # normally its own timeout; "crash" if the hung
                           # worker died in a concurrent pool recycle
            any(k in ("timeout", "crash") for k in kinds.get(hang_job, [])),
        "no_unexpected_retries": all(
            label in (transient_job, crash_job, hang_job)
            or set(ks) == {"crash"}  # innocent neighbors of the pool break
            for label, ks in kinds.items()),
        "corrupt_entry_quarantined":
            [q.job for q in rep2.quarantined] == [corrupt_job]
            and replay.stats["quarantined"] == 1,
        "replay_clean": rep2.ok and rep2.completed == rep2.total,
    }
    return {
        "processes": procs,
        "sims": len(jobs),
        "wall_s": round(wall, 2),
        "injected": {"transient": transient_job, "crash": crash_job,
                     "hang": hang_job, "corrupt": corrupt_job},
        "chaos_report": rep.to_dict(),
        "replay_report": rep2.to_dict(),
        "verdicts": verdicts,
        "all_verdicts_pass": all(verdicts.values()),
    }


def measure_golden_serial(jobs) -> dict:
    from repro.sim.golden import golden_simulate
    t0 = time.time()
    total_instr = 0
    for name, cfg in jobs:
        total_instr += golden_simulate(get_workload(name), cfg).instructions
    wall = time.time() - t0
    return {
        "engine": "seed-serial",
        "sims": len(jobs),
        "wall_s": round(wall, 2),
        "sim_instructions": total_instr,
        "sim_instr_per_s": round(total_instr / max(wall, 1e-9), 1),
    }


def run_bench(smoke: bool = False, processes: int | None = None,
              out_path: pathlib.Path = OUT_PATH,
              suite: str | None = None) -> dict:
    if smoke:
        jobs = sweep_jobs(workloads=SMOKE_WORKLOADS, designs=SMOKE_DESIGNS,
                          table2_configs=(7,))
        label = "smoke(2 workloads x 2 designs)"
    elif suite in (None, "synth"):
        jobs = sweep_jobs()
        label = "fig14_subset(tc6+tc7, 7 designs, 14 workloads, + baselines)"
    else:
        jobs = sweep_jobs(suite=suite)
        label = f"fig14_subset(tc6+tc7, 7 designs, suite={suite}, + baselines)"
    report = {"sweep": label}
    report.update(measure_fast_path(jobs, processes=processes))
    cache = report["sim_cache"]
    print(f"# sim cache: timing_run={cache['timing_run']} "
          f"replay={cache['replay']} all_hits={cache['replay_all_hits']}",
          file=sys.stderr)
    if not smoke:  # CI runs the GPU/bank/interval/obs sweeps as own steps
        # same-run A/B: the event-heap results just measured are the
        # bit-identity reference (replayed through the disk cache, so the
        # batch run is the only compute here)
        ref_runner = SimRunner(processes=1)
        reference = {job: ref_runner.sim(*job) for job in set(jobs)}
        report["batch_engine"] = measure_batch_engine(
            jobs, reference=reference,
            event_instr_per_s=report["sim_instr_per_s"])
        report["analytic_tier"] = measure_analytic_tier(
            jobs, engine_results=reference,
            engine_instr_per_s=report["sim_instr_per_s"],
            processes=processes)
        report["analytic_screening"] = measure_screening(processes=processes)
        report["gpu_sweep"] = measure_gpu_sweep(processes=processes)
        report["bank_sweep"] = measure_bank_sweep(processes=processes,
                                                  suite=suite)
        report["interval_sweep"] = measure_interval_sweep(processes=processes,
                                                          suite=suite)
        report["cycle_breakdown"] = measure_breakdown_sweep(
            processes=processes, suite=suite)
    tracked = not smoke and suite in (None, "synth")
    if tracked and BASELINE_PATH.exists():
        base = json.loads(BASELINE_PATH.read_text())
        report["baseline"] = base
        report["speedup_vs_baseline"] = round(
            base["wall_s"] / max(report["wall_s"], 1e-9), 2)
        report["counters_match_baseline"] = (
            base.get("sim_instructions") == report["sim_instructions"])
        out_path.write_text(json.dumps(report, indent=1) + "\n")
        print(f"# wrote {out_path}", file=sys.stderr)
    return report


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny 2x2 sweep for CI")
    ap.add_argument("--suite", default=None,
                    choices=("synth", "traced", "all"),
                    help="workload suite to sweep (default: the tracked "
                         "synthetic suite; traced/all runs are not compared "
                         "against the baseline)")
    ap.add_argument("--baseline", action="store_true",
                    help="re-measure the golden engine serially and rewrite "
                         "the committed baseline")
    ap.add_argument("--gpu-smoke", action="store_true",
                    help="run only the multi-SM scheduler-sensitivity "
                         "mini-sweep (CI GPU-scale smoke)")
    ap.add_argument("--bank-smoke", action="store_true",
                    help="run only the bank-arbitration/renumbering "
                         "ablation sweep (CI bank smoke)")
    ap.add_argument("--interval-smoke", action="store_true",
                    help="run only the interval-formation-strategy "
                         "ablation sweep (CI interval smoke)")
    ap.add_argument("--batch-smoke", action="store_true",
                    help="A/B the vectorized batch engine against the "
                         "event-heap engine on a small matrix: asserts "
                         "bit-identical SimResults + watchdog parity, "
                         "records the speedup, and writes "
                         "BENCH_batch_smoke.json; exits non-zero on any "
                         "failed verdict (CI batch smoke)")
    ap.add_argument("--obs-smoke", action="store_true",
                    help="run the observability smoke: cycle-attribution "
                         "invariant on the smoke workloads, a traced run "
                         "written as a Chrome-trace artifact, and the "
                         "sweep-service metrics snapshot; exits non-zero on "
                         "any failed verdict (CI obs smoke)")
    ap.add_argument("--fit-calibration", action="store_true",
                    help="re-fit the analytical tier's exposure coefficients "
                         "against engine runs of the tracked sweep domain "
                         "(cache-accelerated) and persist them to the sim "
                         "cache's analytic_calib.json for SimRunner to pick "
                         "up; prints the fitted calibration")
    ap.add_argument("--analytic-smoke", action="store_true",
                    help="run the analytical-tier differential smoke: "
                         "Spearman rank correlation, relative error and "
                         "Pareto-frontier recall vs the engine, plus the "
                         "hybrid-tier confirmation sweep and the 100x "
                         "throughput gate; writes BENCH_analytic_smoke.json "
                         "and exits non-zero on any failed verdict (CI "
                         "analytic smoke)")
    ap.add_argument("--screening-smoke", action="store_true",
                    help="run the full 3752-point screening grid through "
                         "the hybrid tier: every point priced by the "
                         "analytical model, the estimated frontier "
                         "engine-confirmed, counts + wall-clock asserted; "
                         "writes BENCH_screening_smoke.json and exits "
                         "non-zero on any failed verdict (CI screening "
                         "smoke)")
    ap.add_argument("--chaos-smoke", action="store_true",
                    help="run a small sweep under injected faults (crash + "
                         "hang + transient + corrupt cache entry) and "
                         "verify the SweepReport; exits non-zero on any "
                         "failed verdict (CI chaos smoke)")
    ap.add_argument("--procs", type=int, default=None)
    args = ap.parse_args(argv)
    if args.gpu_smoke:
        report = measure_gpu_sweep(processes=args.procs)
        print(json.dumps(report, indent=1))
        return
    if args.bank_smoke:
        report = measure_bank_sweep(processes=args.procs, suite=args.suite)
        print(json.dumps(report, indent=1))
        return
    if args.interval_smoke:
        report = measure_interval_sweep(processes=args.procs,
                                        suite=args.suite)
        print(json.dumps(report, indent=1))
        return
    if args.batch_smoke:
        report = measure_batch_smoke()
        print(json.dumps(report, indent=1))
        if not report["all_verdicts_pass"]:
            failed = [k for k, v in report["verdicts"].items() if v is False]
            print(f"# batch smoke FAILED: {failed}", file=sys.stderr)
            sys.exit(1)
        return
    if args.obs_smoke:
        report = measure_obs_smoke(processes=args.procs)
        print(json.dumps(report, indent=1))
        if not report["all_verdicts_pass"]:
            failed = [k for k, v in report["verdicts"].items() if not v]
            print(f"# obs smoke FAILED: {failed}", file=sys.stderr)
            sys.exit(1)
        return
    if args.fit_calibration:
        from repro.serving.sweep import CALIBRATION_KEY
        from repro.sim.analytic import (analytic_supported,
                                        calibration_to_dict, fit_calibration,
                                        save_calibration)

        runner = SimRunner(processes=args.procs)
        jobs = [j for j in dict.fromkeys(sweep_jobs(suite=args.suite))
                if analytic_supported(j[1])]
        runner.prefill(jobs, tier="engine")
        samples = [(get_workload(n), cfg, runner.sim(n, cfg).cycles)
                   for n, cfg in jobs]
        calib = fit_calibration(samples)
        path = runner.store.path(CALIBRATION_KEY)
        save_calibration(calib, path)
        print(f"# wrote {path}", file=sys.stderr)
        print(json.dumps(calibration_to_dict(calib), indent=1))
        return
    if args.analytic_smoke:
        report = measure_analytic_smoke()
        print(json.dumps(report, indent=1))
        if not report["all_verdicts_pass"]:
            failed = [k for k, v in report["verdicts"].items() if not v]
            print(f"# analytic smoke FAILED: {failed}", file=sys.stderr)
            sys.exit(1)
        return
    if args.screening_smoke:
        report = measure_screening_smoke()
        print(json.dumps(report, indent=1))
        if not report["all_verdicts_pass"]:
            failed = [k for k, v in report["verdicts"].items() if not v]
            print(f"# screening smoke FAILED: {failed}", file=sys.stderr)
            sys.exit(1)
        return
    if args.chaos_smoke:
        report = measure_chaos_sweep(processes=args.procs)
        print(json.dumps(report, indent=1))
        if not report["all_verdicts_pass"]:
            failed = [k for k, v in report["verdicts"].items() if not v]
            print(f"# chaos smoke FAILED: {failed}", file=sys.stderr)
            sys.exit(1)
        return
    if args.baseline:
        report = measure_golden_serial(sweep_jobs())
        BASELINE_PATH.parent.mkdir(parents=True, exist_ok=True)
        BASELINE_PATH.write_text(json.dumps(report, indent=1) + "\n")
        print(f"# wrote {BASELINE_PATH}", file=sys.stderr)
    else:
        report = run_bench(smoke=args.smoke, processes=args.procs,
                           suite=args.suite)
    print(json.dumps(report, indent=1))


if __name__ == "__main__":
    main()
