import os
if "--xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Roofline analysis from compiled dry-run artifacts (deliverable g).

XLA's cost analysis counts a while-loop body ONCE, so the dry-run JSON's raw
FLOPs undercount scanned layers.  This module therefore lowers *unrolled*
small-L probe variants of each cell (scan_layers=False, n_micro=1) and
reconstructs exact per-device totals:

    layer   = probe(L=2) - probe(L=1)            per-layer flops/bytes/coll
    base    = probe(L=1) - layer - opt(L=1)      embed + head + loss
    total   = n_micro * (L*layer + base) + opt(L_full)     [train]
              n_micro * (L*layer + base)                   [prefill]
              L*layer + base                                [decode]

(the optimizer update is loop-free HLO, probed exactly on the full stacked
parameter shapes; hybrid archs get separate mamba/shared-attention deltas).

Roofline terms per chip (TPU v5e: 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI):

    compute_t = HLO_flops / PEAK        memory_t = HLO_bytes / HBM_BW
    collective_t = collective_bytes / ICI_BW

The reported `roofline_fraction` is the MFU bound: analytic MODEL_FLOPS per
chip / PEAK, divided by the dominant term — i.e. how close the cell could get
to peak if the dominant term were the only cost.
"""
import argparse
import dataclasses
import json
import pathlib
import time

import jax

from repro.configs import ARCH_IDS, SHAPES, cell_is_runnable, get_arch, input_specs
from repro.distributed.sharding import default_rules, shardings_for
from repro.launch.hlo_stats import _cost_analysis, _eval_shape_with_axes, collective_stats
from repro.launch.mesh import make_production_mesh
from repro.models.lm import init_decode_cache, init_params
from repro.optim.adamw import AdamWConfig, adamw_update, init_opt_state, opt_state_axes
from repro.runtime.train_step import (
    batch_axes_for, build_decode_step, build_prefill_step, build_train_step,
)

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

OUT_DIR = pathlib.Path(__file__).resolve().parent.parent / "experiments" / "roofline"


def _probe_metrics(compiled):
    cost = _cost_analysis(compiled)
    try:
        hlo = compiled.as_text()
    except Exception:
        hlo = ""
    coll = collective_stats(hlo)
    return {
        "flops": cost.get("flops", 0.0),
        "bytes": cost.get("bytes accessed", 0.0),
        "coll": float(coll["total_bytes"]),
    }


def _sub(a, b):
    return {k: a[k] - b[k] for k in a}


def _mul(a, s):
    return {k: a[k] * s for k in a}


def _add(a, b):
    return {k: a[k] + b[k] for k in a}


def probe_step(cfg, shape, mesh, rules, kind: str):
    """Lower+compile one unrolled variant; returns flops/bytes/coll (per chip)."""
    key = jax.random.PRNGKey(0)
    specs = input_specs(cfg, shape)
    b_sh = shardings_for(rules, batch_axes_for(
        cfg, "decode" if kind == "decode" else "train"), specs)
    p_shapes, p_axes = _eval_shape_with_axes(lambda k: init_params(cfg, k), key)
    p_sh = shardings_for(rules, p_axes, p_shapes)
    if kind == "decode":
        c_shapes, c_axes = _eval_shape_with_axes(
            lambda: init_decode_cache(cfg, shape.global_batch, shape.seq_len))
        c_sh = shardings_for(rules, c_axes, c_shapes)
        fn = build_decode_step(cfg, rules)
        lowered = jax.jit(fn, in_shardings=(p_sh, c_sh, b_sh["tokens"],
                                            b_sh["cache_len"])).lower(
            p_shapes, c_shapes, specs["tokens"], specs["cache_len"])
    elif kind == "prefill":
        fn = build_prefill_step(cfg, rules, n_micro=1)
        lowered = jax.jit(fn, in_shardings=(p_sh, b_sh)).lower(p_shapes, specs)
    else:
        o_shapes = jax.eval_shape(init_opt_state, p_shapes)
        st_sh = {"params": p_sh,
                 "opt": shardings_for(rules, opt_state_axes(p_axes), o_shapes)}
        fn = build_train_step(cfg, rules, n_micro=1)
        lowered = jax.jit(fn, in_shardings=(st_sh, b_sh)).lower(
            {"params": p_shapes, "opt": o_shapes}, specs)
    return _probe_metrics(lowered.compile())


def probe_opt(cfg, mesh, rules):
    """Exact optimizer-update cost on the full stacked params (loop-free)."""
    key = jax.random.PRNGKey(0)
    p_shapes, p_axes = _eval_shape_with_axes(lambda k: init_params(cfg, k), key)
    p_sh = shardings_for(rules, p_axes, p_shapes)
    o_shapes = jax.eval_shape(init_opt_state, p_shapes)
    o_sh = shardings_for(rules, opt_state_axes(p_axes), o_shapes)
    fn = lambda p, g, s: adamw_update(AdamWConfig(), p, g, s)
    lowered = jax.jit(fn, in_shardings=(p_sh, p_sh, o_sh)).lower(
        p_shapes, p_shapes, o_shapes)
    return _probe_metrics(lowered.compile())


def model_flops(cfg, shape) -> float:
    """Analytic MODEL_FLOPS for the whole cell (all chips)."""
    N = cfg.active_param_count()
    B, S = shape.global_batch, shape.seq_len
    hd = cfg.hd
    if shape.kind == "train":
        tokens = B * S
        mf = 6.0 * N * tokens
        if cfg.n_heads:
            mf += 3 * 2 * 2 * B * cfg.n_heads * S * S * hd * 0.5 * cfg.n_layers
        return mf
    if shape.kind == "prefill":
        tokens = B * S
        mf = 2.0 * N * tokens
        if cfg.n_heads:
            mf += 2 * 2 * B * cfg.n_heads * S * S * hd * 0.5 * cfg.n_layers
        return mf
    # decode: one token, reads the whole cache
    mf = 2.0 * N * B
    if cfg.n_heads:
        n_attn = (cfg.n_layers if cfg.family != "hybrid"
                  else cfg.n_layers // cfg.attn_every)
        mf += 2 * 2 * B * cfg.n_heads * S * hd * n_attn
    return mf


def analytic_bytes(cfg, shape, n_dev: int, n_micro: int) -> float:
    """Fused-execution HBM-traffic estimate per chip (bytes).

    The CPU backend neither fuses elementwise chains nor keeps bf16 end to
    end, so cost_analysis 'bytes accessed' overstates HBM traffic by an
    order of magnitude; this estimate assumes TPU-typical fusion: params are
    read twice per microbatch (fwd + bwd recompute), optimizer state
    streams once per step, activations make one write + two reads per layer
    boundary, decode reads the whole KV/state cache once per token."""
    N = cfg.active_param_count()
    p_bytes = 2.0 * N / n_dev                      # bf16 shards
    B, S = shape.global_batch, shape.seq_len
    D = cfg.d_model
    if shape.kind == "decode":
        if cfg.n_heads:
            n_attn = (cfg.n_layers if cfg.family != "hybrid"
                      else cfg.n_layers // cfg.attn_every)
            cache = 2.0 * n_attn * B * S * cfg.n_kv_heads * cfg.hd * 2 / n_dev
        else:
            cache = 0.0
        if cfg.family in ("ssm", "hybrid"):
            d_inner = cfg.ssm_expand * D
            nh = d_inner // cfg.ssm_headdim
            cache += (cfg.n_layers * B * nh * cfg.ssm_headdim * cfg.ssm_state
                      * 2.0 / n_dev)
        return p_bytes + cache
    tokens_local = B * S / n_dev / n_micro
    act = 3.0 * cfg.n_layers * tokens_local * D * 2.0  # write + 2 reads, bf16
    logits = tokens_local * cfg.vocab * 4.0 / max(n_dev // 16, 1)
    per_micro = 2.0 * p_bytes + act + logits
    if shape.kind == "train":
        opt = 12.0 * N / n_dev + 4.0 * N / n_dev * 2  # adam fp32 + fp32 accum
        return n_micro * per_micro + opt
    return n_micro * (p_bytes + act / 3 + logits)


def roofline_cell(arch_id: str, shape_name: str) -> dict:
    cfg = get_arch(arch_id)
    shape = SHAPES[shape_name]
    ok, why = cell_is_runnable(cfg, shape)
    if not ok:
        return {"arch": arch_id, "shape": shape_name, "skipped": why}
    mesh = make_production_mesh()
    rules = default_rules(mesh)
    n_dev = int(mesh.devices.size)
    dp = n_dev // int(mesh.shape["model"])
    kind = shape.kind
    n_micro = max(1, shape.global_batch // dp) if kind != "decode" else 1
    # probe shape: one microbatch
    micro_shape = dataclasses.replace(
        shape, global_batch=max(shape.global_batch // n_micro, 1)) \
        if kind != "decode" else shape

    t0 = time.time()
    if cfg.family == "hybrid":
        v = lambda L, ae: dataclasses.replace(cfg, n_layers=L, attn_every=ae,
                                              scan_layers=False)
        p1 = probe_step(v(1, 999), micro_shape, mesh, rules, kind)
        p2 = probe_step(v(2, 999), micro_shape, mesh, rules, kind)
        p1s = probe_step(v(1, 1), micro_shape, mesh, rules, kind)
        layer = _sub(p2, p1)
        shared = _sub(p1s, p1)
        opt1 = probe_opt(v(1, 999), mesh, rules) if kind == "train" else \
            {"flops": 0.0, "bytes": 0.0, "coll": 0.0}
        base = _sub(_sub(p1, layer), opt1)
        n_shared = cfg.n_layers // cfg.attn_every
        per_micro = _add(_add(_mul(layer, cfg.n_layers),
                              _mul(shared, n_shared)), base)
    else:
        v = lambda L: dataclasses.replace(cfg, n_layers=L, scan_layers=False)
        p1 = probe_step(v(1), micro_shape, mesh, rules, kind)
        p2 = probe_step(v(2), micro_shape, mesh, rules, kind)
        layer = _sub(p2, p1)
        opt1 = probe_opt(v(1), mesh, rules) if kind == "train" else \
            {"flops": 0.0, "bytes": 0.0, "coll": 0.0}
        base = _sub(_sub(p1, layer), opt1)
        per_micro = _add(_mul(layer, cfg.n_layers), base)

    if kind == "train":
        opt_full = probe_opt(cfg, mesh, rules)
        total = _add(_mul(per_micro, n_micro), opt_full)
    elif kind == "prefill":
        total = _mul(per_micro, n_micro)
    else:
        total = per_micro

    compute_t = total["flops"] / PEAK_FLOPS
    memory_raw_t = total["bytes"] / HBM_BW   # CPU-unfused upper bound
    memory_t = analytic_bytes(cfg, shape, n_dev, n_micro) / HBM_BW
    coll_t = total["coll"] / ICI_BW
    terms = {"compute_s": compute_t, "memory_s": memory_t,
             "collective_s": coll_t}
    dominant = max(terms, key=terms.get)
    mf = model_flops(cfg, shape)
    mf_per_chip = mf / n_dev
    bound = max(terms.values()) or 1e-12
    frac = (mf_per_chip / PEAK_FLOPS) / bound

    hints = {
        "compute_s": "compute-bound: raise useful-FLOP share (less remat "
                     "recompute, fuse elementwise into matmuls)",
        "memory_s": "HBM-bound: increase arithmetic intensity (bigger "
                    "microbatch per chip, fewer activation round-trips, "
                    "bf16 temps instead of f32)",
        "collective_s": "ICI-bound: reshard to cut all-gather volume "
                        "(fewer TP boundaries per layer, overlap collectives "
                        "with compute, int8 gradient compression)",
    }
    rec = {
        "arch": arch_id, "shape": shape_name, "mesh": "pod16x16",
        "devices": n_dev, "n_micro": n_micro,
        "per_layer": layer, "base": base, "total_per_chip": total,
        "terms_seconds": terms, "memory_s_hlo_unfused": memory_raw_t,
        "dominant": dominant,
        "model_flops_total": mf,
        "hlo_flops_total": total["flops"] * n_dev,
        "useful_flop_ratio": mf / max(total["flops"] * n_dev, 1e-9),
        "roofline_fraction": frac,
        "next_lever": hints[dominant],
        "probe_seconds": round(time.time() - t0, 1),
    }
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--all", action="store_true")
    args = ap.parse_args()
    OUT_DIR.mkdir(parents=True, exist_ok=True)
    cells = ([(a, s) for a in ARCH_IDS for s in SHAPES] if args.all
             else [(args.arch, args.shape)])
    for a, s in cells:
        try:
            rec = roofline_cell(a, s)
        except Exception as e:  # noqa: BLE001
            rec = {"arch": a, "shape": s, "error": f"{type(e).__name__}: {e}"}
        (OUT_DIR / f"{a}_{s}.json").write_text(json.dumps(rec, indent=1))
        if "skipped" in rec:
            print(f"{a:22s} {s:12s} SKIP ({rec['skipped'][:40]})", flush=True)
        elif "error" in rec:
            print(f"{a:22s} {s:12s} ERROR {rec['error']}", flush=True)
        else:
            t = rec["terms_seconds"]
            print(f"{a:22s} {s:12s} comp={t['compute_s']*1e3:8.2f}ms "
                  f"mem={t['memory_s']*1e3:8.2f}ms coll={t['collective_s']*1e3:8.2f}ms "
                  f"dom={rec['dominant'][:-2]:10s} useful={rec['useful_flop_ratio']:.2f} "
                  f"frac={rec['roofline_fraction']:.3f}", flush=True)


if __name__ == "__main__":
    main()
