"""The canonical paper-figure sweep subset used for perf tracking.

This is the Fig. 14-shaped grid (both Table-2 design points x all designs x
the workload suite, plus the per-workload normalization baselines) that
`BENCH_sim.json` times.  Kept in its own module so the pre/post-change
measurements are guaranteed to run the *same* job list.

The default job list is pinned to the synthetic suite (`workload_names()`
with no suite): lazily-registered suites like ``traced`` never change the
tracked benchmark.  Pass ``suite="traced"`` (or ``"all"``) to sweep the
real lifted kernels instead.
"""
from __future__ import annotations

from repro.sim import SimConfig, baseline_config, design_config
from repro.sim.designs import TOLERANCE_MULTS
from repro.workloads import get_workload, workload_names

SWEEP_DESIGNS = ("BL", "RFC", "SHRF", "LTRF", "LTRF_conf", "LTRF_plus", "Ideal")

# The interval-formation ablation (ISSUE 5): the paper's algorithm vs the
# capacity-clamped variant vs naive fixed-length intervals, swept at an
# interval_cap deliberately larger than the default design's RFC
# entries-per-warp (128 entries / 8 active slots = 16) so the capacity
# strategy actually clamps.  Verdicts are computed on LTRF_conf — the
# paper's full compile pipeline (intervals + ICG renumbering).
INTERVAL_STRATEGIES_SWEPT = ("paper", "capacity", "fixed:8")
INTERVAL_SWEEP_CAP = 48
INTERVAL_VERDICT_DESIGN = "LTRF_conf"

GPU_SCHEDULERS = ("two_level", "gto", "lrr")

# The cycle-attribution comparison points (ISSUE 7): the baseline that eats
# the slow-MRF latency raw, vs the two paper designs that hide it behind
# interval prefetch.  Pinned at Table-2 config #7 (DWM, 6.3x latency) — the
# design point where latency tolerance matters most — and deliberately a
# subset of `sweep_jobs`' tc7 grid, so the figure harness shares sim-cache
# entries with Fig. 14.
BREAKDOWN_DESIGNS = ("BL", "LTRF", "LTRF_conf")

# The §4.3 renumbering-ablation comparison points: LTRF with the full ICG
# renumbering pipeline, the same design with the coloring pass ablated
# (identity numbering), and the BL reference — all under the arbitrated
# bank model so operand/writeback conflicts are actually charged.
BANK_VARIANTS = (
    ("BL", "icg"),
    ("LTRF_conf", "icg"),
    ("LTRF_conf", "identity"),
)


def bank_sweep_jobs(workloads=None, table2_config: int = 7,
                    variants=BANK_VARIANTS,
                    suite: str | None = None) -> list[tuple[str, SimConfig]]:
    """The bank-arbitration/renumbering ablation recorded in BENCH_sim.json
    (and run as the CI bank smoke).  Single-SM configs: run them through
    `SimRunner.sim` like the main sweep."""
    names = list(workloads) if workloads else list(workload_names(suite))
    return [
        (name, design_config(d, table2_config=table2_config,
                             bank_model="arbitrated", renumber=rn))
        for name in names for d, rn in variants
    ]


def gpu_sweep_jobs(num_sms: int = 2, warps_per_sm: int = 16,
                   workloads=("srad", "bfs"), designs=("BL", "LTRF"),
                   schedulers=GPU_SCHEDULERS,
                   table2_config: int = 7) -> list[tuple[str, SimConfig]]:
    """The multi-SM scheduler-sensitivity mini-sweep recorded in
    BENCH_sim.json (and run as the CI GPU-scale smoke).  Each job's config
    is a *whole-GPU* config: run it through `SimRunner.sim_gpu` /
    `repro.sim.gpu.simulate_gpu`, not the single-SM engine."""
    return [
        (name, design_config(d, table2_config=table2_config,
                             num_warps=warps_per_sm * num_sms,
                             num_sms=num_sms, scheduler=s))
        for name in workloads for d in designs for s in schedulers
    ]


def interval_sweep_jobs(workloads=None, table2_config: int = 7,
                        strategies=INTERVAL_STRATEGIES_SWEPT,
                        interval_cap: int = INTERVAL_SWEEP_CAP,
                        designs=SWEEP_DESIGNS,
                        suite: str | None = None) -> list[tuple[str, SimConfig]]:
    """The interval-strategy ablation recorded in BENCH_sim.json (and run as
    the CI interval smoke).  Defaults to the *high-register-pressure*
    (register-sensitive) workloads of the suite — the kernels whose working
    sets the strategies actually shape.  Single-SM configs: run them
    through `SimRunner.sim` like the main sweep."""
    if workloads is None:
        workloads = [n for n in workload_names(suite)
                     if get_workload(n).register_sensitive]
    return [
        (name, design_config(d, table2_config=table2_config,
                             interval_cap=interval_cap, interval_strategy=s))
        for name in workloads for d in designs for s in strategies
    ]


def breakdown_sweep_jobs(workloads=None, table2_config: int = 7,
                         designs=BREAKDOWN_DESIGNS,
                         suite: str | None = None) -> list[tuple[str, SimConfig]]:
    """The cycle-attribution sweep recorded in BENCH_sim.json's
    ``cycle_breakdown`` section (and run as the CI obs smoke).  Single-SM
    configs: run them through `SimRunner.sim` like the main sweep."""
    names = list(workloads) if workloads else list(workload_names(suite))
    return [
        (name, design_config(d, table2_config=table2_config))
        for name in names for d in designs
    ]


def sweep_jobs(workloads=None, designs=SWEEP_DESIGNS,
               table2_configs=(6, 7),
               suite: str | None = None) -> list[tuple[str, SimConfig]]:
    """(workload name, SimConfig) pairs for the tracked sweep subset."""
    names = list(workloads) if workloads else list(workload_names(suite))
    jobs: list[tuple[str, SimConfig]] = []
    for tc in table2_configs:
        for name in names:
            jobs.append((name, baseline_config()))
            for d in designs:
                jobs.append((name, design_config(d, table2_config=tc)))
    return jobs


def screening_jobs(workloads=None, designs=SWEEP_DESIGNS,
                   rf_sizes_kb=(256, 2048),
                   mults=TOLERANCE_MULTS,
                   schedulers=("two_level", "gto"),
                   suite: str | None = None) -> list[tuple[str, SimConfig]]:
    """The *screening-scale* grid for the analytical fast tier (ISSUE 9).

    `sweep_jobs`' design x workload matrix crossed with the full
    tolerated-latency axis, the RF-capacity axis, and the single-SM
    scheduler axis — thousands of unique points, far past what the
    cycle-accurate engine can sweep on the tracked host.  Meant for
    ``SimRunner.prefill(jobs, tier="analytic"|"hybrid")``; running it at
    ``tier="engine"`` is possible but takes hours, not milliseconds."""
    names = list(workloads) if workloads else list(workload_names(suite))
    # dict.fromkeys: designs that pin an axis (Ideal forces mult 1.0)
    # collapse to one point instead of repeating it per swept value
    return list(dict.fromkeys(
        (name, design_config(d, table2_config=7, rf_size_kb=kb,
                             mrf_latency_mult=float(m), scheduler=s))
        for kb in rf_sizes_kb for name in names for d in designs
        for m in mults for s in schedulers
    ))


def run_tier_sweep(jobs, tier: str, runner=None, top_k: int = 3):
    """Run `jobs` at `tier` through a `SimRunner`, returning
    ``(runner, report)``.  Thin convenience for notebooks/benchmarks: the
    caller keeps the runner to read confirmed `sim()` results or fast
    `estimate()`s afterwards."""
    from repro.serving.sweep import SimRunner
    runner = runner or SimRunner(processes=1)
    report = runner.prefill(list(jobs), tier=tier, top_k=top_k)
    return runner, report
