"""The canonical paper-figure sweep subset used for perf tracking.

This is the Fig. 14-shaped grid (both Table-2 design points x all designs x
the workload suite, plus the per-workload normalization baselines) that
`BENCH_sim.json` times.  Kept in its own module so the pre/post-change
measurements are guaranteed to run the *same* job list.

The default job list is pinned to the synthetic suite (`workload_names()`
with no suite): lazily-registered suites like ``traced`` never change the
tracked benchmark.  Pass ``suite="traced"`` (or ``"all"``) to sweep the
real lifted kernels instead.
"""
from __future__ import annotations

from repro.sim import SimConfig, baseline_config, design_config
from repro.workloads import workload_names

SWEEP_DESIGNS = ("BL", "RFC", "SHRF", "LTRF", "LTRF_conf", "LTRF_plus", "Ideal")


def sweep_jobs(workloads=None, designs=SWEEP_DESIGNS,
               table2_configs=(6, 7),
               suite: str | None = None) -> list[tuple[str, SimConfig]]:
    """(workload name, SimConfig) pairs for the tracked sweep subset."""
    names = list(workloads) if workloads else list(workload_names(suite))
    jobs: list[tuple[str, SimConfig]] = []
    for tc in table2_configs:
        for name in names:
            jobs.append((name, baseline_config()))
            for d in designs:
                jobs.append((name, design_config(d, table2_config=tc)))
    return jobs
