"""Differential fuzzing: fast engine vs the frozen golden engine.

A seeded random program generator (nested counted loops, if/else diamonds,
mixed ld/st/alu, varying register pressure) crossed with randomized
`SimConfig`s; every (program, config) pair must produce bit-identical
`SimResult`s from `sim.engine` and `sim.golden`.  Everything is driven by
stdlib ``random`` with fixed seeds (no hypothesis in this environment), so
a failure reproduces from its seed alone.

The golden engine only implements the paper's two-level scheduler and the
paper's interval-formation algorithm, so the differential pairs pin
``scheduler="two_level"`` and ``interval_strategy="paper"``; the new
gto/lrr policies, the capacity/fixed interval strategies, and the multi-SM
aggregation get their own fuzzed invariants below (determinism,
strategy-independent dynamic instruction counts, capacity working-set
bounds, GPU aggregation identities).
"""
from __future__ import annotations

import random
from dataclasses import replace

import pytest

from repro.core.ir import parse_asm
from repro.sim import DESIGNS, SimConfig, simulate, simulate_gpu
from repro.sim.golden import golden_simulate
from repro.workloads.suite import Workload

N_DIFF_SEEDS = 55  # >= 50 differential pairs (ISSUE 3 floor)


# --------------------------------------------------------------- generator

class _Gen:
    """Structured random-program emitter.

    Termination is by construction: backward branches are only emitted as
    counted loops (registered in ``trips``, which both engines consult for
    loop exits), and diamond branches only jump forward.
    """

    def __init__(self, rng: random.Random) -> None:
        self.rng = rng
        self.lines: list[str] = []
        self.trips: dict[str, int] = {}
        self.n_regs = rng.randint(8, 40)
        self.regs = list(range(self.n_regs))
        self.next_pred = 0
        self.next_label = 0

    def reg(self) -> int:
        return self.rng.choice(self.regs)

    def emit(self, line: str) -> None:
        self.lines.append(line)

    def body(self, n: int, mem_ratio: float) -> None:
        rng = self.rng
        for _ in range(n):
            roll = rng.random()
            if roll < mem_ratio:
                if rng.random() < 0.6:
                    self.emit(f"ld r{self.reg()}, [r{self.reg()}]")
                else:
                    self.emit(f"st r{self.reg()}, [r{self.reg()}]")
            elif roll < mem_ratio + 0.15:
                self.emit(f"mad r{self.reg()}, r{self.reg()}, "
                          f"r{self.reg()}, r{self.reg()}")
            else:
                op = rng.choice(("add", "mul", "sub"))
                self.emit(f"{op} r{self.reg()}, r{self.reg()}, r{self.reg()}")

    def diamond(self, mem_ratio: float) -> None:
        p = self.next_pred
        self.next_pred += 1
        k = self.next_label
        self.next_label += 1
        else_l, join_l = f"E{k}", f"J{k}"
        self.emit(f"set p{p}, r{self.reg()}, r{self.reg()}")
        self.emit(f"@!p{p} bra {else_l}")
        self.body(self.rng.randint(1, 4), mem_ratio)
        self.emit(f"bra {join_l}")
        self.emit(f"{else_l}: nop")
        self.body(self.rng.randint(1, 4), mem_ratio)
        self.emit(f"{join_l}: nop")

    def loop(self, depth: int, mem_ratio: float) -> None:
        rng = self.rng
        idx = len(self.trips)
        label = f"L{idx}"
        self.trips[label] = rng.randint(2, 4)
        ctr = rng.randrange(self.n_regs)
        self.emit(f"mov r{ctr}, 0")
        self.emit(f"{label}: nop")
        self.body(rng.randint(2, 8), mem_ratio)
        if depth > 1:
            self.loop(depth - 1, mem_ratio)
        elif rng.random() < 0.5:
            self.diamond(mem_ratio)
        p = self.next_pred
        self.next_pred += 1
        self.emit(f"add r{ctr}, r{ctr}, 1")
        self.emit(f"set p{p}, r{ctr}, r{ctr}")
        self.emit(f"@p{p} bra {label}")


def random_workload(seed: int) -> Workload:
    rng = random.Random(seed)
    g = _Gen(rng)
    mem_ratio = rng.uniform(0.1, 0.5)
    for r in g.regs:  # kernel parameters: no uninitialized reads
        g.emit(f"mov r{r}, {r + 1}")
    g.body(rng.randint(2, 6), 0.1)
    depth = rng.randint(0, 2)
    if depth:
        g.loop(depth, mem_ratio)
    if rng.random() < 0.4:
        g.diamond(mem_ratio)
    g.body(rng.randint(1, 4), 0.0)
    g.emit("exit")
    prog = parse_asm("\n".join(g.lines), name=f"fuzz{seed}")
    return Workload(name=f"fuzz{seed}", program=prog, trips=dict(g.trips),
                    register_sensitive=bool(rng.getrandbits(1)),
                    regs_per_thread=rng.randint(g.n_regs, g.n_regs + 24),
                    suite="fuzz", l1_hit=rng.choice((0.3, 0.6, 0.85)))


def random_config(seed: int, scheduler: str = "two_level",
                  interval_strategy: str = "paper") -> SimConfig:
    rng = random.Random(seed ^ 0x5EED)
    return SimConfig(
        interval_strategy=interval_strategy,
        design=rng.choice(DESIGNS),
        mrf_latency_mult=rng.choice((1.0, 1.6, 2.8, 5.3, 6.3)),
        rf_size_kb=rng.choice((64, 256, 2048)),
        rfc_size_kb=rng.choice((4, 16)),
        add_rfc_to_main=rng.random() < 0.3,
        num_warps=rng.randint(2, 8),
        active_slots=rng.choice((2, 4, 8)),
        issue_width=rng.randint(1, 4),
        num_banks=rng.choice((8, 16)),
        interval_cap=rng.choice((4, 8, 16, 32)),
        mem_cycles=rng.choice((120, 380)),
        l1_hit_rate=rng.choice((0.3, 0.85)),
        num_collectors=rng.choice((2, 4, 32)),
        max_inflight_prefetch=rng.choice((2, 12)),
        dram_interval=rng.choice((1, 4, 16)),
        seed=rng.randint(0, 9999),
        scheduler=scheduler,
    )


# ------------------------------------------------------------ differential

@pytest.mark.parametrize("seed", range(N_DIFF_SEEDS))
def test_fuzz_engine_matches_golden(seed):
    w = random_workload(seed)
    cfg = random_config(seed)
    fast = simulate(w, cfg)
    gold = golden_simulate(w, cfg)
    assert fast == gold, (seed, cfg.design, fast, gold)


def test_fuzz_generator_is_deterministic():
    a, b = random_workload(7), random_workload(7)
    assert a.program.render() == b.program.render()
    assert a.trips == b.trips
    assert random_config(7) == random_config(7)


def test_fuzz_programs_vary():
    renders = {random_workload(s).program.render() for s in range(10)}
    assert len(renders) == 10  # pressure/structure actually varies
    designs = {random_config(s).design for s in range(N_DIFF_SEEDS)}
    assert len(designs) >= 5  # config fuzz covers most designs


# ------------------------------------- scheduler-policy fuzzed invariants

@pytest.mark.parametrize("seed", range(12))
def test_fuzz_schedulers_deterministic_same_work(seed):
    """gto/lrr have no golden oracle; pin what must hold regardless of the
    schedule: determinism, and dynamic instruction counts identical to
    two_level (branch outcomes depend only on (wid, visit, seed))."""
    w = random_workload(100 + seed)
    base = random_config(100 + seed)
    ref = simulate(w, base)
    for sched in ("gto", "lrr"):
        cfg = replace(base, scheduler=sched)
        r = simulate(w, cfg)
        assert r == simulate(w, cfg), (seed, sched)
        assert r.instructions == ref.instructions, (seed, sched)
        assert r.resident_warps == ref.resident_warps


# ------------------------------------------ bank-model fuzzed invariants

@pytest.mark.parametrize("seed", range(10))
def test_fuzz_bank_model_none_noop_and_arbitrated_invariants(seed):
    """ISSUE 4: ``bank_model="none"`` must be bit-identical to the golden
    oracle with zero conflict counters; the arbitrated model is
    deterministic, retires the same dynamic instruction stream, and only
    ever *adds* latency bookkeeping."""
    w = random_workload(300 + seed)
    base = random_config(300 + seed)  # bank_model defaults to "none"
    none = simulate(w, base)
    assert none == golden_simulate(w, base), seed
    assert none.bank_conflicts == 0 and none.bank_conflict_cycles == 0
    arb_cfg = replace(base, bank_model="arbitrated")
    arb = simulate(w, arb_cfg)
    assert arb == simulate(w, arb_cfg), seed
    assert arb.instructions == none.instructions, seed
    assert arb.bank_conflict_cycles >= arb.bank_conflicts >= 0
    if base.design == "Ideal":
        assert arb == none  # Ideal is exempt from arbitration


@pytest.mark.parametrize("seed", range(6))
def test_fuzz_identity_renumber_equals_plain_ltrf(seed):
    """LTRF_conf with ``renumber="identity"`` ablates the coloring pass and
    must therefore be bit-identical to plain LTRF under any bank model."""
    w = random_workload(400 + seed)
    base = random_config(400 + seed)
    for bank_model in ("none", "arbitrated"):
        conf = replace(base, design="LTRF_conf", renumber="identity",
                       bank_model=bank_model)
        ltrf = replace(base, design="LTRF", bank_model=bank_model)
        a, b = simulate(w, conf), simulate(w, ltrf)
        # designs differ only in the ablated pass; counters must agree
        assert (a.cycles, a.instructions, a.mrf_accesses, a.rfc_hits,
                a.bank_conflicts, a.bank_conflict_cycles) == \
               (b.cycles, b.instructions, b.mrf_accesses, b.rfc_hits,
                b.bank_conflicts, b.bank_conflict_cycles), (seed, bank_model)


# ------------------------------------ interval-strategy fuzzed invariants

def _random_strategy(rng: random.Random) -> str:
    roll = rng.random()
    if roll < 1 / 3:
        return "capacity"
    if roll < 2 / 3:
        return f"fixed:{rng.choice((2, 4, 8))}"
    return "paper"


@pytest.mark.parametrize("seed", range(12))
def test_fuzz_interval_strategies(seed):
    """ISSUE 5: randomized ``interval_strategy`` — ``"paper"`` (the only
    strategy the frozen golden engine implements) must stay bit-identical
    to it; every strategy is deterministic and retires the same dynamic
    instruction stream; and under ``"capacity"`` every compiled interval's
    estimated working set fits the config's RFC entries-per-warp."""
    w = random_workload(500 + seed)
    rng = random.Random(500 + seed)
    base = random_config(500 + seed)  # interval_strategy defaults to "paper"
    paper = simulate(w, base)
    assert paper == golden_simulate(w, base), seed
    assert paper.prefetch_stall_cycles >= paper.prefetch_cycles >= 0

    strat = _random_strategy(rng)
    cfg = replace(base, interval_strategy=strat)
    r = simulate(w, cfg)
    assert r == simulate(w, cfg), (seed, strat)  # deterministic
    assert r.instructions == paper.instructions, (seed, strat)
    assert r.resident_warps == paper.resident_warps, (seed, strat)
    if strat == "paper":
        assert r == paper

    from repro.sim import Simulator
    cap_cfg = replace(base, interval_strategy="capacity")
    s = Simulator(cap_cfg, w)
    # the generator's widest instruction (mad) touches 4 registers and
    # random configs keep rfc_entries_per_warp >= 4, so the formation
    # algorithm's single-instruction escape hatch never fires: the bound
    # is exact, not approximate
    bound = cap_cfg.rfc_entries_per_warp
    assert bound >= 4, seed
    # (the knob is a no-op for SHRF/BL/RFC/Ideal — strand or no intervals)
    if cap_cfg.design in ("LTRF", "LTRF_conf", "LTRF_plus") and s.pf_ops:
        assert max(len(op.bitvector) for op in s.pf_ops.values()) <= bound, \
            (seed, cap_cfg.design)


# ---------------------------------------------- watchdog fuzzed invariants

@pytest.mark.parametrize("seed", range(600, 612))
def test_fuzz_watchdog_budget(seed):
    """ISSUE 6: the ``SimConfig.max_cycles`` watchdog.  A budget >= the
    run's final cycle count is a bit-identical no-op in both engines (the
    cache key deliberately ignores it); an artificially small budget raises
    the structured `SimBudgetExceeded` identically — same attributes, same
    trip cycle — from engine and golden."""
    from repro.sim import SimBudgetExceeded

    w = random_workload(seed)
    cfg = random_config(seed)
    ref = simulate(w, cfg)

    exact = replace(cfg, max_cycles=ref.cycles)
    assert simulate(w, exact) == ref, seed
    assert golden_simulate(w, exact) == golden_simulate(w, cfg) == ref, seed
    assert simulate(w, replace(cfg, max_cycles=ref.cycles + 1000)) == ref

    budget = max(1, ref.cycles // 3)
    tight = replace(cfg, max_cycles=budget)
    with pytest.raises(SimBudgetExceeded) as fast_exc:
        simulate(w, tight)
    with pytest.raises(SimBudgetExceeded) as gold_exc:
        golden_simulate(w, tight)
    f, g = fast_exc.value, gold_exc.value
    assert (f.design, f.workload, f.budget) == (cfg.design, w.name, budget)
    assert f.cycles > budget, seed
    assert (f.design, f.workload, f.budget, f.cycles) == \
           (g.design, g.workload, g.budget, g.cycles), seed


# ------------------------------------------- batch-engine fuzzed invariants

N_BATCH_SEEDS = 16


@pytest.mark.slow
def test_fuzz_batch_engine_matches_event_heap():
    """Differential A/B for the vectorized batch engine: one `run_batch`
    call over a pile of random (program, config) pairs must be bit-identical
    — every counter, the full cycle_breakdown — to per-job `simulate`.
    The fuzz configs all sit inside `batch_supported` (two_level scheduler,
    bank_model="none", untraced, single SM), so nothing here silently falls
    back to the scalar path."""
    from repro.sim import batch_supported, run_batch

    jobs = []
    for seed in range(N_BATCH_SEEDS):
        w = random_workload(900 + seed)
        cfg = random_config(900 + seed)
        assert batch_supported(cfg), seed
        jobs.append((w, cfg))
    for seed, (w, cfg), got in zip(range(N_BATCH_SEEDS), jobs,
                                   run_batch(jobs, fallback=False)):
        want = simulate(w, cfg)
        assert got == want, (seed, cfg.design, got, want)


@pytest.mark.slow
def test_fuzz_batch_watchdog_budget_parity():
    """The `max_cycles` watchdog trips identically in the batch engine: the
    returned `SimBudgetExceeded` *instance* carries the same (design,
    workload, budget, trip-cycle) the event engine raises, and a generous
    budget stays a bit-identical no-op."""
    from repro.sim import SimBudgetExceeded, run_batch

    for seed in (5, 11):  # reuse batch-fuzz pairs: compiles stay cached
        w = random_workload(900 + seed)
        cfg = random_config(900 + seed)
        ref = simulate(w, cfg)
        budget = max(1, ref.cycles // 3)
        tight, loose = (replace(cfg, max_cycles=budget),
                        replace(cfg, max_cycles=ref.cycles + 1000))
        out_tight, out_loose = run_batch([(w, tight), (w, loose)],
                                         fallback=False)
        assert out_loose == ref, seed
        assert isinstance(out_tight, SimBudgetExceeded), seed
        with pytest.raises(SimBudgetExceeded) as event_exc:
            simulate(w, tight)
        assert out_tight.args == event_exc.value.args, seed


@pytest.mark.slow
def test_fuzz_batch_time_skip_engages():
    """BATCH_REV 2's event-horizon skip, on random programs steered into
    long dead time: two warps, slow cold memory, the highest MRF latency
    point — whole stretches of cycles where no lane can issue.  The fused
    loop must spend strictly fewer ticks than a skip-free lockstep loop
    would (sum over chunks of the slowest lane's cycles), while every job
    stays bit-identical to the event engine."""
    from repro.sim import batch as B

    jobs = []
    for seed in range(930, 938):
        w = random_workload(seed)
        cfg = replace(random_config(seed), num_warps=2, mem_cycles=380,
                      l1_hit_rate=0.3, mrf_latency_mult=6.3,
                      max_inflight_prefetch=2)
        assert B.batch_supported(cfg), seed
        jobs.append((w, cfg))
    stats = B.reset_run_stats()
    outs = B.run_batch(jobs, fallback=False)
    for seed, (w, cfg), got in zip(range(930, 938), jobs, outs):
        assert got == simulate(w, cfg), (seed, cfg.design)
    lanes = [B._Lane(w, cfg, B._encode_plan(w, cfg), B._occupancy(w, cfg))
             for w, cfg in jobs]
    no_skip = sum(max(outs[i].cycles for i in idxs)
                  for _, idxs in B._chunk_lanes(lanes, list(range(len(jobs)))))
    assert 0 < stats["ticks"] < no_skip, (stats["ticks"], no_skip)


# -------------------------------------- observability fuzzed invariants

@pytest.mark.parametrize("seed", range(700, 718))
def test_fuzz_breakdown_sums_to_cycles(seed):
    """ISSUE 7 hard invariant: every simulated cycle lands in exactly one
    category of `repro.obs.CYCLE_CATEGORIES` — the breakdown sums exactly
    to the run's cycle count on random programs under random configs,
    including the schedulers and bank models the golden oracle doesn't
    implement.  (The engine itself re-checks this via `check_breakdown`;
    asserting here keeps the contract pinned even if that guard is ever
    relaxed.)"""
    from repro.obs import CYCLE_CATEGORIES

    w = random_workload(seed)
    rng = random.Random(seed)
    cfg = replace(random_config(seed),
                  scheduler=rng.choice(("two_level", "gto", "lrr")),
                  bank_model=rng.choice(("none", "arbitrated")))
    r = simulate(w, cfg)
    bd = r.cycle_breakdown
    assert tuple(bd) == CYCLE_CATEGORIES, seed
    assert sum(bd.values()) == r.cycles, (seed, cfg.design, bd, r.cycles)
    assert all(v >= 0 for v in bd.values()), (seed, bd)
    assert bd["issue"] > 0, seed  # every program retires something
    # SHRF prefetches strands, so it can stall on prefetch like LTRF;
    # the designs with no prefetch mechanism at all must never show it
    if cfg.design in ("BL", "RFC", "Ideal"):
        assert bd["prefetch_stall"] == 0, (seed, cfg.design)


@pytest.mark.parametrize("seed", range(750, 760))
def test_fuzz_trace_enabled_is_counter_neutral(seed):
    """The per-warp tracer is pure observation: enabling it must not
    perturb a single counter — `SimResult` equality with the untraced run
    (and `trace` is excluded from the sweep cache key for the same
    reason)."""
    from repro.serving.sweep import sim_key

    w = random_workload(seed)
    cfg = random_config(seed)
    traced_cfg = replace(cfg, trace=True)
    assert simulate(w, traced_cfg) == simulate(w, cfg), seed
    assert sim_key(w.name, traced_cfg) == sim_key(w.name, cfg), seed


@pytest.mark.parametrize("seed", range(760, 766))
def test_fuzz_trace_sink_spans_cover_the_run(seed):
    """A traced run's event stream is well-formed: every span/instant sits
    inside [0, cycles], warp track ids are real warps, and the scheduler
    track's stall spans are exactly the run's non-issue cycles."""
    from repro.obs import SCHED_TID, STALL_CATEGORIES, trace_simulation

    w = random_workload(seed)
    cfg = random_config(seed)
    res, sink = trace_simulation(w, cfg)
    assert sink.events, seed
    for ev in sink.events:
        assert 0 <= ev["ts"] <= res.cycles, (seed, ev)
        # warp instruction spans run to value-ready and may legitimately
        # outlive the run (a result nothing consumed); the scheduler
        # track's stall spans are cycle accounting and must stay inside it
        if ev["ph"] == "X" and ev["tid"] == SCHED_TID:
            assert ev["ts"] + ev["dur"] <= res.cycles, (seed, ev)
    sched_stall = sum(ev["dur"] for ev in sink.events
                      if ev["tid"] == SCHED_TID and ev["ph"] == "X"
                      and ev["name"] in STALL_CATEGORIES)
    assert sched_stall == sum(res.cycle_breakdown[c]
                              for c in STALL_CATEGORIES), seed


@pytest.mark.parametrize("seed", range(8))
def test_fuzz_gpu_breakdown_aggregation(seed):
    """GPU-level breakdown is the per-SM merge: category-wise sums match,
    and the total equals the sum of per-SM cycle counts (NOT the chip's
    max-over-SMs `cycles`)."""
    from repro.obs import CYCLE_CATEGORIES

    w = random_workload(800 + seed)
    rng = random.Random(seed)
    cfg = replace(random_config(800 + seed), num_sms=rng.randint(2, 4),
                  scheduler=rng.choice(("two_level", "gto", "lrr")))
    g = simulate_gpu(w, cfg)
    assert tuple(g.cycle_breakdown) == CYCLE_CATEGORIES
    for c in CYCLE_CATEGORIES:
        assert g.cycle_breakdown[c] == \
            sum(r.cycle_breakdown[c] for r in g.per_sm), (seed, c)
    assert sum(g.cycle_breakdown.values()) == \
        sum(r.cycles for r in g.per_sm), seed


@pytest.mark.parametrize("seed", range(8))
def test_fuzz_gpu_aggregation_identities(seed):
    """Multi-SM runs: instructions sum over SMs, cycles are the slowest SM,
    and the same chip config is deterministic end to end."""
    w = random_workload(200 + seed)
    rng = random.Random(seed)
    cfg = replace(random_config(200 + seed),
                  num_sms=rng.randint(2, 4),
                  mem_partitions=rng.choice((0, 1, 2)),
                  scheduler=rng.choice(("two_level", "gto", "lrr")),
                  bank_model=rng.choice(("none", "arbitrated")))
    g = simulate_gpu(w, cfg)
    assert g.instructions == sum(r.instructions for r in g.per_sm)
    assert g.cycles == max(r.cycles for r in g.per_sm)
    assert g.mrf_accesses == sum(r.mrf_accesses for r in g.per_sm)
    assert g.bank_conflicts == sum(r.bank_conflicts for r in g.per_sm)
    assert g.bank_conflict_cycles == \
        sum(r.bank_conflict_cycles for r in g.per_sm)
    assert len(g.per_sm) <= cfg.num_sms
    assert g == simulate_gpu(w, cfg)
