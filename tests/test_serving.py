"""Serving tests: Address Allocation Unit (paper Fig 13), two-level request
scheduler, and the end-to-end batched decode engine."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import get_smoke
from repro.serving import (
    PAGE_TOKENS, AddressAllocationUnit, ServeConfig, ServingEngine,
    TwoLevelScheduler,
)


# ---------------------------------------------------------------------------
# Address Allocation Unit
# ---------------------------------------------------------------------------

def test_aau_alloc_free_cycle():
    aau = AddressAllocationUnit(4)
    slots = [aau.alloc(owner=i) for i in range(4)]
    assert sorted(slots) == [0, 1, 2, 3]
    assert aau.alloc() is None            # exhausted
    aau.free(slots[1])
    assert aau.alloc(owner="x") == slots[1]  # FIFO reuse of the freed bank
    aau.check_invariants()


def test_aau_double_free_rejected():
    aau = AddressAllocationUnit(2)
    s = aau.alloc()
    aau.free(s)
    with pytest.raises(KeyError):
        aau.free(s)


@settings(max_examples=30, deadline=None)
@given(ops=st.lists(st.integers(0, 1), min_size=1, max_size=200),
       cap=st.integers(1, 16))
def test_aau_invariants_property(ops, cap):
    aau = AddressAllocationUnit(cap)
    held = []
    for op in ops:
        if op == 0:
            s = aau.alloc()
            if s is not None:
                held.append(s)
        elif held:
            aau.free(held.pop())
        aau.check_invariants()
    assert aau.used_count == len(held)


# ---------------------------------------------------------------------------
# two-level scheduler
# ---------------------------------------------------------------------------

def test_scheduler_runs_all_requests():
    aau = AddressAllocationUnit(32)
    s = TwoLevelScheduler(aau, active_slots=4)
    for _ in range(10):
        s.submit(prompt_len=100, max_new_tokens=20)
    s.run_to_completion()
    assert len(s.finished) == 10
    assert aau.used_count == 0  # all pages returned


def test_scheduler_respects_active_slots():
    aau = AddressAllocationUnit(64)
    s = TwoLevelScheduler(aau, active_slots=2)
    for _ in range(6):
        s.submit(prompt_len=10, max_new_tokens=50)
    s.admit()
    assert len(s.active) == 2


def test_scheduler_preempts_on_page_exhaustion():
    # pool barely fits one long request; the second gets preempted
    aau = AddressAllocationUnit(3)
    s = TwoLevelScheduler(aau, active_slots=2)
    s.submit(prompt_len=PAGE_TOKENS, max_new_tokens=2 * PAGE_TOKENS)
    s.submit(prompt_len=PAGE_TOKENS, max_new_tokens=2 * PAGE_TOKENS)
    s.run_to_completion()
    assert len(s.finished) == 2
    assert s.preemptions >= 1


def test_scheduler_page_accounting():
    aau = AddressAllocationUnit(16)
    s = TwoLevelScheduler(aau, active_slots=4)
    r = s.submit(prompt_len=PAGE_TOKENS * 2 + 5, max_new_tokens=4)
    s.admit()
    assert len(r.pages) == r.pages_needed() == 3


# ---------------------------------------------------------------------------
# end-to-end engine
# ---------------------------------------------------------------------------

def test_engine_generates_tokens():
    cfg = get_smoke("tinyllama-1.1b")
    eng = ServingEngine(cfg, sc=ServeConfig(max_len=64, active_slots=4,
                                            total_pages=16))
    rs = [eng.submit([1, 2, 3], max_new_tokens=5) for _ in range(3)]
    out = eng.run()
    for r in rs:
        toks = out[r.rid]
        assert len(toks) >= 5
        assert all(0 <= t < cfg.vocab for t in toks)


def test_engine_deterministic():
    cfg = get_smoke("qwen3-0.6b")
    def run_once():
        eng = ServingEngine(cfg, sc=ServeConfig(max_len=32, active_slots=2,
                                                total_pages=8))
        r = eng.submit([5], max_new_tokens=6)
        return eng.run()[r.rid]
    assert run_once() == run_once()
