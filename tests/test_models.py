"""Per-architecture smoke tests: reduced configs, one forward/train step and
one decode step on CPU, asserting output shapes and finiteness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_arch, get_smoke
from repro.models import decode_step, init_decode_cache, init_params, loss_fn
from repro.optim.adamw import AdamWConfig, adamw_update, init_opt_state

B, S = 2, 64


def _batch(cfg):
    if cfg.family == "vlm":
        return {"tokens": jnp.ones((B, S - cfg.n_patches), jnp.int32),
                "patches": jnp.zeros((B, cfg.n_patches, cfg.d_model), cfg.jdtype),
                "labels": jnp.ones((B, S), jnp.int32)}
    if cfg.family == "audio":
        return {"codes": jnp.ones((B, cfg.n_codebooks, S), jnp.int32),
                "labels": jnp.ones((B, cfg.n_codebooks, S), jnp.int32)}
    return {"tokens": jnp.ones((B, S), jnp.int32),
            "labels": jnp.ones((B, S), jnp.int32)}


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_smoke_forward_loss(arch_id):
    cfg = get_smoke(arch_id)
    params, axes = init_params(cfg, jax.random.PRNGKey(0))
    assert jax.tree.structure(params) == jax.tree.structure(
        axes, is_leaf=lambda x: isinstance(x, tuple))
    loss, metrics = jax.jit(lambda p, b: loss_fn(p, b, cfg))(params, _batch(cfg))
    assert jnp.isfinite(loss), arch_id
    assert float(loss) > 0


@pytest.mark.parametrize("arch_id", [
    pytest.param(a, marks=pytest.mark.slow) if a == "zamba2-1.2b" else a
    for a in ARCH_IDS])
def test_smoke_train_step_no_nans(arch_id):
    cfg = get_smoke(arch_id)
    params, _ = init_params(cfg, jax.random.PRNGKey(0))
    opt = init_opt_state(params)

    @jax.jit
    def step(p, o, b):
        (loss, _), g = jax.value_and_grad(
            lambda pp: loss_fn(pp, b, cfg), has_aux=True)(p)
        return adamw_update(AdamWConfig(lr=1e-3), p, g, o) + (loss,)

    p2, o2, m, loss = step(params, opt, _batch(cfg))
    for leaf in jax.tree.leaves(p2):
        assert np.isfinite(np.asarray(leaf, np.float32)).all(), arch_id
    assert jnp.isfinite(m["grad_norm"])
    assert float(m["grad_norm"]) > 0


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_smoke_decode_step(arch_id):
    cfg = get_smoke(arch_id)
    params, _ = init_params(cfg, jax.random.PRNGKey(0))
    cache, _ = init_decode_cache(cfg, B, 32)
    tok = (jnp.ones((B, cfg.n_codebooks, 1), jnp.int32)
           if cfg.family == "audio" else jnp.ones((B, 1), jnp.int32))
    logits, cache2 = jax.jit(
        lambda p, c, t: decode_step(p, c, t, jnp.int32(3), cfg))(params, cache, tok)
    if cfg.family == "audio":
        assert logits.shape == (B, 1, cfg.n_codebooks, cfg.vocab)
    else:
        assert logits.shape == (B, 1, cfg.vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    # cache structurally unchanged
    assert jax.tree.structure(cache2) == jax.tree.structure(cache)


@pytest.mark.parametrize("arch_id", [
    "tinyllama-1.1b", "mamba2-1.3b",
    pytest.param("zamba2-1.2b", marks=pytest.mark.slow),
    "granite-moe-3b-a800m"])
def test_unrolled_matches_scanned(arch_id):
    """scan_layers=False must compute the same function (roofline probes)."""
    import dataclasses
    cfg = get_smoke(arch_id)
    params, _ = init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg)
    l1, _ = jax.jit(lambda p, b: loss_fn(p, b, cfg))(params, batch)
    cfg2 = dataclasses.replace(cfg, scan_layers=False)
    l2, _ = jax.jit(lambda p, b: loss_fn(p, b, cfg2))(params, batch)
    np.testing.assert_allclose(float(l1), float(l2), rtol=2e-2, atol=1e-3)


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_full_configs_match_assignment(arch_id):
    """The full configs carry the exact assigned hyperparameters."""
    cfg = get_arch(arch_id)
    expect = {
        "phi3-medium-14b": (40, 5120, 40, 10, 17920, 100352),
        "tinyllama-1.1b": (22, 2048, 32, 4, 5632, 32000),
        "granite-20b": (52, 6144, 48, 1, 24576, 49152),
        "qwen3-0.6b": (28, 1024, 16, 8, 3072, 151936),
        "granite-moe-3b-a800m": (32, 1536, 24, 8, 512, 49155),
        "dbrx-132b": (40, 6144, 48, 8, 10752, 100352),
        "llava-next-34b": (60, 7168, 56, 8, 20480, 64000),
        "musicgen-large": (48, 2048, 32, 32, 8192, 2048),
        "mamba2-1.3b": (48, 2048, 0, 0, 0, 50280),
        "zamba2-1.2b": (38, 2048, 32, 32, 8192, 32000),
    }[arch_id]
    got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
           cfg.d_ff, cfg.vocab)
    assert got == expect, (arch_id, got, expect)
    if arch_id == "granite-moe-3b-a800m":
        assert (cfg.n_experts, cfg.top_k) == (40, 8)
    if arch_id == "dbrx-132b":
        assert (cfg.n_experts, cfg.top_k) == (16, 4)
    if arch_id == "mamba2-1.3b":
        assert cfg.ssm_state == 128
    if arch_id == "zamba2-1.2b":
        assert cfg.ssm_state == 64 and cfg.attn_every == 6
    if arch_id == "qwen3-0.6b":
        assert cfg.qk_norm


def test_param_count_sane():
    # analytic parameter counts should be in the right ballpark
    assert 13e9 < get_arch("phi3-medium-14b").param_count() < 16e9
    assert 0.9e9 < get_arch("tinyllama-1.1b").param_count() < 1.4e9
    assert 110e9 < get_arch("dbrx-132b").param_count() < 150e9
    dbrx = get_arch("dbrx-132b")
    assert dbrx.active_param_count() < dbrx.param_count() / 2


@pytest.mark.slow
def test_decode_matches_prefill_logits():
    """Decoding token-by-token must match teacher-forced forward logits."""
    from repro.models.lm import embed_inputs, forward
    cfg = get_smoke("tinyllama-1.1b")
    params, _ = init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 8), 0, cfg.vocab)
    # teacher-forced
    x, pos = embed_inputs(params, cfg, {"tokens": toks})
    h, _ = forward(params, cfg, x, pos)
    full_logits = h @ params["lm_head"]
    # step-by-step
    cache, _ = init_decode_cache(cfg, 1, 16)
    outs = []
    for t in range(8):
        logits, cache = decode_step(params, cache, toks[:, t:t + 1],
                                    jnp.int32(t), cfg)
        outs.append(logits[:, 0])
    dec_logits = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec_logits, np.float32),
                               np.asarray(full_logits, np.float32),
                               rtol=5e-2, atol=5e-2)


@pytest.mark.slow
def test_fp8_kv_cache_decode_close_to_bf16():
    """Quantized (fp8) KV cache: half the decode memory, logits stay close."""
    import dataclasses
    from repro.configs import get_smoke
    cfg = get_smoke("musicgen-large")
    params, _ = init_params(cfg, jax.random.PRNGKey(0))
    toks = jnp.ones((2, cfg.n_codebooks, 1), jnp.int32)

    def run(kv_dtype):
        c = dataclasses.replace(cfg, kv_dtype=kv_dtype)
        cache, _ = init_decode_cache(c, 2, 16)
        logits = None
        for t in range(4):
            logits, cache = decode_step(params, cache, toks, jnp.int32(t), c)
        return np.asarray(logits, np.float32)

    a = run("")                      # bf16 cache
    b = run("float8_e4m3fn")         # fp8 cache
    assert b.nbytes == a.nbytes      # logits same shape/dtype
    # fp8 quantization noise is visible but bounded
    np.testing.assert_allclose(a, b, rtol=0.35, atol=0.6)
    assert np.corrcoef(a.ravel(), b.ravel())[0, 1] > 0.98
