"""Behavioural tests for the SM performance model (paper's §7 trends).

Uses reduced warp counts to keep each simulation fast; the full-size numbers
are produced by the benchmark harness.
"""
import pytest

from repro.sim import (
    SimConfig, baseline_config, design_config, max_tolerable_latency, simulate,
)
from repro.workloads import WORKLOADS

FAST = dict(num_warps=32)


def run(wname, design, tc=7, **kw):
    cfg = design_config(design, table2_config=tc, **{**FAST, **kw})
    return simulate(WORKLOADS[wname], cfg)


def base_ipc(wname):
    return simulate(WORKLOADS[wname], baseline_config(num_warps=32)).ipc


def test_simulation_is_deterministic():
    a = run("srad", "LTRF")
    b = run("srad", "LTRF")
    assert (a.cycles, a.instructions, a.mrf_accesses) == \
           (b.cycles, b.instructions, b.mrf_accesses)


def test_all_instructions_execute():
    r = run("kmeans", "BL")
    r2 = run("kmeans", "LTRF")
    assert r.instructions == r2.instructions  # same dynamic work


def test_occupancy_scales_with_rf_size():
    w = WORKLOADS["srad"]  # 72 regs/thread
    small = simulate(w, SimConfig(design="BL", rf_size_kb=256))
    big = simulate(w, SimConfig(design="BL", rf_size_kb=2048))
    assert big.resident_warps > small.resident_warps
    assert big.resident_warps == 64


def test_insensitive_occupancy_already_maxed():
    w = WORKLOADS["btree"]
    small = simulate(w, SimConfig(design="BL", rf_size_kb=256))
    assert small.resident_warps == 64


def test_ideal_beats_slow_bl_on_sensitive():
    assert run("srad", "Ideal").ipc > run("srad", "BL").ipc


def test_ltrf_tolerates_slow_mrf_better_than_bl():
    """Fig 14 core claim at config #7 (6.3x)."""
    for wname in ("srad", "mri-q"):
        assert run(wname, "LTRF").ipc > run(wname, "BL").ipc


def test_ltrf_conf_at_least_ltrf():
    # per-workload dynamics may wobble a couple percent (the compile-time
    # cost model minimizes (max conflicts, total rounds), not dynamic cycles);
    # the aggregate must improve.
    total_ltrf = total_conf = 0.0
    for wname in ("srad", "mri-q", "stencil"):
        total_ltrf += run(wname, "LTRF").ipc
        conf = run(wname, "LTRF_conf").ipc
        total_conf += conf
        assert conf >= 0.93 * run(wname, "LTRF").ipc
    assert total_conf >= total_ltrf * 0.999


def test_strands_worse_than_intervals():
    """Fig 19: strand-bounded prefetch regions underperform intervals."""
    for wname in ("srad", "sgemm", "btree"):
        assert run(wname, "SHRF").ipc < run(wname, "LTRF").ipc


def test_rfc_hit_rate_low_on_sensitive():
    """Fig 4: hardware register cache thrashes (8-30% hit rates).

    Must run at the paper's 64 warps/SM — the thrash comes from the full
    warp population contending for 128 cache entries."""
    for wname in ("srad", "sgemm", "mri-q"):
        r = run(wname, "RFC", num_warps=64)
        assert r.hit_rate < 0.4, (wname, r.hit_rate)


def test_ltrf_all_accesses_hit_cache():
    r = run("srad", "LTRF")
    assert r.hit_rate == 1.0  # guaranteed by interval prefetch


def test_ltrf_reduces_mrf_traffic_vs_bl():
    """§5.3 power proxy: prefetch-only MRF traffic < per-operand traffic."""
    bl = run("srad", "BL")
    lt = run("srad", "LTRF")
    assert lt.mrf_accesses < bl.mrf_accesses


def test_max_tolerable_latency_ordering():
    """Fig 15: LTRF_conf >= LTRF >= RFC (paper: 6.9x / 5.3x / 2.1x)."""
    tol = {d: max_tolerable_latency(WORKLOADS["mri-q"], d, num_warps=32)
           for d in ("RFC", "LTRF", "LTRF_conf")}
    assert tol["LTRF_conf"] >= tol["LTRF"] >= 1.0
    assert tol["LTRF"] >= tol["RFC"] or tol["LTRF_conf"] > tol["RFC"]


def test_prefetch_ops_counted():
    r = run("srad", "LTRF")
    assert r.prefetch_ops > 0
    assert r.prefetch_cycles > 0
    r2 = run("srad", "BL")
    assert r2.prefetch_ops == 0


def test_active_warps_sensitivity():
    """Fig 18: more active slots help until ~8."""
    w = WORKLOADS["srad"]
    ipc4 = simulate(w, design_config("LTRF", active_slots=4, **FAST)).ipc
    ipc8 = simulate(w, design_config("LTRF", active_slots=8, **FAST)).ipc
    assert ipc8 > ipc4


def test_interval_cap_sensitivity_runs():
    """Fig 17 machinery: different caps produce different schedules."""
    a = simulate(WORKLOADS["srad"], design_config("LTRF", interval_cap=8, **FAST))
    b = simulate(WORKLOADS["srad"], design_config("LTRF", interval_cap=32, **FAST))
    assert a.prefetch_ops != b.prefetch_ops


def test_warps_per_sm_variants():
    """Fig 20 machinery: the model runs at 16..128 warps."""
    w = WORKLOADS["kmeans"]
    for n in (16, 64, 128):
        r = simulate(w, design_config("LTRF", num_warps=n))
        assert r.instructions > 0


def test_ltrf_plus_liveness_variant():
    """§3.2 LTRF+: liveness-aware refetch moves strictly less MRF data and
    never hurts IPC materially (paper: it strictly improves)."""
    for wname in ("srad", "mri-q"):
        lt = run(wname, "LTRF")
        lp = run(wname, "LTRF_plus")
        assert lp.mrf_accesses < lt.mrf_accesses
        assert lp.ipc >= 0.97 * lt.ipc


def test_paper_mrf_traffic_claim():
    """§5.2: LTRF reduces MRF accesses by 4-6x vs BL."""
    bl = run("srad", "BL", num_warps=64)
    lt = run("srad", "LTRF", num_warps=64)
    assert 3.0 <= bl.mrf_accesses / lt.mrf_accesses <= 8.0


@pytest.mark.slow
def test_power_model_paper_claims():
    """§5.3: LTRF saves ~23% power same-tech; §1: DWM 8x + LTRF saves ~46%.

    Asserted over the register-sensitive suite (measured: +25%/+39%); our
    low-L1-hit insensitive workloads over-charge LTRF's deactivation churn
    relative to the paper's benchmarks (documented deviation)."""
    import statistics
    from repro.sim.power import power_comparison
    rows = [power_comparison(WORKLOADS[n])
            for n in ("srad", "hotspot", "sgemm", "mri-q")]
    same = statistics.mean(r["same_tech_saving"] for r in rows)
    dwm = statistics.mean(r["dwm_8x_saving"] for r in rows)
    assert 0.10 <= same <= 0.45   # paper: 0.23
    assert 0.25 <= dwm <= 0.60    # paper: 0.46
    for r in rows:
        assert r["ltrf_8x_power"] < r["bl_power"]
