"""Test-suite configuration.

Provides a deterministic fallback for ``hypothesis`` when the real package is
not installed (it is an optional dev dependency, see pyproject.toml): property
tests then run against a small fixed set of pseudo-random examples instead of
being skipped outright.  With hypothesis installed, the real package is used
untouched.
"""
from __future__ import annotations

import sys

try:
    import hypothesis  # noqa: F401
except ImportError:
    import random
    import types
    import zlib

    _FALLBACK_EXAMPLES = 5  # per-test cap: keep the fallback suite fast

    class _Strategy:
        def __init__(self, draw):
            self.draw = draw

    def _integers(min_value, max_value):
        return _Strategy(lambda rng: rng.randint(min_value, max_value))

    def _floats(min_value=0.0, max_value=1.0, **_kw):
        return _Strategy(lambda rng: rng.uniform(min_value, max_value))

    def _booleans():
        return _Strategy(lambda rng: bool(rng.getrandbits(1)))

    def _sampled_from(seq):
        items = list(seq)
        return _Strategy(lambda rng: items[rng.randrange(len(items))])

    def _lists(elements, min_size=0, max_size=10, **_kw):
        def draw(rng):
            return [elements.draw(rng)
                    for _ in range(rng.randint(min_size, max_size))]
        return _Strategy(draw)

    def _given(**strategies):
        def deco(fn):
            def wrapper():
                declared = getattr(wrapper, "_max_examples", _FALLBACK_EXAMPLES)
                n = min(declared, _FALLBACK_EXAMPLES)
                for i in range(n):
                    seed = zlib.crc32(f"{fn.__module__}.{fn.__qualname__}:{i}"
                                      .encode())
                    rng = random.Random(seed)
                    kwargs = {k: s.draw(rng) for k, s in strategies.items()}
                    try:
                        fn(**kwargs)
                    except Exception:
                        print(f"falsifying example ({fn.__qualname__}): "
                              f"{kwargs}", file=sys.stderr)
                        raise
            wrapper.__name__ = fn.__name__
            wrapper.__qualname__ = fn.__qualname__
            wrapper.__doc__ = fn.__doc__
            wrapper.__module__ = fn.__module__
            return wrapper
        return deco

    def _settings(max_examples=None, **_ignored):
        def deco(fn):
            if max_examples is not None:
                fn._max_examples = max_examples
            return fn
        return deco

    st_mod = types.ModuleType("hypothesis.strategies")
    st_mod.integers = _integers
    st_mod.floats = _floats
    st_mod.booleans = _booleans
    st_mod.sampled_from = _sampled_from
    st_mod.lists = _lists

    hyp_mod = types.ModuleType("hypothesis")
    hyp_mod.given = _given
    hyp_mod.settings = _settings
    hyp_mod.strategies = st_mod
    hyp_mod.__version__ = "0.0-fallback"

    sys.modules["hypothesis"] = hyp_mod
    sys.modules["hypothesis.strategies"] = st_mod
