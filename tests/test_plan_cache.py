"""Tests for the compile cache (core.plan_cache) and sweep orchestrator."""
import sys

import pytest

from repro.core.ir import parse_asm
from repro.core.plan_cache import (
    cache_clear, cache_stats, cached_intervals, cached_prefetch_ops,
    cached_renumber, compile_for_sim, program_fingerprint,
)
from repro.sim import SimConfig, Simulator, design_config, simulate
from repro.workloads import WORKLOADS

ASM = """
    mov r0, 0
    mov r1, 8
L1: ld r2, [r0]
    add r3, r2, r1
    add r0, r0, 4
    set p0, r0, r1
    @p0 bra L1
    exit
"""


def test_fingerprint_is_structural():
    a = parse_asm(ASM, name="a")
    b = parse_asm(ASM, name="b")  # different object, same structure
    assert a is not b
    assert program_fingerprint(a) == program_fingerprint(b)
    c = parse_asm(ASM.replace("add r3, r2, r1", "add r3, r2, r2"), name="c")
    assert program_fingerprint(a) != program_fingerprint(c)


def test_interval_analysis_shared_across_equal_programs():
    a = parse_asm(ASM, name="a")
    b = parse_asm(ASM, name="b")
    assert cached_intervals(a, 8) is cached_intervals(b, 8)
    assert cached_intervals(a, 8) is not cached_intervals(a, 4)


def test_compile_shared_across_simulators_and_latency_points():
    w = WORKLOADS["srad"]
    s1 = Simulator(design_config("LTRF", mrf_latency_mult=2.0), w)
    s2 = Simulator(design_config("LTRF", mrf_latency_mult=6.3), w)
    # the MRF latency multiplier is not a compile input: one shared plan
    assert s1.prog is s2.prog
    assert s1.pf_ops is s2.pf_ops
    s3 = Simulator(design_config("LTRF_conf", mrf_latency_mult=2.0), w)
    assert s3.prog is not s1.prog  # renumbering produces its own program


def test_compile_cache_hits_counted():
    prog = parse_asm(ASM, name="stats")
    before = cache_stats()
    compile_for_sim(prog, "LTRF", 8, 16)
    compile_for_sim(prog, "LTRF", 8, 16)
    after = cache_stats()
    assert after["hits"] > before["hits"]
    assert after["sim_plans"] >= 1


def test_cached_passes_match_direct_results():
    from repro.core.intervals import form_register_intervals
    from repro.core.prefetch import prefetch_schedule
    prog = parse_asm(ASM, name="direct")
    an_direct = form_register_intervals(prog, 8)
    an_cached = cached_intervals(prog, 8)
    assert [iv.working_set for iv in an_direct.intervals] == \
           [iv.working_set for iv in an_cached.intervals]
    ops_direct = prefetch_schedule(an_direct, num_banks=16)
    ops_cached = cached_prefetch_ops(an_cached, num_banks=16)
    assert {o.interval_id: o.bitvector for o in ops_direct} == \
           {i: o.bitvector for i, o in ops_cached.items()}
    rr = cached_renumber(prog, 8, 16)
    assert rr is cached_renumber(prog, 8, 16)


def test_analysis_caches_key_on_interval_grouping():
    """Two analyses over the SAME split program with the same cap and the
    same interval *count* but different block groupings must not collide in
    the prefetch/ICG caches (reachable via custom interval strategies)."""
    from repro.core.intervals import Interval, IntervalAnalysis

    prog = parse_asm("""
        mov r0, 1
        bra B
    B:  add r1, r0, r0
        bra C
    C:  add r2, r1, r1
        exit
    """, name="grouping")
    a_label = prog.order[0]

    def grouped(pairs):
        intervals = [Interval(iid=i, header=blocks[0], blocks=list(blocks),
                              working_set=set().union(
                                  *(prog.blocks[b].refs() for b in blocks)))
                     for i, blocks in enumerate(pairs)]
        bi = {b: iv.iid for iv in intervals for b in iv.blocks}
        return IntervalAnalysis(prog=prog, intervals=intervals,
                                block_interval=bi, n_cap=8)

    an1 = grouped([(a_label, "B"), ("C",)])
    an2 = grouped([(a_label,), ("B", "C")])
    ops1 = cached_prefetch_ops(an1, 16)
    ops2 = cached_prefetch_ops(an2, 16)
    assert ops1 is not ops2
    assert ops1[0].bitvector != ops2[0].bitvector
    # ...and neither must analyses with identical grouping whose working
    # sets differ (e.g. a liveness-trimming custom strategy)
    an3 = grouped([(a_label, "B"), ("C",)])
    for iv in an3.intervals:
        iv.working_set = {min(iv.working_set)}
    ops3 = cached_prefetch_ops(an3, 16)
    assert ops3 is not ops1
    assert ops3[0].bitvector == frozenset({min(ops1[0].bitvector)})


def test_cache_clear_resets():
    prog = parse_asm(ASM, name="clear-me")
    cached_intervals(prog, 8)
    cache_clear()
    assert cache_stats()["intervals"] == 0
    # and the cache repopulates fine afterwards
    assert cached_intervals(prog, 8).intervals


# ------------------------------------------------------------- orchestrator

def _orchestrator():
    sys.path.insert(0, str(__import__("pathlib").Path(__file__).parent.parent))
    from benchmarks import orchestrator
    return orchestrator


def test_runner_memo_and_disk_cache(tmp_path):
    orch = _orchestrator()
    cfg = SimConfig(design="LTRF", num_warps=8)
    runner = orch.SimRunner(processes=1, cache_dir=tmp_path)
    a = runner.sim("kmeans", cfg)
    assert runner.stats["computed"] == 1
    b = runner.sim("kmeans", cfg)
    assert b is a and runner.stats["memo_hits"] == 1
    # a fresh runner sharing the cache dir replays from disk, exactly
    runner2 = orch.SimRunner(processes=1, cache_dir=tmp_path)
    c = runner2.sim("kmeans", cfg)
    assert runner2.stats["disk_hits"] == 1 and runner2.stats["computed"] == 0
    assert c == simulate(WORKLOADS["kmeans"], cfg)


def test_runner_prefill_dedupes(tmp_path):
    orch = _orchestrator()
    cfg = SimConfig(design="BL", num_warps=8)
    runner = orch.SimRunner(processes=1, cache_dir=tmp_path)
    runner.prefill([("bfs", cfg)] * 5 + [("nw", cfg)])
    assert runner.stats["computed"] == 2
    assert runner.sim("bfs", cfg) == simulate(WORKLOADS["bfs"], cfg)


def test_runner_parallel_prefill_matches_serial(tmp_path):
    orch = _orchestrator()
    jobs = [(n, SimConfig(design=d, num_warps=8))
            for n in ("kmeans", "btree") for d in ("BL", "LTRF")]
    par = orch.SimRunner(processes=2, cache_dir=tmp_path / "p")
    par.prefill(jobs)
    for name, cfg in jobs:
        assert par.sim(name, cfg) == simulate(WORKLOADS[name], cfg)
