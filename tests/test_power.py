"""Pins for the §5.3 power proxy (`repro.sim.power`).

Covers the previously-untested arithmetic of `rf_power` / `PowerReport`,
the whole-GPU aggregation in `gpu_rf_power`, and the ordering property the
paper claims: LTRF consumes no more register-file energy than the baseline
on a cached workload (same tech and on the DWM 8x design point).
"""
import pytest

from repro.sim import SimResult, design_config, simulate
from repro.sim.gpu import GpuResult
from repro.sim.power import (
    E_MRF, E_RFC, E_WCB, P_STATIC, RFC_STATIC, WCB_OVERHEAD,
    PowerReport, gpu_rf_power, power_comparison, rf_power,
)
from repro.workloads import WORKLOADS


def _res(**kw):
    base = dict(design="BL", workload="x", cycles=1000, instructions=500,
                resident_warps=8)
    base.update(kw)
    return SimResult(**base)


def test_power_report_total():
    r = PowerReport(design="BL", tech="hp-sram", dynamic=1.5, static=0.4)
    assert r.total == pytest.approx(1.9)


def test_rf_power_uncached_arithmetic():
    r = rf_power(_res(mrf_accesses=2000), "hp-sram", cap_mult=1)
    assert r.dynamic == pytest.approx(2000 * E_MRF["hp-sram"] / 1000)
    assert r.static == pytest.approx(P_STATIC["hp-sram"])
    assert r.total == pytest.approx(2.0 + 0.40)


def test_rf_power_cached_arithmetic():
    res = _res(design="LTRF", mrf_accesses=100, rfc_accesses=1000,
               rfc_hits=800, prefetch_ops=10)
    r = rf_power(res, "dwm", cap_mult=8)
    want_dyn = (100 * E_MRF["dwm"] + 1000 * E_RFC + 1010 * E_WCB) / 1000
    assert r.dynamic == pytest.approx(want_dyn)
    assert r.static == pytest.approx(
        P_STATIC["dwm"] * 8.0 + RFC_STATIC + WCB_OVERHEAD)


def test_rf_power_has_cache_override():
    res = _res(mrf_accesses=100)  # no rfc accesses -> inferred uncached
    inferred = rf_power(res, "hp-sram")
    forced = rf_power(res, "hp-sram", has_cache=True)
    assert inferred.static == pytest.approx(P_STATIC["hp-sram"])
    assert forced.static == pytest.approx(
        P_STATIC["hp-sram"] + RFC_STATIC + WCB_OVERHEAD)
    assert forced.dynamic == inferred.dynamic  # zero cache accesses


def test_rf_power_zero_cycles_guarded():
    r = rf_power(_res(cycles=0, mrf_accesses=10), "hp-sram")
    assert r.dynamic == pytest.approx(10 * E_MRF["hp-sram"])  # /max(cycles,1)


@pytest.mark.parametrize("tech", sorted(E_MRF))
def test_rf_power_all_techs(tech):
    r = rf_power(_res(mrf_accesses=500), tech)
    assert r.tech == tech
    assert r.dynamic == pytest.approx(500 * E_MRF[tech] / 1000)


def _gres(num_sms=2, **kw):
    base = dict(design="LTRF", workload="x", num_sms=num_sms,
                scheduler="two_level", cycles=1000, instructions=2000,
                resident_warps=16)
    base.update(kw)
    return GpuResult(**base)


def test_gpu_rf_power_scales_static_with_sms():
    res = _gres(num_sms=4, mrf_accesses=400, rfc_accesses=2000,
                rfc_hits=2000, prefetch_ops=40)
    r = gpu_rf_power(res, "dwm", cap_mult=8)
    want_dyn = (400 * E_MRF["dwm"] + 2000 * E_RFC + 2040 * E_WCB) / 1000
    assert r.dynamic == pytest.approx(want_dyn)
    assert r.static == pytest.approx(
        (P_STATIC["dwm"] * 8.0 + RFC_STATIC + WCB_OVERHEAD) * 4)


def test_gpu_rf_power_one_sm_matches_single():
    counters = dict(mrf_accesses=300, rfc_accesses=900, rfc_hits=900,
                    prefetch_ops=12)
    single = rf_power(_res(design="LTRF", **counters), "tfet", cap_mult=8)
    gpu = gpu_rf_power(_gres(num_sms=1, **counters), "tfet", cap_mult=8)
    assert gpu.dynamic == pytest.approx(single.dynamic)
    assert gpu.static == pytest.approx(single.static)


def test_power_comparison_ordering_on_cached_workload():
    """Paper §5.3/§1: LTRF energy <= BL energy (same tech and DWM 8x)."""
    row = power_comparison(WORKLOADS["srad"])
    assert row["ltrf_same_tech_power"] <= row["bl_power"]
    assert row["ltrf_8x_power"] <= row["bl_power"]
    assert row["same_tech_saving"] > 0
    assert row["dwm_8x_saving"] > 0


def test_power_comparison_accepts_memoizing_runner():
    calls = []

    def counting_sim(w, cfg):
        calls.append(cfg.design)
        return simulate(w, cfg)

    row = power_comparison(WORKLOADS["kmeans"], sim=counting_sim)
    assert len(calls) == 3  # BL baseline + LTRF 8x + LTRF same-tech
    assert row["workload"] == "kmeans"


def test_design_power_uses_sim_counters():
    """rf_power over real sim results: LTRF on the DWM 8x point draws less
    register-file power than the §6 baseline, and moves less MRF energy
    than BL at the same design point."""
    from repro.sim import baseline_config
    w = WORKLOADS["srad"]
    base = simulate(w, baseline_config(num_warps=16))
    bl = simulate(w, design_config("BL", table2_config=7, num_warps=16))
    lt = simulate(w, design_config("LTRF", table2_config=7, num_warps=16))
    assert rf_power(lt, "dwm", cap_mult=8).total \
        < rf_power(base, "hp-sram", cap_mult=1).total
    # MRF *energy* (access count x per-access cost), not per-cycle power:
    # LTRF's prefetch-only traffic moves far less data than BL's per-operand
    # reads even though LTRF finishes in fewer cycles.
    assert lt.mrf_accesses * E_MRF["dwm"] < bl.mrf_accesses * E_MRF["dwm"]
