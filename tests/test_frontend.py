"""Frontend: jaxpr lifting, linear-scan regalloc, traced-workload registry.

Covers the acceptance bar for the real-kernel path: every traced workload
lifts end to end, its interval plan validates across caps, both simulator
engines agree bit-for-bit across all 7 designs, the allocator honours
``maxregcount`` (including the spill fallback), and the suite registry keeps
the tracked synthetic job list stable while exposing the traced suite.
"""
import subprocess
import sys

import pytest

from repro.core.intervals import form_register_intervals
from repro.core.ir import back_edges, parse_asm, reachable_blocks
from repro.frontend.regalloc import allocate_registers
from repro.frontend.workloads import TRACED_NAMES, build_traced_workload
from repro.kernels._compat import jax_subprocess_env
from repro.sim import DESIGNS, design_config, simulate
from repro.sim.golden import golden_simulate
from repro.workloads import (WORKLOADS, Workload, get_workload,
                             register_workload, workload_names)

# The three in-repo kernel references the acceptance criteria name.
KERNEL_NAMES = ("traced_matmul", "traced_attention", "traced_ssd")


# --------------------------------------------------------------------- lift

@pytest.mark.parametrize("name", TRACED_NAMES)
def test_lift_end_to_end(name):
    w = get_workload(name)
    w.program.validate()
    assert w.program.num_instrs() > 15
    assert w.suite == "traced"
    # the whole CFG is reachable and every loop resolves through the trip table
    assert reachable_blocks(w.program) == set(w.program.order)
    for (_u, header) in back_edges(w.program):
        assert header in w.trips, f"loop {header} missing a trip count"
    assert 0 < w.regs_per_thread <= 64


@pytest.mark.parametrize("name", TRACED_NAMES)
@pytest.mark.parametrize("cap", (8, 16, 32))
def test_traced_interval_plan_validates(name, cap):
    w = get_workload(name)
    an = form_register_intervals(w.program, n_cap=cap)
    an.validate()
    assert len(an.intervals) >= 1


def test_lift_is_deterministic():
    a = build_traced_workload("traced_rmsnorm")
    import repro.core.plan_cache as pc
    pc.cache_clear()
    try:
        b = build_traced_workload("traced_rmsnorm")
    finally:
        pc.cache_clear()
    assert a.program.render() == b.program.render()
    assert a.trips == b.trips and a.regs_per_thread == b.regs_per_thread


def test_lift_cond_and_while():
    """Diamonds (`cond`) and default-trip loops (`while`) lift and terminate."""
    import jax

    def f(x):
        y = jax.lax.cond(x[0] > 0, lambda v: v * 2.0, lambda v: v - 1.0, x)

        def body(c):
            i, v = c
            return i + 1, v * 1.1

        return jax.lax.while_loop(lambda c: c[0] < 5, body, (0, y[0]))[1]

    from repro.frontend.jaxpr_lift import lift_fn

    lifted = lift_fn(f, (jax.ShapeDtypeStruct((4,), "float32"),),
                     name="condwhile")
    lifted.prog.validate()
    w = Workload(name="condwhile", program=lifted.prog, trips=lifted.trips,
                 register_sensitive=False, regs_per_thread=16, suite="test")
    cfg = design_config("LTRF", table2_config=7, num_warps=4)
    r = simulate(w, cfg)
    assert r.instructions > 0 and r.cycles > 0
    assert simulate(w, cfg) == golden_simulate(w, cfg)


# ------------------------------------------------------- engine equivalence

@pytest.mark.parametrize("design", DESIGNS)
def test_traced_kernels_match_golden_all_designs(design):
    for name in KERNEL_NAMES:
        w = get_workload(name)
        cfg = design_config(design, table2_config=7, num_warps=8)
        assert simulate(w, cfg) == golden_simulate(w, cfg), (design, name)


def test_traced_layers_match_golden():
    for name in set(TRACED_NAMES) - set(KERNEL_NAMES):
        w = get_workload(name)
        cfg = design_config("LTRF_plus", table2_config=6, num_warps=8)
        assert simulate(w, cfg) == golden_simulate(w, cfg), name


# ----------------------------------------------------------------- regalloc

def test_regalloc_respects_maxregcount():
    for name in ("traced_attention", "traced_mlp"):
        w = build_traced_workload(name, maxregcount=24)
        assert w.regs_per_thread <= 24
        assert max(w.program.registers()) < 24


def test_regalloc_spill_path_still_simulates():
    full = build_traced_workload("traced_attention", maxregcount=64)
    tight = build_traced_workload("traced_attention", maxregcount=16)
    assert tight.regs_per_thread <= 16
    # spilling rewrites uses through memory: strictly more ld/st traffic
    def mem_ops(w):
        return sum(1 for _, _, ins in w.program.instructions() if ins.is_mem)
    assert mem_ops(tight) > mem_ops(full)
    cfg = design_config("LTRF", table2_config=7, num_warps=4)
    assert simulate(tight, cfg) == golden_simulate(tight, cfg)


# Exact allocator output per (traced workload, maxregcount) — pinned when
# frontend/regalloc dropped its private `_live_intervals` in favor of the
# core liveness pass via the pipeline (ISSUE 5): the refactor must not move
# a single spill.  Format: (regs_per_thread, spills, spill_loads, spill_stores)
REGALLOC_GOLDEN = {
    ("traced_matmul", 64): (29, 0, 0, 0),
    ("traced_matmul", 24): (22, 9, 19, 17),
    ("traced_attention", 64): (30, 0, 0, 0),
    ("traced_attention", 24): (22, 17, 38, 32),
    ("traced_ssd", 64): (23, 0, 0, 0),
    ("traced_ssd", 24): (23, 0, 0, 0),
    ("traced_rmsnorm", 64): (8, 0, 0, 0),
    ("traced_rmsnorm", 24): (8, 0, 0, 0),
    ("traced_mlp", 64): (31, 0, 0, 0),
    ("traced_mlp", 24): (22, 18, 46, 31),
    ("traced_attn_layer", 64): (36, 0, 0, 0),
    ("traced_attn_layer", 24): (23, 23, 48, 38),
}


@pytest.mark.parametrize("name", TRACED_NAMES)
def test_regalloc_output_pinned_on_traced_suite(name):
    """Regression pin for the liveness dedup: `allocate_registers` through
    the core pipeline's liveness pass produces exactly the pre-refactor
    spill counts and register demands on the whole traced suite."""
    from repro.frontend.jaxpr_lift import lift_fn
    from repro.frontend.workloads import TRACED_SPECS

    spec = TRACED_SPECS[name]
    fn, args = spec.builder()
    lifted = lift_fn(fn, args, name=name, while_trips=spec.while_trips)
    for mrc in (64, 24):
        a = allocate_registers(lifted.prog, maxregcount=mrc)
        got = (a.regs_per_thread, a.spill_count, a.spill_loads,
               a.spill_stores)
        assert got == REGALLOC_GOLDEN[(name, mrc)], (name, mrc, got)


def test_regalloc_has_no_private_liveness():
    """The frontend must reuse `repro.core.liveness` through the pipeline —
    the duplicated `_live_intervals` implementation is gone for good."""
    from repro.frontend import regalloc

    assert not hasattr(regalloc, "_live_intervals")
    import inspect
    src = inspect.getsource(regalloc)
    assert "frontend_passes" in src and "back_edges" not in src


def test_regalloc_no_spill_for_small_programs():
    prog = parse_asm("""
        mov r0, 1
        mov r1, 2
        L1: add r2, r0, r1
        add r0, r2, r1
        exit
    """, name="tiny")
    res = allocate_registers(prog, maxregcount=8)
    assert not res.spilled
    assert res.regs_per_thread == 3
    assert res.spill_loads == res.spill_stores == 0


# ----------------------------------------------------------------- registry

def test_default_names_exclude_traced_even_after_loading():
    get_workload("traced_matmul")  # force the lazy suite in
    default = workload_names()
    assert len(default) == 14
    assert not any(n.startswith("traced_") for n in default)
    assert set(workload_names("traced")) == set(TRACED_NAMES)
    assert set(TRACED_NAMES) <= set(workload_names("all"))


def test_register_workload_collision_raises():
    with pytest.raises(ValueError):
        register_workload(WORKLOADS["srad"])
    register_workload(WORKLOADS["srad"], replace=True)  # explicit is fine


def test_sweep_jobs_suite_selector():
    from benchmarks.sweep_subset import sweep_jobs

    default_names = {n for n, _ in sweep_jobs()}
    assert default_names == set(workload_names())
    traced_names = {n for n, _ in sweep_jobs(suite="traced")}
    assert traced_names == set(TRACED_NAMES)


def test_orchestrator_runs_traced_jobs():
    from benchmarks.orchestrator import SimRunner

    runner = SimRunner(processes=1, disk_cache=False)
    cfg = design_config("LTRF", table2_config=7, num_warps=4)
    res = runner.sim("traced_rmsnorm", cfg)
    assert res == simulate(get_workload("traced_rmsnorm"), cfg)
    assert runner.stats["computed"] == 1
    runner.sim("traced_rmsnorm", cfg)
    assert runner.stats["memo_hits"] == 1


# ------------------------------------------------------------- subprocess env

def test_lift_in_subprocess_via_env_helper():
    """Tracing in a child process must pin JAX_PLATFORMS or it can hang on
    TPU-less-libtpu hosts; jax_subprocess_env is the one sanctioned recipe."""
    script = ("from repro.workloads import get_workload; "
              "w = get_workload('traced_rmsnorm'); "
              "print('LIFT_OK', w.regs_per_thread)")
    r = subprocess.run([sys.executable, "-c", script], capture_output=True,
                       text=True, timeout=300, env=jax_subprocess_env())
    assert "LIFT_OK" in r.stdout, r.stdout + r.stderr
