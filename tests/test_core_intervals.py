"""Unit + property tests for register-interval formation (Algorithms 1 & 2)."""
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import form_register_intervals, parse_asm
from repro.core.ir import back_edges, reachable_blocks
from repro.workloads import WORKLOADS, listing1_program
from repro.workloads.synth import SynthSpec, synthesize


def all_programs():
    progs = [("listing1", listing1_program())]
    progs += [(w.name, w.program) for w in WORKLOADS.values()]
    return progs


@pytest.mark.parametrize("ncap", [4, 8, 16, 32])
@pytest.mark.parametrize("name,prog", all_programs())
def test_single_entry_property(name, prog, ncap):
    an = form_register_intervals(prog, n_cap=ncap)
    headers = {iv.iid: iv.header for iv in an.intervals}
    for bb in an.prog:
        i = an.block_interval[bb.label]
        for s in bb.succs:
            j = an.block_interval[s]
            if i != j:
                assert s == headers[j], "inter-interval edge must enter at header"


@pytest.mark.parametrize("ncap", [4, 8, 16, 32])
@pytest.mark.parametrize("name,prog", all_programs())
def test_working_set_cap(name, prog, ncap):
    an = form_register_intervals(prog, n_cap=ncap)
    for iv in an.intervals:
        worst_instr = max(
            (len(set(ins.regs)) for b in iv.blocks for ins in an.prog.blocks[b].instrs),
            default=0,
        )
        assert len(iv.working_set) <= max(ncap, worst_instr)


@pytest.mark.parametrize("name,prog", all_programs())
def test_partition_is_total_and_disjoint(name, prog):
    an = form_register_intervals(prog, n_cap=16)
    seen = {}
    for iv in an.intervals:
        for b in iv.blocks:
            assert b not in seen, f"block {b} in two intervals"
            seen[b] = iv.iid
    assert set(seen) == set(an.prog.order)
    for b, i in an.block_interval.items():
        assert seen[b] == i


def test_instructions_preserved_by_splitting():
    prog = listing1_program()
    an = form_register_intervals(prog, n_cap=2)  # forces splits
    assert an.prog.num_instrs() == prog.num_instrs()
    orig = [i.render() for _, _, i in prog.instructions()]
    new = [i.render() for _, _, i in an.prog.instructions()]
    assert sorted(orig) == sorted(new)


def test_loop_is_single_interval_when_it_fits():
    """Paper Fig. 5: pass 2 folds a whole loop into one interval."""
    prog = parse_asm("""
        mov r0, 0
        mov r1, 100
    LO: nop
        add r2, r0, r1
    LI: add r3, r2, r0
        set p0, r3, r1
        @p0 bra LI
        add r0, r0, 1
        set p1, r0, r1
        @p1 bra LO
        exit
    """)
    an = form_register_intervals(prog, n_cap=16)
    # everything fits -> a single interval containing both nested loops
    assert len(an.intervals) == 1
    be = back_edges(an.prog)
    assert len(be) == 2  # structure intact


def test_pass2_respects_cap():
    prog = listing1_program()
    an1 = form_register_intervals(prog, n_cap=4, run_pass2=False)
    an2 = form_register_intervals(prog, n_cap=4, run_pass2=True)
    assert len(an2.intervals) <= len(an1.intervals)
    for iv in an2.intervals:
        assert len(iv.working_set) <= 4


def test_listing1_loop_fits_with_cap7():
    """With cap >= 7 (r0..r6) the whole Listing-1 kernel is one interval."""
    an = form_register_intervals(listing1_program(), n_cap=7)
    assert len(an.intervals) == 1


def test_strand_mode_terminates_at_loads():
    prog = listing1_program()
    strands = form_register_intervals(prog, n_cap=16, strand_mode=True)
    intervals = form_register_intervals(prog, n_cap=16)
    # strands split after memory ops and skip pass 2 -> strictly more regions
    assert len(strands.intervals) > len(intervals.intervals)
    for iv in strands.intervals:
        mem_positions = []
        seq = [ins for b in iv.blocks for ins in strands.prog.blocks[b].instrs]
        for k, ins in enumerate(seq):
            if ins.is_mem:
                mem_positions.append(k)
        # a memory op inside a strand may only be the last instruction of its block
    assert strands.prog.num_instrs() == prog.num_instrs()


def test_call_blocks_are_solo_intervals():
    prog = parse_asm("""
        mov r0, 1
        add r1, r0, r0
        call helper
        add r2, r1, r0
        exit
    """)
    an = form_register_intervals(prog, n_cap=16)
    solo = [iv for iv in an.intervals if iv.solo]
    assert len(solo) == 1
    blocks = solo[0].blocks
    instrs = [i for b in blocks for i in an.prog.blocks[b].instrs]
    assert len(instrs) == 1 and instrs[0].op == "call"


@settings(max_examples=30, deadline=None)
@given(
    seed=st.integers(0, 10_000),
    n_regs=st.integers(6, 48),
    depth=st.integers(0, 3),
    body=st.integers(4, 24),
    mem=st.floats(0.0, 0.6),
    diamonds=st.integers(0, 2),
    ncap=st.sampled_from([4, 8, 16, 32]),
)
def test_property_interval_invariants(seed, n_regs, depth, body, mem, diamonds, ncap):
    spec = SynthSpec(name="prop", seed=seed, n_regs=n_regs, loop_depth=depth,
                     body_len=body, mem_ratio=mem, diamonds=diamonds,
                     trips=tuple([3] * max(depth, 1)))
    prog, _ = synthesize(spec)
    an = form_register_intervals(prog, n_cap=ncap)
    an.validate()
    # instruction multiset preserved
    assert an.prog.num_instrs() == prog.num_instrs()
    # every reachable block assigned
    for b in reachable_blocks(an.prog):
        assert b in an.block_interval
