"""Chaos suite for the fault-tolerant sweep service (repro.serving.sweep).

Every failure mode the dispatcher claims to survive is exercised here
deterministically through the fault-injection harness
(`repro.serving.faults`): transient raises retried with backoff, worker
crashes recovered by pool recycling, hangs cut off by wall-clock timeouts,
deterministic budget blowups (`SimBudgetExceeded`) recorded without
retries, corrupt/truncated/mis-schema'd cache entries quarantined, leaked
tmp files garbage-collected, and the ENGINE/PLAN/PIPELINE rev triple keying
the on-disk cache.  The final test is the ISSUE-6 acceptance sweep: 56 jobs
under one crash + one hang + one transient + one corrupt entry must
complete, retry with backoff, quarantine the torn entry on replay, and
report exactly the injected failures.
"""
from __future__ import annotations

import json
import os
import pathlib
import pickle
import subprocess
import sys
import time

import pytest

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent))

from repro.serving import faults
from repro.serving import sweep as sweep_mod
from repro.serving.sweep import (
    FAILURE_KINDS, FailureRecord, ResultStore, SimRunner, SweepConfig,
    SweepReport, job_label, sim_key,
)
from repro.sim import SimBudgetExceeded, SimConfig, simulate
from repro.workloads import WORKLOADS

pytestmark = pytest.mark.filterwarnings(
    "ignore::DeprecationWarning")  # os.fork + threads (jax) in pool workers

CFG = SimConfig(design="LTRF", num_warps=4)
FAST = SweepConfig(backoff_base_s=0.01, backoff_max_s=0.05)


def _arm(tmp_path, monkeypatch, fault_specs) -> pathlib.Path:
    plan = tmp_path / "fault_plan.json"
    plan.write_text(json.dumps({"faults": fault_specs}))
    monkeypatch.setenv(faults.ENV_PLAN, str(plan))
    return plan


def _jobs(workloads=("kmeans", "bfs"), designs=("BL", "LTRF"), seeds=3):
    return [(n, SimConfig(design=d, num_warps=4, seed=s))
            for n in workloads for d in designs for s in range(seeds)]


# ------------------------------------------------------------ fault harness

def test_fault_point_is_noop_without_plan(monkeypatch, tmp_path):
    monkeypatch.delenv(faults.ENV_PLAN, raising=False)
    faults.fault_point("run", "anything/BL/seed0")  # must not raise


def test_fault_times_bounded_across_processes(tmp_path, monkeypatch):
    plan = _arm(tmp_path, monkeypatch,
                [{"match": "x/BL/seed0", "action": "raise", "times": 2}])
    for _ in range(2):
        with pytest.raises(faults.InjectedFault):
            faults.fault_point("run", "x/BL/seed0")
    faults.fault_point("run", "x/BL/seed0")  # exhausted: no-op
    state = plan.with_suffix(plan.suffix + ".state")
    assert sorted(p.name for p in state.iterdir()) == ["f0.hit0", "f0.hit1"]


def test_fault_spec_validation():
    with pytest.raises(ValueError):
        faults.FaultSpec(match="x", action="explode")
    with pytest.raises(ValueError):
        faults.FaultSpec(match="x", action="raise", stage="compile")


# ------------------------------------------------------- retry and backoff

def test_transient_fault_retried_with_backoff(tmp_path, monkeypatch):
    label = "bfs/BL/seed0"
    _arm(tmp_path, monkeypatch,
         [{"match": label, "action": "raise", "times": 2}])
    sweep = SweepConfig(max_attempts=3, backoff_base_s=0.1,
                        backoff_factor=2.0, backoff_max_s=2.0)
    runner = SimRunner(processes=2, cache_dir=tmp_path / "cache", sweep=sweep)
    t0 = time.monotonic()
    report = runner.prefill(_jobs())
    wall = time.monotonic() - t0
    assert report.ok and report.completed == report.total
    assert report.retried == {label: 2}
    assert report.retry_kinds[label] == ["transient", "transient"]
    assert report.failed == []
    # exponential backoff actually waited: 0.1s after attempt 1, 0.2s after
    # attempt 2 (deterministic sleeps, so this lower bound cannot flake)
    assert wall >= 0.3
    assert runner.stats["retried"] == 2
    # and the retried job's result is exact
    cfg = SimConfig(design="BL", num_warps=4, seed=0)
    assert runner.sim("bfs", cfg) == simulate(WORKLOADS["bfs"], cfg)


def test_transient_retry_inline_single_process(tmp_path, monkeypatch):
    label = "kmeans/LTRF/seed0"
    _arm(tmp_path, monkeypatch,
         [{"match": label, "action": "raise", "times": 1}])
    runner = SimRunner(processes=1, cache_dir=tmp_path / "cache", sweep=FAST)
    report = runner.prefill(_jobs(workloads=("kmeans",), designs=("LTRF",)))
    assert report.ok and report.retried == {label: 1}
    assert report.computed == report.total == 3


def test_permanent_failure_degrades_gracefully(tmp_path, monkeypatch):
    label = "nw/BL/seed1"
    _arm(tmp_path, monkeypatch, [{"match": label, "action": "raise"}])
    runner = SimRunner(processes=2, cache_dir=tmp_path / "cache",
                       sweep=SweepConfig(max_attempts=2, backoff_base_s=0.01))
    jobs = _jobs(workloads=("nw",), designs=("BL",), seeds=4)
    report = runner.prefill(jobs)
    assert not report.ok
    assert [(f.job, f.kind, f.attempts) for f in report.failed] == \
        [(label, "transient", 2)]
    assert report.failed[0].key == sim_key("nw", jobs[1][1])
    assert report.completed == report.total - 1 == 3
    assert runner.stats["failed"] == 1
    # try_sim degrades to None for the failed point, works for the others
    assert runner.try_sim("nw", jobs[1][1]) is None
    assert runner.try_sim("nw", jobs[0][1]) is not None
    # the report is JSON-serializable for artifacts
    round_trip = json.loads(json.dumps(report.to_dict()))
    assert round_trip["failed"][0]["kind"] == "transient"
    assert round_trip["ok"] is False


# ----------------------------------------------------- crashes and timeouts

def test_worker_crash_recycles_pool_and_retries(tmp_path, monkeypatch):
    label = "kmeans/LTRF/seed1"
    _arm(tmp_path, monkeypatch,
         [{"match": label, "action": "exit", "times": 1}])
    runner = SimRunner(processes=2, cache_dir=tmp_path / "cache", sweep=FAST)
    jobs = _jobs()
    report = runner.prefill(jobs)
    assert report.ok and report.completed == report.total == len(jobs)
    assert report.pool_recycles >= 1
    assert "crash" in report.retry_kinds[label]
    # no job may fail because a *neighbor* crashed the pool: innocents are
    # re-executed without being charged an attempt
    assert report.failed == []
    for name, cfg in jobs:
        assert runner.sim(name, cfg) == simulate(WORKLOADS[name], cfg)


def test_repeated_crashes_exhaust_attempts(tmp_path, monkeypatch):
    label = "bfs/LTRF/seed0"
    _arm(tmp_path, monkeypatch, [{"match": label, "action": "exit"}])
    runner = SimRunner(processes=2, cache_dir=tmp_path / "cache",
                       sweep=SweepConfig(max_attempts=2, backoff_base_s=0.01))
    report = runner.prefill(_jobs(seeds=2))
    assert [(f.job, f.kind) for f in report.failed] == [(label, "crash")]
    assert report.failed[0].attempts == 2
    assert report.completed == report.total - 1
    assert report.pool_recycles >= 2


def test_hung_worker_times_out_and_job_retries(tmp_path, monkeypatch):
    label = "kmeans/LTRF/seed2"
    _arm(tmp_path, monkeypatch,
         [{"match": label, "action": "hang", "seconds": 60, "times": 1}])
    runner = SimRunner(
        processes=2, cache_dir=tmp_path / "cache",
        sweep=SweepConfig(job_timeout_s=1.5, backoff_base_s=0.01))
    t0 = time.monotonic()
    report = runner.prefill(_jobs(workloads=("kmeans",), designs=("LTRF",)))
    wall = time.monotonic() - t0
    assert report.ok and report.completed == report.total
    assert report.retry_kinds[label] == ["timeout"]
    assert report.pool_recycles >= 1
    assert wall < 30  # the 60s sleeper was killed, not waited out


def test_budget_blowup_recorded_not_retried(tmp_path):
    runner = SimRunner(
        processes=2, cache_dir=tmp_path / "cache",
        sweep=SweepConfig(watchdog_max_cycles=50, backoff_base_s=0.01))
    report = runner.prefill(_jobs(workloads=("kmeans",), designs=("BL",)))
    assert not report.ok and len(report.failed) == report.total
    for rec in report.failed:
        assert rec.kind == "budget"
        assert rec.attempts == 1          # deterministic: never retried
        assert "max_cycles=50" in rec.detail
    assert report.retried == {}


def test_per_job_max_cycles_overrides_sweep_watchdog(tmp_path):
    runner = SimRunner(
        processes=1, cache_dir=tmp_path / "cache",
        sweep=SweepConfig(watchdog_max_cycles=50))
    cfg = SimConfig(design="BL", num_warps=4, max_cycles=10_000_000)
    report = runner.prefill([("kmeans", cfg)])
    assert report.ok  # the job's own (ample) budget wins over the sweep's


def test_sim_budget_exceeded_pickles():
    exc = SimBudgetExceeded("BL", "kmeans", 50, 51)
    back = pickle.loads(pickle.dumps(exc))
    assert (back.design, back.workload, back.budget, back.cycles) == \
        ("BL", "kmeans", 50, 51)
    assert "max_cycles=50" in str(back)


# ----------------------------------------------- cache integrity/quarantine

def _seed_cache(tmp_path) -> tuple[pathlib.Path, str]:
    runner = SimRunner(processes=1, cache_dir=tmp_path / "cache")
    runner.sim("kmeans", CFG)
    return tmp_path / "cache", sim_key("kmeans", CFG)


@pytest.mark.parametrize("corruption", ["truncated", "empty", "wrong_schema",
                                        "bit_rot", "mis_keyed"])
def test_corrupt_entry_quarantined_not_silently_recomputed(
        tmp_path, corruption):
    cache_dir, key = _seed_cache(tmp_path)
    entry_path = cache_dir / f"{key}.json"
    if corruption == "truncated":
        text = entry_path.read_text()
        entry_path.write_text(text[: len(text) // 2])
    elif corruption == "empty":
        entry_path.write_text("")
    elif corruption == "wrong_schema":
        # valid checksummed envelope whose payload is not a SimResult
        ResultStore(cache_dir).store(key, {"bogus": 1})
    elif corruption == "bit_rot":
        doc = json.loads(entry_path.read_text())
        doc["payload"]["cycles"] += 1  # flip a counter, keep old checksum
        entry_path.write_text(json.dumps(doc))
    elif corruption == "mis_keyed":
        doc = json.loads(entry_path.read_text())
        doc["key"] = "0" * 20
        entry_path.write_text(json.dumps(doc))

    runner = SimRunner(processes=1, cache_dir=cache_dir)
    res = runner.sim("kmeans", CFG)
    # recomputed (correct result), with the corruption on the record
    assert res == simulate(WORKLOADS["kmeans"], CFG)
    assert runner.stats["computed"] == 1 and runner.stats["disk_hits"] == 0
    assert runner.stats["quarantined"] == 1
    q = cache_dir / "quarantine"
    assert (q / f"{key}.json").exists()          # the evidence, preserved
    record = json.loads((q / f"{key}.failure.json").read_text())
    assert record["key"] == key and record["reason"]
    assert record["job"] == job_label(("kmeans", CFG))
    # the recompute healed the cache: a fresh runner disk-hits cleanly
    healed = SimRunner(processes=1, cache_dir=cache_dir)
    assert healed.sim("kmeans", CFG) == res
    assert healed.stats["disk_hits"] == 1 and healed.stats["quarantined"] == 0


def test_quarantine_surfaces_in_sweep_report(tmp_path):
    cache_dir, key = _seed_cache(tmp_path)
    text = (cache_dir / f"{key}.json").read_text()
    (cache_dir / f"{key}.json").write_text(text[: len(text) // 2])
    runner = SimRunner(processes=1, cache_dir=cache_dir)
    report = runner.prefill([("kmeans", CFG), ("bfs", CFG)])
    assert report.ok  # quarantine degrades to recompute, not failure
    assert [(q.job, q.kind, q.key) for q in report.quarantined] == \
        [(job_label(("kmeans", CFG)), "corrupt", key)]
    assert report.computed == 2 and report.cached == 0


def test_store_load_round_trip_and_stats(tmp_path):
    store = ResultStore(tmp_path / "store")
    store.store("k1", {"a": 1, "b": [2, 3]})
    assert store.load("k1") == {"a": 1, "b": [2, 3]}
    assert store.load("missing") is None
    assert store.stats == {"hits": 1, "misses": 1, "stores": 1,
                           "quarantined": 0, "tmp_gc": 0}


# ------------------------------------------------------------- tmp-file GC

def test_crashed_writer_tmp_file_collected_on_startup(tmp_path):
    cache_dir = tmp_path / "cache"
    cache_dir.mkdir()
    # a writer that died mid-publish: grab a real-but-dead pid so the
    # liveness probe (os.kill 0) takes the ProcessLookupError path
    dead_pid = subprocess.run(
        [sys.executable, "-c", "import os; print(os.getpid())"],
        capture_output=True, text=True, check=True).stdout.strip()
    leaked = cache_dir / f"{'a' * 20}.tmp{dead_pid}"
    leaked.write_text('{"v": 1, "half an entr')
    runner = SimRunner(processes=1, cache_dir=cache_dir)
    assert not leaked.exists()
    assert runner.stats["tmp_gc"] == 1
    report = runner.prefill([("kmeans", CFG)])
    assert report.tmp_files_removed == 1


def test_live_writer_tmp_file_left_alone(tmp_path):
    cache_dir = tmp_path / "cache"
    cache_dir.mkdir()
    mine = cache_dir / f"{'b' * 20}.tmp{os.getpid()}"
    mine.write_text("in-flight write")
    runner = SimRunner(processes=1, cache_dir=cache_dir)
    assert mine.exists()  # this process is alive: not stale
    assert runner.stats["tmp_gc"] == 0


# ----------------------------------------------------- cache-key revisions

def test_sim_key_includes_all_three_revs(monkeypatch):
    base = sim_key("kmeans", CFG)
    for rev in ("ENGINE_REV", "PLAN_REV", "PIPELINE_REV"):
        monkeypatch.setattr(sweep_mod, rev, getattr(sweep_mod, rev) + 1)
        assert sim_key("kmeans", CFG) != base, rev
        monkeypatch.undo()
    assert sim_key("kmeans", CFG) == base


@pytest.mark.parametrize("rev", ["ENGINE_REV", "PLAN_REV", "PIPELINE_REV"])
def test_rev_bump_misses_disk_cache(tmp_path, monkeypatch, rev):
    """The satellite regression: a compiler-side (PLAN/PIPELINE) or
    engine-side rev bump must invalidate cached SimResults."""
    cache_dir, _ = _seed_cache(tmp_path)
    monkeypatch.setattr(sweep_mod, rev, getattr(sweep_mod, rev) + 1)
    runner = SimRunner(processes=1, cache_dir=cache_dir)
    runner.sim("kmeans", CFG)
    assert runner.stats["computed"] == 1 and runner.stats["disk_hits"] == 0


def test_sim_key_ignores_max_cycles():
    """The watchdog can only abort a run, never change a completed result,
    so budgeted and unbudgeted sweeps must share cache entries."""
    from dataclasses import replace
    assert sim_key("kmeans", CFG) == \
        sim_key("kmeans", replace(CFG, max_cycles=12345))
    assert sim_key("kmeans", CFG) != sim_key("kmeans", replace(CFG, seed=1))


# --------------------------------------------------------------- acceptance

def test_chaos_acceptance_sweep(tmp_path, monkeypatch):
    """ISSUE-6 acceptance: a 56-job sweep under one injected worker crash,
    one hang, one twice-firing transient, and one corrupt cache write
    completes, retries with backoff, quarantines the torn entry on replay,
    and reports exactly the injected failures."""
    transient, crash = "bfs/BL/seed0", "kmeans/LTRF/seed1"
    hang, corrupt = "srad/LTRF/seed6", "nw/BL/seed3"
    _arm(tmp_path, monkeypatch, [
        {"match": transient, "action": "raise", "times": 2},
        {"match": crash, "action": "exit", "times": 1},
        {"match": hang, "action": "hang", "seconds": 60, "times": 1},
        {"match": corrupt, "stage": "store", "action": "corrupt", "times": 1},
    ])
    jobs = [(n, SimConfig(design=d, num_warps=4, seed=s))
            for n in ("kmeans", "bfs", "nw", "srad")
            for d in ("BL", "LTRF") for s in range(7)]
    assert len(jobs) == 56
    runner = SimRunner(
        processes=2, cache_dir=tmp_path / "cache",
        sweep=SweepConfig(max_attempts=3, backoff_base_s=0.02,
                          job_timeout_s=5.0))
    report = runner.prefill(jobs)

    assert report.ok and report.completed == report.total == 56
    assert report.failed == []
    assert report.retry_kinds[transient] == ["transient", "transient"]
    assert "crash" in report.retry_kinds[crash]
    assert any(k in ("timeout", "crash") for k in report.retry_kinds[hang])
    assert report.pool_recycles >= 1
    # exactly the injected failures: any other retried job may only be an
    # innocent bystander of the injected pool break (uncharged "crash")
    for label, kinds in report.retry_kinds.items():
        if label not in (transient, crash, hang):
            assert set(kinds) == {"crash"}, (label, kinds)

    # replay with faults off: the torn entry quarantines and recomputes;
    # everything else disk-hits; results are bit-exact vs direct simulation
    monkeypatch.delenv(faults.ENV_PLAN)
    replay = SimRunner(processes=2, cache_dir=tmp_path / "cache")
    report2 = replay.prefill(jobs)
    assert report2.ok
    assert [q.job for q in report2.quarantined] == [corrupt]
    assert report2.cached == 55 and report2.computed == 1
    assert replay.stats["quarantined"] == 1
    for name, cfg in jobs[:8]:
        assert replay.sim(name, cfg) == simulate(WORKLOADS[name], cfg)


def test_failure_kinds_are_closed():
    assert set(FAILURE_KINDS) == \
        {"transient", "crash", "timeout", "budget", "corrupt"}
    rec = FailureRecord(job="a/BL/seed0", workload="a", design="BL",
                        kind="crash")
    assert rec.to_dict()["kind"] == "crash"


# ------------------------------------------------------------ sweep tiers

def test_analytic_tier_never_pollutes_engine_cache(tmp_path):
    """ISSUE-9: analytic estimates are keyed by `ANALYTIC_REV`/`CALIB_REV`
    under distinct "an"-prefixed keys, so an engine sweep over the same
    cache directory can never be served a closed-form estimate."""
    jobs = _jobs(seeds=1)
    cache = tmp_path / "cache"
    runner = SimRunner(processes=1, cache_dir=cache, tier="analytic")
    rep = runner.prefill(jobs)
    assert rep.ok and rep.tier == "analytic"
    assert rep.analytic_points == rep.completed == rep.total == len(jobs)
    for job in jobs:
        akey = runner._analytic_key(job)
        assert akey.startswith("an") and akey != sim_key(*job)
        assert (cache / f"{akey}.json").exists()
        assert not (cache / f"{sim_key(*job)}.json").exists()
    # a later engine sweep finds nothing reusable: every job is computed
    engine = SimRunner(processes=1, cache_dir=cache)
    rep2 = engine.prefill(jobs)
    assert rep2.tier == "engine"
    assert rep2.computed == len(jobs) and rep2.cached == 0
    for name, cfg in jobs:
        assert engine.sim(name, cfg) == simulate(WORKLOADS[name], cfg)


def test_analytic_rev_keys_estimate_cache(tmp_path, monkeypatch):
    jobs = _jobs(seeds=1)
    cache = tmp_path / "cache"
    SimRunner(processes=1, cache_dir=cache, tier="analytic").prefill(jobs)
    warm = SimRunner(processes=1, cache_dir=cache, tier="analytic")
    warm.prefill(jobs)
    assert warm.stats["analytic_disk_hits"] == len(jobs)
    assert warm.stats["analytic_computed"] == 0
    monkeypatch.setattr(sweep_mod, "ANALYTIC_REV", sweep_mod.ANALYTIC_REV + 1)
    bumped = SimRunner(processes=1, cache_dir=cache, tier="analytic")
    bumped.prefill(jobs)
    assert bumped.stats["analytic_computed"] == len(jobs)


def test_hybrid_degrades_to_engine_on_corrupt_calibration(tmp_path):
    """A torn calibration file must not poison the sweep: the hybrid tier
    quarantines it through the standard corrupt-entry path and falls back
    to a full engine sweep, reporting the degradation exactly once."""
    jobs = _jobs(seeds=1)
    cache = tmp_path / "cache"
    runner = SimRunner(processes=1, cache_dir=cache, tier="hybrid")
    calib_path = runner.store.path(sweep_mod.CALIBRATION_KEY)
    calib_path.parent.mkdir(parents=True, exist_ok=True)
    calib_path.write_text('{"torn":')
    rep = runner.prefill(jobs)
    assert rep.tier == "engine" and rep.ok
    assert rep.completed == rep.total == len(jobs)
    assert rep.analytic_points == 0 and rep.frontier_jobs == []
    assert runner.stats["calib_degraded"] == 1
    # the corrupt file went through the shared quarantine machinery
    assert not calib_path.exists()
    qdir = cache / "quarantine"
    assert (qdir / "analytic_calib.json").exists()
    assert (qdir / "analytic_calib.failure.json").exists()
    recs = [q for q in rep.quarantined
            if q.key == sweep_mod.CALIBRATION_KEY]
    assert len(recs) == 1 and recs[0].kind == "corrupt"
    assert "calibration" in recs[0].detail
    # degradation is reported once, not re-surfaced on every later sweep
    rep2 = runner.prefill(jobs, tier="hybrid")
    assert rep2.tier == "engine" and rep2.ok
    assert all(q.key != sweep_mod.CALIBRATION_KEY for q in rep2.quarantined)
    # the fallback results themselves are exact
    for name, cfg in jobs:
        assert runner.sim(name, cfg) == simulate(WORKLOADS[name], cfg)


def test_report_tier_stat_survives_chaos(tmp_path, monkeypatch):
    """`SweepReport.tier` rides along the chaos machinery: a transient fault
    inside the hybrid confirmation sweep is retried and the report still
    identifies the tier that ran (and serializes it)."""
    label = "kmeans/LTRF/seed0"
    _arm(tmp_path, monkeypatch,
         [{"match": label, "action": "raise", "times": 1}])
    runner = SimRunner(processes=1, cache_dir=tmp_path / "cache",
                       sweep=FAST, tier="hybrid")
    jobs = _jobs(seeds=1)
    rep = runner.prefill(jobs)
    assert rep.ok and rep.tier == "hybrid"
    assert rep.analytic_points == len(jobs)
    assert rep.retried == {label: 1}
    assert rep.to_dict()["tier"] == "hybrid"
    # the default (engine) path reports its tier too
    eng = SimRunner(processes=1, cache_dir=tmp_path / "cache2", sweep=FAST)
    assert eng.prefill(jobs).to_dict()["tier"] == "engine"


def test_faults_disabled_results_bit_identical(tmp_path, monkeypatch):
    """With no fault plan, the service path must be invisible: pool prefill
    == serial prefill == direct simulate, and stats stay hit-clean."""
    monkeypatch.delenv(faults.ENV_PLAN, raising=False)
    jobs = _jobs(seeds=2)
    par = SimRunner(processes=2, cache_dir=tmp_path / "p")
    rep = par.prefill(jobs)
    assert rep.ok and rep.retried == {} and rep.pool_recycles == 0
    ser = SimRunner(processes=1, cache_dir=tmp_path / "s")
    ser.prefill(jobs)
    for name, cfg in jobs:
        direct = simulate(WORKLOADS[name], cfg)
        assert par.sim(name, cfg) == direct
        assert ser.sim(name, cfg) == direct
