"""Trust suite for the calibrated analytical fast tier (repro.sim.analytic).

The fast tier is only usable for screening million-point sweeps if it is
*tested into trustworthiness* (ISSUE 9).  This suite pins:

* property tests — estimates are finite/non-negative on fuzzed programs and
  configs, monotone non-decreasing in RF access latency and in working-set
  size at fixed design, the Ideal twin lower-bounds every design, and the
  model matches the engine *exactly* on degenerate single-interval,
  no-conflict programs;
* a schema regression test — the `CompiledPlan.pass_stats` pass names,
  execution order, and counter keys the model consumes cannot silently
  drift when `core.pipeline` changes (the failure message points at
  `src/repro/sim/analytic.py`);
* the differential rank-correlation acceptance — both tiers run in-process
  over sweep domains, Spearman rho / Pareto-frontier recall are asserted,
  and the hybrid tier returns engine-verdict results bit-identical to
  fresh engine runs for every confirmed frontier point;
* calibration — the NNLS fitter returns non-negative coefficients,
  calibrations round-trip through disk, and stale-revision or corrupt
  files raise `CalibrationError` instead of silently skewing estimates.
"""
from __future__ import annotations

import json
import math
import pathlib
import sys
import time
from dataclasses import replace

import pytest
from hypothesis import given, settings, strategies as st

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent))

from benchmarks.sweep_subset import screening_jobs, sweep_jobs
from repro.core.pipeline import sim_passes
from repro.core.plan_cache import compile_for_sim
from repro.obs.attribution import CYCLE_CATEGORIES
from repro.core.ir import parse_asm
from repro.serving.sweep import SimRunner, analytic_sim_key, sim_key
from repro.sim import DESIGNS, SimConfig, simulate
from repro.sim.analytic import (
    ANALYTIC_PASS_ORDER, ANALYTIC_PASS_SCHEMA, ANALYTIC_REV, CALIB_REV,
    DEFAULT_CALIBRATION, AnalyticModelError, Calibration, CalibrationError,
    analytic_supported, calibration_from_dict, calibration_to_dict,
    check_pass_stats, estimate, fit_calibration, load_calibration,
    pareto_frontier, required_passes, save_calibration, spearman_rho,
)
from repro.sim.designs import design_config
from repro.workloads import get_workload
from repro.workloads.suite import Workload
from repro.workloads.synth import SynthSpec, synthesize

TOL_MULTS = (1.0, 4.0, 6.3)


def _degen_workload(n: int) -> Workload:
    """Degenerate single-interval no-conflict program: straight-line movs
    with no register sources and bank-distinct destinations — no RAW/WAW
    hazards, no memory, no bank conflicts, one basic block, one interval."""
    lines = [f"mov r{i % 16}, {i}" for i in range(n)]
    prog = parse_asm("\n".join(lines), name=f"degen{n}")
    return Workload(name=f"degen{n}", program=prog, trips={},
                    register_sensitive=False, regs_per_thread=16,
                    suite="synth", l1_hit=1.0)


def _ws_workload(k: int, n: int = 24) -> Workload:
    """Fixed instruction count, working set growing with ``k`` (distinct
    source registers) — the axis the monotonicity property sweeps."""
    lines = [f"add r0, r{1 + i % k}, r{1 + (i + 1) % k}" for i in range(n)]
    prog = parse_asm("\n".join(lines), name=f"ws{k}")
    return Workload(name=f"ws{k}", program=prog, trips={},
                    register_sensitive=False, regs_per_thread=max(8, k + 1),
                    suite="synth", l1_hit=1.0)


def _fuzz_workload(seed: int, n_regs: int, loop_depth: int, body_len: int,
                   mem_ratio: float, diamonds: int) -> Workload:
    spec = SynthSpec(name=f"afuzz{seed}", seed=seed, n_regs=n_regs,
                     loop_depth=loop_depth, body_len=body_len,
                     mem_ratio=mem_ratio, diamonds=diamonds,
                     trips=tuple([3] * loop_depth),
                     regs_per_thread=max(24, n_regs))
    prog, trips = synthesize(spec)
    return Workload(name=spec.name, program=prog, trips=trips,
                    register_sensitive=True, regs_per_thread=spec.regs_per_thread,
                    suite="synth", l1_hit=0.85)


# ------------------------------------------------------------- properties

@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 10_000),
       n_regs=st.integers(8, 48),
       loop_depth=st.integers(1, 2),
       body_len=st.integers(4, 24),
       mem_ratio=st.floats(0.0, 0.5),
       diamonds=st.integers(0, 2),
       design=st.sampled_from(DESIGNS),
       mult=st.sampled_from(TOL_MULTS),
       warps=st.sampled_from((1, 4, 16)))
def test_estimates_finite_nonnegative_fuzzed(seed, n_regs, loop_depth,
                                             body_len, mem_ratio, diamonds,
                                             design, mult, warps):
    w = _fuzz_workload(seed, n_regs, loop_depth, body_len, mem_ratio,
                       diamonds)
    res = estimate(w, SimConfig(design=design, mrf_latency_mult=mult,
                                num_warps=warps))
    assert math.isfinite(res.cycles) and res.cycles >= 0
    assert math.isfinite(res.ipc) and res.ipc >= 0
    assert res.instructions > 0
    assert res.est_prefetch_events >= 0 and res.est_mrf_accesses >= 0
    assert set(res.cycle_breakdown) == set(CYCLE_CATEGORIES)
    for cat, v in res.cycle_breakdown.items():
        assert math.isfinite(v) and v >= 0, (cat, v)


@pytest.mark.parametrize("design", DESIGNS)
def test_monotone_in_rf_latency(design):
    w = get_workload("srad")
    prev = -1.0
    for m in (1.0, 2.0, 4.0, 6.3, 8.0, 16.0):
        c = estimate(w, SimConfig(design=design, mrf_latency_mult=m)).cycles
        assert c >= prev, (design, m, c, prev)
        prev = c


@pytest.mark.parametrize("design", DESIGNS)
def test_monotone_in_working_set_size(design):
    prev = -1.0
    for k in (2, 4, 8, 12, 15):
        c = estimate(_ws_workload(k),
                     SimConfig(design=design, mrf_latency_mult=6.3)).cycles
        assert c >= prev, (design, k, c, prev)
        prev = c


@pytest.mark.parametrize("design", [d for d in DESIGNS if d != "Ideal"])
def test_ideal_twin_lower_bounds_every_design(design):
    for name in ("srad", "kmeans", "bfs"):
        w = get_workload(name)
        for m in TOL_MULTS:
            cfg = SimConfig(design=design, mrf_latency_mult=m)
            twin = replace(cfg, design="Ideal", mrf_latency_mult=1.0,
                           add_rfc_to_main=True)
            assert estimate(w, twin).cycles <= estimate(w, cfg).cycles, \
                (name, design, m)


@pytest.mark.parametrize("design", DESIGNS)
def test_degenerate_programs_exact_vs_engine(design):
    """On single-interval no-conflict straight-line programs the closed form
    *is* the engine: identical cycles, instructions, and IPC."""
    for n in (6, 12, 33):
        w = _degen_workload(n)
        for mult in TOL_MULTS:
            for warps in (1, 4, 8):
                cfg = SimConfig(design=design, mrf_latency_mult=mult,
                                num_warps=warps)
                eng = simulate(w, cfg)
                est = estimate(w, cfg)
                assert est.cycles == eng.cycles, (n, design, mult, warps)
                assert est.instructions == eng.instructions
                assert est.ipc == pytest.approx(eng.ipc)


def test_unsupported_configs_raise_model_error():
    w = get_workload("kmeans")
    with pytest.raises(AnalyticModelError):
        estimate(w, SimConfig(design="BL", num_sms=2))
    assert not analytic_supported(SimConfig(design="BL", num_sms=2))
    assert analytic_supported(SimConfig(design="BL"))


# ------------------------------------------------ pass_stats schema pinning

def test_pass_stats_schema_pinned_against_pipeline():
    """The exact pass names and execution order the model consumes must
    exist in `core.pipeline.sim_passes()` — in the same relative order."""
    pipeline_names = [p.name for p in sim_passes()]
    assert set(ANALYTIC_PASS_ORDER) <= set(pipeline_names), \
        "pipeline lost a pass the analytical model consumes"
    positions = [pipeline_names.index(n) for n in ANALYTIC_PASS_ORDER]
    assert positions == sorted(positions), \
        "pipeline reordered passes the analytical model consumes"


@pytest.mark.parametrize("design", DESIGNS)
def test_compiled_plan_carries_pinned_counters(design):
    w = get_workload("kmeans")
    plan = compile_for_sim(w.program, design, 16, 16)
    check_pass_stats(plan.pass_stats, design)  # must not raise
    for name in required_passes(design):
        entry = plan.pass_stats[name]
        for key in ANALYTIC_PASS_SCHEMA[name]:
            assert key in entry, (design, name, key)
        assert "time_ms" in entry


def test_schema_drift_error_points_at_analytic_consumers():
    w = get_workload("kmeans")
    plan = compile_for_sim(w.program, "LTRF", 16, 16)
    stats = {k: dict(v) for k, v in plan.pass_stats.items()}
    del stats["prefetch"]["serial_rounds"]
    stats.pop("emit")
    with pytest.raises(AnalyticModelError) as ei:
        check_pass_stats(stats, "LTRF")
    msg = str(ei.value)
    assert "src/repro/sim/analytic.py" in msg
    assert "ANALYTIC_PASS_SCHEMA" in msg
    assert "serial_rounds" in msg and "'emit' missing" in msg


# ------------------------------------------- differential acceptance (fast)

@pytest.fixture(scope="module")
def small_domain(tmp_path_factory):
    """Two workload groups x all designs, both tiers, engine run fresh."""
    cache = tmp_path_factory.mktemp("an_cache")
    jobs = [(n, design_config(d, table2_config=7))
            for n in ("srad", "sgemm") for d in DESIGNS]
    runner = SimRunner(processes=1, cache_dir=cache)
    runner.prefill(jobs, tier="engine")
    eng = {j: runner.sim(*j) for j in jobs}
    est = {j: runner.estimate(*j) for j in jobs}
    return cache, jobs, eng, est


def test_rank_correlation_small_domain(small_domain):
    _, jobs, eng, est = small_domain
    rho = spearman_rho([est[j].cycles for j in jobs],
                       [eng[j].cycles for j in jobs])
    assert rho >= 0.85, f"pooled Spearman rho {rho:.3f} below floor"


def test_frontier_recall_small_domain(small_domain):
    """Per workload, the engine's true Pareto frontier over (cycles, MRF
    accesses) must be contained in the hybrid selection (analytic frontier
    + top-3 estimated-cycle points)."""
    _, jobs, eng, est = small_domain
    for wname in ("srad", "sgemm"):
        members = [j for j in jobs if j[0] == wname]
        eng_front = set(pareto_frontier(
            [(eng[j].cycles, eng[j].mrf_accesses) for j in members]))
        est_pts = [(est[j].cycles, est[j].est_mrf_accesses) for j in members]
        picked = set(pareto_frontier(est_pts))
        picked.update(sorted(range(len(members)),
                             key=lambda i: est_pts[i][0])[:3])
        assert eng_front <= picked, \
            (wname, sorted(eng_front - picked))


def test_hybrid_returns_engine_verdicts_bit_identical(small_domain, tmp_path):
    _, jobs, eng, _ = small_domain
    # fresh cache: only the hybrid confirmation sweep populates it, so the
    # cache itself witnesses exactly which points got engine verdicts
    runner = SimRunner(processes=1, cache_dir=tmp_path / "hyb", tier="hybrid")
    rep = runner.prefill(jobs)
    assert rep.tier == "hybrid" and rep.ok
    assert rep.analytic_points == len(jobs)
    assert rep.frontier_jobs and \
        rep.frontier_confirmed == len(rep.frontier_jobs)
    confirmed = 0
    for job in jobs:
        if runner._lookup(job) is None:
            continue  # screened-out point: estimate only, by design
        confirmed += 1
        res = runner.sim(*job)
        assert res == eng[job]  # replay: engine-verdict result
        assert res == simulate(get_workload(job[0]), job[1])  # fresh engine
    assert confirmed == rep.frontier_confirmed


def test_estimate_ipc_consistency(small_domain):
    _, jobs, _, est = small_domain
    for j, r in est.items():
        assert r.ipc == pytest.approx(r.instructions / max(r.cycles, 1))
        assert r.tier == "analytic"
        total = sum(r.cycle_breakdown.values())
        assert total == pytest.approx(r.cycles, abs=1.0)


def test_screening_grid_is_thousands_of_points():
    jobs = screening_jobs()
    assert len(set(jobs)) == len(jobs) >= 2000
    assert all(analytic_supported(cfg) for _, cfg in jobs)


# ---------------------------------------------------------- calibration

def test_calibration_round_trip(tmp_path):
    calib = Calibration(theta_pf=0.5, theta_mem=0.25, theta_dep=0.0,
                        theta_bank=1.5, source="fitted", n_samples=12)
    path = tmp_path / "calib.json"
    save_calibration(calib, path)
    loaded = load_calibration(path)
    assert loaded == calib
    assert load_calibration(tmp_path / "missing.json") is None


@pytest.mark.parametrize("mutate", [
    lambda d: d.update(analytic_rev=ANALYTIC_REV + 1),
    lambda d: d.update(calib_rev=CALIB_REV + 1),
    lambda d: d.pop("coeffs"),
    lambda d: d["coeffs"].update(theta_pf=-0.1),
    lambda d: d["coeffs"].update(theta_mem=float("nan")),
    lambda d: d["coeffs"].pop("theta_bank"),
])
def test_calibration_validation_rejects_bad_payloads(tmp_path, mutate):
    payload = calibration_to_dict(DEFAULT_CALIBRATION)
    mutate(payload)
    with pytest.raises(CalibrationError):
        calibration_from_dict(payload)


def test_corrupt_calibration_file_raises(tmp_path):
    path = tmp_path / "calib.json"
    path.write_text("{definitely not json")
    with pytest.raises(CalibrationError):
        load_calibration(path)


def test_calibration_keys_estimate_cache():
    cfg = SimConfig(design="LTRF")
    k1 = analytic_sim_key("srad", cfg, DEFAULT_CALIBRATION)
    k2 = analytic_sim_key("srad", cfg,
                          replace(DEFAULT_CALIBRATION, theta_pf=0.5))
    assert k1 != k2, "calibration coefficients must key the estimate cache"
    assert k1.startswith("an")
    assert k1 != sim_key("srad", cfg)


def test_fit_calibration_needs_samples():
    w = get_workload("kmeans")
    cfg = SimConfig(design="BL")
    with pytest.raises(AnalyticModelError):
        fit_calibration([(w, cfg, 100)] * 3)


@pytest.mark.slow
def test_fit_calibration_on_engine_ground_truth():
    """The full fit: engine-run training set -> non-negative coefficients
    that do not *hurt* rank accuracy vs the uncalibrated (theta=1) model."""
    jobs = [(n, design_config(d, table2_config=tc))
            for n in ("srad", "kmeans", "bfs", "sgemm")
            for d in DESIGNS for tc in (6, 7)]
    samples, eng = [], {}
    for name, cfg in jobs:
        w = get_workload(name)
        res = simulate(w, cfg)
        eng[(name, cfg)] = res.cycles
        samples.append((w, cfg, res.cycles))
    calib = fit_calibration(samples)
    assert calib.source == "fitted" and calib.n_samples == len(samples)
    for theta in calib.coeffs():
        assert math.isfinite(theta) and theta >= 0.0
    fitted = [estimate(get_workload(n), c, calib=calib).cycles
              for n, c in jobs]
    default = [estimate(get_workload(n), c,
                        calib=Calibration()).cycles for n, c in jobs]
    truth = [eng[j] for j in jobs]
    assert spearman_rho(fitted, truth) >= spearman_rho(default, truth) - 0.02
    assert spearman_rho(fitted, truth) >= 0.9


# ------------------------------------- tracked-domain acceptance (slow)

@pytest.mark.slow
def test_tracked_domain_differential_acceptance(tmp_path):
    """ISSUE 9 acceptance on the tracked sweep domain, in-process: pooled
    Spearman rho >= 0.9, Pareto-frontier recall pinned at 1.0, and analytic
    throughput >= 100x the engine's on the same host."""
    jobs = [j for j in dict.fromkeys(sweep_jobs())
            if analytic_supported(j[1])]
    runner = SimRunner(processes=1, cache_dir=tmp_path / "cache")
    t0 = time.time()
    rep = runner.prefill(jobs, tier="engine")
    engine_wall = time.time() - t0
    assert rep.ok
    eng = {j: runner.sim(*j) for j in jobs}

    t0 = time.time()
    est = {j: runner.estimate(*j) for j in jobs}
    runner._analytic_memo.clear()
    t0 = time.time()
    est = {j: runner.estimate(*j) for j in jobs}
    analytic_wall = time.time() - t0

    rho = spearman_rho([est[j].cycles for j in jobs],
                       [eng[j].cycles for j in jobs])
    assert rho >= 0.9, f"tracked-domain Spearman rho {rho:.4f} < 0.9"

    groups: dict[tuple, list] = {}
    for j in jobs:
        groups.setdefault((j[0], j[1].rf_size_kb), []).append(j)
    missed = []
    for key, members in groups.items():
        eng_front = set(pareto_frontier(
            [(eng[j].cycles, eng[j].mrf_accesses) for j in members]))
        est_pts = [(est[j].cycles, est[j].est_mrf_accesses) for j in members]
        picked = set(pareto_frontier(est_pts))
        picked.update(sorted(range(len(members)),
                             key=lambda i: est_pts[i][0])[:3])
        if not eng_front <= picked:
            missed.append(key)
    assert not missed, f"frontier recall broken in groups {missed}"

    total_instr = sum(r.instructions for r in eng.values())
    engine_per_s = total_instr / max(engine_wall, 1e-9)
    analytic_per_s = total_instr / max(analytic_wall, 1e-9)
    assert analytic_per_s >= 100 * engine_per_s, \
        f"analytic {analytic_per_s:.0f} instr/s < 100x engine {engine_per_s:.0f}"


def test_bench_artifact_analytic_tier_verdicts():
    """The tracked BENCH_sim.json must carry the analytic_tier section with
    every trust verdict passing — the acceptance is asserted, not just
    recorded."""
    path = pathlib.Path(__file__).resolve().parent.parent / "BENCH_sim.json"
    report = json.loads(path.read_text())
    sec = report.get("analytic_tier")
    assert sec, "BENCH_sim.json lost its analytic_tier section"
    assert sec["analytic_rev"] == ANALYTIC_REV
    assert sec["calib_rev"] == CALIB_REV
    assert sec["pooled_spearman_rho"] >= 0.9
    assert sec["frontier"]["recall"] == 1.0
    assert sec["throughput"]["speedup_vs_engine"] >= 100
    assert sec["verdicts"] and all(sec["verdicts"].values())
    assert sec["all_verdicts_pass"] is True
