"""Golden-equivalence + determinism pins for the fast simulator engine.

Two layers of protection for the event-heap rewrite (and any future engine
optimization):

* **equivalence**: the optimized `engine.Simulator` must produce bit-identical
  `SimResult` counters to the preserved seed implementation
  (`golden.GoldenSimulator`) for every design, across the workload suite;
* **determinism pins**: exact counter values for the paper's Listing-1
  program across all 7 designs, so a behavioural drift is caught even if
  both engines drift together.

The full-size equivalence matrix (64 warps, every workload x design) runs in
the benchmark harness; here reduced warp counts keep tier-1 fast while still
exercising every design-specific code path.
"""
import pytest

from repro.sim import DESIGNS, SimConfig, design_config, simulate
from repro.sim.golden import golden_simulate
from repro.workloads import WORKLOADS, get_workload
from repro.workloads.suite import Workload, listing1_program

# Every design x a workload slice covering: register-sensitive + insensitive,
# loops/diamonds, low L1 hit rates, strand splitting, renumbering, liveness.
EQUIV_WORKLOADS = ("srad", "mri-q", "sgemm", "btree", "bfs", "kmeans")


@pytest.mark.parametrize("design", DESIGNS)
def test_engine_matches_golden(design):
    for name in EQUIV_WORKLOADS:
        w = WORKLOADS[name]
        cfg = design_config(design, table2_config=7, num_warps=16)
        assert simulate(w, cfg) == golden_simulate(w, cfg), (design, name)


@pytest.mark.parametrize("design", ("BL", "RFC", "LTRF", "LTRF_conf"))
def test_engine_matches_golden_latency_points(design):
    w = WORKLOADS["hotspot"]
    for mult in (1.0, 2.0, 5.3):
        cfg = design_config(design, mrf_latency_mult=mult, rf_size_kb=256,
                            num_warps=16)
        assert simulate(w, cfg) == golden_simulate(w, cfg), (design, mult)


@pytest.mark.parametrize("design", ("BL", "RFC", "LTRF", "Ideal"))
def test_engine_matches_golden_scarce_collectors(design):
    """Collector-constrained configs: the seed's retried issues consume MRF
    bandwidth tokens, so the fast engine's issue-loop shortcut must only
    trigger on pure stalls (regression test for exactly that divergence)."""
    w = WORKLOADS["srad"]
    for nc in (1, 2, 8):
        base = design_config(design, table2_config=7, num_warps=8)
        cfg = SimConfig(**{**base.__dict__, "num_collectors": nc})
        assert simulate(w, cfg) == golden_simulate(w, cfg), (design, nc)


def test_full_suite_one_design_matches_golden():
    from repro.workloads import workload_names
    for name in workload_names():  # synthetic suite (traced: test_frontend)
        w = WORKLOADS[name]
        cfg = design_config("LTRF", table2_config=6, num_warps=8)
        assert simulate(w, cfg) == golden_simulate(w, cfg), name


# --------------------------------------------------------------- determinism

def listing1_workload() -> Workload:
    return Workload(name="listing1", program=listing1_program(),
                    trips={"L1": 100}, register_sensitive=False,
                    regs_per_thread=8, suite="paper")


# Exact counters for Listing 1 at Table-2 config #7, 16 warps:
# (cycles, instructions, mrf_accesses, rfc_hits, rfc_accesses)
LISTING1_GOLDEN = {
    "BL":        (807, 232, 288, 0, 0),
    "RFC":       (587, 232, 112, 176, 288),
    "SHRF":      (775, 232, 468, 288, 288),
    "LTRF":      (628, 232, 252, 288, 288),
    "LTRF_conf": (628, 232, 252, 288, 288),
    "LTRF_plus": (550, 232, 0, 288, 288),
    "Ideal":     (577, 232, 0, 0, 0),
}


@pytest.mark.parametrize("design", DESIGNS)
def test_listing1_counters_pinned(design):
    w = listing1_workload()
    cfg = design_config(design, table2_config=7, num_warps=16)
    r = simulate(w, cfg)
    got = (r.cycles, r.instructions, r.mrf_accesses, r.rfc_hits,
           r.rfc_accesses)
    assert got == LISTING1_GOLDEN[design], (design, got)
    # and the golden engine agrees bit-for-bit
    assert golden_simulate(w, cfg) == r


# Exact cycle attribution (repro.obs) for the same Listing-1 pins, in
# CYCLE_CATEGORIES order (issue, alu_dep, mem_stall, prefetch_stall,
# bank_conflict, scheduler_idle, drain).  Each row sums to the design's
# pinned cycle count above; the story the numbers pin is the paper's:
# BL exposes the slow MRF + memory as 517 mem-stall cycles, while the
# LTRF designs shrink that to ~5 by prefetching intervals (83 cycles of
# exposed prefetch) and swapping waiting warps out (scheduler_idle).
LISTING1_BREAKDOWN = {
    "BL":        (107, 32, 517, 0, 0, 0, 151),
    "RFC":       (98, 8, 465, 0, 0, 0, 16),
    "SHRF":      (120, 0, 0, 324, 0, 218, 113),
    "LTRF":      (96, 4, 5, 83, 0, 389, 51),
    "LTRF_conf": (96, 4, 5, 83, 0, 389, 51),
    "LTRF_plus": (91, 9, 13, 0, 0, 412, 25),
    "Ideal":     (95, 0, 452, 0, 0, 0, 30),
}


@pytest.mark.parametrize("design", DESIGNS)
def test_listing1_cycle_breakdown_pinned(design):
    from repro.obs import CYCLE_CATEGORIES

    w = listing1_workload()
    cfg = design_config(design, table2_config=7, num_warps=16)
    r = simulate(w, cfg)
    assert tuple(r.cycle_breakdown) == CYCLE_CATEGORIES
    got = tuple(r.cycle_breakdown.values())
    assert got == LISTING1_BREAKDOWN[design], (design, got)
    assert sum(got) == r.cycles == LISTING1_GOLDEN[design][0]
    # and the golden engine attributes identically
    assert golden_simulate(w, cfg).cycle_breakdown == r.cycle_breakdown


def test_listing1_pins_via_batch_engine():
    """Tentpole acceptance: the vectorized batch engine reproduces the exact
    Listing-1 pins — counters AND the full cycle attribution — for all 7
    designs in one `run_batch` call, bit-identical to the event engine."""
    from repro.sim import run_batch

    w = listing1_workload()
    jobs = [(w, design_config(d, table2_config=7, num_warps=16))
            for d in DESIGNS]
    for design, (_, cfg), r in zip(DESIGNS, jobs, run_batch(jobs)):
        got = (r.cycles, r.instructions, r.mrf_accesses, r.rfc_hits,
               r.rfc_accesses)
        assert got == LISTING1_GOLDEN[design], (design, got)
        assert tuple(r.cycle_breakdown.values()) == \
            LISTING1_BREAKDOWN[design], design
        # full-structure equality with the scalar engine, not just counters
        assert r == simulate(w, cfg), design


# Exact counters for the lifted ltrf_matmul reference (the traced frontend's
# flagship kernel) at Table-2 config #7, 16 warps: behavioural drift in the
# jaxpr lifter, the register allocator, OR the engine shows up here.
TRACED_MATMUL_GOLDEN = {
    "BL":        (7857, 5584, 16000, 0, 0),
    "RFC":       (5878, 5584, 7803, 8197, 16000),
    "SHRF":      (10557, 5584, 13416, 16000, 16000),
    "LTRF":      (7180, 5584, 11552, 16000, 16000),
    "LTRF_conf": (6719, 5584, 11552, 16000, 16000),
    "LTRF_plus": (5468, 5584, 2512, 16000, 16000),
    "Ideal":     (5381, 5584, 0, 0, 0),
}


@pytest.mark.parametrize("design", DESIGNS)
def test_traced_matmul_counters_pinned(design):
    w = get_workload("traced_matmul")  # lifts via jax on first call
    cfg = design_config(design, table2_config=7, num_warps=16)
    r = simulate(w, cfg)
    got = (r.cycles, r.instructions, r.mrf_accesses, r.rfc_hits,
           r.rfc_accesses)
    assert got == TRACED_MATMUL_GOLDEN[design], (design, got)
    # and the golden engine agrees bit-for-bit
    assert golden_simulate(w, cfg) == r


@pytest.mark.slow
def test_bank_model_none_bit_identical_to_golden():
    """ISSUE 4 acceptance pin: the bank-arbitration knob at its default
    ``bank_model="none"`` is a strict no-op — bit-identical to the frozen
    golden oracle (which predates the knob), with zero conflict counters."""
    from dataclasses import replace

    for design in ("BL", "RFC", "LTRF", "LTRF_conf"):
        for name in ("srad", "btree"):
            w = WORKLOADS[name]
            cfg = design_config(design, table2_config=7, num_warps=16)
            explicit = replace(cfg, bank_model="none", renumber="icg")
            r = simulate(w, explicit)
            assert r == golden_simulate(w, cfg), (design, name)
            assert r == simulate(w, cfg)
            assert r.bank_conflicts == 0 and r.bank_conflict_cycles == 0


def test_simulation_repeatable_across_instances():
    w = listing1_workload()
    cfg = SimConfig(design="LTRF_conf", num_warps=24, mrf_latency_mult=6.3)
    assert simulate(w, cfg) == simulate(w, cfg)


def test_gpu_num_sms1_two_level_bit_identical():
    """ISSUE 3 acceptance pin: the whole-GPU model at ``num_sms=1`` with the
    two-level scheduler must reproduce today's single-SM counters
    bit-identically — including through the frozen golden engine."""
    from repro.sim.gpu import per_sm_configs, simulate_gpu

    for name in ("srad", "btree"):
        w = WORKLOADS[name]
        cfg = design_config("LTRF", table2_config=7, num_warps=16)
        assert cfg.num_sms == 1 and cfg.scheduler == "two_level"
        # the dispatcher degenerates to the input config itself
        assert per_sm_configs(cfg) == [cfg]
        r = simulate(w, cfg)
        g = simulate_gpu(w, cfg)
        assert g.per_sm == (r,), name
        got = (g.cycles, g.instructions, g.mrf_accesses, g.rfc_hits,
               g.rfc_accesses, g.prefetch_ops, g.writeback_regs,
               g.activations)
        want = (r.cycles, r.instructions, r.mrf_accesses, r.rfc_hits,
                r.rfc_accesses, r.prefetch_ops, r.writeback_regs,
                r.activations)
        assert got == want, name
        assert golden_simulate(w, cfg) == r, name


def test_gpu_listing1_num_sms1_matches_pins():
    """The GPU path reproduces the exact Listing-1 pinned counters."""
    from repro.sim.gpu import simulate_gpu

    w = listing1_workload()
    for design in DESIGNS:
        g = simulate_gpu(w, design_config(design, table2_config=7,
                                          num_warps=16))
        got = (g.cycles, g.instructions, g.mrf_accesses, g.rfc_hits,
               g.rfc_accesses)
        assert got == LISTING1_GOLDEN[design], (design, got)
