"""Tests for liveness, ICG, coloring, renumbering and prefetch accounting."""
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (
    build_icg, chaitin_color, form_register_intervals, parse_asm,
    prefetch_schedule, renumber_registers,
)
from repro.core.liveness import annotate_dead_operands, block_liveness, build_live_ranges
from repro.core.renumber import bank_of
from repro.workloads import WORKLOADS, listing1_program
from repro.workloads.synth import SynthSpec, synthesize


# ---------------------------------------------------------------------------
# semantic equivalence oracle: interpret a program before/after renumbering
# ---------------------------------------------------------------------------

def interpret(prog, max_steps=20_000):
    """Tiny concrete interpreter: registers hold ints; ld hashes the address;
    loops bounded by max_steps. Returns the trace of (op, computed value)."""
    regs: dict[int, int] = {}
    preds: dict[int, bool] = {}
    label = prog.entry
    idx = 0
    trace = []
    steps = 0
    order = prog.order

    def val(r):
        return regs.get(r, r * 7 + 3)  # deterministic initial values

    while steps < max_steps:
        steps += 1
        bb = prog.blocks[label]
        if idx >= len(bb.instrs):
            i = order.index(label)
            if i + 1 >= len(order):
                break
            label, idx = order[i + 1], 0
            continue
        ins = bb.instrs[idx]
        taken = all(preds.get(p, (steps % 3 == 0)) for p in ins.psrcs) if ins.psrcs else True
        if ins.op == "exit":
            break
        if ins.op == "bra":
            if taken:
                label, idx = ins.target, 0
                continue
            idx += 1
            continue
        if ins.op == "set":
            v = int(val(ins.srcs[0]) < val(ins.srcs[1])) if len(ins.srcs) >= 2 else 1
            preds[ins.pdst] = bool(v)
            trace.append(("set", v))
            idx += 1
            continue
        srcs = [val(s) for s in ins.srcs]
        if ins.op == "ld":
            v = (srcs[0] * 2654435761) % 1000003 if srcs else 17
        elif ins.op == "mul":
            v = (srcs[0] * srcs[1]) % 1_000_003 if len(srcs) > 1 else srcs[0]
        elif ins.op in ("add", "mad", "sub"):
            v = sum(srcs) % 1_000_003
        elif ins.op == "mov":
            v = srcs[0] if srcs else 1
        else:
            v = sum(srcs) % 1_000_003 if srcs else 0
        for d in ins.dsts:
            regs[d] = v
        if ins.dsts:
            trace.append((ins.op, v))
        idx += 1
    return trace


@pytest.mark.parametrize("name", sorted(WORKLOADS))
def test_renumbering_preserves_semantics(name):
    w = WORKLOADS[name]
    an = form_register_intervals(w.program, n_cap=16)
    rr = renumber_registers(an, num_banks=16)
    assert interpret(an.prog) == interpret(rr.prog)


def test_renumbering_preserves_semantics_listing1():
    an = form_register_intervals(listing1_program(), n_cap=4)
    rr = renumber_registers(an, num_banks=4, scheme="grouped")
    assert interpret(an.prog) == interpret(rr.prog)


def test_listing1_walkthrough_conflict_free():
    """Paper §4.3: with 4 banks x 2 regs, renumbering removes all conflicts."""
    an = form_register_intervals(listing1_program(), n_cap=4)
    pre = prefetch_schedule(an, num_banks=4, scheme="grouped", regs_per_bank=2)
    assert any(op.conflicts > 0 for op in pre)  # conflicts exist before
    rr = renumber_registers(an, num_banks=4, scheme="grouped", regs_per_bank=2)
    post = prefetch_schedule(rr.analysis, num_banks=4, scheme="grouped", regs_per_bank=2)
    assert all(op.conflicts == 0 for op in post)
    assert not rr.coloring.uncolorable


def test_coloring_valid_on_colorable_graph():
    adj = {0: {1, 2}, 1: {0, 2}, 2: {0, 1}, 3: {0}}
    col = chaitin_color(adj, 3)
    assert not col.uncolorable
    assert col.conflicts(adj) == 0


def test_coloring_balanced():
    # 8 independent nodes over 4 colors -> exactly 2 of each
    adj = {i: set() for i in range(8)}
    col = chaitin_color(adj, 4)
    from collections import Counter
    assert set(Counter(col.colors.values()).values()) == {2}


def test_coloring_overconstrained_reports_conflicts():
    n = 6
    adj = {i: set(range(n)) - {i} for i in range(n)}  # K6
    col = chaitin_color(adj, 4)
    assert col.uncolorable
    assert col.conflicts(adj) >= 1


def test_bank_of_schemes():
    assert bank_of(5, 4, "interleaved") == 1
    assert bank_of(5, 4, "grouped", 2) == 2
    assert bank_of(9, 4, "grouped", 2) == 0  # wraps


def test_bank_of_unknown_scheme_raises():
    with pytest.raises(ValueError):
        bank_of(0, 4, "hashed")


def test_bank_of_grouped_edge_cases():
    """regs_per_bank x num_banks interplay for the grouped scheme."""
    # regs_per_bank=1 degenerates to the interleaved mapping
    for r in range(32):
        assert bank_of(r, 8, "grouped", 1) == bank_of(r, 8, "interleaved")
    # a full group lands in one bank, the next group in the next bank
    assert [bank_of(r, 4, "grouped", 3) for r in range(6)] == [0, 0, 0, 1, 1, 1]
    # wrap-around period is num_banks * regs_per_bank
    for r in range(64):
        assert bank_of(r, 4, "grouped", 2) == bank_of(r + 8, 4, "grouped", 2)
        assert bank_of(r, 4, "grouped", 3) == bank_of(r + 12, 4, "grouped", 3)
    # regs_per_bank larger than num_banks still cycles through every bank
    banks = {bank_of(r, 4, "grouped", 7) for r in range(4 * 7)}
    assert banks == {0, 1, 2, 3}
    # results always land inside [0, num_banks)
    for r in range(200):
        for nb in (1, 2, 4, 16):
            for rpb in (1, 2, 3, 7):
                assert 0 <= bank_of(r, nb, "grouped", rpb) < nb


def test_bank_regs_generator_inverts_bank_of():
    """Every register `_bank_regs` yields for a bank maps back to that bank
    under `bank_of` — the renumberer's allocation and the prefetch unit's
    accounting can never disagree."""
    from itertools import islice
    from repro.core.renumber import _bank_regs
    for scheme, rpb in (("interleaved", 2), ("grouped", 2), ("grouped", 3)):
        for nb in (2, 4, 8):
            for bank in range(nb):
                for reg in islice(_bank_regs(bank, nb, scheme, rpb), 12):
                    assert bank_of(reg, nb, scheme, rpb) == bank, \
                        (scheme, rpb, nb, bank, reg)


@pytest.mark.parametrize("name", sorted(WORKLOADS))
def test_renumbering_never_increases_max_conflicts(name):
    w = WORKLOADS[name]
    an = form_register_intervals(w.program, n_cap=16)
    pre = prefetch_schedule(an, num_banks=16)
    rr = renumber_registers(an, num_banks=16)
    post = prefetch_schedule(rr.analysis, num_banks=16)
    assert max(o.conflicts for o in post) <= max(o.conflicts for o in pre)


@pytest.mark.parametrize("name", sorted(WORKLOADS))
def test_icg_rounds_le_identity_rounds(name):
    """ISSUE-4 satellite property: total serial prefetch bank rounds with
    ICG coloring never exceed the rounds under identity numbering (the
    renumbering pass is advisory — it keeps the original code when the
    coloring heuristic would lose)."""
    w = WORKLOADS[name]
    an = form_register_intervals(w.program, n_cap=16)
    identity = prefetch_schedule(an, num_banks=16)          # original numbers
    rr = renumber_registers(an, num_banks=16)
    icg = prefetch_schedule(rr.analysis, num_banks=16)
    assert sum(o.serial_rounds for o in icg) <= \
        sum(o.serial_rounds for o in identity), name


def test_suite_conflict_free_fraction_improves():
    """Aggregate §7.3 trend: renumbering raises the conflict-free fraction."""
    from repro.workloads import workload_names
    pre_free = post_free = total = 0
    for w in (WORKLOADS[n] for n in workload_names()):  # the synthetic suite
        an = form_register_intervals(w.program, n_cap=16)
        pre = prefetch_schedule(an, num_banks=16)
        rr = renumber_registers(an, num_banks=16)
        post = prefetch_schedule(rr.analysis, num_banks=16)
        pre_free += sum(1 for o in pre if o.conflicts == 0)
        post_free += sum(1 for o in post if o.conflicts == 0)
        total += len(pre)
    assert post_free > pre_free
    assert post_free / total > 0.5  # paper: 88% at cap 16


def test_dead_operand_annotation():
    prog = parse_asm("""
        mov r0, 1
        add r1, r0, r0
        add r2, r1, r1
        exit
    """)
    annotate_dead_operands(prog)
    instrs = [i for _, _, i in prog.instructions()]
    # r0 dies after the first add; r1 dies after the second
    assert instrs[1].dead_srcs == (0, 1)
    assert instrs[2].dead_srcs == (0, 1)


def test_liveness_basic():
    prog = parse_asm("""
        mov r0, 1
    L1: add r1, r0, r0
        set p0, r1, r0
        @p0 bra L1
        exit
    """)
    live_in, live_out = block_liveness(prog)
    assert 0 in live_in["L1"]  # r0 live around the loop


def test_live_ranges_webs():
    # r0 has two independent webs (no path connects def2's value to use1)
    prog = parse_asm("""
        mov r0, 1
        add r1, r0, r0
        mov r0, 2
        add r2, r0, r0
        exit
    """)
    ranges, occ = build_live_ranges(prog)
    r0_ranges = [lr for lr in ranges if lr.reg == 0]
    assert len(r0_ranges) == 2


@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(0, 10_000),
    n_regs=st.integers(6, 40),
    depth=st.integers(0, 2),
    body=st.integers(4, 16),
    banks=st.sampled_from([4, 8, 16]),
)
def test_property_renumber_semantics_and_conflicts(seed, n_regs, depth, body, banks):
    spec = SynthSpec(name="prop", seed=seed, n_regs=n_regs, loop_depth=depth,
                     body_len=body, mem_ratio=0.3, trips=tuple([3] * max(depth, 1)))
    prog, _ = synthesize(spec)
    an = form_register_intervals(prog, n_cap=16)
    rr = renumber_registers(an, num_banks=banks)
    assert interpret(an.prog) == interpret(rr.prog)
    pre = prefetch_schedule(an, num_banks=banks)
    post = prefetch_schedule(rr.analysis, num_banks=banks)
    assert max(o.conflicts for o in post) <= max(o.conflicts for o in pre)
