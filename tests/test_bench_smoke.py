"""CLI smoke coverage for the jax-heavy benchmark harnesses.

`benchmarks.hillclimb` and `benchmarks.roofline` were previously imported
by nothing in the suite, so suite-API refactors could break them invisibly.
Each runs ``--help`` in a subprocess (covering the full import chain —
jax, configs, sharding, train step) with `jax_subprocess_env`, which pins
``JAX_PLATFORMS`` so hosts with a TPU-less libtpu never hang probing for
accelerators.
"""
import pathlib
import subprocess
import sys

import pytest

from repro.kernels._compat import jax_subprocess_env

ROOT = pathlib.Path(__file__).resolve().parent.parent


@pytest.mark.parametrize("module", ["benchmarks.hillclimb",
                                    "benchmarks.roofline"])
def test_bench_cli_imports_and_help(module):
    r = subprocess.run(
        [sys.executable, "-m", module, "--help"],
        capture_output=True, text=True, timeout=300, cwd=ROOT,
        env=jax_subprocess_env())
    assert r.returncode == 0, (module, r.stdout, r.stderr)
    assert "usage" in r.stdout.lower(), (module, r.stdout)


def test_bench_sim_help_lists_all_smoke_flags():
    """Every CI smoke entry point is wired into the bench_sim CLI (the full
    interval/bank sweeps run as their own CI steps, not in tier-1)."""
    r = subprocess.run(
        [sys.executable, "-m", "benchmarks.bench_sim", "--help"],
        capture_output=True, text=True, timeout=300, cwd=ROOT,
        env=jax_subprocess_env())
    assert r.returncode == 0, (r.stdout, r.stderr)
    for flag in ("--smoke", "--gpu-smoke", "--bank-smoke",
                 "--interval-smoke", "--chaos-smoke", "--baseline",
                 "--suite"):
        assert flag in r.stdout, flag


def test_bench_sim_gpu_smoke_cli():
    """The CI GPU-scale smoke entry point stays runnable end to end."""
    r = subprocess.run(
        [sys.executable, "-m", "benchmarks.bench_sim", "--gpu-smoke"],
        capture_output=True, text=True, timeout=300, cwd=ROOT,
        env=jax_subprocess_env())
    assert r.returncode == 0, (r.stdout, r.stderr)
    assert '"gpu_sims"' in r.stdout and '"scheduler"' in r.stdout
