"""Runtime substrate tests: data pipeline, checkpointing, fault tolerance,
elastic resharding, gradient compression, optimizer."""
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import Checkpointer
from repro.configs import get_smoke
from repro.configs.base import ShapeConfig
from repro.data import DataConfig, PrefetchingLoader, batch_for_step
from repro.distributed.elastic import degraded_mesh, reshard_state
from repro.distributed.fault import (
    FaultConfig, FaultTolerantTrainer, SimulatedFailure,
)
from repro.optim.adamw import (
    AdamWConfig, adamw_update, global_norm, init_opt_state, lr_schedule,
)
from repro.optim.compression import CompressionConfig, compress_gradients

CFG = get_smoke("tinyllama-1.1b")
SHAPE = ShapeConfig("t", 32, 4, "train")


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

def test_data_deterministic_per_step():
    a = batch_for_step(CFG, SHAPE, 7)
    b = batch_for_step(CFG, SHAPE, 7)
    c = batch_for_step(CFG, SHAPE, 8)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_loader_prefetch_and_restore():
    loader = PrefetchingLoader(CFG, SHAPE, DataConfig(seed=5, depth=2))
    try:
        b0 = loader.get()
        b1 = loader.get()
        loader.restore(0)
        b0_again = loader.get()
        np.testing.assert_array_equal(b0["tokens"], b0_again["tokens"])
        assert not np.array_equal(b0["tokens"], b1["tokens"])
    finally:
        loader.close()


def test_loader_straggler_fallback():
    loader = PrefetchingLoader(CFG, SHAPE, DataConfig(seed=5, timeout_s=0.0))
    try:
        # zero deadline forces the synchronous fallback path
        b = loader.get()
        assert b["tokens"].shape == (4, 32)
    finally:
        loader.close()


def test_host_slice():
    full = batch_for_step(CFG, SHAPE, 3)
    half = batch_for_step(CFG, SHAPE, 3, host_slice=slice(0, 2))
    np.testing.assert_array_equal(full["tokens"][:2], half["tokens"])


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------

def _tree():
    return {"a": jnp.arange(12.0).reshape(3, 4),
            "b": {"c": jnp.ones((2,), jnp.int32)}}


def test_checkpoint_roundtrip(tmp_path):
    ck = Checkpointer(tmp_path)
    t = _tree()
    ck.save(5, t)
    assert ck.latest_step() == 5
    out = ck.restore(5, t)
    for x, y in zip(jax.tree.leaves(t), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_checkpoint_async_and_gc(tmp_path):
    ck = Checkpointer(tmp_path, keep=2)
    for s in (1, 2, 3, 4):
        ck.save_async(s, _tree())
    ck.wait()
    ck.save(5, _tree())
    steps = ck.all_steps()
    assert len(steps) <= 2 and 5 in steps


def test_checkpoint_detects_corruption(tmp_path):
    ck = Checkpointer(tmp_path)
    path = ck.save(1, _tree())
    # corrupt the array file
    data = np.load(path / "arrays.npz")
    arrays = {k: np.array(data[k]) for k in data.files}
    arrays["a0"] = arrays["a0"] + 1
    np.savez(path / "arrays.npz", **arrays)
    with pytest.raises(IOError):
        ck.restore(1, _tree())


def test_checkpoint_shape_mismatch(tmp_path):
    ck = Checkpointer(tmp_path)
    ck.save(1, _tree())
    bad = {"a": jnp.zeros((2, 2)), "b": {"c": jnp.ones((2,), jnp.int32)}}
    with pytest.raises(ValueError):
        ck.restore(1, bad)


# ---------------------------------------------------------------------------
# fault tolerance (end to end)
# ---------------------------------------------------------------------------

def test_fault_tolerant_training_replays_exactly(tmp_path):
    from repro.launch.train import train
    # run A: no failures
    a = train("tinyllama-1.1b", steps=12, batch=4, seq=32,
              ckpt_dir=str(tmp_path / "a"), ckpt_every=5)
    # run B: two injected failures mid-run
    b = train("tinyllama-1.1b", steps=12, batch=4, seq=32,
              ckpt_dir=str(tmp_path / "b"), ckpt_every=5,
              inject_failures={7: 1, 9: 1})
    assert b["restarts"] == 2
    assert a["final_step"] == b["final_step"] == 12
    # deterministic data + exact replay => identical final parameters
    pa = jax.tree.leaves(a["state"]["params"])
    pb = jax.tree.leaves(b["state"]["params"])
    for x, y in zip(pa, pb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_fault_trainer_gives_up_after_retries(tmp_path):
    def bad_step(state, batch):
        raise RuntimeError("always broken")

    loader = PrefetchingLoader(CFG, SHAPE, DataConfig())
    try:
        tr = FaultTolerantTrainer(
            step_fn=bad_step, checkpointer=Checkpointer(tmp_path),
            loader=loader, cfg=FaultConfig(max_retries=2))
        with pytest.raises(RuntimeError):
            tr.run({"x": jnp.zeros(())}, 3)
    finally:
        loader.close()


# ---------------------------------------------------------------------------
# elastic resharding
# ---------------------------------------------------------------------------

def test_elastic_reshard_to_smaller_mesh():
    from repro.models import init_params
    params, axes = init_params(CFG, jax.random.PRNGKey(0))
    mesh = degraded_mesh(jax.devices()[:1], model=1)
    out, rules = reshard_state(params, axes, mesh)
    for x, y in zip(jax.tree.leaves(params), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ---------------------------------------------------------------------------
# optimizer + compression
# ---------------------------------------------------------------------------

def test_lr_schedule_shape():
    cfg = AdamWConfig(lr=1e-3, warmup_steps=10, total_steps=100)
    assert float(lr_schedule(cfg, jnp.int32(0))) == 0.0
    assert abs(float(lr_schedule(cfg, jnp.int32(10))) - 1e-3) < 1e-9
    assert float(lr_schedule(cfg, jnp.int32(100))) <= 1e-3 * 0.11


def test_adamw_decreases_quadratic():
    params = {"w": jnp.array([3.0, -2.0])}
    opt = init_opt_state(params)
    cfg = AdamWConfig(lr=5e-2, warmup_steps=0, weight_decay=0.0)
    for _ in range(200):
        g = {"w": 2 * params["w"]}
        params, opt, _ = adamw_update(cfg, params, g, opt)
    assert float(jnp.abs(params["w"]).max()) < 0.2


def test_grad_clip():
    params = {"w": jnp.zeros((3,))}
    opt = init_opt_state(params)
    cfg = AdamWConfig(lr=1e-2, grad_clip=1.0, warmup_steps=0)
    g = {"w": jnp.full((3,), 1e6)}
    p2, opt, m = adamw_update(cfg, params, g, opt)
    assert float(m["grad_norm"]) > 1e5
    assert np.isfinite(np.asarray(p2["w"])).all()


def test_compression_error_feedback_converges():
    """EF quantization: accumulated error stays bounded and the mean
    compressed gradient tracks the true gradient."""
    cfg = CompressionConfig(enabled=True, bits=8)
    g = {"w": jnp.array([1e-3, 2e-3, -5e-1, 1.0])}
    err = None
    acc = jnp.zeros(4)
    for _ in range(64):
        cg, err, _ = compress_gradients(g, err, cfg)
        acc = acc + cg["w"]
    mean = np.asarray(acc) / 64
    np.testing.assert_allclose(mean, np.asarray(g["w"]), rtol=5e-2, atol=1e-4)
    assert float(global_norm(err)) < float(global_norm(g))


def test_compression_quantizes():
    cfg = CompressionConfig(enabled=True, bits=8, ef=False)
    g = {"w": jnp.linspace(-1, 1, 1000)}
    cg, _, _ = compress_gradients(g, None, cfg)
    # at most 255 distinct levels
    assert len(np.unique(np.asarray(cg["w"]))) <= 256
