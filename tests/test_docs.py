"""Doc-consistency checks: the docs/ tree cannot silently go stale.

* every `SimConfig` field and result counter must be documented in
  docs/configuration.md (new knobs cannot land undocumented);
* every `designs.py` knob — `design_config` parameter, design name,
  scheduler/bank-model/renumber mode, Table-2 memory technology — must be
  documented;
* every relative markdown link in README.md and docs/ must resolve (this is
  the CI "markdown link check" — no network, external URLs are skipped).
"""
from __future__ import annotations

import dataclasses
import inspect
import pathlib
import re

import pytest

from repro.sim import (
    BANK_MODELS, DESIGNS, INTERVAL_STRATEGIES, RENUMBER_MODES, SCHEDULERS,
    SimConfig, SimResult,
)
from repro.sim.designs import TABLE2, baseline_config, design_config

ROOT = pathlib.Path(__file__).resolve().parent.parent
DOCS = ROOT / "docs"
CONFIG_DOC = DOCS / "configuration.md"

MARKDOWN_FILES = sorted([ROOT / "README.md", *DOCS.glob("*.md")])


def test_docs_tree_exists():
    for name in ("architecture.md", "simulator.md", "configuration.md",
                 "compiler.md", "serving.md", "observability.md",
                 "analytical.md"):
        assert (DOCS / name).is_file(), f"docs/{name} missing"


def test_every_simconfig_field_documented():
    doc = CONFIG_DOC.read_text()
    missing = [f.name for f in dataclasses.fields(SimConfig)
               if f"`{f.name}`" not in doc]
    assert not missing, (
        f"SimConfig fields missing from docs/configuration.md: {missing} "
        "(document new knobs before landing them)")


def test_every_simresult_counter_documented():
    doc = CONFIG_DOC.read_text()
    missing = [f.name for f in dataclasses.fields(SimResult)
               if f.name not in ("design", "workload") and f"`{f.name}`" not in doc]
    assert not missing, \
        f"SimResult counters missing from docs/configuration.md: {missing}"


def test_every_design_config_knob_documented():
    doc = CONFIG_DOC.read_text()
    for fn in (design_config, baseline_config):
        params = [p for p in inspect.signature(fn).parameters if p != "design"]
        missing = [p for p in params if f"`{p}`" not in doc]
        assert not missing, \
            f"{fn.__name__} parameters missing from configuration.md: {missing}"


def test_design_scheduler_and_mode_names_documented():
    doc = CONFIG_DOC.read_text()
    for name in (*DESIGNS, *SCHEDULERS, *BANK_MODELS, *RENUMBER_MODES,
                 *INTERVAL_STRATEGIES):
        assert f"`{name}`" in doc, f"{name!r} not named in configuration.md"


def test_compiler_doc_names_the_pipeline():
    """docs/compiler.md documents every simulator pipeline pass and every
    interval strategy (keeps the pass/strategy docs from going stale)."""
    from repro.core.pipeline import frontend_passes, sim_passes

    doc = (DOCS / "compiler.md").read_text()
    for p in (*sim_passes(), *frontend_passes()):
        assert f"`{p.name}`" in doc, f"pass {p.name!r} undocumented"
    for s in INTERVAL_STRATEGIES:
        assert f"`{s}" in doc, f"strategy {s!r} undocumented"
    for name in ("CompileContext", "PassManager", "pass_stats",
                 "PIPELINE_REV"):
        assert name in doc, f"{name} undocumented in compiler.md"


def test_memtech_table_documented():
    """The Table-2 memory-technology table (designs.TABLE2) is in the doc:
    every config id with its capacity and latency multipliers."""
    doc = CONFIG_DOC.read_text()
    for tech in ("HP-SRAM", "LSTP", "TFET", "DWM"):
        assert tech in doc, f"memory technology {tech} undocumented"
    for tc, t in TABLE2.items():
        row = re.search(rf"^\|\s*{tc}\s*\|.*$", doc, re.M)
        assert row, f"Table-2 config #{tc} has no row in configuration.md"
        assert f"{t['lat_mult']}x" in row.group(0), \
            f"Table-2 config #{tc} row does not show {t['lat_mult']}x latency"


# ------------------------------------------------------------- link checking

_LINK = re.compile(r"(?<!\!)\[[^\]]+\]\(([^)\s]+)\)")
_CODE_FENCE = re.compile(r"```.*?```", re.S)


def _relative_links(md: pathlib.Path):
    text = _CODE_FENCE.sub("", md.read_text())
    for target in _LINK.findall(text):
        if target.startswith(("http://", "https://", "mailto:")):
            continue
        yield target


@pytest.mark.parametrize("md", MARKDOWN_FILES, ids=lambda p: p.name)
def test_markdown_relative_links_resolve(md):
    for target in _relative_links(md):
        path_part, _, anchor = target.partition("#")
        if not path_part:  # pure in-page anchor
            dest = md
        else:
            dest = (md.parent / path_part).resolve()
            assert dest.exists(), f"{md.name}: broken link -> {target}"
        if anchor and dest.suffix == ".md":
            # GitHub-style anchor: a heading must slug to it
            headings = re.findall(r"^#+\s+(.*)$", dest.read_text(), re.M)
            slugs = {re.sub(r"[^\w\- ]", "", h).strip().lower()
                     .replace(" ", "-") for h in headings}
            assert anchor.lower() in slugs, \
                f"{md.name}: dead anchor -> {target}"


def test_docs_are_linked_from_readme():
    readme = (ROOT / "README.md").read_text()
    for name in ("architecture.md", "simulator.md", "configuration.md",
                 "serving.md", "observability.md", "analytical.md"):
        assert f"docs/{name}" in readme, f"README does not index docs/{name}"


def test_observability_doc_names_every_category_and_metric():
    """docs/observability.md documents every cycle-attribution category and
    every sweep-service metric name, plus the layer's API surface — a new
    category or metric cannot land undocumented."""
    from repro.obs import CYCLE_CATEGORIES, SWEEP_METRICS

    doc = (DOCS / "observability.md").read_text()
    for cat in CYCLE_CATEGORIES:
        assert f"`{cat}`" in doc, f"cycle category {cat!r} undocumented"
    for metric in SWEEP_METRICS:
        assert f"`{metric}`" in doc, f"sweep metric {metric!r} undocumented"
    for name in ("cycle_breakdown", "check_breakdown", "classify_stall",
                 "CycleAttributionError", "TraceSink", "trace_simulation",
                 "MetricsRegistry", "metrics_snapshot", "to_prometheus",
                 "sweep_run_id", "SCHED_TID", "--obs-smoke", "--strict",
                 "chrome://tracing", "fig21_breakdown"):
        assert name in doc, f"{name} undocumented in observability.md"
    # the configuration reference must cover the new knob and counter too
    cfg_doc = CONFIG_DOC.read_text()
    assert "`trace`" in cfg_doc and "`cycle_breakdown`" in cfg_doc


def test_analytical_doc_names_the_model_surface():
    """docs/analytical.md documents the fast tier's full public surface —
    every tier name, every calibration coefficient, the pinned pass-stats
    schema, the CLI workflows, and the accuracy gates — so a model change
    cannot land undocumented."""
    from repro.sim.analytic import ANALYTIC_PASS_SCHEMA, Calibration, TIERS

    doc = (DOCS / "analytical.md").read_text()
    for tier in TIERS:
        assert f"`{tier}`" in doc, f"tier {tier!r} undocumented"
    for f in dataclasses.fields(Calibration):
        assert f"`{f.name}`" in doc or f.name in doc, \
            f"Calibration field {f.name!r} undocumented"
    for name in ANALYTIC_PASS_SCHEMA:
        assert f"`{name}`" in doc, f"consumed pass {name!r} undocumented"
    for name in ("AnalyticResult", "analytic_supported", "fit_calibration",
                 "ANALYTIC_REV", "CALIB_REV", "ANALYTIC_PASS_SCHEMA",
                 "check_pass_stats", "pass_stats", "CompiledPlan",
                 "screening_jobs", "analytic_calib", "est_mrf_accesses",
                 "--fit-calibration", "--analytic-smoke",
                 "BENCH_analytic_smoke.json", "analytic_tier",
                 "scheduler_idle"):
        assert name in doc, f"{name} undocumented in analytical.md"
    # the trust gates are stated in the doc with their pinned thresholds
    for gate in ("0.9", "100x", "1.0"):
        assert gate in doc, f"accuracy gate {gate} missing from analytical.md"
    # and the sibling references exist
    cfg_doc = CONFIG_DOC.read_text()
    assert "`tier`" in cfg_doc or "tier" in cfg_doc
    assert "analytical.md" in cfg_doc
    assert "analytical.md" in (DOCS / "serving.md").read_text()


def test_serving_doc_names_every_sweep_knob():
    """docs/serving.md documents every `SweepConfig` field, every failure
    kind, and the operational surface of the sweep service (env vars,
    quarantine, report) — a new retry/timeout knob cannot land undocumented."""
    from repro.serving.sweep import (
        FAILURE_KINDS, FailureRecord, SweepConfig, SweepReport,
    )

    doc = (DOCS / "serving.md").read_text()
    missing = [f.name for f in dataclasses.fields(SweepConfig)
               if f"`{f.name}`" not in doc]
    assert not missing, \
        f"SweepConfig knobs missing from docs/serving.md: {missing}"
    for kind in FAILURE_KINDS:
        assert f"`{kind}`" in doc, f"failure kind {kind!r} undocumented"
    for f in dataclasses.fields(SweepReport):
        assert f"`{f.name}`" in doc, \
            f"SweepReport field {f.name!r} undocumented in serving.md"
    for f in dataclasses.fields(FailureRecord):
        assert f"`{f.name}`" in doc, \
            f"FailureRecord field {f.name!r} undocumented in serving.md"
    for name in ("REPRO_FAULT_PLAN", "REPRO_SIMCACHE", "REPRO_SIM_PROCS",
                 "quarantine", "sim_key", "SweepReport", "max_cycles",
                 "SimBudgetExceeded", "--chaos-smoke"):
        assert name in doc, f"{name} undocumented in serving.md"
