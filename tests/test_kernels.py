"""Per-kernel validation: shape/dtype sweeps + hypothesis property tests,
all against the pure-jnp oracles, in Pallas interpret mode on CPU."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.ltrf_matmul.ops import ltrf_matmul, matmul_plan, pick_blocks
from repro.kernels.ltrf_matmul.ref import matmul_ref
from repro.kernels.ssd_scan.ops import ssd_scan
from repro.kernels.ssd_scan.ref import ssd_ref


def _tol(dtype):
    return dict(rtol=3e-2, atol=8e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# ltrf_matmul
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", [(128, 128, 128), (256, 384, 128),
                                   (300, 500, 200), (64, 1024, 96)])
def test_matmul_shapes_dtypes(shape, dtype):
    M, K, N = shape
    x = jax.random.normal(jax.random.PRNGKey(0), (M, K)).astype(dtype)
    w = jax.random.normal(jax.random.PRNGKey(1), (K, N)).astype(dtype)
    got = ltrf_matmul(x, w, bm=128, bk=128, bn=128, interpret=True)
    want = matmul_ref(x, w)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


@pytest.mark.parametrize("blocks", [(128, 128, 128), (128, 256, 128)])
def test_matmul_block_sweep(blocks):
    bm, bk, bn = blocks
    x = jax.random.normal(jax.random.PRNGKey(2), (256, 512)).astype(jnp.bfloat16)
    w = jax.random.normal(jax.random.PRNGKey(3), (512, 256)).astype(jnp.bfloat16)
    got = ltrf_matmul(x, w, bm=bm, bk=bk, bn=bn, interpret=True)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(matmul_ref(x, w), np.float32),
                               **_tol(jnp.bfloat16))


@settings(max_examples=10, deadline=None)
@given(m=st.integers(1, 3), k=st.integers(1, 4), n=st.integers(1, 3),
       seed=st.integers(0, 100))
@pytest.mark.slow
def test_matmul_property(m, k, n, seed):
    M, K, N = m * 64 + 32, k * 64, n * 64 + 16
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, (M, K), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(seed + 1), (K, N), jnp.float32)
    got = ltrf_matmul(x, w, bm=128, bk=128, bn=128, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(matmul_ref(x, w)),
                               rtol=1e-3, atol=1e-3)


def test_matmul_plan_conflict_free():
    plan, blocks = matmul_plan(4096, 17920, 5120)  # phi3 MLP down-proj scale
    assert plan.num_intervals >= 1
    plan.validate()
    # every prefetch round fits the budget
    assert plan.max_interval_bytes() <= plan.vmem_budget


def test_pick_blocks_mxu_aligned():
    bm, bk, bn = pick_blocks(4096, 5120, 17920)
    assert bm % 128 == bk % 128 == bn % 128 == 0
    ws = bm * bk * 2 + 2 * bk * bn * 2 + bm * bn * 4 + bm * bn * 2
    assert ws <= 96 * 2 ** 20


# ---------------------------------------------------------------------------
# flash_attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("cfg", [
    dict(B=1, H=2, KV=2, S=128, d=64),   # MHA
    dict(B=2, H=4, KV=2, S=128, d=64),   # GQA 2:1
    dict(B=1, H=8, KV=1, S=256, d=32),   # MQA
])
def test_flash_attention_configs(cfg, dtype):
    B, H, KV, S, d = cfg["B"], cfg["H"], cfg["KV"], cfg["S"], cfg["d"]
    q = jax.random.normal(jax.random.PRNGKey(0), (B, H, S, d)).astype(dtype)
    k = jax.random.normal(jax.random.PRNGKey(1), (B, KV, S, d)).astype(dtype)
    v = jax.random.normal(jax.random.PRNGKey(2), (B, KV, S, d)).astype(dtype)
    got = flash_attention(q, k, v, bq=64, bk=64, interpret=True)
    want = attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


def test_flash_attention_non_causal():
    q = jax.random.normal(jax.random.PRNGKey(0), (1, 2, 128, 32))
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 2, 128, 32))
    v = jax.random.normal(jax.random.PRNGKey(2), (1, 2, 128, 32))
    got = flash_attention(q, k, v, bq=64, bk=64, causal=False, interpret=True)
    want = attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 50), h=st.sampled_from([1, 2, 4]),
       g=st.sampled_from([1, 2]), blocks=st.sampled_from([32, 64]))
@pytest.mark.slow
def test_flash_attention_property(seed, h, g, blocks):
    B, S, d = 1, 128, 32
    H, KV = h * g, h
    q = jax.random.normal(jax.random.PRNGKey(seed), (B, H, S, d))
    k = jax.random.normal(jax.random.PRNGKey(seed + 1), (B, KV, S, d))
    v = jax.random.normal(jax.random.PRNGKey(seed + 2), (B, KV, S, d))
    got = flash_attention(q, k, v, bq=blocks, bk=blocks, interpret=True)
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(attention_ref(q, k, v)),
                               rtol=2e-3, atol=2e-3)


def test_flash_attention_rows_sum_to_one_property():
    """Causal first row attends only to itself: out[0] == v[0]."""
    q = jax.random.normal(jax.random.PRNGKey(0), (1, 1, 64, 32))
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 1, 64, 32))
    v = jax.random.normal(jax.random.PRNGKey(2), (1, 1, 64, 32))
    got = flash_attention(q, k, v, bq=32, bk=32, interpret=True)
    np.testing.assert_allclose(np.asarray(got[0, 0, 0]), np.asarray(v[0, 0, 0]),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# ssd_scan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("chunk", [16, 32, 96])
@pytest.mark.parametrize("S", [96, 160])
def test_ssd_chunk_sizes(S, chunk):
    B, H, P, N = 2, 3, 8, 16
    x = jax.random.normal(jax.random.PRNGKey(0), (B, S, H, P)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(jax.random.PRNGKey(1), (B, S, H)))
    A = -jnp.exp(jnp.linspace(0.0, 1.5, H))
    Bm = jax.random.normal(jax.random.PRNGKey(2), (B, S, N)) * 0.3
    Cm = jax.random.normal(jax.random.PRNGKey(3), (B, S, N)) * 0.3
    y, fin = ssd_scan(x, dt, A, Bm, Cm, chunk=chunk, interpret=True)
    yr, finr = ssd_ref(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), rtol=3e-3, atol=3e-3)
    np.testing.assert_allclose(np.asarray(fin), np.asarray(finr), rtol=3e-3, atol=3e-3)


def test_ssd_bf16_inputs():
    B, S, H, P, N = 1, 64, 2, 8, 8
    x = (jax.random.normal(jax.random.PRNGKey(0), (B, S, H, P)) * 0.5).astype(jnp.bfloat16)
    dt = jax.nn.softplus(jax.random.normal(jax.random.PRNGKey(1), (B, S, H))).astype(jnp.bfloat16)
    A = -jnp.exp(jnp.linspace(0.0, 1.0, H))
    Bm = (jax.random.normal(jax.random.PRNGKey(2), (B, S, N)) * 0.3).astype(jnp.bfloat16)
    Cm = (jax.random.normal(jax.random.PRNGKey(3), (B, S, N)) * 0.3).astype(jnp.bfloat16)
    y, _ = ssd_scan(x, dt, A, Bm, Cm, chunk=32, interpret=True)
    yr, _ = ssd_ref(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yr, np.float32), rtol=1e-1, atol=1e-1)


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 30), chunk=st.sampled_from([8, 16, 32]))
def test_ssd_property_matches_recurrence(seed, chunk):
    B, S, H, P, N = 1, 64, 2, 4, 8
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, (B, S, H, P)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(jax.random.PRNGKey(seed + 1), (B, S, H)))
    A = -jnp.exp(jax.random.uniform(jax.random.PRNGKey(seed + 2), (H,)))
    Bm = jax.random.normal(jax.random.PRNGKey(seed + 3), (B, S, N)) * 0.3
    Cm = jax.random.normal(jax.random.PRNGKey(seed + 4), (B, S, N)) * 0.3
    y, fin = ssd_scan(x, dt, A, Bm, Cm, chunk=chunk, interpret=True)
    yr, finr = ssd_ref(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), rtol=5e-3, atol=5e-3)
    np.testing.assert_allclose(np.asarray(fin), np.asarray(finr), rtol=5e-3, atol=5e-3)


def test_ssd_decay_monotone_property():
    """With C == B == const and positive x, later states accumulate decay:
    the scan must equal the recurrence even for long horizons (stability)."""
    B, S, H, P, N = 1, 128, 1, 4, 4
    x = jnp.ones((B, S, H, P)) * 0.1
    dt = jnp.ones((B, S, H)) * 0.5
    A = jnp.array([-1.0])
    Bm = jnp.ones((B, S, N)) * 0.2
    Cm = jnp.ones((B, S, N)) * 0.2
    y, _ = ssd_scan(x, dt, A, Bm, Cm, chunk=32, interpret=True)
    yr, _ = ssd_ref(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), rtol=1e-4, atol=1e-5)
