"""End-to-end behaviour tests for the paper's system.

These tie the layers together: compiler passes -> performance model
(the paper's claims), and interval plans -> kernels/runtime (the TPU side).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    form_register_intervals, prefetch_schedule, renumber_registers,
)
from repro.core.plan import LayerNode, Tile, plan_layer_stream
from repro.sim import baseline_config, design_config, simulate
from repro.workloads import WORKLOADS, listing1_program, workload_names


def _synth_workloads():
    """The paper's synthetic mix: these claims are about that suite, so pin
    it explicitly — the registry may hold lazily-loaded traced kernels too."""
    return [WORKLOADS[n] for n in workload_names()]


@pytest.mark.slow
def test_paper_headline_claim():
    """An 8x-capacity, 6.3x-slower MRF + LTRF_conf stays competitive with the
    fast-RF baseline on register-sensitive workloads (paper: +34% avg; the
    calibrated model reproduces the direction and per-workload gains)."""
    import math
    vals = []
    for w in (w for w in _synth_workloads() if w.register_sensitive):
        base = simulate(w, baseline_config()).ipc
        conf = simulate(w, design_config("LTRF_conf", table2_config=7)).ipc
        vals.append(conf / base)
    geo = math.exp(sum(math.log(v) for v in vals) / len(vals))
    assert geo > 0.9, f"LTRF_conf geomean {geo:.2f}"
    assert max(vals) > 1.1  # some workloads gain substantially


@pytest.mark.slow
def test_ltrf_beats_bl_and_rfc_at_slow_mrf():
    """The ordering that motivates the paper (Fig 14 at config #7)."""
    import math
    r = {}
    for d in ("BL", "RFC", "LTRF", "LTRF_conf"):
        vals = []
        for w in _synth_workloads():
            base = simulate(w, baseline_config()).ipc
            vals.append(simulate(w, design_config(d, table2_config=7)).ipc / base)
        r[d] = math.exp(sum(math.log(v) for v in vals) / len(vals))
    # measured geomeans (#7): BL 0.73, RFC 0.87, LTRF 0.87, LTRF_conf 0.95.
    # Basic LTRF ties RFC in our model (the 8-active-slot cap costs ~8% that
    # the paper's simulator doesn't charge); the full design LTRF_conf is
    # clearly ahead of both, and everything beats the non-cached BL.
    assert r["LTRF"] > r["BL"]
    assert r["LTRF_conf"] > r["RFC"] > r["BL"]
    assert r["LTRF_conf"] >= r["LTRF"]


@pytest.mark.slow
def test_latency_tolerance_ordering_paper_fig15():
    from repro.sim import max_tolerable_latency
    w = WORKLOADS["mri-q"]
    rfc = max_tolerable_latency(w, "RFC")
    ltrf = max_tolerable_latency(w, "LTRF")
    conf = max_tolerable_latency(w, "LTRF_conf")
    assert conf >= ltrf >= rfc


def test_compiler_to_simulator_integration():
    """The sim consumes real compiler output: renumbering must not increase
    total prefetch serial rounds and never changes executed instructions."""
    w = WORKLOADS["stencil"]
    an = form_register_intervals(w.program, n_cap=16)
    pre = sum(op.serial_rounds for op in prefetch_schedule(an, num_banks=16))
    rr = renumber_registers(an, num_banks=16)
    post = sum(op.serial_rounds
               for op in prefetch_schedule(rr.analysis, num_banks=16))
    assert post <= pre
    a = simulate(w, design_config("LTRF", table2_config=7))
    b = simulate(w, design_config("LTRF_conf", table2_config=7))
    assert a.instructions == b.instructions


def test_walkthrough_end_to_end():
    """Listing 1: intervals -> ICG -> coloring -> conflict-free prefetch."""
    an = form_register_intervals(listing1_program(), n_cap=4)
    rr = renumber_registers(an, num_banks=4, scheme="grouped")
    ops = prefetch_schedule(rr.analysis, num_banks=4, scheme="grouped")
    assert all(op.conflicts == 0 for op in ops)


def test_plan_drives_kernel_blocks():
    """The interval plan and the kernel block picker agree on VMEM budgets."""
    from repro.kernels.ltrf_matmul.ops import VMEM_BUDGET, matmul_plan
    plan, (bm, bk, bn) = matmul_plan(4096, 17920, 5120)
    ws = bm * bk * 2 + 2 * bk * bn * 2 + bm * bn * 4 + bm * bn * 2
    assert ws <= VMEM_BUDGET
    assert plan.max_interval_bytes() <= plan.vmem_budget + plan.tile_bytes


def test_model_layer_plan_for_phi3_scale():
    """A phi3-sized layer stream plans into >1 VMEM interval (the weights
    exceed VMEM: this is the 'high-capacity, slow main RF' regime)."""
    MB = 2 ** 20
    d, ff = 5120, 17920
    layers = []
    for i in range(4):
        layers.append(LayerNode(
            f"blk{i}",
            [Tile(f"attn{i}", 4 * d * d * 2 // 16),      # TP-sharded
             Tile(f"mlp{i}", 3 * d * ff * 2 // 16)]))
    plan = plan_layer_stream(layers, vmem_budget=96 * MB, num_slots=2)
    assert plan.num_intervals >= 2
    plan.validate()


def test_trained_model_serves(tmp_path):
    """Train a few steps, then serve with the trained params (end-to-end)."""
    from repro.configs import get_smoke
    from repro.launch.train import train
    from repro.serving import ServeConfig, ServingEngine

    out = train("qwen3-0.6b", steps=4, batch=4, seq=32,
                ckpt_dir=str(tmp_path), ckpt_every=100)
    cfg = get_smoke("qwen3-0.6b")
    eng = ServingEngine(cfg, params=out["state"]["params"],
                        sc=ServeConfig(max_len=32, active_slots=2,
                                       total_pages=8))
    r = eng.submit([1, 2], max_new_tokens=4)
    toks = eng.run()[r.rid]
    assert len(toks) >= 4 and all(0 <= t < cfg.vocab for t in toks)


@pytest.mark.slow
def test_compression_trains_losslessly_enough(tmp_path):
    """int8 EF compression must not blow up training."""
    from repro.launch.train import train
    a = train("tinyllama-1.1b", steps=8, batch=4, seq=32,
              ckpt_dir=str(tmp_path / "c0"), compress=False)
    b = train("tinyllama-1.1b", steps=8, batch=4, seq=32,
              ckpt_dir=str(tmp_path / "c1"), compress=True)
    assert np.isfinite(b["losses"]).all()
    assert abs(a["losses"][-1] - b["losses"][-1]) < 0.5


@pytest.mark.slow
def test_grad_accum_matches_full_batch():
    """n_micro=2 must match the single-shot gradient step numerically."""
    from repro.configs import get_smoke
    from repro.distributed.sharding import default_rules
    from repro.launch.mesh import make_host_mesh
    from repro.models import init_params
    from repro.optim.adamw import init_opt_state
    from repro.runtime.train_step import build_train_step

    cfg = get_smoke("tinyllama-1.1b")
    rules = default_rules(make_host_mesh())
    params, _ = init_params(cfg, jax.random.PRNGKey(0))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (4, 32), 0,
                                          cfg.vocab),
             "labels": jax.random.randint(jax.random.PRNGKey(1), (4, 32), 0,
                                          cfg.vocab)}
    s1 = {"params": params, "opt": init_opt_state(params)}
    s2 = jax.tree.map(lambda x: x, s1)
    one = jax.jit(build_train_step(cfg, rules, n_micro=1))
    two = jax.jit(build_train_step(cfg, rules, n_micro=2))
    o1, m1 = one(s1, batch)
    o2, m2 = two(s2, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                               rtol=2e-2, atol=2e-3)
    for a, b in zip(jax.tree.leaves(o1["params"]),
                    jax.tree.leaves(o2["params"])):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=5e-2, atol=5e-2)


def test_fsdp_pure_layout_rules():
    """The fsdp_pure layout spans all mesh axes for batch + param sharding."""
    from repro.distributed.sharding import default_rules
    from repro.launch.mesh import make_host_mesh
    rules = default_rules(make_host_mesh(), layout="fsdp_pure")
    assert rules.axis("heads") is None
    assert rules.axis("batch") == rules.axis("embed")
