"""Bank-level register-file arbitration + renumbering ablation (ISSUE 4).

Three layers:

* **no-op guarantee**: ``bank_model="none"`` (the default) never touches the
  new counters and stays bit-identical to the frozen golden engine — the
  hard invariant every engine change must respect;
* **determinism pins**: exact arbitrated counters for the paper's Listing-1
  program, so the arbitration model itself cannot drift silently;
* **the §4.3 ablation property**: under ``bank_model="arbitrated"``,
  LTRF with ICG renumbering accumulates no more bank-conflict cycles than
  the same design with identity numbering on every synthetic workload,
  strictly fewer in aggregate, and never loses IPC — the end-to-end claim
  the renumbering pass exists to deliver.
"""
from dataclasses import replace

import pytest

from repro.sim import (
    BANK_MODELS, DESIGNS, RENUMBER_MODES, SimConfig, design_config, simulate,
    simulate_gpu,
)
from repro.sim.golden import golden_simulate
from repro.workloads import WORKLOADS, workload_names
from repro.workloads.suite import Workload, listing1_program


def listing1_workload() -> Workload:
    return Workload(name="listing1", program=listing1_program(),
                    trips={"L1": 100}, register_sensitive=False,
                    regs_per_thread=8, suite="paper")


# ------------------------------------------------------------ config plumbing

def test_bank_model_none_is_default():
    cfg = SimConfig()
    assert cfg.bank_model == "none"
    assert cfg.renumber == "icg"
    assert "none" in BANK_MODELS and "arbitrated" in BANK_MODELS
    assert RENUMBER_MODES == ("icg", "identity")


def test_unknown_bank_model_and_renumber_raise():
    w = WORKLOADS["bfs"]
    with pytest.raises(ValueError):
        simulate(w, SimConfig(bank_model="banked3000", num_warps=4))
    with pytest.raises(ValueError):
        simulate(w, SimConfig(renumber="rainbow", num_warps=4))


# ----------------------------------------------------------- no-op guarantee

@pytest.mark.parametrize("design", DESIGNS)
def test_bank_model_none_zero_counters_and_golden_identical(design):
    """The default model leaves the new counters untouched and remains
    bit-identical to the frozen seed engine."""
    w = WORKLOADS["srad"]
    cfg = design_config(design, table2_config=7, num_warps=12,
                        bank_model="none")
    r = simulate(w, cfg)
    assert r.bank_conflicts == 0 and r.bank_conflict_cycles == 0
    assert r == golden_simulate(w, cfg), design


def test_arbitrated_same_instructions_as_none():
    """Arbitration adds latency, never work: the retired dynamic instruction
    stream is identical with and without the model."""
    for name in ("srad", "btree", "sgemm"):
        w = WORKLOADS[name]
        for design in ("BL", "RFC", "LTRF", "LTRF_conf"):
            cfg = design_config(design, table2_config=7, num_warps=8)
            arb = simulate(w, replace(cfg, bank_model="arbitrated"))
            none = simulate(w, cfg)
            assert arb.instructions == none.instructions, (name, design)
            assert arb.resident_warps == none.resident_warps


def test_ideal_design_exempt_from_arbitration():
    w = WORKLOADS["srad"]
    cfg = design_config("Ideal", table2_config=7, num_warps=12,
                        bank_model="arbitrated")
    r = simulate(w, cfg)
    assert r.bank_conflicts == 0 and r.bank_conflict_cycles == 0
    assert r == simulate(w, replace(cfg, bank_model="none"))


# ---------------------------------------------------------- determinism pins

# Exact (cycles, bank_conflicts, bank_conflict_cycles) for Listing 1 under
# bank_model="arbitrated" at Table-2 config #7, 16 warps.
LISTING1_ARBITRATED = {
    "BL":        (807, 15, 60),
    "RFC":       (587, 16, 16),
    "SHRF":      (777, 41, 41),
    "LTRF":      (628, 9, 9),
    "LTRF_conf": (628, 9, 9),
    "LTRF_plus": (550, 9, 9),
    "Ideal":     (577, 0, 0),
}


@pytest.mark.parametrize("design", DESIGNS)
def test_listing1_arbitrated_counters_pinned(design):
    w = listing1_workload()
    cfg = design_config(design, table2_config=7, num_warps=16,
                        bank_model="arbitrated")
    r = simulate(w, cfg)
    got = (r.cycles, r.bank_conflicts, r.bank_conflict_cycles)
    assert got == LISTING1_ARBITRATED[design], (design, got)
    # deterministic across instances
    assert simulate(w, cfg) == r


# -------------------------------------------------------- the §4.3 ablation

def _ablation_pair(name: str, table2_config: int = 7):
    w = WORKLOADS[name]
    icg = simulate(w, design_config("LTRF_conf", table2_config=table2_config,
                                    bank_model="arbitrated"))
    ident = simulate(w, design_config("LTRF_conf",
                                      table2_config=table2_config,
                                      bank_model="arbitrated",
                                      renumber="identity"))
    return icg, ident


@pytest.mark.parametrize("name", sorted(workload_names()))
def test_icg_never_worse_than_identity(name):
    """Per workload: ICG renumbering accumulates no more bank-conflict
    cycles than identity numbering and never loses IPC."""
    icg, ident = _ablation_pair(name)
    assert icg.bank_conflict_cycles <= ident.bank_conflict_cycles, name
    assert icg.ipc >= ident.ipc, name


@pytest.mark.slow
def test_icg_strictly_fewer_conflict_cycles_in_aggregate():
    """ISSUE-4 acceptance: strictly fewer bank-conflict cycles across the
    tracked sweep (both Table-2 design points)."""
    for tc in (6, 7):
        tot_icg = tot_ident = 0
        for name in workload_names():
            icg, ident = _ablation_pair(name, table2_config=tc)
            tot_icg += icg.bank_conflict_cycles
            tot_ident += ident.bank_conflict_cycles
        assert tot_icg < tot_ident, tc


def test_identity_renumber_matches_plain_ltrf_plan():
    """LTRF_conf with identity numbering compiles to LTRF's plan: same
    program, same prefetch ops (the knob only ablates the coloring pass)."""
    from repro.sim import Simulator
    w = WORKLOADS["srad"]
    a = Simulator(design_config("LTRF_conf", table2_config=7,
                                renumber="identity"), w)
    b = Simulator(design_config("LTRF", table2_config=7), w)
    assert a.prog is b.prog
    assert a.pf_ops is b.pf_ops


def test_bank_conflict_rate_property():
    w = WORKLOADS["srad"]
    r = simulate(w, design_config("BL", table2_config=7, num_warps=8,
                                  bank_model="arbitrated"))
    assert r.bank_conflicts > 0
    assert r.bank_conflict_rate == r.bank_conflicts / r.instructions


# ----------------------------------------------------------------- GPU scale

def test_gpu_aggregates_bank_counters():
    """Per-SM bank-conflict counters sum into the GpuResult (ISSUE 4:
    sim/gpu.py aggregates the new counters)."""
    w = WORKLOADS["srad"]
    cfg = design_config("LTRF_conf", table2_config=7, num_warps=16,
                        num_sms=2, bank_model="arbitrated")
    g = simulate_gpu(w, cfg)
    assert g.bank_conflicts == sum(r.bank_conflicts for r in g.per_sm)
    assert g.bank_conflict_cycles == \
        sum(r.bank_conflict_cycles for r in g.per_sm)
    assert g.bank_conflicts > 0
    assert g.bank_conflict_rate == g.bank_conflicts / g.instructions


def test_gpu_num_sms1_arbitrated_matches_single_sm():
    """The GPU dispatcher passes the bank knobs through unchanged."""
    w = WORKLOADS["btree"]
    cfg = design_config("LTRF_conf", table2_config=7, num_warps=16,
                        bank_model="arbitrated", renumber="identity")
    g = simulate_gpu(w, cfg)
    r = simulate(w, cfg)
    assert g.per_sm == (r,)
    assert (g.bank_conflicts, g.bank_conflict_cycles) == \
        (r.bank_conflicts, r.bank_conflict_cycles)
