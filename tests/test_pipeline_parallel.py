"""Pipeline-parallel correctness: GPipe schedule == sequential oracle.

Runs in a subprocess with XLA_FLAGS forcing 4 host devices so the pipeline
axis is real (the main test process keeps 1 device)."""
import subprocess
import sys
import textwrap

from repro.kernels._compat import jax_subprocess_env

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax, jax.numpy as jnp, numpy as np
    from repro.distributed.pipeline_parallel import (
        pipeline_forward, sequential_reference)

    mesh = jax.make_mesh((4,), ("stage",))
    D = 16

    def stage_fn(p, x):
        return jnp.tanh(x @ p["w"] + p["b"])

    k = jax.random.PRNGKey(0)
    params = {
        "w": jax.random.normal(k, (4, D, D)) * 0.5,
        "b": jnp.linspace(-1, 1, 4)[:, None] * jnp.ones((4, D)),
    }
    x = jax.random.normal(jax.random.PRNGKey(1), (6, 8, D))  # 6 micro x 8 x D

    got = pipeline_forward(stage_fn, params, x, mesh)
    want = sequential_reference(stage_fn, params, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)
    print("PIPELINE_OK")
""")


def test_gpipe_matches_sequential():
    # jax_subprocess_env pins JAX_PLATFORMS: without it, jax probes for
    # accelerator plugins, which hangs on hosts with a TPU-less libtpu.
    r = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                       text=True, timeout=300, env=jax_subprocess_env())
    assert "PIPELINE_OK" in r.stdout, r.stdout + r.stderr
