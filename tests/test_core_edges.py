"""Edge-case coverage for prefetch scheduling, coloring, and spill fallback.

The cases the sweep never hits but generated/lifted programs can: intervals
with empty working sets, single-register programs, interval caps below a
single instruction's operand count, cliques bigger than the color budget,
and register budgets below the program's working set (spill path).
"""
import pytest

from repro.core.coloring import chaitin_color
from repro.core.intervals import form_register_intervals
from repro.core.ir import parse_asm
from repro.core.prefetch import (code_size_overhead, conflict_distribution,
                                 prefetch_schedule)
from repro.frontend.regalloc import allocate_registers


# ------------------------------------------------------------------ prefetch

def test_empty_working_set_prefetch():
    """Register-free programs produce empty, conflict-free prefetch ops."""
    prog = parse_asm("nop\nnop\nexit", name="empty")
    an = form_register_intervals(prog, n_cap=8)
    an.validate()
    ops = prefetch_schedule(an, num_banks=16)
    assert ops
    for op in ops:
        assert op.bitvector == frozenset()
        assert op.conflicts == 0
        assert op.serial_rounds == 1
    assert conflict_distribution(ops) == {0: 1.0}
    assert code_size_overhead(an) > 0  # bit-vectors still cost code space


def test_conflict_distribution_no_ops():
    assert conflict_distribution([]) == {0: 1.0}


def test_single_register_program():
    prog = parse_asm("""
        mov r0, 1
        add r0, r0, r0
        exit
    """, name="one-reg")
    an = form_register_intervals(prog, n_cap=4)
    an.validate()
    assert len(an.intervals) == 1
    (op,) = prefetch_schedule(an, num_banks=16)
    assert op.bitvector == frozenset({0})
    assert op.serial_rounds == 1 and op.conflicts == 0


def test_cap_smaller_than_single_instruction():
    """A mad touching 4 registers under cap 2: the interval must legally
    exceed the cap (validate's single-instruction escape hatch) and the
    prefetch still schedules it."""
    prog = parse_asm("""
        mov r0, 1
        mov r1, 2
        mov r2, 3
        mad r3, r0, r1, r2
        exit
    """, name="wide-instr")
    an = form_register_intervals(prog, n_cap=2)
    an.validate()
    assert any(len(iv.working_set) > 2 for iv in an.intervals)
    ops = prefetch_schedule(an, num_banks=2)
    assert max(op.serial_rounds for op in ops) >= 2  # 4 regs over 2 banks


# ------------------------------------------------------------------ coloring

def test_uncolorable_clique_fallback():
    """K5 with 2 colors: every node still gets a color, the shortfall is
    reported, and usage stays balanced (the paper's 'minimal remaining
    conflicts' behaviour)."""
    adj = {i: {j for j in range(5) if j != i} for i in range(5)}
    c = chaitin_color(adj, 2)
    assert set(c.colors) == set(range(5))
    assert all(0 <= v < 2 for v in c.colors.values())
    assert c.uncolorable
    assert c.conflicts(adj) > 0
    usage = [sum(1 for v in c.colors.values() if v == k) for k in range(2)]
    assert abs(usage[0] - usage[1]) <= 1


def test_colorable_clique_exact():
    adj = {i: {j for j in range(5) if j != i} for i in range(5)}
    c = chaitin_color(adj, 5)
    assert not c.uncolorable
    assert c.conflicts(adj) == 0
    assert len(set(c.colors.values())) == 5


def test_color_empty_graph():
    c = chaitin_color({}, 4)
    assert c.colors == {} and not c.uncolorable


# ----------------------------------------------------------------- spill path

def test_spill_when_maxregcount_below_working_set():
    """12 simultaneously-live registers under maxregcount=8: the allocator
    must spill, insert shuttle ld/st traffic, and stay under budget."""
    n = 12
    lines = [f"mov r{i}, {i}" for i in range(n)]
    # one instruction reading every value keeps them all live to the end
    for i in range(0, n - 2, 2):
        lines.append(f"mad r{i}, r{i}, r{i + 1}, r{i + 2}")
    lines.append("exit")
    prog = parse_asm("\n".join(lines), name="pressure")
    res = allocate_registers(prog, maxregcount=8)
    assert res.spilled
    assert res.spill_loads > 0 and res.spill_stores > 0
    assert res.regs_per_thread <= 8
    assert max(res.prog.registers()) < 8
    res.prog.validate()


def test_maxregcount_too_small_rejected():
    prog = parse_asm("mov r0, 1\nexit", name="t")
    with pytest.raises(ValueError):
        allocate_registers(prog, maxregcount=4)
