"""Tests for the TPU-side IntervalPlan (the paper's analysis on layer graphs)."""
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.plan import (
    IntervalPlan, LayerNode, Tile, plan_for_matmul, plan_layer_stream,
)

MB = 2 ** 20


def _layers(n, tiles_per_layer, tile_mb):
    return [LayerNode(name=f"layer{i}",
                      tiles=[Tile(f"t{i}_{j}", tile_mb * MB)
                             for j in range(tiles_per_layer)])
            for i in range(n)]


def test_small_model_single_interval():
    plan = plan_layer_stream(_layers(4, 2, 1), vmem_budget=64 * MB)
    assert plan.num_intervals == 1
    assert plan.max_interval_bytes() <= 64 * MB
    plan.validate()


def test_big_model_streams_in_intervals():
    plan = plan_layer_stream(_layers(16, 4, 8), vmem_budget=64 * MB)
    assert plan.num_intervals > 1
    assert plan.max_interval_bytes() <= 64 * MB
    plan.validate()
    # every layer is covered by exactly one prefetch
    covered = [l for p in plan.prefetches for l in p.layer_names]
    assert sorted(covered) == sorted(set(covered))


def test_slots_conflict_free_within_round():
    plan = plan_layer_stream(_layers(8, 2, 8), vmem_budget=32 * MB,
                             num_slots=4)
    for p in plan.prefetches:
        if len(p.tiles) <= plan.num_slots:
            slots = [p.slots[t.name] for t in p.tiles]
            assert len(set(slots)) == len(slots)


def test_matmul_plan_counts_tiles():
    plan = plan_for_matmul(m=1024, k=2048, n=1024, bk=512, bn=512,
                           vmem_budget=16 * MB)
    all_tiles = {t.name for p in plan.prefetches for t in p.tiles}
    assert len(all_tiles) == (2048 // 512) * (1024 // 512)
    plan.validate()


def test_shared_tiles_fetched_once_per_interval():
    # two layers share a tile (zamba2's shared attention block)
    shared = Tile("shared", 4 * MB)
    layers = [
        LayerNode("a", [Tile("wa", 4 * MB), shared]),
        LayerNode("b", [Tile("wb", 4 * MB), shared]),
    ]
    plan = plan_layer_stream(layers, vmem_budget=64 * MB)
    assert plan.num_intervals == 1
    names = [t.name for t in plan.prefetches[0].tiles]
    assert names.count("shared") == 1


@settings(max_examples=20, deadline=None)
@given(n=st.integers(1, 12), tiles=st.integers(1, 4), mb=st.integers(1, 16),
       budget=st.sampled_from([32, 64, 128]))
def test_plan_property_budget_respected(n, tiles, mb, budget):
    plan = plan_layer_stream(_layers(n, tiles, mb), vmem_budget=budget * MB)
    plan.validate()
    for p in plan.prefetches:
        assert p.bytes <= budget * MB or len(p.tiles) == 1
