"""Interval-formation strategies + prefetch-stall accounting (ISSUE 5).

Three layers, mirroring the bank-arbitration suite:

* **no-op guarantee**: ``interval_strategy="paper"`` (the default) is
  bit-identical to the frozen golden engine — the hard invariant the
  pipeline refactor must respect;
* **determinism pins**: exact `prefetch_stall_cycles` for the paper's
  Listing-1 program, so the new counter cannot drift silently;
* **the acceptance verdicts**: on the high-register-pressure workloads
  with an oversized ``interval_cap``, the ``capacity`` strategy yields
  strictly fewer aggregate prefetch-stall cycles than ``paper`` on the
  paper's full compile pipeline (LTRF_conf) with no per-workload IPC
  regression — the claims the `interval_sweep` section of BENCH_sim.json
  records.
"""
from dataclasses import replace

import pytest

from repro.sim import (
    DESIGNS, INTERVAL_STRATEGIES, SimConfig, Simulator, design_config,
    simulate, simulate_gpu,
)
from repro.sim.golden import golden_simulate
from repro.workloads import WORKLOADS, workload_names
from repro.workloads.suite import Workload, listing1_program

# The interval_sweep acceptance parameters (benchmarks.sweep_subset).
SWEEP_CAP = 48
VERDICT_DESIGN = "LTRF_conf"


def listing1_workload() -> Workload:
    return Workload(name="listing1", program=listing1_program(),
                    trips={"L1": 100}, register_sensitive=False,
                    regs_per_thread=8, suite="paper")


def _sensitive_names():
    return [n for n in workload_names() if WORKLOADS[n].register_sensitive]


# ------------------------------------------------------------ config plumbing

def test_paper_strategy_is_default():
    cfg = SimConfig()
    assert cfg.interval_strategy == "paper"
    assert INTERVAL_STRATEGIES == ("paper", "capacity", "fixed")


def test_unknown_strategy_raises():
    w = WORKLOADS["bfs"]
    with pytest.raises(ValueError):
        simulate(w, SimConfig(interval_strategy="strands", num_warps=4))


# ----------------------------------------------------------- no-op guarantee

@pytest.mark.parametrize("design", DESIGNS)
def test_paper_strategy_bit_identical_to_golden(design):
    """ISSUE 5 acceptance pin: the default strategy is a strict no-op —
    bit-identical to the frozen golden oracle (which predates the knob)."""
    w = WORKLOADS["srad"]
    cfg = design_config(design, table2_config=7, num_warps=12)
    explicit = replace(cfg, interval_strategy="paper")
    r = simulate(w, explicit)
    assert r == golden_simulate(w, cfg), design
    assert r == simulate(w, cfg)


def test_strategies_retire_identical_instruction_stream():
    """Interval formation only reshapes prefetch boundaries: every strategy
    retires the same dynamic instructions with the same occupancy."""
    for name in ("srad", "sgemm"):
        w = WORKLOADS[name]
        base = design_config("LTRF", table2_config=7, num_warps=8,
                             interval_cap=SWEEP_CAP)
        ref = simulate(w, base)
        for strat in ("capacity", "fixed:8"):
            r = simulate(w, replace(base, interval_strategy=strat))
            assert r.instructions == ref.instructions, (name, strat)
            assert r.resident_warps == ref.resident_warps, (name, strat)


def test_strategy_noop_on_uncached_designs():
    """BL/RFC/Ideal compile no intervals and SHRF is strand-bounded: the
    knob cannot change their results (they share one cached plan)."""
    w = WORKLOADS["btree"]
    for design in ("BL", "RFC", "Ideal", "SHRF"):
        cfg = design_config(design, table2_config=7, num_warps=8)
        ref = simulate(w, cfg)
        for strat in ("capacity", "fixed:8"):
            assert simulate(w, replace(cfg, interval_strategy=strat)) == ref, \
                (design, strat)


# ---------------------------------------------------------- determinism pins

# Exact (prefetch_ops, prefetch_stall_cycles) for Listing 1 at Table-2
# config #7, 16 warps.  LTRF_plus fetches only live subsets — empty at every
# Listing-1 interval header, so it never blocks on a prefetch here.
LISTING1_STALLS = {
    "BL":        (0, 0),
    "RFC":       (0, 0),
    "SHRF":      (98, 2484),
    "LTRF":      (26, 676),
    "LTRF_conf": (26, 676),
    "LTRF_plus": (0, 0),
    "Ideal":     (0, 0),
}


@pytest.mark.parametrize("design", DESIGNS)
def test_listing1_prefetch_stalls_pinned(design):
    w = listing1_workload()
    cfg = design_config(design, table2_config=7, num_warps=16)
    r = simulate(w, cfg)
    assert (r.prefetch_ops, r.prefetch_stall_cycles) == \
        LISTING1_STALLS[design], design
    # the golden engine counts the new counter identically
    assert golden_simulate(w, cfg) == r


def test_stall_cycles_consistent_with_prefetch_activity():
    w = WORKLOADS["srad"]
    r = simulate(w, design_config("LTRF", table2_config=7, num_warps=16))
    assert r.prefetch_ops > 0
    # every prefetch blocks for at least its own latency's worth of cycles
    assert r.prefetch_stall_cycles >= r.prefetch_cycles > 0
    none = simulate(w, design_config("BL", table2_config=7, num_warps=16))
    assert none.prefetch_stall_cycles == 0


# -------------------------------------------------- the acceptance verdicts

def _strategy_pair(name: str, design: str = VERDICT_DESIGN):
    w = WORKLOADS[name]
    paper = simulate(w, design_config(design, table2_config=7,
                                      interval_cap=SWEEP_CAP))
    cap = simulate(w, design_config(design, table2_config=7,
                                    interval_cap=SWEEP_CAP,
                                    interval_strategy="capacity"))
    return paper, cap


@pytest.mark.parametrize("name", sorted(_sensitive_names()))
def test_capacity_never_worse_per_workload(name):
    """Per high-register-pressure workload: the capacity strategy never
    loses IPC vs the paper strategy on the full compile pipeline."""
    paper, cap = _strategy_pair(name)
    assert cap.ipc >= paper.ipc, name


@pytest.mark.slow
def test_capacity_strictly_fewer_stall_cycles_in_aggregate():
    """ISSUE-5 acceptance: strictly fewer aggregate prefetch-stall cycles
    across the high-register-pressure workloads — the verdict recorded in
    BENCH_sim.json's ``interval_sweep`` section."""
    tot_paper = tot_cap = 0
    for name in _sensitive_names():
        paper, cap = _strategy_pair(name)
        tot_paper += paper.prefetch_stall_cycles
        tot_cap += cap.prefetch_stall_cycles
    assert tot_cap < tot_paper


def test_capacity_working_sets_respect_rfc_capacity():
    """Under ``capacity`` every compiled interval's estimated working set
    fits the RFC's entries-per-warp, so a prefetch round can never
    overflow the cache."""
    for name in _sensitive_names():
        w = WORKLOADS[name]
        cfg = design_config("LTRF", table2_config=7, num_warps=8,
                            interval_cap=SWEEP_CAP,
                            interval_strategy="capacity")
        s = Simulator(cfg, w)
        bound = cfg.rfc_entries_per_warp
        assert all(len(op.bitvector) <= bound
                   for op in s.pf_ops.values()), name


@pytest.mark.slow
def test_interval_sweep_section_verdicts():
    """The bench emitter computes the same verdicts this suite pins (on a
    reduced workload slice so CI stays fast)."""
    import pathlib
    import sys
    sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent))
    from benchmarks.bench_sim import measure_interval_sweep
    import benchmarks.bench_sim as bs
    from benchmarks.sweep_subset import interval_sweep_jobs

    orig = bs.interval_sweep_jobs
    bs.interval_sweep_jobs = lambda **kw: interval_sweep_jobs(
        workloads=("srad", "sgemm"), designs=("BL", "LTRF", VERDICT_DESIGN))
    try:
        rep = bs.measure_interval_sweep(processes=1)
    finally:
        bs.interval_sweep_jobs = orig
    assert rep["capacity_strictly_fewer_stall_cycles"] is True
    assert rep["capacity_no_ipc_regression_all_workloads"] is True
    assert rep["strategy_noop_on_uncached_designs"] is True
    assert rep["verdict_design"] == VERDICT_DESIGN
    assert {r["strategy"] for r in rep["results"]} == \
        {"paper", "capacity", "fixed:8"}


# ----------------------------------------------------------------- GPU scale

def test_gpu_aggregates_prefetch_stall_cycles():
    w = WORKLOADS["srad"]
    cfg = design_config("LTRF", table2_config=7, num_warps=16, num_sms=2)
    g = simulate_gpu(w, cfg)
    assert g.prefetch_stall_cycles == \
        sum(r.prefetch_stall_cycles for r in g.per_sm)
    assert g.prefetch_stall_cycles > 0


def test_gpu_num_sms1_passes_strategy_through():
    w = WORKLOADS["sgemm"]
    cfg = design_config("LTRF", table2_config=7, num_warps=16,
                        interval_cap=SWEEP_CAP, interval_strategy="capacity")
    g = simulate_gpu(w, cfg)
    r = simulate(w, cfg)
    assert g.per_sm == (r,)
    assert g.prefetch_stall_cycles == r.prefetch_stall_cycles
