"""The compiler pass pipeline (ISSUE 5 tentpole).

Covers the pipeline machinery (`CompileContext`/`PassManager`/pass
registry), the pluggable interval-formation strategies, the cache-key
normalization in `compile_for_sim`, and the per-pass stats that travel on
`CompiledPlan`.  Bit-identity of the refactor itself is pinned where it
matters: the pipeline's per-design artifacts must equal what the frozen
golden engine compiles on its own.
"""
import pytest

from repro.core.intervals import form_fixed_intervals, form_register_intervals
from repro.core.ir import parse_asm
from repro.core.pipeline import (
    INTERVAL_STRATEGIES, CompileContext, Pass, PassManager, capacity_cap,
    effective_strategy, frontend_passes, parse_interval_strategy, run_compile,
    sim_passes,
)
from repro.core.plan_cache import compile_for_sim
from repro.sim import SimConfig, Simulator, design_config
from repro.workloads import WORKLOADS


# ----------------------------------------------------------------- machinery

def test_pass_manager_runs_in_order_and_records_stats():
    prog = parse_asm("mov r0, 1\nadd r1, r0, r0\nexit", name="t")
    order = []

    def mk(name, extra):
        def run(ctx):
            order.append(name)
            return {"extra": extra}
        return Pass(name, run)

    ctx = CompileContext(prog=prog)
    PassManager([mk("a", 1), mk("b", 2)]).run(ctx)
    assert order == ["a", "b"]
    assert list(ctx.stats) == ["a", "b"]
    assert ctx.stats["a"]["extra"] == 1
    assert all("time_ms" in s for s in ctx.stats.values())


def test_pass_applies_gate_skips():
    prog = parse_asm("exit", name="t")
    ran = []
    p = Pass("never", lambda ctx: ran.append(1), applies=lambda ctx: False)
    ctx = PassManager([p]).run(CompileContext(prog=prog))
    assert not ran and "never" not in ctx.stats


def test_sim_passes_order_matches_the_staged_pipeline():
    names = [p.name for p in sim_passes()]
    assert names == ["intervals", "liveness", "icg", "renumber",
                     "prefetch", "emit"]
    assert [p.name for p in frontend_passes()] == ["live-intervals"]


def test_compiled_plan_carries_pass_stats():
    w = WORKLOADS["srad"]
    plan = compile_for_sim(w.program, "LTRF_conf", 16, 16)
    assert list(plan.pass_stats) == ["intervals", "icg", "renumber",
                                     "prefetch", "emit"]
    assert plan.pass_stats["intervals"]["strategy"] == "paper"
    assert plan.pass_stats["prefetch"]["prefetch_ops"] == len(plan.pf_ops)
    # uncached designs skip straight to emission
    bl = compile_for_sim(w.program, "BL", 16, 16)
    assert list(bl.pass_stats) == ["emit"]
    # the renumber stages only run for LTRF_conf with icg numbering, and
    # block liveness only where it is consumed (LTRF_plus live fetch sets)
    ltrf = compile_for_sim(w.program, "LTRF", 16, 16)
    assert "icg" not in ltrf.pass_stats and "renumber" not in ltrf.pass_stats
    assert "liveness" not in ltrf.pass_stats
    plus = compile_for_sim(w.program, "LTRF_plus", 16, 16)
    assert list(plus.pass_stats) == ["intervals", "liveness", "prefetch",
                                     "emit"]
    assert plus.live_sets  # the liveness artifact feeds the emitted plan


def test_pipeline_artifacts_match_golden_compile():
    """The refactor cannot change compile results: per design, the emitted
    plan equals what the frozen golden engine compiles for itself."""
    from repro.sim.golden import GoldenSimulator

    for name in ("srad", "btree"):
        w = WORKLOADS[name]
        for design in ("SHRF", "LTRF", "LTRF_conf", "LTRF_plus"):
            cfg = design_config(design, table2_config=7, num_warps=8)
            g = GoldenSimulator(cfg, w)
            plan = compile_for_sim(w.program, design, cfg.interval_cap,
                                   cfg.num_banks)
            assert plan.prog.render() == g.prog.render(), (name, design)
            assert plan.block_interval == g.block_interval, (name, design)
            assert plan.pf_ops == g.pf_ops, (name, design)


# ---------------------------------------------------------------- strategies

def test_parse_interval_strategy():
    assert parse_interval_strategy("paper") == ("paper", 0)
    assert parse_interval_strategy("capacity") == ("capacity", 0)
    assert parse_interval_strategy("fixed:8") == ("fixed", 8)
    for bad in ("strands", "fixed", "fixed:0", "fixed:-1", "fixed:x", ""):
        with pytest.raises(ValueError):
            parse_interval_strategy(bad)
    assert INTERVAL_STRATEGIES == ("paper", "capacity", "fixed")


def test_capacity_cap_clamps():
    assert capacity_cap(48, 16) == 16
    assert capacity_cap(8, 16) == 8
    assert capacity_cap(48, 0) == 48  # 0 = unbounded
    assert capacity_cap(48, -1) == 48


def test_effective_strategy_normalization():
    # no-op combinations all collapse onto the paper key
    assert effective_strategy("BL", "fixed:8", 16, 0) == ("paper", 0)
    assert effective_strategy("SHRF", "capacity", 48, 16) == ("paper", 0)
    assert effective_strategy("LTRF", "capacity", 16, 16) == ("paper", 0)
    # live combinations keep their identity (+ the effective bound)
    assert effective_strategy("LTRF", "capacity", 48, 16) == ("capacity", 16)
    assert effective_strategy("LTRF_conf", "fixed:8", 16, 0) == ("fixed", 8)


def test_noop_strategies_share_one_cached_plan():
    w = WORKLOADS["srad"]
    a = compile_for_sim(w.program, "BL", 16, 16, interval_strategy="paper")
    b = compile_for_sim(w.program, "BL", 16, 16, interval_strategy="fixed:8")
    assert a is b
    # capacity that does not clamp degenerates to paper
    c = compile_for_sim(w.program, "LTRF", 16, 16)
    d = compile_for_sim(w.program, "LTRF", 16, 16,
                        interval_strategy="capacity", rfc_per_warp=16)
    assert c is d


def test_capacity_strategy_bounds_working_sets():
    w = WORKLOADS["srad"]
    plan = compile_for_sim(w.program, "LTRF", 48, 16,
                           interval_strategy="capacity", rfc_per_warp=8)
    assert plan.pf_ops  # intervals exist
    assert max(len(op.bitvector) for op in plan.pf_ops.values()) <= 8
    assert plan.pass_stats["intervals"]["cap"] == 8
    # the paper strategy at the oversized cap does exceed the bound
    paper = compile_for_sim(w.program, "LTRF", 48, 16)
    assert max(len(op.bitvector) for op in paper.pf_ops.values()) > 8


def test_fixed_intervals_shape():
    w = WORKLOADS["kmeans"]
    an = form_fixed_intervals(w.program, 8)
    an.validate()
    # every interval is exactly one block of at most 8 instructions
    for iv in an.intervals:
        assert len(iv.blocks) == 1 and iv.header == iv.blocks[0]
        assert len(an.prog.blocks[iv.header].instrs) <= 8
    assert len(an.intervals) == len(an.prog.order)
    assert an.prog.num_instrs() == w.program.num_instrs()
    with pytest.raises(ValueError):
        form_fixed_intervals(w.program, 0)


def test_fixed_strategy_compiles_and_differs_from_paper():
    w = WORKLOADS["srad"]
    fixed = compile_for_sim(w.program, "LTRF", 16, 16,
                            interval_strategy="fixed:4")
    paper = compile_for_sim(w.program, "LTRF", 16, 16)
    assert len(fixed.pf_ops) > len(paper.pf_ops)
    assert fixed.pass_stats["intervals"]["strategy"] == "fixed:4"


def test_register_interval_strategy_extension_point():
    """A registered strategy is selectable end to end — straight from
    `SimConfig.interval_strategy` through the engine and the plan cache."""
    from repro.core import pipeline as pl

    with pytest.raises(ValueError):
        parse_interval_strategy("halfcap")  # not registered yet

    @pl.register_interval_strategy("halfcap")
    def _half(ctx, arg):
        return form_register_intervals(ctx.prog,
                                       max(1, ctx.interval_cap // (arg or 2)))

    try:
        assert parse_interval_strategy("halfcap") == ("halfcap", 0)
        assert parse_interval_strategy("halfcap:4") == ("halfcap", 4)
        with pytest.raises(ValueError):
            parse_interval_strategy("halfcap:zero")
        w = WORKLOADS["kmeans"]
        cfg = design_config("LTRF", table2_config=7, num_warps=4,
                            interval_strategy="halfcap")
        s = Simulator(cfg, w)
        plan = compile_for_sim(w.program, "LTRF", cfg.interval_cap,
                               cfg.num_banks, interval_strategy="halfcap")
        assert plan.pass_stats["intervals"]["cap"] == cfg.interval_cap // 2
        r = Simulator(cfg, w).run()
        assert r.instructions > 0
        assert s.pf_ops is plan.pf_ops  # one cached plan, keyed by the name
    finally:
        pl._STRATEGIES.pop("halfcap", None)


# --------------------------------------------------------------- sim plumbing

def test_simulator_rejects_unknown_strategy():
    w = WORKLOADS["bfs"]
    with pytest.raises(ValueError):
        Simulator(SimConfig(interval_strategy="best-effort", num_warps=4), w)
    with pytest.raises(ValueError):
        Simulator(SimConfig(interval_strategy="fixed:0", num_warps=4), w)


def test_rfc_entries_per_warp_property():
    cfg = SimConfig()
    assert cfg.rfc_entries == 128
    assert cfg.rfc_entries_per_warp == 16  # 128 entries / 8 active slots
    assert SimConfig(active_slots=4).rfc_entries_per_warp == 32


def test_frontend_pipeline_matches_core_liveness():
    from repro.core.liveness import linear_live_intervals

    prog = WORKLOADS["kmeans"].program
    ctx = CompileContext(prog=prog, design="frontend")
    PassManager(frontend_passes()).run(ctx)
    assert ctx.artifacts["linear_live_intervals"] == \
        linear_live_intervals(prog)
    assert "live-intervals" in ctx.stats


def test_run_compile_equals_cached_compile_content():
    w = WORKLOADS["btree"]
    direct = run_compile(w.program, "LTRF", 16, 16)
    cached = compile_for_sim(w.program, "LTRF", 16, 16)
    assert direct.prog.render() == cached.prog.render()
    assert direct.block_interval == cached.block_interval
    assert direct.pf_ops == cached.pf_ops
