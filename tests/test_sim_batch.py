"""Tests for the vectorized batch simulation engine (`repro.sim.batch`).

Three layers:

* cheap structural tests (the `batch_supported` gate, scalar fallback,
  chunk grouping, sweep-service batch-mode policy) that never touch jax;
* bit-identity pins on the jitted path, including the FMA-contraction
  regression case that originally diverged;
* slow-lane A/B matrices (heterogeneous batches, the sweep service's
  batch prefill path) that run the full lockstep loop.

The bit-identity contract these enforce: for every `batch_supported`
config, `run_batch` produces `SimResult`s equal — every counter AND the
full `cycle_breakdown` — to the event-heap engine, which is itself pinned
bit-identical to the frozen `golden.py` oracle.
"""
from __future__ import annotations

from dataclasses import replace

import pytest

from repro.sim import (
    DESIGNS, SimBudgetExceeded, SimConfig, batch_supported, design_config,
    run_batch, simulate, simulate_batch, simulate_one,
)
from repro.workloads import WORKLOADS


# ------------------------------------------------------------ gate + fallback

def test_batch_supported_gate():
    """Exactly the golden-pinned domain: two-level scheduler, no bank
    arbitration, untraced, single SM.  Compile-side knobs (design,
    interval strategy, renumbering) never disqualify a config."""
    base = design_config("LTRF", table2_config=7, num_warps=8)
    assert batch_supported(base)
    for d in DESIGNS:
        assert batch_supported(replace(base, design=d)), d
    assert batch_supported(replace(base, interval_strategy="fixed:4"))
    assert batch_supported(replace(base, renumber="identity"))
    assert not batch_supported(replace(base, scheduler="gto"))
    assert not batch_supported(replace(base, scheduler="lrr"))
    assert not batch_supported(replace(base, bank_model="arbitrated"))
    assert not batch_supported(replace(base, trace=True))
    assert not batch_supported(replace(base, num_sms=2))


def test_run_batch_falls_back_to_scalar_engine():
    """Unsupported configs ride the event-heap engine job by job (same
    results), or raise when the caller forbids the fallback."""
    w = WORKLOADS["kmeans"]
    cfg = replace(design_config("LTRF", table2_config=7, num_warps=4),
                  scheduler="gto")
    assert not batch_supported(cfg)
    assert run_batch([(w, cfg)]) == [simulate(w, cfg)]
    with pytest.raises(ValueError):
        run_batch([(w, cfg)], fallback=False)


def test_chunk_lanes_groups_by_shape():
    """Chunking keeps cheap lanes out of expensive shapes: a BL lane (all
    resident warps active) must not share a chunk with an LTRF lane (8
    active slots), and every lane survives chunking exactly once."""
    from repro.sim import batch as B

    w = WORKLOADS["kmeans"]
    lanes = []
    for d in ("BL", "LTRF", "LTRF_plus", "Ideal"):
        cfg = design_config(d, table2_config=7, num_warps=16)
        lanes.append(B._Lane(w, cfg, B._encode_plan(w, cfg),
                             B._occupancy(w, cfg)))
    chunks = list(B._chunk_lanes(lanes, list(range(len(lanes)))))
    seen = sorted(i for _, idxs in chunks for i in idxs)
    assert seen == list(range(len(lanes)))
    for chunk, idxs in chunks:
        assert len(chunk) == len(idxs) <= B._MAX_LANES
        acaps = {B._bucket(B._acap(ln), 2) for ln in chunk}
        assert len(acaps) == 1  # one active-width bucket per chunk
    by_design = {ln.cfg.design: ci for ci, (chunk, _) in enumerate(chunks)
                 for ln in chunk}
    assert by_design["BL"] != by_design["LTRF"]
    assert by_design["LTRF"] == by_design["LTRF_plus"]


# --------------------------------------------------------- jitted-path pins

def test_fma_contraction_regression_pin():
    """BL/kmeans at Table-2 #7, 16 warps: the exact case where XLA's CPU
    FMA contraction silently changed a token-bucket float compare until the
    engine's mul-add sites were made contraction-proof.  Full-structure
    equality (breakdown included) with the event engine."""
    w = WORKLOADS["kmeans"]
    cfg = design_config("BL", table2_config=7, num_warps=16)
    assert simulate_one(w, cfg) == simulate(w, cfg)


def test_budget_outcomes_returned_not_raised():
    """`run_batch` reports watchdog trips as `SimBudgetExceeded` instances
    in the outcome list (the sweep service records them as job outcomes);
    `simulate_batch` re-raises to match the scalar `simulate` contract."""
    w = WORKLOADS["kmeans"]
    cfg = design_config("BL", table2_config=7, num_warps=16)
    ref = simulate(w, cfg)
    tight = replace(cfg, max_cycles=max(1, ref.cycles // 2))
    ok, tripped = run_batch([(w, cfg), (w, tight)])
    assert ok == ref
    assert isinstance(tripped, SimBudgetExceeded)
    with pytest.raises(SimBudgetExceeded) as event_exc:
        simulate(w, tight)
    assert tripped.args == event_exc.value.args
    with pytest.raises(SimBudgetExceeded):
        simulate_batch([(w, cfg), (w, tight)])


@pytest.mark.slow
def test_heterogeneous_batch_bit_identical():
    """One `run_batch` call over a mixed pile — every design, two
    workloads, differing latency multipliers — matches per-job `simulate`
    bit-for-bit.  This is the acceptance shape of the tracked sweep."""
    jobs = []
    for d in DESIGNS:
        for name in ("srad", "btree"):
            jobs.append((WORKLOADS[name],
                         design_config(d, table2_config=7, num_warps=8)))
    jobs.append((WORKLOADS["srad"],
                 design_config("LTRF", mrf_latency_mult=2.8, rf_size_kb=256,
                               num_warps=8)))
    for (w, cfg), got in zip(jobs, run_batch(jobs, fallback=False)):
        assert got == simulate(w, cfg), (cfg.design, w.name)


# --------------------------------------- BATCH_REV 2: stats + time skipping

def test_run_stats_compile_run_split():
    """`RUN_STATS` attributes XLA compile wall and launch wall separately —
    the `compile_s` split the perf ledger reports — and counts fused-loop
    ticks.  A cached executable legitimately reports zero compile wall, but
    never zero launches or ticks."""
    from repro.sim import batch as B

    w = WORKLOADS["kmeans"]
    cfg = design_config("LTRF", table2_config=7, num_warps=4)
    stats = B.reset_run_stats()
    assert stats == {"compile_s": 0.0, "run_s": 0.0,
                     "compiles": 0, "launches": 0, "ticks": 0}
    res, = B.run_batch([(w, cfg)], fallback=False)
    assert stats["launches"] == 1
    assert stats["run_s"] > 0.0
    assert stats["ticks"] > 0
    # in-process executable cache hits skip compilation entirely; either
    # way the wall and the counter must agree
    assert (stats["compiles"] == 0) == (stats["compile_s"] == 0.0)
    assert res == simulate(w, cfg)


def test_time_skip_finishes_under_cycle_count():
    """Event-horizon skipping: on a stall-heavy LTRF config (2 warps, the
    Table-2 #7 latency point) whole stretches of cycles pass with no lane
    able to issue, so the fused loop must converge in strictly fewer ticks
    than simulated cycles — while staying bit-identical to the event
    engine, breakdown included."""
    from repro.sim import batch as B

    w = WORKLOADS["kmeans"]
    cfg = design_config("LTRF", table2_config=7, num_warps=2)
    stats = B.reset_run_stats()
    res, = B.run_batch([(w, cfg)], fallback=False)
    assert res == simulate(w, cfg)
    assert 0 < stats["ticks"] < res.cycles, (stats["ticks"], res.cycles)


def test_mixed_supported_and_fallback_positions():
    """A single `run_batch` call mixing batch-supported configs with every
    out-of-domain axis (gto/lrr schedulers, arbitrated banks): fallback
    jobs ride the event heap in place, positions preserved, everything
    bit-identical per job."""
    w = WORKLOADS["kmeans"]
    base = design_config("LTRF", table2_config=7, num_warps=4)
    jobs = [
        (w, base),
        (w, replace(base, scheduler="gto")),
        (w, design_config("BL", table2_config=7, num_warps=4)),
        (w, replace(base, scheduler="lrr")),
        (w, replace(base, bank_model="arbitrated")),
    ]
    assert [batch_supported(c) for _, c in jobs] == \
        [True, False, True, False, False]
    for (wk, cfg), got in zip(jobs, run_batch(jobs)):
        assert got == simulate(wk, cfg), \
            (cfg.design, cfg.scheduler, cfg.bank_model)


def test_watchdog_parity_across_budgets():
    """Budget trips stay bit-identical across several watchdog budgets —
    including budgets that land inside a dead-time gap, where the dt-jump
    must not overshoot the recorded trip cycle."""
    w = WORKLOADS["kmeans"]
    cfg = design_config("LTRF", table2_config=7, num_warps=2)
    ref = simulate(w, cfg)
    for frac in (0.2, 0.5, 0.9):
        tight = replace(cfg, max_cycles=max(1, int(ref.cycles * frac)))
        got, = run_batch([(w, tight)])
        assert isinstance(got, SimBudgetExceeded), frac
        with pytest.raises(SimBudgetExceeded) as event_exc:
            simulate(w, tight)
        assert got.args == event_exc.value.args, frac


# ------------------------------------------------------ sweep-service path

def _runner(tmp_path, **kw):
    from repro.serving.sweep import SimRunner
    return SimRunner(processes=1, cache_dir=tmp_path / "cache", **kw)


def test_sweep_batch_mode_policy(tmp_path, monkeypatch):
    """Explicit flag beats env var beats auto; fault plans force it off
    (the chaos harness targets the per-job classic path)."""
    from repro.serving import faults

    r = _runner(tmp_path)
    monkeypatch.delenv("REPRO_SIM_BATCH", raising=False)
    assert r._batch_mode() == "auto"
    monkeypatch.setenv("REPRO_SIM_BATCH", "1")
    assert r._batch_mode() == "on"
    monkeypatch.setenv("REPRO_SIM_BATCH", "0")
    assert r._batch_mode() == "off"
    assert _runner(tmp_path, batch=True)._batch_mode() == "on"
    monkeypatch.setenv("REPRO_SIM_BATCH", "1")
    assert _runner(tmp_path, batch=False)._batch_mode() == "off"
    on = _runner(tmp_path, batch=True)
    monkeypatch.setattr(faults, "active_plan", lambda: faults.FaultPlan())
    assert on._batch_mode() == "off"


def test_auto_batch_threshold_platform_policy(monkeypatch):
    """'auto' mode's engage bar: low on a loaded non-CPU jax backend, the
    compile-amortizing CPU bar otherwise — and the probe itself must never
    import jax (a cache lookup should not pay a multi-second import)."""
    import sys

    from repro.serving import sweep as S

    monkeypatch.delitem(sys.modules, "jax", raising=False)
    assert S._auto_batch_threshold() == S._MIN_AUTO_BATCH_CPU
    assert "jax" not in sys.modules  # probe did not import it

    class _Dev:
        def __init__(self, platform):
            self.platform = platform

    class _FakeJax:
        def __init__(self, platform):
            self._d = _Dev(platform)

        def devices(self):
            return [self._d]

    monkeypatch.setitem(sys.modules, "jax", _FakeJax("gpu"))
    assert S._auto_batch_threshold() == S._MIN_AUTO_BATCH
    monkeypatch.setitem(sys.modules, "jax", _FakeJax("cpu"))
    assert S._auto_batch_threshold() == S._MIN_AUTO_BATCH_CPU


@pytest.mark.slow
def test_sweep_runner_batch_prefill(tmp_path):
    """`SimRunner(batch=True)` computes cache misses through the batch
    engine — same results as the classic path, `batched` stat accounted,
    report coherent, and everything lands in the disk cache."""
    cfgs = [design_config(d, table2_config=7, num_warps=4)
            for d in ("BL", "LTRF")]
    jobs = [(name, cfg) for name in ("kmeans", "bfs") for cfg in cfgs]

    batched = _runner(tmp_path / "b", batch=True)
    rep = batched.prefill(jobs)
    assert rep.ok and rep.computed == len(jobs)
    assert batched.stats["batched"] == len(jobs)
    assert batched.stats["computed"] == len(jobs)

    classic = _runner(tmp_path / "c", batch=False)
    classic.prefill(jobs)
    assert classic.stats["batched"] == 0
    for name, cfg in jobs:
        assert batched.sim(name, cfg) == classic.sim(name, cfg) \
            == simulate(WORKLOADS[name], cfg), (name, cfg.design)

    # a second prefill is pure cache: nothing recomputed, nothing batched
    rep2 = batched.prefill(jobs)
    assert rep2.cached == len(jobs) and rep2.computed == 0
    assert batched.stats["batched"] == len(jobs)
