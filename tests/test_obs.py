"""Unit tests for the observability layer (`repro.obs`) and its wiring:
cycle-attribution helpers, the Chrome-trace sink, the metrics registry,
deterministic sweep run ids, and the `benchmarks.profile` CLI.

The simulation-level invariants (breakdown sums to cycles on random
programs, tracer bit-identity, GPU aggregation) live in
``tests/test_sim_fuzz.py``; the Listing-1 attribution pins live in
``tests/test_sim_golden.py``.  Here: the pieces in isolation.
"""
from __future__ import annotations

import json

import pytest

from repro.obs import (
    CYCLE_CATEGORIES, SCHED_TID, STALL_CATEGORIES, SWEEP_METRICS,
    CycleAttributionError, MetricsRegistry, TraceSink, breakdown_fractions,
    check_breakdown, classify_stall, merge_breakdowns, new_breakdown,
)

# ------------------------------------------------------------- attribution


def test_categories_contract():
    assert CYCLE_CATEGORIES[0] == "issue"
    assert set(STALL_CATEGORIES) == set(CYCLE_CATEGORIES) - {"issue"}
    bd = new_breakdown()
    assert tuple(bd) == CYCLE_CATEGORIES and all(v == 0 for v in bd.values())


def test_classify_stall_precedence():
    # drain wins over everything; then struct, prefetch, mem, dep; the
    # no-signal fallthrough is scheduler_idle
    assert classify_stall(True, True, True, True, True) == "drain"
    assert classify_stall(False, True, True, True, True) == "bank_conflict"
    assert classify_stall(False, False, True, True, True) == "prefetch_stall"
    assert classify_stall(False, False, False, True, True) == "mem_stall"
    assert classify_stall(False, False, False, False, True) == "alu_dep"
    assert classify_stall(False, False, False, False, False) \
        == "scheduler_idle"


def test_check_breakdown_accepts_exact_sum():
    bd = new_breakdown()
    bd["issue"], bd["mem_stall"] = 7, 3
    check_breakdown(bd, 10, "BL", "wl")  # no raise


def test_check_breakdown_raises_on_mismatch_and_bad_categories():
    bd = new_breakdown()
    bd["issue"] = 9
    with pytest.raises(CycleAttributionError, match="unattributed: 1"):
        check_breakdown(bd, 10, "BL", "wl")
    with pytest.raises(CycleAttributionError, match="categories"):
        check_breakdown({"issue": 10}, 10, "BL", "wl")


def test_fractions_and_merge():
    a, b = new_breakdown(), new_breakdown()
    a["issue"], a["drain"] = 6, 2
    b["issue"], b["mem_stall"] = 2, 2
    merged = merge_breakdowns([a, b])
    assert merged["issue"] == 8 and sum(merged.values()) == 12
    frac = breakdown_fractions(merged)
    assert abs(sum(frac.values()) - 1.0) < 1e-12
    assert frac["issue"] == 8 / 12
    assert breakdown_fractions(new_breakdown()) == \
        {c: 0.0 for c in CYCLE_CATEGORIES}


# -------------------------------------------------------------- trace sink


def test_trace_sink_chrome_document():
    sink = TraceSink(sm=3)
    sink.span(0, "add", 10, 4, {"block": "B0"})
    sink.span(SCHED_TID, "mem_stall", 14, 6)
    sink.instant(1, "activate", 2)
    doc = sink.to_chrome()
    evs = doc["traceEvents"]
    # metadata names every track once, plus the process
    names = {(e["tid"], e["args"]["name"]) for e in evs
             if e["ph"] == "M" and e["name"] == "thread_name"}
    assert names == {(0, "warp 0"), (1, "warp 1"),
                     (SCHED_TID, "scheduler")}
    assert any(e["ph"] == "M" and e["name"] == "process_name"
               and e["args"]["name"] == "SM 3" for e in evs)
    # and the document round-trips through JSON
    again = json.loads(json.dumps(doc))
    assert again["displayTimeUnit"] == "ms"
    assert [e for e in again["traceEvents"] if e["ph"] == "X"] == \
        [e for e in evs if e["ph"] == "X"]


def test_trace_sink_zero_duration_spans_stay_visible():
    sink = TraceSink()
    sink.span(0, "bra", 5, 0)
    assert sink.events[0]["dur"] == 1  # Perfetto drops dur=0 spans


def test_trace_sink_write(tmp_path):
    sink = TraceSink()
    sink.instant(2, "swap_out", 9, {"until": 40})
    p = sink.write(tmp_path / "t.json")
    doc = json.loads(p.read_text())
    ev = [e for e in doc["traceEvents"] if e["ph"] == "i"]
    assert ev == [{"ph": "i", "pid": 0, "tid": 2, "name": "swap_out",
                   "ts": 9, "s": "t", "args": {"until": 40}}]


# ---------------------------------------------------------------- metrics


def test_counter_monotonic():
    reg = MetricsRegistry()
    c = reg.counter("jobs", "help text")
    c.inc()
    c.inc(4)
    assert c.value == 5
    with pytest.raises(ValueError):
        c.inc(-1)


def test_gauge_moves_both_ways():
    g = MetricsRegistry().gauge("pool")
    g.set(4)
    g.dec()
    g.inc(2)
    assert g.value == 5


def test_histogram_nearest_rank_percentiles():
    h = MetricsRegistry().histogram("lat")
    for v in range(1, 101):  # 1..100: pXX == XX under nearest-rank
        h.observe(float(v))
    s = h.summary()
    assert (s["count"], s["min"], s["max"]) == (100, 1.0, 100.0)
    assert (s["p50"], s["p95"], s["p99"]) == (50.0, 95.0, 99.0)
    assert s["sum"] == 5050.0
    one = MetricsRegistry().histogram("one")
    one.observe(7.0)
    assert one.summary()["p99"] == 7.0
    assert MetricsRegistry().histogram("empty").summary() == \
        {"count": 0, "sum": 0.0}


def test_histogram_nearest_rank_matches_naive_reference():
    """Property test over (q, n) grids: the rank must equal the smallest
    1-indexed rank r with r >= q*n — the nearest-rank definition spelled
    out naively.  The old int-scaling trick (-(-int(q*n*100) // 100))
    truncated before ceiling and silently under-ranked whenever q*n had a
    fractional part below 0.01, e.g. (q=0.5000001, n=20)."""
    from repro.obs.metrics import Histogram

    def naive_rank(q, n):
        r = 1
        while r < n and r < q * n:
            r += 1
        return r

    qs = (0.01, 0.05, 0.1, 0.25, 0.5, 0.5000001, 0.75, 0.9, 0.95,
          0.99, 0.999, 1.0)
    for n in (*range(1, 65), 100, 128, 999):
        samples = [float(i) for i in range(1, n + 1)]  # value == rank
        for q in qs:
            got = Histogram._nearest_rank(samples, q)
            assert got == float(naive_rank(q, n)), (q, n, got)
    # an exact case the int-scaling bug got wrong: ceil(10.000002) is 11,
    # but int(1000.0002) // 100 ceiled to 10
    assert Histogram._nearest_rank([float(i) for i in range(1, 21)],
                                   0.5000001) == 11.0


def test_registry_get_or_create_and_kind_clash():
    reg = MetricsRegistry()
    assert reg.counter("x") is reg.counter("x")
    with pytest.raises(TypeError, match="already registered"):
        reg.gauge("x")


def test_snapshot_and_prometheus_and_write(tmp_path):
    reg = MetricsRegistry()
    reg.counter("sweep_jobs_total", "jobs").inc(3)
    reg.gauge("inflight").set(2)
    reg.histogram("sweep_job_latency_s").observe(0.25)
    snap = reg.snapshot(run_id="abc123")
    assert snap["run_id"] == "abc123"
    assert snap["sweep_jobs_total"] == 3
    assert snap["sweep_job_latency_s"]["count"] == 1
    prom = reg.to_prometheus(host="ci")
    assert '# TYPE sweep_jobs_total counter' in prom
    assert 'sweep_jobs_total{host="ci"} 3' in prom
    assert '# TYPE sweep_job_latency_s summary' in prom
    assert 'sweep_job_latency_s{host="ci",quantile="0.5"} 0.25' in prom
    assert 'sweep_job_latency_s_count{host="ci"} 1' in prom
    p = reg.write_snapshot(tmp_path / "m.json", run_id="abc123")
    assert json.loads(p.read_text())["sweep_jobs_total"] == 3


# ----------------------------------------------------- sweep run_id + wiring


def _jobs(n=3):
    from repro.sim import design_config
    return [("srad", design_config(d, num_warps=4))
            for d in ("BL", "LTRF", "LTRF_conf")[:n]]


def test_sweep_run_id_deterministic_and_order_insensitive():
    from repro.serving.sweep import sweep_run_id

    jobs = _jobs()
    rid = sweep_run_id(jobs)
    assert rid and len(rid) == 12
    assert rid == sweep_run_id(list(reversed(jobs)))  # canonicalized
    assert rid != sweep_run_id(_jobs(2))              # job set is identity


def test_runner_metrics_and_run_id(tmp_path):
    from benchmarks.orchestrator import SimRunner
    from repro.serving.sweep import sweep_run_id

    jobs = _jobs()
    runner = SimRunner(processes=1, disk_cache=False)
    rep = runner.prefill(jobs)
    assert rep.run_id == runner.last_run_id == sweep_run_id(jobs)
    snap = runner.metrics_snapshot()
    assert snap["run_id"] == rep.run_id
    assert snap["sweep_jobs_total"] == len(jobs)
    assert snap["sweep_jobs_computed"] == len(jobs)
    assert snap["sweep_job_latency_s"]["count"] == len(jobs)
    # second prefill: all memo hits, counters accumulate
    runner.prefill(jobs)
    snap2 = runner.metrics_snapshot()
    assert snap2["sweep_jobs_total"] == 2 * len(jobs)
    assert snap2["sweep_cache_hits_total"] >= len(jobs)
    for name in SWEEP_METRICS:
        assert name in snap2, name


def test_failure_records_carry_run_id(tmp_path):
    """A failed job's FailureRecord is stamped with the sweep's run_id, so
    degraded-sweep artifacts are joinable with metrics snapshots."""
    from repro.serving.sweep import FailureRecord

    fr = FailureRecord(job="srad/BL", workload="srad", design="BL",
                       kind="crash", detail="x", run_id="deadbeef0123")
    assert fr.to_dict()["run_id"] == "deadbeef0123"


# ------------------------------------------------------------- profile CLI


def test_profile_cli_json_and_trace(tmp_path, capsys):
    from benchmarks.profile import main

    out_trace = tmp_path / "trace.json"
    rc = main(["--workload", "srad", "--design", "LTRF", "--num-warps", "4",
               "--trace-out", str(out_trace), "--json"])
    assert rc == 0
    out = json.loads(capsys.readouterr().out)
    assert out["cycles"] == sum(out["cycle_breakdown"].values())
    assert tuple(out["cycle_breakdown"]) == CYCLE_CATEGORIES
    assert out["trace_events"] > 0
    doc = json.loads(out_trace.read_text())
    assert doc["traceEvents"]


def test_profile_cli_breakdown_table(capsys):
    from benchmarks.profile import main

    rc = main(["--workload", "kmeans", "--design", "BL", "--num-warps", "4",
               "--breakdown"])
    assert rc == 0
    text = capsys.readouterr().out
    for cat in CYCLE_CATEGORIES:
        assert cat in text
    assert "cycles" in text and "ipc=" in text
