"""Multi-SM GPU model + warp-scheduler policy tests (`repro.sim.gpu`).

The bit-identity of ``num_sms=1`` + ``two_level`` against the single-SM
engine/golden pair lives in tests/test_sim_golden.py; here: the CTA
dispatcher, the shared memory-partition model, GpuResult aggregation,
scheduler-policy behaviour, and the orchestrator's GPU path.
"""
import pytest

from repro.sim import SCHEDULERS, SimConfig, design_config, simulate, simulate_gpu
from repro.sim.gpu import (
    SM_SEED_STRIDE, dispatch_ctas, gpu_jobs, per_sm_configs,
)
from repro.workloads import WORKLOADS

W = WORKLOADS["srad"]
WMEM = WORKLOADS["bfs"]  # memory-bound, low L1 hit rate


# ------------------------------------------------------------- dispatcher

def test_dispatch_round_robin_balance():
    assert dispatch_ctas(64, 4) == [16, 16, 16, 16]
    assert dispatch_ctas(10, 4) == [4, 4, 2, 0]
    assert dispatch_ctas(3, 2, warps_per_cta=4) == [3, 0]
    assert dispatch_ctas(0, 3) == [0, 0, 0]


def test_dispatch_preserves_total_warps():
    for n, sms, cta in ((64, 4, 4), (13, 3, 2), (7, 8, 4), (100, 6, 8)):
        assert sum(dispatch_ctas(n, sms, cta)) == n


def test_dispatch_rejects_bad_args():
    with pytest.raises(ValueError):
        dispatch_ctas(8, 0)
    with pytest.raises(ValueError):
        dispatch_ctas(8, 2, warps_per_cta=0)


# --------------------------------------------------------- per-SM configs

def test_per_sm_configs_single_sm_is_identity():
    cfg = design_config("LTRF", table2_config=7, num_warps=16)
    assert per_sm_configs(cfg) == [cfg]


def test_per_sm_configs_distinct_seeds_and_shares():
    cfg = design_config("LTRF", num_warps=24, num_sms=3)
    sub = per_sm_configs(cfg)
    assert [c.num_warps for c in sub] == [8, 8, 8]
    assert [c.seed for c in sub] == [cfg.seed + SM_SEED_STRIDE * i
                                     for i in range(3)]
    assert all(c.num_sms == 1 and c.mem_partitions == 0 for c in sub)


def test_per_sm_configs_idle_sms_dropped():
    cfg = design_config("BL", num_warps=4, num_sms=4)  # one CTA of 4 warps
    sub = per_sm_configs(cfg)
    assert len(sub) == 1 and sub[0].num_warps == 4


def test_shared_dram_partitions_scale_interval():
    cfg = design_config("BL", num_warps=32, num_sms=4, mem_partitions=2)
    sub = per_sm_configs(cfg)
    # 4 SMs sharing 2 partitions: each sees half its uncontended bandwidth
    assert all(c.dram_interval == cfg.dram_interval * 2 for c in sub)
    fair = per_sm_configs(design_config("BL", num_warps=32, num_sms=4))
    assert all(c.dram_interval == cfg.dram_interval for c in fair)


def test_dram_contention_hurts_memory_bound_ipc():
    fair = design_config("BL", table2_config=7, num_warps=64, num_sms=4)
    contended = design_config("BL", table2_config=7, num_warps=64, num_sms=4,
                              mem_partitions=1)
    assert simulate_gpu(WMEM, contended).ipc < simulate_gpu(WMEM, fair).ipc


# ------------------------------------------------------------ aggregation

def test_gpu_result_aggregates_counters():
    cfg = design_config("LTRF", table2_config=7, num_warps=32, num_sms=4)
    g = simulate_gpu(W, cfg)
    assert len(g.per_sm) == 4
    assert g.instructions == sum(r.instructions for r in g.per_sm)
    assert g.cycles == max(r.cycles for r in g.per_sm)
    for f in ("mrf_accesses", "rfc_accesses", "rfc_hits", "prefetch_ops",
              "writeback_regs", "activations", "resident_warps"):
        assert getattr(g, f) == sum(getattr(r, f) for r in g.per_sm), f
    assert g.num_sms == 4 and g.scheduler == "two_level"
    assert g.sm_imbalance >= 1.0


def test_gpu_scales_throughput_over_sms():
    one = design_config("LTRF", table2_config=7, num_warps=16, num_sms=1)
    four = design_config("LTRF", table2_config=7, num_warps=64, num_sms=4)
    # 4 SMs x 16 warps retire ~4x the instructions in about the same time
    assert simulate_gpu(W, four).ipc > 2.5 * simulate_gpu(W, one).ipc


def test_gpu_simulation_deterministic():
    cfg = design_config("LTRF_conf", table2_config=6, num_warps=24,
                        num_sms=3, scheduler="gto")
    assert simulate_gpu(W, cfg) == simulate_gpu(W, cfg)


# ------------------------------------------------------------- schedulers

def test_scheduler_policies_same_dynamic_work():
    """Branch outcomes depend only on (wid, visit, seed), so every policy
    retires the identical dynamic instruction stream."""
    counts = set()
    for sched in SCHEDULERS:
        cfg = design_config("LTRF", table2_config=7, num_warps=16,
                            scheduler=sched)
        counts.add(simulate(W, cfg).instructions)
    assert len(counts) == 1


def test_scheduler_sensitivity_on_cached_design():
    """The policies must actually schedule differently: cycle counts differ
    and only two_level pays deactivation write-backs."""
    res = {s: simulate(W, design_config("LTRF", table2_config=7,
                                        num_warps=16, scheduler=s))
           for s in SCHEDULERS}
    assert res["two_level"].writeback_regs > 0
    assert res["gto"].writeback_regs == 0
    assert res["lrr"].writeback_regs == 0
    assert len({r.cycles for r in res.values()}) >= 2


def test_two_level_equals_lrr_on_uncached_designs():
    """Without a register cache there is no active-slot restriction, so the
    paper scheduler degenerates to loose round-robin."""
    for design in ("BL", "RFC", "Ideal"):
        a = simulate(W, design_config(design, table2_config=7, num_warps=16,
                                      scheduler="two_level"))
        b = simulate(W, design_config(design, table2_config=7, num_warps=16,
                                      scheduler="lrr"))
        assert (a.cycles, a.instructions, a.mrf_accesses) == \
               (b.cycles, b.instructions, b.mrf_accesses), design


def test_gto_differs_from_round_robin():
    a = simulate(W, design_config("BL", table2_config=7, num_warps=16,
                                  scheduler="gto"))
    b = simulate(W, design_config("BL", table2_config=7, num_warps=16,
                                  scheduler="lrr"))
    assert a.instructions == b.instructions
    assert a.cycles != b.cycles


def test_engine_rejects_gpu_scale_configs():
    with pytest.raises(ValueError, match="simulate_gpu"):
        simulate(W, design_config("BL", num_sms=2))
    with pytest.raises(ValueError, match="scheduler"):
        simulate(W, SimConfig(design="BL", scheduler="greedy"))


# ----------------------------------------------------------- orchestrator

def test_orchestrator_gpu_path(tmp_path):
    from benchmarks.orchestrator import SimRunner
    cfg = design_config("LTRF", table2_config=7, num_warps=32, num_sms=4,
                        scheduler="lrr")
    runner = SimRunner(processes=1, cache_dir=tmp_path)
    runner.prefill_gpu([("srad", cfg)])
    g = runner.sim_gpu("srad", cfg)
    assert g == simulate_gpu(W, cfg)
    # every per-SM job was computed exactly once, then replayed from memo
    assert runner.stats["computed"] == len(per_sm_configs(cfg))
    before = dict(runner.stats)
    assert runner.sim_gpu("srad", cfg) == g
    assert runner.stats["computed"] == before["computed"]
    # a fresh runner replays the per-SM results from the disk cache
    replay = SimRunner(processes=1, cache_dir=tmp_path)
    assert replay.sim_gpu("srad", cfg) == g
    assert replay.stats["computed"] == 0 and replay.stats["disk_hits"] > 0


def test_gpu_jobs_expand_per_sm():
    cfg = design_config("BL", num_warps=32, num_sms=4)
    jobs = gpu_jobs("srad", cfg)
    assert len(jobs) == 4
    assert all(name == "srad" and c.num_sms == 1 for name, c in jobs)
