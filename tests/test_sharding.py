"""Unit tests for logical sharding rules, shape-aware shardings and the
dry-run's HLO collective parser."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import SHAPES, cell_is_runnable, get_arch, input_specs
from repro.distributed.sharding import (
    constrain, default_rules, shardings_for, use_rules,
)
from repro.launch.hlo_stats import _shape_bytes, collective_stats
from repro.launch.mesh import make_host_mesh


def rules():
    return default_rules(make_host_mesh())


def test_shape_safe_drops_nondivisible():
    r = rules()  # mesh (1,1) on one device: sizes 1, everything divides
    sh = shardings_for(r, {"w": ("embed", "ffn")},
                       {"w": jax.ShapeDtypeStruct((8, 8), jnp.float32)})
    assert sh["w"].spec == P("data", "model")


def test_shape_safe_dedups_mesh_axes():
    r = rules()
    # experts and ffn both map to 'model': only the first may take it
    sh = shardings_for(
        r, {"w": ("experts", "embed", "ffn")},
        {"w": jax.ShapeDtypeStruct((4, 8, 8), jnp.float32)})
    spec = sh["w"].spec
    flat = [s for s in spec if s == "model"]
    assert len(flat) == 1
    assert spec[0] == "model"  # first dim wins


def test_kv_fallback_to_head_dim():
    import numpy as np
    from jax.sharding import Mesh
    # fake 4-wide model axis via an abstract mesh
    devs = np.array(jax.devices() * 4).reshape(1, 4) if len(jax.devices()) == 1 \
        else None
    if devs is None:
        pytest.skip("multi-device host")
    mesh = Mesh(devs, ("data", "model"))
    r = default_rules(mesh)
    sh = shardings_for(
        r, {"k": ("layers", "act_batch", None, "act_kv", "act_hd")},
        {"k": jax.ShapeDtypeStruct((2, 8, 16, 2, 8), jnp.bfloat16)})
    spec = sh["k"].spec
    assert spec[3] is None          # kv=2 can't take model=4
    assert spec[4] == "model"       # head_dim=8 takes it instead


def test_constrain_noop_without_rules():
    x = jnp.ones((4, 4))
    assert constrain(x, ("act_batch", None)) is x


def test_constrain_applies_with_rules():
    with use_rules(rules()):
        y = constrain(jnp.ones((4, 4)), ("act_batch", "act_embed"))
        assert y.shape == (4, 4)


def test_layouts_exist():
    m = make_host_mesh()
    for layout in ("2d", "fsdp_pure", "ep_only", "ep_dp"):
        r = default_rules(m, layout=layout)
        assert r.axis("batch") is not None or layout == "2d"


# ---------------------------------------------------------------------------
# dry-run parsing helpers
# ---------------------------------------------------------------------------

def test_shape_bytes():
    assert _shape_bytes("f32[4,4]") == 64
    assert _shape_bytes("bf16[2,3]") == 12
    assert _shape_bytes("(f32[2], s8[4])") == 12
    assert _shape_bytes("pred[8]") == 8


def test_collective_stats_parsing():
    hlo = """
      %ag = bf16[16,128]{1,0} all-gather(%x), dimensions={0}
      %ar = (f32[4,4]{1,0}, f32[4,4]{1,0}) all-reduce(%a, %b), to_apply=%sum
      %cp = f32[8]{0} collective-permute(%y), source_target_pairs={{0,1}}
      %notacoll = f32[8]{0} add(%y, %y)
    """
    st = collective_stats(hlo)
    assert st["all-gather"]["count"] == 1
    assert st["all-gather"]["bytes"] == 16 * 128 * 2
    assert st["all-reduce"]["count"] == 1
    assert st["all-reduce"]["bytes"] == 2 * 16 * 4
    assert st["collective-permute"]["count"] == 1
    assert st["total_count"] == 3


# ---------------------------------------------------------------------------
# cell definitions
# ---------------------------------------------------------------------------

def test_40_cells_defined():
    from repro.configs import ARCH_IDS, all_cells
    cells = all_cells()
    assert len(cells) == 40
    skips = [c for c in cells if not c[2]]
    assert len(skips) == 8  # 8 quadratic archs skip long_500k
    assert all(s[1] == "long_500k" for s in skips)
    runnable = [c for c in cells if c[2]]
    assert len(runnable) == 32


@pytest.mark.parametrize("arch_id", ["phi3-medium-14b", "musicgen-large",
                                     "llava-next-34b", "mamba2-1.3b"])
@pytest.mark.parametrize("shape", ["train_4k", "decode_32k"])
def test_input_specs_shapes(arch_id, shape):
    cfg = get_arch(arch_id)
    specs = input_specs(cfg, SHAPES[shape])
    B = SHAPES[shape].global_batch
    if SHAPES[shape].is_decode:
        if cfg.family == "audio":
            assert specs["tokens"].shape == (B, cfg.n_codebooks, 1)
        else:
            assert specs["tokens"].shape == (B, 1)
    else:
        if cfg.family == "vlm":
            total = specs["tokens"].shape[1] + specs["patches"].shape[1]
            assert total == SHAPES[shape].seq_len
        elif cfg.family == "audio":
            assert specs["codes"].shape == (B, cfg.n_codebooks,
                                            SHAPES[shape].seq_len)
        else:
            assert specs["tokens"].shape == (B, SHAPES[shape].seq_len)
