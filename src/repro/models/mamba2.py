"""Mamba2 (SSD — state-space duality) blocks, chunked scan + decode step.

Implements the SSD dual form from arXiv:2405.21060: within chunks of length Q
the output is computed with dense matmuls (MXU-friendly), while chunk-final
states are carried by an associative `lax.scan` — this is the structure the
`kernels/ssd_scan` Pallas kernel accelerates.

Shapes follow the minimal Mamba2 formulation with n_groups=1:
  x:  (B, S, H, P)    per-head inputs (P = head dim)
  dt: (B, S, H)       softplus-positive step sizes
  B,C:(B, S, N)       input/output projections (shared across heads)
  A:  (H,)            negative decay rates
State: (B, H, P, N).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .layers import _init, rms_norm

CONV_K = 4  # depthwise conv kernel width


def init_mamba2(key, d_model, d_state, headdim, expand, dtype):
    d_inner = expand * d_model
    nheads = d_inner // headdim
    ks = jax.random.split(key, 5)
    s = 1.0 / math.sqrt(d_model)
    d_in_proj = 2 * d_inner + 2 * d_state + nheads  # z, x, B, C, dt
    params = {
        "in_proj": _init(ks[0], (d_model, d_in_proj), s, dtype),
        "conv": _init(ks[1], (CONV_K, d_inner + 2 * d_state), 0.5, dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, nheads)).astype(jnp.float32),
        "dt_bias": jnp.zeros((nheads,), dtype=jnp.float32),
        "D": jnp.ones((nheads,), dtype=jnp.float32),
        "norm": jnp.ones((d_inner,), dtype=jnp.float32),
        "out_proj": _init(ks[2], (d_inner, d_model), 1.0 / math.sqrt(d_inner), dtype),
    }
    axes = {
        "in_proj": ("embed", "ffn"),
        "conv": (None, "ffn"),
        "A_log": (None,),
        "dt_bias": (None,),
        "D": (None,),
        "norm": ("ffn",),
        "out_proj": ("ffn", "embed"),
    }
    return params, axes


def _split_proj(zxbcdt, d_inner, d_state):
    z = zxbcdt[..., :d_inner]
    xBC = zxbcdt[..., d_inner:2 * d_inner + 2 * d_state]
    dt = zxbcdt[..., 2 * d_inner + 2 * d_state:]
    return z, xBC, dt


def _causal_conv(xBC, conv_w, state=None):
    """Depthwise causal conv along seq.  xBC: (B,S,C); conv_w: (K,C).

    With ``state`` (B, K-1, C) performs streaming conv (decode)."""
    B, S, C = xBC.shape
    if state is not None:
        xBC = jnp.concatenate([state, xBC], axis=1)
        new_state = xBC[:, -(CONV_K - 1):]
    else:
        xBC = jnp.pad(xBC, ((0, 0), (CONV_K - 1, 0), (0, 0)))
        new_state = xBC[:, -(CONV_K - 1):]
    out = sum(xBC[:, k:k + S] * conv_w[k][None, None] for k in range(CONV_K))
    return jax.nn.silu(out), new_state


def ssd_chunked(x, dt, A, Bm, Cm, chunk: int):
    """SSD forward over a full sequence (training / prefill).

    x: (B,S,H,P) dt: (B,S,H) A: (H,) Bm/Cm: (B,S,N).
    Returns (y: (B,S,H,P), final_state: (B,H,P,N)).
    """
    Bsz, S, H, P = x.shape
    N = Bm.shape[-1]
    Q = min(chunk, S)
    nc = -(-S // Q)
    pad = nc * Q - S
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    S_p = nc * Q

    xc = x.reshape(Bsz, nc, Q, H, P)
    dtc = dt.reshape(Bsz, nc, Q, H)
    Bc = Bm.reshape(Bsz, nc, Q, N)
    Cc = Cm.reshape(Bsz, nc, Q, N)

    dA = dtc * A[None, None, None, :]          # (B,nc,Q,H)  (negative)
    cum = jnp.cumsum(dA, axis=2)               # within-chunk cumulative
    # decay from position j to end of chunk / from start to position i
    seg_end = cum[:, :, -1:, :] - cum          # (B,nc,Q,H): end-of-chunk decay
    # intra-chunk causal kernel L[i,j] = exp(cum_i - cum_j) for i >= j
    li = cum[:, :, :, None, :]                 # i index
    lj = cum[:, :, None, :, :]                 # j index
    L = jnp.exp(jnp.clip(li - lj, -60.0, 0.0))
    idx = jnp.arange(Q)
    causal = (idx[:, None] >= idx[None, :])
    L = L * causal[None, None, :, :, None]

    xdt = xc * dtc[..., None]                  # dt-weighted inputs
    # intra-chunk: y[i] = C_i . sum_j L[i,j] B_j x_j dt_j
    G = jnp.einsum("bcin,bcjn->bcij", Cc, Bc)  # (B,nc,Q,Q)
    M = G[..., None] * L                       # (B,nc,Q,Q,H)
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", M, xdt)

    # chunk-final states: sum_j exp(cum_end - cum_j) B_j x_j dt_j
    decay_to_end = jnp.exp(jnp.clip(seg_end, -60.0, 0.0))  # (B,nc,Q,H)
    chunk_state = jnp.einsum("bcjn,bcjh,bcjhp->bchpn", Bc, decay_to_end, xdt)

    # inter-chunk recurrence over chunk states
    chunk_decay = jnp.exp(jnp.clip(cum[:, :, -1, :], -60.0, 0.0))  # (B,nc,H)

    def step(h_prev, inp):
        st, dec = inp  # (B,H,P,N), (B,H)
        h = h_prev * dec[..., None, None] + st
        return h, h_prev

    init = jnp.zeros((Bsz, H, P, N), dtype=x.dtype)
    final, prev_states = jax.lax.scan(
        step,
        init,
        (chunk_state.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)),
    )
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)  # (B,nc,H,P,N)

    # inter-chunk contribution: y[i] += (C_i . h_prev) * exp(cum_i)
    in_decay = jnp.exp(jnp.clip(cum, -60.0, 0.0))  # (B,nc,Q,H)
    y_inter = jnp.einsum("bcin,bchpn,bcih->bcihp", Cc, prev_states, in_decay)

    y = (y_intra + y_inter).reshape(Bsz, S_p, H, P)
    if pad:
        y = y[:, :S]
    return y, final


def ssd_decode_step(state, x, dt, A, Bm, Cm):
    """One-token SSD update.  state: (B,H,P,N); x: (B,H,P); dt: (B,H);
    Bm/Cm: (B,N).  Returns (y, new_state)."""
    dA = jnp.exp(jnp.clip(dt * A[None, :], -60.0, 0.0))  # (B,H)
    xdt = x * dt[..., None]
    upd = jnp.einsum("bhp,bn->bhpn", xdt, Bm)
    new_state = state * dA[..., None, None] + upd
    y = jnp.einsum("bhpn,bn->bhp", new_state, Cm)
    return y, new_state


def mamba2_block(params, x, *, d_state, headdim, expand, chunk,
                 norm_eps=1e-5, initial=None, return_state=False):
    """Full Mamba2 mixer over a sequence.  x: (B,S,D)."""
    B, S, D = x.shape
    d_inner = expand * D
    nheads = d_inner // headdim
    zxbcdt = x @ params["in_proj"]
    z, xBC, dt = _split_proj(zxbcdt, d_inner, d_state)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])
    conv_state = None if initial is None else initial.get("conv")
    xBC, new_conv = _causal_conv(xBC, params["conv"], conv_state)
    xs = xBC[..., :d_inner].reshape(B, S, nheads, headdim)
    Bm = xBC[..., d_inner:d_inner + d_state]
    Cm = xBC[..., d_inner + d_state:]
    A = -jnp.exp(params["A_log"])
    ssm_state = None if initial is None else initial.get("ssm")
    y, final = ssd_chunked(xs.astype(jnp.float32), dt, A,
                           Bm.astype(jnp.float32), Cm.astype(jnp.float32), chunk)
    if ssm_state is not None:
        # carry-in state contribution (decode prefill continuation): add
        # C_t . (decay from t=0) h_in
        cumdA = jnp.cumsum(dt * A[None, None, :], axis=1)
        dec = jnp.exp(jnp.clip(cumdA, -60.0, 0.0))
        y = y + jnp.einsum("bsn,bhpn,bsh->bshp", Cm.astype(jnp.float32),
                           ssm_state.astype(jnp.float32), dec)
        final = final + ssm_state * jnp.exp(jnp.clip(cumdA[:, -1], -60.0, 0.0))[..., None, None]
    y = y + xs.astype(jnp.float32) * params["D"][None, None, :, None]
    y = y.reshape(B, S, d_inner).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), params["norm"], norm_eps)
    out = y @ params["out_proj"]
    if return_state:
        return out, {"conv": new_conv, "ssm": final}
    return out


def mamba2_decode(params, x, cache, *, d_state, headdim, expand, norm_eps=1e-5):
    """One-token decode.  x: (B,1,D); cache: {'conv': (B,K-1,C), 'ssm': (B,H,P,N)}."""
    B, S, D = x.shape
    d_inner = expand * D
    nheads = d_inner // headdim
    zxbcdt = x @ params["in_proj"]
    z, xBC, dt = _split_proj(zxbcdt, d_inner, d_state)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])[:, 0]  # (B,H)
    xBC, new_conv = _causal_conv(xBC, params["conv"], cache["conv"])
    xs = xBC[:, 0, :d_inner].reshape(B, nheads, headdim)
    Bm = xBC[:, 0, d_inner:d_inner + d_state]
    Cm = xBC[:, 0, d_inner + d_state:]
    A = -jnp.exp(params["A_log"])
    y, new_ssm = ssd_decode_step(cache["ssm"].astype(jnp.float32),
                                 xs.astype(jnp.float32), dt, A,
                                 Bm.astype(jnp.float32), Cm.astype(jnp.float32))
    y = y + xs.astype(jnp.float32) * params["D"][None, :, None]
    y = y.reshape(B, 1, d_inner).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), params["norm"], norm_eps)
    return y @ params["out_proj"], {"conv": new_conv, "ssm": new_ssm}
