from .lm import (
    decode_step, forward, init_decode_cache, init_params, loss_fn,
)

__all__ = ["decode_step", "forward", "init_decode_cache", "init_params",
           "loss_fn"]
