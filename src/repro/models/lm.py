"""Unified language-model substrate for all ten assigned architectures.

One parameter/forward implementation covers the dense / moe / vlm / audio /
ssm / hybrid families.  Layers are *scanned* (params stacked on a leading
axis) so the lowered HLO stays small enough to compile 512-device meshes on
one CPU host.  Activation/param logical-axis annotations flow through
`repro.distributed.sharding.constrain`.

Entry points:
  init_params(cfg, key)            -> (params, logical_axes)
  loss_fn(params, batch, cfg)      -> (scalar loss, metrics)  [train/prefill]
  init_decode_cache(cfg, B, S_max) -> cache pytree (+ axes)
  decode_step(params, cache, tokens, cache_len, cfg) -> (logits, cache)
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.distributed.sharding import constrain, stack_axes

from .layers import (
    attention_block, attention_decode, cross_entropy, embed, init_attention,
    init_embedding, init_mlp, init_rms, mlp_block, rms_norm, _init,
)
from .mamba2 import (
    CONV_K, init_mamba2, mamba2_block, mamba2_decode,
)
from .moe import init_moe, moe_block


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _stack(inits):
    """Stack a list of identical pytrees along a new leading axis."""
    return jax.tree.map(lambda *xs: jnp.stack(xs), *inits)


def _init_block(cfg: ArchConfig, key):
    """One transformer/moe/ssm block's params + logical axes."""
    ks = jax.random.split(key, 4)
    dt = cfg.jdtype
    if cfg.family == "ssm" or cfg.family == "hybrid":
        p, a = init_mamba2(ks[0], cfg.d_model, cfg.ssm_state, cfg.ssm_headdim,
                           cfg.ssm_expand, dt)
        n, na = init_rms(cfg.d_model)
        return {"mixer": p, "norm": n}, {"mixer": a, "norm": na}
    params: dict = {}
    axes: dict = {}
    params["attn"], axes["attn"] = init_attention(
        ks[0], cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd, cfg.qk_norm, dt)
    params["norm1"], axes["norm1"] = init_rms(cfg.d_model)
    params["norm2"], axes["norm2"] = init_rms(cfg.d_model)
    if cfg.family == "moe":
        params["moe"], axes["moe"] = init_moe(
            ks[1], cfg.d_model, cfg.d_ff, cfg.n_experts, dt)
    else:
        params["mlp"], axes["mlp"] = init_mlp(ks[1], cfg.d_model, cfg.d_ff, dt)
    return params, axes


def init_params(cfg: ArchConfig, key):
    ks = jax.random.split(key, 8)
    dt = cfg.jdtype
    params: dict = {}
    axes: dict = {}

    if cfg.family == "audio":
        K = cfg.n_codebooks
        tabs = [init_embedding(k, cfg.vocab, cfg.d_model, dt)[0]
                for k in jax.random.split(ks[0], K)]
        params["embed"] = jnp.stack(tabs)
        axes["embed"] = (None, "vocab", "embed")
        params["lm_head"] = _init(ks[1], (cfg.d_model, K * cfg.vocab),
                                  1.0 / math.sqrt(cfg.d_model), dt)
        axes["lm_head"] = ("embed", "vocab")
    else:
        params["embed"], axes["embed"] = init_embedding(ks[0], cfg.vocab,
                                                        cfg.d_model, dt)
        params["lm_head"] = _init(ks[1], (cfg.d_model, cfg.vocab),
                                  1.0 / math.sqrt(cfg.d_model), dt)
        axes["lm_head"] = ("embed", "vocab")

    blocks = [_init_block(cfg, k) for k in jax.random.split(ks[2], cfg.n_layers)]
    params["layers"] = _stack([b[0] for b in blocks])
    axes["layers"] = stack_axes(blocks[0][1])

    if cfg.family == "hybrid":
        # one shared full transformer block (attention + MLP), re-entrant
        sp: dict = {}
        sa: dict = {}
        sp["attn"], sa["attn"] = init_attention(
            ks[3], cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd,
            cfg.qk_norm, dt)
        sp["mlp"], sa["mlp"] = init_mlp(ks[4], cfg.d_model, cfg.d_ff, dt)
        sp["norm1"], sa["norm1"] = init_rms(cfg.d_model)
        sp["norm2"], sa["norm2"] = init_rms(cfg.d_model)
        params["shared_attn"] = sp
        axes["shared_attn"] = sa

    params["final_norm"], axes["final_norm"] = init_rms(cfg.d_model)
    return params, axes


# ---------------------------------------------------------------------------
# blocks (forward)
# ---------------------------------------------------------------------------

def _dense_block(cfg: ArchConfig, p, x, positions):
    h = rms_norm(x, p["norm1"], cfg.norm_eps)
    h = attention_block(p["attn"], h, n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads,
                        head_dim=cfg.hd, positions=positions,
                        qk_norm=cfg.qk_norm, rope_theta=cfg.rope_theta,
                        norm_eps=cfg.norm_eps, q_block=cfg.q_block)
    x = x + h
    x = constrain(x, ("act_batch", "act_seq", "act_embed"))
    h = rms_norm(x, p["norm2"], cfg.norm_eps)
    if cfg.family == "moe":
        h, aux = moe_block(p["moe"], h, top_k=cfg.top_k,
                           capacity_factor=cfg.capacity_factor,
                           groups=cfg.moe_groups)
    else:
        h, aux = mlp_block(p["mlp"], h), jnp.zeros((), jnp.float32)
    x = x + h
    x = constrain(x, ("act_batch", "act_seq", "act_embed"))
    return x, aux


def _ssm_block(cfg: ArchConfig, p, x):
    h = rms_norm(x, p["norm"], cfg.norm_eps)
    h = mamba2_block(p["mixer"], h, d_state=cfg.ssm_state,
                     headdim=cfg.ssm_headdim, expand=cfg.ssm_expand,
                     chunk=cfg.ssm_chunk, norm_eps=cfg.norm_eps)
    x = x + h
    return constrain(x, ("act_batch", "act_seq", "act_embed"))


def _shared_block(cfg: ArchConfig, p, x, positions):
    h = rms_norm(x, p["norm1"], cfg.norm_eps)
    h = attention_block(p["attn"], h, n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads,
                        head_dim=cfg.hd, positions=positions,
                        rope_theta=cfg.rope_theta, norm_eps=cfg.norm_eps,
                        q_block=cfg.q_block)
    x = x + h
    h = rms_norm(x, p["norm2"], cfg.norm_eps)
    x = x + mlp_block(p["mlp"], h)
    return constrain(x, ("act_batch", "act_seq", "act_embed"))


def _maybe_remat(fn, cfg: ArchConfig):
    if cfg.remat == "none":
        return fn
    policy = (jax.checkpoint_policies.nothing_saveable if cfg.remat == "full"
              else jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(fn, policy=policy)


# ---------------------------------------------------------------------------
# full forward
# ---------------------------------------------------------------------------

def _layer_slice(layers, i):
    return jax.tree.map(lambda a: a[i], layers)


def forward(params, cfg: ArchConfig, x, positions):
    """Backbone over embedded inputs x: (B, S, D) -> (B, S, D)."""
    if not cfg.scan_layers:
        return _forward_unrolled(params, cfg, x, positions)
    if cfg.family in ("dense", "moe", "vlm", "audio"):
        blk = _maybe_remat(
            lambda xx, p: (_dense_block(cfg, p, xx, positions)), cfg)

        def body(carry, p):
            xx, aux = carry
            xx, a = blk(xx, p)
            return (xx, aux + a), None

        (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                                   params["layers"])
    elif cfg.family == "ssm":
        blk = _maybe_remat(lambda xx, p: _ssm_block(cfg, p, xx), cfg)
        x, _ = jax.lax.scan(lambda xx, p: (blk(xx, p), None), x,
                            params["layers"])
        aux = jnp.zeros((), jnp.float32)
    elif cfg.family == "hybrid":
        aux = jnp.zeros((), jnp.float32)
        period = cfg.attn_every
        groups = cfg.n_layers // period
        head_n = groups * period
        head = jax.tree.map(
            lambda a: a[:head_n].reshape(groups, period, *a.shape[1:]),
            params["layers"])
        tail = jax.tree.map(lambda a: a[head_n:], params["layers"])
        blk = _maybe_remat(lambda xx, p: _ssm_block(cfg, p, xx), cfg)
        shared = _maybe_remat(
            lambda xx, p: _shared_block(cfg, p, xx, positions), cfg)

        def group_body(xx, gp):
            xx, _ = jax.lax.scan(lambda c, p: (blk(c, p), None), xx, gp)
            xx = shared(xx, params["shared_attn"])
            return xx, None

        x, _ = jax.lax.scan(group_body, x, head)
        if cfg.n_layers - head_n:
            x, _ = jax.lax.scan(lambda c, p: (blk(c, p), None), x, tail)
    else:
        raise ValueError(cfg.family)
    return rms_norm(x, params["final_norm"], cfg.norm_eps), aux


def _forward_unrolled(params, cfg: ArchConfig, x, positions):
    """Python-loop variant (scan_layers=False): identical math, unrolled HLO.

    Used by the roofline probes — XLA cost analysis counts a while-loop body
    once, so per-layer FLOP/byte/collective numbers come from unrolled
    small-L lowers and are scaled analytically."""
    aux = jnp.zeros((), jnp.float32)
    L = cfg.n_layers
    if cfg.family in ("dense", "moe", "vlm", "audio"):
        for i in range(L):
            x, a = _dense_block(cfg, _layer_slice(params["layers"], i), x,
                                positions)
            aux = aux + a
    elif cfg.family == "ssm":
        for i in range(L):
            x = _ssm_block(cfg, _layer_slice(params["layers"], i), x)
    elif cfg.family == "hybrid":
        for i in range(L):
            x = _ssm_block(cfg, _layer_slice(params["layers"], i), x)
            if (i + 1) % cfg.attn_every == 0:
                x = _shared_block(cfg, params["shared_attn"], x, positions)
    else:
        raise ValueError(cfg.family)
    return rms_norm(x, params["final_norm"], cfg.norm_eps), aux


def embed_inputs(params, cfg: ArchConfig, batch):
    """Family-specific input embedding.  Returns (x, positions, label_info)."""
    if cfg.family == "vlm":
        tok_x = embed(params["embed"], batch["tokens"])
        x = jnp.concatenate([batch["patches"].astype(tok_x.dtype), tok_x], axis=1)
    elif cfg.family == "audio":
        # codes: (B, K, S) -> sum of per-codebook embeddings
        K = cfg.n_codebooks
        x = sum(embed(params["embed"][k], batch["codes"][:, k]) for k in range(K))
    else:
        x = embed(params["embed"], batch["tokens"])
    B, S = x.shape[0], x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    x = constrain(x, ("act_batch", "act_seq", "act_embed"))
    return x, positions


def loss_fn(params, batch, cfg: ArchConfig):
    """Causal LM loss over the batch.  Returns (loss, metrics)."""
    x, positions = embed_inputs(params, cfg, batch)
    h, aux = forward(params, cfg, x, positions)
    labels = batch["labels"]
    if cfg.family == "audio":
        B, S, D = h.shape
        logits = (h @ params["lm_head"]).reshape(B, S, cfg.n_codebooks, cfg.vocab)
        logits = logits[:, :-1]
        lbl = labels[:, :, 1:].transpose(0, 2, 1)  # (B,S-1,K)
        loss = cross_entropy(logits, lbl)
    else:
        logits = h @ params["lm_head"]
        logits = constrain(logits, ("act_batch", "act_seq", "act_vocab"))
        loss = cross_entropy(logits[:, :-1], labels[:, 1:])
    total = loss + 0.01 * aux
    return total, {"loss": loss, "aux_loss": aux}


# ---------------------------------------------------------------------------
# decode path (serve_step)
# ---------------------------------------------------------------------------

def init_decode_cache(cfg: ArchConfig, batch: int, max_len: int):
    """Cache pytree + logical axes for one-token decoding."""
    dt = cfg.jdtype
    kv_dt = getattr(jnp, cfg.kv_dtype) if cfg.kv_dtype else dt
    L = cfg.n_layers
    if cfg.family == "ssm" or cfg.family == "hybrid":
        d_inner = cfg.ssm_expand * cfg.d_model
        nheads = d_inner // cfg.ssm_headdim
        conv_c = d_inner + 2 * cfg.ssm_state
        cache = {
            "conv": jnp.zeros((L, batch, CONV_K - 1, conv_c), dt),
            "ssm": jnp.zeros((L, batch, nheads, cfg.ssm_headdim, cfg.ssm_state), dt),
        }
        axes = {
            "conv": ("layers", "act_batch", None, "act_ffn"),
            "ssm": ("layers", "act_batch", None, None, None),
        }
        if cfg.family == "hybrid":
            n_shared = cfg.n_layers // cfg.attn_every
            cache["k"] = jnp.zeros((n_shared, batch, max_len, cfg.n_kv_heads, cfg.hd), dt)
            cache["v"] = jnp.zeros_like(cache["k"])
            axes["k"] = (None, "act_batch", None, "act_kv", "act_hd")
            axes["v"] = axes["k"]
        return cache, axes
    cache = {
        "k": jnp.zeros((L, batch, max_len, cfg.n_kv_heads, cfg.hd), kv_dt),
        "v": jnp.zeros((L, batch, max_len, cfg.n_kv_heads, cfg.hd), kv_dt),
    }
    axes = {"k": ("layers", "act_batch", None, "act_kv", "act_hd"),
            "v": ("layers", "act_batch", None, "act_kv", "act_hd")}
    return cache, axes


def decode_step(params, cache, tokens, cache_len, cfg: ArchConfig):
    """One-token decode.  tokens: (B,1) int32 (audio: (B,K,1)).

    Returns (logits, new_cache)."""
    if cfg.family == "audio":
        K = cfg.n_codebooks
        x = sum(embed(params["embed"][k], tokens[:, k]) for k in range(K))
    elif cfg.family == "vlm":
        x = embed(params["embed"], tokens)
    else:
        x = embed(params["embed"], tokens)
    x = constrain(x, ("act_batch", None, "act_embed"))

    if cfg.family in ("dense", "moe", "vlm", "audio"):
        def body(xx, layer):
            p, ck, cv = layer
            h = rms_norm(xx, p["norm1"], cfg.norm_eps)
            h, ck, cv = attention_decode(
                p["attn"], h, ck, cv, cache_len, n_heads=cfg.n_heads,
                n_kv=cfg.n_kv_heads, head_dim=cfg.hd, qk_norm=cfg.qk_norm,
                rope_theta=cfg.rope_theta, norm_eps=cfg.norm_eps)
            xx = xx + h
            h = rms_norm(xx, p["norm2"], cfg.norm_eps)
            if cfg.family == "moe":
                h, _ = moe_block(p["moe"], h, top_k=cfg.top_k,
                                 capacity_factor=cfg.capacity_factor)
            else:
                h = mlp_block(p["mlp"], h)
            xx = xx + h
            return xx, (ck, cv)

        if cfg.scan_layers:
            x, (k_new, v_new) = jax.lax.scan(
                body, x, (params["layers"], cache["k"], cache["v"]))
            new_cache = {"k": k_new, "v": v_new}
        else:
            ks, vs = [], []
            for i in range(cfg.n_layers):
                x, (ck, cv) = body(x, (_layer_slice(params["layers"], i),
                                       cache["k"][i], cache["v"][i]))
                ks.append(ck)
                vs.append(cv)
            new_cache = {"k": jnp.stack(ks), "v": jnp.stack(vs)}
    elif cfg.family == "ssm":
        def body(xx, layer):
            p, conv, ssm = layer
            h = rms_norm(xx, p["norm"], cfg.norm_eps)
            h, new = mamba2_decode(p["mixer"], h, {"conv": conv, "ssm": ssm},
                                   d_state=cfg.ssm_state, headdim=cfg.ssm_headdim,
                                   expand=cfg.ssm_expand, norm_eps=cfg.norm_eps)
            return xx + h, (new["conv"], new["ssm"])

        if cfg.scan_layers:
            x, (conv_new, ssm_new) = jax.lax.scan(
                body, x, (params["layers"], cache["conv"], cache["ssm"]))
            new_cache = {"conv": conv_new, "ssm": ssm_new}
        else:
            cs, ss = [], []
            for i in range(cfg.n_layers):
                x, (c1, s1) = body(x, (_layer_slice(params["layers"], i),
                                       cache["conv"][i], cache["ssm"][i]))
                cs.append(c1)
                ss.append(s1)
            new_cache = {"conv": jnp.stack(cs), "ssm": jnp.stack(ss)}
    elif cfg.family == "hybrid" and not cfg.scan_layers:
        def one(xx, p, conv, ssm):
            h = rms_norm(xx, p["norm"], cfg.norm_eps)
            h, new = mamba2_decode(p["mixer"], h, {"conv": conv, "ssm": ssm},
                                   d_state=cfg.ssm_state, headdim=cfg.ssm_headdim,
                                   expand=cfg.ssm_expand, norm_eps=cfg.norm_eps)
            return xx + h, new

        cs, ss, ks, vs = [], [], [], []
        g = 0
        for i in range(cfg.n_layers):
            x, new = one(x, _layer_slice(params["layers"], i),
                         cache["conv"][i], cache["ssm"][i])
            cs.append(new["conv"])
            ss.append(new["ssm"])
            if (i + 1) % cfg.attn_every == 0 and g < cache["k"].shape[0]:
                sp = params["shared_attn"]
                h = rms_norm(x, sp["norm1"], cfg.norm_eps)
                h, ck, cv = attention_decode(
                    sp["attn"], h, cache["k"][g], cache["v"][g], cache_len,
                    n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads, head_dim=cfg.hd,
                    rope_theta=cfg.rope_theta, norm_eps=cfg.norm_eps)
                x = x + h
                h = rms_norm(x, sp["norm2"], cfg.norm_eps)
                x = x + mlp_block(sp["mlp"], h)
                ks.append(ck)
                vs.append(cv)
                g += 1
        while g < cache["k"].shape[0]:
            ks.append(cache["k"][g])
            vs.append(cache["v"][g])
            g += 1
        new_cache = {"conv": jnp.stack(cs), "ssm": jnp.stack(ss),
                     "k": jnp.stack(ks) if ks else cache["k"],
                     "v": jnp.stack(vs) if vs else cache["v"]}
    elif cfg.family == "hybrid":
        period = cfg.attn_every
        groups = cfg.n_layers // period
        head_n = groups * period

        def ssm_body(xx, layer):
            p, conv, ssm = layer
            h = rms_norm(xx, p["norm"], cfg.norm_eps)
            h, new = mamba2_decode(p["mixer"], h, {"conv": conv, "ssm": ssm},
                                   d_state=cfg.ssm_state, headdim=cfg.ssm_headdim,
                                   expand=cfg.ssm_expand, norm_eps=cfg.norm_eps)
            return xx + h, (new["conv"], new["ssm"])

        take = lambda a, lo, n: jax.tree.map(lambda t: t[lo:lo + n], a)
        convs, ssms = [], []
        ks, vs = [], []
        for g in range(groups):
            layer = (take(params["layers"], g * period, period),
                     take(cache["conv"], g * period, period),
                     take(cache["ssm"], g * period, period))
            x, (c_new, s_new) = jax.lax.scan(ssm_body, x, layer)
            convs.append(c_new)
            ssms.append(s_new)
            sp = params["shared_attn"]
            h = rms_norm(x, sp["norm1"], cfg.norm_eps)
            h, ck, cv = attention_decode(
                sp["attn"], h, cache["k"][g], cache["v"][g], cache_len,
                n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads, head_dim=cfg.hd,
                rope_theta=cfg.rope_theta, norm_eps=cfg.norm_eps)
            x = x + h
            h = rms_norm(x, sp["norm2"], cfg.norm_eps)
            x = x + mlp_block(sp["mlp"], h)
            ks.append(ck)
            vs.append(cv)
        if cfg.n_layers - head_n:
            layer = (take(params["layers"], head_n, cfg.n_layers - head_n),
                     take(cache["conv"], head_n, cfg.n_layers - head_n),
                     take(cache["ssm"], head_n, cfg.n_layers - head_n))
            x, (c_new, s_new) = jax.lax.scan(ssm_body, x, layer)
            convs.append(c_new)
            ssms.append(s_new)
        new_cache = {
            "conv": jnp.concatenate(convs), "ssm": jnp.concatenate(ssms),
            "k": jnp.stack(ks), "v": jnp.stack(vs),
        }
    else:
        raise ValueError(cfg.family)

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = x @ params["lm_head"]
    if cfg.family == "audio":
        B = x.shape[0]
        logits = logits.reshape(B, 1, cfg.n_codebooks, cfg.vocab)
    return logits, new_cache
