"""Mixture-of-Experts layer: top-k routing, sort-based capacity dispatch.

Dispatch is the production-style sort/gather formulation (token dropping at a
capacity factor) rather than the textbook (tokens, experts, capacity) one-hot
einsum — the one-hot tensor is O(T^2) at dbrx scale, while this version's
working set is the dispatched activations (E, C, D) themselves.  All data
movement is gathers, which GSPMD turns into all-to-all-style collectives when
the expert axis is sharded over 'model' and tokens over 'data'.

The LTRF connection (DESIGN.md §Arch-applicability): the activated experts'
weight tiles are the per-interval register working set — the interval planner
(`repro.core.plan`) bounds how many expert tiles stream through VMEM per step.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .layers import _init


def init_moe(key, d_model, d_ff, n_experts, dtype):
    ks = jax.random.split(key, 4)
    s = 1.0 / math.sqrt(d_model)
    params = {
        "router": _init(ks[0], (d_model, n_experts), s, jnp.float32),
        "w_gate": _init(ks[1], (n_experts, d_model, d_ff), s, dtype),
        "w_up": _init(ks[2], (n_experts, d_model, d_ff), s, dtype),
        "w_down": _init(ks[3], (n_experts, d_ff, d_model), 1.0 / math.sqrt(d_ff), dtype),
    }
    axes = {
        "router": ("embed", None),
        "w_gate": ("experts", "embed", "ffn"),
        "w_up": ("experts", "embed", "ffn"),
        "w_down": ("experts", "ffn", "embed"),
    }
    return params, axes


def moe_block(params, x, *, top_k: int, capacity_factor: float = 1.25,
              groups: int = 1):
    """x: (B, S, D) -> ((B, S, D), aux_loss).

    ``groups > 1`` dispatches each token group independently (per-group
    capacity) — align groups with the token sharding and the argsort /
    position bookkeeping become shard-local (no collective); only the
    expert-gather itself crosses shards (the all-to-all).  This is the
    standard grouped-dispatch formulation (t5x/MaxText)."""
    B, S, D = x.shape
    T = B * S
    if groups > 1:
        assert T % groups == 0, (T, groups)
        xg = x.reshape(groups, T // groups, 1, D)
        out, aux = jax.vmap(
            lambda g: moe_block(params, g, top_k=top_k,
                                capacity_factor=capacity_factor, groups=1)
        )(xg)
        return out.reshape(B, S, D), aux.mean()
    E = params["router"].shape[1]
    N = T * top_k
    xt = x.reshape(T, D)

    logits = xt.astype(jnp.float32) @ params["router"]          # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, top_k)           # (T, k)
    gate_vals = gate_vals / (gate_vals.sum(-1, keepdims=True) + 1e-9)

    flat_e = gate_idx.reshape(-1)                               # (N,)
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    sorted_tok = (jnp.arange(N) // top_k)[order]

    # one-hot count (vmap-safe, unlike bincount)
    counts = (flat_e[:, None] == jnp.arange(E)[None, :]).sum(0)  # (E,)
    starts = jnp.concatenate([jnp.zeros((1,), counts.dtype),
                              jnp.cumsum(counts)[:-1]])

    C = max(1, int(capacity_factor * N / E))
    slot = starts[:, None] + jnp.arange(C)[None, :]             # (E, C)
    valid = jnp.arange(C)[None, :] < counts[:, None]
    slot_tok = sorted_tok[jnp.clip(slot, 0, N - 1)]             # (E, C)

    # Expert FFN in capacity chunks: the (E, chunk, d_ff) hidden working set
    # is bounded regardless of C (the LTRF working-set idea applied to the
    # expert pipeline), and the per-chunk gather streams tokens in.
    c0 = min(C, 8192)
    nch = -(-C // c0)
    pad_c = nch * c0 - C
    st = jnp.pad(slot_tok, ((0, 0), (0, pad_c))) if pad_c else slot_tok
    vd = jnp.pad(valid, ((0, 0), (0, pad_c))) if pad_c else valid
    st = st.reshape(E, nch, c0).transpose(1, 0, 2)              # (nch, E, c0)
    vd = vd.reshape(E, nch, c0).transpose(1, 0, 2)

    def expert_chunk(_, inp):
        tok, ok = inp
        xe = xt[tok] * ok[..., None].astype(x.dtype)            # (E, c0, D)
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, params["w_gate"])) \
            * jnp.einsum("ecd,edf->ecf", xe, params["w_up"])
        return None, jnp.einsum("ecf,efd->ecd", h, params["w_down"])

    _, ye = jax.lax.scan(expert_chunk, None, (st, vd))          # (nch, E, c0, D)
    ye = ye.transpose(1, 0, 2, 3).reshape(E, nch * c0, D)[:, :C]  # (E, C, D)

    pos = jnp.arange(N) - starts[sorted_e]                      # (N,)
    kept = pos < C
    ye_n = ye[sorted_e, jnp.clip(pos, 0, C - 1)]                # (N, D)
    ye_n = ye_n * kept[:, None].astype(x.dtype)
    inv = jnp.argsort(order)
    y = (ye_n[inv].reshape(T, top_k, D)
         * gate_vals[..., None].astype(x.dtype)).sum(axis=1)

    # auxiliary load-balance loss (Switch-style)
    me = probs.mean(0)
    ce = (counts / max(N, 1)).astype(jnp.float32)
    aux = E * jnp.sum(me * ce)
    return y.reshape(B, S, D), aux


def moe_flops_per_token(d_model: int, d_ff: int, top_k: int) -> int:
    """Active FLOPs per token for the expert MLPs (fwd): 3 matmuls x top_k."""
    return 2 * 3 * d_model * d_ff * top_k
