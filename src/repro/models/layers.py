"""Shared neural-net layers (pure functional JAX, no framework deps).

Params are plain pytrees of jnp arrays.  Every init function returns
(params, logical_axes) where logical_axes mirrors the params pytree with
tuples of logical axis names consumed by repro.distributed.sharding.
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np


def _init(key, shape, scale, dtype):
    return (jax.random.normal(key, shape) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def rms_norm(x, weight, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    y = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (y * weight.astype(jnp.float32)).astype(dt)


def init_rms(d, dtype=jnp.float32):
    return jnp.ones((d,), dtype=dtype), ("embed",)


# ---------------------------------------------------------------------------
# rotary position embeddings
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float = 10000.0):
    return 1.0 / (theta ** (np.arange(0, head_dim, 2) / head_dim))


def apply_rope(x, positions, theta: float = 10000.0):
    """x: (..., seq, heads, head_dim); positions: (..., seq)"""
    hd = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(hd, theta), dtype=jnp.float32)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., seq, hd/2)
    cos = jnp.cos(angles)[..., :, None, :]
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention (GQA, causal, memory-efficient q-blocked form)
# ---------------------------------------------------------------------------

def init_attention(key, d_model, n_heads, n_kv, head_dim, qk_norm, dtype):
    ks = jax.random.split(key, 4)
    s = 1.0 / math.sqrt(d_model)
    params = {
        "wq": _init(ks[0], (d_model, n_heads * head_dim), s, dtype),
        "wk": _init(ks[1], (d_model, n_kv * head_dim), s, dtype),
        "wv": _init(ks[2], (d_model, n_kv * head_dim), s, dtype),
        "wo": _init(ks[3], (n_heads * head_dim, d_model), s / math.sqrt(2), dtype),
    }
    axes = {
        "wq": ("embed", "heads"),
        "wk": ("embed", "kv"),
        "wv": ("embed", "kv"),
        "wo": ("heads", "embed"),
    }
    if qk_norm:
        params["q_norm"] = jnp.ones((head_dim,), dtype=jnp.float32)
        params["k_norm"] = jnp.ones((head_dim,), dtype=jnp.float32)
        axes["q_norm"] = (None,)
        axes["k_norm"] = (None,)
    return params, axes


def _qkv(params, x, cfg_heads, cfg_kv, head_dim, positions, qk_norm, rope_theta,
         norm_eps):
    B, S, _ = x.shape
    q = (x @ params["wq"]).reshape(B, S, cfg_heads, head_dim)
    k = (x @ params["wk"]).reshape(B, S, cfg_kv, head_dim)
    v = (x @ params["wv"]).reshape(B, S, cfg_kv, head_dim)
    if qk_norm:
        q = rms_norm(q, params["q_norm"], norm_eps)
        k = rms_norm(k, params["k_norm"], norm_eps)
    q = apply_rope(q, positions, rope_theta)
    k = apply_rope(k, positions, rope_theta)
    return q, k, v


def _repeat_kv(k, n_heads):
    """(B,S,kv,hd) -> (B,S,H,hd) by repeating groups."""
    B, S, kv, hd = k.shape
    rep = n_heads // kv if n_heads % kv == 0 else -1
    if rep == -1:  # uneven GQA (e.g. 40q/10kv is even; guard anyway)
        rep = -(-n_heads // kv)
        k = jnp.repeat(k, rep, axis=2)[:, :, :n_heads]
        return k
    return jnp.repeat(k, rep, axis=2)


def causal_attention(q, k, v, q_block: int = 512, q_offset=None):
    """Memory-efficient causal attention.

    q: (B,Sq,H,hd), k/v: (B,Skv,KV,hd).  Scans over q blocks so peak memory is
    O(Sq_block x Skv) rather than O(Sq x Skv).  ``q_offset`` shifts query
    positions (for decode, q_offset = Skv - Sq).
    """
    B, Sq, H, hd = q.shape
    Skv = k.shape[1]
    k = _repeat_kv(k, H)
    v = _repeat_kv(v, H)
    scale = 1.0 / math.sqrt(hd)
    offset = Skv - Sq if q_offset is None else q_offset

    kT = k.transpose(0, 2, 3, 1)  # (B,H,hd,Skv)
    vT = v.transpose(0, 2, 1, 3)  # (B,H,Skv,hd)
    kv_pos = jnp.arange(Skv)

    q_block = min(q_block, Sq)
    nblk = -(-Sq // q_block)
    pad = nblk * q_block - Sq
    qp = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0))) if pad else q
    qb = qp.reshape(B, nblk, q_block, H, hd).transpose(1, 0, 3, 2, 4)  # (nblk,B,H,qb,hd)

    @partial(jax.checkpoint, policy=jax.checkpoint_policies.nothing_saveable)
    def one_q_block(blk_idx, qblk):
        # rematerialized per block: backward never holds more than one
        # (q_block x Skv) logits/softmax tile in memory
        qpos = blk_idx * q_block + jnp.arange(q_block) + offset
        logits = jnp.einsum("bhqd,bhdk->bhqk", qblk.astype(jnp.float32),
                            kT.astype(jnp.float32)) * scale
        mask = kv_pos[None, :] <= qpos[:, None]
        logits = jnp.where(mask[None, None], logits, -1e30)
        p = jax.nn.softmax(logits, axis=-1)
        return jnp.einsum("bhqk,bhkd->bhqd", p, vT.astype(jnp.float32))

    def one_block(carry, inp):
        blk_idx, qblk = inp
        return carry, one_q_block(blk_idx, qblk)

    _, outs = jax.lax.scan(one_block, None, (jnp.arange(nblk), qb))
    out = outs.transpose(1, 0, 3, 2, 4).reshape(B, nblk * q_block, H, hd)
    if pad:
        out = out[:, :Sq]
    return out.astype(q.dtype)


def attention_block(params, x, *, n_heads, n_kv, head_dim, positions,
                    qk_norm=False, rope_theta=10000.0, norm_eps=1e-5,
                    q_block=512):
    q, k, v = _qkv(params, x, n_heads, n_kv, head_dim, positions, qk_norm,
                   rope_theta, norm_eps)
    out = causal_attention(q, k, v, q_block=q_block)
    B, S, _, _ = out.shape
    return out.reshape(B, S, n_heads * head_dim) @ params["wo"]


def attention_decode(params, x, cache_k, cache_v, cache_len, *, n_heads, n_kv,
                     head_dim, qk_norm=False, rope_theta=10000.0, norm_eps=1e-5):
    """One-token decode against a (B, S_max, kv, hd) KV cache.

    Returns (out, new_cache_k, new_cache_v).
    """
    B, S, _ = x.shape  # S == 1
    positions = jnp.full((B, S), cache_len, dtype=jnp.int32)
    q, k, v = _qkv(params, x, n_heads, n_kv, head_dim, positions, qk_norm,
                   rope_theta, norm_eps)
    cache_k = jax.lax.dynamic_update_slice_in_dim(cache_k, k.astype(cache_k.dtype), cache_len, axis=1)
    cache_v = jax.lax.dynamic_update_slice_in_dim(cache_v, v.astype(cache_v.dtype), cache_len, axis=1)
    S_max = cache_k.shape[1]
    kk = _repeat_kv(cache_k, n_heads)
    vv = _repeat_kv(cache_v, n_heads)
    scale = 1.0 / math.sqrt(head_dim)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32), kk.astype(jnp.float32)) * scale
    mask = jnp.arange(S_max)[None, :] <= cache_len  # current token included
    logits = jnp.where(mask[None, None], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, vv.astype(jnp.float32)).astype(x.dtype)
    out = out.reshape(B, S, n_heads * head_dim) @ params["wo"]
    return out, cache_k, cache_v


# ---------------------------------------------------------------------------
# SwiGLU MLP
# ---------------------------------------------------------------------------

def init_mlp(key, d_model, d_ff, dtype):
    ks = jax.random.split(key, 3)
    s = 1.0 / math.sqrt(d_model)
    params = {
        "w_gate": _init(ks[0], (d_model, d_ff), s, dtype),
        "w_up": _init(ks[1], (d_model, d_ff), s, dtype),
        "w_down": _init(ks[2], (d_ff, d_model), 1.0 / math.sqrt(d_ff), dtype),
    }
    axes = {"w_gate": ("embed", "ffn"), "w_up": ("embed", "ffn"),
            "w_down": ("ffn", "embed")}
    return params, axes


def mlp_block(params, x):
    h = jax.nn.silu(x @ params["w_gate"]) * (x @ params["w_up"])
    return h @ params["w_down"]


# ---------------------------------------------------------------------------
# embeddings / heads
# ---------------------------------------------------------------------------

def init_embedding(key, vocab, d_model, dtype):
    return _init(key, (vocab, d_model), 1.0, dtype), ("vocab", "embed")


def embed(table, tokens):
    return jnp.take(table, tokens, axis=0)


def unembed(x, table):
    return x @ table.T


def cross_entropy(logits, labels):
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return (logz - gold).mean()
