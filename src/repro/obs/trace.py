"""Per-warp event tracing with Chrome trace-event export.

`TraceSink` collects simulator events (instruction issue, interval
prefetches, warp swap-in/swap-out, bank conflicts, per-cycle stall
attribution) and serializes them as Chrome trace-event JSON — the format
chrome://tracing and https://ui.perfetto.dev load directly.  Mapping:

* one **process** per SM (``pid`` = SM index),
* one **track** (thread) per warp (``tid`` = warp id) plus a synthetic
  ``scheduler`` track (`SCHED_TID`) carrying the zero-issue stall spans
  labelled with their `repro.obs.attribution` category,
* simulated cycles are reported as microseconds (``ts``/``dur``), so one
  trace second = one megacycle and Perfetto's zoom/measure tools read
  directly in cycles.

Tracing is strictly opt-in (``SimConfig.trace``): the engine's hooks are
guarded by a single ``is not None`` test and the disabled path is
fuzz-pinned bit-identical to the frozen golden oracle, which never traces.

Use `trace_simulation` for the one-call version, or pass a trace-enabled
config to ``repro.sim.engine.Simulator`` and read its ``trace`` attribute.
"""
from __future__ import annotations

import dataclasses
import json
import pathlib

# tid of the synthetic per-SM scheduler track (far above any real warp id).
SCHED_TID = 1_000_000


class TraceSink:
    """Accumulates trace events for one simulated SM.

    Methods are deliberately tiny — they run inside the simulator's hot
    loop when tracing is enabled — and record plain dicts in the Chrome
    trace-event schema (ph "X" complete spans, ph "i" instants).
    """

    def __init__(self, sm: int = 0) -> None:
        self.sm = sm
        self.events: list[dict] = []
        self._tids: set[int] = set()

    # ------------------------------------------------------------------ record
    def span(self, tid: int, name: str, start: int, dur: int,
             args: dict | None = None) -> None:
        ev = {"ph": "X", "pid": self.sm, "tid": tid, "name": name,
              "ts": start, "dur": max(dur, 1)}
        if args:
            ev["args"] = args
        self.events.append(ev)
        self._tids.add(tid)

    def instant(self, tid: int, name: str, ts: int,
                args: dict | None = None) -> None:
        ev = {"ph": "i", "pid": self.sm, "tid": tid, "name": name,
              "ts": ts, "s": "t"}
        if args:
            ev["args"] = args
        self.events.append(ev)
        self._tids.add(tid)

    # ------------------------------------------------------------------ export
    def to_chrome(self) -> dict:
        """The complete Chrome trace-event document (metadata + events)."""
        meta = [{"ph": "M", "pid": self.sm, "tid": tid,
                 "name": "thread_name",
                 "args": {"name": "scheduler" if tid == SCHED_TID
                          else f"warp {tid}"}}
                for tid in sorted(self._tids)]
        meta.append({"ph": "M", "pid": self.sm, "name": "process_name",
                     "args": {"name": f"SM {self.sm}"}})
        return {"traceEvents": meta + self.events,
                "displayTimeUnit": "ms",
                "otherData": {"time_unit": "1 ts = 1 simulated cycle"}}

    def write(self, path) -> pathlib.Path:
        path = pathlib.Path(path)
        path.write_text(json.dumps(self.to_chrome()))
        return path


def trace_simulation(workload, cfg):
    """Run the fast engine with tracing on; returns ``(SimResult, TraceSink)``.

    ``cfg.trace`` is forced on (via ``dataclasses.replace``) so callers can
    hand in any existing sweep config unchanged.  Import is deferred:
    ``repro.sim.engine`` imports this module for `TraceSink`, so the
    top-level dependency must stay one-directional.
    """
    from repro.sim.engine import Simulator

    if not cfg.trace:
        cfg = dataclasses.replace(cfg, trace=True)
    sim = Simulator(cfg, workload)
    result = sim.run()
    return result, sim.trace
