"""Cycle-attribution accounting: where did every simulated cycle go?

The paper's headline claim — LTRF overlaps MRF prefetch latency with other
warps' execution — is a statement about *cycle attribution*: the design
converts cycles the baseline loses to register-file and memory latency into
issue cycles.  This module defines the accounting both simulator engines
(`repro.sim.engine` and the frozen golden oracle `repro.sim.golden`) apply
identically: every simulated SM cycle lands in **exactly one** category of
`CYCLE_CATEGORIES`, the per-category totals are carried on
``SimResult.cycle_breakdown``, and `check_breakdown` enforces the hard
invariant ``sum(cycle_breakdown.values()) == SimResult.cycles`` at the end
of every run (fuzz-pinned engine-vs-golden in ``tests/test_sim_fuzz.py``).

Category definitions (documented for humans in docs/observability.md; the
doc-consistency suite asserts every name below appears there):

``issue``
    at least one instruction issued this cycle.
``drain``
    no issue, the admission queue is empty, and retirement has left fewer
    live warps than one scheduler's worth (``active_slots``): the
    unavoidable kernel tail, not a latency-tolerance failure.
``bank_conflict``
    no issue; a warp with ready operands could not issue for a structural
    register-file reason — operand collectors busy, or MRF bank bandwidth
    exhausted (the per-cycle bank-port token model).  Under
    ``bank_model="arbitrated"`` the *extra serialization rounds* are
    additionally charged into operand latency and counted by
    ``SimResult.bank_conflicts``; this category is the cycles where RF
    structure alone blocked an otherwise-ready issue.
``prefetch_stall``
    no issue; at least one active-slot warp is blocked on an in-flight
    register-interval prefetch (the LTRF cost the scheduler tries to hide).
``mem_stall``
    no issue, nothing prefetching; a schedulable warp is waiting on a
    memory-produced operand (L1/DRAM latency exposed).
``alu_dep``
    no issue; schedulable warps are waiting only on ALU / writeback
    dependencies (register read-after-write chains).
``scheduler_idle``
    everything else: the scheduler has no schedulable warp at all — under
    the two-level policy this is the "all active warps swapped out on
    memory" state, the classic latency-tolerance failure mode.

The stall categories are resolved by `classify_stall` with the fixed
precedence drain > bank_conflict > prefetch_stall > mem_stall > alu_dep >
scheduler_idle, so attribution is deterministic even when several causes
coincide in one cycle.
"""
from __future__ import annotations

# Order is presentation order (stacked figures, docs tables); membership is
# the accounting contract.
CYCLE_CATEGORIES = (
    "issue",
    "alu_dep",
    "mem_stall",
    "prefetch_stall",
    "bank_conflict",
    "scheduler_idle",
    "drain",
)

# Everything that is not "issue": the stall side of the ledger.
STALL_CATEGORIES = tuple(c for c in CYCLE_CATEGORIES if c != "issue")


def new_breakdown() -> dict[str, int]:
    """A zero-filled breakdown (every category present, fixed order)."""
    return {c: 0 for c in CYCLE_CATEGORIES}


def classify_stall(drain: bool, struct_stall: bool, saw_prefetch: bool,
                   saw_mem: bool, saw_dep: bool) -> str:
    """Resolve one zero-issue cycle to its category.

    Both engines derive the five booleans from identical observable state
    (admission queue / resident count, the issue loop's structural-stall
    flag, and active-warp status + operand readiness) and call this one
    function, so attribution cannot diverge between them.
    """
    if drain:
        return "drain"
    if struct_stall:
        return "bank_conflict"
    if saw_prefetch:
        return "prefetch_stall"
    if saw_mem:
        return "mem_stall"
    if saw_dep:
        return "alu_dep"
    return "scheduler_idle"


class CycleAttributionError(AssertionError):
    """The accounting invariant broke: breakdown does not sum to cycles."""


def check_breakdown(breakdown: dict[str, int], cycles: int,
                    design: str, workload: str) -> None:
    """Hard invariant: every cycle attributed to exactly one known category.

    Raised (never warned) — a run whose cycles cannot be accounted for is a
    bug in the engine, not a reporting blemish.
    """
    if set(breakdown) != set(CYCLE_CATEGORIES):
        raise CycleAttributionError(
            f"{workload}/{design}: breakdown categories "
            f"{sorted(breakdown)} != {sorted(CYCLE_CATEGORIES)}")
    total = sum(breakdown.values())
    if total != cycles:
        raise CycleAttributionError(
            f"{workload}/{design}: cycle_breakdown sums to {total}, "
            f"but the run took {cycles} cycles "
            f"(unattributed: {cycles - total})")


def breakdown_fractions(breakdown: dict[str, int]) -> dict[str, float]:
    """The breakdown normalized to fractions of total cycles (0.0 on an
    empty run); categories keep `CYCLE_CATEGORIES` order."""
    total = sum(breakdown.values())
    if not total:
        return {c: 0.0 for c in CYCLE_CATEGORIES}
    return {c: breakdown.get(c, 0) / total for c in CYCLE_CATEGORIES}


def merge_breakdowns(breakdowns) -> dict[str, int]:
    """Sum per-category totals (e.g. per-SM results into a GPU total)."""
    out = new_breakdown()
    for bd in breakdowns:
        for c, v in bd.items():
            out[c] = out.get(c, 0) + v
    return out
