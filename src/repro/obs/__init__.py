"""Observability layer: cycle attribution, event tracing, sweep metrics.

Three independent pieces (see docs/observability.md):

* `repro.obs.attribution` — the always-on cycle-accounting contract both
  simulator engines implement (`SimResult.cycle_breakdown`);
* `repro.obs.trace` — the opt-in per-warp event tracer with Chrome
  trace-event export (``SimConfig.trace`` / `TraceSink`);
* `repro.obs.metrics` — counters/gauges/histograms backing the sweep
  service's operational telemetry (`MetricsRegistry`).

This package never imports ``repro.sim`` at module level — the simulator
imports *us*, and `trace_simulation` closes the loop lazily.
"""
from .attribution import (
    CYCLE_CATEGORIES, STALL_CATEGORIES, CycleAttributionError,
    breakdown_fractions, check_breakdown, classify_stall, merge_breakdowns,
    new_breakdown,
)
from .metrics import (
    SWEEP_METRICS, Counter, Gauge, Histogram, MetricsRegistry,
)
from .trace import SCHED_TID, TraceSink, trace_simulation

__all__ = [
    "CYCLE_CATEGORIES", "STALL_CATEGORIES", "CycleAttributionError",
    "breakdown_fractions", "check_breakdown", "classify_stall",
    "merge_breakdowns", "new_breakdown",
    "SWEEP_METRICS", "Counter", "Gauge", "Histogram", "MetricsRegistry",
    "SCHED_TID", "TraceSink", "trace_simulation",
]
