"""Process-local metrics registry: counters, gauges, histograms.

Backs the sweep service's operational telemetry (`repro.serving.sweep`):
job latency, queue wait, cache hits/misses, retries, pool recycles,
quarantines.  Two export formats:

* `MetricsRegistry.snapshot()` — a plain-JSON dict (folded into
  ``BENCH_sim.json`` meta and the ``run.py --strict`` report);
* `MetricsRegistry.to_prometheus()` — Prometheus text exposition
  (counters/gauges as samples, histograms as summaries with
  ``quantile=\"0.5|0.95|0.99\"`` plus ``_sum``/``_count``), so a scrape
  endpoint or textfile collector can ship the same numbers.

Histograms keep raw samples and compute **nearest-rank** percentiles at
snapshot time — exact, deterministic, and cheap at sweep scale (thousands
of jobs, not millions).  No locking: the sweep dispatcher records results
from its single collector thread; one registry belongs to one runner.
"""
from __future__ import annotations

import dataclasses
import json
import math
import pathlib

# Canonical sweep-service metric names (docs/observability.md documents every
# one of these; tests/test_docs.py enforces it).  The registry itself is
# generic — these are the names `SimRunner` wires up.
SWEEP_METRICS = (
    "sweep_jobs_total",
    "sweep_jobs_cached",
    "sweep_jobs_computed",
    "sweep_jobs_failed",
    "sweep_retries_total",
    "sweep_pool_recycles_total",
    "sweep_quarantined_total",
    "sweep_cache_hits_total",
    "sweep_cache_misses_total",
    "sweep_job_latency_s",
    "sweep_queue_wait_s",
)

_QUANTILES = (("p50", 0.50), ("p95", 0.95), ("p99", 0.99))


@dataclasses.dataclass
class Counter:
    """Monotonically increasing count (resets only with its registry)."""
    name: str
    help: str = ""
    value: float = 0

    def inc(self, n: float = 1) -> None:
        if n < 0:
            raise ValueError(f"counter {self.name} cannot decrease (n={n})")
        self.value += n


@dataclasses.dataclass
class Gauge:
    """A value that can go up and down (e.g. pool size, inflight jobs)."""
    name: str
    help: str = ""
    value: float = 0

    def set(self, v: float) -> None:
        self.value = v

    def inc(self, n: float = 1) -> None:
        self.value += n

    def dec(self, n: float = 1) -> None:
        self.value -= n


class Histogram:
    """Raw-sample distribution with exact nearest-rank percentiles."""

    def __init__(self, name: str, help: str = "") -> None:
        self.name = name
        self.help = help
        self.samples: list[float] = []

    def observe(self, v: float) -> None:
        self.samples.append(v)

    @staticmethod
    def _nearest_rank(sorted_samples: list[float], q: float) -> float:
        # nearest-rank: ceil(q*N)-th smallest sample (1-indexed).  The old
        # int-scaling trick (-(-int(q*n*100) // 100)) truncated q*n*100 to an
        # int *before* ceiling, so e.g. (q=0.95, n=20) -> 19 instead of 20.
        n = len(sorted_samples)
        rank = max(1, math.ceil(q * n))
        return sorted_samples[min(rank, n) - 1]

    def summary(self) -> dict:
        if not self.samples:
            return {"count": 0, "sum": 0.0}
        s = sorted(self.samples)
        out = {"count": len(s), "sum": sum(s), "min": s[0], "max": s[-1]}
        for label, q in _QUANTILES:
            out[label] = self._nearest_rank(s, q)
        return out


class MetricsRegistry:
    """Get-or-create registry; one per sweep runner."""

    def __init__(self) -> None:
        self._metrics: dict[str, Counter | Gauge | Histogram] = {}

    def _get(self, name: str, kind, help: str):
        m = self._metrics.get(name)
        if m is None:
            m = kind(name, help)
            self._metrics[name] = m
        elif not isinstance(m, kind):
            raise TypeError(f"metric {name!r} already registered as "
                            f"{type(m).__name__}, not {kind.__name__}")
        return m

    def counter(self, name: str, help: str = "") -> Counter:
        return self._get(name, Counter, help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._get(name, Gauge, help)

    def histogram(self, name: str, help: str = "") -> Histogram:
        return self._get(name, Histogram, help)

    # ------------------------------------------------------------------ export
    def snapshot(self, **meta) -> dict:
        """JSON-ready dict: scalar metrics as numbers, histograms as their
        summary dicts; ``meta`` keys (e.g. ``run_id=...``) ride along."""
        out: dict = dict(meta)
        for name in sorted(self._metrics):
            m = self._metrics[name]
            out[name] = m.summary() if isinstance(m, Histogram) else m.value

        return out

    def to_prometheus(self, **labels) -> str:
        """Prometheus text exposition (histograms as summaries)."""
        lbl = ""
        if labels:
            lbl = "{" + ",".join(f'{k}="{v}"' for k, v in sorted(labels.items())) + "}"
        lines: list[str] = []
        for name in sorted(self._metrics):
            m = self._metrics[name]
            if m.help:
                lines.append(f"# HELP {name} {m.help}")
            if isinstance(m, Histogram):
                lines.append(f"# TYPE {name} summary")
                s = m.summary()
                for label, q in _QUANTILES:
                    if label in s:
                        ql = (lbl[:-1] + "," if lbl else "{") \
                            + f'quantile="{q}"' + "}"
                        lines.append(f"{name}{ql} {s[label]:g}")
                lines.append(f"{name}_sum{lbl} {s['sum']:g}")
                lines.append(f"{name}_count{lbl} {s['count']}")
            else:
                kind = "counter" if isinstance(m, Counter) else "gauge"
                lines.append(f"# TYPE {name} {kind}")
                lines.append(f"{name}{lbl} {m.value:g}")
        return "\n".join(lines) + "\n"

    def write_snapshot(self, path, **meta) -> pathlib.Path:
        path = pathlib.Path(path)
        path.write_text(json.dumps(self.snapshot(**meta), indent=2,
                                   sort_keys=True))
        return path
