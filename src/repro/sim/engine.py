"""Discrete-event SM performance model (GPGPU-Sim stand-in).

Models one streaming multiprocessor at warp/instruction granularity with the
structures the paper evaluates:

* a banked **main register file** (MRF) with a configurable latency
  multiplier (Table 2's design points: 1x .. 6.3x) read through a limited
  pool of operand collectors — a collector is held for the full register
  read, so slow MRFs throttle issue bandwidth structurally (this is what
  makes the non-cached BL design suffer at 5.3x/6.3x);
* an optional **register file cache** (RFC, 16KB = 128 warp-registers, LRU);
* a **two-level warp scheduler** (8 active slots): a warp that *stalls on a
  value still in flight from memory* is swapped out for a ready warp
  (Gebhart'11/Narasiman'11), paying write-back + working-set refetch in the
  LTRF designs;
* LTRF's **interval prefetch** engine: a warp entering a new
  register-interval blocks until its working set streams from the MRF
  (serial bank rounds x MRF bank latency + crossbar transfer) on one of a
  small number of prefetch slots, while other active warps keep issuing;
* an L1 model (hit: short latency, no deactivation; miss: long latency,
  deactivation) with deterministic per-access jitter;
* an optional **bank-arbitration stage** (``SimConfig.bank_model``):
  operand reads and writebacks hitting the same register bank in the same
  cycle serialize, making the §4.3 renumbering ablation measurable end to
  end (``SimConfig.renumber`` switches LTRF_conf between ICG coloring and
  identity numbering).  ``bank_model="none"`` (default) stays bit-identical
  to the frozen golden engine.

The model is event-driven (idle cycles are skipped), deterministic, and
counts MRF/RFC traffic so both performance (IPC) and the paper's power-proxy
(MRF access reduction, §5.3) can be reported.

This is the *fast* engine: warp wake-ups and collector allocation go through
min-heaps, per-warp operand readiness is cached between issues, and the
compiler passes are memoized in `repro.core.plan_cache` — while staying
cycle-exact with the seed implementation.  `golden.py` preserves that
original engine; the golden-equivalence harness asserts `SimResult` equality
between the two across the full design x workload matrix.
"""
from __future__ import annotations

from collections import OrderedDict
from dataclasses import dataclass, field
from heapq import heappop, heappush, heapreplace

from repro.core.pipeline import INTERVAL_STRATEGIES, parse_interval_strategy
from repro.core.plan_cache import compile_for_sim
from repro.core.ir import Instr, Program
from repro.obs.attribution import (
    check_breakdown, classify_stall, new_breakdown,
)
from repro.obs.trace import SCHED_TID, TraceSink
from repro.workloads.suite import Workload

DESIGNS = ("BL", "RFC", "SHRF", "LTRF", "LTRF_conf", "LTRF_plus", "Ideal")

# Bump whenever SimResult counters intentionally change: it keys the on-disk
# sim cache (benchmarks.orchestrator), so stale artifacts never replay across
# engine-behavior revisions.
# rev 2: bank_model/renumber config axes + bank-conflict counters.
# rev 3: interval_strategy config axis + prefetch_stall_cycles counter.
# rev 4: cycle_breakdown attribution (repro.obs) carried on every result.
ENGINE_REV = 4

# Designs with a software-managed register cache (two-level scheduling).
_CACHED_DESIGNS = frozenset({"LTRF", "LTRF_conf", "LTRF_plus", "SHRF"})
# Designs that prefetch the next interval at block edges.
_EDGE_PREFETCH = frozenset({"LTRF", "LTRF_conf", "SHRF"})

# Warp-scheduler policies (see repro.sim.gpu for the policy table):
#   two_level - the paper's scheduler: `active_slots` active warps, L1-miss
#               stalls swap the warp out (write-back + re-prefetch when cached)
#   gto       - greedy-then-oldest over all resident warps, no deactivation
#   lrr       - loose round-robin over all resident warps, no deactivation
SCHEDULERS = ("two_level", "gto", "lrr")

# Register-file bank-arbitration models (``SimConfig.bank_model``):
#   none       - banks only serialize interval prefetches (the seed behavior;
#                bit-identical to the frozen golden engine)
#   arbitrated - operand reads and writebacks that hit the same bank in the
#                same cycle serialize too (§4.3); extra rounds are charged at
#                the design's read/write target latency and counted in
#                SimResult.bank_conflicts / bank_conflict_cycles.  The Ideal
#                design is exempt (it is the no-structural-limits bound).
BANK_MODELS = ("none", "arbitrated")

# Renumbering modes (``SimConfig.renumber``) — the §4 ablation axis:
#   icg      - the paper's pipeline: ICG coloring + bank-aware renumbering
#              (only LTRF_conf renumbers; the golden engine implements this)
#   identity - skip the coloring pass: LTRF_conf keeps the original register
#              numbers, exposing the bank conflicts renumbering would remove
RENUMBER_MODES = ("icg", "identity")

# Interval-formation strategies (``SimConfig.interval_strategy``), resolved
# by the compiler pass pipeline (repro.core.pipeline):
#   paper      - Algorithms 1+2 (the default; golden-pinned bit-identical)
#   capacity   - the paper's algorithm with the working-set cap clamped to
#                the design's RFC entries-per-warp, so prefetch rounds can
#                never overflow the register cache
#   fixed:N    - naive fixed-length (<= N instructions) intervals
# The knob only affects the interval-prefetching designs (LTRF family);
# SHRF always uses strands, BL/RFC/Ideal compile no intervals at all.
# INTERVAL_STRATEGIES lists the base names.


@dataclass(frozen=True)
class SimConfig:
    design: str = "BL"
    mrf_latency_mult: float = 1.0
    rf_size_kb: int = 256          # main register file capacity
    rfc_size_kb: int = 16          # register file cache capacity
    add_rfc_to_main: bool = False  # §6: BL gets the RFC's 16KB added to MRF
    num_warps: int = 64            # total warp contexts worth of work
    active_slots: int = 8
    issue_width: int = 3
    num_banks: int = 16
    interval_cap: int = 16         # registers allowed per register-interval
    base_rf_cycles: int = 4        # MRF bank access at 1x
    rfc_cycles: int = 1
    alu_cycles: int = 3
    mem_cycles: int = 380          # L1-miss latency (average)
    l1_cycles: int = 8             # L1-hit latency
    l1_hit_rate: float = 0.85
    num_collectors: int = 32       # operand collectors shared by the SM
    xbar_regs_per_cycle: int = 8   # prefetch crossbar bandwidth (1024-bit)
    max_inflight_prefetch: int = 12
    dram_interval: int = 4         # cycles between DRAM line services (bw/SM)
    seed: int = 0
    max_cycles: int = 0            # cycle-budget watchdog: a simulation that
                                   # passes this cycle raises SimBudgetExceeded
                                   # (0 = unlimited).  Never changes the
                                   # counters of a run that completes, so the
                                   # sweep cache (serving.sweep.sim_key)
                                   # deliberately excludes it.
    scheduler: str = "two_level"   # warp-scheduler policy (SCHEDULERS)
    num_sms: int = 1               # SMs on the chip; >1 via repro.sim.gpu
    mem_partitions: int = 0        # DRAM partitions feeding the SMs
                                   # (0 = one per SM, i.e. uncontended)
    bank_model: str = "none"       # RF bank arbitration (BANK_MODELS)
    renumber: str = "icg"          # renumbering ablation axis (RENUMBER_MODES)
    interval_strategy: str = "paper"  # interval formation (INTERVAL_STRATEGIES)
    trace: bool = False            # opt-in per-warp event tracer (repro.obs.
                                   # trace): records issue/stall/prefetch/swap
                                   # events on Simulator.trace for Chrome
                                   # trace-event export.  Pure observation —
                                   # never changes counters — so the sweep
                                   # cache (serving.sweep.sim_key) excludes it
                                   # like max_cycles.

    @property
    def mrf_cycles(self) -> float:
        return self.base_rf_cycles * self.mrf_latency_mult

    @property
    def rfc_entries(self) -> int:
        return self.rfc_size_kb * 1024 // 128  # 1024-bit warp registers

    @property
    def rfc_entries_per_warp(self) -> int:
        """Register-cache entries one active warp can claim — the bound the
        ``capacity`` interval strategy clamps working sets to."""
        return self.rfc_entries // max(self.active_slots, 1)


@dataclass
class SimResult:
    design: str
    workload: str
    cycles: int
    instructions: int
    resident_warps: int
    rfc_hits: int = 0
    rfc_accesses: int = 0
    mrf_accesses: int = 0
    prefetch_ops: int = 0
    prefetch_cycles: int = 0
    prefetch_stall_cycles: int = 0  # cycles warps spent blocked on an
                                    # in-flight interval prefetch (queueing
                                    # for a prefetch slot + the fetch itself)
    writeback_regs: int = 0
    activations: int = 0
    bank_conflicts: int = 0        # extra serialization rounds (arbitrated)
    bank_conflict_cycles: int = 0  # latency cycles those rounds added
    cycle_breakdown: dict[str, int] = field(default_factory=dict)
    # ^ where every cycle went: one entry per repro.obs.attribution category
    #   (issue/alu_dep/mem_stall/prefetch_stall/bank_conflict/scheduler_idle/
    #   drain); both engines enforce sum(cycle_breakdown.values()) == cycles.

    @property
    def ipc(self) -> float:
        return self.instructions / max(self.cycles, 1)

    @property
    def hit_rate(self) -> float:
        return self.rfc_hits / max(self.rfc_accesses, 1)

    @property
    def bank_conflict_rate(self) -> float:
        """Extra bank-serialization rounds per retired instruction."""
        return self.bank_conflicts / max(self.instructions, 1)


class SimBudgetExceeded(RuntimeError):
    """A simulation ran past its ``SimConfig.max_cycles`` budget.

    Structured (design/workload/budget/cycles attributes) and raised at the
    same simulated cycle by both the fast engine and the golden oracle (the
    watchdog sits at the identical point of both run loops), so the sweep
    service can classify runaway configs deterministically.  Args are passed
    positionally to ``RuntimeError`` so the exception survives pickling
    across process-pool workers."""

    def __init__(self, design: str, workload: str,
                 budget: int, cycles: int) -> None:
        super().__init__(design, workload, budget, cycles)
        self.design = design
        self.workload = workload
        self.budget = budget
        self.cycles = cycles

    def __str__(self) -> str:
        return (f"{self.workload}/{self.design}: simulation exceeded "
                f"max_cycles={self.budget} (reached cycle {self.cycles})")


ACTIVE, INACTIVE_READY, INACTIVE_WAIT, PREFETCH, DONE = range(5)


@dataclass
class _Warp:
    wid: int
    block: str
    idx: int = 0
    status: int = INACTIVE_READY
    ready_at: int = 0
    reg_ready: dict[int, float] = field(default_factory=dict)
    reg_from_mem: dict[int, bool] = field(default_factory=dict)
    pred_ready: dict[int, float] = field(default_factory=dict)
    loop_counters: dict[str, int] = field(default_factory=dict)
    diamond_visits: dict[tuple[str, int], int] = field(default_factory=dict)
    interval: int = -1
    issued: int = 0
    mem_ops: int = 0
    # Operand-readiness cache: a warp's register/predicate state only changes
    # when IT issues (or its prefetch lands), so the current instruction's
    # readiness is computed once per issue instead of once per scheduler scan.
    ver: int = 0                   # bumped whenever reg/pred state or PC moves
    c_ver: int = -1                # ver the cache below was computed at
    c_ins: Instr | None = None     # current instruction
    c_maxrdy: float = 0.0          # cycle at which all operands are ready
    c_times: tuple = ()            # pending operand-ready times (for events)
    c_mem: tuple = ()              # pending times of memory-produced operands


class Simulator:
    def __init__(self, cfg: SimConfig, workload: Workload) -> None:
        if cfg.num_sms != 1:
            raise ValueError(
                f"Simulator models one SM (num_sms={cfg.num_sms}); "
                "use repro.sim.gpu.simulate_gpu for whole-GPU runs")
        if cfg.scheduler not in SCHEDULERS:
            raise ValueError(
                f"unknown scheduler {cfg.scheduler!r}; one of {SCHEDULERS}")
        if cfg.bank_model not in BANK_MODELS:
            raise ValueError(
                f"unknown bank_model {cfg.bank_model!r}; one of {BANK_MODELS}")
        if cfg.renumber not in RENUMBER_MODES:
            raise ValueError(
                f"unknown renumber mode {cfg.renumber!r}; "
                f"one of {RENUMBER_MODES}")
        parse_interval_strategy(cfg.interval_strategy)  # raises on junk
        self.cfg = cfg
        self.w = workload
        plan = compile_for_sim(workload.program, cfg.design,
                               cfg.interval_cap, cfg.num_banks,
                               renumber=cfg.renumber,
                               interval_strategy=cfg.interval_strategy,
                               rfc_per_warp=cfg.rfc_entries_per_warp)
        self.prog: Program = plan.prog
        self.block_interval = plan.block_interval
        self.pf_ops = plan.pf_ops
        self.live_sets = plan.live_sets
        self._plus_fetch = plan.plus_fetch
        self.result = SimResult(design=cfg.design, workload=workload.name,
                                cycles=0, instructions=0,
                                resident_warps=self._occupancy())
        self._order_index = plan.order_index
        self._dram_next = 0
        # Hot-loop constants (avoid per-access property/str dispatch).
        self._mrf_cyc = cfg.mrf_cycles
        self._rfc_cyc = float(cfg.rfc_cycles)
        self._mem_thresh = 2 * cfg.l1_cycles
        self._l1_hit = getattr(workload, "l1_hit", cfg.l1_hit_rate)
        self._edge_prefetch = cfg.design in _EDGE_PREFETCH
        self._is_plus = cfg.design == "LTRF_plus"
        # writeback latency is design-static (see seed `_write_latency`)
        if cfg.design == "Ideal":
            self._wlat = cfg.base_rf_cycles
        elif cfg.design == "BL":
            self._wlat = cfg.mrf_cycles
        else:
            self._wlat = float(cfg.rfc_cycles)
        # per-instruction operand metadata: (n_accesses, combined reg tuple)
        meta: dict[int, tuple[int, tuple[int, ...]]] = {}
        for _, _, ins in self.prog.instructions():
            regs = tuple(ins.srcs) + tuple(ins.dsts)
            meta[id(ins)] = (len(regs), regs)
        self._instr_meta = meta
        self._done_dirty = False
        self._stall_pure = True
        self._sched = cfg.scheduler
        self._gto_last = -1
        # Bank arbitration (bank_model="arbitrated"): per-cycle read/write
        # port usage per bank.  Ideal is exempt — it is the design with no
        # structural register-file limits, the paper's upper bound.
        self._arb = cfg.bank_model == "arbitrated" and cfg.design != "Ideal"
        self._instr_banks = plan.instr_banks
        self._read_from_mrf = False     # set per issue by _operand_latency
        self._arb_wb_unit = cfg.base_rf_cycles if cfg.design == "BL" \
            else cfg.rfc_cycles
        self._bank_cycle = -1
        self._rd_use: list[int] = []
        self._wr_use: list[int] = []
        # Opt-in event tracer (None = disabled: the hot loop pays one `is
        # not None` test per hook and nothing else).
        self.trace: TraceSink | None = TraceSink() if cfg.trace else None

    # ------------------------------------------------------------------ static
    def _occupancy(self) -> int:
        cfg = self.cfg
        cap_kb = cfg.rf_size_kb + (cfg.rfc_size_kb if cfg.add_rfc_to_main else 0)
        warp_regs_capacity = cap_kb * 1024 // 128
        per_warp = max(self.w.regs_per_thread, 1)
        return max(1, min(cfg.num_warps, warp_regs_capacity // per_warp))

    # ----------------------------------------------------------------- dynamic
    def run(self) -> SimResult:
        cfg = self.cfg
        res = self.result
        cached = cfg.design in _CACHED_DESIGNS
        # RFC is a plain hardware cache shared by ALL resident warps -- the
        # paper's Fig. 4 thrashing story (8-30% hit rate) requires the full
        # warp population to contend for the 128 entries.
        # Only the two_level policy restricts issue to `active_slots` warps
        # and swaps out memory-stalled warps; gto/lrr schedule over the whole
        # resident population (prefetch still runs on activation/interval
        # edges for the cached designs, but there is no deactivation churn).
        two_level = cached and self._sched == "two_level"
        use_gto = self._sched == "gto"
        resident_cap = res.resident_warps
        active_cap = min(cfg.active_slots, resident_cap) if two_level else resident_cap
        # Kernel-tail threshold for cycle attribution: once retirement leaves
        # fewer live warps than one scheduler's worth (`active_slots`),
        # zero-issue cycles are the unavoidable drain of the last warps, not
        # a latency-tolerance failure (same for every scheduler policy).
        tail_cap = min(cfg.active_slots, resident_cap)

        warps = [_Warp(wid=i, block=self.prog.entry) for i in range(cfg.num_warps)]
        pending = list(range(cfg.num_warps))
        pending_pos = 0  # head of the admit queue (avoids O(n) pop(0))
        resident: list[int] = []   # stays sorted ascending by wid
        active: list[int] = []
        self._pf_free = [0] * cfg.max_inflight_prefetch   # min-heap
        self._col_free = [0] * cfg.num_collectors         # min-heap
        # MRF bank throughput: slow cells (DWM shift, TFET) pipeline only
        # partially (sub-banked arrays, depth ~6), so aggregate MRF bandwidth
        # is num_banks / (initiation interval = latency/6) accesses per cycle.
        self._mrf_rate = cfg.num_banks / max(cfg.mrf_cycles / 6.0, 1.0)
        self._mrf_tokens = float(cfg.num_banks)
        self._mrf_last = 0
        rfc_lru: OrderedDict[tuple[int, int], None] = OrderedDict()

        # Event structures: `wake` holds (ready_at, wid) for warps that left
        # the active set (INACTIVE_WAIT) or are mid-prefetch (PREFETCH);
        # `ready_q` holds INACTIVE_READY resident warps.  Because `resident`
        # is always ascending by wid, the seed's "first ready resident warp"
        # is exactly the ready_q minimum.
        wake: list[tuple[int, int]] = []
        ready_q: list[int] = []
        self._wake = wake

        def admit() -> None:
            nonlocal pending_pos
            while pending_pos < len(pending) and len(resident) < resident_cap:
                wid = pending[pending_pos]
                pending_pos += 1
                resident.append(wid)
                heappush(ready_q, wid)

        trace = self.trace

        def activate(cycle: int) -> None:
            while len(active) < active_cap:
                while ready_q and warps[ready_q[0]].status != INACTIVE_READY:
                    heappop(ready_q)  # stale entry
                if not ready_q:
                    break
                wid = heappop(ready_q)
                wp = warps[wid]
                res.activations += 1
                if trace is not None:
                    trace.instant(wid, "activate", cycle)
                if cached:
                    self._start_prefetch(wp, cycle, force=True)
                active.append(wid)
                if wp.status != PREFETCH:
                    wp.status = ACTIVE

        def deactivate(wid: int, until: float, cycle: int) -> None:
            wp = warps[wid]
            active.remove(wid)
            wp.status = INACTIVE_WAIT
            wp.ready_at = int(until)
            if trace is not None:
                trace.instant(wid, "swap_out", cycle,
                              {"until": wp.ready_at})
            heappush(wake, (wp.ready_at, wid))
            if cached and wp.interval >= 0:
                ws = self.pf_ops.get(wp.interval)
                if ws is not None:
                    n_wb = len(self.live_sets.get(wp.interval, ws.bitvector)) \
                        if self._is_plus else len(ws.bitvector)
                    res.writeback_regs += n_wb
                    res.mrf_accesses += n_wb
            wp.interval = -1  # must re-prefetch on activation
            activate(cycle)

        admit()
        activate(0)

        issue_width = cfg.issue_width
        max_cycles = cfg.max_cycles
        # Cycle attribution (repro.obs.attribution): the loop below advances
        # `cycle` at exactly two sites — +1 after an issuing cycle, or a jump
        # to the next event after a zero-issue cycle — and every advance is
        # charged to exactly one category, so the breakdown sums to the final
        # cycle count by construction (and is hard-checked at the end).
        bd = res.cycle_breakdown = new_breakdown()
        cycle = 0
        guard = 0
        while True:
            guard += 1
            if guard > 8_000_000:
                raise RuntimeError("simulator wedged")
            if max_cycles and cycle > max_cycles:
                raise SimBudgetExceeded(cfg.design, self.w.name,
                                        max_cycles, cycle)

            while wake and wake[0][0] <= cycle:
                _, wid = heappop(wake)
                wp = warps[wid]
                if wp.ready_at > cycle:
                    continue  # stale: warp re-entered a wait with a later deadline
                if wp.status == INACTIVE_WAIT:
                    wp.status = INACTIVE_READY
                    heappush(ready_q, wid)
                elif wp.status == PREFETCH:
                    wp.status = ACTIVE
            activate(cycle)

            issued_now = 0
            struct_stall = False
            mem_stalled: list[tuple[int, float]] = []
            for _ in range(issue_width):
                wid = (self._pick_gto(warps, active, cycle) if use_gto else
                       self._pick(warps, active, cycle, mem_stalled, two_level))
                if wid is None:
                    break
                if self._issue(warps[wid], cycle, rfc_lru):
                    issued_now += 1
                    if use_gto:
                        self._gto_last = wid
                else:
                    # a ready warp blocked by RF structure (collector / MRF
                    # bandwidth): remembered for cycle attribution
                    struct_stall = True
                    if self._stall_pure:
                        # Pure structural stall: the failed issue consumed
                        # nothing, so the seed's remaining issue slots would
                        # re-pick this same warp and fail identically.  (A
                        # collector stall that already consumed MRF bandwidth
                        # tokens is NOT pure — the retry must run, token state
                        # changed.)
                        break

            if two_level:
                for wid, until in mem_stalled:
                    if warps[wid].status == ACTIVE and wid in active:
                        deactivate(wid, until, cycle)

            if self._done_dirty:
                self._done_dirty = False
                for wid in list(active):
                    if warps[wid].status == DONE:
                        active.remove(wid)
                        resident.remove(wid)
                        admit()
                        activate(cycle)
            if not resident and pending_pos >= len(pending):
                break

            if issued_now:
                bd["issue"] += 1
                cycle += 1
            else:
                drain = (pending_pos >= len(pending)
                         and len(resident) < tail_cap)
                cat = self._classify_stall(warps, active, cycle,
                                           struct_stall, drain)
                nxt = self._next_event(warps, active, cycle)
                bd[cat] += nxt - cycle
                if trace is not None:
                    trace.span(SCHED_TID, cat, cycle, nxt - cycle)
                cycle = nxt

        res.cycles = cycle
        res.instructions = sum(w.issued for w in warps)
        check_breakdown(bd, cycle, cfg.design, self.w.name)
        return res

    # ----------------------------------------------------------------- helpers
    def _start_prefetch(self, wp: _Warp, cycle: int, force: bool = False) -> None:
        cfg = self.cfg
        iid = self.block_interval.get(wp.block, -1)
        if iid < 0:
            return
        if not force and iid == wp.interval:
            return
        op = self.pf_ops.get(iid)
        wp.interval = iid
        if op is None or not op.bitvector:
            return
        fetch = op.bitvector
        rounds = op.serial_rounds
        if self._is_plus:
            # fetch only the live subset (dead entries: space, no data)
            ent = self._plus_fetch.get(iid)
            if ent is not None:
                fetch, rounds = ent
                if not fetch:
                    return
        if self._arb and rounds > 1:
            # prefetch bank serialization is already charged in the latency
            # below (it predates the arbitration model); under the arbitrated
            # model it is also *counted*, so the renumbering ablation sees
            # every conflict source in one pair of counters.
            self.result.bank_conflicts += rounds - 1
            self.result.bank_conflict_cycles += int((rounds - 1) * self._mrf_cyc)
        lat = rounds * self._mrf_cyc \
            + len(fetch) / cfg.xbar_regs_per_cycle
        pf = self._pf_free
        start = pf[0]
        if start < cycle:
            start = cycle
        done = int(start + lat)
        heapreplace(pf, done)
        wp.status = PREFETCH
        wp.ready_at = done
        if self.trace is not None:
            self.trace.span(wp.wid, "prefetch", cycle, done - cycle,
                            {"interval": iid, "regs": len(fetch),
                             "rounds": rounds})
        heappush(self._wake, (done, wp.wid))
        self.result.prefetch_ops += 1
        self.result.prefetch_cycles += int(lat)
        # the warp is blocked from issue until the prefetch lands (including
        # any wait for a free prefetch slot)
        self.result.prefetch_stall_cycles += done - cycle
        self.result.mrf_accesses += len(fetch)
        reg_ready = wp.reg_ready
        for r in op.bitvector:
            t = reg_ready.get(r, 0)
            reg_ready[r] = done if done > t else t
        wp.ver += 1

    def _refresh_ready(self, wp: _Warp, ins: Instr) -> None:
        """Recompute the warp's operand-readiness cache for ``ins``."""
        reg_ready = wp.reg_ready
        from_mem = wp.reg_from_mem
        maxr = 0.0
        times = []
        mem = []
        for s in ins.srcs:
            t = reg_ready.get(s, 0)
            if t:
                times.append(t)
                if t > maxr:
                    maxr = t
                if from_mem.get(s):
                    mem.append(t)
        if ins.psrcs:
            pred_ready = wp.pred_ready
            for p in ins.psrcs:
                t = pred_ready.get(p, 0)
                if t:
                    times.append(t)
                    if t > maxr:
                        maxr = t
        wp.c_ins = ins
        wp.c_maxrdy = maxr
        wp.c_times = times
        wp.c_mem = mem
        wp.c_ver = wp.ver

    def _pick(self, warps, active, cycle, mem_stalled, track_mem=True):
        """Round-robin over active warps; also reports warps stalled on
        memory-produced values (two-level deactivation candidates —
        ``track_mem`` is False for single-level designs, which ignore them)."""
        n = len(active)
        if not n:
            return None
        start = cycle % n
        thresh = self._mem_thresh
        for k in range(n):
            i = start + k
            if i >= n:
                i -= n
            wid = active[i]
            wp = warps[wid]
            if wp.status != ACTIVE:
                continue
            if wp.c_ver == wp.ver:
                ins = wp.c_ins
            else:
                ins = self._fetch(wp)
                if ins is None:
                    wp.status = DONE
                    self._done_dirty = True
                    continue
                self._refresh_ready(wp, ins)
            if wp.c_maxrdy <= cycle:
                return wid
            if not track_mem:
                continue
            # only a *long-latency* (L1-miss) wait justifies swapping the
            # warp out of the active set
            blocked = 0.0
            for t in wp.c_mem:
                if t > cycle and t - cycle > thresh and t > blocked:
                    blocked = t
            if blocked:
                mem_stalled.append((wid, blocked))
        return None

    def _pick_gto(self, warps, active, cycle):
        """Greedy-then-oldest: keep issuing from the warp that issued last;
        when it can't, fall back to the oldest ready warp (lowest wid —
        ``active`` is filled in admission order and only shrinks, so it is
        ascending by wid whenever this policy is selected)."""
        last = self._gto_last
        if 0 <= last and warps[last].status == ACTIVE:
            order = [last]
            order.extend(active)
        else:
            order = active
        for wid in order:
            wp = warps[wid]
            if wp.status != ACTIVE:
                continue
            if wp.c_ver == wp.ver:
                ins = wp.c_ins
            else:
                ins = self._fetch(wp)
                if ins is None:
                    wp.status = DONE
                    self._done_dirty = True
                    continue
                self._refresh_ready(wp, ins)
            if wp.c_maxrdy <= cycle:
                return wid
        return None

    def _fetch(self, wp: _Warp) -> Instr | None:
        blocks = self.prog.blocks
        bb = blocks[wp.block]
        while wp.idx >= len(bb.instrs):
            i = self._order_index[wp.block]
            if i + 1 >= len(self.prog.order):
                return None
            wp.block = self.prog.order[i + 1]
            wp.idx = 0
            bb = blocks[wp.block]
        return bb.instrs[wp.idx]

    def _mrf_bandwidth(self, cycle: int, n: int) -> bool:
        """Consume ``n`` MRF bank slots; False => structural stall."""
        cfg = self.cfg
        if cycle > self._mrf_last:
            self._mrf_tokens = min(
                float(cfg.num_banks),
                self._mrf_tokens + self._mrf_rate * (cycle - self._mrf_last))
            self._mrf_last = cycle
        if self._mrf_tokens < n:
            return False
        self._mrf_tokens -= n
        return True

    def _mrf_next_free(self, cycle: int, n: int = 1) -> int:
        deficit = max(0.0, n - self._mrf_tokens)
        return cycle + max(1, int(deficit / self._mrf_rate))

    def _grab_collector(self, cycle: int) -> bool:
        # banks are pipelined: a collector is held for the *gather* time (a
        # few cycles), not the full access latency — latency shows up in the
        # dependency chain (read + execute + writeback), not as a hard
        # throughput ceiling.
        cf = self._col_free
        if cf[0] > cycle:
            return False
        heapreplace(cf, cycle + self.cfg.base_rf_cycles)
        return True

    def _operand_latency(self, wp: _Warp, ins: Instr, rfc_lru, cycle: int) -> float | None:
        """Register read latency; None => structural stall (no collector).

        On a stall, ``self._stall_pure`` records whether the attempt consumed
        any state: a bandwidth stall consumes nothing (pure), but a collector
        stall after a successful bandwidth check has already deducted MRF
        tokens — the seed's retry of such an issue is NOT a no-op."""
        cfg = self.cfg
        design = cfg.design
        res = self.result
        if design == "Ideal":
            if not self._grab_collector(cycle):
                self._stall_pure = True
                return None
            return cfg.base_rf_cycles
        if design == "BL":
            n_acc = self._instr_meta[id(ins)][0]
            if n_acc and not self._mrf_bandwidth(cycle, n_acc):
                self._stall_pure = True
                return None
            if not self._grab_collector(cycle):
                self._stall_pure = n_acc == 0
                return None
            res.mrf_accesses += n_acc
            self._read_from_mrf = True
            return self._mrf_cyc
        if design == "RFC":
            n_acc, regs = self._instr_meta[id(ins)]
            wid = wp.wid
            misses = 0
            hits = []
            for r in regs:
                key = (wid, r)
                if key in rfc_lru:
                    hits.append(key)
                else:
                    misses += 1
            if misses and not self._mrf_bandwidth(cycle, misses):
                self._stall_pure = True
                return None
            if not self._grab_collector(cycle):
                self._stall_pure = misses == 0
                return None
            res.rfc_accesses += n_acc
            res.rfc_hits += len(hits)
            res.mrf_accesses += misses
            for key in hits:
                rfc_lru.move_to_end(key)
            entries = cfg.rfc_entries
            for r in regs:
                key = (wid, r)
                if key not in rfc_lru:
                    rfc_lru[key] = None
                    if len(rfc_lru) > entries:
                        rfc_lru.popitem(last=False)
            self._read_from_mrf = misses > 0
            return self._mrf_cyc if misses else self._rfc_cyc
        # LTRF-family: every in-interval access hits the register cache
        if not self._grab_collector(cycle):
            self._stall_pure = True
            return None
        n_acc = self._instr_meta[id(ins)][0]
        res.rfc_accesses += n_acc
        res.rfc_hits += n_acc
        self._read_from_mrf = False
        return self._rfc_cyc

    def _bank_arbitrate(self, ins: Instr, cycle: int) -> tuple[int, int]:
        """(extra read rounds, extra writeback rounds) from same-cycle
        same-bank contention, under ``bank_model="arbitrated"``.

        Per-cycle per-bank access counters model each bank's single read and
        single write port: the k-th access to a bank within a cycle waits k
        extra serialization rounds, and an instruction is held up by its
        worst operand (ports pipeline across *different* banks for free)."""
        if cycle != self._bank_cycle:
            self._bank_cycle = cycle
            n = self.cfg.num_banks
            self._rd_use = [0] * n
            self._wr_use = [0] * n
        src_banks, dst_banks = self._instr_banks[id(ins)]
        rd_extra = 0
        use = self._rd_use
        for b in src_banks:
            pos = use[b]
            use[b] = pos + 1
            if pos > rd_extra:
                rd_extra = pos
        wr_extra = 0
        use = self._wr_use
        for b in dst_banks:
            pos = use[b]
            use[b] = pos + 1
            if pos > wr_extra:
                wr_extra = pos
        return rd_extra, wr_extra

    def _mem_latency(self, wp: _Warp, cycle: int) -> tuple[int, bool]:
        """(latency, is_l1_miss) with deterministic jitter + DRAM queuing.

        Misses are serviced by a single-server DRAM queue (one cache line per
        ``dram_interval`` cycles per SM): memory-heavy kernels saturate DRAM
        bandwidth regardless of TLP — which is exactly why the paper's
        register-insensitive workloads gain nothing from bigger register
        files."""
        cfg = self.cfg
        h = (wp.wid * 2654435761 + wp.mem_ops * 40503 + cfg.seed * 97) & 0xFFFF
        wp.mem_ops += 1
        if (h / 0xFFFF) < self._l1_hit:
            return cfg.l1_cycles, False
        spread = ((h >> 3) / 0x1FFF - 0.5) * 0.6
        start = max(cycle, self._dram_next)
        self._dram_next = start + cfg.dram_interval
        queue = start - cycle
        return int(queue + cfg.mem_cycles * (1.0 + spread)), True

    def _issue(self, wp: _Warp, cycle: int, rfc_lru) -> bool:
        """Issue the warp's next instruction. Returns True if issued."""
        cfg = self.cfg
        ins = wp.c_ins if wp.c_ver == wp.ver else self._fetch(wp)
        assert ins is not None and wp.status == ACTIVE

        if ins.op == "bra":
            wp.issued += 1
            wp.ver += 1
            if self.trace is not None:
                self.trace.span(wp.wid, "bra", cycle, 1)
            if self._branch_taken(wp, ins):
                wp.block, wp.idx = ins.target, 0
            else:
                wp.idx += 1
            self._maybe_prefetch_edge(wp, cycle)
            return True
        if ins.op == "exit":
            wp.issued += 1
            wp.ver += 1
            wp.status = DONE
            self._done_dirty = True
            if self.trace is not None:
                self.trace.span(wp.wid, "exit", cycle, 1)
            return True

        read_lat = self._operand_latency(wp, ins, rfc_lru, cycle)
        if read_lat is None:
            return False  # structural stall: collectors busy
        wp.issued += 1
        wp.ver += 1
        done_at = cycle + read_lat
        wlat = self._wlat
        if self._arb:
            rd_extra, wr_extra = self._bank_arbitrate(ins, cycle)
            res = self.result
            if rd_extra:
                # extra rounds re-access the bank at its nominal cell latency:
                # the design's read target (MRF at base_rf_cycles, RFC/LTRF
                # register cache at rfc_cycles)
                pen = rd_extra * (cfg.base_rf_cycles if self._read_from_mrf
                                  else cfg.rfc_cycles)
                done_at += pen
                res.bank_conflicts += rd_extra
                res.bank_conflict_cycles += pen
            if wr_extra:
                pen = wr_extra * self._arb_wb_unit
                wlat = wlat + pen
                res.bank_conflicts += wr_extra
                res.bank_conflict_cycles += pen
            if self.trace is not None and (rd_extra or wr_extra):
                self.trace.instant(wp.wid, "bank_conflict", cycle,
                                   {"rd_rounds": rd_extra,
                                    "wr_rounds": wr_extra})
        if ins.op == "set":
            done_at += cfg.alu_cycles
            if ins.pdst is not None:
                wp.pred_ready[ins.pdst] = done_at  # predicates live in the scoreboard
        elif ins.op == "ld":
            lat, _miss = self._mem_latency(wp, cycle)
            done_at += lat + wlat
            for d in ins.dsts:
                wp.reg_ready[d] = done_at
                wp.reg_from_mem[d] = True
        else:
            done_at += cfg.alu_cycles + wlat
            for d in ins.dsts:
                wp.reg_ready[d] = done_at
                wp.reg_from_mem[d] = False
        if self.trace is not None:
            self.trace.span(wp.wid, ins.op, cycle, int(done_at) - cycle,
                            {"block": wp.block})
        wp.idx += 1
        self._maybe_prefetch_edge(wp, cycle)
        return True

    def _maybe_prefetch_edge(self, wp: _Warp, cycle: int) -> None:
        if not self._edge_prefetch:
            return
        if wp.status != ACTIVE:
            return
        if self._fetch(wp) is None:
            return
        iid = self.block_interval.get(wp.block, -1)
        if iid >= 0 and iid != wp.interval:
            self._start_prefetch(wp, cycle)

    def _branch_taken(self, wp: _Warp, ins: Instr) -> bool:
        if not ins.psrcs:
            return True
        target = ins.target
        trips = self.w.trips.get(target)
        if trips is not None:
            c = wp.loop_counters.get(target, 0) + 1
            if c < trips:
                wp.loop_counters[target] = c
                return True
            wp.loop_counters[target] = 0
            return False
        key = (wp.block, wp.idx)
        v = wp.diamond_visits.get(key, 0)
        wp.diamond_visits[key] = v + 1
        h = (wp.wid * 31 + v * 17 + self.cfg.seed) & 0xFF
        return bool(h & 1)

    def _classify_stall(self, warps, active, cycle: int,
                        struct_stall: bool, drain: bool) -> str:
        """Attribute one zero-issue cycle (see repro.obs.attribution).

        Scans the active set for the observable stall causes and defers the
        precedence decision to `classify_stall`, which the golden oracle
        calls with identically-derived booleans — attribution is part of the
        bit-identical `SimResult` contract.  Reading a warp's pending
        operands may refresh its readiness cache via `_fetch` (the same
        idempotent block-walk `_next_event` performs); it never changes
        schedulable state.
        """
        if drain or struct_stall:
            return classify_stall(drain, struct_stall, False, False, False)
        saw_prefetch = saw_mem = saw_dep = False
        for wid in active:
            wp = warps[wid]
            st = wp.status
            if st == PREFETCH:
                saw_prefetch = True
            elif st == ACTIVE:
                if wp.c_ver != wp.ver:
                    ins = self._fetch(wp)
                    if ins is None:
                        continue
                    self._refresh_ready(wp, ins)
                for t in wp.c_mem:
                    if t > cycle:
                        saw_mem = True
                        break
                if not saw_dep:
                    for t in wp.c_times:
                        if t > cycle:
                            saw_dep = True
                            break
        return classify_stall(False, False, saw_prefetch, saw_mem, saw_dep)

    def _next_event(self, warps, active, cycle: int) -> int:
        """Earliest future time anything can change state.

        Candidates: the next collector release, the next warp wake-up
        (deactivation deadline / prefetch completion, via the wake heap), and
        the earliest pending operand of any active warp (via the per-warp
        readiness cache).  Matches the seed engine's full-scan result.
        """
        best = 0.0
        m = self._col_free[0]
        if m > cycle:
            best = m
        wake = self._wake
        if wake:
            t = wake[0][0]
            if t > cycle and (not best or t < best):
                best = t
        for wid in active:
            wp = warps[wid]
            if wp.status != ACTIVE:
                continue
            if wp.c_ver != wp.ver:
                ins = self._fetch(wp)
                if ins is None:
                    continue
                self._refresh_ready(wp, ins)
            for t in wp.c_times:
                if t > cycle and (not best or t < best):
                    best = t
        if not best:
            return cycle + 1
        nxt = int(best)
        return nxt if nxt > cycle else cycle + 1


def simulate(workload: Workload, cfg: SimConfig) -> SimResult:
    return Simulator(cfg, workload).run()
