"""Vectorized batch simulation engine: many independent sims in lockstep.

The scalar engines (`engine.py` event-heap, `golden.py` oracle) spend ~10us
of Python per retired instruction — the bottleneck for every sweep the
orchestrator runs.  This module restructures the *same* discrete-event tick
into a masked, functional step over arrays indexed ``(lane, warp)``: one
step advances a whole batch of independent simulations (per-SM shards,
sweep job lists) together, and the entire run loop executes as a single
jitted ``lax.while_loop`` — no Python in the hot path at all.

Correctness contract (same discipline as the event-heap engine, PR 1):
``golden.py`` stays frozen, and for every supported config the batch engine
produces **bit-identical** `SimResult`s — every counter and the full
`cycle_breakdown` — to the golden/event engines.  The differential fuzz
harness (`tests/test_sim_fuzz.py`) extends to batch-vs-golden, and the
Listing-1 pins go through the batch path too.

Supported domain (`batch_supported`): the paper's two-level scheduler,
``bank_model="none"``, untraced, single-SM configs — i.e. exactly the
tracked fast-path sweep.  Any design, any interval strategy, any renumber
mode (those are compile-side: the batch engine consumes the same
`CompiledPlan` the event engine does).  Unsupported configs transparently
fall back to the scalar event engine, job by job.

Numeric discipline: every float the scalar engines touch is a Python f64,
so the batch engine runs under ``jax.experimental.enable_x64`` and performs
the *identical* operations in the *identical* order (token-bucket refills,
``int()`` truncations, DRAM jitter hashes) — IEEE f64 arithmetic is then
bit-equal between the scalar and vector paths by construction.

Why lockstep is exact: the scalar tick's sequential sub-loops collapse.
* The round-robin issue scan is rank arithmetic: the chosen warp is the
  minimum ``(pos - cycle % n) mod n`` among ready active slots, and golden's
  DONE-marking / mem-stall recording applies exactly to the ranks it
  scanned (``rank <= chosen_rank``).
* Deactivation order is irrelevant: the scalar loop's interleaved
  ``deactivate -> activate`` calls never change which warps activate (the
  READY pool only shrinks, admitted wids only increase), so one vectorized
  deactivate + one greedy lowest-wid-first activation phase is equivalent.
* The RFC's OrderedDict LRU is a (key, stamp) array pair: move-to-end and
  insert are monotonic stamps, eviction is argmin-stamp — multiset-equal to
  ``popitem(last=False)``.
* The collector / prefetch-slot min-heaps are argmin-replace on arrays
  (multiset equality with both the heap and golden's first-argmin scan).

BATCH_REV 2 (fused tick): on XLA CPU every scatter/gather dispatch costs
microseconds regardless of size, so REV 1's ~60 per-tick `.at[...]` updates
and four full `(lane, slot, src)` readiness scans dominated the wall clock.
REV 2 restructures the step around struct-of-arrays *families* and a
per-warp readiness cache (the scalar engines' `_refresh_ready` memo,
vectorized):

* ``wf``  (K, W, 6+loops+dias) — status/pc/iv/ready_at/issued/mem_ops plus
  the loop/diamond branch counters: one row gather + one row scatter per
  selected warp instead of one dispatch per field.
* ``rv``  (K, W, regs+preds, 2) — register/predicate ready-times and the
  from-mem flag as one value plane; dst+pred writeback is a single scatter
  (out-of-bounds indices drop masked writes, no read-modify-write).
* ``cf``  (K, W, 2+S+PS) — cached max/mem-max/per-operand ready times of
  each warp's *current* instruction, refreshed only when that warp's state
  changes (its own issue or prefetch, exactly the scalar cache-invalidation
  sites).  Scheduler scans and the event-horizon search become elementwise
  reads of this plane — no per-slot 3D gathers.
* ``rc``  (K, E, 2) — RFC (key, stamp) rows; the LRU move-to-end phase is
  one scatter-max (stamps are monotone, so duplicate-key last-write ==
  max), only the insert/evict phase stays a short sequential loop.
* Active-list compaction is a cumsum + dropped-out-of-bounds scatter
  instead of a stable argsort.

Event-horizon time skipping (REV 1's ``delta`` jump) is unchanged: on a
zero-issue tick every lane advances straight to its next event — the min
over collector frees, warp wake-ups, and pending operand times, exactly
the scalar `_next_event` — with the skipped cycles charged to the same
`cycle_breakdown` category, so sum==cycles and bit-identity survive.
"""
from __future__ import annotations

import os
import time
from dataclasses import dataclass
from typing import Sequence

import numpy as np

from repro.core.pipeline import parse_interval_strategy
from repro.core.plan_cache import compile_for_sim
from repro.obs.attribution import CYCLE_CATEGORIES, check_breakdown, new_breakdown
from repro.workloads.suite import Workload

from .engine import (
    ACTIVE, DONE, INACTIVE_READY, INACTIVE_WAIT, PREFETCH,
    _CACHED_DESIGNS, _EDGE_PREFETCH,
    SimBudgetExceeded, SimConfig, SimResult, simulate,
)

# Bump with ENGINE_REV-style discipline if batch-engine behavior ever
# intentionally diverges (it must not: bit-identity is the contract).
# REV 2: fused-family tick (struct-of-arrays state, cached readiness
# planes, one-scatter LRU hit phase, cumsum compaction) — bit-identical
# to REV 1 by construction, ~O(families) dispatches per tick.
BATCH_REV = 2

# Opcode kinds in the flat-PC instruction encoding.
_OP_OTHER, _OP_BRA, _OP_EXIT, _OP_SET, _OP_LD = range(5)

_BIG = np.int64(1) << 60          # sentinel "never" timestamp / rank
_GUARD = 8_000_000                # same wedge guard as the scalar engines

_CAT_INDEX = {c: i for i, c in enumerate(CYCLE_CATEGORIES)}

# warp-family (``wf``) fixed field columns; loop counters start at
# _F_LC, diamond counters at _F_LC + n_loop_slots + 1 (chunk-dependent).
F_ST, F_PC, F_IV, F_RA, F_IS, F_MO = range(6)
_F_LC = 6

# packed per-pc metadata (``meta``) fixed columns; the variable-width
# src/psrc/dst/acc column groups follow (see `_meta_cols`).
M_KIND, M_NACC, M_PDST, M_TGT, M_TRIPS, M_LSL, M_DSL, M_IVPC = range(8)


def _meta_cols(S: int, PS: int, DD: int):
    """Column offsets of the variable-width groups in the meta table."""
    m_s = 8
    m_ps = m_s + S
    m_d = m_ps + PS
    m_g = m_d + DD
    return m_s, m_ps, m_d, m_g


_LEGACY_RT_FLAG = "--xla_cpu_use_thunk_runtime=false"


def _maybe_prefer_legacy_cpu_runtime() -> None:
    """Ask XLA:CPU for the legacy (pre-thunk) runtime before the backend
    initializes.  The fused tick is a ~200-op loop body; the thunk
    interpreter charges ~8µs of dispatch per op per tick, while the legacy
    emitter runs the same HLO ~2.5x faster (measured on the tracked
    serial-CPU host, see docs/simulator.md).  Best-effort only: if jax is
    already initialized the flag is left alone, and
    ``REPRO_BATCH_LEGACY_CPU_RT=0`` opts out (e.g. if a future jaxlib
    drops the flag)."""
    if os.environ.get("REPRO_BATCH_LEGACY_CPU_RT", "1") == "0":
        return
    import sys
    mod = sys.modules.get("jax")
    if mod is not None and getattr(mod, "_src", None) is not None:
        try:  # backend already up? then mutating XLA_FLAGS is a no-op
            from jax._src import xla_bridge
            if xla_bridge._backends:
                return
        except Exception:
            pass
    flags = os.environ.get("XLA_FLAGS", "")
    if "xla_cpu_use_thunk_runtime" not in flags:
        os.environ["XLA_FLAGS"] = (flags + " " + _LEGACY_RT_FLAG).strip()


def _jax():
    """Import jax lazily so jax-free consumers never pay for it."""
    _maybe_prefer_legacy_cpu_runtime()
    import jax
    import jax.numpy as jnp
    from jax import lax
    return jax, jnp, lax


_CACHE_DIR_SET = False


def _maybe_enable_compile_cache() -> None:
    """Best-effort persistent XLA compile cache (huge win for CI reruns)."""
    global _CACHE_DIR_SET
    if _CACHE_DIR_SET:
        return
    _CACHE_DIR_SET = True
    path = os.environ.get("REPRO_JAX_CACHE_DIR",
                          os.path.expanduser("~/.cache/repro-jax"))
    try:
        import jax
        os.makedirs(path, exist_ok=True)
        jax.config.update("jax_compilation_cache_dir", path)
        jax.config.update("jax_persistent_cache_min_compile_time_secs", 1.0)
    except Exception:
        pass  # cache is an optimization, never a requirement


def batch_supported(cfg: SimConfig) -> bool:
    """Can this config run on the vectorized fast path?

    The batch engine implements the paper's two-level scheduler with no
    bank arbitration and no tracer — the golden-pinned domain, and exactly
    what the tracked sweep runs.  Everything compile-side (design, interval
    strategy, renumbering) is supported because the plan is shared.
    """
    return (cfg.scheduler == "two_level"
            and cfg.bank_model == "none"
            and not cfg.trace
            and cfg.num_sms == 1)


# --------------------------------------------------------------------------
# Static per-lane encoding: flat-PC program tables + interval tables.
# --------------------------------------------------------------------------

@dataclass
class _PlanCode:
    """Flat-PC encoding of one compiled plan (+ workload trip counts).

    All arrays are numpy; shared read-only across lanes and batches.
    ``P`` rows of instruction metadata plus one sentinel row at index P
    (the "past the end" position the clamped pc gather lands on).
    """
    n_pc: int                 # instruction count (flat program length)
    op_kind: np.ndarray       # (P+1,) int32
    srcs: np.ndarray          # (P+1, S) int32, sentinel = n_regs
    psrcs: np.ndarray         # (P+1, PS) int32, sentinel = n_preds
    dsts: np.ndarray          # (P+1, D) int32, sentinel = n_regs
    pdst: np.ndarray          # (P+1,) int32, sentinel = n_preds
    n_acc: np.ndarray         # (P+1,) int32
    acc_regs: np.ndarray      # (P+1, G) int32 srcs+dsts in order, -1 pad
    target: np.ndarray        # (P+1,) int32 flat target pc (bra)
    trips: np.ndarray         # (P+1,) int32 loop trip count (0 if not loop)
    loop_slot: np.ndarray     # (P+1,) int32, sentinel = n_loops
    dia_slot: np.ndarray      # (P+1,) int32, sentinel = n_dias
    interval_of_pc: np.ndarray  # (P+1,) int32, -1 = none
    n_regs: int
    n_preds: int
    n_loops: int
    n_dias: int
    # interval tables, indexed by interval id (row IV = "no interval")
    iv_rounds: np.ndarray     # (IV+1,) int32
    iv_nfetch: np.ndarray     # (IV+1,) int32 effective fetch count
    iv_nwb: np.ndarray        # (IV+1,) int32 writeback regs on deactivation
    iv_has_op: np.ndarray     # (IV+1,) bool  prefetch actually fires
    iv_regs: np.ndarray       # (IV+1, GV) int32 FULL bitvector, -1 pad
    n_ivs: int


_ENCODE_MEMO: dict = {}


def _encode_plan(workload: Workload, cfg: SimConfig) -> _PlanCode:
    plan = compile_for_sim(workload.program, cfg.design,
                           cfg.interval_cap, cfg.num_banks,
                           renumber=cfg.renumber,
                           interval_strategy=cfg.interval_strategy,
                           rfc_per_warp=cfg.rfc_entries_per_warp)
    trips_key = tuple(sorted(workload.trips.items()))
    key = (id(plan), cfg.design == "LTRF_plus", trips_key)
    hit = _ENCODE_MEMO.get(key)
    if hit is not None:
        return hit[0]

    prog = plan.prog
    is_plus = cfg.design == "LTRF_plus"
    flat: list[tuple[str, int, object]] = []     # (label, idx, ins)
    block_first: dict[str, int] = {}             # label -> flat pc of first
    for label in prog.order:
        bb = prog.blocks[label]
        block_first[label] = len(flat)           # even for empty blocks:
        for i, ins in enumerate(bb.instrs):      # first instr at-or-after
            flat.append((label, i, ins))
    P = len(flat)

    def target_pc(label: str) -> int:
        # flat pc of the first instruction in-or-after `label` (the scalar
        # engines' lazy block walk); past-the-end collapses to P.
        start = block_first.get(label)
        return P if start is None else start

    n_regs = 0
    n_preds = 0
    max_s = 1
    max_ps = 1
    max_d = 1
    for _, _, ins in flat:
        for r in tuple(ins.srcs) + tuple(ins.dsts):
            n_regs = max(n_regs, r + 1)
        for p in ins.psrcs:
            n_preds = max(n_preds, p + 1)
        if ins.pdst is not None:
            n_preds = max(n_preds, ins.pdst + 1)
        max_s = max(max_s, len(ins.srcs))
        max_ps = max(max_ps, len(ins.psrcs))
        max_d = max(max_d, len(ins.dsts))
    for op in plan.pf_ops.values():
        for r in op.bitvector:
            n_regs = max(n_regs, r + 1)

    # loop slots: one counter per trip-count label (shared across branch
    # sites, like the scalar `loop_counters[target]`); diamond slots: one
    # visit counter per conditional non-loop branch *site* (flat pc).
    loop_labels: dict[str, int] = {}
    n_dias = 0

    max_g = max(1, max_s + max_d)
    op_kind = np.zeros(P + 1, np.int32)
    srcs = np.full((P + 1, max_s), n_regs, np.int32)
    psrcs = np.full((P + 1, max_ps), n_preds, np.int32)
    dsts = np.full((P + 1, max_d), n_regs, np.int32)
    pdst = np.full(P + 1, n_preds, np.int32)
    n_acc = np.zeros(P + 1, np.int32)
    acc_regs = np.full((P + 1, max_g), -1, np.int32)
    target = np.zeros(P + 1, np.int32)
    trips = np.zeros(P + 1, np.int32)
    interval_of_pc = np.full(P + 1, -1, np.int32)

    loop_slot_rows = np.zeros(P + 1, np.int32)
    dia_slot_rows = np.zeros(P + 1, np.int32)
    kinds = {"bra": _OP_BRA, "exit": _OP_EXIT, "set": _OP_SET, "ld": _OP_LD}

    for pc, (label, idx, ins) in enumerate(flat):
        interval_of_pc[pc] = plan.block_interval.get(label, -1)
        op_kind[pc] = kinds.get(ins.op, _OP_OTHER)
        for j, r in enumerate(ins.srcs):
            srcs[pc, j] = r
        for j, p in enumerate(ins.psrcs):
            psrcs[pc, j] = p
        for j, r in enumerate(ins.dsts):
            dsts[pc, j] = r
        if ins.pdst is not None:
            pdst[pc] = ins.pdst
        regs = tuple(ins.srcs) + tuple(ins.dsts)
        n_acc[pc] = len(regs)
        for j, r in enumerate(regs):
            acc_regs[pc, j] = r
        if ins.op == "bra":
            target[pc] = target_pc(ins.target)
            t = workload.trips.get(ins.target)
            if ins.psrcs and t is not None:
                trips[pc] = t
                slot = loop_labels.setdefault(ins.target, len(loop_labels))
                loop_slot_rows[pc] = slot + 1  # 0 = "not a loop" below
            elif ins.psrcs:
                n_dias += 1
                dia_slot_rows[pc] = n_dias     # 0 = "not a diamond"
    # the lazy block walk parks a finished warp on the LAST block in order,
    # so the sentinel row's interval is that block's (activation prefetch
    # of an at-end warp — unreachable in practice, encoded for fidelity).
    interval_of_pc[P] = plan.block_interval.get(prog.order[-1], -1) \
        if prog.order else -1
    op_kind[P] = _OP_EXIT

    n_loops = len(loop_labels)
    loop_slot = np.where(loop_slot_rows > 0, loop_slot_rows - 1,
                         n_loops).astype(np.int32)
    dia_slot = np.where(dia_slot_rows > 0, dia_slot_rows - 1,
                        n_dias).astype(np.int32)

    # ------------------------------------------------------ interval tables
    n_ivs = 0
    for iid in plan.pf_ops:
        n_ivs = max(n_ivs, iid + 1)
    for iid in plan.block_interval.values():
        n_ivs = max(n_ivs, iid + 1)
    max_gv = 1
    for op in plan.pf_ops.values():
        max_gv = max(max_gv, len(op.bitvector))
    iv_rounds = np.zeros(n_ivs + 1, np.int32)
    iv_nfetch = np.zeros(n_ivs + 1, np.int32)
    iv_nwb = np.zeros(n_ivs + 1, np.int32)
    iv_has_op = np.zeros(n_ivs + 1, bool)
    iv_regs = np.full((n_ivs + 1, max_gv), -1, np.int32)
    for iid, op in plan.pf_ops.items():
        fetch = op.bitvector
        rounds = op.serial_rounds
        has = bool(fetch)
        if is_plus:
            ent = plan.plus_fetch.get(iid)
            if ent is not None:
                live, live_rounds = ent
                if fetch:                       # engine consults plus_fetch
                    fetch, rounds = live, live_rounds   # only past this guard
                    has = bool(live)
            nwb = len(plan.live_sets.get(iid, op.bitvector))
        else:
            nwb = len(op.bitvector)
        iv_rounds[iid] = rounds
        iv_nfetch[iid] = len(fetch)
        iv_nwb[iid] = nwb
        iv_has_op[iid] = has
        # reg_ready refresh uses the FULL bitvector even for LTRF+ (cache
        # slots are reserved for dead entries; only the data movement is
        # trimmed) — order irrelevant (independent per-register max).
        for j, r in enumerate(sorted(op.bitvector)):
            iv_regs[iid, j] = r

    code = _PlanCode(
        n_pc=P, op_kind=op_kind, srcs=srcs, psrcs=psrcs, dsts=dsts,
        pdst=pdst, n_acc=n_acc, acc_regs=acc_regs, target=target,
        trips=trips, loop_slot=loop_slot, dia_slot=dia_slot,
        interval_of_pc=interval_of_pc, n_regs=n_regs, n_preds=n_preds,
        n_loops=n_loops, n_dias=n_dias,
        iv_rounds=iv_rounds, iv_nfetch=iv_nfetch, iv_nwb=iv_nwb,
        iv_has_op=iv_has_op, iv_regs=iv_regs, n_ivs=n_ivs,
    )
    _ENCODE_MEMO[key] = (code, plan)  # keep `plan` alive: memo key uses id()
    return code


# --------------------------------------------------------------------------
# Batch assembly: pad lanes into shared (lane, ...) arrays.
# --------------------------------------------------------------------------

@dataclass
class _Lane:
    workload: Workload
    cfg: SimConfig
    code: _PlanCode
    occupancy: int


def _occupancy(workload: Workload, cfg: SimConfig) -> int:
    cap_kb = cfg.rf_size_kb + (cfg.rfc_size_kb if cfg.add_rfc_to_main else 0)
    per_warp = max(workload.regs_per_thread, 1)
    return max(1, min(cfg.num_warps, cap_kb * 1024 // 128 // per_warp))


def _acap(ln: "_Lane") -> int:
    """Active-slot cap for one lane (mirrors the scalar engines')."""
    if ln.cfg.design in _CACHED_DESIGNS:
        return min(ln.cfg.active_slots, ln.occupancy)
    return ln.occupancy


def _bucket(n: int, floor: int) -> int:
    """Next power-of-two >= n (>= floor): shape buckets bound recompiles."""
    b = floor
    while b < n:
        b *= 2
    return b


def _build(lanes: Sequence[_Lane]):
    """Pad every lane's tables/config into batch arrays (numpy, 64-bit)."""
    i32, i64, f64 = np.int32, np.int64, np.float64
    K = _bucket(len(lanes), 2)
    W = _bucket(max(ln.cfg.num_warps for ln in lanes), 4)
    # Active-list width: cached designs cap it at `active_slots` (8), the
    # uncached ones scan every resident warp.  Keeping this dimension tight
    # is the difference between (K, 8) and (K, 64) work in the per-slot
    # scheduler scans — `run_batch` groups lanes by it.
    A = _bucket(max(_acap(ln) for ln in lanes), 2)
    P = _bucket(max(ln.code.n_pc for ln in lanes), 16)
    S = max(ln.code.srcs.shape[1] for ln in lanes)
    PS = max(ln.code.psrcs.shape[1] for ln in lanes)
    DD = max(ln.code.dsts.shape[1] for ln in lanes)
    G = max(ln.code.acc_regs.shape[1] for ln in lanes)
    GV = _bucket(max(ln.code.iv_regs.shape[1] for ln in lanes), 4)
    R = _bucket(max(ln.code.n_regs for ln in lanes), 8)
    PR = _bucket(max(ln.code.n_preds for ln in lanes), 2)
    L = _bucket(max(ln.code.n_loops for ln in lanes), 2)
    DM = _bucket(max(ln.code.n_dias for ln in lanes), 2)
    IV = _bucket(max(ln.code.n_ivs for ln in lanes), 4)
    C = max(ln.cfg.num_collectors for ln in lanes)
    PF = max(ln.cfg.max_inflight_prefetch for ln in lanes)
    # E == 1 statically means "no RFC lane in this chunk": the jitted run
    # skips the whole cache-classification + LRU block (RFC chunks are
    # padded to >= 2 entries so the gate never misfires).
    _rfc_es = [ln.cfg.rfc_entries for ln in lanes if ln.cfg.design == "RFC"]
    E = max(2, *_rfc_es) if _rfc_es else 1
    IW = max(ln.cfg.issue_width for ln in lanes)

    m_s, m_ps, m_d, m_g = _meta_cols(S, PS, DD)
    MW = m_g + G                      # packed meta row width
    NWF = _F_LC + (L + 1) + (DM + 1)  # warp-family row width
    RVW = (R + 1) + (PR + 1)          # register+predicate value rows

    meta = np.zeros((K, P + 1, MW), i32)
    meta[:, :, M_KIND] = _OP_EXIT
    meta[:, :, M_PDST] = PR
    meta[:, :, M_LSL] = L
    meta[:, :, M_DSL] = DM
    meta[:, :, M_IVPC] = -1
    meta[:, :, m_s: m_s + S] = R
    meta[:, :, m_ps: m_ps + PS] = PR
    meta[:, :, m_d: m_d + DD] = R
    meta[:, :, m_g: m_g + G] = -1

    co = {
        # packed per-pc instruction metadata (sentinel row at pc=P)
        "meta": meta,
        # per-interval table: [rounds, nfetch, nwb, has_op] (sentinel at IV)
        "ivt": np.zeros((K, IV + 1, 4), i32),
        "ivregs": np.full((K, IV + 1, GV), -1, i32),
        # per-lane scalars
        "endpc": np.zeros(K, i32),
        "mrfc": np.zeros(K, f64), "rfcc": np.zeros(K, f64),
        "brf_f": np.zeros(K, f64), "wlat": np.zeros(K, f64),
        "rate": np.zeros(K, f64), "l1h": np.zeros(K, f64),
        "xbar": np.ones(K, f64), "banksf": np.zeros(K, f64),
        "aluf": np.zeros(K, f64), "memf": np.zeros(K, f64),
        "brf_i": np.zeros(K, i64), "l1c": np.zeros(K, i64),
        # dram_interval is a float on gpu.per_sm_configs shards (the per-SM
        # effective interval is dram_interval*num_sms/partitions) — golden
        # does the same arithmetic in Python floats, exactly representable
        "thr": np.zeros(K, i64), "drint": np.zeros(K, f64),
        "seed": np.zeros(K, i64), "maxc": np.zeros(K, i64),
        "iw": np.zeros(K, i32), "nw": np.zeros(K, i32),
        "rcap": np.zeros(K, i32), "acap": np.zeros(K, i32),
        "tcap": np.zeros(K, i32), "ecap": np.ones(K, i32),
        "cached": np.zeros(K, bool), "edge": np.zeros(K, bool),
        "bl": np.zeros(K, bool), "rfc": np.zeros(K, bool),
        "ideal": np.zeros(K, bool), "fam": np.zeros(K, bool),
        # wedge guard / tick cap: a traced scalar so profiling harnesses can
        # cap the fused loop without recompiling (production leaves _GUARD)
        "tmax": np.asarray(_GUARD, i64),
        # dummies whose SHAPES carry the static widths the traced step
        # needs (issue-slot unroll, meta column groups, value/counter rows)
        "slots": np.zeros(IW, np.int8),
        "mdims": np.zeros((S, PS, DD, G), np.int8),
        "rdims": np.zeros((R + 1, PR + 1), np.int8),
        "ldims": np.zeros((L + 1, DM + 1), np.int8),
    }

    def remap(a, sent_old, sent_new):
        return np.where(a == sent_old, sent_new, a).astype(np.int32)

    for k, ln in enumerate(lanes):
        c, cfg = ln.code, ln.cfg
        n = c.n_pc
        m = meta[k]
        m[: n + 1, M_KIND] = c.op_kind
        m[: n + 1, M_NACC] = c.n_acc
        m[: n + 1, M_PDST] = remap(c.pdst, c.n_preds, PR)
        m[: n + 1, M_TGT] = c.target
        m[: n + 1, M_TRIPS] = c.trips
        m[: n + 1, M_LSL] = remap(c.loop_slot, c.n_loops, L)
        m[: n + 1, M_DSL] = remap(c.dia_slot, c.n_dias, DM)
        m[: n + 1, M_IVPC] = c.interval_of_pc
        m[: n + 1, m_s: m_s + c.srcs.shape[1]] = remap(c.srcs, c.n_regs, R)
        m[: n + 1, m_ps: m_ps + c.psrcs.shape[1]] = \
            remap(c.psrcs, c.n_preds, PR)
        m[: n + 1, m_d: m_d + c.dsts.shape[1]] = remap(c.dsts, c.n_regs, R)
        m[: n + 1, m_g: m_g + c.acc_regs.shape[1]] = c.acc_regs
        nv = c.n_ivs
        co["ivt"][k, : nv + 1, 0] = c.iv_rounds
        co["ivt"][k, : nv + 1, 1] = c.iv_nfetch
        co["ivt"][k, : nv + 1, 2] = c.iv_nwb
        co["ivt"][k, : nv + 1, 3] = c.iv_has_op.astype(i32)
        co["ivregs"][k, : nv + 1, : c.iv_regs.shape[1]] = c.iv_regs
        # sentinel rows must stay inert even where lane rows ended early
        co["ivt"][k, nv, 3] = 0

        co["endpc"][k] = n
        design = cfg.design
        cached = design in _CACHED_DESIGNS
        rcap = ln.occupancy
        co["mrfc"][k] = cfg.mrf_cycles
        co["rfcc"][k] = float(cfg.rfc_cycles)
        co["brf_f"][k] = float(cfg.base_rf_cycles)
        co["wlat"][k] = (float(cfg.base_rf_cycles) if design == "Ideal"
                         else cfg.mrf_cycles if design == "BL"
                         else float(cfg.rfc_cycles))
        co["rate"][k] = cfg.num_banks / max(cfg.mrf_cycles / 6.0, 1.0)
        co["l1h"][k] = ln.workload.l1_hit
        co["xbar"][k] = float(cfg.xbar_regs_per_cycle)
        co["banksf"][k] = float(cfg.num_banks)
        co["aluf"][k] = float(cfg.alu_cycles)
        co["memf"][k] = float(cfg.mem_cycles)
        co["brf_i"][k] = cfg.base_rf_cycles
        co["l1c"][k] = cfg.l1_cycles
        co["thr"][k] = 2 * cfg.l1_cycles
        co["drint"][k] = cfg.dram_interval
        co["seed"][k] = cfg.seed
        co["maxc"][k] = cfg.max_cycles
        co["iw"][k] = cfg.issue_width
        co["nw"][k] = cfg.num_warps
        co["rcap"][k] = rcap
        co["acap"][k] = min(cfg.active_slots, rcap) if cached else rcap
        co["tcap"][k] = min(cfg.active_slots, rcap)
        co["ecap"][k] = max(1, min(cfg.rfc_entries, E))
        co["cached"][k] = cached
        co["edge"][k] = design in _EDGE_PREFETCH
        co["bl"][k] = design == "BL"
        co["rfc"][k] = design == "RFC"
        co["ideal"][k] = design == "Ideal"
        co["fam"][k] = cached

    wf = np.zeros((K, W, NWF), i64)
    wf[:, :, F_ST] = INACTIVE_READY
    wf[:, :, F_IV] = -1
    rc = np.full((K, E, 2), -1, i64)
    rc[:, :, 1] = _BIG
    st = {
        "cycle": np.zeros(K, i64),
        "guard": np.zeros((), i64),
        "alive": np.zeros(K, bool),
        "budget": np.zeros(K, bool),
        "wf": wf,
        "cf": np.zeros((K, W, 2 + S + PS), f64),
        "rv": np.zeros((K, W, RVW, 2), f64),
        "act": np.zeros((K, A), i32),
        "na": np.zeros(K, i32),
        "res": np.zeros((K, W), bool),
        "nr": np.zeros(K, i32),
        "ptr": np.zeros(K, i32),
        "pf": np.full((K, PF), _BIG, i64),
        "col": np.full((K, C), _BIG, i64),
        "tok": np.zeros(K, f64),
        "mlast": np.zeros(K, i64),
        "dnext": np.zeros(K, f64),
        "rc": rc,
        "rcnt": np.zeros(K, i32),
        "rstamp": np.zeros(K, i64),
        "bd": np.zeros((K, len(CYCLE_CATEGORIES)), i64),
        "ch": np.zeros(K, i64), "ca": np.zeros(K, i64),
        "cm": np.zeros(K, i64), "cpo": np.zeros(K, i64),
        "cpc": np.zeros(K, i64), "cps": np.zeros(K, i64),
        "cwb": np.zeros(K, i64), "cact": np.zeros(K, i64),
    }
    for k, ln in enumerate(lanes):
        cfg = ln.cfg
        st["alive"][k] = True
        # initial admit(): the first resident_cap warps, in wid order
        st["res"][k, : ln.occupancy] = True
        st["nr"][k] = ln.occupancy
        st["ptr"][k] = ln.occupancy
        st["pf"][k, : cfg.max_inflight_prefetch] = 0
        st["col"][k, : cfg.num_collectors] = 0
        st["tok"][k] = float(cfg.num_banks)
    return co, st


# --------------------------------------------------------------------------
# The jitted lockstep run: one lax.while_loop over the whole batch.
# --------------------------------------------------------------------------

def _run_jax(co, st):
    """Advance every lane to completion.  Traced+jitted once per shape."""
    _, jnp, lax = _jax()
    i64, f64 = jnp.int64, jnp.float64
    K, W, NWF = st["wf"].shape
    A = st["act"].shape[1]
    E = st["rc"].shape[1]         # 1 <=> no RFC lane in this chunk (static)
    P = co["meta"].shape[1] - 1
    S, PS, DD, G = co["mdims"].shape
    R = co["rdims"].shape[0] - 1
    PRS = co["rdims"].shape[1] - 1
    RVW = st["rv"].shape[2]       # masked writes use index RVW: OOB-dropped
    LS = co["ldims"].shape[0] - 1
    DS = co["ldims"].shape[1] - 1
    IVS = co["ivt"].shape[1] - 1
    IW = co["slots"].shape[0]
    NCAT = len(CYCLE_CATEGORIES)
    M_S, M_PS, M_D, M_G = _meta_cols(S, PS, DD)
    F_DC = _F_LC + LS + 1
    READY, WAIT = INACTIVE_READY, INACTIVE_WAIT
    kk = jnp.arange(K)
    wI = jnp.arange(W)
    aI = jnp.arange(A)
    ctrI = jnp.arange(NWF - _F_LC)
    BIG = jnp.asarray(_BIG, i64)

    def rnd(s, x):
        """Round a float product before its consuming add.  XLA CPU
        contracts a*b+c into one fma (single rounding), but the scalar
        engines round the product first — a one-ulp difference that is
        enough to flip a token-bucket comparison.  The select on a
        loop-carried value cannot be folded away, so the intermediate is
        materialized and rounded exactly like the Python arithmetic."""
        return jnp.where(s["guard"] >= 0, x, 0.0)

    def refresh_cf(s, wid, mask, md):
        """Recompute the readiness-cache row for one selected warp per lane
        (the scalar engines' `_refresh_ready`, at the identical sites: the
        warp's own issue or prefetch — the only events that can change its
        current instruction's operand times).  ``md`` is the warp's meta
        row at its (post-update) pc."""
        sidx = md[:, M_S: M_S + S]                          # (K, S)
        pidx = md[:, M_PS: M_PS + PS]                       # (K, PS)
        rvw = s["rv"][kk[:, None], wid[:, None], sidx]      # (K, S, 2)
        ts = rvw[:, :, 0]
        fm = rvw[:, :, 1] > 0.0
        tp = s["rv"][kk[:, None], wid[:, None], R + 1 + pidx, 0]
        cmax = jnp.maximum(ts.max(axis=1), tp.max(axis=1))
        cmem = jnp.where(fm, ts, 0.0).max(axis=1)
        newcf = jnp.concatenate([cmax[:, None], cmem[:, None], ts, tp],
                                axis=1)
        oldcf = s["cf"][kk, wid]
        s["cf"] = s["cf"].at[kk, wid].set(
            jnp.where(mask[:, None], newcf, oldcf))
        return s

    def prefetch_slot(s, body, lat):
        """Charge one prefetch op into the inflight-slot array, masked.
        Returns (state, done_time) — the caller folds status/ra/iv into
        its own warp-family row write."""
        slot = jnp.argmin(s["pf"], axis=1)
        freet = s["pf"][kk, slot]
        startt = jnp.maximum(s["cycle"], freet)
        done = (startt.astype(f64) + lat).astype(i64)   # int(start + lat)
        s["pf"] = s["pf"].at[kk, slot].set(jnp.where(body, done, freet))
        return s, done

    def prefetch_charge(s, wid, ii, body, done):
        """Max the fetched interval's registers up to the landing time."""
        regs = co["ivregs"][kk, ii]                     # (K, GV)
        vp = (regs >= 0) & body[:, None]
        ridx = jnp.where(vp, regs, RVW)                 # OOB: masked drop
        val = jnp.where(vp, done[:, None].astype(f64), 0.0)
        s["rv"] = s["rv"].at[kk[:, None], wid[:, None], ridx, 0].max(val)
        return s

    def activation(s, act):
        """Greedy lowest-wid-ready activation until slots/candidates run out
        (the scalar engines' interleaved activate() calls collapse to this:
        admitted wids only increase and the READY pool never grows mid-loop,
        so batched ascending-wid activation charges identical prefetches)."""
        def more(s):
            cand = s["res"] & (s["wf"][:, :, F_ST] == READY)
            return jnp.any(act & (s["na"] < co["acap"])
                           & jnp.any(cand, axis=1))

        def one(s):
            cand = s["res"] & (s["wf"][:, :, F_ST] == READY)
            do = act & (s["na"] < co["acap"]) & jnp.any(cand, axis=1)
            wid = jnp.argmax(cand, axis=1)
            # _start_prefetch(force=True) for the activating warp
            row = s["wf"][kk, wid]                       # (K, NWF)
            pcc = jnp.minimum(row[:, F_PC], P)
            md = co["meta"][kk, pcc]
            iid = md[:, M_IVPC]
            go = do & co["cached"] & (iid >= 0)
            ii = jnp.where(go, iid, IVS)
            ivt = co["ivt"][kk, ii]                      # (K, 4)
            body = go & (ivt[:, 3] > 0)
            nf = ivt[:, 1].astype(i64)
            lat = rnd(s, ivt[:, 0].astype(f64) * co["mrfc"]) \
                + nf.astype(f64) / co["xbar"]
            s, done = prefetch_slot(s, body, lat)
            s["cpo"] += body.astype(i64)
            s["cpc"] += jnp.where(body, lat.astype(i64), 0)
            s["cps"] += jnp.where(body, done - s["cycle"], 0)
            s["cm"] += jnp.where(body, nf, 0)
            s = prefetch_charge(s, wid, ii, body, done)
            # fold activation + prefetch into one warp-family row write
            newst = jnp.where(body, PREFETCH,
                              jnp.where(do, ACTIVE, row[:, F_ST]))
            newiv = jnp.where(go, iid.astype(i64), row[:, F_IV])
            newra = jnp.where(body, done, row[:, F_RA])
            newrow = jnp.concatenate(
                [newst[:, None], row[:, F_PC: F_PC + 1], newiv[:, None],
                 newra[:, None], row[:, F_RA + 1:]], axis=1)
            s["wf"] = s["wf"].at[kk, wid].set(newrow)
            s = refresh_cf(s, wid, body, md)
            s["cact"] += do.astype(i64)
            pos = jnp.minimum(s["na"], A - 1)
            oldv = s["act"][kk, pos]
            s["act"] = s["act"].at[kk, pos].set(
                jnp.where(do, wid.astype(s["act"].dtype), oldv))
            s["na"] = s["na"] + do.astype(s["na"].dtype)
            return s

        return lax.while_loop(more, one, s)

    def issue_one(s, picked, wsel):
        """The _issue body for one selected warp per lane, masked.
        Returns (state, instruction-issued, structural-stall)."""
        row = s["wf"][kk, wsel]                         # (K, NWF)
        pcs = row[:, F_PC]
        pcc = jnp.minimum(pcs, P)
        md = co["meta"][kk, pcc]                        # (K, MW)
        kind = md[:, M_KIND]
        bra = picked & (kind == _OP_BRA)
        ext = picked & (kind == _OP_EXIT)
        opnd = picked & (kind != _OP_BRA) & (kind != _OP_EXIT)
        nacc = md[:, M_NACC].astype(i64)
        # RFC classification against the PRE-issue cache state (statically
        # skipped in chunks with no RFC lane: co["rfc"] is all-False there,
        # so every consumer of n_miss/n_hit reduces to the zero branch)
        regs = md[:, M_G: M_G + G]                      # (K, G)
        if E > 1:
            onr = (regs >= 0) & opnd[:, None] & co["rfc"][:, None]
            keyv = jnp.where(onr,
                             wsel.astype(i64)[:, None] * (R + 1) + regs, -2)
            memb = (s["rc"][:, None, :, 0] == keyv[:, :, None]).any(axis=2)
            n_miss = (onr & ~memb).sum(axis=1).astype(i64)
            n_hit = memb.sum(axis=1).astype(i64)
        else:
            n_miss = jnp.zeros((K,), i64)
            n_hit = jnp.zeros((K,), i64)
        # MRF bandwidth token bucket (refill only on a non-zero request)
        n_bw = jnp.where(co["bl"], jnp.where(opnd, nacc, 0),
                         jnp.where(co["rfc"], n_miss, 0))
        do_bw = opnd & (n_bw > 0)
        refill = do_bw & (s["cycle"] > s["mlast"])
        newtok = jnp.minimum(
            co["banksf"],
            s["tok"] + rnd(s, co["rate"]
                           * (s["cycle"] - s["mlast"]).astype(f64)))
        tok = jnp.where(refill, newtok, s["tok"])
        s["mlast"] = jnp.where(refill, s["cycle"], s["mlast"])
        bw_ok = ~do_bw | (tok >= n_bw.astype(f64))
        # tokens are consumed before the collector attempt (and leak if the
        # collector then fails — the scalar engines' exact semantics)
        s["tok"] = jnp.where(do_bw & bw_ok, tok - n_bw.astype(f64), tok)
        cslot = jnp.argmin(s["col"], axis=1)
        cfree = s["col"][kk, cslot]
        ok = opnd & bw_ok & (cfree <= s["cycle"])
        s["col"] = s["col"].at[kk, cslot].set(
            jnp.where(ok, s["cycle"] + co["brf_i"], cfree))
        sfail = opnd & ~ok
        read_lat = jnp.where(
            co["ideal"], co["brf_f"],
            jnp.where(co["bl"], co["mrfc"],
                      jnp.where(co["rfc"],
                                jnp.where(n_miss > 0, co["mrfc"], co["rfcc"]),
                                co["rfcc"])))
        s["cm"] += jnp.where(ok, jnp.where(co["bl"], nacc,
                                           jnp.where(co["rfc"], n_miss, 0)), 0)
        s["ca"] += jnp.where(ok & (co["rfc"] | co["fam"]), nacc, 0)
        s["ch"] += jnp.where(ok, jnp.where(co["rfc"], n_hit,
                                           jnp.where(co["fam"], nacc, 0)), 0)
        # RFC LRU mutation: move-to-end every pre-state hit in operand order,
        # then insert misses with oldest-stamp eviction (OrderedDict-equal).
        # The hit phase is ONE scatter-max: stamps are globally monotone, so
        # a duplicate key's last move-to-end is exactly the max stamp, and
        # every fresh stamp exceeds the entry's old one.
        if E > 1:
            lru = ok & co["rfc"]
            hvs = lru[:, None] & memb                   # (K, G)
            hvi = hvs.astype(i64)
            stamps = s["rstamp"][:, None] + jnp.cumsum(hvi, axis=1) - hvi
            pos = jnp.argmax(s["rc"][:, None, :, 0] == keyv[:, :, None],
                             axis=2)
            posm = jnp.where(hvs, pos, E)               # OOB: masked drop
            s["rc"] = s["rc"].at[kk[:, None], posm, 1].max(stamps)
            s["rstamp"] += hvi.sum(axis=1)
            for i in range(G):                          # insert/evict phase
                ki = keyv[:, i]
                membL = (s["rc"][:, :, 0] == ki[:, None]).any(axis=1)
                ins = lru & (ki >= 0) & ~membL          # vs LIVE state
                full = s["rcnt"] >= co["ecap"]
                slot = jnp.where(full,
                                 jnp.argmin(s["rc"][:, :, 1], axis=1)
                                 .astype(s["rcnt"].dtype),
                                 s["rcnt"])
                slot = jnp.minimum(slot, E - 1)
                oldrow = s["rc"][kk, slot]
                newr = jnp.stack([ki, s["rstamp"]], axis=1)
                s["rc"] = s["rc"].at[kk, slot].set(
                    jnp.where(ins[:, None], newr, oldrow))
                s["rstamp"] += ins.astype(i64)
                s["rcnt"] += (ins & ~full).astype(s["rcnt"].dtype)
        # memory latency: deterministic jitter hash + single-server DRAM queue
        is_ld = kind == _OP_LD
        ldo = ok & is_ld
        mops = row[:, F_MO]
        h = (wsel.astype(i64) * 2654435761 + mops * 40503
             + co["seed"] * 97) & 0xFFFF
        hit = (h.astype(f64) / 65535.0) < co["l1h"]
        spread = rnd(s, ((h >> 3).astype(f64) / 8191.0 - 0.5) * 0.6)
        dstart = jnp.maximum(s["cycle"].astype(f64), s["dnext"])
        s["dnext"] = jnp.where(ldo & ~hit, dstart + co["drint"], s["dnext"])
        mlat = jnp.where(hit, co["l1c"],
                         (dstart - s["cycle"].astype(f64)
                          + rnd(s, co["memf"] * (1.0 + spread))).astype(i64))
        # writeback chain: done_at accumulates exactly like the scalar code
        base = s["cycle"].astype(f64) + read_lat
        is_set = kind == _OP_SET
        da = jnp.where(is_set, base + co["aluf"],
                       jnp.where(is_ld, base + (mlat.astype(f64) + co["wlat"]),
                                 base + (co["aluf"] + co["wlat"])))
        # dst-register + dst-predicate writeback: ONE scatter into the
        # unified (reg | pred) value plane, masked rows dropped via OOB
        pd = md[:, M_PDST]
        onp = ok & is_set & (pd < PRS)
        dsts = md[:, M_D: M_D + DD]                     # (K, DD)
        ond = (ok & ~is_set)[:, None] & (dsts < R)
        didx = jnp.where(ond, dsts, RVW)
        pcol = jnp.where(onp, R + 1 + pd, RVW)[:, None]
        wix = jnp.concatenate([didx, pcol], axis=1)     # (K, DD+1)
        vt = jnp.concatenate(
            [jnp.broadcast_to(da[:, None], ond.shape), da[:, None]], axis=1)
        vm = jnp.concatenate(
            [(ond & is_ld[:, None]).astype(f64),
             jnp.zeros((K, 1), f64)], axis=1)
        s["rv"] = s["rv"].at[kk[:, None], wsel[:, None], wix].set(
            jnp.stack([vt, vm], axis=2))
        happened = bra | ext | ok
        # branch resolution (loop trip counters / diamond visit hashes);
        # the counters live in the warp-family row — updated in place via
        # one-hot column selects, folded into the single row write below
        tgt = md[:, M_TGT]
        trips = md[:, M_TRIPS]
        lsl = md[:, M_LSL]
        dsl = md[:, M_DSL]
        uncond = md[:, M_PS] >= PRS
        isl = bra & (lsl < LS)
        lidx = jnp.where(isl, lsl, LS)
        oldl = jnp.take_along_axis(row, (_F_LC + lidx)[:, None], axis=1)[:, 0]
        c = oldl + 1
        tkl = c < trips
        newl = jnp.where(tkl, c, 0)
        isd = bra & ~uncond & (lsl >= LS)
        didx2 = jnp.where(isd, dsl, DS)
        v = jnp.take_along_axis(row, (F_DC + didx2)[:, None], axis=1)[:, 0]
        hh = (wsel.astype(i64) * 31 + v * 17 + co["seed"]) & 0xFF
        taken = jnp.where(uncond, True,
                          jnp.where(isl, tkl, (hh & 1) == 1))
        npc = jnp.where(bra, jnp.where(taken, tgt.astype(i64), pcs + 1),
                        jnp.where(ok, pcs + 1, pcs))
        npce = jnp.where(picked & ~ext, npc, pcs)
        # edge prefetch: issued warp crossed into a new interval's block
        # (_start_prefetch with force=False, at the post-update pc)
        ep = co["edge"] & (bra | ok) & (npc < co["endpc"])
        pccp = jnp.minimum(npce, P)
        md2 = co["meta"][kk, pccp]          # shared with the cache refresh
        iid = md2[:, M_IVPC]
        go = ep & (iid >= 0) & (iid != row[:, F_IV])
        ii = jnp.where(go, iid, IVS)
        ivt = co["ivt"][kk, ii]
        body = go & (ivt[:, 3] > 0)
        nf = ivt[:, 1].astype(i64)
        lat = rnd(s, ivt[:, 0].astype(f64) * co["mrfc"]) \
            + nf.astype(f64) / co["xbar"]
        s, done = prefetch_slot(s, body, lat)
        s["cpo"] += body.astype(i64)
        s["cpc"] += jnp.where(body, lat.astype(i64), 0)
        s["cps"] += jnp.where(body, done - s["cycle"], 0)
        s["cm"] += jnp.where(body, nf, 0)
        s = prefetch_charge(s, wsel, ii, body, done)
        # ONE warp-family row write covers pc/status/iv/ra/issued/mops and
        # both branch counters (ext and edge-prefetch are disjoint: ep
        # requires bra|ok, which excludes exit instructions)
        newst = jnp.where(ext, DONE,
                          jnp.where(body, PREFETCH, row[:, F_ST]))
        newiv = jnp.where(go, iid.astype(i64), row[:, F_IV])
        newra = jnp.where(body, done, row[:, F_RA])
        newis = row[:, F_IS] + happened.astype(i64)
        newmo = mops + ldo.astype(i64)
        ctr = row[:, _F_LC:]
        ctr = jnp.where(isl[:, None] & (ctrI[None, :] == lidx[:, None]),
                        newl[:, None], ctr)
        ctr = jnp.where(isd[:, None]
                        & (ctrI[None, :] == (LS + 1 + didx2)[:, None]),
                        (v + 1)[:, None], ctr)
        newrow = jnp.concatenate(
            [newst[:, None], npce[:, None], newiv[:, None], newra[:, None],
             newis[:, None], newmo[:, None], ctr], axis=1)
        s["wf"] = s["wf"].at[kk, wsel].set(newrow)
        s = refresh_cf(s, wsel, happened, md2)
        return s, happened, sfail

    def tick(s):
        s["guard"] = s["guard"] + 1
        # cycle-budget watchdog: freeze the lane at the identical cycle the
        # scalar engines raise SimBudgetExceeded
        exceed = s["alive"] & (co["maxc"] > 0) & (s["cycle"] > co["maxc"])
        s["budget"] = s["budget"] | exceed
        s["alive"] = s["alive"] & ~exceed
        act = s["alive"]
        # wake: WAIT->READY, PREFETCH->ACTIVE once ready_at arrives
        stp = s["wf"][:, :, F_ST]
        wake = s["res"] & act[:, None] \
            & (s["wf"][:, :, F_RA] <= s["cycle"][:, None])
        ns = jnp.where(wake & (stp == WAIT), READY,
                       jnp.where(wake & (stp == PREFETCH), ACTIVE, stp))
        s["wf"] = s["wf"].at[:, :, F_ST].set(ns)
        s = activation(s, act)
        # issue slots (round-robin rank arithmetic == the golden scan).
        # The active list is frozen across the unrolled slots (compaction
        # runs after), so slot position / rank / DONE-mark bookkeeping is
        # accumulated per slot and applied in two scatters at the end —
        # deferring the DONE status write is exact because an at-end warp
        # is never ready (atend gates every consumer the status would).
        posv = aI[None, :] < s["na"][:, None]
        wida = jnp.where(posv, s["act"], 0)
        nz = jnp.maximum(s["na"], 1).astype(i64)
        rank = jnp.where(posv,
                         (aI[None, :] - (s["cycle"] % nz)[:, None])
                         % nz[:, None], BIG)
        ndacc = jnp.zeros((K, A), bool)
        msacc = jnp.zeros((K, A), f64)
        issue_any = jnp.zeros((K,), bool)
        struct = jnp.zeros((K,), bool)
        for j in range(IW):
            slot_on = act & (j < co["iw"])
            wfa = s["wf"][kk[:, None], wida]            # (K, A, NWF)
            cfa = s["cf"][kk[:, None], wida]            # (K, A, CW)
            stat = wfa[:, :, F_ST]
            isact = posv & (stat == ACTIVE)
            pca = wfa[:, :, F_PC]
            atend = pca >= co["endpc"][:, None]
            # readiness/blockedness from the cached per-warp planes — no
            # per-slot operand gathers (scalar `_refresh_ready` semantics:
            # a warp's operand times only change when IT issues/prefetches)
            cyc = s["cycle"].astype(f64)[:, None]
            ready = isact & ~atend & (cfa[:, :, 0] <= cyc)
            thr = (s["cycle"] + co["thr"]).astype(f64)[:, None]
            blocked = jnp.where(cfa[:, :, 1] > thr, cfa[:, :, 1], 0.0)
            rrk = jnp.where(ready & slot_on[:, None], rank, BIG)
            crank = rrk.min(axis=1)
            picked = (crank < BIG) & slot_on
            visited = posv & slot_on[:, None] & (rank <= crank[:, None])
            # scanned warps at program end retire (applied after the slots)
            ndacc = ndacc | (visited & isact & atend)
            # scanned warps blocked on long memory: deactivation candidates
            ms = visited & isact & ~atend & ~ready & (blocked > 0)
            msacc = jnp.maximum(msacc, jnp.where(ms, blocked, 0.0))
            wsel = s["act"][kk, jnp.argmin(rrk, axis=1)]
            s, happened, sfail = issue_one(s, picked, wsel)
            issue_any = issue_any | happened
            struct = struct | sfail
        s["wf"] = s["wf"].at[kk[:, None], wida, F_ST].max(
            jnp.where(ndacc, DONE, 0))
        stall_until = jnp.zeros((K, W), f64).at[kk[:, None], wida].max(msacc)
        # two-level deactivation (cached designs swap stalled warps out)
        stp2 = s["wf"][:, :, F_ST]
        de = (stall_until > 0) & (stp2 == ACTIVE) \
            & co["cached"][:, None] & act[:, None]
        ivv = s["wf"][:, :, F_IV]
        ii = jnp.where(de & (ivv >= 0), ivv, IVS)
        nwb = jnp.where(de, co["ivt"][kk[:, None], ii, 2].astype(i64), 0) \
            .sum(axis=1)
        s["cwb"] += nwb
        s["cm"] += nwb
        s["wf"] = s["wf"].at[:, :, F_ST].set(jnp.where(de, WAIT, stp2))
        s["wf"] = s["wf"].at[:, :, F_RA].set(
            jnp.where(de, stall_until.astype(i64), s["wf"][:, :, F_RA]))
        s["wf"] = s["wf"].at[:, :, F_IV].set(jnp.where(de, -1, ivv))
        # compact the active list: drop deactivated (WAIT) + retired (DONE).
        # Stable compaction = cumsum of keepers + dropped-OOB scatter (the
        # argsort this replaces cost more than every other tick op).
        stw = s["wf"][kk[:, None], wida, F_ST]
        gone = posv & act[:, None] & ((stw == WAIT) | (stw == DONE))
        keep = posv & ~gone
        cpos = jnp.where(keep, jnp.cumsum(keep.astype(jnp.int32), axis=1) - 1,
                         A)
        s["act"] = jnp.zeros_like(s["act"]).at[kk[:, None], cpos].set(
            wida.astype(s["act"].dtype))
        s["na"] = keep.sum(axis=1).astype(s["na"].dtype)
        # retire DONE warps from residency, admit pending warps
        donep = posv & act[:, None] & (stw == DONE)
        s["res"] = s["res"].at[kk[:, None], wida].min(~donep)
        s["nr"] = s["nr"] - donep.sum(axis=1).astype(s["nr"].dtype)
        nadm = jnp.maximum(
            jnp.minimum(co["nw"] - s["ptr"], co["rcap"] - s["nr"]), 0)
        nadm = jnp.where(act, nadm, 0)
        newres = (wI[None, :] >= s["ptr"][:, None]) \
            & (wI[None, :] < (s["ptr"] + nadm)[:, None])
        s["res"] = s["res"] | newres
        s["nr"] = s["nr"] + nadm
        s["ptr"] = s["ptr"] + nadm
        # one activation pass covers the scalar engines' interleaved
        # deactivate()/cleanup activate() calls (admitted wids exceed every
        # resident wid, so ascending-wid order is the same either way)
        s = activation(s, act)
        # terminate lanes with nothing resident and nothing pending
        fin = act & (s["nr"] == 0) & (s["ptr"] >= co["nw"])
        s["alive"] = s["alive"] & ~fin
        adv = act & ~fin
        # classify the zero-issue cycle + find the next event horizon —
        # all elementwise reads of the status/pc/readiness planes (status
        # ACTIVE/PREFETCH <=> active-list membership, so no slot gathers)
        stc = s["wf"][:, :, F_ST]
        pcw = s["wf"][:, :, F_PC]
        livew = (stc == ACTIVE) & (pcw < co["endpc"][:, None])
        cycf = s["cycle"].astype(f64)
        cmaxw = s["cf"][:, :, 0]
        cmemw = s["cf"][:, :, 1]
        saw_pf = (stc == PREFETCH).any(axis=1)
        saw_mem = (livew & (cmemw > cycf[:, None])).any(axis=1)
        saw_dep = (livew & (cmaxw > cycf[:, None])).any(axis=1)
        drain = (s["ptr"] >= co["nw"]) & (s["nr"] < co["tcap"])
        cat = jnp.where(drain, _CAT_INDEX["drain"],
              jnp.where(struct, _CAT_INDEX["bank_conflict"],
              jnp.where(saw_pf, _CAT_INDEX["prefetch_stall"],
              jnp.where(saw_mem, _CAT_INDEX["mem_stall"],
              jnp.where(saw_dep, _CAT_INDEX["alu_dep"],
                        _CAT_INDEX["scheduler_idle"])))))
        cyc = s["cycle"]
        INF = jnp.inf
        colf = s["col"].min(axis=1)
        c1 = jnp.where(colf > cyc, colf.astype(f64), INF)
        wnp = s["res"] & ((stc == WAIT) | (stc == PREFETCH))
        c2 = jnp.where(wnp, s["wf"][:, :, F_RA].astype(f64), INF).min(axis=1)
        tsv = s["cf"][:, :, 2: 2 + S]
        tpv = s["cf"][:, :, 2 + S:]
        tsrc = jnp.where(livew[:, :, None] & (tsv > cycf[:, None, None]),
                         tsv, INF).min(axis=(1, 2))
        tpd = jnp.where(livew[:, :, None] & (tpv > cycf[:, None, None]),
                        tpv, INF).min(axis=(1, 2))
        best = jnp.minimum(jnp.minimum(c1, c2), jnp.minimum(tsrc, tpd))
        nxt = jnp.where(jnp.isinf(best), cyc + 1,
                        jnp.maximum(best.astype(i64), cyc + 1))
        delta = jnp.where(issue_any, 1, nxt - cyc)
        cati = jnp.where(issue_any, 0, cat)
        oh = (jnp.arange(NCAT)[None, :] == cati[:, None]) & adv[:, None]
        s["bd"] = s["bd"] + jnp.where(oh, delta[:, None], 0)
        s["cycle"] = cyc + jnp.where(adv, delta, 0)
        if _DEBUG_HOOK is not None:  # debug-only tracing (no jit cost when None)
            _DEBUG_HOOK({"cycle": cyc, "issue": issue_any, "cat": cati,
                         "delta": delta, "struct": struct, "na": s["na"],
                         "act": s["act"], "s": s})
        return s

    def running(s):
        return jnp.any(s["alive"]) & (s["guard"] <= co["tmax"])

    return lax.while_loop(running, tick, st)


# Eager-only per-tick trace hook (set under jax.disable_jit(); checked at
# trace time, so the jitted path never pays for it).
_DEBUG_HOOK = None

# Launch accounting for the perf ledger: XLA compile wall vs steady-state
# simulation wall, plus the fused-loop tick count (how hard the
# event-horizon skip is working).  `bench_sim` snapshots this around its
# batch A/B so `BENCH_sim.json` can report `compile_s` separately.
RUN_STATS = {"compile_s": 0.0, "run_s": 0.0,
             "compiles": 0, "launches": 0, "ticks": 0}


def reset_run_stats() -> dict:
    """Zero the compile/run accounting (returns the live dict)."""
    for k, v in RUN_STATS.items():
        RUN_STATS[k] = type(v)(0)
    return RUN_STATS


_COMPILED: dict = {}


def _aot_compile(co, st):
    """Compile (or fetch) the executable for this chunk's shape bucket.

    Ahead-of-time ``lower().compile()`` instead of a bare ``jax.jit`` call
    so compilation wall is attributed to ``RUN_STATS["compile_s"]`` and the
    launch wall to ``RUN_STATS["run_s"]`` — the honest throughput split the
    ledger reports (the persistent compile cache still applies)."""
    sig = (tuple(sorted((k, v.shape, str(v.dtype)) for k, v in co.items())),
           tuple(sorted((k, v.shape, str(v.dtype)) for k, v in st.items())))
    fn = _COMPILED.get(sig)
    if fn is None:
        jax, _, _ = _jax()
        _maybe_enable_compile_cache()
        t0 = time.perf_counter()
        fn = jax.jit(_run_jax).lower(co, st).compile()
        RUN_STATS["compile_s"] += time.perf_counter() - t0
        RUN_STATS["compiles"] += 1
        _COMPILED[sig] = fn
    return fn


def _run_lanes(lanes: Sequence[_Lane]) -> list:
    from jax.experimental import enable_x64

    co, st = _build(lanes)
    with enable_x64():  # the scalar engines do Python-f64 arithmetic
        fn = _aot_compile(co, st)
        t0 = time.perf_counter()
        out = fn(co, st)
        out = {k: np.asarray(v) for k, v in out.items()}
        RUN_STATS["run_s"] += time.perf_counter() - t0
        RUN_STATS["launches"] += 1
        RUN_STATS["ticks"] += int(out["guard"])
    if out["alive"].any():
        raise RuntimeError("batch simulator wedged")
    return [_extract(ln, i, out) for i, ln in enumerate(lanes)]


def _extract(lane: _Lane, i: int, out: dict):
    cfg = lane.cfg
    if out["budget"][i]:
        return SimBudgetExceeded(cfg.design, lane.workload.name,
                                 cfg.max_cycles, int(out["cycle"][i]))
    bd = new_breakdown()
    for j, c in enumerate(CYCLE_CATEGORIES):
        bd[c] = int(out["bd"][i, j])
    res = SimResult(design=cfg.design, workload=lane.workload.name,
                    cycles=int(out["cycle"][i]),
                    instructions=int(out["wf"][i, :, F_IS].sum()),
                    resident_warps=lane.occupancy,
                    rfc_hits=int(out["ch"][i]),
                    rfc_accesses=int(out["ca"][i]),
                    mrf_accesses=int(out["cm"][i]),
                    prefetch_ops=int(out["cpo"][i]),
                    prefetch_cycles=int(out["cpc"][i]),
                    prefetch_stall_cycles=int(out["cps"][i]),
                    writeback_regs=int(out["cwb"][i]),
                    activations=int(out["cact"][i]),
                    cycle_breakdown=bd)
    check_breakdown(bd, res.cycles, cfg.design, lane.workload.name)
    return res


# --------------------------------------------------------------------------
# Public API
# --------------------------------------------------------------------------

# Lanes per compiled run: bounds peak memory on huge sweeps while keeping
# each launch big enough to amortize dispatch.
_MAX_LANES = 512

# Lanes per sub-chunk within a shape group (see `_chunk_lanes`): small
# enough that a length-sorted group retires its short lanes early instead
# of carrying them to the group's slowest straggler, big enough that the
# lane-independent while-loop overhead stays a few percent of the launch.
_SUB_LANES = 8


def run_batch(jobs: Sequence[tuple[Workload, SimConfig]], *,
              fallback: bool = True) -> list:
    """Simulate many (workload, config) jobs; vectorized where supported.

    Returns one outcome per job, in order: a `SimResult`, or a
    `SimBudgetExceeded` *instance* (not raised) for lanes that blew their
    ``max_cycles`` watchdog — the sweep service records those as outcomes.
    Unsupported configs (see `batch_supported`) fall back to the scalar
    event-heap engine per job; pass ``fallback=False`` to get a
    `ValueError` instead.
    """
    outcomes: list = [None] * len(jobs)
    lanes: list[_Lane] = []
    idxs: list[int] = []
    for i, (w, cfg) in enumerate(jobs):
        if batch_supported(cfg):
            parse_interval_strategy(cfg.interval_strategy)  # raise like engine
            code = _encode_plan(w, cfg)
            lanes.append(_Lane(w, cfg, code, _occupancy(w, cfg)))
            idxs.append(i)
        elif fallback:
            try:
                outcomes[i] = simulate(w, cfg)
            except SimBudgetExceeded as e:
                outcomes[i] = e
        else:
            raise ValueError(
                f"config not batch-supported (scheduler={cfg.scheduler!r}, "
                f"bank_model={cfg.bank_model!r}, trace={cfg.trace}, "
                f"num_sms={cfg.num_sms})")
    for chunk, chunk_idxs in _chunk_lanes(lanes, idxs):
        for i, r in zip(chunk_idxs, _run_lanes(chunk)):
            outcomes[i] = r
    return outcomes


def _chunk_lanes(lanes: list[_Lane], idxs: list[int]):
    """Partition lanes into compile-friendly, utilization-friendly chunks.

    Lanes are grouped by the shape dimensions that dominate per-tick cost —
    active-list width (8 for the cached designs vs. all-resident for
    BL/RFC/Ideal), warp count, and the shared-RFC entry table — so a chunk
    of LTRF lanes pays (K, 8) scheduler scans instead of inheriting (K, 64)
    from one BL bystander.  Within a group, lanes are ordered by a crude
    run-length estimate: the lockstep while-loop runs until the *slowest*
    lane finishes, so co-scheduling similar-length lanes keeps the rest of
    the chunk from idling (and finished lanes from being dead weight).

    Groups are then cut into sub-chunks of at most `_SUB_LANES` lanes.
    Per-tick cost is nearly linear in the lane count (the K-independent
    loop overhead is small), so a finished lane that stays resident until
    the chunk's slowest lane retires costs almost as much as a live one —
    on the tracked sweep the longest lane runs ~5x the mean, and one big
    chunk burns that whole imbalance as dead weight.  Length-sorted
    sub-chunks retire short lanes in cheap early launches and leave the
    stragglers in small tail chunks, at the price of a few extra XLA
    shapes (compiled once, persistently cached)."""
    groups: dict[tuple, list[int]] = {}
    for j, ln in enumerate(lanes):
        cfg = ln.cfg
        sig = (_bucket(cfg.num_warps, 4), _bucket(_acap(ln), 2),
               cfg.rfc_entries if cfg.design == "RFC" else 0)
        groups.setdefault(sig, []).append(j)
    for sig, members in groups.items():
        members.sort(key=lambda j: _length_hint(lanes[j]))
        for lo in range(0, len(members), _SUB_LANES):
            part = members[lo: lo + _SUB_LANES]
            yield [lanes[j] for j in part], [idxs[j] for j in part]


def _length_hint(ln: _Lane) -> float:
    """Rough relative cycle count (ordering heuristic only)."""
    cfg = ln.cfg
    return (ln.code.n_pc * ln.occupancy
            * (cfg.mrf_cycles + cfg.mem_cycles * (1.0 - cfg.l1_hit_rate)))


def simulate_batch(jobs: Sequence[tuple[Workload, SimConfig]], *,
                   fallback: bool = True) -> list[SimResult]:
    """Like `run_batch` but raises the first `SimBudgetExceeded` (matching
    the scalar `simulate` contract)."""
    outcomes = run_batch(jobs, fallback=fallback)
    for r in outcomes:
        if isinstance(r, SimBudgetExceeded):
            raise r
    return outcomes


def simulate_one(workload: Workload, cfg: SimConfig) -> SimResult:
    """Single-job convenience wrapper over the batch path."""
    return simulate_batch([(workload, cfg)])[0]
