from .engine import SimConfig, SimResult, Simulator, simulate, DESIGNS
from .designs import (
    TABLE2, baseline_config, design_config, max_tolerable_latency,
    normalized_ipc, run,
)

__all__ = [
    "SimConfig", "SimResult", "Simulator", "simulate", "DESIGNS",
    "TABLE2", "baseline_config", "design_config", "max_tolerable_latency",
    "normalized_ipc", "run",
]
