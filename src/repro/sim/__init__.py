from .engine import (
    BANK_MODELS, DESIGNS, INTERVAL_STRATEGIES, RENUMBER_MODES, SCHEDULERS,
    SimBudgetExceeded, SimConfig, SimResult, Simulator, simulate,
)
from .designs import (
    TABLE2, baseline_config, design_config, max_tolerable_latency,
    normalized_ipc, run,
)
from .gpu import GpuResult, simulate_gpu
from .batch import (
    BATCH_REV, batch_supported, run_batch, simulate_batch, simulate_one,
)
from .analytic import (
    ANALYTIC_REV, CALIB_REV, TIERS, AnalyticModelError, AnalyticResult,
    Calibration, CalibrationError, analytic_supported, estimate,
    fit_calibration, load_calibration, pareto_frontier, save_calibration,
    spearman_rho,
)

__all__ = [
    "SimBudgetExceeded",
    "SimConfig", "SimResult", "Simulator", "simulate", "DESIGNS",
    "SCHEDULERS", "BANK_MODELS", "RENUMBER_MODES", "INTERVAL_STRATEGIES",
    "GpuResult", "simulate_gpu",
    "BATCH_REV", "batch_supported", "run_batch", "simulate_batch",
    "simulate_one",
    "TABLE2", "baseline_config", "design_config", "max_tolerable_latency",
    "normalized_ipc", "run",
    "ANALYTIC_REV", "CALIB_REV", "TIERS", "AnalyticModelError",
    "AnalyticResult", "Calibration", "CalibrationError",
    "analytic_supported", "estimate", "fit_calibration", "load_calibration",
    "pareto_frontier", "save_calibration", "spearman_rho",
]
