"""Golden reference simulator: the original (pre-optimization) engine.

This is the seed implementation of the discrete-event SM model, kept
verbatim (unoptimized, no compile cache, linear scans) as the behavioural
oracle for the event-heap engine in `engine.py`.  The golden-equivalence
harness (tests/test_sim_golden.py, benchmarks) asserts that both engines
produce bit-identical `SimResult` counters for every (design, workload)
pair.  Do not optimize this file; optimize `engine.py` and prove equality.

The golden engine predates the pluggable pass pipeline: it always runs the
paper's interval-formation algorithm (``SimConfig.interval_strategy`` is
ignored, exactly like the gto/lrr schedulers and multi-SM knobs), so
differential comparisons must pin ``interval_strategy="paper"``.
"""
from __future__ import annotations

from collections import OrderedDict

from repro.core.intervals import form_register_intervals
from repro.core.ir import Instr
from repro.core.prefetch import prefetch_schedule
from repro.core.renumber import renumber_registers
from repro.obs.attribution import (
    check_breakdown, classify_stall, new_breakdown,
)
from repro.workloads.suite import Workload

from .engine import (
    ACTIVE, DONE, INACTIVE_READY, INACTIVE_WAIT, PREFETCH,
    SimBudgetExceeded, SimConfig, SimResult, _Warp,
)

class GoldenSimulator:
    def __init__(self, cfg: SimConfig, workload: Workload) -> None:
        self.cfg = cfg
        self.w = workload
        self.prog, self.block_interval, self.pf_ops = self._compile()
        self.result = SimResult(design=cfg.design, workload=workload.name,
                                cycles=0, instructions=0,
                                resident_warps=self._occupancy())
        self._order_index = {l: i for i, l in enumerate(self.prog.order)}
        self._lru_counter = 0
        self._dram_next = 0

    # ------------------------------------------------------------------ static
    def _compile(self):
        cfg = self.cfg
        prog = self.w.program
        self.live_sets = {}
        if cfg.design in ("BL", "RFC", "Ideal"):
            return prog, {}, {}
        if cfg.design == "SHRF":
            an = form_register_intervals(prog, cfg.interval_cap, strand_mode=True)
        else:
            an = form_register_intervals(prog, cfg.interval_cap)
            if cfg.design == "LTRF_conf":
                rr = renumber_registers(an, num_banks=cfg.num_banks)
                an = rr.analysis
        ops = {op.interval_id: op
               for op in prefetch_schedule(an, num_banks=cfg.num_banks)}
        if cfg.design == "LTRF_plus":
            # LTRF+ (paper §3.2): only LIVE registers are written back on
            # deactivation and refetched on activation; dead working-set
            # entries get cache space but no data movement.
            from repro.core.liveness import block_liveness
            live_in, _ = block_liveness(an.prog)
            for iv in an.intervals:
                self.live_sets[iv.iid] = frozenset(
                    live_in[iv.header] & iv.working_set)
        return an.prog, dict(an.block_interval), ops

    def _occupancy(self) -> int:
        cfg = self.cfg
        cap_kb = cfg.rf_size_kb + (cfg.rfc_size_kb if cfg.add_rfc_to_main else 0)
        warp_regs_capacity = cap_kb * 1024 // 128
        per_warp = max(self.w.regs_per_thread, 1)
        return max(1, min(cfg.num_warps, warp_regs_capacity // per_warp))

    # ----------------------------------------------------------------- dynamic
    def run(self) -> SimResult:
        cfg = self.cfg
        res = self.result
        cached = cfg.design in ("LTRF", "LTRF_conf", "LTRF_plus", "SHRF")
        # RFC is a plain hardware cache shared by ALL resident warps -- the
        # paper's Fig. 4 thrashing story (8-30% hit rate) requires the full
        # warp population to contend for the 128 entries.
        two_level = cached
        resident_cap = res.resident_warps
        active_cap = min(cfg.active_slots, resident_cap) if two_level else resident_cap
        # Kernel-tail threshold for cycle attribution (see engine.run).
        tail_cap = min(cfg.active_slots, resident_cap)

        warps = [_Warp(wid=i, block=self.prog.entry) for i in range(cfg.num_warps)]
        pending = list(range(cfg.num_warps))
        resident: list[int] = []
        active: list[int] = []
        self._pf_free = [0] * cfg.max_inflight_prefetch
        self._col_free = [0] * cfg.num_collectors
        # MRF bank throughput: slow cells (DWM shift, TFET) pipeline only
        # partially (sub-banked arrays, depth ~6), so aggregate MRF bandwidth
        # is num_banks / (initiation interval = latency/6) accesses per cycle.
        self._mrf_rate = cfg.num_banks / max(cfg.mrf_cycles / 6.0, 1.0)
        self._mrf_tokens = float(cfg.num_banks)
        self._mrf_last = 0
        rfc_lru: OrderedDict[tuple[int, int], None] = OrderedDict()

        def admit() -> None:
            while pending and len(resident) < resident_cap:
                resident.append(pending.pop(0))

        def activate(cycle: int) -> None:
            while len(active) < active_cap:
                cand = [w for w in resident if warps[w].status == INACTIVE_READY]
                if not cand:
                    break
                wid = cand[0]
                wp = warps[wid]
                res.activations += 1
                if cached:
                    self._start_prefetch(wp, cycle, force=True)
                active.append(wid)
                if wp.status != PREFETCH:
                    wp.status = ACTIVE

        def deactivate(wid: int, until: float, cycle: int) -> None:
            wp = warps[wid]
            active.remove(wid)
            wp.status = INACTIVE_WAIT
            wp.ready_at = int(until)
            if cached and wp.interval >= 0:
                ws = self.pf_ops.get(wp.interval)
                if ws is not None:
                    n_wb = len(self.live_sets.get(wp.interval, ws.bitvector)) \
                        if cfg.design == "LTRF_plus" else len(ws.bitvector)
                    res.writeback_regs += n_wb
                    res.mrf_accesses += n_wb
            wp.interval = -1  # must re-prefetch on activation
            activate(cycle)

        admit()
        activate(0)

        # Cycle attribution (repro.obs.attribution): charged at the same two
        # advance sites as the fast engine, from identically-derived state —
        # `cycle_breakdown` is part of the bit-identical SimResult contract.
        bd = res.cycle_breakdown = new_breakdown()
        cycle = 0
        max_cycles = cfg.max_cycles
        guard = 0
        while True:
            guard += 1
            if guard > 8_000_000:
                raise RuntimeError("simulator wedged")
            if max_cycles and cycle > max_cycles:
                raise SimBudgetExceeded(cfg.design, self.w.name,
                                        max_cycles, cycle)

            for wid in resident:
                wp = warps[wid]
                if wp.status == INACTIVE_WAIT and wp.ready_at <= cycle:
                    wp.status = INACTIVE_READY
                elif wp.status == PREFETCH and wp.ready_at <= cycle:
                    wp.status = ACTIVE
            activate(cycle)

            issued_now = 0
            struct_stall = False
            mem_stalled: list[tuple[int, float]] = []
            for _ in range(cfg.issue_width):
                wid = self._pick(warps, active, cycle, mem_stalled)
                if wid is None:
                    break
                if self._issue(warps[wid], cycle, rfc_lru):
                    issued_now += 1
                else:
                    # a ready warp blocked by RF structure (collector / MRF
                    # bandwidth): remembered for cycle attribution
                    struct_stall = True

            if two_level:
                for wid, until in mem_stalled:
                    if warps[wid].status == ACTIVE and wid in active:
                        deactivate(wid, until, cycle)

            for wid in list(active):
                if warps[wid].status == DONE:
                    active.remove(wid)
                    resident.remove(wid)
                    admit()
                    activate(cycle)
            if not resident and not pending:
                break

            if issued_now:
                bd["issue"] += 1
                cycle += 1
            else:
                drain = not pending and len(resident) < tail_cap
                cat = self._classify_stall(warps, active, cycle,
                                           struct_stall, drain)
                nxt = self._next_event(warps, resident, cycle)
                bd[cat] += nxt - cycle
                cycle = nxt

        res.cycles = cycle
        res.instructions = sum(w.issued for w in warps)
        check_breakdown(bd, cycle, cfg.design, self.w.name)
        return res

    # ----------------------------------------------------------------- helpers
    def _start_prefetch(self, wp: _Warp, cycle: int, force: bool = False) -> None:
        cfg = self.cfg
        iid = self.block_interval.get(wp.block, -1)
        if iid < 0:
            return
        if not force and iid == wp.interval:
            return
        op = self.pf_ops.get(iid)
        wp.interval = iid
        if op is None or not op.bitvector:
            return
        fetch = op.bitvector
        rounds = op.serial_rounds
        if cfg.design == "LTRF_plus":
            # fetch only the live subset (dead entries: space, no data)
            live = self.live_sets.get(iid)
            if live is not None:
                fetch = live if live else frozenset()
                if not fetch:
                    return
                occ = [0] * cfg.num_banks
                from repro.core.renumber import bank_of
                for r in fetch:
                    occ[bank_of(r, cfg.num_banks)] += 1
                rounds = max(occ) if any(occ) else 1
        lat = rounds * cfg.mrf_cycles \
            + len(fetch) / cfg.xbar_regs_per_cycle
        slot = min(range(len(self._pf_free)), key=self._pf_free.__getitem__)
        start = max(cycle, self._pf_free[slot])
        done = int(start + lat)
        self._pf_free[slot] = done
        wp.status = PREFETCH
        wp.ready_at = done
        self.result.prefetch_ops += 1
        self.result.prefetch_cycles += int(lat)
        # the warp is blocked from issue until the prefetch lands (including
        # any wait for a free prefetch slot)
        self.result.prefetch_stall_cycles += done - cycle
        self.result.mrf_accesses += len(fetch)
        for r in op.bitvector:
            wp.reg_ready[r] = max(wp.reg_ready.get(r, 0), done)

    def _pick(self, warps, active, cycle, mem_stalled):
        """Round-robin over active warps; also reports warps stalled on
        memory-produced values (two-level deactivation candidates)."""
        if not active:
            return None
        start = cycle % len(active)
        order = active[start:] + active[:start]
        for wid in order:
            wp = warps[wid]
            if wp.status != ACTIVE:
                continue
            ins = self._fetch(wp)
            if ins is None:
                wp.status = DONE
                continue
            blocked_on_mem = 0.0
            ready = True
            for s in ins.srcs:
                t = wp.reg_ready.get(s, 0)
                if t > cycle:
                    ready = False
                    # only a *long-latency* (L1-miss) wait justifies swapping
                    # the warp out of the active set
                    if wp.reg_from_mem.get(s) and t - cycle > 2 * self.cfg.l1_cycles:
                        blocked_on_mem = max(blocked_on_mem, t)
            for p in ins.psrcs:
                if wp.pred_ready.get(p, 0) > cycle:
                    ready = False
            if ready:
                return wid
            if blocked_on_mem:
                mem_stalled.append((wid, blocked_on_mem))
        return None

    def _fetch(self, wp: _Warp) -> Instr | None:
        bb = self.prog.blocks[wp.block]
        while wp.idx >= len(bb.instrs):
            i = self._order_index[wp.block]
            if i + 1 >= len(self.prog.order):
                return None
            wp.block = self.prog.order[i + 1]
            wp.idx = 0
            bb = self.prog.blocks[wp.block]
        return bb.instrs[wp.idx]

    def _mrf_bandwidth(self, cycle: int, n: int) -> bool:
        """Consume ``n`` MRF bank slots; False => structural stall."""
        cfg = self.cfg
        if cycle > self._mrf_last:
            self._mrf_tokens = min(
                float(cfg.num_banks),
                self._mrf_tokens + self._mrf_rate * (cycle - self._mrf_last))
            self._mrf_last = cycle
        if self._mrf_tokens < n:
            return False
        self._mrf_tokens -= n
        return True

    def _mrf_next_free(self, cycle: int, n: int = 1) -> int:
        deficit = max(0.0, n - self._mrf_tokens)
        return cycle + max(1, int(deficit / self._mrf_rate))

    def _grab_collector(self, cycle: int, hold: float) -> bool:
        # banks are pipelined: a collector is held for the *gather* time (a
        # few cycles), not the full access latency — latency shows up in the
        # dependency chain (read + execute + writeback), not as a hard
        # throughput ceiling.
        del hold
        slot = min(range(len(self._col_free)), key=self._col_free.__getitem__)
        if self._col_free[slot] > cycle:
            return False
        self._col_free[slot] = cycle + self.cfg.base_rf_cycles
        return True

    def _write_latency(self, wp: _Warp, ins: Instr, rfc_lru) -> float:
        """Cycles until a written register becomes readable (writeback)."""
        cfg = self.cfg
        if cfg.design == "Ideal":
            return cfg.base_rf_cycles
        if cfg.design == "BL":
            return cfg.mrf_cycles
        # RFC and the LTRF family write into the register cache
        return float(cfg.rfc_cycles)

    def _operand_latency(self, wp: _Warp, ins: Instr, rfc_lru, cycle: int) -> float | None:
        """Register read latency; None => structural stall (no collector)."""
        cfg = self.cfg
        res = self.result
        if cfg.design == "Ideal":
            if not self._grab_collector(cycle, cfg.base_rf_cycles):
                return None
            return cfg.base_rf_cycles
        if cfg.design == "BL":
            n_acc = len(ins.srcs) + len(ins.dsts)
            if n_acc and not self._mrf_bandwidth(cycle, n_acc):
                return None
            if not self._grab_collector(cycle, cfg.mrf_cycles):
                return None
            res.mrf_accesses += n_acc
            return cfg.mrf_cycles
        if cfg.design == "RFC":
            misses = 0
            hits = []
            for r in list(ins.srcs) + list(ins.dsts):
                key = (wp.wid, r)
                if key in rfc_lru:
                    hits.append(key)
                else:
                    misses += 1
            if misses and not self._mrf_bandwidth(cycle, misses):
                return None
            if not self._grab_collector(cycle, cfg.mrf_cycles if misses else cfg.rfc_cycles):
                return None
            res.rfc_accesses += len(ins.srcs) + len(ins.dsts)
            res.rfc_hits += len(hits)
            res.mrf_accesses += misses
            for key in hits:
                rfc_lru.move_to_end(key)
            for r in list(ins.srcs) + list(ins.dsts):
                key = (wp.wid, r)
                if key not in rfc_lru:
                    rfc_lru[key] = None
                    if len(rfc_lru) > cfg.rfc_entries:
                        rfc_lru.popitem(last=False)
            return cfg.mrf_cycles if misses else float(cfg.rfc_cycles)
        # LTRF-family: every in-interval access hits the register cache
        if not self._grab_collector(cycle, cfg.rfc_cycles):
            return None
        res.rfc_accesses += len(ins.srcs) + len(ins.dsts)
        res.rfc_hits += len(ins.srcs) + len(ins.dsts)
        return float(cfg.rfc_cycles)

    def _mem_latency(self, wp: _Warp, cycle: int) -> tuple[int, bool]:
        """(latency, is_l1_miss) with deterministic jitter + DRAM queuing.

        Misses are serviced by a single-server DRAM queue (one cache line per
        ``dram_interval`` cycles per SM): memory-heavy kernels saturate DRAM
        bandwidth regardless of TLP — which is exactly why the paper's
        register-insensitive workloads gain nothing from bigger register
        files."""
        cfg = self.cfg
        h = (wp.wid * 2654435761 + wp.mem_ops * 40503 + cfg.seed * 97) & 0xFFFF
        wp.mem_ops += 1
        hit_rate = getattr(self.w, 'l1_hit', cfg.l1_hit_rate)
        if (h / 0xFFFF) < hit_rate:
            return cfg.l1_cycles, False
        spread = ((h >> 3) / 0x1FFF - 0.5) * 0.6
        start = max(cycle, self._dram_next)
        self._dram_next = start + cfg.dram_interval
        queue = start - cycle
        return int(queue + cfg.mem_cycles * (1.0 + spread)), True

    def _issue(self, wp: _Warp, cycle: int, rfc_lru) -> bool:
        """Issue the warp's next instruction. Returns True if issued."""
        cfg = self.cfg
        ins = self._fetch(wp)
        assert ins is not None and wp.status == ACTIVE

        if ins.op == "bra":
            wp.issued += 1
            if self._branch_taken(wp, ins):
                wp.block, wp.idx = ins.target, 0
            else:
                wp.idx += 1
            self._maybe_prefetch_edge(wp, cycle)
            return True
        if ins.op == "exit":
            wp.issued += 1
            wp.status = DONE
            return True

        read_lat = self._operand_latency(wp, ins, rfc_lru, cycle)
        if read_lat is None:
            return False  # structural stall: collectors busy
        wp.issued += 1
        done_at = cycle + read_lat
        wlat = self._write_latency(wp, ins, rfc_lru)
        if ins.op == "set":
            done_at += cfg.alu_cycles
            if ins.pdst is not None:
                wp.pred_ready[ins.pdst] = done_at  # predicates live in the scoreboard
        elif ins.op == "ld":
            lat, _miss = self._mem_latency(wp, cycle)
            done_at += lat + wlat
            for d in ins.dsts:
                wp.reg_ready[d] = done_at
                wp.reg_from_mem[d] = True
        else:
            done_at += cfg.alu_cycles + wlat
            for d in ins.dsts:
                wp.reg_ready[d] = done_at
                wp.reg_from_mem[d] = False
        wp.idx += 1
        self._maybe_prefetch_edge(wp, cycle)
        return True

    def _maybe_prefetch_edge(self, wp: _Warp, cycle: int) -> None:
        if self.cfg.design not in ("LTRF", "LTRF_conf", "SHRF"):
            return
        if wp.status != ACTIVE:
            return
        if self._fetch(wp) is None:
            return
        iid = self.block_interval.get(wp.block, -1)
        if iid >= 0 and iid != wp.interval:
            self._start_prefetch(wp, cycle)

    def _branch_taken(self, wp: _Warp, ins: Instr) -> bool:
        if not ins.psrcs:
            return True
        target = ins.target
        trips = self.w.trips.get(target)
        if trips is not None:
            c = wp.loop_counters.get(target, 0) + 1
            if c < trips:
                wp.loop_counters[target] = c
                return True
            wp.loop_counters[target] = 0
            return False
        key = (wp.block, wp.idx)
        v = wp.diamond_visits.get(key, 0)
        wp.diamond_visits[key] = v + 1
        h = (wp.wid * 31 + v * 17 + self.cfg.seed) & 0xFF
        return bool(h & 1)

    def _classify_stall(self, warps, active, cycle: int,
                        struct_stall: bool, drain: bool) -> str:
        """Attribute one zero-issue cycle (see repro.obs.attribution).

        Derives the same booleans as the fast engine's classifier — a
        prefetching warp in the active set, a pending memory-produced
        source, any pending operand — by direct scan, and defers the
        precedence decision to the shared `classify_stall`."""
        if drain or struct_stall:
            return classify_stall(drain, struct_stall, False, False, False)
        saw_prefetch = saw_mem = saw_dep = False
        for wid in active:
            wp = warps[wid]
            if wp.status == PREFETCH:
                saw_prefetch = True
            elif wp.status == ACTIVE:
                ins = self._fetch(wp)
                if ins is None:
                    continue
                pend = False
                for s in ins.srcs:
                    t = wp.reg_ready.get(s, 0)
                    if t > cycle:
                        pend = True
                        if wp.reg_from_mem.get(s):
                            saw_mem = True
                for p in ins.psrcs:
                    if wp.pred_ready.get(p, 0) > cycle:
                        pend = True
                if pend:
                    saw_dep = True
        return classify_stall(False, False, saw_prefetch, saw_mem, saw_dep)

    def _next_event(self, warps, resident, cycle: int) -> int:
        nxt = [min(self._col_free)] if self._col_free else []
        nxt = [t for t in nxt if t > cycle]
        for wid in resident:
            wp = warps[wid]
            if wp.status in (INACTIVE_WAIT, PREFETCH):
                nxt.append(wp.ready_at)
            elif wp.status == ACTIVE:
                ins = self._fetch(wp)
                if ins is not None:
                    for s in ins.srcs:
                        t = wp.reg_ready.get(s, 0)
                        if t > cycle:
                            nxt.append(t)
                    for p in ins.psrcs:
                        t = wp.pred_ready.get(p, 0)
                        if t > cycle:
                            nxt.append(t)
        if not nxt:
            return cycle + 1
        return max(int(min(nxt)), cycle + 1)


def golden_simulate(workload: Workload, cfg: SimConfig) -> SimResult:
    return GoldenSimulator(cfg, workload).run()
