"""Whole-GPU model: N SMs, a CTA dispatcher, and shared memory partitions.

The paper's headline numbers (34% speedup, Fig 20's warps-per-SM scaling)
are whole-GPU results; this module scales the single-SM discrete-event
engine (`engine.Simulator`) to a full chip without re-implementing it:

* a **CTA/thread-block dispatcher** splits the kernel's ``num_warps`` total
  warps into CTAs (``warps_per_cta`` warps each) and deals them round-robin
  across ``num_sms`` SMs, GPGPU-Sim style;
* each SM runs an independent per-SM `Simulator` with its warp share and a
  distinct deterministic seed (different CTAs see different data-dependent
  branches and memory jitter);
* the per-SM ``dram_interval`` hack becomes a **shared memory-partition
  model**: the chip has ``mem_partitions`` DRAM partitions (default: one
  per SM), each serving one line every ``dram_interval`` cycles, so the
  per-SM effective service interval is
  ``dram_interval * num_sms / mem_partitions`` — fewer partitions than SMs
  models global bandwidth contention, which is what caps the paper's
  register-insensitive workloads at GPU scale;
* per-SM `SimResult`s aggregate into a `GpuResult`: whole-GPU IPC (total
  instructions over the slowest SM's cycles — SMs run concurrently) and
  summed traffic counters; hand the `GpuResult` to `power.gpu_rf_power`
  for the whole-GPU §5.3 energy proxy (the benchmark harness records it
  per sweep config).

The invariant that makes this safe: ``num_sms=1`` with the ``two_level``
scheduler derives a per-SM config *equal* to the input config, so the GPU
model reproduces today's single-SM counters bit-identically
(tests/test_sim_golden.py pins this).

Warp-scheduler policies (``SimConfig.scheduler``)
-------------------------------------------------

==============  ============================================================
policy          description
==============  ============================================================
``two_level``   the paper's scheduler (Gebhart'11/Narasiman'11): only
                ``active_slots`` warps are schedulable; a warp stalling on
                an L1-miss value is swapped out for a ready warp, paying
                register-cache write-back + working-set re-prefetch in the
                cached designs.  Default, and the only policy the frozen
                golden engine implements.
``gto``         greedy-then-oldest: every resident warp is schedulable;
                issue sticks with the warp that issued last until it
                stalls, then falls back to the oldest (lowest-wid) ready
                warp.  No deactivation churn.
``lrr``         loose round-robin over all resident warps — the classic
                baseline scheduler.  No deactivation churn.
==============  ============================================================

For the non-cached designs (BL/RFC/Ideal) ``two_level`` and ``lrr`` issue
identically (there is no active-slot restriction without a register cache);
``gto`` differs on all designs.
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace

from repro.obs.attribution import merge_breakdowns
from repro.workloads.suite import Workload

from .engine import SCHEDULERS, SimConfig, SimResult, simulate

__all__ = [
    "SCHEDULERS", "GpuResult", "dispatch_ctas", "per_sm_configs",
    "gpu_jobs", "simulate_gpu",
]

# Per-SM seed offset: distinct CTAs must see distinct branch/memory jitter
# streams, while SM 0 keeps the chip-level seed (num_sms=1 bit-identity).
SM_SEED_STRIDE = 7919


def dispatch_ctas(num_warps: int, num_sms: int,
                  warps_per_cta: int = 4) -> list[int]:
    """Round-robin CTA dispatch: per-SM warp counts.

    The kernel's ``num_warps`` warps form ``ceil(num_warps/warps_per_cta)``
    CTAs (the last one possibly partial); CTA *i* lands on SM ``i % num_sms``.
    """
    if num_warps < 0 or num_sms < 1 or warps_per_cta < 1:
        raise ValueError("need num_warps >= 0, num_sms >= 1, warps_per_cta >= 1")
    shares = [0] * num_sms
    cta = 0
    remaining = num_warps
    while remaining > 0:
        take = warps_per_cta if remaining >= warps_per_cta else remaining
        shares[cta % num_sms] += take
        cta += 1
        remaining -= take
    return shares


def _effective_dram_interval(cfg: SimConfig) -> int | float:
    """Per-SM DRAM service interval under the shared-partition model.

    ``mem_partitions`` partitions (0 -> one per SM) each serve one line per
    ``dram_interval`` cycles; an SM's fair share of that global bandwidth is
    one line every ``dram_interval * num_sms / mem_partitions`` cycles.
    Integral results stay ``int`` so the uncontended case keys sim caches
    identically to the raw config.
    """
    partitions = cfg.mem_partitions or cfg.num_sms
    eff = cfg.dram_interval * cfg.num_sms / partitions
    return int(eff) if eff == int(eff) else eff


def per_sm_configs(cfg: SimConfig, warps_per_cta: int = 4) -> list[SimConfig]:
    """Derive one single-SM `SimConfig` per SM that received work.

    With ``num_sms=1`` (and default ``mem_partitions``) the derived config
    equals ``cfg`` — the GPU model degenerates to today's single-SM engine,
    caches included.
    """
    eff = _effective_dram_interval(cfg)
    shares = dispatch_ctas(cfg.num_warps, cfg.num_sms, warps_per_cta)
    return [
        replace(cfg, num_sms=1, mem_partitions=0, num_warps=share,
                seed=cfg.seed + SM_SEED_STRIDE * sm, dram_interval=eff)
        for sm, share in enumerate(shares) if share > 0
    ]


def gpu_jobs(workload: str, cfg: SimConfig,
             warps_per_cta: int = 4) -> list[tuple[str, SimConfig]]:
    """The per-SM (workload, config) jobs one GPU simulation expands into —
    hand these to `benchmarks.orchestrator.SimRunner.prefill` to run a
    GPU-scale sweep across the process pool with cache reuse."""
    return [(workload, c) for c in per_sm_configs(cfg, warps_per_cta)]


@dataclass
class GpuResult:
    """Aggregated whole-GPU counters (sums; ``cycles`` is the slowest SM)."""
    design: str
    workload: str
    num_sms: int
    scheduler: str
    cycles: int
    instructions: int
    resident_warps: int
    rfc_hits: int = 0
    rfc_accesses: int = 0
    mrf_accesses: int = 0
    prefetch_ops: int = 0
    prefetch_cycles: int = 0
    prefetch_stall_cycles: int = 0
    writeback_regs: int = 0
    activations: int = 0
    bank_conflicts: int = 0
    bank_conflict_cycles: int = 0
    cycle_breakdown: dict[str, int] = field(default_factory=dict)
    # ^ per-category cycle attribution summed over SMs (repro.obs): the
    #   breakdown accounts for every SM-cycle simulated, so it sums to
    #   sum(per_sm cycles) — NOT to the chip-level `cycles` (slowest SM).
    per_sm: tuple[SimResult, ...] = ()

    @property
    def ipc(self) -> float:
        """Whole-GPU IPC: SMs run concurrently, so the chip retires the
        total instruction count in the slowest SM's cycle count."""
        return self.instructions / max(self.cycles, 1)

    @property
    def hit_rate(self) -> float:
        return self.rfc_hits / max(self.rfc_accesses, 1)

    @property
    def bank_conflict_rate(self) -> float:
        """Extra bank-serialization rounds per retired instruction (chip)."""
        return self.bank_conflicts / max(self.instructions, 1)

    @property
    def sm_imbalance(self) -> float:
        """Slowest-SM cycles over mean SM cycles (1.0 = perfectly balanced)."""
        if not self.per_sm:
            return 1.0
        mean = sum(r.cycles for r in self.per_sm) / len(self.per_sm)
        return self.cycles / max(mean, 1e-9)


def aggregate(cfg: SimConfig, results: list[SimResult],
              workload: str) -> GpuResult:
    """Fold per-SM `SimResult`s into one `GpuResult`."""
    return GpuResult(
        design=cfg.design, workload=workload, num_sms=cfg.num_sms,
        scheduler=cfg.scheduler,
        cycles=max((r.cycles for r in results), default=0),
        instructions=sum(r.instructions for r in results),
        resident_warps=sum(r.resident_warps for r in results),
        rfc_hits=sum(r.rfc_hits for r in results),
        rfc_accesses=sum(r.rfc_accesses for r in results),
        mrf_accesses=sum(r.mrf_accesses for r in results),
        prefetch_ops=sum(r.prefetch_ops for r in results),
        prefetch_cycles=sum(r.prefetch_cycles for r in results),
        prefetch_stall_cycles=sum(r.prefetch_stall_cycles for r in results),
        writeback_regs=sum(r.writeback_regs for r in results),
        activations=sum(r.activations for r in results),
        bank_conflicts=sum(r.bank_conflicts for r in results),
        bank_conflict_cycles=sum(r.bank_conflict_cycles for r in results),
        cycle_breakdown=merge_breakdowns(r.cycle_breakdown for r in results),
        per_sm=tuple(results),
    )


def simulate_gpu(workload: Workload, cfg: SimConfig,
                 sim=simulate, warps_per_cta: int = 4) -> GpuResult:
    """Simulate a whole GPU: dispatch CTAs, run every SM, aggregate.

    ``sim`` accepts any ``(workload, SimConfig) -> SimResult`` callable, so
    callers can swap in the memoizing orchestrator runner
    (`benchmarks.orchestrator.SimRunner.sim`) — the per-SM jobs then hit the
    compile cache, the in-process memo, and the on-disk sim cache.
    """
    results = [sim(workload, c) for c in per_sm_configs(cfg, warps_per_cta)]
    name = workload if isinstance(workload, str) else workload.name
    return aggregate(cfg, results, name)
