"""The paper's §6 comparison points, as ready-made simulator configurations.

* ``BL``     — conventional non-cached register file (gets the 16KB the other
               designs spend on the RFC added to its MRF, per §6).
* ``RFC``    — hardware register file cache (Gebhart'11 ISCA).
* ``SHRF``   — software-managed hierarchy with strand-bounded prefetch
               (Gebhart'11 MICRO), i.e. LTRF with strands instead of
               register-intervals and no pass-2 merging.
* ``LTRF``   — the paper's design (register-interval prefetch).
* ``LTRF_conf`` — LTRF + compile-time register renumbering (§4).
* ``Ideal``  — enlarged register file with no latency increase.

Table 2 design points used in the evaluation:
  #6 TFET-SRAM: 8x capacity, 5.3x latency   #7 DWM: 8x capacity, 6.3x latency
"""
from __future__ import annotations

from .engine import SimConfig, SimResult, simulate
from repro.workloads.suite import Workload

TABLE2 = {
    1: dict(cap_mult=1, lat_mult=1.0),    # HP-SRAM baseline
    2: dict(cap_mult=8, lat_mult=1.25),   # HP-SRAM, 8x banks size
    3: dict(cap_mult=8, lat_mult=1.5),    # HP-SRAM, 8x banks
    4: dict(cap_mult=8, lat_mult=1.6),    # LSTP
    5: dict(cap_mult=8, lat_mult=2.8),    # LSTP, 8x banks
    6: dict(cap_mult=8, lat_mult=5.3),    # TFET
    7: dict(cap_mult=8, lat_mult=6.3),    # DWM
}

BASE_RF_KB = 256

# The latency-multiplier grid `max_tolerable_latency` walks; callers that
# pre-simulate the grid (benchmarks.paper_figs) import this so the two can
# never drift apart.
TOLERANCE_MULTS = (1, 2, 3, 4, 5, 6, 7, 8, 10, 12, 16)


def design_config(
    design: str,
    table2_config: int = 7,
    num_warps: int = 64,
    active_slots: int = 8,
    interval_cap: int = 16,
    mrf_latency_mult: float | None = None,
    rf_size_kb: int | None = None,
    num_sms: int = 1,
    scheduler: str = "two_level",
    mem_partitions: int = 0,
    bank_model: str = "none",
    renumber: str = "icg",
    interval_strategy: str = "paper",
    max_cycles: int = 0,
) -> SimConfig:
    """One design point.  GPU-scale knobs: ``num_sms`` > 1 (run the config
    through `repro.sim.gpu.simulate_gpu`; ``num_warps`` is then the kernel's
    whole-GPU warp count), ``scheduler`` picks the warp-scheduler policy,
    ``mem_partitions`` sizes the shared DRAM-partition model (0 = one per
    SM, i.e. uncontended fair share).  Bank-level knobs:
    ``bank_model="arbitrated"`` turns on same-cycle bank arbitration for
    operand reads/writebacks, ``renumber="identity"`` makes LTRF_conf skip
    the ICG renumbering pass (the §4.3 ablation axis).  Compiler knob:
    ``interval_strategy`` picks the interval-formation strategy for the
    LTRF-family designs (``"paper"``/``"capacity"``/``"fixed:N"``).
    Robustness knob: ``max_cycles`` arms the cycle-budget watchdog — a run
    that passes it raises `repro.sim.SimBudgetExceeded` (0 = unlimited)."""
    t = TABLE2[table2_config]
    size = rf_size_kb if rf_size_kb is not None else BASE_RF_KB * t["cap_mult"]
    mult = mrf_latency_mult if mrf_latency_mult is not None else t["lat_mult"]
    if design == "Ideal":
        mult = 1.0
    return SimConfig(
        design=design,
        mrf_latency_mult=mult,
        rf_size_kb=size,
        add_rfc_to_main=design in ("BL", "Ideal"),
        num_warps=num_warps,
        active_slots=active_slots,
        interval_cap=interval_cap,
        num_sms=num_sms,
        scheduler=scheduler,
        mem_partitions=mem_partitions,
        bank_model=bank_model,
        renumber=renumber,
        interval_strategy=interval_strategy,
        max_cycles=max_cycles,
    )


def baseline_config(num_warps: int = 64, num_sms: int = 1,
                    mem_partitions: int = 0,
                    bank_model: str = "none",
                    max_cycles: int = 0) -> SimConfig:
    """§6 normalization point: config #1 + the 16KB RFC space, no cache, 1x.

    At GPU scale the baseline keeps the default ``two_level`` scheduler
    (identical to ``lrr`` for the uncached BL design)."""
    return SimConfig(design="BL", mrf_latency_mult=1.0, rf_size_kb=BASE_RF_KB,
                     add_rfc_to_main=True, num_warps=num_warps,
                     num_sms=num_sms, mem_partitions=mem_partitions,
                     bank_model=bank_model, max_cycles=max_cycles)


def run(workload: Workload, cfg: SimConfig) -> SimResult:
    return simulate(workload, cfg)


def normalized_ipc(workload: Workload, cfg: SimConfig,
                   base: SimConfig | None = None) -> float:
    base = base or baseline_config(num_warps=cfg.num_warps)
    return simulate(workload, cfg).ipc / simulate(workload, base).ipc


def max_tolerable_latency(
    workload: Workload,
    design: str,
    loss: float = 0.05,
    mults: tuple[float, ...] = TOLERANCE_MULTS,
    num_warps: int = 64,
    sim=simulate,
) -> float:
    """§7.2 metric: largest MRF latency multiplier with <= ``loss`` IPC drop
    relative to the same design at 1x (main RF size held constant).

    ``sim`` lets callers swap in a memoizing runner (benchmarks.orchestrator)
    without changing the metric."""
    ref = sim(workload, design_config(design, mrf_latency_mult=1.0,
                                      rf_size_kb=BASE_RF_KB,
                                      num_warps=num_warps)).ipc
    best = 1.0
    for m in mults:
        if m == 1:
            continue
        ipc = sim(workload, design_config(design, mrf_latency_mult=float(m),
                                          rf_size_kb=BASE_RF_KB,
                                          num_warps=num_warps)).ipc
        if ipc >= (1 - loss) * ref:
            best = float(m)
        else:
            break
    return best
