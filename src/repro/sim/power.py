"""Register-file power proxy (paper §5.3 / Table 2).

Energy is dominated by per-access costs; we charge every access class with a
relative energy (baseline HP-SRAM MRF access = 1.0) and add a static term.
Constants follow Table 2's power column and CACTI-style capacity scaling
(a 16KB cache access is ~5x cheaper than a 256KB bank access; the WCB is a
small SRAM table; DWM cells draw 0.65x dynamic and far less static power).

The paper's claims this reproduces:
  * §5.3  LTRF consumes ~23% less power than the baseline RF (same tech),
          despite the added WCB/arbiter/cache structures;
  * §1    DWM main RF + LTRF cuts register-file power ~46% while 8x capacity.
"""
from __future__ import annotations

from dataclasses import dataclass, replace

from .engine import SimResult

# relative per-access energies (baseline 256KB HP-SRAM bank access = 1.0)
E_MRF = {"hp-sram": 1.0, "lstp-sram": 0.4, "tfet": 0.13, "dwm": 0.5}
E_RFC = 0.3      # 16KB cache bank
E_WCB = 0.08     # register-cache address table lookup
# static power per cycle, as a fraction of one MRF access energy
P_STATIC = {"hp-sram": 0.40, "lstp-sram": 0.16, "tfet": 0.05, "dwm": 0.10}
STATIC_CAP_SCALE = {"1x": 1.0, "8x": 8.0}  # static scales with capacity
RFC_STATIC = 0.05
WCB_OVERHEAD = 0.08  # arbiter + allocation units, always-on


@dataclass(frozen=True)
class PowerReport:
    design: str
    tech: str
    dynamic: float
    static: float

    @property
    def total(self) -> float:
        return self.dynamic + self.static


def rf_power(res: SimResult, tech: str = "hp-sram", cap_mult: int = 1,
             has_cache: bool | None = None) -> PowerReport:
    """Average register-file power (arbitrary units ~ energy/cycle)."""
    cycles = max(res.cycles, 1)
    cached = has_cache if has_cache is not None else res.rfc_accesses > 0
    dyn = res.mrf_accesses * E_MRF[tech]
    if cached:
        dyn += res.rfc_accesses * E_RFC
        dyn += (res.rfc_accesses + res.prefetch_ops) * E_WCB
    static = P_STATIC[tech] * (8.0 if cap_mult == 8 else 1.0)
    if cached:
        static += RFC_STATIC + WCB_OVERHEAD
    return PowerReport(design=res.design, tech=tech,
                       dynamic=dyn / cycles, static=static)


def gpu_rf_power(res, tech: str = "hp-sram", cap_mult: int = 1,
                 has_cache: bool | None = None) -> PowerReport:
    """Whole-GPU register-file power for a `repro.sim.gpu.GpuResult`.

    Dynamic energy is the chip-wide access total spread over the GPU's
    wall-clock (`GpuResult` sums the counters and takes the slowest SM's
    cycles — all SMs burn energy concurrently, so `rf_power`'s per-cycle
    arithmetic applies unchanged); static power is the per-SM static term
    times ``num_sms`` (idle SMs still leak).
    """
    p = rf_power(res, tech, cap_mult=cap_mult, has_cache=has_cache)
    return replace(p, static=p.static * res.num_sms)


def power_comparison(workload, table2_config: int = 7, sim=None):
    """BL (HP-SRAM 1x) vs LTRF on the Table-2 design point's technology.

    ``sim`` lets callers swap in a memoizing runner (benchmarks.orchestrator).
    """
    from .designs import baseline_config, design_config
    from .engine import simulate

    if sim is None:
        sim = simulate
    tech = {6: "tfet", 7: "dwm"}[table2_config]
    bl = sim(workload, baseline_config())
    lt = sim(workload, design_config("LTRF", table2_config=table2_config))
    lt_same = sim(workload, design_config("LTRF", mrf_latency_mult=1.0,
                                          rf_size_kb=256))
    p_bl = rf_power(bl, "hp-sram", cap_mult=1)
    p_lt = rf_power(lt, tech, cap_mult=8)
    p_lt_same = rf_power(lt_same, "hp-sram", cap_mult=1)
    return {
        "workload": workload.name,
        "bl_power": p_bl.total,
        "ltrf_same_tech_power": p_lt_same.total,
        "ltrf_8x_power": p_lt.total,
        "same_tech_saving": 1 - p_lt_same.total / p_bl.total,
        "dwm_8x_saving": 1 - p_lt.total / p_bl.total,
    }
