"""Calibrated analytical fast tier: closed-form per-interval cost model.

The cycle-accurate event engine (`repro.sim.engine`) does ~100k sim-instr/s
per core, which caps design sweeps at hundreds of points.  This module is
the PPT-GPU-style escape hatch (SNIPPETS.md Snippet 1): a closed-form model
that prices one design point in microseconds, accurate enough to *rank*
points, so a hybrid sweep can screen thousands of configurations
analytically and spend engine time only on the Pareto frontier
(`repro.serving.sweep` tier="analytic"|"engine"|"hybrid").

The model consumes exactly what the compiler already proved about the
program — `CompiledPlan.pass_stats` (validated against
`ANALYTIC_PASS_SCHEMA` so pipeline drift cannot silently skew estimates),
the interval/prefetch structure (working-set bit-vectors, per-interval
serial bank rounds, LTRF+ `plus_fetch` live-trimmed refetch sets) and the
per-instruction operand bank vectors — plus the per-design latency terms a
`SimConfig` carries (`repro.sim.designs`).

Structure of the estimate, mirroring the engine's cycle attribution
(`repro.obs.attribution.CYCLE_CATEGORIES`):

``cycles = startup + T_issue + struct_excess + dram_excess + theta . X``

* **exact dynamic profile** — the engine's instruction stream is
  timing-independent: loop branches depend only on ``Workload.trips`` and
  diamond branches on ``(wid*31 + v*17 + seed) & 1``, i.e. on the *parity*
  of ``wid``.  Walking two representative warps (wid 0 and 1) at basic-block
  segment granularity therefore reproduces the exact dynamic instruction
  count, per-interval entry counts and operand totals for every warp — the
  model's ``instructions`` field equals the engine's exactly.
* **startup** — the first interval prefetch (``serial_rounds * mrf_cycles +
  |working set| / xbar_regs_per_cycle``) is serial before any issue, exactly
  as the engine charges it.
* **throughput bounds** — issue width, MRF bank bandwidth (BL/RFC operand
  traffic vs the token-bucket rate), the single-server DRAM queue, and
  operand-collector occupancy; the binding bound sets the floor.
* **calibrated exposure terms** ``X`` — prefetch latency not hidden by
  multithreading, memory latency, dependency chains, and bank-conflict
  serialization, each divided by the active-warp overlap factor and scaled
  by a non-negative fitted coefficient (`Calibration`).  Coefficients are
  fit by non-negative least squares on a small engine-run training set
  (`fit_calibration`) and persisted with `CALIB_REV`/`ANALYTIC_REV` keys so
  stale constants are rejected, never silently reused.

Non-negative coefficients make the estimate monotone non-decreasing in the
RF access latency multiplier and in working-set size by construction, and
the Ideal design is enforced as a lower bound on every other design —
properties pinned in ``tests/test_sim_analytic.py``.  On degenerate
straight-line, no-load, conflict-free programs every exposure term is
structurally zero and the estimate equals the engine cycle-for-cycle.

Trust is established by the differential harness
(``benchmarks/bench_sim.py --analytic-smoke`` and the ``analytic_tier``
section of ``BENCH_sim.json``): Spearman rank correlation and per-point
relative error vs the engine over the tracked sweep, with hard pass/fail
verdicts.  See docs/analytical.md.
"""
from __future__ import annotations

import json
import math
import os
import pathlib
from dataclasses import asdict, dataclass, field, replace

from ..core.ir import Instr, Program
from ..core.plan_cache import (CompiledPlan, cached_value, compile_for_sim,
                               program_fingerprint)
from ..obs.attribution import CYCLE_CATEGORIES
from .engine import _CACHED_DESIGNS, _EDGE_PREFETCH, DESIGNS, SimConfig

# Analytical-model revision: part of every persisted analytic result key
# (`repro.serving.sweep.analytic_sim_key`) and of the calibration file
# schema.  Bump when the cost equations, the profile walk, or the feature
# definitions change — cached estimates from an older model must never be
# replayed as current.
ANALYTIC_REV = 1

# Calibration-constant revision: the *fitting contract* (feature vector
# layout + coefficient meaning).  A persisted calibration carries both revs;
# `load_calibration` rejects a mismatch on either so constants fitted
# against an older model are never applied to a newer one.
CALIB_REV = 1

# The sweep tiers wired through `repro.serving.sweep.SimRunner.prefill` and
# `benchmarks/sweep_subset.py`.
TIERS = ("engine", "analytic", "hybrid")


class AnalyticModelError(ValueError):
    """The analytical model cannot price this point (bad inputs/schema)."""


class CalibrationError(ValueError):
    """A persisted calibration file is corrupt, stale, or malformed."""


# ---------------------------------------------------------------------------
# pass_stats schema contract
# ---------------------------------------------------------------------------
# The model's compiler inputs: for each pipeline pass it consumes, the
# counter keys it reads (directly or as sanity anchors for the structures it
# walks).  `check_pass_stats` enforces presence so a pipeline refactor that
# renames/drops a counter fails loudly *here* instead of silently skewing
# estimates; tests/test_sim_analytic.py pins names and execution order.
ANALYTIC_PASS_SCHEMA: dict[str, tuple[str, ...]] = {
    "intervals": ("strategy", "cap", "intervals", "block_splits",
                  "max_working_set", "mean_working_set"),
    "liveness": ("blocks", "max_live_in"),
    "prefetch": ("prefetch_ops", "fetched_regs", "serial_rounds",
                 "max_conflicts"),
    "emit": ("instructions", "intervals"),
}

# Pipeline execution order of the passes above (subset of
# `core.pipeline.sim_passes()` order); pinned by the schema regression test.
ANALYTIC_PASS_ORDER = ("intervals", "liveness", "prefetch", "emit")


def required_passes(design: str) -> tuple[str, ...]:
    """The pass_stats entries the model reads for ``design``, in order."""
    if design in ("BL", "RFC", "Ideal"):
        return ("emit",)
    if design == "LTRF_plus":
        return ("intervals", "liveness", "prefetch", "emit")
    return ("intervals", "prefetch", "emit")


def check_pass_stats(pass_stats: dict, design: str) -> None:
    """Validate the compiler counters the analytical model consumes.

    Raises `AnalyticModelError` naming every missing pass/key; the message
    points at this module so whoever changes `core.pipeline` lands here.
    """
    problems = []
    for name in required_passes(design):
        entry = pass_stats.get(name)
        if entry is None:
            problems.append(f"pass {name!r} missing entirely")
            continue
        missing = [k for k in ANALYTIC_PASS_SCHEMA[name] if k not in entry]
        if missing:
            problems.append(f"pass {name!r} lost counters {missing}")
    if problems:
        raise AnalyticModelError(
            f"CompiledPlan.pass_stats no longer matches what the analytical "
            f"fast tier consumes for design {design!r}: {'; '.join(problems)}. "
            f"The consumers live in src/repro/sim/analytic.py "
            f"(ANALYTIC_PASS_SCHEMA) — update the model and bump ANALYTIC_REV "
            f"together with the pipeline change.")


# ---------------------------------------------------------------------------
# Calibration
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class Calibration:
    """Non-negative exposure coefficients (theta) for the calibrated terms.

    All four are dimensionless multipliers on cycle-valued features; keeping
    them >= 0 (enforced on load and by the NNLS fitter) is what makes the
    estimate provably monotone in RF latency and working-set size.
    """

    theta_pf: float = 1.0     # un-hidden prefetch latency
    theta_mem: float = 1.0    # exposed memory latency
    theta_dep: float = 1.0    # dependency-chain (RAW scoreboard) latency
    theta_bank: float = 1.0   # bank-conflict serialization rounds
    source: str = "default"   # "default" | "builtin" | "fitted"
    n_samples: int = 0        # engine runs the fit saw (0 for defaults)

    def coeffs(self) -> tuple[float, float, float, float]:
        return (self.theta_pf, self.theta_mem, self.theta_dep,
                self.theta_bank)

    def fingerprint(self) -> list:
        """Stable identity for cache keys: the rounded coefficient vector."""
        return [round(c, 6) for c in self.coeffs()]


# Fitted on the tracked sweep domain (sweep_jobs(): 14 synthetic workloads x
# 7 designs + baseline x table2 configs 6-7) via `fit_calibration` against
# the event engine; baked in so the fast tier needs no calibration file to
# hit its accuracy gates.  Re-fit per host with
# `python -m benchmarks.bench_sim --fit-calibration` when the constants
# drift (the differential smoke will tell you).
DEFAULT_CALIBRATION = Calibration(
    theta_pf=0.993022, theta_mem=0.0394, theta_dep=0.0, theta_bank=0.0,
    source="builtin", n_samples=196)


def calibration_to_dict(calib: Calibration) -> dict:
    return {
        "analytic_rev": ANALYTIC_REV,
        "calib_rev": CALIB_REV,
        "coeffs": {"theta_pf": calib.theta_pf, "theta_mem": calib.theta_mem,
                   "theta_dep": calib.theta_dep,
                   "theta_bank": calib.theta_bank},
        "source": calib.source,
        "n_samples": calib.n_samples,
    }


def calibration_from_dict(payload) -> Calibration:
    """Parse + validate a persisted calibration; `CalibrationError` on any
    corruption, schema violation, stale revision, or non-finite/negative
    coefficient — a bad file must degrade the tier, never skew it."""
    if not isinstance(payload, dict):
        raise CalibrationError(f"calibration payload is {type(payload).__name__}, "
                               f"expected an object")
    for rev_key, want in (("analytic_rev", ANALYTIC_REV),
                          ("calib_rev", CALIB_REV)):
        got = payload.get(rev_key)
        if got != want:
            raise CalibrationError(
                f"calibration {rev_key}={got!r} does not match current "
                f"{rev_key}={want}: constants fitted against another model "
                f"revision are stale — re-fit with fit_calibration")
    coeffs = payload.get("coeffs")
    if not isinstance(coeffs, dict):
        raise CalibrationError("calibration 'coeffs' missing or not an object")
    vals = {}
    for name in ("theta_pf", "theta_mem", "theta_dep", "theta_bank"):
        v = coeffs.get(name)
        if not isinstance(v, (int, float)) or isinstance(v, bool) \
                or not math.isfinite(v) or v < 0:
            raise CalibrationError(
                f"calibration coefficient {name}={v!r} is not a finite "
                f"non-negative number")
        vals[name] = float(v)
    return Calibration(source=str(payload.get("source", "fitted")),
                       n_samples=int(payload.get("n_samples", 0) or 0),
                       **vals)


def save_calibration(calib: Calibration, path) -> None:
    path = pathlib.Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    tmp = path.with_suffix(f".tmp{os.getpid()}")
    tmp.write_text(json.dumps(calibration_to_dict(calib), indent=1,
                              sort_keys=True))
    tmp.replace(path)


def load_calibration(path) -> Calibration | None:
    """Load a persisted calibration; None when the file does not exist,
    `CalibrationError` when it exists but cannot be trusted."""
    path = pathlib.Path(path)
    if not path.exists():
        return None
    try:
        payload = json.loads(path.read_text())
    except (OSError, UnicodeDecodeError, json.JSONDecodeError) as e:
        raise CalibrationError(f"unreadable calibration file {path}: {e}") \
            from e
    return calibration_from_dict(payload)


# ---------------------------------------------------------------------------
# Result
# ---------------------------------------------------------------------------

@dataclass
class AnalyticResult:
    """One analytically-priced design point.

    ``instructions`` is *exact* (the profile walk reproduces the engine's
    dynamic stream); ``cycles`` is the calibrated estimate; the breakdown
    mirrors `CYCLE_CATEGORIES` in float cycles and sums to the pre-rounding
    estimate.  ``tier`` marks the provenance so a replayed analytic record
    can never be mistaken for an engine verdict.
    """

    design: str
    workload: str
    cycles: int
    instructions: int
    resident_warps: int
    est_prefetch_events: int = 0
    est_mrf_accesses: int = 0
    cycle_breakdown: dict[str, float] = field(default_factory=dict)
    calib_source: str = "default"
    tier: str = "analytic"

    @property
    def ipc(self) -> float:
        return self.instructions / max(self.cycles, 1)

    def to_dict(self) -> dict:
        d = asdict(self)
        d["ipc"] = self.ipc
        return d


def analytic_supported(cfg: SimConfig) -> bool:
    """Can the fast tier price this config?  Multi-SM dispatch is engine-only
    for now; unsupported jobs fall through to the engine in every tier."""
    return cfg.num_sms == 1 and cfg.design in DESIGNS


# ---------------------------------------------------------------------------
# Exact dynamic profile (the parity-class walk)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class _Seg:
    """A run of straight-line instructions inside one basic block, ending at
    a branch, an exit, or the block end.  All counts are static; the walk
    weighs them by visit count."""

    n_instr: int          # instructions in the segment (incl. terminator)
    n_ctl: int            # bra/exit instructions (no operand collector)
    n_ld: int
    n_acc: int            # operand accesses (len(srcs)+len(dsts), non-ctl)
    n_dep: int            # instrs reading a reg written <=2 instrs earlier
    n_ld_consumed: int    # distinct earlier-ld dests read inside the segment
    self_rd_rounds: int   # guaranteed same-instr src bank collisions
    self_wr_rounds: int   # guaranteed same-instr dst bank collisions
    bra: Instr | None     # terminator branch (None: fell off / exit)
    bra_idx: int          # index of the bra within the block (diamond key)
    has_exit: bool


def _build_segments(plan: CompiledPlan) -> dict[str, list[_Seg]]:
    segs: dict[str, list[_Seg]] = {}
    banks = plan.instr_banks
    for label in plan.prog.order:
        bb = plan.prog.blocks[label]
        out: list[_Seg] = []
        n_i = n_ctl = n_ld = n_acc = n_dep = n_cons = s_rd = s_wr = 0
        writer_pos: dict[int, int] = {}
        ld_dsts: set[int] = set()
        consumed: set[int] = set()
        pos = 0
        for idx, ins in enumerate(bb.instrs):
            n_i += 1
            if ins.op in ("bra", "exit"):
                n_ctl += 1
                if ins.op == "bra":
                    out.append(_Seg(n_i, n_ctl, n_ld, n_acc, n_dep, n_cons,
                                    s_rd, s_wr, ins, idx, False))
                else:
                    out.append(_Seg(n_i, n_ctl, n_ld, n_acc, n_dep, n_cons,
                                    s_rd, s_wr, None, idx, True))
                n_i = n_ctl = n_ld = n_acc = n_dep = n_cons = 0
                s_rd = s_wr = 0
                writer_pos.clear()
                ld_dsts.clear()
                consumed.clear()
                pos = 0
                continue
            n_acc += len(ins.srcs) + len(ins.dsts)
            if any(writer_pos.get(s, -9) >= pos - 2 for s in ins.srcs) \
                    or ins.psrcs:
                n_dep += 1
            for s in ins.srcs:
                if s in ld_dsts and s not in consumed:
                    consumed.add(s)
                    n_cons += 1
            if ins.op == "ld":
                n_ld += 1
                ld_dsts.update(ins.dsts)
            bank_vec = banks.get(id(ins))
            if bank_vec is not None:
                for vec, is_rd in ((bank_vec[0], True), (bank_vec[1], False)):
                    seen: dict[int, int] = {}
                    extra = 0
                    for b in vec:
                        c = seen.get(b, 0)
                        seen[b] = c + 1
                        extra += 1 if c else 0
                    if is_rd:
                        s_rd += extra
                    else:
                        s_wr += extra
            for d in ins.dsts:
                writer_pos[d] = pos
            pos += 1
        if n_i:
            out.append(_Seg(n_i, n_ctl, n_ld, n_acc, n_dep, n_cons,
                            s_rd, s_wr, None, -1, False))
        segs[label] = out
    return segs


@dataclass(frozen=True)
class _ClassProfile:
    """Exact dynamic totals for one warp behavior class (wid parity)."""

    n_instr: int
    n_ctl: int
    n_ld: int
    n_acc: int
    n_dep: int
    n_ld_consumed: int
    self_rd_rounds: int
    self_wr_rounds: int
    entries: tuple[tuple[int, int], ...]      # (interval id, entry events)
    instrs_by_iid: tuple[tuple[int, int], ...]  # (interval id, dyn instrs)


# Hard stop for the profile walk, mirroring the engine's own wedge guard:
# a walk this long means a malformed/unterminated control-flow graph.
_WALK_GUARD = 4_000_000


def _walk_class(plan: CompiledPlan, segs: dict[str, list[_Seg]],
                trips: dict[str, int], wid: int, seed: int) -> _ClassProfile:
    """Replay one warp's control flow at segment granularity.

    Branch decisions replicate `engine.Simulator._branch_taken` exactly:
    loop branches count trips per target (warp-independent), diamond
    branches hash ``(wid*31 + v*17 + seed) & 0xFF`` — so one walk per wid
    parity reproduces every warp in that class.
    """
    prog = plan.prog
    order = prog.order
    order_index = plan.order_index
    block_interval = plan.block_interval

    n_instr = n_ctl = n_ld = n_acc = n_dep = n_cons = s_rd = s_wr = 0
    entries: dict[int, int] = {}
    instrs_by_iid: dict[int, int] = {}
    loop_counters: dict[str, int] = {}
    diamond_visits: dict[tuple[str, int], int] = {}

    def advance(label: str) -> tuple[str, int] | None:
        """First block at/after ``label`` (in order) that has segments."""
        i = order_index[label]
        while not segs.get(order[i]):
            if i + 1 >= len(order):
                return None
            i += 1
        return order[i], 0

    # Activation state: the engine's first forced prefetch targets the entry
    # block's interval (`_start_prefetch` sets wp.interval before issuing
    # anything), which is the first entry event.
    cur_iid = block_interval.get(prog.entry, -1)
    if cur_iid >= 0:
        entries[cur_iid] = 1
    pos = advance(prog.entry)
    guard = 0
    while pos is not None:
        guard += 1
        if guard > _WALK_GUARD:
            raise AnalyticModelError(
                f"analytic profile walk wedged after {_WALK_GUARD} segments "
                f"on program {prog.name!r} (unterminated control flow?)")
        block, si = pos
        iid = block_interval.get(block, -1)
        if iid >= 0 and iid != cur_iid:
            entries[iid] = entries.get(iid, 0) + 1
            cur_iid = iid
        seg = segs[block][si]
        n_instr += seg.n_instr
        n_ctl += seg.n_ctl
        n_ld += seg.n_ld
        n_acc += seg.n_acc
        n_dep += seg.n_dep
        n_cons += seg.n_ld_consumed
        s_rd += seg.self_rd_rounds
        s_wr += seg.self_wr_rounds
        if iid >= 0:
            instrs_by_iid[iid] = instrs_by_iid.get(iid, 0) + seg.n_instr
        if seg.has_exit:
            break
        bra = seg.bra
        if bra is None:  # fell off the block end
            i = order_index[block]
            pos = advance(order[i + 1]) if i + 1 < len(order) else None
            continue
        # --- _branch_taken, replicated bit-for-bit -----------------------
        if not bra.psrcs:
            taken = True
        else:
            t = trips.get(bra.target)
            if t is not None:
                c = loop_counters.get(bra.target, 0) + 1
                if c < t:
                    loop_counters[bra.target] = c
                    taken = True
                else:
                    loop_counters[bra.target] = 0
                    taken = False
            else:
                key = (block, seg.bra_idx)
                v = diamond_visits.get(key, 0)
                diamond_visits[key] = v + 1
                taken = bool(((wid * 31 + v * 17 + seed) & 0xFF) & 1)
        if taken:
            pos = advance(bra.target)
        elif si + 1 < len(segs[block]):
            pos = (block, si + 1)
        else:
            i = order_index[block]
            pos = advance(order[i + 1]) if i + 1 < len(order) else None
    return _ClassProfile(
        n_instr=n_instr, n_ctl=n_ctl, n_ld=n_ld, n_acc=n_acc, n_dep=n_dep,
        n_ld_consumed=n_cons, self_rd_rounds=s_rd, self_wr_rounds=s_wr,
        entries=tuple(sorted(entries.items())),
        instrs_by_iid=tuple(sorted(instrs_by_iid.items())))


def _profiles(plan: CompiledPlan, workload, seed: int,
              num_banks: int) -> tuple[_ClassProfile, _ClassProfile]:
    """(even-wid profile, odd-wid profile), memoized across estimates."""
    fp = program_fingerprint(plan.prog)
    bi_sig = tuple(sorted(plan.block_interval.items()))
    trips_sig = tuple(sorted(workload.trips.items()))

    def build():
        segs = cached_value(
            (("analytic-segs", ANALYTIC_REV), fp, num_banks),
            lambda: _build_segments(plan))
        return (_walk_class(plan, segs, workload.trips, 0, seed),
                _walk_class(plan, segs, workload.trips, 1, seed))

    return cached_value(
        (("analytic-profile", ANALYTIC_REV), fp, bi_sig, trips_sig, seed,
         num_banks), build)


# ---------------------------------------------------------------------------
# The cost model
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class _Terms:
    """Deterministic cost components + calibrated feature vector for one
    (workload, config) point; `_total` folds in the coefficients."""

    startup: float
    t_issue: float
    struct_excess: float   # max(bw, collector) beyond the issue bound
    dram_excess: float     # DRAM queue beyond every other bound
    x_pf: float
    x_mem: float
    x_dep: float
    x_bank: float
    instructions: int
    resident: int
    prefetch_events: int
    mrf_accesses: float


def _terms(workload, cfg: SimConfig) -> _Terms:
    design = cfg.design
    plan = compile_for_sim(workload.program, design, cfg.interval_cap,
                           cfg.num_banks, renumber=cfg.renumber,
                           interval_strategy=cfg.interval_strategy,
                           rfc_per_warp=cfg.rfc_entries_per_warp)
    check_pass_stats(plan.pass_stats, design)
    even, odd = _profiles(plan, workload, cfg.seed, cfg.num_banks)

    n_even = (cfg.num_warps + 1) // 2     # wids 0, 2, 4, ...
    n_odd = cfg.num_warps // 2
    classes = ((even, n_even), (odd, n_odd))

    def total(attr: str) -> int:
        return sum(getattr(p, attr) * c for p, c in classes)

    n_instr = total("n_instr")
    n_ld = total("n_ld")
    n_acc = total("n_acc")
    n_dep = total("n_dep")
    n_cons = total("n_ld_consumed")
    n_ctl = total("n_ctl")

    # Occupancy / overlap, exactly as the engine computes them.
    cap_kb = cfg.rf_size_kb + (cfg.rfc_size_kb if cfg.add_rfc_to_main else 0)
    warp_capacity = cap_kb * 1024 // 128
    resident = max(1, min(cfg.num_warps,
                          warp_capacity // max(workload.regs_per_thread, 1)))
    two_level = cfg.scheduler == "two_level"
    overlap = min(cfg.active_slots, resident) if two_level else resident

    cached = design in _CACHED_DESIGNS
    is_plus = design == "LTRF_plus"
    mrf_cyc = cfg.mrf_cycles
    l1_hit = getattr(workload, "l1_hit", cfg.l1_hit_rate)
    n_miss = n_ld * (1.0 - l1_hit)
    n_hit = n_ld * l1_hit

    # Per-interval prefetch event cost, mirroring `_start_prefetch` (LTRF+
    # substitutes the live-trimmed fetch set + rounds from plus_fetch).
    def pf_cost_len(iid: int) -> tuple[float, int]:
        op = plan.pf_ops.get(iid)
        if op is None or not op.bitvector:
            return 0.0, 0
        fetch, rounds = op.bitvector, op.serial_rounds
        if is_plus:
            ent = plan.plus_fetch.get(iid)
            if ent is not None:
                fetch, rounds = ent
                if not fetch:  # fully-dead working set: no data movement
                    return 0.0, 0
        return rounds * mrf_cyc + len(fetch) / cfg.xbar_regs_per_cycle, \
            len(fetch)

    startup = 0.0
    x_pf = 0.0
    prefetch_events = 0
    pf_fetch_regs = 0.0
    deact_lat = 0.0
    deact_regs = 0.0
    if cached:
        entry_iid = plan.block_interval.get(plan.prog.entry, -1)
        entry_cost, _entry_len = pf_cost_len(entry_iid)
        startup = float(int(entry_cost))
        event_lat = 0.0
        if design in _EDGE_PREFETCH:
            for prof, cnt in classes:
                for iid, n in prof.entries:
                    c, flen = pf_cost_len(iid)
                    if c > 0:
                        event_lat += cnt * n * c
                        prefetch_events += cnt * n
                        pf_fetch_regs += cnt * n * flen
        else:  # LTRF+: prefetch only on (re)activation, at the current block
            if entry_cost > 0:
                event_lat = cfg.num_warps * entry_cost
                prefetch_events += cfg.num_warps
                pf_fetch_regs += cfg.num_warps * _entry_len
        # Two-level deactivations on L1 misses force a writeback + refetch on
        # reactivation; weight refetch cost by where warps spend their time.
        if two_level and n_instr:
            share_lat = share_regs = share_wb = 0.0
            for prof, cnt in classes:
                for iid, n in prof.instrs_by_iid:
                    c, flen = pf_cost_len(iid)
                    w = cnt * n / n_instr
                    share_lat += w * c
                    share_regs += w * flen
                    op = plan.pf_ops.get(iid)
                    if op is not None and op.bitvector:
                        wb = len(plan.live_sets.get(iid, op.bitvector)) \
                            if is_plus else len(op.bitvector)
                        share_wb += w * wb
            n_deact = n_cons * (1.0 - l1_hit)
            deact_lat = n_deact * share_lat
            deact_regs = n_deact * (share_regs + share_wb)
            prefetch_events += int(n_deact)
            pf_fetch_regs += n_deact * share_regs
        x_pf = (max(0.0, event_lat - overlap * entry_cost) + deact_lat) \
            / overlap

    # Issue-throughput floor.  The engine's run loop breaks *before*
    # charging the final issuing cycle whenever retirement is discovered in
    # the same iteration, which nets out to floor(N / issue_width) — exact
    # on degenerate programs, the right floor elsewhere.
    t_issue = float(n_instr // cfg.issue_width)

    # MRF bandwidth bound (token bucket; only BL/RFC operand traffic draws
    # tokens — prefetch and writeback traffic is counted, not arbitrated).
    n_regs = len(plan.prog.registers())
    rfc_miss = 0.0
    if design == "RFC":
        cold = min(float(n_acc), float(cfg.num_warps * n_regs))
        pressure = resident * n_regs
        churn = max(0.0, 1.0 - cfg.rfc_entries / pressure) if pressure else 0.0
        rfc_miss = min(float(n_acc), cold + (n_acc - cold) * churn)
    bw_demand = float(n_acc) if design == "BL" else rfc_miss
    mrf_rate = cfg.num_banks / max(mrf_cyc / 6.0, 1.0)
    t_bw = max(0.0, (bw_demand - cfg.num_banks) / mrf_rate)

    # Operand-collector occupancy bound (bra/exit bypass the collectors).
    t_col = (n_instr - n_ctl) * cfg.base_rf_cycles / max(cfg.num_collectors, 1)

    # Single-server DRAM queue bound (one line per dram_interval per SM).
    t_dram = n_miss * cfg.dram_interval

    base = max(t_issue, t_bw, t_col)
    struct_excess = base - t_issue
    dram_excess = max(base, t_dram) - base

    x_mem = (n_miss * cfg.mem_cycles + n_hit * cfg.l1_cycles) / overlap \
        if n_ld else 0.0

    if design == "Ideal":
        read_unit = float(cfg.base_rf_cycles)
        wlat = float(cfg.base_rf_cycles)
    elif design == "BL":
        read_unit = float(mrf_cyc)
        wlat = float(mrf_cyc)
    elif design == "RFC":
        m = rfc_miss / max(n_acc, 1)
        read_unit = m * mrf_cyc + (1.0 - m) * cfg.rfc_cycles
        wlat = float(cfg.rfc_cycles)
    else:
        read_unit = float(cfg.rfc_cycles)
        wlat = float(cfg.rfc_cycles)
    x_dep = n_dep * (read_unit + cfg.alu_cycles + wlat) / overlap

    x_bank = 0.0
    if cfg.bank_model == "arbitrated" and design != "Ideal":
        arb_rd = cfg.base_rf_cycles if design == "BL" else cfg.rfc_cycles
        arb_wb = cfg.base_rf_cycles if design == "BL" else cfg.rfc_cycles
        self_rd = total("self_rd_rounds")
        self_wr = total("self_wr_rounds")
        cross = n_acc * n_acc / (2.0 * cfg.num_banks * max(t_issue, 1.0))
        x_bank = (self_rd * arb_rd + self_wr * arb_wb + cross * arb_rd) \
            / overlap

    # Estimated MRF traffic (the Pareto frontier's second axis).
    if design == "BL":
        mrf_accesses = float(n_acc)
    elif design == "RFC":
        mrf_accesses = rfc_miss
    elif design == "Ideal":
        mrf_accesses = 0.0
    else:
        mrf_accesses = pf_fetch_regs + deact_regs

    return _Terms(startup=startup, t_issue=t_issue,
                  struct_excess=struct_excess, dram_excess=dram_excess,
                  x_pf=x_pf, x_mem=x_mem, x_dep=x_dep, x_bank=x_bank,
                  instructions=n_instr, resident=resident,
                  prefetch_events=prefetch_events, mrf_accesses=mrf_accesses)


def _total(t: _Terms, calib: Calibration) -> float:
    return (t.startup + t.t_issue + t.struct_excess + t.dram_excess
            + calib.theta_pf * t.x_pf + calib.theta_mem * t.x_mem
            + calib.theta_dep * t.x_dep + calib.theta_bank * t.x_bank)


def _idealized(cfg: SimConfig) -> SimConfig:
    """The Ideal-design twin of ``cfg`` (matches `designs.design_config`'s
    Ideal normalization: 1x latency, RFC capacity folded into the MRF)."""
    return replace(cfg, design="Ideal", mrf_latency_mult=1.0,
                   add_rfc_to_main=True)


def estimate(workload, cfg: SimConfig,
             calib: Calibration | None = None) -> AnalyticResult:
    """Price one design point analytically.  Microseconds, not seconds.

    The returned cycles are ``max(model, model of the Ideal twin)`` so the
    Ideal design lower-bounds every other design by construction (any floor
    shortfall is attributed to ``scheduler_idle``).
    """
    if not analytic_supported(cfg):
        raise AnalyticModelError(
            f"analytic tier cannot price design={cfg.design!r} "
            f"num_sms={cfg.num_sms} (engine-only point)")
    calib = calib or DEFAULT_CALIBRATION
    t = _terms(workload, cfg)
    total = _total(t, calib)
    bd = {c: 0.0 for c in CYCLE_CATEGORIES}
    bd["issue"] = t.t_issue
    bd["prefetch_stall"] = t.startup + calib.theta_pf * t.x_pf
    bd["mem_stall"] = calib.theta_mem * t.x_mem + t.dram_excess
    bd["alu_dep"] = calib.theta_dep * t.x_dep
    bd["bank_conflict"] = calib.theta_bank * t.x_bank + t.struct_excess
    if cfg.design != "Ideal":
        ideal_total = _total(_terms(workload, _idealized(cfg)), calib)
        if ideal_total > total:
            bd["scheduler_idle"] = ideal_total - total
            total = ideal_total
    return AnalyticResult(
        design=cfg.design, workload=workload.name, cycles=int(round(total)),
        instructions=t.instructions, resident_warps=t.resident,
        est_prefetch_events=int(t.prefetch_events),
        est_mrf_accesses=int(round(t.mrf_accesses)),
        cycle_breakdown=bd, calib_source=calib.source)


# ---------------------------------------------------------------------------
# Calibration fitting (clamped non-negative least squares)
# ---------------------------------------------------------------------------

def fit_calibration(samples) -> Calibration:
    """Fit the four exposure coefficients on engine ground truth.

    ``samples``: iterable of ``(workload, cfg, engine_cycles)``.  Solves
    ``min || base + X.theta - y ||`` with ``theta >= 0`` via iterated
    least squares with negative-coefficient clamping (no scipy dependency);
    a coefficient clamped to zero simply means that exposure is already
    covered by the deterministic bounds on this training set.
    """
    import numpy as np

    rows, resid = [], []
    n = 0
    for workload, cfg, engine_cycles in samples:
        t = _terms(workload, cfg)
        base = t.startup + t.t_issue + t.struct_excess + t.dram_excess
        rows.append([t.x_pf, t.x_mem, t.x_dep, t.x_bank])
        resid.append(float(engine_cycles) - base)
        n += 1
    if n < 4:
        raise AnalyticModelError(
            f"fit_calibration needs at least 4 samples, got {n}")
    A = np.asarray(rows, dtype=float)
    y = np.asarray(resid, dtype=float)
    theta = np.zeros(4)
    active = [j for j in range(4) if A[:, j].any()]
    for _ in range(8):
        if not active:
            break
        sol, *_ = np.linalg.lstsq(A[:, active], y, rcond=None)
        neg = [j for j, v in zip(active, sol) if v < 0]
        if not neg:
            for j, v in zip(active, sol):
                theta[j] = v
            break
        active = [j for j in active if j not in neg]
    return Calibration(theta_pf=float(theta[0]), theta_mem=float(theta[1]),
                       theta_dep=float(theta[2]), theta_bank=float(theta[3]),
                       source="fitted", n_samples=n)


# ---------------------------------------------------------------------------
# Ranking helpers shared by the sweep tiers, the bench harness and the tests
# ---------------------------------------------------------------------------

def _avg_ranks(values) -> list[float]:
    """Average (tie-aware) ranks, 1-based."""
    order = sorted(range(len(values)), key=lambda i: values[i])
    ranks = [0.0] * len(values)
    i = 0
    while i < len(order):
        j = i
        while j + 1 < len(order) \
                and values[order[j + 1]] == values[order[i]]:
            j += 1
        r = (i + j) / 2.0 + 1.0
        for k in range(i, j + 1):
            ranks[order[k]] = r
        i = j + 1
    return ranks


def spearman_rho(xs, ys) -> float:
    """Spearman rank correlation (average ranks for ties; 1.0 on degenerate
    constant inputs — identical rankings cannot disagree)."""
    xs, ys = list(xs), list(ys)
    if len(xs) != len(ys):
        raise ValueError(f"length mismatch: {len(xs)} vs {len(ys)}")
    n = len(xs)
    if n < 2:
        return 1.0
    rx, ry = _avg_ranks(xs), _avg_ranks(ys)
    mx = sum(rx) / n
    my = sum(ry) / n
    cov = sum((a - mx) * (b - my) for a, b in zip(rx, ry))
    vx = sum((a - mx) ** 2 for a in rx)
    vy = sum((b - my) ** 2 for b in ry)
    if vx == 0 or vy == 0:
        return 1.0
    return cov / math.sqrt(vx * vy)


def pareto_frontier(points) -> list[int]:
    """Indices of the 2-D minimization Pareto frontier of ``(a, b)`` pairs
    (a point survives unless some other point is <= on both axes and < on
    one), in ascending-``a`` order."""
    idx = sorted(range(len(points)), key=lambda i: (points[i][0],
                                                    points[i][1]))
    out: list[int] = []
    best_b = math.inf
    for i in idx:
        a, b = points[i]
        if b < best_b:
            out.append(i)
            best_b = b
    return out
