"""Sharded train / prefill / decode step builders.

`build_train_step(cfg, mesh)` returns (step_fn, state_shardings) where
step_fn(state, batch) -> (state, metrics) is ready for jax.jit with the
returned shardings.  The same builders drive the real trainer, the examples,
and the 512-device dry-run (which only lowers + compiles them).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.distributed.sharding import (
    ShardingRules, default_rules, logical_to_spec, param_shardings, use_rules,
)
from repro.models.lm import decode_step, init_decode_cache, init_params, loss_fn
from repro.optim.adamw import (
    AdamWConfig, adamw_update, init_opt_state, opt_state_axes,
)
from repro.optim.compression import CompressionConfig, compress_gradients


def batch_shardings(rules: ShardingRules, batch_axes: dict):
    return {k: NamedSharding(rules.mesh, logical_to_spec(rules, v))
            for k, v in batch_axes.items()}


def batch_axes_for(cfg: ArchConfig, kind: str) -> dict:
    if kind == "decode":
        ax = {"tokens": ("act_batch", None, None) if cfg.family == "audio"
              else ("act_batch", None),
              "cache_len": ()}
        return ax
    if cfg.family == "vlm":
        return {"tokens": ("act_batch", "act_seq"),
                "patches": ("act_batch", "act_seq", None),
                "labels": ("act_batch", "act_seq")}
    if cfg.family == "audio":
        return {"codes": ("act_batch", None, "act_seq"),
                "labels": ("act_batch", None, "act_seq")}
    return {"tokens": ("act_batch", "act_seq"),
            "labels": ("act_batch", "act_seq")}


def make_train_state(cfg: ArchConfig, key):
    params, axes = init_params(cfg, key)
    opt = init_opt_state(params)
    return {"params": params, "opt": opt}, {"params": axes,
                                            "opt": opt_state_axes(axes)}


def state_shardings(rules: ShardingRules, state_axes):
    return param_shardings(rules, state_axes)


def build_train_step(cfg: ArchConfig, rules: ShardingRules,
                     opt_cfg: AdamWConfig | None = None,
                     compression: CompressionConfig | None = None,
                     n_micro: int = 1, accum_dtype=jnp.float32):
    """Returns step(state, batch) -> (state, metrics), pure & jit-ready.

    ``n_micro > 1`` enables gradient accumulation: the global batch is split
    into microbatches scanned sequentially, so activation memory scales with
    the *microbatch* while arithmetic intensity per chip is unchanged.  This
    is what lets the large dense/moe cells fit 16GB HBM at global batch 256.
    ``accum_dtype`` controls the accumulation buffer precision (bf16 halves
    the buffer for very large models at negligible quality cost when
    n_micro <= ~32).
    """
    opt_cfg = opt_cfg or AdamWConfig()

    def grads_of(params, batch):
        return jax.value_and_grad(
            lambda p: loss_fn(p, batch, cfg), has_aux=True)(params)

    def step(state, batch):
        with use_rules(rules):
            params = state["params"]
            if n_micro > 1:
                micro = jax.tree.map(
                    lambda x: x.reshape(n_micro, x.shape[0] // n_micro,
                                        *x.shape[1:]),
                    batch)

                def acc_fn(acc, mb):
                    (loss, metrics), g = grads_of(params, mb)
                    gacc, lacc, aacc = acc
                    gacc = jax.tree.map(
                        lambda a, b: a + (b / n_micro).astype(a.dtype), gacc, g)
                    return (gacc, lacc + loss / n_micro,
                            aacc + metrics["aux_loss"] / n_micro), None

                zero = (jax.tree.map(
                            lambda p: jnp.zeros(p.shape, accum_dtype), params),
                        jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32))
                (grads, loss, aux), _ = jax.lax.scan(acc_fn, zero, micro)
                metrics = {"loss": loss, "aux_loss": aux}
            else:
                (loss, metrics), grads = grads_of(params, batch)
            if compression is not None and compression.enabled:
                grads, state_err, cstats = compress_gradients(
                    grads, state.get("err"), compression)
                metrics.update(cstats)
            else:
                state_err = state.get("err")
            new_params, new_opt, opt_metrics = adamw_update(
                opt_cfg, params, grads, state["opt"])
            metrics.update(opt_metrics)
            metrics["loss_total"] = loss
            out = {"params": new_params, "opt": new_opt}
            if state_err is not None:
                out["err"] = state_err
            return out, metrics

    return step


def build_eval_step(cfg: ArchConfig, rules: ShardingRules):
    def step(params, batch):
        with use_rules(rules):
            loss, metrics = loss_fn(params, batch, cfg)
            return metrics

    return step


def build_prefill_step(cfg: ArchConfig, rules: ShardingRules, n_micro: int = 1):
    """Forward-only step (inference prefill): returns logits stats + loss.

    ``n_micro`` scans the request batch in chunks so the 32k-token MoE
    dispatch working set stays inside HBM."""
    def step(params, batch):
        with use_rules(rules):
            if n_micro > 1:
                micro = jax.tree.map(
                    lambda x: x.reshape(n_micro, x.shape[0] // n_micro,
                                        *x.shape[1:]),
                    batch)

                def one(acc, mb):
                    loss, _ = loss_fn(params, mb, cfg)
                    return acc + loss / n_micro, None

                loss, _ = jax.lax.scan(one, jnp.zeros((), jnp.float32), micro)
                return {"loss": loss}
            loss, metrics = loss_fn(params, batch, cfg)
            return {"loss": loss, **metrics}

    return step


def build_decode_step(cfg: ArchConfig, rules: ShardingRules):
    """serve_step: one new token against a seq-deep KV/state cache."""
    def step(params, cache, tokens, cache_len):
        with use_rules(rules):
            logits, new_cache = decode_step(params, cache, tokens, cache_len, cfg)
            next_tok = jnp.argmax(logits[..., -1, :] if cfg.family != "audio"
                                  else logits[:, -1], axis=-1)
            return next_tok, new_cache

    return step
