from .train_step import (
    batch_axes_for, batch_shardings, build_decode_step, build_eval_step,
    build_prefill_step, build_train_step, make_train_state, state_shardings,
)

__all__ = [
    "batch_axes_for", "batch_shardings", "build_decode_step",
    "build_eval_step", "build_prefill_step", "build_train_step",
    "make_train_state", "state_shardings",
]
