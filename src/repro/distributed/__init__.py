from .sharding import (
    ShardingRules, constrain, default_rules, logical_to_spec, param_shardings,
    shardings_for, use_rules,
)
from .fault import FaultConfig, FaultTolerantTrainer, SimulatedFailure
from .elastic import degraded_mesh, reshard_state
from .pipeline_parallel import pipeline_forward, sequential_reference

__all__ = [
    "ShardingRules", "constrain", "default_rules", "logical_to_spec",
    "param_shardings", "shardings_for", "use_rules",
    "FaultConfig", "FaultTolerantTrainer", "SimulatedFailure",
    "degraded_mesh", "reshard_state",
    "pipeline_forward", "sequential_reference",
]
