"""Logical-axis sharding rules (MaxText-style) for the production mesh.

Model code annotates params and activations with *logical* axis names
('embed', 'heads', 'act_batch', ...).  A :class:`ShardingRules` table maps
those to mesh axes; `constrain` applies `with_sharding_constraint` when a
rule-set is active (a contextvar), and is a no-op otherwise so the same model
code runs unsharded on one device.

Default 2D layout (+ optional pod axis):
  * batch / act_batch       -> ('pod', 'data')      data parallelism
  * embed                   -> 'data'               FSDP: params + optimizer
                                                    state sharded over DP
  * heads/kv/ffn/vocab/
    experts                 -> 'model'              tensor / expert parallelism
  * act_seq                 -> None ('model' when sequence parallelism is on)
"""
from __future__ import annotations

import contextlib
import contextvars
from dataclasses import dataclass, field
from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclass(frozen=True)
class ShardingRules:
    mesh: Mesh
    table: dict[str | None, Any] = field(default_factory=dict)

    def axis(self, name: str | None):
        return self.table.get(name)


def default_rules(mesh: Mesh, sequence_parallel: bool = False,
                  fsdp: bool = True, layout: str = "2d") -> ShardingRules:
    """Sharding layouts over the fixed production mesh.

    * ``2d`` (default): batch over ('pod','data'), TP over 'model'; fsdp=True
      shards params + optimizer state ('embed') over 'data' (ZeRO-3-style),
      fsdp=False keeps params TP-only/replicated (ZeRO-1 posture).
    * ``fsdp_pure``: no tensor parallelism — batch AND the FSDP shard span
      ('pod','data','model') jointly (fully-sharded DP).  Removes every
      per-layer TP activation all-reduce; weights stream layer-by-layer via
      one all-gather per traversal.  The right layout when one chip's
      compute fits a layer and the global batch >= chip count (phi3-class).
    """
    axes = set(mesh.axis_names)
    if layout == "fsdp_pure":
        all_axes = tuple(a for a in ("pod", "data", "model") if a in axes)
        table = {
            None: None,
            "batch": all_axes,
            "act_batch": all_axes,
            "embed": all_axes if fsdp else None,
            "heads": None, "kv": None, "ffn": None,
            "vocab": None, "experts": None,
            "layers": None,
            "act_seq": None, "act_embed": None, "act_heads": None,
            "act_kv": None, "act_hd": None, "act_experts": None,
            "act_vocab": None, "act_ffn": None,
        }
        return ShardingRules(mesh=mesh, table=table)
    if layout == "ep_dp":
        # MoE posture #2: batch spans ALL mesh axes (full DP for the dense
        # paths — no replicated attention compute), experts + vocab sharded
        # over 'model' (tokens all-to-all into expert shards), attention
        # weights FSDP-sharded over 'data'.  GSPMD chooses between gathering
        # dm-sharded expert weights and partial-sum all-reduces.
        all_axes = tuple(a for a in ("pod", "data", "model") if a in axes)
        model = "model" if "model" in axes else None
        data = "data" if "data" in axes else None
        table = {
            None: None,
            "batch": all_axes,
            "act_batch": all_axes,
            "embed": data if fsdp else None,
            "heads": None, "kv": None, "ffn": None,
            "vocab": model, "experts": model,
            "layers": None,
            "act_seq": None, "act_embed": None, "act_heads": None,
            "act_kv": None, "act_hd": None,
            "act_experts": model, "act_vocab": model, "act_ffn": None,
        }
        return ShardingRules(mesh=mesh, table=table)
    if layout == "ep_only":
        # MoE posture: expert parallelism (+ sharded vocab head) on 'model',
        # FSDP on 'data', NO tensor parallelism on attention/dense paths —
        # removes the per-layer TP activation all-reduces while keeping the
        # expert weights distributed; the MoE all-to-all is the only
        # per-layer collective left.
        batch = tuple(a for a in ("pod", "data") if a in axes) or None
        if isinstance(batch, tuple) and len(batch) == 1:
            batch = batch[0]
        model = "model" if "model" in axes else None
        data = "data" if "data" in axes else None
        table = {
            None: None,
            "batch": batch,
            "act_batch": batch,
            "embed": data if fsdp else None,
            "heads": None, "kv": None, "ffn": None,
            "vocab": model, "experts": model,
            "layers": None,
            "act_seq": None, "act_embed": None, "act_heads": None,
            "act_kv": None, "act_hd": None,
            "act_experts": model, "act_vocab": model, "act_ffn": None,
        }
        return ShardingRules(mesh=mesh, table=table)
    batch = tuple(a for a in ("pod", "data") if a in axes) or None
    if isinstance(batch, tuple) and len(batch) == 1:
        batch = batch[0]
    model = "model" if "model" in axes else None
    data = "data" if "data" in axes else None
    table = {
        None: None,
        "batch": batch,
        "act_batch": batch,
        "embed": data if fsdp else None,
        "heads": model,
        "kv": model,
        "ffn": model,
        "vocab": model,
        "experts": model,
        "layers": None,
        "act_seq": model if sequence_parallel else None,
        "act_embed": None,
        "act_heads": model,
        "act_ffn": model,
        "act_vocab": model,
        "act_kv": model,
        "act_hd": None,
        "act_experts": model,
    }
    return ShardingRules(mesh=mesh, table=table)


_ACTIVE: contextvars.ContextVar[ShardingRules | None] = \
    contextvars.ContextVar("sharding_rules", default=None)


@contextlib.contextmanager
def use_rules(rules: ShardingRules | None):
    tok = _ACTIVE.set(rules)
    try:
        yield rules
    finally:
        _ACTIVE.reset(tok)


def active_rules() -> ShardingRules | None:
    return _ACTIVE.get()


def logical_to_spec(rules: ShardingRules, names: tuple) -> P:
    return P(*(rules.axis(n) for n in names))


def constrain(x, names: tuple):
    """Annotate an intermediate with logical axes (no-op without rules).

    Applies the same shape-aware rules as :func:`shardings_for`: a mesh axis
    is used at most once per tensor (first dimension wins) and only when it
    divides the dimension."""
    rules = _ACTIVE.get()
    if rules is None:
        return x
    spec: list = []
    used: set[str] = set()
    for i, dim in enumerate(x.shape):
        name = names[i] if i < len(names) else None
        ax = rules.axis(name)
        mem = set(ax) if isinstance(ax, (tuple, list)) else {ax}
        if (ax is not None and dim % _axis_size(rules.mesh, ax) == 0
                and not (mem & used)):
            spec.append(ax)
            used |= mem
        else:
            spec.append(None)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(rules.mesh, P(*spec)))


def param_shardings(rules: ShardingRules, axes_tree) -> Any:
    """Map a pytree of logical-axis tuples to NamedShardings."""
    return jax.tree.map(
        lambda names: NamedSharding(rules.mesh, logical_to_spec(rules, names)),
        axes_tree,
        is_leaf=lambda x: isinstance(x, tuple),
    )


def _axis_size(mesh: Mesh, ax) -> int:
    if ax is None:
        return 1
    if isinstance(ax, (tuple, list)):
        n = 1
        for a in ax:
            n *= mesh.shape[a]
        return n
    return mesh.shape[ax]


# When a primary dimension can't take its mesh axis (non-divisible), the
# axis may move to a fallback dimension of the same tensor: KV caches with
# few kv-heads shard the head_dim over 'model' instead.
_FALLBACK_TARGETS = {"act_hd": "act_kv"}  # dim name -> dim it substitutes for


def shardings_for(rules: ShardingRules, axes_tree, shapes_tree) -> Any:
    """Shape-aware shardings for jit *arguments*: a mesh axis is applied to a
    dimension only when it divides it evenly (jit arguments, unlike internal
    constraints, reject uneven sharding).  E.g. kv=4 heads stay replicated on
    a model=16 axis; a 50280 vocab stays unsharded over 16.  A dropped
    'act_kv' axis falls back onto the tensor's 'act_hd' dimension."""
    def one(names, shp):
        dims = getattr(shp, "shape", None)
        if dims is None:
            return NamedSharding(rules.mesh, P())
        spec: list = []
        dropped: set[str] = set()
        used: set[str] = set()

        def members(ax):
            return set(ax) if isinstance(ax, (tuple, list)) else {ax}

        for i, dim in enumerate(dims):
            name = names[i] if i < len(names) else None
            ax = rules.axis(name)
            ok = (ax is not None
                  and dim % _axis_size(rules.mesh, ax) == 0
                  and not (members(ax) & used))  # each mesh axis used once
            if ok:
                spec.append(ax)
                used |= members(ax)
            else:
                spec.append(None)
                if ax is not None and name is not None:
                    dropped.add(name)
        for i, dim in enumerate(dims):
            name = names[i] if i < len(names) else None
            src = _FALLBACK_TARGETS.get(name or "")
            if src and src in dropped and spec[i] is None:
                ax = rules.axis(src)
                if (ax is not None and dim % _axis_size(rules.mesh, ax) == 0
                        and not (members(ax) & used)):
                    spec[i] = ax
                    used |= members(ax)
        return NamedSharding(rules.mesh, P(*spec))

    return jax.tree.map(one, axes_tree, shapes_tree,
                        is_leaf=lambda x: isinstance(x, tuple))


def stack_axes(axes_tree, prefix: str | None = "layers"):
    """Prepend a leading (scan/stack) axis to every logical-axes tuple."""
    return jax.tree.map(
        lambda names: (prefix, *names),
        axes_tree,
        is_leaf=lambda x: isinstance(x, tuple),
    )
