"""Fault tolerance: checkpoint/restart training supervisor.

`FaultTolerantTrainer` wraps a step function with:
  * periodic async checkpoints (bounded in-flight, content-hashed);
  * failure recovery — on any step exception (a real fleet: device loss /
    preemption / data corruption) it restores the last committed checkpoint,
    repositions the deterministic data stream and replays;
  * an injectable failure schedule for testing (`inject_failures`).

Restart-from-zero and restart-mid-run are the same code path: `resume()`
finds the newest committed checkpoint or initializes fresh.
"""
from __future__ import annotations

import logging
from dataclasses import dataclass, field
from typing import Any, Callable

from repro.checkpoint import Checkpointer

log = logging.getLogger("repro.fault")


@dataclass
class FaultConfig:
    ckpt_every: int = 50
    max_retries: int = 3
    inject_failures: dict[int, int] = field(default_factory=dict)
    # {step: n_times} -> raise simulated failure at `step`, n times


class SimulatedFailure(RuntimeError):
    pass


@dataclass
class FaultTolerantTrainer:
    step_fn: Callable[[Any, Any], tuple[Any, Any]]
    checkpointer: Checkpointer
    loader: Any                      # PrefetchingLoader-compatible
    cfg: FaultConfig = field(default_factory=FaultConfig)
    restarts: int = 0
    _injected: dict[int, int] = field(default_factory=dict)

    def resume(self, init_state) -> tuple[Any, int]:
        last = self.checkpointer.latest_step()
        if last is None:
            # commit the initial state synchronously: a failure before the
            # first periodic checkpoint must never fall back to `init_state`,
            # whose buffers the donating step function has already consumed
            self.checkpointer.save(0, init_state)
            return init_state, 0
        state = self.checkpointer.restore(last, init_state)
        self.loader.restore(last)
        log.info("resumed from checkpoint step %d", last)
        return state, last

    def run(self, init_state, num_steps: int):
        # shape/dtype template for restores (never holds live buffers)
        import jax
        template = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), init_state)
        state, start = self.resume(init_state)
        step = start
        metrics_log = []
        retries = 0
        while step < num_steps:
            batch = self.loader.get()
            try:
                self._maybe_inject(step)
                state, metrics = self.step_fn(state, batch)
            except Exception as e:  # noqa: BLE001 - supervisor catches all
                retries += 1
                self.restarts += 1
                if retries > self.cfg.max_retries:
                    raise
                log.warning("step %d failed (%s); restoring", step, e)
                self.checkpointer.wait()  # let any in-flight write commit
                last = self.checkpointer.latest_step()
                assert last is not None  # step-0 checkpoint always exists
                state = self.checkpointer.restore(last, template)
                step = last
                self.loader.restore(step)
                continue
            retries = 0
            step += 1
            metrics_log.append(metrics)
            if step % self.cfg.ckpt_every == 0:
                self.checkpointer.save_async(step, state)
        self.checkpointer.wait()
        self.checkpointer.save(step, state)
        return state, step, metrics_log

    def _maybe_inject(self, step: int) -> None:
        want = self.cfg.inject_failures.get(step, 0)
        done = self._injected.get(step, 0)
        if done < want:
            self._injected[step] = done + 1
            raise SimulatedFailure(f"injected failure at step {step}")
