"""Elastic scaling: rebuild the mesh from surviving devices and reshard.

On a real fleet, losing a slice means restarting the job on fewer hosts; the
recovery path is exactly what `reshard_state` implements — load the last
checkpoint (host arrays) and `device_put` with shardings derived from the
*new* mesh.  Because every sharding in this codebase is derived from logical
rules + concrete shapes (`shardings_for`), nothing else changes: the same
step builder compiles for the new topology.
"""
from __future__ import annotations

import jax

from repro.distributed.sharding import default_rules, shardings_for


def degraded_mesh(devices=None, model: int | None = None):
    """Largest (data, model) mesh from the given devices (default: all)."""
    devices = list(devices if devices is not None else jax.devices())
    n = len(devices)
    if model is None:
        model = 1
        for m in (16, 8, 4, 2):
            if n % m == 0 and m <= n:
                model = m
                break
    data = n // model
    import numpy as np
    arr = np.array(devices[: data * model]).reshape(data, model)
    from jax.sharding import Mesh
    return Mesh(arr, ("data", "model"))


def reshard_state(state, axes_tree, new_mesh, sequence_parallel: bool = False):
    """Re-place a host-loaded (or device) state onto a new mesh."""
    rules = default_rules(new_mesh, sequence_parallel=sequence_parallel)
    shapes = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state)
    sh = shardings_for(rules, axes_tree, shapes)
    return jax.tree.map(lambda x, s: jax.device_put(x, s), state, sh), rules
