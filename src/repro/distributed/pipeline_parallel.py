"""GPipe-style pipeline parallelism via shard_map + collective_permute.

For depth-dominated models (or when TP/FSDP axes are exhausted), layers are
split into `n_stages` contiguous stages placed along a mesh axis; microbatches
flow through the classic GPipe schedule: with M microbatches and P stages the
pipeline runs M + P - 1 ticks, each stage computing its resident microbatch
and then `ppermute`-ing activations to the next stage.

This module implements the *forward* pipeline as a composable primitive
(`pipeline_forward`) plus a self-contained correctness artifact: the same
stage function run sequentially must produce identical outputs.  It is
exercised on a host-device mesh in tests (the production meshes would place
'stage' on the pod axis — DCN-friendly point-to-point traffic only).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P


def pipeline_forward(stage_fn, params_stacked, x_micro, mesh: Mesh,
                     stage_axis: str = "stage"):
    """Run microbatches through pipeline stages laid out on ``stage_axis``.

    stage_fn(stage_params, x) -> x            (one stage's computation)
    params_stacked: pytree with leading axis n_stages (sharded over stages)
    x_micro: (n_micro, mb, ...) microbatched inputs (replicated)

    Returns (n_micro, mb, ...) outputs after all stages.
    """
    n_stages = int(mesh.shape[stage_axis])
    n_micro = x_micro.shape[0]
    ticks = n_micro + n_stages - 1

    def per_stage(params, xs):
        # params: this stage's slice (leading axis 1); xs: all microbatches
        params = jax.tree.map(lambda a: a[0], params)
        idx = jax.lax.axis_index(stage_axis)
        mb_shape = xs.shape[1:]
        buf = jnp.zeros(mb_shape, xs.dtype)          # resident activation
        outs = jnp.zeros_like(xs)                    # collected at last stage

        def tick(t, carry):
            buf, outs = carry
            # stage 0 injects microbatch t (if any remain)
            inject = jnp.where(t < n_micro, t, 0)
            incoming = jnp.where(
                (idx == 0) & (t < n_micro),
                xs[inject].astype(buf.dtype),
                buf)
            y = stage_fn(params, incoming)
            # active iff this stage holds a real microbatch at tick t
            active = (t - idx >= 0) & (t - idx < n_micro)
            y = jnp.where(active, y, jnp.zeros_like(y))
            # last stage banks its finished microbatch
            done_idx = jnp.clip(t - (n_stages - 1), 0, n_micro - 1)
            outs = jnp.where(
                (idx == n_stages - 1) & active,
                outs.at[done_idx].set(y),
                outs)
            # shift activations to the next stage
            perm = [(i, i + 1) for i in range(n_stages - 1)]
            buf = jax.lax.ppermute(y, stage_axis, perm)
            return buf, outs

        _, outs = jax.lax.fori_loop(0, ticks, tick, (buf, outs))
        # only the last stage holds real outputs; broadcast them
        outs = jax.lax.psum(
            jnp.where(idx == n_stages - 1, outs, jnp.zeros_like(outs)),
            stage_axis)
        return outs

    spec_params = jax.tree.map(lambda _: P(stage_axis), params_stacked)
    fn = shard_map(per_stage, mesh=mesh,
                   in_specs=(spec_params, P()),
                   out_specs=P(),
                   check_rep=False)
    return fn(params_stacked, x_micro)


def sequential_reference(stage_fn, params_stacked, x_micro):
    """Oracle: run every stage in order on each microbatch."""
    n_stages = jax.tree.leaves(params_stacked)[0].shape[0]

    def run_one(x):
        for s in range(n_stages):
            p = jax.tree.map(lambda a: a[s], params_stacked)
            x = stage_fn(p, x)
        return x

    return jax.vmap(run_one)(x_micro)
