"""Jit wrapper: full SSD scan = Pallas chunk kernel + tiny inter-chunk scan."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .kernel import ssd_chunk_kernel
from .ref import ssd_ref


@partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x, dt, A, Bm, Cm, chunk: int = 64, interpret: bool = False):
    """Chunked SSD forward.  Same contract as `ssd_ref`.

    x: (B,S,H,P); dt: (B,S,H); A: (H,); Bm/Cm: (B,S,N)
    -> (y: (B,S,H,P), final_state: (B,H,P,N) f32)
    """
    Bsz, S, H, P = x.shape
    N = Bm.shape[-1]
    Q = min(chunk, S)
    pad = (-S) % Q
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    Sp = S + pad
    nc = Sp // Q

    y_intra, states, in_decay, chunk_decay = ssd_chunk_kernel(
        x, dt, A, Bm, Cm, chunk=Q, interpret=interpret)

    # inter-chunk recurrence over (B,H,P,N) chunk states
    def step(h_prev, inp):
        st, dec = inp                       # (B,H,P,N), (B,H,1)
        h = h_prev * dec[..., None] + st
        return h, h_prev

    init = jnp.zeros((Bsz, H, P, N), jnp.float32)
    final, prev = jax.lax.scan(
        step, init,
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2, 3)))
    prev = prev.transpose(1, 0, 2, 3, 4)     # (B,nc,H,P,N)

    # Y_inter[i] = (C_i . h_prev_chunk) * exp(cum_i)
    Cc = Cm.reshape(Bsz, nc, Q, N).astype(jnp.float32)
    y_inter = jnp.einsum("bcin,bchpn,bchi->bchip", Cc, prev, in_decay)
    y = (y_intra + y_inter).transpose(0, 1, 3, 2, 4).reshape(Bsz, Sp, H, P)
    if pad:
        y = y[:, :S]
    return y.astype(x.dtype), final


__all__ = ["ssd_scan", "ssd_ref"]
