"""Naive per-token recurrence oracle for the Mamba2 SSD scan."""
import jax
import jax.numpy as jnp


def ssd_ref(x, dt, A, Bm, Cm):
    """Sequential state-space recurrence.

    x: (B,S,H,P); dt: (B,S,H); A: (H,); Bm/Cm: (B,S,N).
    Returns (y: (B,S,H,P), final_state: (B,H,P,N))."""
    Bsz, S, H, P = x.shape
    N = Bm.shape[-1]

    def step(h_state, inp):
        xt, dtt, bt, ct = inp           # (B,H,P), (B,H), (B,N), (B,N)
        dA = jnp.exp(jnp.clip(dtt * A[None, :], -60.0, 0.0))
        upd = jnp.einsum("bhp,bn->bhpn", xt * dtt[..., None], bt)
        h_state = h_state * dA[..., None, None] + upd
        y = jnp.einsum("bhpn,bn->bhp", h_state, ct)
        return h_state, y

    init = jnp.zeros((Bsz, H, P, N), jnp.float32)
    xs = (x.astype(jnp.float32).transpose(1, 0, 2, 3),
          dt.astype(jnp.float32).transpose(1, 0, 2),
          Bm.astype(jnp.float32).transpose(1, 0, 2),
          Cm.astype(jnp.float32).transpose(1, 0, 2))
    final, ys = jax.lax.scan(step, init, xs)
    return ys.transpose(1, 0, 2, 3).astype(x.dtype), final
