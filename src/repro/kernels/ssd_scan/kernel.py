"""Mamba2 SSD chunk kernel: the intra-chunk dual form on the MXU.

Per grid step (b, c, h) the kernel computes, entirely in VMEM:
  * within-chunk decay L[i,j] = exp(cum[i]-cum[j]) (i>=j) from the dt*A
    cumulative sum;
  * Y_intra = ((C B^T) . L) (x*dt)       — two (Q x Q)/(Q x P) matmuls;
  * the chunk's outgoing state  sum_j exp(cum[end]-cum[j]) B_j (x*dt)_j;
  * the incoming-state operators: in_decay = exp(cum) (for Y_inter outside)
    and chunk_decay = exp(cum[end]).

The inter-chunk recurrence (a tiny (H,P,N) scan over chunks) and the
Y_inter = C . h_prev correction stay outside in ops.py: they are O(S/Q)
sequential work on small tensors, while all O(S*Q) math runs here.  This is
the paper's interval structure again: a chunk = one interval whose working
set (x, B, C, dt tiles + the Q x Q decay) is VMEM-resident; the HBM stream
is a single pass.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import tpu_compiler_params


def _ssd_chunk_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref,
                      y_ref, state_ref, indecay_ref, chunkdecay_ref):
    x = x_ref[0, 0, 0].astype(jnp.float32)        # (Q, P)
    dt = dt_ref[0, 0, 0].astype(jnp.float32)      # (Q,)
    A = a_ref[0]                                   # scalar (this head)
    Bm = b_ref[0, 0].astype(jnp.float32)           # (Q, N)
    Cm = c_ref[0, 0].astype(jnp.float32)           # (Q, N)

    dA = dt * A                                    # (Q,) negative
    cum = jnp.cumsum(dA)
    seg = cum[:, None] - cum[None, :]              # (Q, Q)
    Q = x.shape[0]
    ii = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    L = jnp.where(ii >= jj, jnp.exp(jnp.clip(seg, -60.0, 0.0)), 0.0)

    xdt = x * dt[:, None]                          # (Q, P)
    G = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (Q, Q)
    y = jax.lax.dot_general(G * L, xdt, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (Q, P)

    decay_end = jnp.exp(jnp.clip(cum[-1] - cum, -60.0, 0.0))      # (Q,)
    state = jax.lax.dot_general(
        xdt * decay_end[:, None], Bm, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)                       # (P, N)

    y_ref[0, 0, 0] = y.astype(y_ref.dtype)
    state_ref[0, 0, 0] = state
    indecay_ref[0, 0, 0] = jnp.exp(jnp.clip(cum, -60.0, 0.0))
    chunkdecay_ref[0, 0, 0] = jnp.exp(jnp.clip(cum[-1:], -60.0, 0.0))


def ssd_chunk_kernel(x, dt, A, Bm, Cm, *, chunk: int, interpret: bool = False):
    """x: (B,S,H,P); dt: (B,S,H); A: (H,) f32; Bm/Cm: (B,S,N).

    Returns (y_intra: (B,nc,H,Q,P) f32, states: (B,nc,H,P,N) f32,
             in_decay: (B,nc,H,Q) f32, chunk_decay: (B,nc,H,1) f32)."""
    Bsz, S, H, P = x.shape
    N = Bm.shape[-1]
    Q = chunk
    assert S % Q == 0, (S, Q)
    nc = S // Q

    xc = x.reshape(Bsz, nc, Q, H, P).transpose(0, 1, 3, 2, 4)   # (B,nc,H,Q,P)
    dtc = dt.reshape(Bsz, nc, Q, H).transpose(0, 1, 3, 2)       # (B,nc,H,Q)
    Bc = Bm.reshape(Bsz, nc, Q, N)
    Cc = Cm.reshape(Bsz, nc, Q, N)

    grid = (Bsz, nc, H)
    return pl.pallas_call(
        _ssd_chunk_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, 1, Q, P), lambda b, c, h: (b, c, h, 0, 0)),
            pl.BlockSpec((1, 1, 1, Q), lambda b, c, h: (b, c, h, 0)),
            pl.BlockSpec((1,), lambda b, c, h: (h,)),
            pl.BlockSpec((1, 1, Q, N), lambda b, c, h: (b, c, 0, 0)),
            pl.BlockSpec((1, 1, Q, N), lambda b, c, h: (b, c, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, 1, Q, P), lambda b, c, h: (b, c, h, 0, 0)),
            pl.BlockSpec((1, 1, 1, P, N), lambda b, c, h: (b, c, h, 0, 0)),
            pl.BlockSpec((1, 1, 1, Q), lambda b, c, h: (b, c, h, 0)),
            pl.BlockSpec((1, 1, 1, 1), lambda b, c, h: (b, c, h, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bsz, nc, H, Q, P), jnp.float32),
            jax.ShapeDtypeStruct((Bsz, nc, H, P, N), jnp.float32),
            jax.ShapeDtypeStruct((Bsz, nc, H, Q), jnp.float32),
            jax.ShapeDtypeStruct((Bsz, nc, H, 1), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary", "parallel"),
        ),
        interpret=interpret,
    )(xc, dtc, A.astype(jnp.float32), Bc, Cc)
