"""Version compatibility helpers shared by the Pallas TPU kernels."""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu


def tpu_compiler_params(**kwargs):
    """Build Mosaic compiler params across jax versions.

    The class was renamed ``TPUCompilerParams`` -> ``CompilerParams`` in newer
    jax releases; accept either so the kernels run on the full supported range.
    """
    cls = getattr(pltpu, "CompilerParams", None) \
        or getattr(pltpu, "TPUCompilerParams")
    return cls(**kwargs)
