"""Version/environment compatibility helpers shared by the jax-facing code."""
from __future__ import annotations

import os


def tpu_compiler_params(**kwargs):
    """Build Mosaic compiler params across jax versions.

    The class was renamed ``TPUCompilerParams`` -> ``CompilerParams`` in newer
    jax releases; accept either so the kernels run on the full supported range.
    """
    from jax.experimental.pallas import tpu as pltpu

    cls = getattr(pltpu, "CompilerParams", None) \
        or getattr(pltpu, "TPUCompilerParams")
    return cls(**kwargs)


def jax_subprocess_env(extra: dict | None = None) -> dict:
    """Minimal environment for subprocesses that import jax.

    Always pins ``JAX_PLATFORMS`` (defaulting to ``cpu``): without it jax
    probes for accelerator plugins, which hangs forever on hosts with a
    TPU-less libtpu — the failure mode behind the seed's
    ``test_pipeline_parallel`` timeout, and the same class of hang any
    frontend tracing subprocess would hit.  Use this instead of ad-hoc env
    dicts whenever spawning a python that will ``import jax``.
    """
    env = {
        "PATH": os.environ.get("PATH", "/usr/bin:/bin"),
        "PYTHONPATH": os.environ.get("PYTHONPATH", "src"),
        "JAX_PLATFORMS": os.environ.get("JAX_PLATFORMS", "cpu"),
    }
    for key in ("HOME", "TMPDIR", "XDG_CACHE_HOME"):
        if key in os.environ:
            env[key] = os.environ[key]
    if extra:
        env.update(extra)
    return env
