"""Naive-softmax oracle for blocked causal attention (GQA)."""
import jax.numpy as jnp


def attention_ref(q, k, v, causal: bool = True):
    """q: (B, H, S, d); k/v: (B, KV, S, d); KV divides H."""
    B, H, S, d = q.shape
    KV = k.shape[1]
    rep = H // KV
    k = jnp.repeat(k, rep, axis=1)
    v = jnp.repeat(v, rep, axis=1)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) / jnp.sqrt(float(d))
    if causal:
        mask = jnp.tril(jnp.ones((S, S), bool))
        logits = jnp.where(mask[None, None], logits, -1e30)
    p = jnp.exp(logits - logits.max(-1, keepdims=True))
    p = p / p.sum(-1, keepdims=True)
    return jnp.einsum("bhqk,bhkd->bhqd", p,
                      v.astype(jnp.float32)).astype(q.dtype)
