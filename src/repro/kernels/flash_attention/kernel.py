"""Blocked causal attention (FlashAttention-style online softmax) for TPU.

Grid (bh, qi, ki) with the KV axis innermost ('arbitrary'): running max /
sum / accumulator tiles live in VMEM scratch across KV steps, so HBM traffic
is one pass over Q, K, V and one write of O — the attention analogue of the
LTRF working-set guarantee (everything the inner loop touches is
VMEM-resident; K/V tiles stream through the pipeline's buffer slots).

GQA is handled in the index map: query head h reads kv head h // (H // KV).
Causality is enforced per-tile with an index mask (fully-masked tiles still
execute; the wrapper chooses block sizes so they are a small fraction).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import tpu_compiler_params

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
                  *, scale: float, bq: int, bk: int, n_k: int, causal: bool):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)            # (bq, d)
    k = k_ref[0].astype(jnp.float32)            # (bk, d)
    v = v_ref[0].astype(jnp.float32)            # (bk, d)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    if causal:
        q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        k_pos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        s = jnp.where(q_pos >= k_pos, s, NEG_INF)

    m_prev = m_ref[...]                          # (bq,)
    m_cur = jnp.maximum(m_prev, s.max(axis=-1))
    alpha = jnp.exp(m_prev - m_cur)
    p = jnp.exp(s - m_cur[:, None])
    l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_cur

    @pl.when(ki == n_k - 1)
    def _flush():
        o_ref[0] = (acc_ref[...]
                    / jnp.maximum(l_ref[...], 1e-30)[:, None]).astype(o_ref.dtype)


def flash_attention_kernel(
    q: jax.Array,            # (BH, S, d)   (batch*heads flattened)
    k: jax.Array,            # (BKV, S, d)
    v: jax.Array,
    *,
    group: int,              # H // KV (query heads per kv head)
    bq: int = 512,
    bk: int = 512,
    causal: bool = True,
    interpret: bool = False,
) -> jax.Array:
    BH, S, d = q.shape
    assert S % bq == 0 and S % bk == 0
    n_k = S // bk
    scale = 1.0 / (d ** 0.5)

    grid = (BH, S // bq, n_k)
    return pl.pallas_call(
        functools.partial(_flash_kernel, scale=scale, bq=bq, bk=bk,
                          n_k=n_k, causal=causal),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, bk, d), lambda bh, qi, ki, g=group: (bh // g, ki, 0)),
            pl.BlockSpec((1, bk, d), lambda bh, qi, ki, g=group: (bh // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),      # running max
            pltpu.VMEM((bq,), jnp.float32),      # running sum
            pltpu.VMEM((bq, d), jnp.float32),    # output accumulator
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(q, k, v)
