"""Jit wrapper for the blocked causal attention kernel (GQA layout glue)."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .kernel import flash_attention_kernel
from .ref import attention_ref


@partial(jax.jit, static_argnames=("bq", "bk", "causal", "interpret"))
def flash_attention(q, k, v, bq: int = 256, bk: int = 256,
                    causal: bool = True, interpret: bool = False):
    """q: (B, H, S, d); k/v: (B, KV, S, d) -> (B, H, S, d)."""
    B, H, S, d = q.shape
    KV = k.shape[1]
    assert H % KV == 0, (H, KV)
    bq = min(bq, S)
    bk = min(bk, S)
    assert S % bq == 0 and S % bk == 0, (S, bq, bk)
    out = flash_attention_kernel(
        q.reshape(B * H, S, d),
        k.reshape(B * KV, S, d),
        v.reshape(B * KV, S, d),
        group=H // KV, bq=bq, bk=bk, causal=causal, interpret=interpret)
    return out.reshape(B, H, S, d)


__all__ = ["flash_attention", "attention_ref"]
