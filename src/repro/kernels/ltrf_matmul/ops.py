"""Jit wrapper: LTRF-planned matmul with interval-derived tile sizes.

`ltrf_matmul(x, w)` consults `repro.core.plan.plan_for_matmul` to choose
(bk, bn) so the in-flight working set — two weight-tile slots (double
buffer), the x tile and the fp32 accumulator — fits the VMEM budget, then
pads to MXU-aligned blocks and calls the Pallas kernel.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.plan import plan_for_matmul

from .kernel import ltrf_matmul_kernel
from .ref import matmul_ref

VMEM_BUDGET = 96 * 2 ** 20  # leave headroom below the ~128MB v5e VMEM


def _round_up(v: int, m: int) -> int:
    return -(-v // m) * m


def pick_blocks(M: int, K: int, N: int, dtype_bytes: int = 2,
                vmem_budget: int = VMEM_BUDGET) -> tuple[int, int, int]:
    """Choose MXU-aligned (bm, bk, bn) whose working set fits VMEM.

    working set = bm*bk (x tile) + 2*bk*bn (double-buffered weight tiles)
                + bm*bn*4 (fp32 acc) + bm*bn (out tile)."""
    bm = min(_round_up(min(M, 256), 128), _round_up(M, 128))
    best = None
    for bk in (2048, 1024, 512, 256, 128):
        for bn in (1024, 512, 256, 128):
            ws = (bm * bk * dtype_bytes + 2 * bk * bn * dtype_bytes
                  + bm * bn * 4 + bm * bn * dtype_bytes)
            if ws <= vmem_budget:
                cand = (bk * bn, bk, bn)
                if best is None or cand > best:
                    best = cand
    assert best is not None
    _, bk, bn = best
    return bm, min(bk, _round_up(K, 128)), min(bn, _round_up(N, 128))


@partial(jax.jit, static_argnames=("bm", "bk", "bn", "interpret", "use_plan"))
def ltrf_matmul(x, w, bm: int = 0, bk: int = 0, bn: int = 0,
                interpret: bool = False, use_plan: bool = True):
    """x: (M, K) @ w: (K, N) -> (M, N) via the LTRF-planned Pallas kernel."""
    M, K = x.shape
    _, N = w.shape
    if bm == 0 or bk == 0 or bn == 0:
        bm, bk, bn = pick_blocks(M, K, N, x.dtype.itemsize)
    Mp, Kp, Np = _round_up(M, bm), _round_up(K, bk), _round_up(N, bn)
    xp = jnp.pad(x, ((0, Mp - M), (0, Kp - K))) if (Mp, Kp) != (M, K) else x
    wp = jnp.pad(w, ((0, Kp - K), (0, Np - N))) if (Kp, Np) != (K, N) else w
    out = ltrf_matmul_kernel(xp, wp, bm=bm, bk=bk, bn=bn, interpret=interpret)
    return out[:M, :N]


def matmul_plan(M: int, K: int, N: int, dtype_bytes: int = 2,
                vmem_budget: int = VMEM_BUDGET):
    """The explicit IntervalPlan for this matmul's weight stream (for
    inspection/validation: one prefetch round per interval, slots
    conflict-free)."""
    bm, bk, bn = pick_blocks(M, K, N, dtype_bytes)
    plan = plan_for_matmul(M, K, N, bk, bn, vmem_budget=vmem_budget,
                           num_slots=2, dtype_bytes=dtype_bytes)
    plan.validate()
    return plan, (bm, bk, bn)


__all__ = ["ltrf_matmul", "matmul_plan", "matmul_ref", "pick_blocks"]
