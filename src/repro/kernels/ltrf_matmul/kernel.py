"""LTRF-planned blocked matmul — the paper's prefetch scheme as a TPU kernel.

Mapping (DESIGN.md §2B): the weight matrix lives in HBM (the paper's big/slow
main register file); each (bk x bn) tile is a "register"; VMEM is the
register cache.  Pallas's software pipeline emits the HBM->VMEM copy of tile
t+1 while the MXU consumes tile t — that is exactly the paper's "prefetch
overlapped with other warps' execution", with the grid's K-innermost
iteration order playing the role of the interval schedule and the pipeline's
buffer slots the role of register-cache banks.  `repro.core.plan` chooses
tile shapes so one interval (two in-flight tiles + operand/accumulator
blocks) fits the VMEM budget, and verifies the tile->slot assignment is
conflict-free (no DMA ever targets a slot still being read).

Block shapes must be MXU-aligned (multiples of 128 in the matmul dims); the
wrapper in ops.py pads as needed.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import tpu_compiler_params


def _ltrf_matmul_kernel(x_ref, w_ref, o_ref, acc_ref, *, n_k: int):
    """Grid (i, j, k): accumulate x[i,k] @ w[k,j] into acc; flush at k end."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        x_ref[...], w_ref[...],
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(k == n_k - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def ltrf_matmul_kernel(
    x: jax.Array,          # (M, K)
    w: jax.Array,          # (K, N)
    *,
    bm: int = 256,
    bk: int = 512,
    bn: int = 256,
    out_dtype=None,
    interpret: bool = False,
) -> jax.Array:
    M, K = x.shape
    K2, N = w.shape
    assert K == K2, (x.shape, w.shape)
    assert M % bm == 0 and K % bk == 0 and N % bn == 0, (
        f"unpadded shapes {(M, K, N)} vs blocks {(bm, bk, bn)}")
    out_dtype = out_dtype or x.dtype
    n_k = K // bk

    grid = (M // bm, N // bn, n_k)
    return pl.pallas_call(
        functools.partial(_ltrf_matmul_kernel, n_k=n_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(x, w)
