"""Pure-jnp oracle for the LTRF-planned matmul."""
import jax.numpy as jnp


def matmul_ref(x, w, out_dtype=None):
    out_dtype = out_dtype or x.dtype
    return jnp.dot(x.astype(jnp.float32), w.astype(jnp.float32)).astype(out_dtype)
