"""Deterministic synthetic data pipeline with background prefetch.

Design goals (mirrors what a production loader must provide):
  * **Stateless addressing** — ``batch_for_step(step)`` is a pure function of
    (seed, step, shape), so checkpoint restore replays the exact stream with
    no loader state to persist, and elastic re-sharding just changes which
    slice each host materializes.
  * **Host-side prefetch** — a double-buffered background thread keeps
    ``depth`` batches ready (the LTRF idea applied at the host->device
    boundary: fetch the next working set while the current one computes).
  * **Straggler mitigation** — ``get()`` returns a *recomputed* batch
    if the prefetch thread misses its deadline; the step never blocks on a
    slow producer.
  * **Restore safety** — ``restore(step)`` bumps a generation counter so an
    in-flight producer iteration cannot clobber the repositioned stream
    (stale-generation batches are discarded by the consumer).
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass

import numpy as np

from repro.configs.base import ArchConfig, ShapeConfig


@dataclass(frozen=True)
class DataConfig:
    seed: int = 1234
    depth: int = 2           # prefetch depth
    timeout_s: float = 5.0   # straggler deadline


def _rng_for(seed: int, step: int) -> np.random.Generator:
    return np.random.default_rng(np.random.SeedSequence([seed, step]))


def batch_for_step(cfg: ArchConfig, shape: ShapeConfig, step: int,
                   seed: int = 1234, host_slice: slice | None = None) -> dict:
    """Pure function (seed, step) -> batch.  ``host_slice`` selects this
    host's rows for multi-host data loading."""
    rng = _rng_for(seed, step)
    B, S = shape.global_batch, shape.seq_len
    sl = host_slice or slice(None)
    if cfg.family == "audio":
        codes = rng.integers(0, cfg.vocab, (B, cfg.n_codebooks, S),
                             dtype=np.int32)
        return {"codes": codes[sl], "labels": codes[sl]}
    if cfg.family == "vlm":
        toks = rng.integers(0, cfg.vocab, (B, S - cfg.n_patches), dtype=np.int32)
        patches = rng.standard_normal(
            (B, cfg.n_patches, cfg.d_model), dtype=np.float32) * 0.02
        labels = np.concatenate(
            [np.zeros((B, cfg.n_patches), np.int32), toks], axis=1)
        return {"tokens": toks[sl], "patches": patches[sl],
                "labels": labels[sl]}
    toks = rng.integers(0, cfg.vocab, (B, S), dtype=np.int32)
    return {"tokens": toks[sl], "labels": toks[sl]}


class PrefetchingLoader:
    """Background-threaded loader with deadline-based straggler fallback."""

    def __init__(self, cfg: ArchConfig, shape: ShapeConfig,
                 data_cfg: DataConfig | None = None, start_step: int = 0):
        self.cfg, self.shape = cfg, shape
        self.dc = data_cfg or DataConfig()
        self._q: queue.Queue = queue.Queue(maxsize=self.dc.depth)
        self._lock = threading.Lock()
        self._gen = 0
        self._next_produce = start_step
        self._next_consume = start_step
        self._stop = threading.Event()
        self.straggler_fallbacks = 0
        self._thread = threading.Thread(target=self._produce, daemon=True)
        self._thread.start()

    def _produce(self) -> None:
        while not self._stop.is_set():
            with self._lock:
                gen, step = self._gen, self._next_produce
            batch = batch_for_step(self.cfg, self.shape, step, self.dc.seed)
            try:
                self._q.put((gen, step, batch), timeout=0.25)
            except queue.Full:
                continue
            with self._lock:
                if gen == self._gen:   # a restore() may have intervened
                    self._next_produce = step + 1

    def get(self) -> dict:
        """Next batch; recomputes synchronously if the producer is late."""
        with self._lock:
            gen, step = self._gen, self._next_consume
        deadline_hits = 0
        batch = None
        while True:
            try:
                got_gen, got_step, got = self._q.get(timeout=self.dc.timeout_s)
            except queue.Empty:
                self.straggler_fallbacks += 1
                batch = batch_for_step(self.cfg, self.shape, step, self.dc.seed)
                break
            if got_gen == gen and got_step == step:
                batch = got
                break
            deadline_hits += 1
            if deadline_hits > 4 * self.dc.depth + 4:
                # stale stream (restore raced repeatedly): compute directly
                self.straggler_fallbacks += 1
                batch = batch_for_step(self.cfg, self.shape, step, self.dc.seed)
                break
        with self._lock:
            self._next_consume = step + 1
        return batch

    def restore(self, step: int) -> None:
        """Reposition the stream after checkpoint restore (exact replay)."""
        with self._lock:
            self._gen += 1
            self._next_consume = step
            self._next_produce = step
        while True:
            try:
                self._q.get_nowait()
            except queue.Empty:
                break

    def close(self) -> None:
        self._stop.set()
        self._thread.join(timeout=2)
