from .pipeline import DataConfig, PrefetchingLoader, batch_for_step

__all__ = ["DataConfig", "PrefetchingLoader", "batch_for_step"]
