"""Checkpointing: async, shard-manifest based, restore-with-resharding.

Layout (one directory per step):
    <dir>/step_000123/
        manifest.json      # tree structure, shapes, dtypes, content hashes
        arrays.npz         # flattened leaves (host arrays)
        COMMIT             # written last: a checkpoint without it is partial

* **Async**: `save_async` snapshots device arrays to host then writes on a
  background thread (double-buffered; at most one write in flight — a slow
  writer never blocks more than one step).
* **Integrity**: every leaf carries a sha256; `restore` verifies before use.
* **Restore-with-resharding**: arrays are loaded on host then `jax.device_put`
  with the *target* shardings — so a checkpoint written on one mesh restores
  onto a smaller/larger mesh (elastic scaling).
* **GC**: keep the last `keep` committed checkpoints.
"""
from __future__ import annotations

import hashlib
import json
import pathlib
import re
import shutil
import threading

import jax
import ml_dtypes  # registers bfloat16/f8 dtype names with numpy
import numpy as np

_UINT_OF_SIZE = {1: np.uint8, 2: np.uint16, 4: np.uint32, 8: np.uint64}


def _storable(a: np.ndarray) -> np.ndarray:
    """npz round-trips only standard dtypes; view exotic ones as raw uints."""
    if a.dtype.kind in "biufc":
        return a
    return np.ascontiguousarray(a).view(_UINT_OF_SIZE[a.dtype.itemsize])


def _restore_dtype(a: np.ndarray, dtype_str: str) -> np.ndarray:
    want = np.dtype(dtype_str)
    if a.dtype == want:
        return a
    return a.view(want)


def _flatten(tree):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def _tree_paths(tree):
    paths = [jax.tree_util.keystr(kp)
             for kp, _ in jax.tree_util.tree_flatten_with_path(tree)[0]]
    return paths


class Checkpointer:
    def __init__(self, directory: str | pathlib.Path, keep: int = 3):
        self.dir = pathlib.Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._inflight: threading.Thread | None = None

    # ------------------------------------------------------------------ save
    def save(self, step: int, tree) -> pathlib.Path:
        host = jax.tree.map(np.asarray, jax.device_get(tree))
        return self._write(step, host)

    def save_async(self, step: int, tree) -> None:
        """Snapshot to host now; write on a background thread."""
        self.wait()  # bounded in-flight: one writer
        host = jax.tree.map(np.asarray, jax.device_get(tree))
        self._inflight = threading.Thread(
            target=self._write, args=(step, host), daemon=True)
        self._inflight.start()

    def wait(self) -> None:
        if self._inflight is not None:
            self._inflight.join()
            self._inflight = None

    def _write(self, step: int, host_tree) -> pathlib.Path:
        leaves, _ = _flatten(host_tree)
        paths = _tree_paths(host_tree)
        out = self.dir / f"step_{step:09d}"
        tmp = self.dir / f".tmp_step_{step:09d}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        arrays = {f"a{i}": _storable(np.asarray(x)) for i, x in enumerate(leaves)}
        np.savez(tmp / "arrays.npz", **arrays)
        manifest = {
            "step": step,
            "leaves": [
                {
                    "key": f"a{i}",
                    "path": p,
                    "shape": list(np.asarray(x).shape),
                    "dtype": str(np.asarray(x).dtype),
                    "sha256": hashlib.sha256(
                        np.ascontiguousarray(x).tobytes()).hexdigest(),
                }
                for i, (p, x) in enumerate(zip(paths, leaves))
            ],
        }
        (tmp / "manifest.json").write_text(json.dumps(manifest, indent=1))
        (tmp / "COMMIT").write_text("ok")
        if out.exists():
            shutil.rmtree(out)
        tmp.rename(out)
        self._gc()
        return out

    # --------------------------------------------------------------- restore
    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def all_steps(self) -> list[int]:
        out = []
        for p in self.dir.glob("step_*"):
            if (p / "COMMIT").exists():
                m = re.match(r"step_(\d+)", p.name)
                if m:
                    out.append(int(m.group(1)))
        return sorted(out)

    def restore(self, step: int, like_tree, shardings=None, verify: bool = True):
        """Load checkpoint ``step`` shaped like ``like_tree``; device_put with
        ``shardings`` when given (restores onto any mesh)."""
        path = self.dir / f"step_{step:09d}"
        manifest = json.loads((path / "manifest.json").read_text())
        data = np.load(path / "arrays.npz")
        leaves, treedef = _flatten(like_tree)
        assert len(manifest["leaves"]) == len(leaves), (
            f"checkpoint has {len(manifest['leaves'])} leaves, "
            f"target tree has {len(leaves)}")
        out = []
        for i, (meta, like) in enumerate(zip(manifest["leaves"], leaves)):
            arr = _restore_dtype(data[meta["key"]], meta["dtype"])
            if verify:
                h = hashlib.sha256(np.ascontiguousarray(arr).tobytes()).hexdigest()
                if h != meta["sha256"]:
                    raise IOError(f"checkpoint corruption at leaf {meta['path']}")
            want = getattr(like, "shape", None)
            if want is not None and tuple(arr.shape) != tuple(want):
                raise ValueError(
                    f"leaf {meta['path']}: checkpoint shape {arr.shape} != "
                    f"target {want}")
            out.append(arr)
        tree = jax.tree.unflatten(treedef, out)
        if shardings is not None:
            tree = jax.tree.map(
                lambda x, s: jax.device_put(x, s), tree, shardings)
        else:
            # always hand back committed device arrays: numpy leaves would be
            # rejected by donating jit functions downstream
            tree = jax.tree.map(jax.device_put, tree)
        return tree

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(self.dir / f"step_{s:09d}", ignore_errors=True)
