from .adamw import (
    AdamWConfig, adamw_update, global_norm, init_opt_state, lr_schedule,
    opt_state_axes,
)
from .compression import CompressionConfig, compress_gradients

__all__ = [
    "AdamWConfig", "adamw_update", "global_norm", "init_opt_state",
    "lr_schedule", "opt_state_axes", "CompressionConfig",
    "compress_gradients",
]
