"""AdamW with decoupled weight decay, global-norm clipping and fp32 master
state — implemented directly in JAX (no optax dependency).

State mirrors the parameter pytree (so FSDP sharding rules apply to optimizer
state automatically) plus a scalar step count.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def lr_schedule(cfg: AdamWConfig, step):
    """Linear warmup + cosine decay."""
    step = step.astype(jnp.float32) if hasattr(step, "astype") else jnp.float32(step)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    prog = (step - cfg.warmup_steps) / jnp.maximum(
        cfg.total_steps - cfg.warmup_steps, 1)
    prog = jnp.clip(prog, 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    decayed = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, decayed)


def init_opt_state(params):
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return {
        "mu": zeros,
        "nu": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
        "step": jnp.zeros((), jnp.int32),
    }


def opt_state_axes(param_axes):
    """Logical axes for the optimizer state (mirrors params)."""
    return {
        "mu": param_axes,
        "nu": param_axes,
        "step": (),
    }


def global_norm(tree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_update(cfg: AdamWConfig, params, grads, state):
    """Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    lr = lr_schedule(cfg, step)

    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd_leaf(p, g, mu, nu):
        g = g.astype(jnp.float32) * scale
        mu = cfg.b1 * mu + (1 - cfg.b1) * g
        nu = cfg.b2 * nu + (1 - cfg.b2) * g * g
        mhat = mu / b1c
        vhat = nu / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), mu, nu

    # NOTE: a lax.map-over-layers variant was tried to shrink the fp32 update
    # temporaries (~0.66GB/leaf at 132B) but REGRESSED: scan outputs cannot
    # alias their inputs, so the optimizer state double-buffers (+4GB >> the
    # temp saving).  Measured in EXPERIMENTS.md §Perf (dbrx iter H8).
    upd = upd_leaf

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_mu = treedef.flatten_up_to(state["mu"])
    flat_nu = treedef.flatten_up_to(state["nu"])
    out = [upd(p, g, m, n) for p, g, m, n in zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_params = treedef.unflatten([o[0] for o in out])
    new_state = {
        "mu": treedef.unflatten([o[1] for o in out]),
        "nu": treedef.unflatten([o[2] for o in out]),
        "step": step,
    }
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
