"""Error-feedback int8 gradient compression.

Before the optimizer consumes gradients, each leaf is quantized to int8 with
a per-leaf scale; the quantization error is kept in an error-feedback buffer
and added back next step (1-bit-Adam-style EF-SGD guarantees).  Under pjit
this compresses the *mathematical* gradient values; on a real fleet it is
paired with XLA's int8 all-reduce (the quantize happens before the psum the
sharded value numbers flow through), cutting DP gradient traffic 4x vs fp32 /
2x vs bf16.

`compress_gradients` is pure and jit-safe; the error buffers live in the
train state.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class CompressionConfig:
    enabled: bool = True
    bits: int = 8
    ef: bool = True  # error feedback


def _quantize(x, bits: int):
    x = x.astype(jnp.float32)
    qmax = float(2 ** (bits - 1) - 1)
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / qmax
    q = jnp.clip(jnp.round(x / scale), -qmax, qmax)
    return q * scale  # dequantized value (int8 on the wire)


def compress_gradients(grads, err_state, cfg: CompressionConfig):
    """Returns (compressed_grads, new_err_state, stats)."""
    if err_state is None:
        err_state = jax.tree.map(
            lambda g: jnp.zeros(g.shape, jnp.float32), grads)

    def one(g, e):
        g32 = g.astype(jnp.float32)
        corrected = g32 + (e if cfg.ef else 0.0)
        q = _quantize(corrected, cfg.bits)
        new_e = corrected - q if cfg.ef else jnp.zeros_like(g32)
        return q.astype(g.dtype), new_e

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = treedef.flatten_up_to(err_state)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    comp = treedef.unflatten([o[0] for o in out])
    new_err = treedef.unflatten([o[1] for o in out])
    err_norm = jnp.sqrt(sum(jnp.sum(jnp.square(e)) for e in
                            [o[1] for o in out]))
    return comp, new_err, {"compression_err_norm": err_norm}
