"""phi3-medium-14b — dense, RoPE+SwiGLU+GQA.  [arXiv:2404.14219; unverified]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="phi3-medium-14b", family="dense",
    n_layers=40, d_model=5120, n_heads=40, n_kv_heads=10,
    d_ff=17920, vocab=100352,
    source="arXiv:2404.14219 (Phi-3 Technical Report); unverified tier",
)


def smoke() -> ArchConfig:
    return ArchConfig(
        name="phi3-medium-14b-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=1,
        d_ff=160, vocab=256, remat="none",
        source="reduced smoke variant",
    )
