"""qwen3-0.6b — dense with qk-norm, GQA, 151936 vocab.  [hf:Qwen/Qwen3-8B family; hf]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-0.6b", family="dense",
    n_layers=28, d_model=1024, n_heads=16, n_kv_heads=8,
    d_ff=3072, vocab=151936, head_dim=128, qk_norm=True,
    source="hf:Qwen/Qwen3-0.6B (qk_norm, head_dim 128); hf tier",
)


def smoke() -> ArchConfig:
    return ArchConfig(
        name="qwen3-0.6b-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=192, vocab=512, head_dim=32, qk_norm=True, remat="none",
        source="reduced smoke variant",
    )
