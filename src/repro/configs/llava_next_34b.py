"""llava-next-34b — VLM backbone (anyres tiling frontend is a stub:
``input_specs`` supplies precomputed patch embeddings).
[hf:llava-hf/llava-v1.6-*; unverified]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="llava-next-34b", family="vlm",
    n_layers=60, d_model=7168, n_heads=56, n_kv_heads=8,
    d_ff=20480, vocab=64000,
    n_patches=576,  # 24x24 anyres base grid (stubbed frontend)
    source="hf:llava-hf/llava-v1.6 family backbone; unverified tier",
)


def smoke() -> ArchConfig:
    return ArchConfig(
        name="llava-next-34b-smoke", family="vlm",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=192, vocab=256, n_patches=16, remat="none",
        source="reduced smoke variant",
    )
