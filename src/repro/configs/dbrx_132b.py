"""dbrx-132b — MoE 16 experts top-4, fine-grained.  [hf:databricks/dbrx-base; unverified]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="dbrx-132b", family="moe",
    n_layers=40, d_model=6144, n_heads=48, n_kv_heads=8,
    d_ff=10752, vocab=100352,
    n_experts=16, top_k=4,
    source="hf:databricks/dbrx-base; unverified tier",
)


def smoke() -> ArchConfig:
    return ArchConfig(
        name="dbrx-132b-smoke", family="moe",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=96, vocab=256, n_experts=4, top_k=2, remat="none",
        source="reduced smoke variant",
    )
