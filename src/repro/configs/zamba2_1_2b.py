"""zamba2-1.2b — hybrid: Mamba2 backbone + one shared attention block applied
every 6 layers (re-entrant weights, per-call-site KV caches).
[arXiv:2411.15242; hf]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-1.2b", family="hybrid",
    n_layers=38, d_model=2048, n_heads=32, n_kv_heads=32,
    d_ff=8192, vocab=32000,
    ssm_state=64, ssm_headdim=64, ssm_expand=2, ssm_chunk=256,
    attn_every=6,
    source="arXiv:2411.15242 (Zamba2); hf tier",
)


def smoke() -> ArchConfig:
    return ArchConfig(
        name="zamba2-1.2b-smoke", family="hybrid",
        n_layers=5, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=128, vocab=256, ssm_state=16, ssm_headdim=16, ssm_expand=2,
        ssm_chunk=16, attn_every=2, remat="none",
        source="reduced smoke variant",
    )
