"""musicgen-large — decoder-only over EnCodec tokens (4 codebooks).
The EnCodec frontend is a stub: inputs are codebook token ids, embedded and
summed (delay-pattern handling happens in the data pipeline).
[arXiv:2306.05284; hf]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="musicgen-large", family="audio",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=32,
    d_ff=8192, vocab=2048,
    n_codebooks=4,
    source="arXiv:2306.05284 (MusicGen); hf tier",
)


def smoke() -> ArchConfig:
    return ArchConfig(
        name="musicgen-large-smoke", family="audio",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=192, vocab=128, n_codebooks=4, remat="none",
        source="reduced smoke variant",
    )
