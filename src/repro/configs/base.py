"""Architecture + shape configuration substrate.

Every assigned architecture provides an `ArchConfig` (full production config)
plus a `smoke()` reduced config of the same family for CPU tests.  The four
assigned input shapes are defined here once; `input_specs` builds
ShapeDtypeStruct stand-ins (no allocation) for the dry-run.
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                 # dense | moe | vlm | audio | ssm | hybrid
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0           # 0 -> d_model // n_heads
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    # MoE
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    moe_groups: int = 1         # grouped dispatch (align with token sharding)
    # SSM (mamba2)
    ssm_state: int = 0
    ssm_headdim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 256
    # hybrid (zamba2)
    attn_every: int = 0         # shared attention block period
    # frontends
    n_codebooks: int = 0        # musicgen: parallel EnCodec codebooks
    n_patches: int = 0          # llava: image patch positions (frontend stub)
    norm_eps: float = 1e-5
    dtype: str = "bfloat16"
    kv_dtype: str = ""          # decode KV-cache dtype ("" -> dtype; e.g. float8_e4m3fn)
    remat: str = "full"         # none | block | full (full = recompute blocks)
    scan_layers: bool = True    # False: unrolled python loop (roofline probes)
    q_block: int = 512          # attention q-block (memory-efficient scan)
    source: str = ""            # provenance note

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // max(self.n_heads, 1))

    @property
    def jdtype(self):
        return jnp.bfloat16 if self.dtype == "bfloat16" else jnp.float32

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        return self.family in ("ssm", "hybrid")

    def param_count(self) -> int:
        """Analytic parameter count (embeddings + blocks + head)."""
        D, F, L, V = self.d_model, self.d_ff, self.n_layers, self.vocab
        H, KV, hd = self.n_heads, self.n_kv_heads, self.hd
        n = V * D  # embed
        if self.n_codebooks:
            n = self.n_codebooks * V * D
        attn = D * H * hd + 2 * D * KV * hd + H * hd * D
        mlp = 3 * D * F
        if self.family == "moe":
            per_layer = attn + self.n_experts * mlp + D * self.n_experts + 2 * D
            n += L * per_layer
        elif self.family == "ssm":
            n += L * self._mamba_params() + L * D
        elif self.family == "hybrid":
            n += L * self._mamba_params() + L * D
            n += attn + mlp + 2 * D  # one shared block
        else:
            n += L * (attn + mlp + 2 * D)
        n += D  # final norm
        n += D * V * max(self.n_codebooks, 1)  # head
        return n

    def _mamba_params(self) -> int:
        D = self.d_model
        d_inner = self.ssm_expand * D
        nheads = d_inner // self.ssm_headdim
        d_in_proj = 2 * d_inner + 2 * self.ssm_state + nheads
        return (D * d_in_proj + 4 * (d_inner + 2 * self.ssm_state)
                + 3 * nheads + d_inner + d_inner * D)

    def active_param_count(self) -> int:
        """MoE: params touched per token (top-k experts only)."""
        if self.family != "moe":
            return self.param_count()
        D, F, L = self.d_model, self.d_ff, self.n_layers
        H, KV, hd = self.n_heads, self.n_kv_heads, self.hd
        attn = D * H * hd + 2 * D * KV * hd + H * hd * D
        mlp = 3 * D * F
        n = self.vocab * D * 2
        n += L * (attn + self.top_k * mlp + D * self.n_experts + 2 * D)
        return n


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                   # train | prefill | decode

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


def cell_is_runnable(arch: ArchConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """Whether (arch x shape) is a defined dry-run cell (see DESIGN.md)."""
    if shape.name == "long_500k" and not arch.sub_quadratic:
        return False, "full quadratic attention at 524k context: skipped per assignment"
    return True, ""


def input_specs(arch: ArchConfig, shape: ShapeConfig) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    f = arch.jdtype
    if shape.kind in ("train", "prefill"):
        if arch.family == "vlm":
            n_img = arch.n_patches
            return {
                "tokens": jax.ShapeDtypeStruct((B, S - n_img), i32),
                "patches": jax.ShapeDtypeStruct((B, n_img, arch.d_model), f),
                "labels": jax.ShapeDtypeStruct((B, S), i32),
            }
        if arch.family == "audio":
            K = arch.n_codebooks
            return {
                "codes": jax.ShapeDtypeStruct((B, K, S), i32),
                "labels": jax.ShapeDtypeStruct((B, K, S), i32),
            }
        return {
            "tokens": jax.ShapeDtypeStruct((B, S), i32),
            "labels": jax.ShapeDtypeStruct((B, S), i32),
        }
    # decode: one new token against a seq_len-deep cache
    if arch.family == "audio":
        tok = jax.ShapeDtypeStruct((B, arch.n_codebooks, 1), i32)
    else:
        tok = jax.ShapeDtypeStruct((B, 1), i32)
    return {"tokens": tok, "cache_len": jax.ShapeDtypeStruct((), i32)}


def smoke_shape(kind: str = "train") -> ShapeConfig:
    if kind == "decode":
        return ShapeConfig("smoke_decode", 64, 2, "decode")
    return ShapeConfig("smoke_train", 64, 2, "train")
