from .base import (
    ArchConfig, SHAPES, ShapeConfig, cell_is_runnable, input_specs,
    smoke_shape,
)
from .registry import ARCH_IDS, all_cells, get_arch, get_smoke

__all__ = [
    "ArchConfig", "SHAPES", "ShapeConfig", "cell_is_runnable", "input_specs",
    "smoke_shape", "ARCH_IDS", "all_cells", "get_arch", "get_smoke",
]
