"""mamba2-1.3b — attention-free SSD (state-space duality).  [arXiv:2405.21060; unverified]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-1.3b", family="ssm",
    n_layers=48, d_model=2048, n_heads=0, n_kv_heads=0,
    d_ff=0, vocab=50280,
    ssm_state=128, ssm_headdim=64, ssm_expand=2, ssm_chunk=256,
    source="arXiv:2405.21060 (Mamba-2 / SSD); unverified tier",
)


def smoke() -> ArchConfig:
    return ArchConfig(
        name="mamba2-1.3b-smoke", family="ssm",
        n_layers=2, d_model=64, n_heads=0, n_kv_heads=0,
        d_ff=0, vocab=256, ssm_state=16, ssm_headdim=16, ssm_expand=2,
        ssm_chunk=16, remat="none",
        source="reduced smoke variant",
    )
