"""granite-moe-3b-a800m — fine-grained MoE, 40 experts top-8.
[hf:ibm-granite/granite-3.0-*-base family; hf]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="granite-moe-3b-a800m", family="moe",
    n_layers=32, d_model=1536, n_heads=24, n_kv_heads=8,
    d_ff=512, vocab=49155,
    n_experts=40, top_k=8,
    source="hf:ibm-granite/granite-3.0-3b-a800m-base; hf tier",
)


def smoke() -> ArchConfig:
    return ArchConfig(
        name="granite-moe-3b-a800m-smoke", family="moe",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=64, vocab=256, n_experts=8, top_k=2, remat="none",
        source="reduced smoke variant",
    )
