"""granite-20b — dense llama-arch code model, MQA (kv=1).  [arXiv:2405.04324; hf]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="granite-20b", family="dense",
    n_layers=52, d_model=6144, n_heads=48, n_kv_heads=1,
    d_ff=24576, vocab=49152,
    source="arXiv:2405.04324 (Granite Code Models); hf tier",
)


def smoke() -> ArchConfig:
    return ArchConfig(
        name="granite-20b-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=1,
        d_ff=256, vocab=256, remat="none",
        source="reduced smoke variant",
    )
