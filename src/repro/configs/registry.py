"""Architecture registry: --arch <id> resolution + smoke variants."""
from __future__ import annotations

import importlib

from .base import ArchConfig, SHAPES, ShapeConfig, cell_is_runnable, input_specs

ARCH_IDS = [
    "phi3-medium-14b",
    "tinyllama-1.1b",
    "granite-20b",
    "qwen3-0.6b",
    "granite-moe-3b-a800m",
    "dbrx-132b",
    "llava-next-34b",
    "musicgen-large",
    "mamba2-1.3b",
    "zamba2-1.2b",
]

_MODULES = {a: a.replace("-", "_").replace(".", "_") for a in ARCH_IDS}


def get_arch(arch_id: str) -> ArchConfig:
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch_id]}")
    return mod.CONFIG


def get_smoke(arch_id: str) -> ArchConfig:
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch_id]}")
    return mod.smoke()


def all_cells() -> list[tuple[str, str, bool, str]]:
    """[(arch_id, shape_name, runnable, skip_reason)] for all 40 cells."""
    out = []
    for a in ARCH_IDS:
        cfg = get_arch(a)
        for s in SHAPES.values():
            ok, why = cell_is_runnable(cfg, s)
            out.append((a, s.name, ok, why))
    return out
