"""The lazy ``traced`` suite: in-repo kernels lifted through the frontend.

Importing this module only registers the suite's *names*; tracing (which
needs jax) runs the first time a traced workload is requested via
`get_workload` / `load_suite` / `workload_names("traced")`.
"""
from __future__ import annotations

from repro.frontend.workloads import TRACED_NAMES

from .suite import register_suite


def _load():
    from repro.frontend.workloads import traced_suite

    return traced_suite().values()


register_suite("traced", _load, names=TRACED_NAMES)
