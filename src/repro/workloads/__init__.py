from .suite import (WORKLOADS, Workload, get_workload, listing1_program,
                    load_suite, register_suite, register_workload,
                    workload_names)
from . import traced as _traced  # noqa: F401  (registers the lazy traced suite)

__all__ = ["WORKLOADS", "Workload", "get_workload", "listing1_program",
           "load_suite", "register_suite", "register_workload",
           "workload_names"]
