from .suite import WORKLOADS, Workload, get_workload, listing1_program

__all__ = ["WORKLOADS", "Workload", "get_workload", "listing1_program"]
