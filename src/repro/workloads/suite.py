"""The workload registry + the synthetic suite.

`WORKLOADS` is a *registry*: the 14 synthetic kernels (9 register-sensitive +
5 register-insensitive, mirroring the paper's CUDA-SDK / Rodinia / Parboil
mix, §6 Fig. 3) register eagerly at import, and further suites register
lazily via `register_suite` — the ``traced`` suite (the repo's own kernels
lifted through `repro.frontend`) only traces when first requested, so
jax-free consumers and the tracked benchmark job list are unaffected.
Also exports the paper's Listing-1 walk-through program.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Iterable

from repro.core.ir import Program, parse_asm

from .synth import SynthSpec, synthesize

LISTING1 = """
    mov r0, A
    mov r1, B
    mov r2, 0
    mov r3, 100
L1: ld r4, [r0]
    ld r5, [r1]
    set p0, r4, r5
    @!p0 bra L2
    add r0, r0, 4
    add r1, r1, 4
    add r2, r2, 1
    set p1, r2, r3
    @p1 bra L1
    mov r6, 1
    bra L3
L2: mov r6, 0
L3: exit
"""


def listing1_program() -> Program:
    return parse_asm(LISTING1, name="listing1")


@dataclass(frozen=True)
class Workload:
    name: str
    program: Program
    trips: dict[str, int]
    register_sensitive: bool
    regs_per_thread: int  # compiled (maxregcount) register demand
    suite: str
    l1_hit: float = 0.85  # data-cache hit rate

    @property
    def key(self) -> str:
        return self.name


def _mk(name: str, suite: str, sensitive: bool, **kw) -> Workload:
    spec = SynthSpec(name=name, **kw)
    prog, trips = synthesize(spec)
    return Workload(name=name, program=prog, trips=trips,
                    register_sensitive=sensitive,
                    regs_per_thread=spec.regs_per_thread, suite=suite,
                    l1_hit=spec.l1_hit)


def _build_suite() -> dict[str, Workload]:
    ws: list[Workload] = [
        # --- register-sensitive (occupancy-capped at 256KB) ---
        _mk("backprop", "rodinia", True, seed=11, n_regs=40, loop_depth=2,
            body_len=14, mem_ratio=0.3, trips=(6, 10), regs_per_thread=48),
        _mk("hotspot", "rodinia", True, seed=12, n_regs=44, loop_depth=2,
            body_len=18, mem_ratio=0.25, diamonds=1, trips=(5, 8), regs_per_thread=52),
        _mk("lud", "rodinia", True, seed=13, n_regs=36, loop_depth=3,
            body_len=10, mem_ratio=0.2, trips=(4, 4, 6), regs_per_thread=64),
        _mk("srad", "rodinia", True, seed=14, n_regs=48, loop_depth=2,
            body_len=20, mem_ratio=0.3, diamonds=2, trips=(5, 8), regs_per_thread=72),
        _mk("gaussian", "rodinia", True, seed=15, n_regs=34, loop_depth=2,
            body_len=12, mem_ratio=0.35, trips=(6, 8), regs_per_thread=56),
        _mk("sgemm", "parboil", True, seed=16, n_regs=52, loop_depth=2,
            body_len=24, mem_ratio=0.15, trips=(4, 12), regs_per_thread=60),
        _mk("mri-q", "parboil", True, seed=17, n_regs=42, loop_depth=1,
            body_len=30, mem_ratio=0.2, trips=(24,), regs_per_thread=80),
        _mk("stencil", "parboil", True, seed=18, n_regs=38, loop_depth=3,
            body_len=12, mem_ratio=0.3, trips=(3, 4, 8), regs_per_thread=54),
        _mk("dct8x8", "cudasdk", True, seed=19, n_regs=46, loop_depth=1,
            body_len=36, mem_ratio=0.18, diamonds=1, trips=(16,), regs_per_thread=62),
        # --- register-insensitive (fit 64 warps at 256KB) ---
        _mk("btree", "rodinia", False, seed=21, n_regs=16, loop_depth=1,
            body_len=10, mem_ratio=0.45, diamonds=2, trips=(12,), regs_per_thread=18, l1_hit=0.5),
        _mk("kmeans", "rodinia", False, seed=22, n_regs=18, loop_depth=2,
            body_len=8, mem_ratio=0.4, trips=(6, 8), regs_per_thread=20, l1_hit=0.6),
        _mk("bfs", "rodinia", False, seed=23, n_regs=14, loop_depth=1,
            body_len=8, mem_ratio=0.5, diamonds=1, trips=(14,), regs_per_thread=16, l1_hit=0.45),
        _mk("nw", "rodinia", False, seed=24, n_regs=20, loop_depth=2,
            body_len=9, mem_ratio=0.35, trips=(6, 6), regs_per_thread=24, l1_hit=0.65),
        _mk("pathfinder", "rodinia", False, seed=25, n_regs=17, loop_depth=1,
            body_len=11, mem_ratio=0.4, diamonds=1, trips=(16,), regs_per_thread=20, l1_hit=0.55),
    ]
    return {w.name: w for w in ws}


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

WORKLOADS: dict[str, Workload] = {}

# Suites whose loaders run only on first use (tracing real kernels needs jax).
_SUITE_LOADERS: dict[str, Callable[[], Iterable[Workload]]] = {}
_SUITE_NAMES: dict[str, tuple[str, ...]] = {}
_LOADED_SUITES: set[str] = set()

# The stable synthetic default: sweep/benchmark job lists are built from these
# suites unless a caller asks for more, so lazily-registered workloads can
# never silently change the tracked perf artifact.
SYNTH_SUITES = ("rodinia", "parboil", "cudasdk")


def register_workload(w: Workload, replace: bool = False) -> Workload:
    """Add a workload to the registry (errors on collisions unless asked)."""
    if not replace and w.name in WORKLOADS:
        raise ValueError(f"workload {w.name!r} already registered")
    WORKLOADS[w.name] = w
    return w


def register_suite(suite: str, loader: Callable[[], Iterable[Workload]],
                   names: Iterable[str]) -> None:
    """Declare a lazily-built suite.  ``names`` must be known up front so
    `get_workload` can resolve them without running the loader."""
    _SUITE_LOADERS[suite] = loader
    _SUITE_NAMES[suite] = tuple(names)


def load_suite(suite: str) -> dict[str, Workload]:
    """Run a lazy suite's loader (once) and return its workloads."""
    if suite not in _LOADED_SUITES:
        loader = _SUITE_LOADERS.get(suite)
        if loader is not None:
            for w in loader():
                register_workload(w, replace=True)
        _LOADED_SUITES.add(suite)
    return {n: w for n, w in WORKLOADS.items() if w.suite == suite}


def get_workload(name: str) -> Workload:
    w = WORKLOADS.get(name)
    if w is None:
        for suite, names in _SUITE_NAMES.items():
            if name in names:
                load_suite(suite)
                break
        w = WORKLOADS.get(name)
        if w is None:
            raise KeyError(name)
    return w


def workload_names(suite: str | None = None) -> tuple[str, ...]:
    """Workload names for a suite selector.

    ``None``/``"synth"`` -> the stable synthetic default; ``"all"`` -> every
    suite (loading lazy ones); otherwise that suite's names (loaded on
    demand).
    """
    if suite in (None, "synth"):
        return tuple(n for n, w in WORKLOADS.items() if w.suite in SYNTH_SUITES)
    if suite == "all":
        for s in list(_SUITE_LOADERS):
            load_suite(s)
        return tuple(WORKLOADS)
    if suite in _SUITE_LOADERS:
        load_suite(suite)
    names = tuple(n for n, w in WORKLOADS.items() if w.suite == suite)
    if not names:
        raise ValueError(f"unknown workload suite {suite!r}")
    return names


for _w in _build_suite().values():
    register_workload(_w)
REGISTER_SENSITIVE = [w for w in WORKLOADS.values() if w.register_sensitive]
REGISTER_INSENSITIVE = [w for w in WORKLOADS.values() if not w.register_sensitive]
