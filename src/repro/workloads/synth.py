"""Deterministic synthetic GPU-kernel generator.

Builds PTX-like programs (our asm DSL) with controllable register pressure,
loop nesting, memory intensity and branch structure — standing in for the
paper's CUDA-SDK / Rodinia / Parboil kernels.  Generation is fully seeded so
every run of the suite is identical.

Register usage is *phase-clustered*, as in real compiled kernels: each
structural region (prelude, each loop level, epilogue) works on its own small
register subset plus a few shared loop-carried values, so a ~30-instruction
window touches 8-16 distinct registers even when the whole kernel uses 40+.
This is exactly the locality Table 4 of the paper measures (real interval
length ~= 89% of optimal).
"""
from __future__ import annotations

import random
from dataclasses import dataclass

from repro.core.ir import Program, parse_asm


@dataclass
class LoopInfo:
    label: str
    trips: int


@dataclass
class SynthSpec:
    name: str
    seed: int
    n_regs: int              # register pressure (distinct general registers)
    loop_depth: int = 1      # nesting depth
    body_len: int = 12       # instructions per loop body
    mem_ratio: float = 0.25  # fraction of body instructions that are loads
    diamonds: int = 0        # if/else diamonds inside the innermost body
    trips: tuple[int, ...] = (8,)  # per-depth trip counts (outer..inner)
    epilogue_len: int = 4
    phase_size: int = 8      # registers per structural region
    shared_regs: int = 3     # loop-carried registers shared across phases
    regs_per_thread: int = 0  # compiled register demand (0 -> n_regs)
    l1_hit: float = 0.85     # data-cache hit rate (insensitive suites: divergent, low)

    def __post_init__(self) -> None:
        if self.regs_per_thread == 0:
            self.regs_per_thread = self.n_regs
        if len(self.trips) < self.loop_depth:
            self.trips = tuple(list(self.trips) + [self.trips[-1]] * (self.loop_depth - len(self.trips)))


class _Builder:
    def __init__(self, spec: SynthSpec) -> None:
        self.spec = spec
        self.rng = random.Random(spec.seed)
        self.lines: list[str] = []
        self.loops: list[LoopInfo] = []
        self.next_pred = 0
        self.counters = list(range(spec.loop_depth))
        self.bounds = list(range(spec.loop_depth, 2 * spec.loop_depth))
        data0 = 2 * spec.loop_depth
        self.data_regs = data_regs = list(range(data0, max(spec.n_regs, data0 + 4)))
        self.shared = data_regs[: spec.shared_regs]
        pool = data_regs[spec.shared_regs:]
        k = max(spec.phase_size, 4)
        self.phases = [pool[i:i + k] for i in range(0, len(pool), k)] or [pool or data_regs]
        self.cur = 0  # current phase index
        self.recent: list[int] = []

    # -- register selection --------------------------------------------------
    def _phase(self) -> list[int]:
        return self.phases[self.cur % len(self.phases)] + self.shared

    def enter_phase(self, idx: int) -> None:
        self.cur = idx
        # on entering a region, only shared loop-carried values stay "recent"
        self.recent = [r for r in self.recent if r in self._phase()]

    def dst(self) -> int:
        r = self.rng.choice(self._phase())
        self.recent.append(r)
        if len(self.recent) > 10:
            self.recent.pop(0)
        return r

    def src(self) -> int:
        if self.recent and self.rng.random() < 0.45:
            return self.rng.choice(self.recent)
        return self.rng.choice(self._phase())

    def emit(self, line: str) -> None:
        self.lines.append(line)

    # -- code regions ---------------------------------------------------------
    def body(self, n: int, mem_ratio: float) -> None:
        for _ in range(n):
            if self.rng.random() < mem_ratio:
                # loads are compiler-hoisted: the destination is NOT put in the
                # recent-use window, so consumers appear several instructions
                # later (memory-level parallelism, as real compilers schedule)
                d = self.rng.choice(self._phase())
                a = self.src()
                self.emit(f"ld r{d}, [r{a}]")
            else:
                op = self.rng.choice(["add", "mul", "mad", "sub"])
                d, a, b = self.dst(), self.src(), self.src()
                if op == "mad":
                    self.emit(f"mad r{d}, r{a}, r{b}, r{self.src()}")
                else:
                    self.emit(f"{op} r{d}, r{a}, r{b}")

    def diamond(self, k: int) -> None:
        p = self.next_pred
        self.next_pred += 1
        a, b = self.src(), self.src()
        else_l, join_l = f"E{k}_{p}", f"J{k}_{p}"
        self.emit(f"set p{p}, r{a}, r{b}")
        self.emit(f"@!p{p} bra {else_l}")
        self.body(max(2, self.spec.body_len // 4), self.spec.mem_ratio)
        self.emit(f"bra {join_l}")
        self.emit(f"{else_l}: nop")
        self.body(max(2, self.spec.body_len // 4), self.spec.mem_ratio)
        self.emit(f"{join_l}: nop")

    def loop(self, depth: int) -> None:
        spec = self.spec
        idx = spec.loop_depth - depth  # 0 == outermost
        ctr, bound = self.counters[idx], self.bounds[idx]
        label = f"L{idx}"
        self.loops.append(LoopInfo(label=label, trips=spec.trips[idx]))
        self.emit(f"mov r{ctr}, 0")
        self.emit(f"{label}: nop")
        self.enter_phase(idx + 1)  # each loop level has its own register subset
        self.body(spec.body_len, spec.mem_ratio)
        if depth == 1:
            for k in range(spec.diamonds):
                self.diamond(k)
        else:
            self.loop(depth - 1)
            self.enter_phase(idx + 1)
        p = self.next_pred
        self.next_pred += 1
        self.emit(f"add r{ctr}, r{ctr}, 1")
        self.emit(f"set p{p}, r{ctr}, r{bound}")
        self.emit(f"@p{p} bra {label}")

    def build(self) -> tuple[Program, dict[str, int]]:
        spec = self.spec
        for b in self.bounds:
            self.emit(f"mov r{b}, 100")
        # Initialize every data register (kernel parameters / constants):
        # real compilers never emit reads of uninitialized registers.
        for r in self.data_regs:
            self.emit(f"mov r{r}, {r * 3 + 1}")
        self.enter_phase(0)
        self.body(max(2, spec.body_len // 3), 0.1)  # setup
        if spec.loop_depth > 0:
            self.loop(spec.loop_depth)
        self.enter_phase(len(self.phases) - 1)
        self.body(spec.epilogue_len, 0.0)
        self.emit("exit")
        prog = parse_asm("\n".join(self.lines), name=spec.name)
        trips = {li.label: li.trips for li in self.loops}
        return prog, trips


def synthesize(spec: SynthSpec) -> tuple[Program, dict[str, int]]:
    return _Builder(spec).build()
