import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production meshes and extract memory / cost / collective statistics.

MUST be run as a module entry point (``python -m repro.launch.dryrun``) so the
XLA_FLAGS line above executes before jax initializes its backends.

Per cell this:
  1. builds the 16x16 (single-pod) or 2x16x16 (multi-pod) mesh;
  2. builds the train/prefill or decode step via the SAME builders the real
     trainer/server use;
  3. ``jit(...).lower(shapes)`` with ShapeDtypeStruct stand-ins (no
     allocation), then ``.compile()`` — a sharding mismatch, OOM-at-compile
     or unsupported collective fails here;
  4. records ``compiled.memory_analysis()`` (proves the cell fits HBM),
     ``compiled.cost_analysis()`` (FLOPs/bytes for the roofline), and
     collective bytes parsed from the post-SPMD HLO;
  5. writes JSON to experiments/dryrun/<arch>_<shape>_<mesh>.json.

Usage:
  python -m repro.launch.dryrun --arch tinyllama-1.1b --shape train_4k
  python -m repro.launch.dryrun --arch tinyllama-1.1b --shape train_4k --multi-pod
  python -m repro.launch.dryrun --all [--multi-pod]
"""
import argparse
import json
import pathlib
import re
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, SHAPES, cell_is_runnable, get_arch, input_specs
from repro.distributed.sharding import default_rules, shardings_for
from repro.launch.mesh import make_production_mesh
from repro.models.lm import init_decode_cache, init_params
from repro.optim.adamw import init_opt_state, opt_state_axes
from repro.runtime.train_step import (
    batch_axes_for, batch_shardings, build_decode_step, build_prefill_step,
    build_train_step,
)

OUT_DIR = pathlib.Path(__file__).resolve().parents[3] / "experiments" / "dryrun"

# v5e hardware constants (per chip) for the roofline terms
PEAK_FLOPS = 197e12      # bf16 FLOP/s
HBM_BW = 819e9           # bytes/s
ICI_BW = 50e9            # bytes/s/link
HBM_BYTES = 16 * 2 ** 30

from repro.launch.hlo_stats import (  # noqa: F401 (re-exported)
    _BYTES, _COLL_OPS, _SHAPE_RE, _cost_analysis, _eval_shape_with_axes,
    _mem_analysis, _shape_bytes, collective_stats,
)


def run_cell(arch_id: str, shape_name: str, multi_pod: bool,
             verbose: bool = True) -> dict:
    cfg = get_arch(arch_id)
    shape = SHAPES[shape_name]
    ok, why = cell_is_runnable(cfg, shape)
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    rec: dict = {"arch": arch_id, "shape": shape_name, "mesh": mesh_name,
                 "runnable": ok, "skip_reason": why, "ok": False}
    if not ok:
        rec["ok"] = True  # a defined skip counts as pass
        return rec

    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = int(mesh.devices.size)
    rules = default_rules(mesh)
    key = jax.random.PRNGKey(0)

    specs = input_specs(cfg, shape)
    kind = "decode" if shape.is_decode else "train"
    b_sh = shardings_for(rules, batch_axes_for(cfg, kind), specs)

    p_shapes, p_axes = _eval_shape_with_axes(lambda k: init_params(cfg, k), key)
    p_sh = shardings_for(rules, p_axes, p_shapes)

    if shape.is_decode:
        c_shapes, c_axes = _eval_shape_with_axes(
            lambda: init_decode_cache(cfg, shape.global_batch, shape.seq_len))
        c_sh = shardings_for(rules, c_axes, c_shapes)
        step = build_decode_step(cfg, rules)
        jitted = jax.jit(
            step,
            in_shardings=(p_sh, c_sh, b_sh["tokens"], b_sh["cache_len"]),
            donate_argnums=(1,),
        )
        lowered = jitted.lower(p_shapes, c_shapes, specs["tokens"],
                               specs["cache_len"])
    elif shape.kind == "prefill":
        dp = n_dev // int(mesh.shape["model"])
        n_micro = max(1, shape.global_batch // dp)
        rec["n_micro"] = n_micro
        step = build_prefill_step(cfg, rules, n_micro=n_micro)
        jitted = jax.jit(step, in_shardings=(p_sh, b_sh))
        lowered = jitted.lower(p_shapes, specs)
    else:
        o_shapes = jax.eval_shape(init_opt_state, p_shapes)
        state_shapes = {"params": p_shapes, "opt": o_shapes}
        st_sh = {"params": p_sh,
                 "opt": shardings_for(rules, opt_state_axes(p_axes), o_shapes)}
        # gradient accumulation: one sequence per device per microbatch
        dp = n_dev // int(mesh.shape["model"])
        n_micro = max(1, shape.global_batch // dp)
        rec["n_micro"] = n_micro
        step = build_train_step(cfg, rules, n_micro=n_micro)
        jitted = jax.jit(step, in_shardings=(st_sh, b_sh), donate_argnums=(0,))
        lowered = jitted.lower(state_shapes, specs)

    t_lower = time.time() - t0
    compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower

    mem = _mem_analysis(compiled)
    cost = _cost_analysis(compiled)
    try:
        hlo = compiled.as_text()
    except Exception:
        hlo = lowered.as_text()
    coll = collective_stats(hlo)

    flops_total = cost.get("flops", 0.0)
    # XLA's CPU cost analysis reports per-program flops for the SPMD module
    # (one device's share); scale to fleet totals for bookkeeping.
    rec.update({
        "ok": True,
        "devices": n_dev,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory": mem,
        "cost": cost,
        "collectives": coll,
        "param_count": cfg.param_count(),
        "active_param_count": cfg.active_param_count(),
        "hlo_bytes": len(hlo),
    })
    mem_dev = mem.get("total_hbm_bytes")
    if verbose:
        print(f"[{arch_id} x {shape_name} x {mesh_name}] "
              f"lower={t_lower:.1f}s compile={t_compile:.1f}s "
              f"flops={flops_total:.3g} "
              f"mem/dev={mem_dev if mem_dev is None else mem_dev/2**30:.3f}GiB "
              f"coll={coll['total_bytes']/2**20:.1f}MiB/{coll['total_count']}ops",
              flush=True)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out-dir", default=str(OUT_DIR))
    args = ap.parse_args()

    out_dir = pathlib.Path(args.out_dir)
    out_dir.mkdir(parents=True, exist_ok=True)

    cells: list[tuple[str, str]] = []
    if args.all:
        for a in ARCH_IDS:
            for s in SHAPES:
                cells.append((a, s))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all required"
        cells = [(args.arch, args.shape)]

    failures = 0
    for arch_id, shape_name in cells:
        mesh_name = "pod2x16x16" if args.multi_pod else "pod16x16"
        path = out_dir / f"{arch_id}_{shape_name}_{mesh_name}.json"
        try:
            rec = run_cell(arch_id, shape_name, args.multi_pod)
        except Exception as e:  # noqa: BLE001 - record the failure
            rec = {"arch": arch_id, "shape": shape_name, "mesh": mesh_name,
                   "ok": False, "error": f"{type(e).__name__}: {e}",
                   "traceback": traceback.format_exc()[-4000:]}
            print(f"[{arch_id} x {shape_name} x {mesh_name}] FAILED: {e}",
                  flush=True)
            failures += 1
        path.write_text(json.dumps(rec, indent=2))
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
