"""Serving driver: continuous batching with paged KV on the host mesh.

``python -m repro.launch.serve --arch tinyllama-1.1b --requests 16``

Wraps the ServingEngine (two-level request scheduler + the paper's Address
Allocation Unit for KV pages) with a synthetic request generator and reports
throughput/fairness stats.  On a fleet the same engine runs with the
production mesh shardings (see dryrun.py's decode cells for the compiled
evidence).
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro.configs import get_arch, get_smoke
from repro.serving import ServeConfig, ServingEngine


def serve(arch_id: str, smoke: bool = True, n_requests: int = 16,
          max_new: int = 12, seed: int = 0, active_slots: int = 4,
          total_pages: int = 32, max_len: int = 128) -> dict:
    cfg = get_smoke(arch_id) if smoke else get_arch(arch_id)
    rng = np.random.default_rng(seed)
    engine = ServingEngine(cfg, sc=ServeConfig(
        max_len=max_len, active_slots=active_slots, total_pages=total_pages))
    reqs = []
    for _ in range(n_requests):
        prompt = rng.integers(0, cfg.vocab, rng.integers(1, 8)).tolist()
        reqs.append(engine.submit(prompt, max_new_tokens=int(
            rng.integers(2, max_new + 1))))
    t0 = time.time()
    out = engine.run()
    dt = time.time() - t0
    tokens = sum(len(v) for v in out.values())
    engine.aau.check_invariants()
    return {
        "requests": n_requests,
        "completed": len(engine.sched.finished),
        "tokens": tokens,
        "tok_per_s": tokens / max(dt, 1e-9),
        "preemptions": engine.sched.preemptions,
        "pages_leaked": engine.aau.used_count,
        "wall_s": dt,
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    stats = serve(args.arch, smoke=not args.full, n_requests=args.requests)
    print(", ".join(f"{k}={v if not isinstance(v, float) else round(v, 2)}"
                    for k, v in stats.items()))


if __name__ == "__main__":
    main()
