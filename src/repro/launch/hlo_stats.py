"""Side-effect-free helpers shared by dryrun/roofline/hillclimb/tests.

(dryrun.py sets XLA_FLAGS at import, so anything that does NOT want 512 fake
devices must import from here instead.)
"""
import re

import jax

_SHAPE_RE = re.compile(
    r"(f64|f32|f16|bf16|f8e4m3fn|f8e5m2|s64|s32|s16|s8|u64|u32|u16|u8|pred)\[([\d,]*)\]")
_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
          "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4,
          "u16": 2, "u8": 1, "pred": 1}
_COLL_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
             "collective-permute")


def _shape_bytes(sig: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(sig):
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _BYTES[dt]
    return total


def collective_stats(hlo_text: str) -> dict:
    """Sum output-shape bytes of every collective op in post-SPMD HLO."""
    stats = {op: {"count": 0, "bytes": 0} for op in _COLL_OPS}
    pat = re.compile(r"=\s+((?:\([^)]*\))|(?:\S+))\s+(all-gather|all-reduce|"
                     r"reduce-scatter|all-to-all|collective-permute)")
    for line in hlo_text.splitlines():
        m = pat.search(line)
        if not m:
            continue
        sig, op = m.group(1), m.group(2)
        stats[op]["count"] += 1
        stats[op]["bytes"] += _shape_bytes(sig)
    stats["total_bytes"] = sum(v["bytes"] for v in stats.values()
                               if isinstance(v, dict))
    stats["total_count"] = sum(v["count"] for v in stats.values()
                               if isinstance(v, dict))
    return stats


def _eval_shape_with_axes(fn, *args):
    """eval_shape a (tree, axes) returning fn; captures axes eagerly."""
    box = {}

    def wrapped(*a):
        tree, axes = fn(*a)
        box["axes"] = axes
        return tree

    shapes = jax.eval_shape(wrapped, *args)
    return shapes, box["axes"]


def _mem_analysis(compiled) -> dict:
    try:
        m = compiled.memory_analysis()
    except Exception:
        return {}
    out = {}
    for k in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "alias_size_in_bytes",
              "generated_code_size_in_bytes"):
        v = getattr(m, k, None)
        if v is not None:
            out[k] = int(v)
    out["total_hbm_bytes"] = (out.get("argument_size_in_bytes", 0)
                              + out.get("output_size_in_bytes", 0)
                              + out.get("temp_size_in_bytes", 0)
                              - out.get("alias_size_in_bytes", 0))
    return out


def _cost_analysis(compiled) -> dict:
    try:
        c = compiled.cost_analysis()
    except Exception:
        return {}
    if isinstance(c, (list, tuple)):
        c = c[0] if c else {}
    return {k: float(v) for k, v in c.items()
            if isinstance(v, (int, float)) and (
                k in ("flops", "bytes accessed", "optimal_seconds")
                or k.startswith("bytes accessed"))}
