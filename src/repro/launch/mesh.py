"""Production mesh construction.

A function, not a module-level constant, so importing this module never
touches jax device state.  Single pod: (data=16, model=16) = 256 chips
(one v5e pod).  Multi-pod: (pod=2, data=16, model=16) = 512 chips; the
leading 'pod' axis carries only data parallelism (gradient all-reduce over
DCN), matching how real multi-pod training lays out traffic.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int = 1):
    """Mesh over whatever devices exist locally (tests / examples)."""
    n = len(jax.devices())
    data = n // model
    return jax.make_mesh((data, model), ("data", "model"))
