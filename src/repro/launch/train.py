"""Training driver: end-to-end fault-tolerant trainer on the local mesh.

``python -m repro.launch.train --arch tinyllama-1.1b --smoke --steps 50``

Production posture on a real fleet: the same builders compile against
``make_production_mesh()`` (see dryrun.py); here we train the reduced config
on the host devices so the full loop (data -> sharded step -> checkpoint ->
restore -> elastic reshard) is exercised for real.
"""
from __future__ import annotations

import argparse
import logging
import time

import jax
import numpy as np

from repro.checkpoint import Checkpointer
from repro.configs import get_arch, get_smoke
from repro.configs.base import ShapeConfig
from repro.data import DataConfig, PrefetchingLoader
from repro.distributed.fault import FaultConfig, FaultTolerantTrainer
from repro.distributed.sharding import default_rules, shardings_for
from repro.launch.mesh import make_host_mesh
from repro.optim.adamw import AdamWConfig
from repro.optim.compression import CompressionConfig
from repro.runtime.train_step import (
    batch_axes_for, build_train_step, make_train_state,
)

log = logging.getLogger("repro.train")


def train(arch_id: str, smoke: bool = True, steps: int = 50,
          batch: int = 8, seq: int = 64, ckpt_dir: str | None = None,
          ckpt_every: int = 20, compress: bool = False,
          inject_failures: dict[int, int] | None = None,
          n_micro: int = 1, seed: int = 0):
    cfg = get_smoke(arch_id) if smoke else get_arch(arch_id)
    shape = ShapeConfig("driver", seq, batch, "train")
    mesh = make_host_mesh()
    rules = default_rules(mesh)

    state, state_axes = make_train_state(cfg, jax.random.PRNGKey(seed))
    shapes = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state)
    st_sh = shardings_for(rules, state_axes, shapes)
    state = jax.tree.map(lambda x, s: jax.device_put(x, s), state, st_sh)

    opt_cfg = AdamWConfig(lr=1e-3, warmup_steps=10, total_steps=max(steps, 1))
    comp = CompressionConfig(enabled=True) if compress else None
    step_fn = jax.jit(
        build_train_step(cfg, rules, opt_cfg, comp, n_micro=n_micro),
        donate_argnums=(0,))

    loader = PrefetchingLoader(cfg, shape, DataConfig(seed=seed + 1))
    ckpt = Checkpointer(ckpt_dir or f"/tmp/repro_ckpt_{arch_id}", keep=2)
    trainer = FaultTolerantTrainer(
        step_fn=step_fn, checkpointer=ckpt, loader=loader,
        cfg=FaultConfig(ckpt_every=ckpt_every,
                        inject_failures=inject_failures or {}))
    t0 = time.time()
    state, final_step, metrics = trainer.run(state, steps)
    dt = time.time() - t0
    losses = [float(m["loss"]) for m in metrics]
    loader.close()
    return {
        "final_step": final_step,
        "losses": losses,
        "restarts": trainer.restarts,
        "straggler_fallbacks": loader.straggler_fallbacks,
        "wall_s": dt,
        "state": state,
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--full", action="store_true", help="full (non-smoke) config")
    ap.add_argument("--compress", action="store_true")
    ap.add_argument("--n-micro", type=int, default=1)
    args = ap.parse_args()
    logging.basicConfig(level=logging.INFO)
    out = train(args.arch, smoke=not args.full, steps=args.steps,
                batch=args.batch, seq=args.seq, compress=args.compress,
                n_micro=args.n_micro)
    print(f"steps={out['final_step']} loss[0]={out['losses'][0]:.4f} "
          f"loss[-1]={out['losses'][-1]:.4f} wall={out['wall_s']:.1f}s "
          f"restarts={out['restarts']}")


if __name__ == "__main__":
    main()
