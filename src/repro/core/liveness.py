"""Liveness + register-live-range (web) analysis.

Implements the dataflow substrate the paper's §3 (LTRF+ dead-operand bits) and
§4 (register-live-ranges, the ICG nodes) require:

* classic backward liveness (block level and per-instruction points);
* reaching definitions (block level), used to build *webs*: maximal
  def-use chains of one architectural register — the paper's
  "register-live-range: a chain of common uses of a specific register";
* linearized ``[first, last]`` live intervals with loop extension — the
  substrate linear-scan register allocation needs (exposed to the frontend
  through the pipeline's ``live-intervals`` pass).
"""
from __future__ import annotations

from dataclasses import dataclass, field

from .ir import Instr, Program, back_edges


def block_liveness(prog: Program) -> tuple[dict[str, set[int]], dict[str, set[int]]]:
    """Backward may-liveness over general registers. Returns (live_in, live_out)."""
    uses: dict[str, set[int]] = {}
    defs: dict[str, set[int]] = {}
    for bb in prog:
        u, d = bb.uses_defs()
        uses[bb.label], defs[bb.label] = u, d
    live_in = {l: set() for l in prog.order}
    live_out = {l: set() for l in prog.order}
    changed = True
    while changed:
        changed = False
        for label in reversed(prog.order):
            bb = prog.blocks[label]
            out = set()
            for s in bb.succs:
                out |= live_in[s]
            inn = uses[label] | (out - defs[label])
            if out != live_out[label] or inn != live_in[label]:
                live_out[label], live_in[label] = out, inn
                changed = True
    return live_in, live_out


def instr_live_out(prog: Program) -> dict[tuple[str, int], set[int]]:
    """Per-instruction live-out sets (keyed by (block label, instr index))."""
    _, block_out = block_liveness(prog)
    points: dict[tuple[str, int], set[int]] = {}
    for bb in prog:
        live = set(block_out[bb.label])
        for i in range(len(bb.instrs) - 1, -1, -1):
            ins = bb.instrs[i]
            points[(bb.label, i)] = set(live)
            live -= set(ins.dsts)
            live |= set(ins.srcs)
    return points


def annotate_dead_operands(prog: Program) -> Program:
    """LTRF+ dead-operand bits: mark source operands whose register is dead
    immediately after the instruction (conservative static liveness)."""
    louts = instr_live_out(prog)
    for bb in prog:
        for i, ins in enumerate(bb.instrs):
            lo = louts[(bb.label, i)]
            dead = tuple(k for k, s in enumerate(ins.srcs) if s not in lo and s not in ins.dsts)
            bb.instrs[i] = Instr(
                op=ins.op, dsts=ins.dsts, srcs=ins.srcs, pdst=ins.pdst,
                psrcs=ins.psrcs, target=ins.target, dead_srcs=dead,
            )
    return prog


def linear_live_intervals(prog: Program) -> tuple[dict[int, int], dict[int, int]]:
    """[first, last] linear positions per register, extended over loops.

    A register whose first access inside a loop span is a *read* carries a
    value across the back edge, so its interval must cover the whole span.
    This is the liveness substrate for linear-scan allocation
    (`repro.frontend.regalloc`), reached via the pipeline's
    ``live-intervals`` pass.
    """
    first: dict[int, int] = {}
    last: dict[int, int] = {}
    block_span: dict[str, tuple[int, int]] = {}
    pos = 0
    flat: list[Instr] = []
    for label in prog.order:
        start = pos
        for ins in prog.blocks[label].instrs:
            for r in ins.regs:
                first.setdefault(r, pos)
                last[r] = pos
            flat.append(ins)
            pos += 1
        block_span[label] = (start, pos - 1)

    spans = []
    for (u, v) in back_edges(prog):
        s, e = block_span[v][0], block_span[u][1]
        if s <= e:
            spans.append((s, e))
    changed = True
    while changed:
        changed = False
        for (s, e) in spans:
            defined: set[int] = set()
            carried: set[int] = set()
            for ins in flat[s:e + 1]:
                for r in ins.srcs:
                    if r not in defined:
                        carried.add(r)
                defined.update(ins.dsts)
            for r in carried:
                nf, nl = min(first[r], s), max(last[r], e)
                if (nf, nl) != (first[r], last[r]):
                    first[r], last[r] = nf, nl
                    changed = True
    return first, last


# ---------------------------------------------------------------------------
# Reaching definitions + webs (register-live-ranges)
# ---------------------------------------------------------------------------

DefSite = tuple[str, int, int]  # (block, instr index, dst position)


def _def_sites(prog: Program) -> dict[int, list[DefSite]]:
    sites: dict[int, list[DefSite]] = {}
    for label, i, ins in prog.instructions():
        for k, r in enumerate(ins.dsts):
            sites.setdefault(r, []).append((label, i, k))
    return sites


def reaching_defs(prog: Program) -> dict[str, dict[int, set[DefSite]]]:
    """Block-entry reaching definitions, per register."""
    gen: dict[str, dict[int, DefSite]] = {}
    kill: dict[str, set[int]] = {}
    for bb in prog:
        g: dict[int, DefSite] = {}
        for i, ins in enumerate(bb.instrs):
            for k, r in enumerate(ins.dsts):
                g[r] = (bb.label, i, k)  # last def in block wins
        gen[bb.label] = g
        kill[bb.label] = set(g)
    rin: dict[str, dict[int, set[DefSite]]] = {l: {} for l in prog.order}
    changed = True
    while changed:
        changed = False
        for label in prog.order:
            bb = prog.blocks[label]
            # out[pred] = gen[pred] ∪ (in[pred] - kill[pred])
            new_in: dict[int, set[DefSite]] = {}
            for p in bb.preds:
                pin = rin[p]
                for r, ds in pin.items():
                    if r not in kill[p]:
                        new_in.setdefault(r, set()).update(ds)
                for r, d in gen[p].items():
                    new_in.setdefault(r, set()).add(d)
            if new_in != rin[label]:
                rin[label] = new_in
                changed = True
    return rin


@dataclass
class LiveRange:
    """A web: one allocatable entity. ``reg`` is the original register."""

    lr_id: int
    reg: int
    defs: frozenset[DefSite]
    use_sites: frozenset[tuple[str, int, int]] = frozenset()  # (block, instr, src pos)
    intervals: set[int] = field(default_factory=set)  # filled by icg.py


class _UF:
    def __init__(self) -> None:
        self.p: dict[DefSite, DefSite] = {}

    def find(self, x: DefSite) -> DefSite:
        self.p.setdefault(x, x)
        while self.p[x] != x:
            self.p[x] = self.p[self.p[x]]
            x = self.p[x]
        return x

    def union(self, a: DefSite, b: DefSite) -> None:
        ra, rb = self.find(a), self.find(b)
        if ra != rb:
            self.p[ra] = rb


def build_live_ranges(prog: Program) -> tuple[list[LiveRange], dict[tuple[str, int, str, int], int]]:
    """Build webs and an occurrence map.

    Returns (live_ranges, occ) where ``occ[(block, instr_idx, 'd'|'s', pos)]``
    is the lr_id of that operand occurrence.  Uses without a reaching def
    (kernel inputs) get a synthetic entry def at the program entry.
    """
    rdefs = reaching_defs(prog)
    uf = _UF()
    use_defs: dict[tuple[str, int, int], set[DefSite]] = {}

    for bb in prog:
        cur: dict[int, set[DefSite]] = {r: set(ds) for r, ds in rdefs[bb.label].items()}
        for i, ins in enumerate(bb.instrs):
            for k, r in enumerate(ins.srcs):
                ds = cur.get(r)
                if not ds:
                    synth: DefSite = ("__entry__", -1, r)  # undefined-before-use input
                    ds = {synth}
                    cur[r] = set(ds)
                use_defs[(bb.label, i, k)] = set(ds)
                first = next(iter(ds))
                for d in ds:
                    uf.union(first, d)
            for k, r in enumerate(ins.dsts):
                cur[r] = {(bb.label, i, k)}

    # Group def sites per (register, web root).
    def reg_of(d: DefSite) -> int:
        if d[0] == "__entry__":
            return d[2]
        return prog.blocks[d[0]].instrs[d[1]].dsts[d[2]]

    groups: dict[tuple[int, DefSite], set[DefSite]] = {}
    for label, i, ins in prog.instructions():
        for k, _ in enumerate(ins.dsts):
            d = (label, i, k)
            groups.setdefault((reg_of(d), uf.find(d)), set()).add(d)
    for ds in use_defs.values():
        for d in ds:
            groups.setdefault((reg_of(d), uf.find(d)), set()).add(d)

    ranges: list[LiveRange] = []
    root_to_lr: dict[tuple[int, DefSite], int] = {}
    for (reg, root), ds in sorted(groups.items(), key=lambda kv: (kv[0][0], str(kv[0][1]))):
        lr = LiveRange(lr_id=len(ranges), reg=reg, defs=frozenset(ds))
        root_to_lr[(reg, root)] = lr.lr_id
        ranges.append(lr)

    occ: dict[tuple[str, int, str, int], int] = {}
    uses_by_lr: dict[int, set[tuple[str, int, int]]] = {}
    for label, i, ins in prog.instructions():
        for k, r in enumerate(ins.dsts):
            occ[(label, i, "d", k)] = root_to_lr[(r, uf.find((label, i, k)))]
        for k, r in enumerate(ins.srcs):
            ds = use_defs[(label, i, k)]
            lr_id = root_to_lr[(r, uf.find(next(iter(ds))))]
            occ[(label, i, "s", k)] = lr_id
            uses_by_lr.setdefault(lr_id, set()).add((label, i, k))
    for lr in ranges:
        lr.use_sites = frozenset(uses_by_lr.get(lr.lr_id, set()))
    return ranges, occ
