"""The staged compiler pass pipeline.

Every compile in the repo — the per-design simulator compile, the frontend
register allocator's liveness query, the figure harness' one-off analyses —
used to chain the passes in `core/` by ad-hoc positional calls, with the
interval-formation algorithm hardwired.  This module makes the pipeline
explicit and extensible:

* :class:`CompileContext` — the single mutable compile state: the program
  (passes may replace it with a split/renumbered copy), the compile knobs,
  named ``artifacts`` each pass reads/writes, and per-pass ``stats``
  (counters + wall time) that travel on the emitted plan;
* :class:`Pass` / :class:`PassManager` — a registered, ordered pass list
  (interval formation -> liveness -> ICG -> coloring/renumber -> prefetch
  planning -> plan emission; liveness follows formation because its
  consumers need liveness over the *split* program) where each pass
  declares when it applies, so one pipeline serves all designs
  (``BL``/``RFC``/``Ideal`` skip straight to emission, only ``LTRF_conf``
  colors, only ``LTRF_plus`` needs block liveness, ...);
* **pluggable interval formation** — `SimConfig.interval_strategy` selects
  a registered strategy instead of the one hardwired algorithm:

  ==============  =========================================================
  strategy        meaning
  ==============  =========================================================
  ``paper``       Algorithms 1+2 of the paper (the default; bit-identical
                  to the frozen golden engine, pinned in test_sim_golden
                  and the differential fuzzer)
  ``capacity``    the paper's algorithm with the cap clamped to the
                  design's RFC **entries-per-warp**, so no interval's
                  working set — hence no prefetch round — can overflow the
                  register cache even when ``interval_cap`` is set larger
  ``fixed:N``     fixed-length intervals (every run of at most N
                  instructions is its own interval, no merging): the naive
                  baseline the ablation figures compare against
  ==============  =========================================================

All heavy lifting stays memoized in `core.plan_cache`; a pass is a thin,
timed orchestration layer over those caches, so the pipeline refactor
cannot change compile *results* — only make the stages visible.

Adding a pass: build a :class:`Pass` (name, run(ctx), applies(ctx)) and
insert it into a `PassManager([...])` of your own, or extend `sim_passes()`.
Adding a strategy: decorate a ``(ctx, arg) -> IntervalAnalysis`` function
with `@register_interval_strategy("name")`; it becomes selectable as
``interval_strategy="name"`` (or ``"name:arg"``) end to end.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable

from .intervals import IntervalAnalysis
from .ir import Program
from .liveness import block_liveness, linear_live_intervals
from .renumber import bank_of

# Pipeline behaviour revision: part of every compiled-plan cache key (see
# plan_cache.compile_for_sim).  Bump when pass ordering/semantics change in a
# way that alters emitted plans.
PIPELINE_REV = 1

# Base names of the built-in interval-formation strategies (``fixed`` takes a
# mandatory ``:N`` argument: ``interval_strategy="fixed:8"``).
INTERVAL_STRATEGIES = ("paper", "capacity", "fixed")

# Designs with no software-managed register cache: no interval passes at all.
UNCACHED_DESIGNS = frozenset({"BL", "RFC", "Ideal"})

# The strategy registry (filled below; extended via
# `register_interval_strategy`).  Registered names are accepted end to end:
# `parse_interval_strategy` consults this table, so a custom strategy is
# selectable straight from ``SimConfig.interval_strategy``.
_STRATEGIES: dict[str, Callable] = {}


def parse_interval_strategy(spec: str) -> tuple[str, int]:
    """``"paper" | "capacity" | "fixed:N" | "<registered>[:N]"`` ->
    ``(kind, arg)``; raises on junk."""
    kind, sep, arg = spec.partition(":")
    n = int(arg) if arg.isdigit() else 0
    if kind == "fixed":
        if n > 0:
            return kind, n
    elif kind in ("paper", "capacity"):
        if not sep:
            return kind, 0
    elif kind in _STRATEGIES:
        if not sep or n > 0:  # bare name, or a positive :N argument
            return kind, n
    raise ValueError(
        f"unknown interval_strategy {spec!r}; one of 'paper', 'capacity', "
        f"'fixed:N' (N >= 1), or a registered strategy name")


def capacity_cap(interval_cap: int, rfc_per_warp: int) -> int:
    """The ``capacity`` strategy's effective working-set cap.

    ``rfc_per_warp`` is the design's register-cache entries-per-warp
    (``SimConfig.rfc_entries // active_slots``); 0 means unbounded (compile
    without a simulator config, e.g. in unit tests)."""
    if rfc_per_warp <= 0:
        return interval_cap
    return max(1, min(interval_cap, rfc_per_warp))


def effective_strategy(design: str, interval_strategy: str,
                       interval_cap: int, rfc_per_warp: int) -> tuple:
    """Normalize a strategy request into the canonical cache-key form.

    The knob is a no-op for the uncached designs and for ``SHRF`` (which
    always uses strand-bounded intervals), and ``capacity`` degenerates to
    ``paper`` whenever the RFC bound does not actually clamp the cap — all
    of those normalize to ``("paper", 0)`` so equivalent compiles share one
    cached plan."""
    kind, arg = parse_interval_strategy(interval_strategy)
    if design in UNCACHED_DESIGNS or design == "SHRF":
        return ("paper", 0)
    if kind == "capacity":
        cap = capacity_cap(interval_cap, rfc_per_warp)
        return ("paper", 0) if cap >= interval_cap else ("capacity", cap)
    return (kind, arg)  # paper, fixed, and registered extension strategies


# ---------------------------------------------------------------------------
# Context + pass machinery
# ---------------------------------------------------------------------------

@dataclass
class CompileContext:
    """Mutable state threaded through one pipeline run."""

    prog: Program                  # current program; passes may replace it
    design: str = ""
    interval_cap: int = 16
    num_banks: int = 16
    renumber: str = "icg"
    interval_strategy: str = "paper"
    rfc_per_warp: int = 0          # capacity strategy's RFC bound (0 = off)
    artifacts: dict = field(default_factory=dict)
    stats: dict[str, dict] = field(default_factory=dict)  # pass -> counters


@dataclass(frozen=True)
class Pass:
    """One pipeline stage: ``run(ctx)`` returns a stats dict (or None)."""

    name: str
    run: Callable[[CompileContext], dict | None]
    applies: Callable[[CompileContext], bool] = lambda ctx: True


class PassManager:
    """Runs an ordered pass list over a context, timing each applied pass."""

    def __init__(self, passes) -> None:
        self.passes = list(passes)

    def run(self, ctx: CompileContext) -> CompileContext:
        for p in self.passes:
            if not p.applies(ctx):
                continue
            t0 = time.perf_counter()
            stats = p.run(ctx) or {}
            stats = dict(stats)
            stats["time_ms"] = round((time.perf_counter() - t0) * 1e3, 3)
            ctx.stats[p.name] = stats
        return ctx


# ---------------------------------------------------------------------------
# Interval-formation strategies (pluggable)
# ---------------------------------------------------------------------------

def register_interval_strategy(kind: str):
    """Register a ``(ctx, arg) -> IntervalAnalysis`` interval strategy.

    Registration makes ``interval_strategy="<kind>"`` (or ``"<kind>:N"``)
    valid end to end — `parse_interval_strategy` accepts it, the plan cache
    keys on ``(kind, N)``, and the ``intervals`` pass dispatches here."""
    def deco(fn):
        _STRATEGIES[kind] = fn
        return fn
    return deco


@register_interval_strategy("paper")
def _paper_strategy(ctx: CompileContext, arg: int) -> IntervalAnalysis:
    from .plan_cache import cached_intervals
    return cached_intervals(ctx.prog, ctx.interval_cap)


@register_interval_strategy("capacity")
def _capacity_strategy(ctx: CompileContext, arg: int) -> IntervalAnalysis:
    from .plan_cache import cached_intervals
    return cached_intervals(
        ctx.prog, capacity_cap(ctx.interval_cap, ctx.rfc_per_warp))


@register_interval_strategy("fixed")
def _fixed_strategy(ctx: CompileContext, arg: int) -> IntervalAnalysis:
    from .plan_cache import cached_fixed_intervals
    return cached_fixed_intervals(ctx.prog, arg)


# ---------------------------------------------------------------------------
# The passes
# ---------------------------------------------------------------------------

def _needs_intervals(ctx: CompileContext) -> bool:
    return ctx.design not in UNCACHED_DESIGNS


def _liveness(ctx: CompileContext) -> dict:
    """Block liveness over the *current* program.

    In the simulator pipeline this runs right after interval formation —
    its consumer (LTRF+'s live-trimmed fetch sets, in the ``emit`` pass)
    needs live-in per *split-program* block label, so running it any
    earlier would compute liveness over labels the plan never executes."""
    live_in, live_out = block_liveness(ctx.prog)
    ctx.artifacts["live_in"] = live_in
    ctx.artifacts["live_out"] = live_out
    return {"blocks": len(live_in),
            "max_live_in": max((len(s) for s in live_in.values()), default=0)}


def _linear_intervals(ctx: CompileContext) -> dict:
    first, last = linear_live_intervals(ctx.prog)
    ctx.artifacts["linear_live_intervals"] = (first, last)
    return {"registers": len(first)}


def _form_intervals(ctx: CompileContext) -> dict:
    if ctx.design == "SHRF":
        # SHRF is strand-bounded by definition; the strategy knob is a no-op.
        from .plan_cache import cached_intervals
        an = cached_intervals(ctx.prog, ctx.interval_cap, strand_mode=True)
        used = "strand"
    else:
        kind, arg = parse_interval_strategy(ctx.interval_strategy)
        an = _STRATEGIES[kind](ctx, arg)
        used = ctx.interval_strategy
    n_blocks_in = len(ctx.prog.order)
    ctx.artifacts["analysis"] = an
    ctx.prog = an.prog  # interval formation may have split blocks
    sizes = [len(iv.working_set) for iv in an.intervals]
    return {"strategy": used, "cap": an.n_cap,
            "intervals": len(an.intervals),
            "block_splits": len(an.prog.order) - n_blocks_in,
            "max_working_set": max(sizes, default=0),
            "mean_working_set": round(sum(sizes) / max(len(sizes), 1), 2)}


def _wants_renumber(ctx: CompileContext) -> bool:
    return (_needs_intervals(ctx) and ctx.design == "LTRF_conf"
            and ctx.renumber == "icg")


def _build_icg(ctx: CompileContext) -> dict:
    from .plan_cache import cached_icg
    icg = cached_icg(ctx.artifacts["analysis"])
    ctx.artifacts["icg"] = icg
    return {"live_ranges": len(icg.ranges), "conflict_edges": icg.num_edges}


def _renumber(ctx: CompileContext) -> dict:
    from .plan_cache import cached_renumber_analysis
    rr = cached_renumber_analysis(ctx.artifacts["analysis"], ctx.num_banks,
                                  icg=ctx.artifacts["icg"])
    ctx.artifacts["renumber"] = rr
    ctx.artifacts["analysis"] = rr.analysis
    ctx.prog = rr.analysis.prog
    return {"applied": rr.applied,
            "colors": len(set(rr.coloring.colors.values()))
            if rr.coloring.colors else 0}


def _plan_prefetch(ctx: CompileContext) -> dict:
    from .plan_cache import cached_prefetch_ops
    ops = cached_prefetch_ops(ctx.artifacts["analysis"], ctx.num_banks)
    ctx.artifacts["pf_ops"] = ops
    vals = list(ops.values())
    return {"prefetch_ops": len(vals),
            "fetched_regs": sum(len(o.bitvector) for o in vals),
            "serial_rounds": sum(o.serial_rounds for o in vals),
            "max_conflicts": max((o.conflicts for o in vals), default=0)}


def _emit_plan(ctx: CompileContext) -> dict:
    from .plan_cache import CompiledPlan

    an = ctx.artifacts.get("analysis")
    prog = an.prog if an is not None else ctx.prog
    block_interval = dict(an.block_interval) if an is not None else {}
    pf_ops = ctx.artifacts.get("pf_ops", {})
    live_sets: dict[int, frozenset[int]] = {}
    plus_fetch: dict[int, tuple[frozenset[int], int]] = {}
    if an is not None and ctx.design == "LTRF_plus":
        # LTRF+ (paper §3.2): only LIVE registers are written back on
        # deactivation and refetched on activation; dead working-set entries
        # get cache space but no data movement.
        live_in = ctx.artifacts["live_in"]  # from the liveness pass
        for iv in an.intervals:
            live = frozenset(live_in[iv.header] & iv.working_set)
            live_sets[iv.iid] = live
            occ = [0] * ctx.num_banks
            for r in live:
                occ[bank_of(r, ctx.num_banks)] += 1
            rounds = max(occ) if any(occ) else 1
            plus_fetch[iv.iid] = (live, rounds)
    banks: dict[int, tuple[tuple[int, ...], tuple[int, ...]]] = {}
    for _, _, ins in prog.instructions():
        banks[id(ins)] = (
            tuple(bank_of(r, ctx.num_banks) for r in ins.srcs),
            tuple(bank_of(r, ctx.num_banks) for r in ins.dsts),
        )
    # ctx.stats is shared by reference: the manager appends this pass' own
    # timing entry right after, so the emitted plan carries the full record.
    ctx.artifacts["plan"] = CompiledPlan(
        prog=prog, block_interval=block_interval, pf_ops=pf_ops,
        live_sets=live_sets, plus_fetch=plus_fetch,
        order_index={l: i for i, l in enumerate(prog.order)},
        instr_banks=banks, pass_stats=ctx.stats,
    )
    return {"instructions": prog.num_instrs(),
            "intervals": len(an.intervals) if an is not None else 0}


def sim_passes() -> list[Pass]:
    """The simulator compile pipeline (one list per run: safe to extend).

    The liveness pass sits after interval formation because its consumer
    (LTRF+'s live fetch sets) needs liveness over the split program the
    emitted plan actually executes; it only applies where it is consumed.
    """
    return [
        Pass("intervals", _form_intervals, _needs_intervals),
        Pass("liveness", _liveness,
             lambda ctx: ctx.design == "LTRF_plus"),
        Pass("icg", _build_icg, _wants_renumber),
        Pass("renumber", _renumber, _wants_renumber),
        Pass("prefetch", _plan_prefetch, _needs_intervals),
        Pass("emit", _emit_plan),
    ]


def frontend_passes() -> list[Pass]:
    """The liveness pipeline the frontend register allocator runs: the
    linearized, loop-extended live intervals linear scan consumes."""
    return [
        Pass("live-intervals", _linear_intervals),
    ]


def run_compile(prog: Program, design: str, interval_cap: int, num_banks: int,
                renumber: str = "icg", interval_strategy: str = "paper",
                rfc_per_warp: int = 0):
    """Run the full simulator pipeline; returns the emitted `CompiledPlan`.

    Callers wanting memoization should go through
    `plan_cache.compile_for_sim`, which keys on the normalized strategy and
    delegates here on a miss."""
    ctx = CompileContext(prog=prog, design=design, interval_cap=interval_cap,
                         num_banks=num_banks, renumber=renumber,
                         interval_strategy=interval_strategy,
                         rfc_per_warp=rfc_per_warp)
    PassManager(sim_passes()).run(ctx)
    return ctx.artifacts["plan"]
