"""Chaitin-style balanced graph coloring — paper §4.2 phase 3.

O(n + e) simplify/select with *balanced* color choice (colors used equally
often), exactly the property the paper relies on for balanced bank
assignment.  No spill code is ever produced: when a node cannot be colored
(clique bigger than k), it receives the least-loaded color among its
neighbours' colors and the residual conflict is reported, mirroring the
paper's "minimal remaining conflicts" behaviour.
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass
class Coloring:
    colors: dict[int, int]
    num_colors: int
    uncolorable: set[int]  # nodes that had to share a color with a neighbor

    def conflicts(self, adj: dict[int, set[int]]) -> int:
        bad = 0
        for u, nbrs in adj.items():
            for v in nbrs:
                if u < v and self.colors[u] == self.colors[v]:
                    bad += 1
        return bad


def chaitin_color(adj: dict[int, set[int]], k: int) -> Coloring:
    nodes = list(adj)
    degree = {n: len(adj[n]) for n in nodes}
    removed: set[int] = set()
    stack: list[int] = []

    work = sorted(nodes, key=lambda n: (degree[n], n))
    while len(stack) < len(nodes):
        pick = None
        for n in sorted(nodes, key=lambda n: (degree[n], n)):
            if n not in removed and degree[n] < k:
                pick = n
                break
        if pick is None:
            # optimistic: push the max-degree node and hope neighbours share colors
            pick = max((n for n in nodes if n not in removed),
                       key=lambda n: (degree[n], -n))
        removed.add(pick)
        stack.append(pick)
        for v in adj[pick]:
            if v not in removed:
                degree[v] -= 1

    colors: dict[int, int] = {}
    usage = [0] * max(k, 1)
    uncolorable: set[int] = set()
    while stack:
        n = stack.pop()
        taken = {colors[v] for v in adj[n] if v in colors}
        free = [c for c in range(k) if c not in taken]
        if free:
            c = min(free, key=lambda c: (usage[c], c))  # balanced choice
        else:
            c = min(range(k), key=lambda c: (usage[c], c))
            uncolorable.add(n)
        colors[n] = c
        usage[c] += 1
    return Coloring(colors=colors, num_colors=k, uncolorable=uncolorable)
