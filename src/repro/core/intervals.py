"""Register-interval formation — Algorithms 1 & 2 of the paper.

A *register-interval* is a CFG subgraph with (1) a single control-flow entry
and (2) a register working-set of at most ``n_cap`` registers (the size of one
register-file-cache partition).  Pass 1 (Algorithm 1) grows intervals block by
block, splitting basic blocks whose own instruction stream overflows the cap
and at function calls.  Pass 2 (Algorithm 2) repeatedly merges
single-predecessor intervals whose union still fits, so whole (nested) loops
collapse into one interval — one prefetch per loop.

Deviation from the paper's pseudocode (documented in DESIGN.md): the
pseudocode bounds the *per-path* accumulated register list; we bound the
*whole interval's* working-set union.  The paper's §3.1 guarantee — every
access inside the interval is a register-cache hit after one entry prefetch —
only holds under the union reading, and Algorithm 2's merge condition already
uses the union, so we apply it uniformly.

``strand_mode=True`` instead builds Gebhart'11-style *strands* (§7.6):
prefetch regions additionally terminated at long-latency memory ops and never
merged across loop back edges (pass 2 disabled).
"""
from __future__ import annotations

from dataclasses import dataclass, field

from .ir import BasicBlock, Instr, Program


@dataclass
class Interval:
    iid: int
    header: str
    blocks: list[str] = field(default_factory=list)
    working_set: set[int] = field(default_factory=set)
    solo: bool = False  # function-call intervals: never merged

    @property
    def size(self) -> int:
        return len(self.working_set)


@dataclass
class IntervalAnalysis:
    prog: Program  # with any split blocks applied
    intervals: list[Interval]
    block_interval: dict[str, int]
    n_cap: int

    def interval_of(self, label: str) -> Interval:
        return self.intervals[self.block_interval[label]]

    def edges(self) -> set[tuple[int, int]]:
        out: set[tuple[int, int]] = set()
        for bb in self.prog:
            i = self.block_interval[bb.label]
            for s in bb.succs:
                j = self.block_interval[s]
                if i != j:
                    out.add((i, j))
        return out

    def validate(self) -> None:
        # Single entry: every inter-interval edge lands on the interval header.
        headers = {iv.iid: iv.header for iv in self.intervals}
        for bb in self.prog:
            i = self.block_interval[bb.label]
            for s in bb.succs:
                j = self.block_interval[s]
                if i != j:
                    assert s == headers[j], (
                        f"edge {bb.label}->{s} enters interval {j} not at header {headers[j]}"
                    )
        for iv in self.intervals:
            assert iv.blocks, f"empty interval {iv.iid}"
            # Working-set cap (single huge basic-block instructions excepted).
            if not iv.solo and len(iv.working_set) > self.n_cap:
                # only legal when some single instruction exceeds the cap
                worst = max(
                    (len(set(ins.regs)) for b in iv.blocks for ins in self.prog.blocks[b].instrs),
                    default=0,
                )
                assert worst > self.n_cap, (
                    f"interval {iv.iid} working set {len(iv.working_set)} > cap {self.n_cap}"
                )


def _split_block(prog: Program, label: str, at: int, salt: int) -> str:
    """Split ``label`` before instruction index ``at``; return new block label."""
    bb = prog.blocks[label]
    new_label = f"{label}.s{salt}"
    assert new_label not in prog.blocks
    tail = BasicBlock(label=new_label, instrs=bb.instrs[at:])
    bb.instrs = bb.instrs[:at]
    prog.blocks[new_label] = tail
    prog.order.insert(prog.order.index(label) + 1, new_label)
    # Edges: tail inherits bb's successors; bb falls through to tail.
    tail.succs = bb.succs
    bb.succs = [new_label]
    tail.preds = [label]
    for s in tail.succs:
        ps = prog.blocks[s].preds
        prog.blocks[s].preds = [new_label if p == label else p for p in ps]
    return new_label


def _presplit_calls(prog: Program) -> set[str]:
    """Isolate every call instruction into its own basic block.

    Returns labels of call-only blocks (they become solo intervals).
    """
    call_blocks: set[str] = set()
    salt = 0
    work = list(prog.order)
    while work:
        label = work.pop(0)
        bb = prog.blocks[label]
        for i, ins in enumerate(bb.instrs):
            if ins.is_call:
                if i > 0:
                    nl = _split_block(prog, label, i, salt)
                    salt += 1
                    work.insert(0, nl)
                    break
                if len(bb.instrs) > 1:
                    _split_block(prog, label, 1, salt)
                    salt += 1
                call_blocks.add(label)
                break
        else:
            continue
    return call_blocks


def _traverse(
    prog: Program,
    label: str,
    interval: Interval,
    n_cap: int,
    salt: list[int],
    strand_mode: bool,
) -> str | None:
    """Algorithm 1's TRAVERSE: fold ``label``'s instructions into the interval
    working set, splitting the block if the cap is exceeded (or, in strand
    mode, after a long-latency memory instruction).  Returns the label of the
    split-off tail block (a fresh interval header) if a split happened."""
    bb = prog.blocks[label]
    ws = interval.working_set
    for i, ins in enumerate(bb.instrs):
        regs = set(ins.regs)
        if not (regs <= ws):
            grown = ws | regs
            if len(grown) > n_cap and ws:
                # split before this instruction; tail starts a new interval
                tail = _split_block(prog, label, i, salt[0])
                salt[0] += 1
                return tail
            if len(grown) > n_cap and not ws and i > 0:
                tail = _split_block(prog, label, i, salt[0])
                salt[0] += 1
                return tail
            ws |= regs  # single instruction may exceed cap: must admit it
        if strand_mode and ins.is_mem and i + 1 < len(bb.instrs):
            # strands end at long-latency ops: split AFTER the memory op
            tail = _split_block(prog, label, i + 1, salt[0])
            salt[0] += 1
            return tail
    return None


def form_register_intervals(
    prog: Program,
    n_cap: int,
    strand_mode: bool = False,
    run_pass2: bool | None = None,
) -> IntervalAnalysis:
    """Run Algorithm 1 (+ Algorithm 2 unless strand_mode) on a copy of ``prog``."""
    import copy

    prog = copy.deepcopy(prog)
    call_blocks = _presplit_calls(prog)
    if run_pass2 is None:
        run_pass2 = not strand_mode

    intervals: list[Interval] = []
    block_interval: dict[str, int] = {}
    salt = [0]

    def new_interval(header: str, solo: bool = False) -> Interval:
        iv = Interval(iid=len(intervals), header=header, solo=solo)
        intervals.append(iv)
        return iv

    worklist: list[str] = [prog.entry]
    pending: set[str] = {prog.entry}
    new_interval(prog.entry, solo=prog.entry in call_blocks)
    block_interval[prog.entry] = 0

    def assigned(label: str) -> bool:
        return label in block_interval

    while worklist:
        label = worklist.pop(0)
        pending.discard(label)
        iv = intervals[block_interval[label]]
        iv.blocks.append(label)
        tail = _traverse(prog, label, iv, n_cap, salt, strand_mode)
        if tail is not None:
            t_iv = new_interval(tail, solo=tail in call_blocks)
            block_interval[tail] = t_iv.iid
            worklist.insert(0, tail)
            pending.add(tail)

        # Grow interval: admit blocks whose every predecessor is already in iv
        # and whose registers keep the union within the cap.
        if not iv.solo:
            changed = True
            while changed:
                changed = False
                for cand in prog.order:
                    if assigned(cand) or cand in pending:
                        continue
                    bb = prog.blocks[cand]
                    if not bb.preds:
                        continue
                    if not all(
                        assigned(p) and block_interval[p] == iv.iid and p in iv.blocks
                        for p in bb.preds
                    ):
                        continue
                    if prog.blocks[cand].instrs and strand_mode:
                        pass  # strands may still grow across forward edges
                    if len(iv.working_set | bb.refs()) > n_cap:
                        continue
                    if cand in call_blocks:
                        continue
                    block_interval[cand] = iv.iid
                    iv.blocks.append(cand)
                    t2 = _traverse(prog, cand, iv, n_cap, salt, strand_mode)
                    if t2 is not None:
                        t_iv = new_interval(t2, solo=t2 in call_blocks)
                        block_interval[t2] = t_iv.iid
                        worklist.insert(0, t2)
                        pending.add(t2)
                    changed = True
        # Successor blocks not yet assigned become new interval headers.
        for member in list(iv.blocks):
            for s in prog.blocks[member].succs:
                if not assigned(s) and s not in pending:
                    s_iv = new_interval(s, solo=s in call_blocks)
                    block_interval[s] = s_iv.iid
                    worklist.append(s)
                    pending.add(s)

    # Unreachable blocks: give each its own interval (keeps maps total).
    for label in prog.order:
        if label not in block_interval:
            iv = new_interval(label, solo=label in call_blocks)
            block_interval[label] = iv.iid
            iv.blocks.append(label)
            iv.working_set |= prog.blocks[label].refs()

    analysis = IntervalAnalysis(prog=prog, intervals=intervals,
                                block_interval=block_interval, n_cap=n_cap)
    if run_pass2:
        analysis = _reduce(analysis)
    analysis.validate()
    return analysis


def form_fixed_intervals(prog: Program, length: int) -> IntervalAnalysis:
    """Naive fixed-length interval formation (``interval_strategy="fixed:N"``).

    Splits every basic block into runs of at most ``length`` instructions and
    makes each resulting block its own interval (no growing, no merging).
    Single-entry holds trivially — every interval is one block, which is its
    own header — but the working set is *unbounded*: a run of N instructions
    touches whatever it touches.  That is the point: this is the strawman
    baseline the ablation figures compare the paper's algorithm against.
    """
    import copy

    if length < 1:
        raise ValueError(f"fixed interval length must be >= 1, got {length}")
    prog = copy.deepcopy(prog)
    salt = 0
    work = list(prog.order)
    while work:
        label = work.pop(0)
        if len(prog.blocks[label].instrs) > length:
            tail = _split_block(prog, label, length, salt)
            salt += 1
            work.insert(0, tail)

    intervals: list[Interval] = []
    block_interval: dict[str, int] = {}
    for label in prog.order:
        iv = Interval(iid=len(intervals), header=label, blocks=[label],
                      working_set=prog.blocks[label].refs())
        intervals.append(iv)
        block_interval[label] = iv.iid
    n_cap = max((iv.size for iv in intervals), default=1)
    analysis = IntervalAnalysis(prog=prog, intervals=intervals,
                                block_interval=block_interval,
                                n_cap=max(n_cap, 1))
    analysis.validate()
    return analysis


def _reduce(analysis: IntervalAnalysis) -> IntervalAnalysis:
    """Algorithm 2: merge single-predecessor intervals until fixpoint."""
    prog, n_cap = analysis.prog, analysis.n_cap
    parent = {iv.iid: iv.iid for iv in analysis.intervals}

    def find(x: int) -> int:
        while parent[x] != x:
            parent[x] = parent[parent[x]]
            x = parent[x]
        return x

    ws = {iv.iid: set(iv.working_set) for iv in analysis.intervals}
    solo = {iv.iid: iv.solo for iv in analysis.intervals}
    header = {iv.iid: iv.header for iv in analysis.intervals}

    def ipreds(iid: int) -> set[int]:
        out: set[int] = set()
        h = header[iid]
        for member_label in members[iid]:
            for p in prog.blocks[member_label].preds:
                pi = find(analysis.block_interval[p])
                if pi != iid and member_label == h:
                    out.add(pi)
        return out

    members = {iv.iid: list(iv.blocks) for iv in analysis.intervals}

    changed = True
    while changed:
        changed = False
        for iid in [iv.iid for iv in analysis.intervals]:
            cur = find(iid)
            if cur != iid:
                continue
            preds = ipreds(cur)
            if len(preds) != 1:
                continue
            (p,) = preds
            if p == cur or solo[p] or solo[cur]:
                continue
            if len(ws[p] | ws[cur]) > n_cap:
                continue
            # merge cur into p
            parent[cur] = p
            ws[p] |= ws[cur]
            members[p] += members[cur]
            changed = True

    # Rebuild compact interval list.
    roots = sorted({find(iv.iid) for iv in analysis.intervals})
    remap = {r: k for k, r in enumerate(roots)}
    new_intervals: list[Interval] = []
    for r in roots:
        blocks = sorted(members[r], key=prog.order.index)
        new_intervals.append(Interval(
            iid=remap[r], header=header[r], blocks=blocks,
            working_set=set(ws[r]), solo=solo[r],
        ))
    block_interval = {b: remap[find(i)] for b, i in analysis.block_interval.items()}
    return IntervalAnalysis(prog=prog, intervals=new_intervals,
                            block_interval=block_interval, n_cap=n_cap)
