"""PTX-like register IR + control-flow graph.

This is the front-end the paper's compiler passes operate on.  Programs are
lists of instructions over virtual/architectural registers ``r0..rK`` and
predicate registers ``p0..pK``; control flow is expressed with labels and
(predicated) branches, exactly enough to express the paper's Listing 1 and the
workload suite (loops, nested loops, if/else diamonds, function calls).

A tiny asm DSL keeps workloads and tests readable::

    mov   r0, A          ; immediate / symbol sources are ignored operands
    L1: ld r4, [r0]      ; loads are long-latency instructions
    set   p0, r4, r5
    @!p0 bra L2
    add   r0, r0, 4
    bra   L1
    L2: exit

Registers are integers (``r7`` -> 7); predicates live in a separate small
space (``p0`` -> 0) because the paper's bank-conflict machinery only concerns
general registers.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field, replace
from typing import Iterable, Iterator, Sequence

# Instruction opcodes with a memory (long-latency) semantics.
MEM_OPS = frozenset({"ld", "st"})
# Opcodes that transfer control.
BRANCH_OPS = frozenset({"bra", "exit", "ret"})
CALL_OPS = frozenset({"call"})


@dataclass(frozen=True)
class Instr:
    """One IR instruction.

    ``dsts``/``srcs`` are general-register ids.  ``pdst``/``psrcs`` are
    predicate-register ids (``set`` writes a predicate, ``@p``/``@!p`` guards
    read one).  ``target`` is a label for branches/calls.
    """

    op: str
    dsts: tuple[int, ...] = ()
    srcs: tuple[int, ...] = ()
    pdst: int | None = None
    psrcs: tuple[int, ...] = ()
    target: str | None = None
    # Dead-operand bits (LTRF+): positions into ``srcs`` whose register dies
    # right after this instruction.  Filled in by liveness analysis.
    dead_srcs: tuple[int, ...] = ()

    @property
    def regs(self) -> tuple[int, ...]:
        return tuple(self.dsts) + tuple(self.srcs)

    @property
    def is_mem(self) -> bool:
        return self.op in MEM_OPS

    @property
    def is_branch(self) -> bool:
        return self.op in BRANCH_OPS

    @property
    def is_call(self) -> bool:
        return self.op in CALL_OPS

    def with_regs(self, mapping: dict[tuple[str, int], int]) -> "Instr":
        """Rewrite register operands.  ``mapping`` keys are ('d'|'s', position)."""
        dsts = tuple(mapping.get(("d", i), r) for i, r in enumerate(self.dsts))
        srcs = tuple(mapping.get(("s", i), r) for i, r in enumerate(self.srcs))
        return replace(self, dsts=dsts, srcs=srcs)

    def render(self) -> str:
        parts = [self.op]
        ops = [f"r{d}" for d in self.dsts]
        if self.pdst is not None:
            ops.append(f"p{self.pdst}")
        ops += [f"r{s}" for s in self.srcs]
        if self.target:
            ops.append(self.target)
        guard = "".join(f"@p{p} " for p in self.psrcs) if self.op != "set" else ""
        return guard + parts[0] + " " + ", ".join(ops)


@dataclass
class BasicBlock:
    label: str
    instrs: list[Instr] = field(default_factory=list)
    succs: list[str] = field(default_factory=list)
    preds: list[str] = field(default_factory=list)

    def refs(self) -> set[int]:
        """All general registers referenced (read or written) in the block."""
        out: set[int] = set()
        for ins in self.instrs:
            out.update(ins.regs)
        return out

    def uses_defs(self) -> tuple[set[int], set[int]]:
        """(upward-exposed uses, defs) over general registers."""
        uses: set[int] = set()
        defs: set[int] = set()
        for ins in self.instrs:
            uses.update(s for s in ins.srcs if s not in defs)
            defs.update(ins.dsts)
        return uses, defs


@dataclass
class Program:
    """A CFG: ordered blocks, entry first."""

    blocks: dict[str, BasicBlock]
    order: list[str]
    name: str = "kernel"

    @property
    def entry(self) -> str:
        return self.order[0]

    def __iter__(self) -> Iterator[BasicBlock]:
        for label in self.order:
            yield self.blocks[label]

    def instructions(self) -> Iterator[tuple[str, int, Instr]]:
        for label in self.order:
            for i, ins in enumerate(self.blocks[label].instrs):
                yield label, i, ins

    def registers(self) -> set[int]:
        out: set[int] = set()
        for bb in self:
            out.update(bb.refs())
        return out

    def num_instrs(self) -> int:
        return sum(len(bb.instrs) for bb in self)

    def recompute_edges(self) -> None:
        """(Re)build succ/pred lists from terminators + fallthrough order."""
        for bb in self.blocks.values():
            bb.succs, bb.preds = [], []
        for idx, label in enumerate(self.order):
            bb = self.blocks[label]
            nxt = self.order[idx + 1] if idx + 1 < len(self.order) else None
            term = bb.instrs[-1] if bb.instrs else None
            succs: list[str] = []
            if term is not None and term.op == "bra":
                assert term.target is not None
                succs.append(term.target)
                if term.psrcs and nxt is not None:  # predicated: may fall through
                    succs.append(nxt)
            elif term is not None and term.op in ("exit", "ret"):
                pass
            else:  # fallthrough (including calls: they return)
                if nxt is not None:
                    succs.append(nxt)
            bb.succs = list(dict.fromkeys(succs))
        for label in self.order:
            for s in self.blocks[label].succs:
                if label not in self.blocks[s].preds:
                    self.blocks[s].preds.append(label)

    def validate(self) -> None:
        assert self.order and self.order[0] in self.blocks
        for label in self.order:
            for s in self.blocks[label].succs:
                assert s in self.blocks, f"dangling edge {label}->{s}"

    def render(self) -> str:
        lines = []
        for bb in self:
            lines.append(f"{bb.label}:")
            lines += [f"  {ins.render()}" for ins in bb.instrs]
        return "\n".join(lines)


_LINE = re.compile(
    r"^\s*(?:(?P<label>[A-Za-z_]\w*)\s*:)?\s*(?P<guards>(?:@!?p\d+\s+)*)"
    r"(?P<op>[a-z.]+)?\s*(?P<ops>.*?)\s*(?:;.*)?$"
)
_REG = re.compile(r"^r(\d+)$")
_PREG = re.compile(r"^p(\d+)$")


def parse_asm(text: str, name: str = "kernel") -> Program:
    """Parse the asm DSL into a Program with block-level CFG."""
    raw: list[tuple[str | None, Instr | None]] = []
    for line in text.strip().splitlines():
        line = line.strip()
        if not line or line.startswith(";") or line.startswith("#"):
            continue
        m = _LINE.match(line)
        if not m:
            raise ValueError(f"bad asm line: {line!r}")
        label = m.group("label")
        op = m.group("op")
        if op is None:
            raw.append((label, None))
            continue
        op = op.split(".")[0]  # strip type suffixes like ld.local.u32
        guards = tuple(int(g) for g in re.findall(r"@!?p(\d+)", m.group("guards") or ""))
        toks = [t.strip() for t in m.group("ops").split(",") if t.strip()] if m.group("ops") else []
        dsts: list[int] = []
        srcs: list[int] = []
        pdst: int | None = None
        psrcs: list[int] = list(guards)
        target: str | None = None
        for i, tok in enumerate(toks):
            tok = tok.strip("[]")  # memory operands read an address register
            rm, pm = _REG.match(tok), _PREG.match(tok)
            if pm:
                if op == "set" and pdst is None:
                    pdst = int(pm.group(1))
                else:
                    psrcs.append(int(pm.group(1)))
            elif rm:
                r = int(rm.group(1))
                # first operand is the destination except for st/bra/call
                if i == 0 and op not in ("st", "bra", "call", "exit", "ret", "set"):
                    dsts.append(r)
                else:
                    srcs.append(r)
            elif op in ("bra", "call") and re.match(r"^[A-Za-z_]\w*$", tok):
                target = tok
            # anything else (immediates / symbols) is a non-register operand
        raw.append((label, Instr(op=op, dsts=tuple(dsts), srcs=tuple(srcs),
                                 pdst=pdst, psrcs=tuple(psrcs), target=target)))

    # Split into basic blocks: leaders are labeled lines and post-branch lines.
    blocks: dict[str, BasicBlock] = {}
    order: list[str] = []
    cur: BasicBlock | None = None
    anon = 0

    def new_block(label: str | None) -> BasicBlock:
        nonlocal anon
        if label is None:
            label = f".b{anon}"
            anon += 1
        bb = BasicBlock(label=label)
        blocks[label] = bb
        order.append(label)
        return bb

    prev_was_branch = True  # force a leader at program start
    for label, ins in raw:
        if label is not None or prev_was_branch or cur is None:
            cur = new_block(label)
            prev_was_branch = False
        if ins is None:
            continue
        cur.instrs.append(ins)
        if ins.is_branch:
            prev_was_branch = True
    prog = Program(blocks=blocks, order=order, name=name)
    prog.recompute_edges()
    prog.validate()
    return prog


def linearize(prog: Program) -> list[Instr]:
    return [ins for _, _, ins in prog.instructions()]


def reachable_blocks(prog: Program) -> set[str]:
    seen: set[str] = set()
    stack = [prog.entry]
    while stack:
        b = stack.pop()
        if b in seen:
            continue
        seen.add(b)
        stack.extend(prog.blocks[b].succs)
    return seen


def back_edges(prog: Program) -> set[tuple[str, str]]:
    """DFS back edges (loop edges) of the CFG."""
    color: dict[str, int] = {}
    out: set[tuple[str, str]] = set()

    def dfs(u: str) -> None:
        color[u] = 1
        for v in prog.blocks[u].succs:
            c = color.get(v, 0)
            if c == 0:
                dfs(v)
            elif c == 1:
                out.add((u, v))
        color[u] = 2

    import sys

    old = sys.getrecursionlimit()
    sys.setrecursionlimit(max(old, 10000))
    try:
        dfs(prog.entry)
    finally:
        sys.setrecursionlimit(old)
    return out
