"""Prefetch-operation construction + bank-conflict accounting — paper §3.2/§4.

Each register-interval gets one :class:`PrefetchOp` carrying the interval's
working-set bit-vector.  The MRF is ``num_banks`` single-ported banks, so a
prefetch completes in ``max_bank_occupancy`` serial bank rounds; the paper
counts an interval as having *N conflicts* when some bank holds N+1 of its
registers.
"""
from __future__ import annotations

from collections import Counter
from dataclasses import dataclass

from .intervals import IntervalAnalysis
from .renumber import bank_of


@dataclass(frozen=True)
class PrefetchOp:
    interval_id: int
    bitvector: frozenset[int]  # registers to fetch (architectural ids)
    bank_occupancy: tuple[int, ...]  # per-bank register counts

    @property
    def conflicts(self) -> int:
        return max(self.bank_occupancy, default=0) - 1 if self.bitvector else 0

    @property
    def serial_rounds(self) -> int:
        """Serial bank rounds the prefetch needs (1 == conflict-free)."""
        return max(self.bank_occupancy, default=1) if self.bitvector else 1


def prefetch_schedule(
    analysis: IntervalAnalysis,
    num_banks: int = 16,
    scheme: str = "interleaved",
    regs_per_bank: int = 2,
) -> list[PrefetchOp]:
    ops = []
    for iv in analysis.intervals:
        occ = [0] * num_banks
        for r in iv.working_set:
            occ[bank_of(r, num_banks, scheme, regs_per_bank)] += 1
        ops.append(PrefetchOp(interval_id=iv.iid,
                              bitvector=frozenset(iv.working_set),
                              bank_occupancy=tuple(occ)))
    return ops


def conflict_distribution(ops: list[PrefetchOp]) -> dict[int, float]:
    """Fraction of prefetch operations with exactly N bank conflicts."""
    if not ops:
        return {0: 1.0}
    c = Counter(op.conflicts for op in ops)
    total = sum(c.values())
    return {k: v / total for k, v in sorted(c.items())}


def code_size_overhead(analysis: IntervalAnalysis, bitvec_bits: int = 256,
                       instr_bits: int = 64, explicit_instr: bool = False) -> float:
    """Fractional static code-size increase from embedding prefetch bit-vectors
    (§5.3: ~7% bit-vector-only, ~9% with explicit prefetch instructions)."""
    base = analysis.prog.num_instrs() * instr_bits
    extra = len(analysis.intervals) * (bitvec_bits + (instr_bits if explicit_instr else 0))
    return extra / max(base, 1)
