"""Interval Conflict Graph (ICG) construction — paper §4.2 phases 1-2.

Nodes are register-live-ranges.  Two relations are computed:

* ``adj`` — *bank-conflict* edges used for coloring: two live-ranges conflict
  when both belong to the *working set* (are fetched by the prefetch op) of a
  common register-interval.  This is what determines prefetch bank conflicts:
  only registers fetched together compete for MRF banks (live-through values
  stay in the MRF and are not part of the prefetch).  The paper's Fig. 9
  walk-through is only 4-colorable under this reading.
* ``interfere`` — classic liveness interference (co-live at some program
  point, block-granular): the *correctness* constraint for physical register
  reuse during renumbering.  Renumbering may give two live-ranges the same
  register only if they neither interfere nor bank-conflict.
"""
from __future__ import annotations

from dataclasses import dataclass, field

from .intervals import IntervalAnalysis
from .liveness import LiveRange, block_liveness, build_live_ranges, reaching_defs


@dataclass
class ICG:
    ranges: list[LiveRange]
    occ: dict[tuple[str, int, str, int], int]  # operand occurrence -> lr_id
    adj: dict[int, set[int]] = field(default_factory=dict)        # bank conflicts
    interfere: dict[int, set[int]] = field(default_factory=dict)  # liveness
    interval_members: dict[int, set[int]] = field(default_factory=dict)  # iid -> fetched lr_ids

    def degree(self, n: int) -> int:
        return len(self.adj.get(n, ()))

    @property
    def num_edges(self) -> int:
        return sum(len(v) for v in self.adj.values()) // 2


def _clique(adj: dict[int, set[int]], nodes: set[int]) -> None:
    lst = sorted(nodes)
    for i, a in enumerate(lst):
        for b in lst[i + 1:]:
            adj[a].add(b)
            adj[b].add(a)


def _coalesce_same_reg(
    ranges: list[LiveRange],
    occ: dict[tuple[str, int, str, int], int],
    lr_intervals: dict[int, set[int]],
) -> tuple[list[LiveRange], dict[tuple[str, int, str, int], int], dict[int, set[int]]]:
    """Merge webs of the *same architectural register* that share an interval.

    The prefetch bit-vector has one bit per register number, so two webs of
    ``rK`` fetched in the same interval are physically one fetch; leaving them
    as separate ICG nodes would force them into different banks (and different
    register numbers), inflating the working set.  Same-register webs are
    never simultaneously live, so the merge is always safe.
    """
    parent = {lr.lr_id: lr.lr_id for lr in ranges}

    def find(x: int) -> int:
        while parent[x] != x:
            parent[x] = parent[parent[x]]
            x = parent[x]
        return x

    by_reg: dict[int, list[LiveRange]] = {}
    for lr in ranges:
        by_reg.setdefault(lr.reg, []).append(lr)
    changed = True
    ivs = {lr.lr_id: set(lr_intervals[lr.lr_id]) for lr in ranges}
    while changed:
        changed = False
        for _reg, lst in by_reg.items():
            roots: dict[int, int] = {}
            for lr in lst:
                r = find(lr.lr_id)
                roots.setdefault(r, r)
            rs = list(roots)
            for i, a in enumerate(rs):
                for b in rs[i + 1:]:
                    ra, rb = find(a), find(b)
                    if ra != rb and ivs[ra] & ivs[rb]:
                        parent[rb] = ra
                        ivs[ra] |= ivs[rb]
                        changed = True

    groups: dict[int, list[LiveRange]] = {}
    for lr in ranges:
        groups.setdefault(find(lr.lr_id), []).append(lr)
    new_ranges: list[LiveRange] = []
    old_to_new: dict[int, int] = {}
    new_intervals: dict[int, set[int]] = {}
    for root, lrs in sorted(groups.items()):
        nid = len(new_ranges)
        merged = LiveRange(
            lr_id=nid, reg=lrs[0].reg,
            defs=frozenset().union(*(lr.defs for lr in lrs)),
            use_sites=frozenset().union(*(lr.use_sites for lr in lrs)),
        )
        merged.intervals = set().union(*(lr_intervals[lr.lr_id] for lr in lrs))
        new_ranges.append(merged)
        new_intervals[nid] = merged.intervals
        for lr in lrs:
            old_to_new[lr.lr_id] = nid
    new_occ = {k: old_to_new[v] for k, v in occ.items()}
    return new_ranges, new_occ, new_intervals


def build_icg(analysis: IntervalAnalysis) -> ICG:
    prog = analysis.prog
    ranges, occ = build_live_ranges(prog)
    live_in, _ = block_liveness(prog)
    rdefs = reaching_defs(prog)

    lr_intervals: dict[int, set[int]] = {lr.lr_id: set() for lr in ranges}
    for (label, _i, _kind, _pos), lr_id in occ.items():
        lr_intervals[lr_id].add(analysis.block_interval[label])
    ranges, occ, lr_intervals = _coalesce_same_reg(ranges, occ, lr_intervals)

    icg = ICG(ranges=ranges, occ=occ,
              adj={lr.lr_id: set() for lr in ranges},
              interfere={lr.lr_id: set() for lr in ranges})

    # --- bank-conflict edges: co-membership in an interval's fetched set ---
    members: dict[int, set[int]] = {}
    for (label, _i, _kind, _pos), lr_id in occ.items():
        iid = analysis.block_interval[label]
        members.setdefault(iid, set()).add(lr_id)
    for lr in ranges:
        lr.intervals = lr_intervals[lr.lr_id]
    icg.interval_members = members
    for lrs in members.values():
        _clique(icg.adj, lrs)

    # --- interference edges: co-live within a block (conservative) ---
    defs_to_lr: dict[tuple, int] = {}
    input_lr: dict[int, int] = {}
    for lr in ranges:
        for d in lr.defs:
            defs_to_lr[d] = lr.lr_id
            if d[0] == "__entry__":
                input_lr[lr.reg] = lr.lr_id
    for bb in prog:
        live_here: set[int] = set()
        reach = rdefs[bb.label]
        for r in live_in[bb.label]:
            ds = reach.get(r)
            if ds:
                for d in ds:
                    lr_id = defs_to_lr.get(d)
                    if lr_id is not None:
                        live_here.add(lr_id)
            elif r in input_lr:
                live_here.add(input_lr[r])
        for i, _ins in enumerate(bb.instrs):
            for kind in ("d", "s"):
                k = 0
                while (bb.label, i, kind, k) in occ:
                    live_here.add(occ[(bb.label, i, kind, k)])
                    k += 1
        _clique(icg.interfere, live_here)
    return icg
