"""LTRF core: the paper's primary contribution.

Compiler side: PTX-like IR (+ a tiny asm DSL), register-interval formation
(Algorithms 1 & 2), liveness / register-live-ranges, Interval Conflict Graph,
Chaitin balanced coloring, register renumbering, prefetch-op construction.

System side (`plan`): the same interval/coloring machinery applied to model
layer graphs to schedule HBM->VMEM tile prefetching on TPU.
"""
from .ir import Instr, BasicBlock, Program, parse_asm
from .intervals import Interval, IntervalAnalysis, form_register_intervals
from .liveness import annotate_dead_operands, block_liveness, build_live_ranges
from .icg import ICG, build_icg
from .coloring import Coloring, chaitin_color
from .renumber import RenumberResult, bank_of, renumber_registers
from .prefetch import PrefetchOp, conflict_distribution, prefetch_schedule

__all__ = [
    "Instr", "BasicBlock", "Program", "parse_asm",
    "Interval", "IntervalAnalysis", "form_register_intervals",
    "annotate_dead_operands", "block_liveness", "build_live_ranges",
    "ICG", "build_icg", "Coloring", "chaitin_color",
    "RenumberResult", "bank_of", "renumber_registers",
    "PrefetchOp", "conflict_distribution", "prefetch_schedule",
]
