"""IntervalPlan: the paper's interval analysis applied to model layer graphs.

This is the bridge between Layer A (the GPU compiler passes) and Layer B (the
TPU runtime/kernels).  A model is lowered to a tiny *tile program*: each
layer-group is a basic block whose "registers" are its weight/state tiles
(one tile = one VMEM-resident operand block).  Running the SAME
`form_register_intervals` + ICG coloring over that program yields:

  * **intervals** — runs of layers whose aggregate tile working set fits the
    VMEM budget: one HBM->VMEM prefetch per interval, issued ahead of
    compute (the kernels' multi-buffered pipeline depth comes from here);
  * **slot coloring** — tiles co-fetched in an interval get distinct buffer
    slots (the bank-conflict pass; a slot still being read is never the
    target of the next DMA);
  * **PrefetchOp list** — the explicit, inspectable HW/SW contract that the
    paper encodes as ISA bit-vectors.

Used by `kernels/ltrf_matmul` (tile order + buffer depth) and by the runtime
to choose per-layer-group streaming/remat policy.
"""
from __future__ import annotations

from dataclasses import dataclass, field

from .coloring import chaitin_color
from .ir import parse_asm
from .plan_cache import cached_intervals


@dataclass(frozen=True)
class Tile:
    name: str
    bytes: int


@dataclass
class LayerNode:
    name: str
    tiles: list[Tile]
    flops: int = 0


@dataclass
class TilePrefetch:
    interval_id: int
    layer_names: list[str]
    tiles: list[Tile]
    slots: dict[str, int]  # tile name -> buffer slot
    fetch_bytes: int = 0   # exact bytes this round DMAs (granule-accurate:
                           # a tile split across rounds is fetched partially)

    @property
    def bytes(self) -> int:
        return self.fetch_bytes or sum(t.bytes for t in self.tiles)


@dataclass
class IntervalPlan:
    prefetches: list[TilePrefetch]
    vmem_budget: int
    num_slots: int
    tile_bytes: int

    @property
    def num_intervals(self) -> int:
        return len(self.prefetches)

    def max_interval_bytes(self) -> int:
        return max((p.bytes for p in self.prefetches), default=0)

    def validate(self) -> None:
        for p in self.prefetches:
            # granule-accurate fetch bytes never exceed the budget (a single
            # granule bigger than the budget is impossible by construction)
            assert p.bytes <= self.vmem_budget + self.tile_bytes
            # Slot reuse within one fetch round is bounded: co-fetched tiles
            # form a clique, so balanced coloring hands each slot at most
            # ceil(tiles / num_slots) of them.  A slot reused beyond that
            # bound would serialize the DMA stream behind a single buffer.
            used: dict[int, list[str]] = {}
            for t in p.tiles:
                used.setdefault(p.slots[t.name], []).append(t.name)
            bound = -(-len(p.tiles) // max(self.num_slots, 1))
            for s, names in used.items():
                assert len(names) <= bound, (
                    f"slot {s} reused {len(names)}x in interval "
                    f"{p.interval_id} (bound {bound}): {names}")
        # conflict-free within a fetch round: tiles fetched together should
        # map to distinct slots whenever enough slots exist
        for p in self.prefetches:
            if len(p.tiles) <= self.num_slots:
                vals = [p.slots[t.name] for t in p.tiles]
                assert len(set(vals)) == len(vals), "slot conflict"


def _balanced_slots(names: list[str], idx: dict[str, int],
                    colors: dict[int, int], num_slots: int) -> dict[str, int]:
    """Per-round buffer-slot assignment derived from the global coloring.

    The ICG coloring is a preference, not a guarantee: a tile constrained by
    *other* intervals' cliques can land on a slot already taken in this round.
    Rebalance within the round so no slot serves more than
    ceil(tiles/num_slots) tiles — the bound `IntervalPlan.validate` enforces —
    while keeping the colored slot whenever it is still under that bound.
    """
    bound = -(-len(names) // max(num_slots, 1))
    usage = [0] * max(num_slots, 1)
    out: dict[str, int] = {}
    for n in names:
        s = colors[idx[n]] % num_slots
        if usage[s] >= bound:
            s = min(range(num_slots), key=lambda c: (usage[c], c))
        out[n] = s
        usage[s] += 1
    return out


def plan_layer_stream(
    layers: list[LayerNode],
    vmem_budget: int,
    num_slots: int = 4,
) -> IntervalPlan:
    """Plan HBM->VMEM streaming for a sequential layer graph.

    Tiles are quantized to a common granule so the interval pass (which
    counts registers) can bound bytes: granule = vmem_budget / cap where cap
    is chosen so each granule is one 'register'.
    """
    cap = 64  # registers per interval (VMEM granules)
    granule = max(1, vmem_budget // cap)

    # Build the tile program: one block per layer; each tile occupies
    # ceil(bytes/granule) registers so the working-set cap == byte budget.
    reg_of_tile: dict[str, list[int]] = {}
    next_reg = 0
    lines = []
    for li, layer in enumerate(layers):
        lines.append(f"L{li}: nop")
        for t in layer.tiles:
            regs = reg_of_tile.get(t.name)
            if regs is None:
                n = max(1, -(-t.bytes // granule))
                regs = list(range(next_reg, next_reg + n))
                next_reg += n
                reg_of_tile[t.name] = regs
            # touch every granule of the tile in this layer
            for r in regs:
                lines.append(f"add r{r}, r{r}, r{r}")
    lines.append("exit")
    prog = parse_asm("\n".join(lines), name="layer-stream")
    # memoized: repeated plans over the same layer graph compile once
    analysis = cached_intervals(prog, cap)

    # Map intervals back to layers + tiles.
    reg_to_tile = {}
    for name, regs in reg_of_tile.items():
        for r in regs:
            reg_to_tile[r] = name
    tile_by_name = {t.name: t for layer in layers for t in layer.tiles}
    layer_of_block = {}
    for li in range(len(layers)):
        layer_of_block[f"L{li}"] = layers[li].name

    # Slot coloring: tiles co-fetched in one interval must take different
    # buffer slots (ICG over tiles, colored with num_slots colors).
    tiles_per_interval: list[list[str]] = []
    for iv in analysis.intervals:
        names = []
        for r in sorted(iv.working_set):
            n = reg_to_tile.get(r)
            if n is not None and n not in names:
                names.append(n)
        tiles_per_interval.append(names)
    all_tiles = sorted({n for ns in tiles_per_interval for n in ns})
    idx = {n: i for i, n in enumerate(all_tiles)}
    adj = {i: set() for i in range(len(all_tiles))}
    for ns in tiles_per_interval:
        ids = [idx[n] for n in ns]
        for i, a in enumerate(ids):
            for b in ids[i + 1:]:
                adj[a].add(b)
                adj[b].add(a)
    coloring = chaitin_color(adj, num_slots)

    prefetches = []
    for k, iv in enumerate(analysis.intervals):
        names = tiles_per_interval[k]
        if not names:
            continue
        lnames = sorted({layer_of_block[b.split(".")[0]] for b in iv.blocks
                         if b.split(".")[0] in layer_of_block})
        n_granules = sum(1 for r in iv.working_set if r in reg_to_tile)
        prefetches.append(TilePrefetch(
            interval_id=iv.iid,
            layer_names=lnames,
            tiles=[tile_by_name[n] for n in names],
            slots=_balanced_slots(names, idx, coloring.colors, num_slots),
            fetch_bytes=n_granules * granule,
        ))
    plan = IntervalPlan(prefetches=prefetches, vmem_budget=vmem_budget,
                        num_slots=num_slots, tile_bytes=granule)
    return plan


def plan_for_matmul(m: int, k: int, n: int, bk: int, bn: int,
                    vmem_budget: int, num_slots: int = 2,
                    dtype_bytes: int = 2) -> IntervalPlan:
    """Interval plan for a K/N-blocked matmul's weight-tile stream.

    Each (bk x bn) weight tile is one 'register'; intervals group the tile
    stream into VMEM-budget-sized prefetch rounds; slots alternate so DMA of
    round i+1 never lands in a buffer still being read by round i."""
    layers = []
    for j in range(-(-n // bn)):
        tiles = [Tile(name=f"w_{i}_{j}", bytes=bk * bn * dtype_bytes)
                 for i in range(-(-k // bk))]
        layers.append(LayerNode(name=f"col{j}", tiles=tiles,
                                flops=2 * m * k * bn))
    return plan_layer_stream(layers, vmem_budget, num_slots=num_slots)
