"""Compile cache for the LTRF compiler pass pipeline.

The design-space sweeps run the same workload program through the same
compiler pipeline once per (design, MRF-latency) point even though the
compiled artifact only depends on (program, pass configuration).  This
module memoizes the expensive passes — interval formation (all strategies),
ICG construction, register renumbering, prefetch scheduling — plus the
fully packaged `CompiledPlan` the simulator consumes, so a 7-design x
N-latency sweep compiles each workload once per distinct pass
configuration instead of once per simulator instance.

The pass *sequencing* lives in `core.pipeline` (`run_compile`); this module
only caches.  Keys are structural program fingerprints (not object
identity), so two equal programs parsed independently share cache entries.
All cached values are treated as immutable by every consumer: the simulator
never mutates the analysis, the prefetch ops, or the (split) program it
receives.
"""
from __future__ import annotations

from dataclasses import dataclass, field

from .icg import ICG, build_icg
from .intervals import (
    IntervalAnalysis, form_fixed_intervals, form_register_intervals,
)
from .ir import Program
from .prefetch import PrefetchOp, prefetch_schedule
from .renumber import RenumberResult, renumber_registers

# Compiled-plan layout revision: part of every _SIM_PLANS key (and available
# to any consumer deriving persistent keys from plans).  Bump when
# CompiledPlan gains/changes fields or the packaging itself changes behavior.
# rev 2: per-instruction operand bank vectors (instr_banks) + renumber axis.
# rev 3: pipeline emission + per-pass stats + interval-strategy axis.
PLAN_REV = 3

# program id -> (program ref, fingerprint).  The strong reference keeps the
# id stable for the lifetime of the entry.
_FINGERPRINTS: dict[int, tuple[Program, tuple]] = {}
_INTERVALS: dict[tuple, IntervalAnalysis] = {}
_RENUMBER: dict[tuple, RenumberResult] = {}
_PREFETCH: dict[tuple, dict[int, PrefetchOp]] = {}
_SIM_PLANS: dict[tuple, "CompiledPlan"] = {}
_VALUES: dict[tuple, object] = {}
_STATS = {"hits": 0, "misses": 0}

# FIFO bound per cache: plenty for the workload suite + sweeps, while a
# long-lived process compiling a stream of distinct programs (property
# tests, generated workloads) cannot grow memory without limit.
_CACHE_CAP = 512


def _put(cache: dict, key, value):
    if len(cache) >= _CACHE_CAP:
        cache.pop(next(iter(cache)))  # FIFO eviction
    cache[key] = value
    return value


def program_fingerprint(prog: Program) -> tuple:
    """A structural, hashable fingerprint of a program's CFG + instructions."""
    ent = _FINGERPRINTS.get(id(prog))
    if ent is not None and ent[0] is prog:
        return ent[1]
    fp = tuple(
        (label, tuple(prog.blocks[label].instrs), tuple(prog.blocks[label].succs))
        for label in prog.order
    )
    _put(_FINGERPRINTS, id(prog), (prog, fp))
    return fp


def cached_value(key: tuple, build):
    """Generic memo for expensive frontend artifacts (e.g. jaxpr lifts).

    ``key`` must be a stable, hashable fingerprint of everything ``build``
    depends on (include a revision constant so behaviour changes invalidate).
    The cached value is read-only by contract, like every other entry here.
    """
    v = _VALUES.get(key)
    if v is None:
        _STATS["misses"] += 1
        v = _put(_VALUES, key, build())
    else:
        _STATS["hits"] += 1
    return v


def cached_intervals(prog: Program, n_cap: int,
                     strand_mode: bool = False) -> IntervalAnalysis:
    """Memoized `form_register_intervals` (treat the result as read-only)."""
    key = (program_fingerprint(prog), n_cap, strand_mode)
    an = _INTERVALS.get(key)
    if an is None:
        _STATS["misses"] += 1
        an = _put(_INTERVALS, key,
                  form_register_intervals(prog, n_cap, strand_mode=strand_mode))
    else:
        _STATS["hits"] += 1
    return an


def cached_fixed_intervals(prog: Program, length: int) -> IntervalAnalysis:
    """Memoized `form_fixed_intervals` (``interval_strategy="fixed:N"``)."""
    key = (program_fingerprint(prog), "fixed", length)
    an = _INTERVALS.get(key)
    if an is None:
        _STATS["misses"] += 1
        an = _put(_INTERVALS, key, form_fixed_intervals(prog, length))
    else:
        _STATS["hits"] += 1
    return an


def _analysis_key(analysis: IntervalAnalysis) -> tuple:
    """Structural identity of an interval analysis.

    The interval *grouping* and *working sets* are part of the key (not
    just the count): strategies registered through the pipeline's extension
    point can split a program identically yet group its blocks — or trim
    their working sets — differently, and the ICG/renumber/prefetch results
    depend on both."""
    return (program_fingerprint(analysis.prog), analysis.n_cap,
            tuple((iv.iid, iv.header, iv.solo,
                   tuple(sorted(iv.working_set)))
                  for iv in analysis.intervals),
            tuple(sorted(analysis.block_interval.items())))


def cached_icg(analysis: IntervalAnalysis) -> ICG:
    """Memoized `build_icg` over a (cached) interval analysis (read-only)."""
    return cached_value(("icg", _analysis_key(analysis)),
                        lambda: build_icg(analysis))


def cached_renumber_analysis(analysis: IntervalAnalysis, num_banks: int,
                             icg: ICG | None = None) -> RenumberResult:
    """Memoized `renumber_registers` over a (cached) analysis (read-only)."""
    key = (_analysis_key(analysis), num_banks)
    rr = _RENUMBER.get(key)
    if rr is None:
        _STATS["misses"] += 1
        rr = _put(_RENUMBER, key,
                  renumber_registers(analysis, num_banks=num_banks, icg=icg))
    else:
        _STATS["hits"] += 1
    return rr


def cached_renumber(prog: Program, n_cap: int, num_banks: int) -> RenumberResult:
    """Memoized interval formation + register renumbering (read-only result)."""
    an = cached_intervals(prog, n_cap)
    return cached_renumber_analysis(an, num_banks, icg=cached_icg(an))


def cached_prefetch_ops(analysis: IntervalAnalysis,
                        num_banks: int) -> dict[int, PrefetchOp]:
    """Memoized `prefetch_schedule`, keyed by interval_id (read-only)."""
    key = (_analysis_key(analysis), num_banks)
    ops = _PREFETCH.get(key)
    if ops is None:
        _STATS["misses"] += 1
        ops = _put(_PREFETCH, key,
                   {op.interval_id: op
                    for op in prefetch_schedule(analysis, num_banks=num_banks)})
    else:
        _STATS["hits"] += 1
    return ops


@dataclass(frozen=True)
class CompiledPlan:
    """Everything the simulator needs from the compiler, per design family.

    Shared across Simulator instances — all fields are read-only by contract.
    ``plus_fetch`` (LTRF+ only) maps interval id -> (live fetch set, serial
    bank rounds) so the liveness-trimmed refetch cost is computed once per
    interval instead of once per prefetch event.  ``instr_banks`` maps
    ``id(instruction)`` (instructions of ``prog`` — the plan's own, possibly
    renumbered, numbering) -> (source bank vector, dest bank vector) so the
    simulator's bank-arbitration stage never recomputes ``bank_of`` per
    issue.  ``pass_stats`` is the pipeline's per-pass record (counters +
    wall time, keyed by pass name in execution order).
    """
    prog: Program
    block_interval: dict[str, int]
    pf_ops: dict[int, PrefetchOp]
    live_sets: dict[int, frozenset[int]] = field(default_factory=dict)
    plus_fetch: dict[int, tuple[frozenset[int], int]] = field(default_factory=dict)
    order_index: dict[str, int] = field(default_factory=dict)
    instr_banks: dict[int, tuple[tuple[int, ...], tuple[int, ...]]] = \
        field(default_factory=dict)
    pass_stats: dict[str, dict] = field(default_factory=dict)


def compile_for_sim(prog: Program, design: str, interval_cap: int,
                    num_banks: int, renumber: str = "icg",
                    interval_strategy: str = "paper",
                    rfc_per_warp: int = 0) -> CompiledPlan:
    """The simulator's compile step, memoized per (program, design family).

    Runs the staged pass pipeline (`core.pipeline.run_compile`) the paper
    evaluates per design: SHRF uses strand-bounded intervals, LTRF/LTRF+
    plain register-intervals, LTRF_conf adds ICG register renumbering, and
    the non-cached designs need no analysis.  ``renumber`` is the §4
    ablation axis (``"identity"`` skips the coloring pass; normalized out of
    the key for every design but LTRF_conf).  ``interval_strategy`` selects
    the interval-formation strategy (``"paper"``/``"capacity"``/
    ``"fixed:N"``); with ``"capacity"``, ``rfc_per_warp`` is the RFC
    entries-per-warp bound the working sets are clamped to.  Both are
    normalized (`pipeline.effective_strategy`) so no-op combinations share
    one cached plan.
    """
    from .pipeline import PIPELINE_REV, effective_strategy, run_compile

    eff_renumber = renumber if design == "LTRF_conf" else "icg"
    eff_strategy = effective_strategy(design, interval_strategy,
                                      interval_cap, rfc_per_warp)
    key = (PLAN_REV, PIPELINE_REV, program_fingerprint(prog), design,
           interval_cap, num_banks, eff_renumber, eff_strategy)
    plan = _SIM_PLANS.get(key)
    if plan is not None:
        _STATS["hits"] += 1
        return plan
    _STATS["misses"] += 1
    kind, arg = eff_strategy
    if kind == "capacity":
        strategy, eff_rfc = "capacity", arg
    else:  # paper, fixed:N, registered extension strategies
        strategy, eff_rfc = (f"{kind}:{arg}" if arg else kind), 0
    plan = run_compile(prog, design, interval_cap, num_banks,
                       renumber=eff_renumber, interval_strategy=strategy,
                       rfc_per_warp=eff_rfc)
    _put(_SIM_PLANS, key, plan)
    return plan


def cache_stats() -> dict[str, int]:
    return dict(_STATS,
                intervals=len(_INTERVALS), renumber=len(_RENUMBER),
                prefetch=len(_PREFETCH), sim_plans=len(_SIM_PLANS),
                values=len(_VALUES))


def cache_clear() -> None:
    for d in (_FINGERPRINTS, _INTERVALS, _RENUMBER, _PREFETCH, _SIM_PLANS,
              _VALUES):
        d.clear()
    _STATS.update(hits=0, misses=0)
