"""Compile cache for the LTRF compiler passes.

The design-space sweeps run the same workload program through the same
compiler pipeline once per (design, MRF-latency) point even though the
compiled artifact only depends on (program, pass kind, interval cap, bank
count).  This module memoizes the three expensive passes —
`form_register_intervals`, `renumber_registers`, `prefetch_schedule` — plus
the per-design packaging the simulator needs (`compile_for_sim`), so a
7-design x N-latency sweep compiles each workload once per distinct pass
configuration instead of once per simulator instance.

Keys are structural program fingerprints (not object identity), so two
equal programs parsed independently share cache entries.  All cached values
are treated as immutable by every consumer: the simulator never mutates the
analysis, the prefetch ops, or the (split) program it receives.
"""
from __future__ import annotations

from dataclasses import dataclass, field

from .intervals import IntervalAnalysis, form_register_intervals
from .ir import Program
from .prefetch import PrefetchOp, prefetch_schedule
from .renumber import RenumberResult, bank_of, renumber_registers

# Compiled-plan layout revision: part of every _SIM_PLANS key (and available
# to any consumer deriving persistent keys from plans).  Bump when
# CompiledPlan gains/changes fields or the packaging itself changes behavior.
# rev 2: per-instruction operand bank vectors (instr_banks) + renumber axis.
PLAN_REV = 2

# program id -> (program ref, fingerprint).  The strong reference keeps the
# id stable for the lifetime of the entry.
_FINGERPRINTS: dict[int, tuple[Program, tuple]] = {}
_INTERVALS: dict[tuple, IntervalAnalysis] = {}
_RENUMBER: dict[tuple, RenumberResult] = {}
_PREFETCH: dict[tuple, dict[int, PrefetchOp]] = {}
_SIM_PLANS: dict[tuple, "CompiledPlan"] = {}
_VALUES: dict[tuple, object] = {}
_STATS = {"hits": 0, "misses": 0}

# FIFO bound per cache: plenty for the workload suite + sweeps, while a
# long-lived process compiling a stream of distinct programs (property
# tests, generated workloads) cannot grow memory without limit.
_CACHE_CAP = 512


def _put(cache: dict, key, value):
    if len(cache) >= _CACHE_CAP:
        cache.pop(next(iter(cache)))  # FIFO eviction
    cache[key] = value
    return value


def program_fingerprint(prog: Program) -> tuple:
    """A structural, hashable fingerprint of a program's CFG + instructions."""
    ent = _FINGERPRINTS.get(id(prog))
    if ent is not None and ent[0] is prog:
        return ent[1]
    fp = tuple(
        (label, tuple(prog.blocks[label].instrs), tuple(prog.blocks[label].succs))
        for label in prog.order
    )
    _put(_FINGERPRINTS, id(prog), (prog, fp))
    return fp


def cached_value(key: tuple, build):
    """Generic memo for expensive frontend artifacts (e.g. jaxpr lifts).

    ``key`` must be a stable, hashable fingerprint of everything ``build``
    depends on (include a revision constant so behaviour changes invalidate).
    The cached value is read-only by contract, like every other entry here.
    """
    v = _VALUES.get(key)
    if v is None:
        _STATS["misses"] += 1
        v = _put(_VALUES, key, build())
    else:
        _STATS["hits"] += 1
    return v


def cached_intervals(prog: Program, n_cap: int,
                     strand_mode: bool = False) -> IntervalAnalysis:
    """Memoized `form_register_intervals` (treat the result as read-only)."""
    key = (program_fingerprint(prog), n_cap, strand_mode)
    an = _INTERVALS.get(key)
    if an is None:
        _STATS["misses"] += 1
        an = _put(_INTERVALS, key,
                  form_register_intervals(prog, n_cap, strand_mode=strand_mode))
    else:
        _STATS["hits"] += 1
    return an


def cached_renumber(prog: Program, n_cap: int, num_banks: int) -> RenumberResult:
    """Memoized interval formation + register renumbering (read-only result)."""
    key = (program_fingerprint(prog), n_cap, num_banks)
    rr = _RENUMBER.get(key)
    if rr is None:
        _STATS["misses"] += 1
        rr = _put(_RENUMBER, key,
                  renumber_registers(cached_intervals(prog, n_cap),
                                     num_banks=num_banks))
    else:
        _STATS["hits"] += 1
    return rr


def cached_prefetch_ops(analysis: IntervalAnalysis,
                        num_banks: int) -> dict[int, PrefetchOp]:
    """Memoized `prefetch_schedule`, keyed by interval_id (read-only)."""
    key = (program_fingerprint(analysis.prog), analysis.n_cap, num_banks,
           len(analysis.intervals))
    ops = _PREFETCH.get(key)
    if ops is None:
        _STATS["misses"] += 1
        ops = _put(_PREFETCH, key,
                   {op.interval_id: op
                    for op in prefetch_schedule(analysis, num_banks=num_banks)})
    else:
        _STATS["hits"] += 1
    return ops


@dataclass(frozen=True)
class CompiledPlan:
    """Everything the simulator needs from the compiler, per design family.

    Shared across Simulator instances — all fields are read-only by contract.
    ``plus_fetch`` (LTRF+ only) maps interval id -> (live fetch set, serial
    bank rounds) so the liveness-trimmed refetch cost is computed once per
    interval instead of once per prefetch event.  ``instr_banks`` maps
    ``id(instruction)`` (instructions of ``prog`` — the plan's own, possibly
    renumbered, numbering) -> (source bank vector, dest bank vector) so the
    simulator's bank-arbitration stage never recomputes ``bank_of`` per
    issue.
    """
    prog: Program
    block_interval: dict[str, int]
    pf_ops: dict[int, PrefetchOp]
    live_sets: dict[int, frozenset[int]] = field(default_factory=dict)
    plus_fetch: dict[int, tuple[frozenset[int], int]] = field(default_factory=dict)
    order_index: dict[str, int] = field(default_factory=dict)
    instr_banks: dict[int, tuple[tuple[int, ...], tuple[int, ...]]] = \
        field(default_factory=dict)


def _finish(prog: Program, block_interval, pf_ops, live_sets=None,
            plus_fetch=None, num_banks: int = 16) -> CompiledPlan:
    banks: dict[int, tuple[tuple[int, ...], tuple[int, ...]]] = {}
    for _, _, ins in prog.instructions():
        banks[id(ins)] = (
            tuple(bank_of(r, num_banks) for r in ins.srcs),
            tuple(bank_of(r, num_banks) for r in ins.dsts),
        )
    return CompiledPlan(
        prog=prog, block_interval=block_interval, pf_ops=pf_ops,
        live_sets=live_sets or {}, plus_fetch=plus_fetch or {},
        order_index={l: i for i, l in enumerate(prog.order)},
        instr_banks=banks,
    )


def compile_for_sim(prog: Program, design: str, interval_cap: int,
                    num_banks: int, renumber: str = "icg") -> CompiledPlan:
    """The simulator's compile step, memoized per (program, design family).

    Mirrors the per-design pipeline the paper evaluates: SHRF uses
    strand-bounded intervals, LTRF/LTRF+ plain register-intervals, LTRF_conf
    adds register renumbering, and the non-cached designs need no analysis.
    ``renumber`` is the §4 ablation axis: ``"identity"`` makes LTRF_conf skip
    the ICG coloring pass and keep the original register numbers (the knob
    is a no-op for every other design, and is normalized out of the cache
    key for them).
    """
    eff_renumber = renumber if design == "LTRF_conf" else "icg"
    key = (PLAN_REV, program_fingerprint(prog), design, interval_cap,
           num_banks, eff_renumber)
    plan = _SIM_PLANS.get(key)
    if plan is not None:
        _STATS["hits"] += 1
        return plan
    _STATS["misses"] += 1

    if design in ("BL", "RFC", "Ideal"):
        plan = _finish(prog, {}, {}, num_banks=num_banks)
    else:
        if design == "SHRF":
            an = cached_intervals(prog, interval_cap, strand_mode=True)
        elif design == "LTRF_conf" and eff_renumber == "icg":
            an = cached_renumber(prog, interval_cap, num_banks).analysis
        else:  # LTRF, LTRF_plus, LTRF_conf with identity numbering
            an = cached_intervals(prog, interval_cap)
        ops = cached_prefetch_ops(an, num_banks)
        live_sets: dict[int, frozenset[int]] = {}
        plus_fetch: dict[int, tuple[frozenset[int], int]] = {}
        if design == "LTRF_plus":
            # LTRF+ (paper §3.2): only LIVE registers are written back on
            # deactivation and refetched on activation; dead working-set
            # entries get cache space but no data movement.
            from .liveness import block_liveness
            live_in, _ = block_liveness(an.prog)
            for iv in an.intervals:
                live = frozenset(live_in[iv.header] & iv.working_set)
                live_sets[iv.iid] = live
                occ = [0] * num_banks
                for r in live:
                    occ[bank_of(r, num_banks)] += 1
                rounds = max(occ) if any(occ) else 1
                plus_fetch[iv.iid] = (live, rounds)
        plan = _finish(an.prog, dict(an.block_interval), ops,
                       live_sets, plus_fetch, num_banks=num_banks)
    _put(_SIM_PLANS, key, plan)
    return plan


def cache_stats() -> dict[str, int]:
    return dict(_STATS,
                intervals=len(_INTERVALS), renumber=len(_RENUMBER),
                prefetch=len(_PREFETCH), sim_plans=len(_SIM_PLANS),
                values=len(_VALUES))


def cache_clear() -> None:
    for d in (_FINGERPRINTS, _INTERVALS, _RENUMBER, _PREFETCH, _SIM_PLANS,
              _VALUES):
        d.clear()
    _STATS.update(hits=0, misses=0)
