"""Register renumbering — paper §4.2 phase 4.

Rewrites every register operand occurrence so each live-range lands in the
register bank chosen by the ICG coloring.  Non-conflicting live-ranges of the
same color may share one physical register (standard web allocation); a
live-range is always given a register of its color's bank, so the prefetch
unit touches each bank at most ``ceil(|working set| / num_banks)`` times.

Bank mapping schemes:
* ``interleaved`` (hardware default): bank(r) = r mod num_banks
* ``grouped`` (paper's Fig. 8 walk-through): bank(r) = r // regs_per_bank
"""
from __future__ import annotations

import copy
from dataclasses import dataclass

from .coloring import Coloring, chaitin_color
from .icg import ICG, build_icg
from .intervals import IntervalAnalysis
from .ir import Program


def bank_of(reg: int, num_banks: int, scheme: str = "interleaved", regs_per_bank: int = 2) -> int:
    if scheme == "interleaved":
        return reg % num_banks
    if scheme == "grouped":
        return (reg // regs_per_bank) % num_banks
    raise ValueError(scheme)


def _bank_regs(bank: int, num_banks: int, scheme: str, regs_per_bank: int):
    """Infinite generator of register ids living in ``bank``."""
    m = 0
    while True:
        if scheme == "interleaved":
            yield bank + m * num_banks
        else:
            base = bank * regs_per_bank + m * num_banks * regs_per_bank
            for j in range(regs_per_bank):
                yield base + j
        m += 1


@dataclass
class RenumberResult:
    prog: Program
    analysis: IntervalAnalysis  # intervals recomputed over the renumbered prog
    icg: ICG
    coloring: Coloring
    lr_reg: dict[int, int]  # lr_id -> new register
    applied: bool = True  # False: pass found no improvement, kept original code


def _schedule_cost(analysis: IntervalAnalysis, num_banks: int, scheme: str,
                   regs_per_bank: int) -> tuple[int, int]:
    """(max conflicts, total serial bank rounds) — lower is better."""
    from .prefetch import prefetch_schedule

    ops = prefetch_schedule(analysis, num_banks=num_banks, scheme=scheme,
                            regs_per_bank=regs_per_bank)
    return (max((o.conflicts for o in ops), default=0),
            sum(o.serial_rounds for o in ops))


def renumber_registers(
    analysis: IntervalAnalysis,
    num_banks: int,
    scheme: str = "interleaved",
    regs_per_bank: int = 2,
    max_regs: int = 256,
    icg: ICG | None = None,
) -> RenumberResult:
    # The pipeline's ICG pass hands its (memoized) graph in; standalone
    # callers let the pass pair collapse into one call.
    if icg is None:
        icg = build_icg(analysis)
    coloring = chaitin_color(icg.adj, num_banks)

    # Assign physical registers per color-bank, reusing a register across
    # live-ranges only when they do not interfere.
    lr_reg: dict[int, int] = {}
    bank_alloc: dict[int, list[tuple[int, set[int]]]] = {}  # color -> [(reg, lr_ids)]
    order = sorted(icg.ranges, key=lambda lr: (min(lr.intervals or {1 << 30}), lr.lr_id))
    for lr in order:
        c = coloring.colors[lr.lr_id]
        slots = bank_alloc.setdefault(c, [])
        placed = False
        blocked = icg.adj[lr.lr_id] | icg.interfere[lr.lr_id]
        for reg, holders in slots:
            if not (blocked & holders):
                holders.add(lr.lr_id)
                lr_reg[lr.lr_id] = reg
                placed = True
                break
        if not placed:
            gen = _bank_regs(c, num_banks, scheme, regs_per_bank)
            used = {r for r, _ in slots}
            for reg in gen:
                if reg not in used:
                    break
                if reg > max_regs * 4:  # safety valve
                    break
            slots.append((reg, {lr.lr_id}))
            lr_reg[lr.lr_id] = reg

    new_prog = copy.deepcopy(analysis.prog)
    for label, i, ins in list(new_prog.instructions()):
        mapping: dict[tuple[str, int], int] = {}
        for k, _ in enumerate(ins.dsts):
            lr_id = icg.occ.get((label, i, "d", k))
            if lr_id is not None:
                mapping[("d", k)] = lr_reg[lr_id]
        for k, _ in enumerate(ins.srcs):
            lr_id = icg.occ.get((label, i, "s", k))
            if lr_id is not None:
                mapping[("s", k)] = lr_reg[lr_id]
        new_prog.blocks[label].instrs[i] = ins.with_regs(mapping)

    # Intervals are structurally identical; recompute working sets over the
    # renumbered registers by replaying membership.
    new_analysis = IntervalAnalysis(
        prog=new_prog,
        intervals=copy.deepcopy(analysis.intervals),
        block_interval=dict(analysis.block_interval),
        n_cap=analysis.n_cap,
    )
    for iv in new_analysis.intervals:
        ws: set[int] = set()
        for b in iv.blocks:
            ws |= new_prog.blocks[b].refs()
        iv.working_set = ws

    # The pass is advisory: keep the renumbered code only when it actually
    # reduces prefetch bank pressure (the coloring heuristic can lose on
    # over-constrained graphs, e.g. 16-register intervals over 4 banks).
    if _schedule_cost(new_analysis, num_banks, scheme, regs_per_bank) > \
       _schedule_cost(analysis, num_banks, scheme, regs_per_bank):
        ident = {lr.lr_id: lr.reg for lr in icg.ranges}
        return RenumberResult(prog=analysis.prog, analysis=analysis, icg=icg,
                              coloring=coloring, lr_reg=ident, applied=False)
    return RenumberResult(prog=new_prog, analysis=new_analysis, icg=icg,
                          coloring=coloring, lr_reg=lr_reg, applied=True)
