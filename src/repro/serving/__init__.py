from .allocator import AddressAllocationUnit
from .scheduler import PAGE_TOKENS, Request, TwoLevelScheduler
from .engine import ServeConfig, ServingEngine
from .sweep import (
    FAILURE_KINDS, FailureRecord, ResultStore, SimRunner, SweepConfig,
    SweepReport, default_processes, default_runner, job_label, sim_key,
)

__all__ = ["AddressAllocationUnit", "PAGE_TOKENS", "Request",
           "TwoLevelScheduler", "ServeConfig", "ServingEngine",
           "FAILURE_KINDS", "FailureRecord", "ResultStore", "SimRunner",
           "SweepConfig", "SweepReport", "default_processes",
           "default_runner", "job_label", "sim_key"]
