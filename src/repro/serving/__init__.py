from .allocator import AddressAllocationUnit
from .scheduler import PAGE_TOKENS, Request, TwoLevelScheduler
from .engine import ServeConfig, ServingEngine

__all__ = ["AddressAllocationUnit", "PAGE_TOKENS", "Request",
           "TwoLevelScheduler", "ServeConfig", "ServingEngine"]
