"""Address Allocation Unit (paper Fig. 13) applied to paged KV-cache slots.

The paper's AAU is two queues — *unused* (free banks) and *occupied* — used
to hand register-cache banks to prefetched registers.  The identical
structure manages KV-cache pages in the serving engine: allocation pops the
head of the unused queue; deallocation returns the entry.  O(1), fragment-
free, and trivially auditable — exactly why the paper chose it.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field


@dataclass
class AddressAllocationUnit:
    capacity: int
    unused: deque = field(default_factory=deque)
    occupied: dict[int, object] = field(default_factory=dict)

    def __post_init__(self) -> None:
        self.unused = deque(range(self.capacity))

    def alloc(self, owner=None) -> int | None:
        """Pop the head of the unused queue (None if exhausted)."""
        if not self.unused:
            return None
        slot = self.unused.popleft()
        self.occupied[slot] = owner
        return slot

    def free(self, slot: int) -> None:
        owner = self.occupied.pop(slot, _MISSING)
        if owner is _MISSING:
            raise KeyError(f"slot {slot} not allocated")
        self.unused.append(slot)

    def owner_of(self, slot: int):
        return self.occupied.get(slot)

    @property
    def free_count(self) -> int:
        return len(self.unused)

    @property
    def used_count(self) -> int:
        return len(self.occupied)

    def check_invariants(self) -> None:
        assert self.free_count + self.used_count == self.capacity
        assert set(self.unused).isdisjoint(self.occupied.keys())


class _Missing:
    pass


_MISSING = _Missing()
