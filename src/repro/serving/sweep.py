"""Fault-tolerant sweep service: the orchestration layer behind the sweeps.

The paper's subject is latency *tolerance* — overlapping long-latency
operations instead of stalling on them — and this module applies the same
discipline to the sweep infrastructure itself.  The original
``benchmarks.orchestrator`` died on its first fault: one crashed pool
worker aborted a whole ``prefill`` with `BrokenProcessPool`, a hung
simulation blocked a sweep forever, and a corrupt cache entry was silently
recomputed with no record.  This layer survives all of them:

* **future-per-job dispatch** — every job is its own future; a broken
  process pool is recycled and only the jobs that were actually in flight
  are re-examined (each suspect is then probed *serially*, so a genuine
  crasher is charged its attempt while innocent bystanders are retried for
  free — the `SweepReport` names exactly the faulty jobs);
* **bounded retries with exponential backoff** — transient failures
  (exceptions, worker crashes, timeouts) are retried up to
  `SweepConfig.max_attempts` times, waiting
  ``backoff_base_s * backoff_factor**(attempt-1)`` (capped at
  ``backoff_max_s``) between attempts;
* **per-job wall-clock timeouts** — a job that exceeds
  `SweepConfig.job_timeout_s` has its pool recycled (the hung worker is
  killed) and is charged a ``timeout`` attempt.  The in-band counterpart is
  the `SimConfig.max_cycles` watchdog (`SweepConfig.watchdog_max_cycles`
  applies it sweep-wide): runaway configs raise a structured
  `repro.sim.SimBudgetExceeded` instead of spinning;
* **a checksummed, content-addressed result store** — cache entries are
  ``{"v", "key", "sha256", "payload"}`` envelopes; truncated, torn,
  wrong-schema, or bit-rotted entries are detected on load, *quarantined*
  under ``simcache/quarantine/`` next to a structured ``*.failure.json``
  record, and recomputed — never silently trusted or silently dropped;
* **graceful degradation** — `SimRunner.prefill` returns a `SweepReport`
  (completed / retried / failed / quarantined, per job) instead of raising,
  so `benchmarks.bench_sim` and `benchmarks.paper_figs` can finish a sweep
  with annotated missing points rather than crashing.

The deterministic chaos harness that exercises all of this lives in
`repro.serving.faults`; `tests/test_sweep_faults.py` is the suite.
"""
from __future__ import annotations

import heapq
import json
import hashlib
import os
import pathlib
import time
from concurrent.futures import (
    FIRST_COMPLETED, Future, ProcessPoolExecutor, wait,
)
from concurrent.futures.process import BrokenProcessPool
from dataclasses import asdict, dataclass, field, replace

from repro.core.pipeline import PIPELINE_REV
from repro.core.plan_cache import PLAN_REV
from repro.obs.metrics import MetricsRegistry
from repro.serving import faults
from repro.sim import SimBudgetExceeded, SimConfig, SimResult, simulate
from repro.sim.analytic import (ANALYTIC_REV, CALIB_REV, DEFAULT_CALIBRATION,
                                TIERS, AnalyticResult, Calibration,
                                CalibrationError, analytic_supported,
                                estimate as analytic_estimate,
                                load_calibration, pareto_frontier)
from repro.sim.engine import ENGINE_REV
from repro.sim.gpu import GpuResult, aggregate, per_sm_configs
from repro.workloads import get_workload

ROOT = pathlib.Path(__file__).resolve().parents[3]
SIMCACHE = pathlib.Path(os.environ.get(
    "REPRO_SIMCACHE", ROOT / "experiments" / "paper" / "simcache"))

Job = tuple[str, SimConfig]

# In 'auto' batch mode the vectorized engine only engages once a prefill
# has this many supported misses: below that, jit compilation costs more
# than it saves and per-job latency histograms lose their meaning.
# Explicit opt-in (batch=True or REPRO_SIM_BATCH=1) batches everything it
# can.  On parallel backends (GPU/TPU) the bar is low; on CPU the BATCH_REV
# 2 fused tick beats the event-heap engine in *steady state* (measured:
# `batch_engine` in BENCH_sim.json), but a cold prefill still pays tens of
# seconds of XLA compilation per shape bucket, so the CPU bar is set where
# a tracked-sweep-sized prefill amortizes it and a smoke-sized one never
# triggers it.
_MIN_AUTO_BATCH = 8
_MIN_AUTO_BATCH_CPU = 64


def _auto_batch_threshold() -> int:
    """Supported-miss count at which 'auto' mode engages the batch engine.

    Deliberately refuses to *import* jax for the probe: a cache lookup
    should not cost a multi-second import.  If jax is already up on a
    non-CPU backend the low bar applies; otherwise (plain CPU host, or jax
    not loaded yet — `run_batch` imports it lazily only once the threshold
    is actually met) the compile-amortizing CPU bar applies."""
    import sys

    j = sys.modules.get("jax")
    if j is not None:
        try:
            if j.devices()[0].platform != "cpu":
                return _MIN_AUTO_BATCH
        except Exception:  # noqa: BLE001 - any probe failure means "cpu"
            pass
    return _MIN_AUTO_BATCH_CPU


def _auto_batch_ok() -> bool:
    """Back-compat shim: 'auto' mode now always consults
    `_auto_batch_threshold` (CPU hosts batch too, at a higher bar)."""
    return True

# Failure/retry classification (FailureRecord.kind):
#   transient - the job raised an ordinary exception (incl. injected faults)
#   crash     - the job's worker process died (BrokenProcessPool)
#   timeout   - the job exceeded SweepConfig.job_timeout_s wall-clock
#   budget    - the simulation raised SimBudgetExceeded (deterministic:
#               never retried, retrying cannot change the outcome)
#   corrupt   - a cache entry failed validation and was quarantined
FAILURE_KINDS = ("transient", "crash", "timeout", "budget", "corrupt")
_RETRIABLE = frozenset({"transient", "crash", "timeout"})

STORE_VERSION = 1


def job_label(job: Job) -> str:
    """Human-stable job identity used in reports and fault-plan matching."""
    name, cfg = job
    return f"{name}/{cfg.design}/seed{cfg.seed}"


def sim_key(workload: str, cfg: SimConfig) -> str:
    """Stable on-disk key for one simulation job.

    The full revision triple is part of the key — ENGINE_REV for the
    engine's counters, PLAN_REV/PIPELINE_REV for the compiler passes that
    shape what the engine simulates — so a behavioral change on *either*
    side makes old cache entries unreachable instead of silently mixing two
    behaviors into one sweep.  ``max_cycles`` is excluded: the watchdog can
    only abort a simulation (raising `SimBudgetExceeded`), never change a
    completed result, so budgeted and unbudgeted runs share entries.
    ``trace`` is excluded for the same reason: the event tracer observes a
    run without changing any counter, so traced and untraced runs share
    entries."""
    cfg_payload = asdict(cfg)
    cfg_payload.pop("max_cycles", None)
    cfg_payload.pop("trace", None)
    payload = json.dumps([[ENGINE_REV, PLAN_REV, PIPELINE_REV],
                          workload, cfg_payload], sort_keys=True)
    return hashlib.sha1(payload.encode()).hexdigest()[:20]


def analytic_sim_key(workload: str, cfg: SimConfig,
                     calib: Calibration) -> str:
    """Stable on-disk key for one *analytical* estimate.

    Deliberately a different namespace from `sim_key`: the payload leads
    with an ``"analytic"`` tag plus `ANALYTIC_REV`/`CALIB_REV` and the
    calibration coefficient fingerprint, so a fast-tier estimate can never
    collide with (or be replayed as) an engine verdict, and re-fitting the
    calibration invalidates exactly the estimates it would change."""
    cfg_payload = asdict(cfg)
    cfg_payload.pop("max_cycles", None)
    cfg_payload.pop("trace", None)
    payload = json.dumps(
        [["analytic", ANALYTIC_REV, CALIB_REV, ENGINE_REV, PLAN_REV,
          PIPELINE_REV], calib.fingerprint(), workload, cfg_payload],
        sort_keys=True)
    return "an" + hashlib.sha1(payload.encode()).hexdigest()[:18]


# Calibration constants live in the result store root under this key so the
# store's quarantine machinery covers a corrupt calibration file exactly
# like a corrupt result entry.
CALIBRATION_KEY = "analytic_calib"

# Hybrid tier: engine-confirm the analytic Pareto frontier plus this many
# best-estimated-cycles points per workload group.
DEFAULT_TOP_K = 3


def sweep_run_id(jobs: list[Job]) -> str:
    """Deterministic run identity for one sweep: the sorted `sim_key` set
    plus the revision triple, hashed to 12 hex chars.

    Two sweeps over the same jobs under the same engine/compiler revisions
    share a ``run_id`` (re-runs of a sweep are the *same* run for artifact
    joining); any change to the job set or the code revisions yields a new
    one.  Stamped on `SweepReport`, on every sweep `FailureRecord`, on
    quarantine ``*.failure.json`` records, and on metrics snapshots, so the
    artifacts of one sweep are joinable."""
    keys = sorted(sim_key(name, cfg) for name, cfg in jobs)
    payload = json.dumps([[ENGINE_REV, PLAN_REV, PIPELINE_REV], keys])
    return hashlib.sha1(payload.encode()).hexdigest()[:12]


def default_processes() -> int:
    env = os.environ.get("REPRO_SIM_PROCS")
    if env:
        return max(1, int(env))
    return max(1, os.cpu_count() or 1)


# --------------------------------------------------------------------------
# Sweep configuration + report

@dataclass(frozen=True)
class SweepConfig:
    """Fault-tolerance knobs for one sweep (see docs/serving.md)."""
    max_attempts: int = 3          # total tries per job (1 = no retry)
    backoff_base_s: float = 0.05   # wait before attempt 2
    backoff_factor: float = 2.0    # growth per further attempt
    backoff_max_s: float = 2.0     # backoff ceiling
    job_timeout_s: float | None = None   # per-job wall clock (None = off)
    watchdog_max_cycles: int = 0   # SimConfig.max_cycles applied sweep-wide
                                   # to jobs that don't set their own


@dataclass
class FailureRecord:
    """One structured failure event (a job's final failure, or a
    quarantined cache entry)."""
    job: str
    workload: str
    design: str
    kind: str                      # one of FAILURE_KINDS
    detail: str = ""
    attempts: int = 0
    key: str = ""
    run_id: str = ""               # sweep identity (sweep_run_id); empty for
                                   # failures outside a prefill sweep

    def to_dict(self) -> dict:
        return asdict(self)


@dataclass
class SweepReport:
    """What happened to every job of one `SimRunner.prefill` call."""
    run_id: str = ""               # deterministic sweep identity (sweep_run_id)
    total: int = 0                 # unique jobs requested
    cached: int = 0                # served from memo/disk before dispatch
    computed: int = 0              # simulated this call
    completed: int = 0             # jobs with a result available at the end
    retried: dict[str, int] = field(default_factory=dict)  # label -> retries
    retry_kinds: dict[str, list[str]] = field(default_factory=dict)
    failed: list[FailureRecord] = field(default_factory=list)
    quarantined: list[FailureRecord] = field(default_factory=list)
    pool_recycles: int = 0
    tmp_files_removed: int = 0
    wall_s: float = 0.0
    tier: str = "engine"           # which tier actually ran ("engine" |
                                   # "analytic" | "hybrid"; a degraded
                                   # analytic/hybrid sweep reports "engine")
    analytic_points: int = 0       # jobs priced by the analytical fast tier
    frontier_confirmed: int = 0    # hybrid: frontier jobs engine-confirmed
    frontier_jobs: list[str] = field(default_factory=list)  # their labels

    @property
    def ok(self) -> bool:
        return not self.failed

    def failed_jobs(self) -> list[str]:
        return [r.job for r in self.failed]

    def to_dict(self) -> dict:
        d = asdict(self)
        d["ok"] = self.ok
        return d


# --------------------------------------------------------------------------
# Content-addressed result store (checksums + quarantine + tmp GC)

class ResultStore:
    """On-disk result store with integrity checking.

    Entries are JSON envelopes ``{"v": 1, "key": ..., "sha256": ...,
    "payload": {...}}`` written atomically (tmp file + rename).  ``load``
    never returns questionable data: any entry that is unreadable,
    truncated, mis-keyed, checksum-mismatched, or schema-invalid is moved
    to ``<root>/quarantine/`` with a ``<key>.failure.json`` record and
    reported as a miss, so the caller recomputes *and* the corruption is
    visible in `SimRunner.stats` / `SweepReport.quarantined`."""

    def __init__(self, root: pathlib.Path) -> None:
        self.root = pathlib.Path(root)
        self.quarantine_dir = self.root / "quarantine"
        self.quarantines: list[FailureRecord] = []
        self.run_id = ""  # current sweep identity; stamped on quarantines
        self.stats = {"hits": 0, "misses": 0, "stores": 0,
                      "quarantined": 0, "tmp_gc": 0}

    def path(self, key: str) -> pathlib.Path:
        return self.root / f"{key}.json"

    # -- write -------------------------------------------------------------
    @staticmethod
    def _digest(payload: dict) -> str:
        canon = json.dumps(payload, sort_keys=True).encode()
        return hashlib.sha256(canon).hexdigest()

    def store(self, key: str, payload: dict, label: str = "") -> None:
        entry = {"v": STORE_VERSION, "key": key,
                 "sha256": self._digest(payload), "payload": payload}
        self.root.mkdir(parents=True, exist_ok=True)
        p = self.path(key)
        tmp = p.with_suffix(f".tmp{os.getpid()}")
        tmp.write_text(json.dumps(entry))
        faults.fault_point("store", label or key, path=tmp)
        tmp.replace(p)  # atomic: concurrent runs race benignly
        self.stats["stores"] += 1

    # -- read --------------------------------------------------------------
    def load(self, key: str, label: str = "") -> dict | None:
        """The validated payload for ``key``, or None (miss/quarantined)."""
        p = self.path(key)
        if not p.exists():
            self.stats["misses"] += 1
            return None
        reason = None
        entry = None
        try:
            entry = json.loads(p.read_text())
        except (ValueError, OSError) as e:
            reason = f"unparseable JSON ({e})"
        if reason is None:
            reason = self._validate(entry, key)
        if reason is not None:
            self.quarantine(key, reason, label=label)
            self.stats["misses"] += 1
            return None
        self.stats["hits"] += 1
        return entry["payload"]

    @classmethod
    def _validate(cls, entry, key: str) -> str | None:
        if not isinstance(entry, dict):
            return f"entry is {type(entry).__name__}, not an envelope"
        missing = {"v", "key", "sha256", "payload"} - entry.keys()
        if missing:
            return f"envelope missing fields {sorted(missing)}"
        if entry["v"] != STORE_VERSION:
            return f"unknown store version {entry['v']!r}"
        if entry["key"] != key:
            return f"entry is keyed {entry['key']!r}, expected {key!r}"
        if not isinstance(entry["payload"], dict):
            return "payload is not an object"
        if cls._digest(entry["payload"]) != entry["sha256"]:
            return "payload checksum mismatch"
        return None

    # -- quarantine --------------------------------------------------------
    def quarantine(self, key: str, reason: str, label: str = "") -> None:
        """Move ``key``'s entry out of the cache and record why."""
        p = self.path(key)
        self.quarantine_dir.mkdir(parents=True, exist_ok=True)
        size = p.stat().st_size if p.exists() else 0
        if p.exists():
            p.replace(self.quarantine_dir / p.name)
        record = {"key": key, "job": label, "reason": reason,
                  "size_bytes": size, "quarantined_at": time.time(),
                  "quarantined_from": str(p), "run_id": self.run_id}
        (self.quarantine_dir / f"{key}.failure.json").write_text(
            json.dumps(record, indent=1))
        workload, _, rest = label.partition("/")
        design, _, _ = rest.partition("/")
        self.quarantines.append(FailureRecord(
            job=label or key, workload=workload, design=design,
            kind="corrupt", detail=reason, key=key, run_id=self.run_id))
        self.stats["quarantined"] += 1

    # -- tmp-file GC -------------------------------------------------------
    def gc_stale_tmp(self, max_age_s: float = 3600.0) -> int:
        """Remove tmp files abandoned by crashed writers.

        Writers publish via ``<key>.tmp<pid>`` + rename; a writer that dies
        mid-write leaks its tmp file forever.  A tmp file is stale when its
        writer pid no longer exists, or (pid unparseable / recycled) when it
        is older than ``max_age_s``.  Called at sweep startup."""
        removed = 0
        if not self.root.is_dir():
            return 0
        now = time.time()
        for tmp in self.root.glob("*.tmp*"):
            pid_s = tmp.suffix[len(".tmp"):]
            stale = False
            if pid_s.isdigit() and int(pid_s) != os.getpid():
                stale = not _pid_alive(int(pid_s))
            if not stale:
                try:
                    stale = now - tmp.stat().st_mtime > max_age_s
                except OSError:
                    continue  # raced with a concurrent publish
            if stale:
                try:
                    tmp.unlink()
                    removed += 1
                except OSError:
                    pass
        self.stats["tmp_gc"] += removed
        return removed


def _pid_alive(pid: int) -> bool:
    try:
        os.kill(pid, 0)
    except ProcessLookupError:
        return False
    except (PermissionError, OverflowError):
        return True  # exists (another user's), or out of range: be cautious
    return True


# --------------------------------------------------------------------------
# Pool worker entry point (module-level: must pickle by reference)

def _run_job(job: Job, watchdog_max_cycles: int = 0) -> tuple[str, SimConfig, dict]:
    name, cfg = job
    faults.fault_point("run", job_label(job))
    run_cfg = cfg
    if watchdog_max_cycles and not cfg.max_cycles:
        run_cfg = replace(cfg, max_cycles=watchdog_max_cycles)
    # get_workload resolves lazy suites (e.g. traced kernels) in pool workers
    res = simulate(get_workload(name), run_cfg)
    return name, cfg, asdict(res)


# --------------------------------------------------------------------------
# The dispatcher

@dataclass
class _JobState:
    job: Job
    attempts: int = 0
    retries: list[str] = field(default_factory=list)
    failure: FailureRecord | None = None
    done: bool = False
    enqueued_at: float = 0.0       # when the job (re-)entered the ready heap
    submitted_at: float = 0.0      # when its latest attempt hit the pool


class _Dispatcher:
    """Future-per-job process-pool dispatcher with retry/timeout/recycle."""

    def __init__(self, processes: int, sweep: SweepConfig, on_success,
                 metrics: MetricsRegistry | None = None) -> None:
        self.processes = processes
        self.cfg = sweep
        self.on_success = on_success
        self.metrics = metrics or MetricsRegistry()
        self.pool: ProcessPoolExecutor | None = None
        self.pool_recycles = 0

    # -- telemetry ---------------------------------------------------------
    def _mark_submit(self, st: _JobState) -> None:
        st.submitted_at = time.monotonic()
        self.metrics.histogram(
            "sweep_queue_wait_s",
            "seconds jobs waited between ready and pool submit").observe(
            max(st.submitted_at - st.enqueued_at, 0.0))

    # -- pool lifecycle ----------------------------------------------------
    def _fresh_pool(self) -> ProcessPoolExecutor:
        if self.pool is None:
            self.pool = ProcessPoolExecutor(max_workers=self.processes)
        return self.pool

    def _kill_pool(self) -> None:
        """Tear the pool down even if workers are hung or dead."""
        pool = self.pool
        self.pool = None
        if pool is None:
            return
        self.pool_recycles += 1
        procs = list(getattr(pool, "_processes", {}).values())
        try:
            pool.shutdown(wait=False, cancel_futures=True)
        except Exception:
            pass
        for p in procs:
            try:
                p.terminate()
            except Exception:
                pass
        for p in procs:
            try:
                p.join(timeout=5)
            except Exception:
                pass

    # -- bookkeeping -------------------------------------------------------
    def _backoff(self, attempts: int) -> float:
        c = self.cfg
        return min(c.backoff_max_s,
                   c.backoff_base_s * c.backoff_factor ** max(attempts - 1, 0))

    def _charge(self, st: _JobState, kind: str, detail: str) -> bool:
        """Record one failed attempt; True if the job will be retried."""
        st.attempts += 1
        retry = kind in _RETRIABLE and st.attempts < self.cfg.max_attempts
        if retry:
            st.retries.append(kind)
            return True
        name, cfg = st.job
        st.failure = FailureRecord(
            job=job_label(st.job), workload=name, design=cfg.design,
            kind=kind, detail=detail, attempts=st.attempts,
            key=sim_key(name, cfg))
        st.done = True
        return False

    def _classify(self, exc: BaseException) -> tuple[str, str]:
        if isinstance(exc, BrokenProcessPool):
            return "crash", "worker process died (BrokenProcessPool)"
        if isinstance(exc, SimBudgetExceeded):
            return "budget", str(exc)
        return "transient", f"{type(exc).__name__}: {exc}"

    def _succeed(self, st: _JobState, payload: dict) -> None:
        self.metrics.histogram(
            "sweep_job_latency_s",
            "seconds from pool submit to completed simulation").observe(
            max(time.monotonic() - st.submitted_at, 0.0))
        self.on_success(st.job, payload)
        st.done = True

    # -- serial suspect probe ---------------------------------------------
    def _probe(self, st: _JobState, ready, now_seq) -> None:
        """Run one pool-break suspect alone to attribute the crash exactly.

        When a worker dies, every in-flight job fails with
        `BrokenProcessPool` — the culprit is unknown.  Probing each suspect
        serially (one job in flight in a fresh pool) makes the next break
        unambiguous: only the actual crasher is charged a ``crash``
        attempt; innocent bystanders complete here for free."""
        deadline = (time.monotonic() + self.cfg.job_timeout_s
                    if self.cfg.job_timeout_s else None)
        try:
            fut = self._fresh_pool().submit(
                _run_job, st.job, self.cfg.watchdog_max_cycles)
        except BrokenProcessPool:
            self._kill_pool()
            if self._charge(st, "crash", "pool broke on submit"):
                self._requeue(st, ready, now_seq)
            return
        self._mark_submit(st)
        timeout = None if deadline is None else max(
            deadline - time.monotonic(), 0.0)
        done, _ = wait([fut], timeout=timeout)
        if not done:  # the suspect hangs: kill it, charge a timeout
            self._kill_pool()
            if self._charge(st, "timeout",
                            f"exceeded job_timeout_s="
                            f"{self.cfg.job_timeout_s}s (serial probe)"):
                self._requeue(st, ready, now_seq)
            return
        exc = fut.exception()
        if exc is None:
            self._succeed(st, fut.result()[2])
            return
        kind, detail = self._classify(exc)
        if kind == "crash":
            self._kill_pool()
        if self._charge(st, kind, detail):
            self._requeue(st, ready, now_seq)

    def _requeue(self, st: _JobState, ready, now_seq) -> None:
        seq = next(now_seq)
        st.enqueued_at = time.monotonic()
        heapq.heappush(
            ready, (st.enqueued_at + self._backoff(st.attempts), seq, st))

    # -- main loop ---------------------------------------------------------
    def run(self, jobs: list[Job]) -> tuple[list[_JobState], int]:
        t0 = time.monotonic()
        states = [_JobState(job=j, enqueued_at=t0) for j in jobs]
        seq_counter = iter(range(1, 1 << 30))
        ready: list[tuple[float, int, _JobState]] = [
            (0.0, -len(states) + i, st) for i, st in enumerate(states)]
        heapq.heapify(ready)
        inflight: dict[Future, tuple[_JobState, float]] = {}

        try:
            while ready or inflight:
                now = time.monotonic()
                # submit ready jobs, at most one per worker (so a submit
                # time approximates a start time for the timeout clock,
                # and a pool break loses at most `processes` jobs)
                while ready and ready[0][0] <= now \
                        and len(inflight) < self.processes:
                    _, _, st = heapq.heappop(ready)
                    deadline = (now + self.cfg.job_timeout_s
                                if self.cfg.job_timeout_s else float("inf"))
                    try:
                        fut = self._fresh_pool().submit(
                            _run_job, st.job, self.cfg.watchdog_max_cycles)
                    except BrokenProcessPool:
                        self._kill_pool()
                        if self._charge(st, "crash", "pool broke on submit"):
                            self._requeue(st, ready, seq_counter)
                        continue
                    self._mark_submit(st)
                    inflight[fut] = (st, deadline)
                if not inflight:
                    if ready:
                        time.sleep(max(ready[0][0] - time.monotonic(), 0.0))
                    continue

                next_deadline = min(dl for _, dl in inflight.values())
                next_ready = ready[0][0] if ready else float("inf")
                timeout = min(next_deadline, next_ready) - time.monotonic()
                done, _ = wait(
                    inflight,
                    timeout=None if timeout == float("inf")
                    else max(timeout, 0.01),
                    return_when=FIRST_COMPLETED)

                pool_broke = False
                for fut in done:
                    st, _ = inflight.pop(fut)
                    exc = fut.exception()
                    if exc is None:
                        self._succeed(st, fut.result()[2])
                        continue
                    kind, detail = self._classify(exc)
                    if kind == "crash":
                        # suspect: attribution happens in the serial probes
                        pool_broke = True
                        inflight[fut] = (st, float("inf"))
                        continue
                    if self._charge(st, kind, detail):
                        self._requeue(st, ready, seq_counter)

                now = time.monotonic()
                overdue = {fut for fut, (st, dl) in inflight.items()
                           if dl <= now and not fut.done()}
                if pool_broke or overdue:
                    suspects = sorted((st for st, _ in inflight.values()),
                                      key=lambda st: job_label(st.job))
                    timed_out = {id(st) for fut, (st, _) in inflight.items()
                                 if fut in overdue}
                    inflight.clear()
                    self._kill_pool()
                    for st in suspects:
                        if id(st) not in timed_out:
                            continue
                        if self._charge(
                                st, "timeout",
                                f"exceeded job_timeout_s="
                                f"{self.cfg.job_timeout_s}s"):
                            self._requeue(st, ready, seq_counter)
                    for st in suspects:
                        if st.done or id(st) in timed_out:
                            continue
                        if pool_broke:
                            # this job re-executes because a worker died; the
                            # re-run is visible in the report (an uncharged
                            # "crash" retry) whether or not this job was the
                            # culprit — the serial probe below settles blame.
                            st.retries.append("crash")
                            self._probe(st, ready, seq_counter)
                        else:
                            # innocent casualty of a timeout recycle: its
                            # worker was killed through no fault of its own.
                            # Requeue without charging an attempt.
                            self._requeue(st, ready, seq_counter)
        finally:
            if self.pool is not None:
                self.pool.shutdown(wait=True, cancel_futures=True)
                self.pool = None
        return states, self.pool_recycles


# --------------------------------------------------------------------------
# The runner

class SimRunner:
    """Memoizing, disk-backed, fault-tolerant simulation runner."""

    def __init__(self, processes: int | None = None,
                 disk_cache: bool = True,
                 cache_dir: pathlib.Path | None = None,
                 sweep: SweepConfig | None = None,
                 batch: bool | None = None,
                 tier: str = "engine") -> None:
        if tier not in TIERS:
            raise ValueError(f"unknown tier {tier!r}; expected one of {TIERS}")
        self.processes = processes if processes is not None else default_processes()
        self.disk_cache = disk_cache
        self.cache_dir = pathlib.Path(cache_dir) if cache_dir else SIMCACHE
        self.store = ResultStore(self.cache_dir)
        self.sweep_config = sweep or SweepConfig()
        # Batch-engine policy: True/False force it, None defers to the
        # REPRO_SIM_BATCH env var ("1"/"0"), else auto — batch large
        # cache-miss sweeps when there is no process pool to lean on.
        self.batch = batch
        # Default tier for `prefill` (a per-call override wins).  The
        # analytical tier has its own memo + disk keys (`analytic_sim_key`)
        # so estimates can never shadow engine results.
        self.tier = tier
        self._analytic_memo: dict[Job, AnalyticResult] = {}
        self._calibration: Calibration | None = None
        self._calib_degraded = False
        self._calib_failure: FailureRecord | None = None
        self._calib_reported = False
        self._memo: dict[Job, SimResult] = {}
        self.failures: dict[Job, FailureRecord] = {}
        # Operational telemetry (repro.obs.metrics): counters/histograms
        # accumulated across every prefill/sim of this runner's lifetime;
        # snapshot with `metrics_snapshot` (JSON) or `metrics.to_prometheus`.
        self.metrics = MetricsRegistry()
        self.last_run_id = ""
        self.stats = {"memo_hits": 0, "disk_hits": 0, "computed": 0,
                      "batched": 0, "retried": 0, "failed": 0,
                      "quarantined": 0, "pool_recycles": 0, "tmp_gc": 0,
                      "analytic_memo_hits": 0, "analytic_disk_hits": 0,
                      "analytic_computed": 0, "calib_degraded": 0}
        if self.disk_cache:
            # sweep startup garbage-collects tmp files leaked by writers
            # that crashed mid-publish
            self.stats["tmp_gc"] += self.store.gc_stale_tmp()

    # -- cache layers ------------------------------------------------------
    def _disk_path(self, job: Job) -> pathlib.Path:
        return self.store.path(sim_key(*job))

    def _disk_load(self, job: Job) -> SimResult | None:
        if not self.disk_cache:
            return None
        key = sim_key(*job)
        label = job_label(job)
        payload = self.store.load(key, label=label)
        if payload is None:
            self._sync_quarantines()
            return None
        try:
            return SimResult(**payload)
        except TypeError as e:
            # checksummed envelope, but the payload is not a SimResult
            # (wrong-schema entry): quarantine, recompute
            self.store.quarantine(key, f"payload schema mismatch ({e})",
                                  label=label)
            self._sync_quarantines()
            return None

    def _disk_store(self, job: Job, res: SimResult) -> None:
        if not self.disk_cache:
            return
        self.store.store(sim_key(*job), asdict(res), label=job_label(job))

    def _sync_quarantines(self) -> None:
        self.stats["quarantined"] = self.store.stats["quarantined"]

    def _lookup(self, job: Job) -> SimResult | None:
        res = self._memo.get(job)
        if res is not None:
            self.stats["memo_hits"] += 1
            self.metrics.counter("sweep_cache_hits_total",
                                 "memo/disk cache hits").inc()
            return res
        res = self._disk_load(job)
        if res is not None:
            self.stats["disk_hits"] += 1
            self.metrics.counter("sweep_cache_hits_total",
                                 "memo/disk cache hits").inc()
            self._memo[job] = res
        else:
            self.metrics.counter("sweep_cache_misses_total",
                                 "memo/disk cache misses").inc()
        return res

    # -- analytical fast tier ----------------------------------------------
    def calibration(self) -> Calibration:
        """The calibration the analytical tier prices with.

        Loads ``<cache_dir>/analytic_calib.json`` once per runner; a missing
        file falls back to the built-in fit, a *corrupt* file is quarantined
        through the ResultStore machinery and flips the runner into degraded
        mode (analytic/hybrid prefills run engine-only from then on)."""
        if self._calibration is not None:
            return self._calibration
        path = self.store.path(CALIBRATION_KEY)
        try:
            calib = load_calibration(path) if self.disk_cache else None
        except CalibrationError as e:
            self.store.quarantine(CALIBRATION_KEY, f"calibration: {e}",
                                  label=CALIBRATION_KEY)
            self._sync_quarantines()
            self._calib_degraded = True
            self.stats["calib_degraded"] = 1
            self._calib_failure = self.store.quarantines[-1]
            calib = None
        self._calibration = calib or DEFAULT_CALIBRATION
        return self._calibration

    def _analytic_key(self, job: Job) -> str:
        return analytic_sim_key(*job, self.calibration())

    def estimate(self, workload, cfg: SimConfig) -> AnalyticResult:
        """One analytical estimate through its own memo/disk cache.

        Estimates are keyed by `analytic_sim_key` (tagged with
        `ANALYTIC_REV`/`CALIB_REV` and the calibration fingerprint), so they
        can never collide with engine `sim_key` entries."""
        name = workload if isinstance(workload, str) else workload.name
        job = (name, cfg)
        res = self._analytic_memo.get(job)
        if res is not None:
            self.stats["analytic_memo_hits"] += 1
            return res
        key = self._analytic_key(job)
        if self.disk_cache:
            payload = self.store.load(key, label="analytic:" + job_label(job))
            if payload is not None:
                payload.pop("ipc", None)   # derived, re-exposed as a property
                try:
                    res = AnalyticResult(**payload)
                except TypeError as e:
                    self.store.quarantine(
                        key, f"analytic payload schema mismatch ({e})",
                        label="analytic:" + job_label(job))
                    self._sync_quarantines()
                    res = None
                else:
                    self.stats["analytic_disk_hits"] += 1
                    self._analytic_memo[job] = res
                    return res
        res = analytic_estimate(get_workload(name), cfg,
                                calib=self.calibration())
        self.stats["analytic_computed"] += 1
        self._analytic_memo[job] = res
        if self.disk_cache:
            self.store.store(key, res.to_dict(),
                             label="analytic:" + job_label(job))
        return res

    # -- public API --------------------------------------------------------
    def sim(self, workload, cfg: SimConfig) -> SimResult:
        """One simulation through the memo/disk cache (inline on miss)."""
        name = workload if isinstance(workload, str) else workload.name
        job = (name, cfg)
        res = self._lookup(job)
        if res is None:
            self.stats["computed"] += 1
            _, _, payload = _run_job(job, self.sweep_config.watchdog_max_cycles)
            res = SimResult(**payload)
            self._memo[job] = res
            self._disk_store(job, res)
        return res

    def try_sim(self, workload, cfg: SimConfig) -> SimResult | None:
        """`sim`, degraded: None for jobs that already failed this sweep or
        fail inline — the caller annotates the missing point and goes on."""
        name = workload if isinstance(workload, str) else workload.name
        job = (name, cfg)
        if job in self.failures:
            return None
        try:
            return self.sim(name, cfg)
        except Exception as e:  # noqa: BLE001 - degrade, don't crash sweeps
            self.failures[job] = FailureRecord(
                job=job_label(job), workload=name, design=cfg.design,
                kind="budget" if isinstance(e, SimBudgetExceeded)
                else "transient",
                detail=f"{type(e).__name__}: {e}", attempts=1,
                key=sim_key(name, cfg))
            self.stats["failed"] = len(self.failures)
            return None

    def sim_gpu(self, workload, cfg: SimConfig) -> GpuResult:
        """One whole-GPU simulation: the per-SM jobs go through the memo /
        disk cache (and the pool, if several SMs miss), then aggregate."""
        name = workload if isinstance(workload, str) else workload.name
        jobs = [(name, c) for c in per_sm_configs(cfg)]
        self.prefill(jobs, tier="engine")   # aggregation needs real results
        return aggregate(cfg, [self.sim(*job) for job in jobs], name)

    def prefill_gpu(self, jobs: list[Job]) -> SweepReport:
        """Expand whole-GPU jobs into their per-SM jobs and prefill those."""
        return self.prefill([(name, c) for name, cfg in jobs
                             for c in per_sm_configs(cfg)], tier="engine")

    def prefill(self, jobs: list[Job], tier: str | None = None,
                top_k: int = DEFAULT_TOP_K) -> SweepReport:
        """Execute a sweep at the requested tier (default: the runner's).

        * ``"engine"`` — classic path: every cache-missing job is
          cycle-accurately simulated across the process pool.
        * ``"analytic"`` — every supported job is priced by the closed-form
          model in `repro.sim.analytic` (microseconds/point, own cache
          keys); unsupported jobs fall through to the engine.
        * ``"hybrid"`` — analytic screening pass, then the per-workload
          Pareto frontier (est. cycles × est. MRF accesses) plus the
          ``top_k`` best-cycle points are *confirmed* by the engine, so
          every frontier verdict is a real `SimResult`.

        A corrupt calibration file degrades analytic/hybrid to engine-only
        (the quarantine is attached to the report).  Never raises on job
        failure: check ``report.ok``."""
        tier = tier or self.tier
        if tier not in TIERS:
            raise ValueError(f"unknown tier {tier!r}; expected one of {TIERS}")
        if tier != "engine":
            self.calibration()          # may flip the degraded flag
            if self._calib_degraded:
                report = self._prefill_engine(jobs)
                report.tier = "engine"
                if not self._calib_reported and self._calib_failure:
                    report.quarantined.insert(0, self._calib_failure)
                    self._calib_reported = True
                return report
        if tier == "analytic":
            return self._prefill_analytic(jobs)
        if tier == "hybrid":
            return self._prefill_hybrid(jobs, top_k=top_k)
        return self._prefill_engine(jobs)

    def _prefill_engine(self, jobs: list[Job]) -> SweepReport:
        """Execute all cache-missing jobs across the process pool.

        Never raises on job failure: faults are retried/recorded per
        `SweepConfig` and the returned `SweepReport` says exactly what
        completed, what was retried, what was quarantined, and what is
        missing.  Callers that need hard failure check ``report.ok``."""
        t0 = time.time()
        q_before = self.store.stats["quarantined"]
        run_id = sweep_run_id(jobs)
        self.last_run_id = self.store.run_id = run_id
        misses: list[Job] = []
        seen: set[Job] = set()
        for job in jobs:
            if job in seen:
                continue
            seen.add(job)
            if self._lookup(job) is None:
                misses.append(job)
        report = SweepReport(run_id=run_id, total=len(seen),
                             cached=len(seen) - len(misses))
        batch_states: list[_JobState] = []
        if misses:
            mode = self._batch_mode()
            if mode in ("on", "auto"):
                misses, batch_states = self._prefill_batch(
                    misses,
                    min_jobs=(_auto_batch_threshold() if mode == "auto"
                              else 1))
            if misses:
                if self.processes <= 1 or len(misses) == 1:
                    self._prefill_inline(misses, report)
                else:
                    self._prefill_pool(misses, report)
        # the classic backends reset report.computed before recording their
        # own outcomes, so batch outcomes are folded in afterwards
        self._record_outcomes(batch_states, report)
        report.quarantined = list(
            self.store.quarantines[q_before:])
        report.completed = report.cached + report.computed
        report.tmp_files_removed = self.stats["tmp_gc"]
        report.wall_s = round(time.time() - t0, 3)
        self._sync_quarantines()
        self.stats["retried"] += sum(report.retried.values())
        self.stats["failed"] = len(self.failures)
        self.stats["pool_recycles"] += report.pool_recycles
        m = self.metrics
        m.counter("sweep_jobs_total", "unique jobs requested").inc(report.total)
        m.counter("sweep_jobs_cached",
                  "jobs served from memo/disk cache").inc(report.cached)
        m.counter("sweep_jobs_computed",
                  "jobs simulated").inc(report.computed)
        m.counter("sweep_jobs_failed",
                  "jobs with no result after retries").inc(len(report.failed))
        m.counter("sweep_retries_total",
                  "retried job attempts").inc(sum(report.retried.values()))
        m.counter("sweep_pool_recycles_total",
                  "process-pool teardowns").inc(report.pool_recycles)
        m.counter("sweep_quarantined_total",
                  "cache entries quarantined").inc(len(report.quarantined))
        return report

    def _split_supported(self, jobs: list[Job]) -> tuple[list[Job], list[Job]]:
        """Dedup, then split into (analytic-supported, engine-only) jobs."""
        seen: set[Job] = set()
        supported: list[Job] = []
        engine_only: list[Job] = []
        for job in jobs:
            if job in seen:
                continue
            seen.add(job)
            (supported if analytic_supported(job[1]) else engine_only).append(job)
        return supported, engine_only

    @staticmethod
    def _merge_nested(report: SweepReport, nested: SweepReport,
                      count_jobs: bool = True) -> None:
        """Fold an engine sub-sweep's outcomes into a tiered report.

        ``count_jobs=False`` merges only the engine *activity* (cache hits,
        compute, retries, faults) — used for hybrid confirmation sweeps,
        whose jobs were already counted once as analytic estimates."""
        if count_jobs:
            report.total += nested.total
            report.completed += nested.completed
        report.cached += nested.cached
        report.computed += nested.computed
        for label, n in nested.retried.items():
            report.retried[label] = report.retried.get(label, 0) + n
        report.retry_kinds.update(nested.retry_kinds)
        report.failed.extend(nested.failed)
        report.quarantined.extend(nested.quarantined)
        report.pool_recycles += nested.pool_recycles

    def _estimate_jobs(self, jobs: list[Job],
                       report: SweepReport) -> dict[Job, AnalyticResult]:
        """Price `jobs` analytically; failures degrade per-job, like
        `try_sim` — a structured FailureRecord, not a crashed sweep."""
        q_before = len(self.store.quarantines)
        out: dict[Job, AnalyticResult] = {}
        for job in jobs:
            try:
                out[job] = self.estimate(*job)
            except Exception as e:  # noqa: BLE001 - degrade, don't crash
                report.failed.append(FailureRecord(
                    job=job_label(job), workload=job[0], design=job[1].design,
                    kind="transient",
                    detail=f"analytic {type(e).__name__}: {e}", attempts=1,
                    key=self._analytic_key(job)))
        report.quarantined.extend(self.store.quarantines[q_before:])
        report.analytic_points = len(out)
        report.completed += len(out)
        return out

    def _prefill_analytic(self, jobs: list[Job]) -> SweepReport:
        """Screen every supported job with the closed-form model; jobs the
        model cannot price (multi-SM, unknown designs) go to the engine."""
        t0 = time.time()
        supported, engine_only = self._split_supported(jobs)
        run_id = sweep_run_id(jobs)
        self.last_run_id = self.store.run_id = run_id
        report = SweepReport(run_id=run_id, total=len(supported),
                             tier="analytic")
        self._estimate_jobs(supported, report)
        if engine_only:
            self._merge_nested(report, self._prefill_engine(engine_only))
        self.last_run_id = self.store.run_id = run_id
        report.wall_s = round(time.time() - t0, 3)
        return report

    def _prefill_hybrid(self, jobs: list[Job],
                        top_k: int = DEFAULT_TOP_K) -> SweepReport:
        """Analytic screening, engine confirmation of the interesting points.

        Per workload, the engine confirms the analytic Pareto frontier over
        (estimated cycles, estimated MRF accesses) plus the `top_k` lowest
        estimated-cycle points; everything else keeps its fast estimate.
        Confirmed results come from `_prefill_engine`, i.e. the ordinary
        cache/retry machinery — `sim()` replays them bit-identically."""
        t0 = time.time()
        supported, engine_only = self._split_supported(jobs)
        run_id = sweep_run_id(jobs)
        self.last_run_id = self.store.run_id = run_id
        report = SweepReport(run_id=run_id, total=len(supported),
                             tier="hybrid")
        ests = self._estimate_jobs(supported, report)
        by_workload: dict[str, list[Job]] = {}
        for job in ests:
            by_workload.setdefault(job[0], []).append(job)
        confirm: list[Job] = []
        for group in by_workload.values():
            pts = [(float(ests[j].cycles), float(ests[j].est_mrf_accesses))
                   for j in group]
            picked = set(pareto_frontier(pts))
            for i in sorted(range(len(group)), key=lambda i: pts[i][0])[:top_k]:
                picked.add(i)
            confirm.extend(group[i] for i in sorted(picked))
        if confirm:
            nested = self._prefill_engine(confirm)
            self._merge_nested(report, nested, count_jobs=False)
            report.frontier_jobs = sorted(job_label(j) for j in confirm)
            report.frontier_confirmed = sum(
                1 for j in confirm if self._lookup(j) is not None)
        if engine_only:
            self._merge_nested(report, self._prefill_engine(engine_only))
        self.last_run_id = self.store.run_id = run_id
        report.wall_s = round(time.time() - t0, 3)
        return report

    def metrics_snapshot(self) -> dict:
        """JSON-ready metrics snapshot, stamped with the last sweep's
        ``run_id`` and the runner's layered-cache stats."""
        return self.metrics.snapshot(run_id=self.last_run_id,
                                     runner_stats=dict(self.stats))

    # -- dispatch backends -------------------------------------------------
    def _batch_mode(self) -> str:
        """'on' | 'auto' | 'off'.  Fault-injection plans force 'off': the
        chaos harness targets the per-job classic paths (fault points,
        retries, pool recycles), which the vectorized engine bypasses.

        'auto' engages the batch engine above a platform-dependent
        supported-miss threshold (`_auto_batch_threshold`): a low bar on
        parallel backends, a compile-amortizing bar on CPU — where the
        BATCH_REV 2 fused tick beats the event-heap engine in steady state
        (the measured `batch_engine` verdict in BENCH_sim.json) but cold
        XLA compilation still costs tens of seconds per shape bucket."""
        if faults.active_plan() is not None:
            return "off"
        if self.batch is True:
            return "on"
        if self.batch is False:
            return "off"
        env = os.environ.get("REPRO_SIM_BATCH", "")
        if env == "1":
            return "on"
        if env == "0":
            return "off"
        return "auto"

    def _prefill_batch(self, misses: list[Job],
                       min_jobs: int = 1) -> tuple[list[Job], list[_JobState]]:
        """Run the batch-supported misses through the vectorized engine.

        Returns (jobs left for the classic backends, completed job states).
        Any whole-batch failure (jax unavailable, engine bug) degrades to
        the classic path with every job intact — the batch engine is an
        accelerator, never a new single point of failure."""
        from repro.sim.batch import batch_supported, run_batch

        supported = [j for j in misses if batch_supported(j[1])]
        if len(supported) < min_jobs:
            return misses, []
        rest = [j for j in misses if not batch_supported(j[1])]
        wd = self.sweep_config.watchdog_max_cycles
        t0 = time.monotonic()
        run_jobs = []
        for name, cfg in supported:
            run_cfg = cfg
            if wd and not cfg.max_cycles:
                run_cfg = replace(cfg, max_cycles=wd)
            run_jobs.append((get_workload(name), run_cfg))
        try:
            outcomes = run_batch(run_jobs)
        except Exception:  # noqa: BLE001 - degrade to the classic backends
            return misses, []
        per_job = max(time.monotonic() - t0, 0.0) / len(supported)
        states: list[_JobState] = []
        for job, out in zip(supported, outcomes):
            st = _JobState(job=job, attempts=1, done=True)
            if isinstance(out, SimBudgetExceeded):
                name, cfg = job
                # deterministic, like the classic budget outcome: no retry
                st.failure = FailureRecord(
                    job=job_label(job), workload=name, design=cfg.design,
                    kind="budget", detail=f"SimBudgetExceeded: {out}",
                    attempts=1, key=sim_key(name, cfg))
            else:
                self._memo[job] = out
                self._disk_store(job, out)
                self.stats["computed"] += 1
                self.stats["batched"] += 1
                self.metrics.histogram(
                    "sweep_job_latency_s",
                    "seconds from pool submit to completed simulation"
                ).observe(per_job)
                self.metrics.histogram(
                    "sweep_queue_wait_s",
                    "seconds jobs waited between ready and pool submit"
                ).observe(0.0)
            states.append(st)
        return rest, states

    def _record_outcomes(self, states, report: SweepReport) -> None:
        for st in states:
            if st.retries:
                report.retried[job_label(st.job)] = len(st.retries)
                report.retry_kinds[job_label(st.job)] = list(st.retries)
            if st.failure is not None:
                st.failure.run_id = report.run_id
                report.failed.append(st.failure)
                self.failures[st.job] = st.failure
            else:
                report.computed += 1

    def _prefill_inline(self, misses: list[Job], report: SweepReport) -> None:
        """Serial fallback (processes <= 1): retries transient/budget-style
        exceptions in-process; crash/hang protection needs the pool path."""
        cfgd = self.sweep_config
        states = []
        for job in misses:
            st = _JobState(job=job, enqueued_at=time.monotonic())
            states.append(st)
            while not st.done:
                st.submitted_at = time.monotonic()
                self.metrics.histogram(
                    "sweep_queue_wait_s",
                    "seconds jobs waited between ready and pool submit"
                ).observe(max(st.submitted_at - st.enqueued_at, 0.0))
                try:
                    _, _, payload = _run_job(job, cfgd.watchdog_max_cycles)
                except Exception as e:  # noqa: BLE001 - classified below
                    kind = ("budget" if isinstance(e, SimBudgetExceeded)
                            else "transient")
                    retry = kind in _RETRIABLE \
                        and st.attempts + 1 < cfgd.max_attempts
                    st.attempts += 1
                    if retry:
                        st.retries.append(kind)
                        time.sleep(min(cfgd.backoff_max_s,
                                       cfgd.backoff_base_s
                                       * cfgd.backoff_factor
                                       ** (st.attempts - 1)))
                        continue
                    name, cfg = job
                    st.failure = FailureRecord(
                        job=job_label(job), workload=name, design=cfg.design,
                        kind=kind, detail=f"{type(e).__name__}: {e}",
                        attempts=st.attempts, key=sim_key(name, cfg))
                    st.done = True
                else:
                    self.metrics.histogram(
                        "sweep_job_latency_s",
                        "seconds from pool submit to completed simulation"
                    ).observe(max(time.monotonic() - st.submitted_at, 0.0))
                    res = SimResult(**payload)
                    self._memo[job] = res
                    self._disk_store(job, res)
                    self.stats["computed"] += 1
                    st.done = True
        report.computed = 0
        self._record_outcomes(states, report)

    def _prefill_pool(self, misses: list[Job], report: SweepReport) -> None:
        def on_success(job: Job, payload: dict) -> None:
            res = SimResult(**payload)
            self._memo[job] = res
            self._disk_store(job, res)
            self.stats["computed"] += 1

        dispatcher = _Dispatcher(self.processes, self.sweep_config, on_success,
                                 metrics=self.metrics)
        states, recycles = dispatcher.run(misses)
        report.pool_recycles = recycles
        report.computed = 0
        self._record_outcomes(states, report)


_DEFAULT: SimRunner | None = None


def default_runner() -> SimRunner:
    """Process-wide shared runner (memo survives across figure functions)."""
    global _DEFAULT
    if _DEFAULT is None:
        _DEFAULT = SimRunner()
    return _DEFAULT
