"""Deterministic fault injection for the sweep service (chaos harness).

The sweep dispatcher (`repro.serving.sweep`) is fault-*tolerant* code; this
module makes its failure paths *testable* without flaky timing tricks: a
fault plan designates specific jobs (by label substring) and makes them
raise, kill their worker process, hang, or corrupt their cache entry — all
deterministically, so the chaos suite (`tests/test_sweep_faults.py`) and
the CI ``bench_sim --chaos-smoke`` step can assert exact `SweepReport`
contents.

Plans cross the process-pool boundary through the environment: set
``REPRO_FAULT_PLAN`` to the path of a JSON plan file before the pool is
created and every worker consults it at each fault point.  With the
variable unset (production), `fault_point` is a near-free no-op.

Plan format::

    {"faults": [
        {"match": "kmeans/BL/seed3", "stage": "run",   "action": "raise",
         "times": 2},
        {"match": "bfs/LTRF/seed0",  "stage": "run",   "action": "exit"},
        {"match": "nw/BL/seed1",     "stage": "run",   "action": "hang",
         "seconds": 60},
        {"match": "srad/LTRF/seed2", "stage": "store", "action": "corrupt"}
    ]}

* ``match``   — substring of the job label (``workload/design/seed<N>``)
  or of the store key, depending on the stage.
* ``stage``   — ``run`` (inside the worker, before simulating) or
  ``store`` (in the writer, after the cache tmp file is written but
  before it is atomically published — a crashed-mid-write torn entry).
* ``action``  — ``raise`` (a transient `InjectedFault`), ``exit``
  (``os._exit``: the worker dies, the pool breaks), ``hang``
  (sleep ``seconds``, default 3600 — exercises the wall-clock timeout),
  ``corrupt`` (truncate the just-written file to half its bytes).
* ``times``   — fire at most N times per plan file (default: unlimited).
  Attempt counting is cross-process: each firing atomically claims a
  marker file under ``<plan>.state/`` via ``O_CREAT|O_EXCL``, so retried
  jobs in fresh pool workers see a consistent countdown.
"""
from __future__ import annotations

import json
import os
import pathlib
import time
from dataclasses import dataclass, field

ENV_PLAN = "REPRO_FAULT_PLAN"

STAGES = ("run", "store")
ACTIONS = ("raise", "exit", "hang", "corrupt")

EXIT_CODE = 17      # the injected worker-crash exit status
HANG_S = 3600.0     # default hang duration (killed by the pool recycler)


class InjectedFault(RuntimeError):
    """A deliberately injected transient job failure."""


@dataclass
class FaultSpec:
    match: str
    action: str
    stage: str = "run"
    times: int | None = None     # None = unlimited
    seconds: float = HANG_S
    fault_id: str = ""

    def __post_init__(self) -> None:
        if self.stage not in STAGES:
            raise ValueError(f"unknown fault stage {self.stage!r}")
        if self.action not in ACTIONS:
            raise ValueError(f"unknown fault action {self.action!r}")


@dataclass
class FaultPlan:
    """A parsed fault plan + its cross-process firing-state directory."""
    specs: list[FaultSpec] = field(default_factory=list)
    state_dir: pathlib.Path | None = None

    @classmethod
    def parse(cls, doc: dict, state_dir: pathlib.Path | None) -> "FaultPlan":
        specs = []
        for i, raw in enumerate(doc.get("faults", ())):
            raw = dict(raw)
            raw.setdefault("fault_id", f"f{i}")
            specs.append(FaultSpec(**raw))
        return cls(specs=specs, state_dir=state_dir)

    def _claim(self, spec: FaultSpec) -> bool:
        """Atomically claim one firing of ``spec`` (False once exhausted)."""
        if spec.times is None:
            return True
        if self.state_dir is None:
            return False
        self.state_dir.mkdir(parents=True, exist_ok=True)
        for n in range(spec.times):
            marker = self.state_dir / f"{spec.fault_id}.hit{n}"
            try:
                os.close(os.open(marker, os.O_CREAT | os.O_EXCL | os.O_WRONLY))
                return True
            except FileExistsError:
                continue  # this firing already happened (possibly elsewhere)
        return False

    def fire(self, stage: str, label: str, path=None) -> None:
        for spec in self.specs:
            if spec.stage != stage or spec.match not in label:
                continue
            if not self._claim(spec):
                continue
            if spec.action == "raise":
                raise InjectedFault(
                    f"injected fault at {stage}: {label}")
            if spec.action == "exit":
                os._exit(EXIT_CODE)
            if spec.action == "hang":
                time.sleep(spec.seconds)
            elif spec.action == "corrupt" and path is not None:
                _truncate(pathlib.Path(path))


def _truncate(path: pathlib.Path) -> None:
    """Tear the file in half — a crashed-mid-write cache entry."""
    data = path.read_bytes()
    path.write_bytes(data[: len(data) // 2])


# Plan cache: keyed by (path, mtime_ns) so tests rewriting the plan file in
# place are picked up, while the common no-plan case stays one getenv call.
_CACHE: dict[tuple[str, int], FaultPlan] = {}


def active_plan() -> FaultPlan | None:
    """The plan named by ``REPRO_FAULT_PLAN``, or None (the default)."""
    path = os.environ.get(ENV_PLAN)
    if not path:
        return None
    p = pathlib.Path(path)
    try:
        key = (path, p.stat().st_mtime_ns)
    except OSError:
        return None
    plan = _CACHE.get(key)
    if plan is None:
        plan = FaultPlan.parse(json.loads(p.read_text()),
                               state_dir=p.with_suffix(p.suffix + ".state"))
        _CACHE.clear()  # one live plan at a time; drop stale mtimes
        _CACHE[key] = plan
    return plan


def fault_point(stage: str, label: str, path=None) -> None:
    """Consult the active fault plan at a named execution point.

    No-op unless ``REPRO_FAULT_PLAN`` is set.  ``path`` is the file a
    ``store``-stage ``corrupt`` action mutilates."""
    plan = active_plan()
    if plan is not None:
        plan.fire(stage, label, path=path)
