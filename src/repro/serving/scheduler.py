"""Two-level request scheduler (the paper's warp scheduler, for serving).

Requests mirror warps:
  * a bounded **active set** (the paper's 8 active warps) holds requests with
    KV pages resident ("register cache" space);
  * **inactive** requests wait in an admission queue; when a request finishes
    or is preempted, the scheduler *activates* a waiting one — paying the
    page-allocation (prefetch) cost then, not on the decode critical path;
  * preemption on page exhaustion writes nothing back (pages are the source
    of truth), matching LTRF+'s "only live state moves".
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field

from .allocator import AddressAllocationUnit

PAGE_TOKENS = 256


@dataclass
class Request:
    rid: int
    prompt_len: int
    max_new_tokens: int
    generated: int = 0
    pages: list[int] = field(default_factory=list)
    state: str = "waiting"  # waiting | active | finished | preempted

    @property
    def tokens(self) -> int:
        return self.prompt_len + self.generated

    def pages_needed(self) -> int:
        return -(-max(self.tokens, 1) // PAGE_TOKENS)


@dataclass
class TwoLevelScheduler:
    aau: AddressAllocationUnit
    active_slots: int = 8
    active: list[Request] = field(default_factory=list)
    waiting: list[Request] = field(default_factory=list)
    finished: list[Request] = field(default_factory=list)
    preemptions: int = 0
    _ids: itertools.count = field(default_factory=itertools.count)

    def submit(self, prompt_len: int, max_new_tokens: int) -> Request:
        r = Request(rid=next(self._ids), prompt_len=prompt_len,
                    max_new_tokens=max_new_tokens)
        self.waiting.append(r)
        return r

    # -- page management ------------------------------------------------------
    def _grow(self, r: Request) -> bool:
        """Ensure ``r`` owns enough pages; False if the pool is exhausted."""
        while len(r.pages) < r.pages_needed():
            slot = self.aau.alloc(owner=r.rid)
            if slot is None:
                return False
            r.pages.append(slot)
        return True

    def _release(self, r: Request) -> None:
        for p in r.pages:
            self.aau.free(p)
        r.pages = []

    # -- scheduling ------------------------------------------------------------
    def admit(self) -> list[Request]:
        """Activate waiting requests while slots + pages allow."""
        admitted = []
        while self.waiting and len(self.active) < self.active_slots:
            r = self.waiting[0]
            if not self._grow(r):
                self._release(r)
                break  # page pool exhausted; try again after completions
            self.waiting.pop(0)
            r.state = "active"
            self.active.append(r)
            admitted.append(r)
        return admitted

    def step(self) -> list[Request]:
        """One decode step for the active batch; returns finished requests."""
        done = []
        for r in list(self.active):
            r.generated += 1
            if not self._grow(r):
                # page exhaustion mid-flight: preempt the *youngest* request
                victim = max(self.active, key=lambda q: q.rid)
                victim.state = "preempted"
                self.preemptions += 1
                self._release(victim)
                self.active.remove(victim)
                self.waiting.insert(0, victim)
                victim.generated = 0  # will re-prefill on activation
                if victim is r:
                    continue
            if r.generated >= r.max_new_tokens:
                r.state = "finished"
                self._release(r)
                self.active.remove(r)
                self.finished.append(r)
                done.append(r)
        self.admit()
        return done

    def run_to_completion(self, max_steps: int = 100_000) -> int:
        self.admit()
        steps = 0
        while (self.active or self.waiting) and steps < max_steps:
            self.step()
            steps += 1
            if not self.active and self.waiting:
                # nothing admissible: a single waiting request larger than
                # the pool would deadlock; fail loudly instead
                if not self.admit():
                    raise RuntimeError("page pool too small for request")
        return steps
