"""Batched serving engine: continuous batching over the unified decode step.

Couples the two-level request scheduler (paged KV via the Address Allocation
Unit) with the jitted `decode_step`.  The device-side cache is a dense
(L, B_slots, S_max, kv, hd) ring indexed by active slot; the scheduler's page
accounting decides *which* requests own slots — on real hardware the page
table would also drive a gather, which we fold into slot assignment here
(one request per slot, contiguous history).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models.lm import decode_step, init_decode_cache, init_params

from .allocator import AddressAllocationUnit
from .scheduler import PAGE_TOKENS, TwoLevelScheduler, Request


@dataclass
class ServeConfig:
    max_len: int = 512
    active_slots: int = 8
    total_pages: int = 64


class ServingEngine:
    def __init__(self, cfg: ArchConfig, params=None, sc: ServeConfig | None = None,
                 key=None):
        self.cfg = cfg
        self.sc = sc or ServeConfig()
        key = key if key is not None else jax.random.PRNGKey(0)
        self.params = params if params is not None else init_params(cfg, key)[0]
        self.aau = AddressAllocationUnit(self.sc.total_pages)
        self.sched = TwoLevelScheduler(self.aau, active_slots=self.sc.active_slots)
        self.cache, _ = init_decode_cache(cfg, self.sc.active_slots,
                                          self.sc.max_len)
        self._decode = jax.jit(
            lambda p, c, t, n: decode_step(p, c, t, n, cfg))
        self.tokens = np.zeros((self.sc.active_slots, 1), np.int32)
        self.generated: dict[int, list[int]] = {}

    def submit(self, prompt: list[int], max_new_tokens: int = 16) -> Request:
        r = self.sched.submit(len(prompt), max_new_tokens)
        self.generated[r.rid] = []
        return r

    def run(self, max_steps: int = 4096) -> dict[int, list[int]]:
        """Greedy-decode all submitted requests to completion."""
        self.sched.admit()
        cache_len = 0
        steps = 0
        while (self.sched.active or self.sched.waiting) and steps < max_steps:
            steps += 1
            toks = jnp.asarray(self.tokens)
            if self.cfg.family == "audio":
                toks = jnp.broadcast_to(
                    toks[:, None, :], (toks.shape[0], self.cfg.n_codebooks, 1))
            logits, self.cache = self._decode(
                self.params, self.cache, toks,
                jnp.int32(min(cache_len, self.sc.max_len - 1)))
            nxt = np.asarray(jnp.argmax(
                logits[..., -1, :] if self.cfg.family != "audio"
                else logits[:, -1, :, :], axis=-1))
            for i, r in enumerate(list(self.sched.active)):
                if i >= self.tokens.shape[0]:
                    break
                tok = int(nxt[i] if np.ndim(nxt[i]) == 0 else np.ravel(nxt[i])[0])
                self.generated[r.rid].append(tok)
                self.tokens[i, 0] = tok
            cache_len = min(cache_len + 1, self.sc.max_len - 1)
            self.sched.step()
        return self.generated
