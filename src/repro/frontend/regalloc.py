"""Linear-scan register allocation for lifted programs.

The jaxpr lifter emits over unlimited virtual registers; the simulator's
occupancy model needs a compiled ``regs_per_thread`` under a configurable
``maxregcount`` (the nvcc knob real kernels are tuned with).  This pass:

* computes live intervals over the linearized program through the core
  compiler pipeline's liveness passes (`repro.core.pipeline.frontend_passes`
  -> `repro.core.liveness.linear_live_intervals`), conservatively extending
  any register that is live across a loop back edge to the whole loop span
  (its value must survive every iteration);
* runs a classic linear scan, assigning dense architectural ids — dense ids
  keep the interleaved bank mapping (``reg % num_banks``) balanced;
* on pressure above ``maxregcount``, spills the farthest-ending live ranges
  to (shared) memory: every spilled use loads through a small set of reserved
  shuttle registers and every spilled def stores back, so the simulator
  naturally charges the long-latency spill traffic.

The output program re-validates and runs on both simulator engines; the
``regs_per_thread`` metadata feeds `Simulator._occupancy` exactly like the
synthetic suite's hand-assigned register demands.
"""
from __future__ import annotations

from dataclasses import dataclass
from heapq import heappop, heappush

from repro.core.ir import BasicBlock, Instr, Program
from repro.core.pipeline import CompileContext, PassManager, frontend_passes

# Reserved when spilling: 3 shuttle registers (mad reads up to 3 sources)
# plus the spill base address register.
_RESERVED = 4


@dataclass(frozen=True)
class AllocResult:
    prog: Program
    regs_per_thread: int
    vreg_map: dict[int, int]       # virtual -> architectural (unspilled only)
    spilled: frozenset[int]
    spill_loads: int
    spill_stores: int

    @property
    def spill_count(self) -> int:
        return len(self.spilled)


def _liveness_via_pipeline(prog: Program) -> tuple[dict[int, int], dict[int, int]]:
    """Run the core liveness pipeline; returns linear [first, last] intervals."""
    ctx = CompileContext(prog=prog, design="frontend")
    PassManager(frontend_passes()).run(ctx)
    return ctx.artifacts["linear_live_intervals"]


def _linear_scan(ivals: list[tuple[int, int, int]],
                 k: int) -> tuple[dict[int, int], set[int]]:
    """Classic linear scan over (start, end, reg); farthest-end spill victim."""
    assign: dict[int, int] = {}
    spilled: set[int] = set()
    active: list[tuple[int, int]] = []  # (end, reg)
    free: list[int] = list(range(k))
    for start, end, r in ivals:
        keep = []
        for (e, v) in active:
            if e < start:
                heappush(free, assign[v])
            else:
                keep.append((e, v))
        active = keep
        if free:
            assign[r] = heappop(free)
            active.append((end, r))
            continue
        far = max(active, key=lambda t: (t[0], t[1]), default=None)
        if far is not None and far[0] > end:
            far_e, far_v = far
            spilled.add(far_v)
            assign[r] = assign.pop(far_v)
            active.remove(far)
            active.append((end, r))
        else:
            spilled.add(r)
    return assign, spilled


def allocate_registers(prog: Program, maxregcount: int = 64) -> AllocResult:
    """Lower unlimited virtual registers to at most ``maxregcount`` ids."""
    if maxregcount < _RESERVED + 2:
        raise ValueError(f"maxregcount={maxregcount} below the reserved "
                         f"spill machinery ({_RESERVED + 2} registers)")
    first, last = _liveness_via_pipeline(prog)
    ivals = sorted((first[r], last[r], r) for r in first)

    assign, spilled = _linear_scan(ivals, maxregcount)
    shuttles: tuple[int, ...] = ()
    spill_base = -1
    if spilled:
        k = maxregcount - _RESERVED
        assign, spilled = _linear_scan(ivals, k)
        shuttles = (k, k + 1, k + 2)
        spill_base = k + 3

    loads = stores = 0
    blocks: dict[str, BasicBlock] = {}
    for bb in prog:
        out: list[Instr] = []
        if bb.label == prog.entry and spilled:
            out.append(Instr(op="mov", dsts=(spill_base,)))
        for ins in bb.instrs:
            mapping: dict[tuple[str, int], int] = {}
            pre: list[Instr] = []
            post: list[Instr] = []
            src_shuttle: dict[int, int] = {}
            for k2, s in enumerate(ins.srcs):
                if s in spilled:
                    t = src_shuttle.get(s)
                    if t is None:
                        t = shuttles[len(src_shuttle)]
                        src_shuttle[s] = t
                        pre.append(Instr(op="ld", dsts=(t,),
                                         srcs=(spill_base,)))
                        loads += 1
                    mapping[("s", k2)] = t
                else:
                    mapping[("s", k2)] = assign[s]
            for k2, d in enumerate(ins.dsts):
                if d in spilled:
                    t = shuttles[0]
                    mapping[("d", k2)] = t
                    post.append(Instr(op="st", srcs=(t, spill_base)))
                    stores += 1
                else:
                    mapping[("d", k2)] = assign[d]
            out.extend(pre)
            out.append(ins.with_regs(mapping))
            out.extend(post)
        blocks[bb.label] = BasicBlock(label=bb.label, instrs=out)

    new_prog = Program(blocks=blocks, order=list(prog.order), name=prog.name)
    new_prog.recompute_edges()
    new_prog.validate()
    return AllocResult(
        prog=new_prog,
        regs_per_thread=len(new_prog.registers()),
        vreg_map=dict(assign),
        spilled=frozenset(spilled),
        spill_loads=loads,
        spill_stores=stores,
    )
