"""Frontend smoke CLI: lift one traced workload and simulate it on CPU.

Used by CI (and humans) to prove the real-kernel path end to end::

    JAX_PLATFORMS=cpu PYTHONPATH=src python -m repro.frontend traced_matmul

Lifts the named workload, checks the interval plan validates, runs it on
both simulator engines across a design, and fails loudly on any divergence.
"""
from __future__ import annotations

import argparse
import json
import os
import sys


def main(argv=None) -> int:
    # Tracing probes jax backends: pin the CPU platform up front so a host
    # with a TPU-less libtpu never hangs (same class as test_pipeline_parallel).
    os.environ.setdefault("JAX_PLATFORMS", "cpu")

    from repro.frontend.workloads import (DEFAULT_MAXREGCOUNT, TRACED_NAMES,
                                          build_traced_workload)

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("name", nargs="?", default="traced_matmul",
                    choices=TRACED_NAMES)
    ap.add_argument("--design", default="LTRF")
    ap.add_argument("--maxregcount", type=int, default=DEFAULT_MAXREGCOUNT)
    ap.add_argument("--num-warps", type=int, default=16)
    ap.add_argument("--cap", type=int, default=16,
                    help="interval register cap for the plan check")
    ap.add_argument("--asm", action="store_true",
                    help="also print the lifted program")
    args = ap.parse_args(argv)

    from repro.core.intervals import form_register_intervals
    from repro.sim import design_config, simulate
    from repro.sim.golden import golden_simulate

    w = build_traced_workload(args.name, maxregcount=args.maxregcount)
    an = form_register_intervals(w.program, n_cap=args.cap)
    an.validate()
    if args.asm:
        print(w.program.render())

    cfg = design_config(args.design, table2_config=7, num_warps=args.num_warps)
    fast = simulate(w, cfg)
    gold = golden_simulate(w, cfg)
    report = {
        "workload": w.name,
        "instructions_static": w.program.num_instrs(),
        "regs_per_thread": w.regs_per_thread,
        "intervals": len(an.intervals),
        "design": args.design,
        "cycles": fast.cycles,
        "instructions": fast.instructions,
        "ipc": round(fast.ipc, 4),
        "prefetch_ops": fast.prefetch_ops,
        "engines_match": fast == gold,
    }
    print(json.dumps(report, indent=1))
    if fast != gold:
        print("FATAL: engine/golden divergence on traced kernel",
              file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
