"""Traced workloads: the repo's own kernels and model layers as sim inputs.

Each entry names a real JAX computation (the Pallas kernels' reference
implementations, plus model-layer slices from `repro.models.layers`), the
example shapes to trace it at, and the memory behaviour the SM model should
assume.  `build_traced_workload` traces + lifts + register-allocates it into
a `Workload` the full pipeline (intervals -> ICG -> renumber -> prefetch ->
both sim engines) consumes like any synthetic kernel.

This module imports jax *lazily*: `TRACED_NAMES` and the spec table are
importable from jax-free paths (the workload registry, CLI arg parsing), and
tracing only happens inside the builders.  Lifts are memoized in
`repro.core.plan_cache` keyed by (name, maxregcount, LIFT_REV) so a sweep
traces each kernel once per process.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import TYPE_CHECKING

from repro.core.plan_cache import cached_value

if TYPE_CHECKING:  # real import stays lazy: repro.workloads imports us back
    from repro.workloads.suite import Workload

DEFAULT_MAXREGCOUNT = 64


@dataclass(frozen=True)
class TracedSpec:
    """What to trace and how the memory system should treat it."""

    name: str
    builder: object          # () -> (fn, example_args)
    l1_hit: float = 0.85
    while_trips: int = 8


# -- example builders (jax imported inside; shapes via ShapeDtypeStruct) -----

def _matmul():
    import jax
    import jax.numpy as jnp

    from repro.kernels.ltrf_matmul.ref import matmul_ref

    sd = jax.ShapeDtypeStruct
    return matmul_ref, (sd((64, 128), jnp.bfloat16), sd((128, 64), jnp.bfloat16))


def _attention():
    import jax
    import jax.numpy as jnp

    from repro.kernels.flash_attention.ref import attention_ref

    sd = jax.ShapeDtypeStruct
    return attention_ref, (sd((1, 4, 64, 32), jnp.float32),
                           sd((1, 2, 64, 32), jnp.float32),
                           sd((1, 2, 64, 32), jnp.float32))


def _ssd():
    import jax
    import jax.numpy as jnp

    from repro.kernels.ssd_scan.ref import ssd_ref

    sd = jax.ShapeDtypeStruct
    return ssd_ref, (sd((1, 32, 2, 8), jnp.float32),
                     sd((1, 32, 2), jnp.float32),
                     sd((2,), jnp.float32),
                     sd((1, 32, 8), jnp.float32),
                     sd((1, 32, 8), jnp.float32))


def _rmsnorm():
    import jax
    import jax.numpy as jnp

    from repro.models.layers import rms_norm

    sd = jax.ShapeDtypeStruct
    return rms_norm, (sd((8, 64), jnp.float32), sd((64,), jnp.float32))


def _mlp():
    import jax
    import jax.numpy as jnp

    from repro.models.layers import mlp_block

    sd = jax.ShapeDtypeStruct
    params = {"w_gate": sd((64, 128), jnp.float32),
              "w_up": sd((64, 128), jnp.float32),
              "w_down": sd((128, 64), jnp.float32)}
    return mlp_block, (params, sd((1, 8, 64), jnp.float32))


def _attn_layer():
    import jax
    import jax.numpy as jnp

    from repro.models.layers import causal_attention

    sd = jax.ShapeDtypeStruct

    def layer(q, k, v):
        return causal_attention(q, k, v, q_block=32)

    return layer, (sd((1, 64, 4, 32), jnp.float32),
                   sd((1, 64, 2, 32), jnp.float32),
                   sd((1, 64, 2, 32), jnp.float32))


TRACED_SPECS: dict[str, TracedSpec] = {
    s.name: s for s in (
        TracedSpec("traced_matmul", _matmul, l1_hit=0.9),
        TracedSpec("traced_attention", _attention, l1_hit=0.85),
        TracedSpec("traced_ssd", _ssd, l1_hit=0.8),
        TracedSpec("traced_rmsnorm", _rmsnorm, l1_hit=0.85),
        TracedSpec("traced_mlp", _mlp, l1_hit=0.9),
        TracedSpec("traced_attn_layer", _attn_layer, l1_hit=0.85),
    )
}
TRACED_NAMES: tuple[str, ...] = tuple(TRACED_SPECS)


def build_traced_workload(name: str,
                          maxregcount: int = DEFAULT_MAXREGCOUNT) -> Workload:
    """Trace, lift, and register-allocate one traced workload (memoized)."""
    spec = TRACED_SPECS[name]

    def build() -> "Workload":
        import os

        # Tracing probes jax backends: pin the CPU platform before the first
        # jax import so hosts with a TPU-less libtpu never hang, whichever
        # entry point (bench_sim/run.py/pool worker/CLI) triggered the lift.
        os.environ.setdefault("JAX_PLATFORMS", "cpu")

        from repro.workloads.suite import Workload

        from .jaxpr_lift import lift_fn
        from .regalloc import allocate_registers

        fn, args = spec.builder()
        lifted = lift_fn(fn, args, name=name, while_trips=spec.while_trips)
        alloc = allocate_registers(lifted.prog, maxregcount=maxregcount)
        return Workload(
            name=name,
            program=alloc.prog,
            trips=lifted.trips,
            register_sensitive=alloc.regs_per_thread > 32,
            regs_per_thread=alloc.regs_per_thread,
            suite="traced",
            l1_hit=spec.l1_hit,
        )

    from .jaxpr_lift import LIFT_REV

    return cached_value(("traced_workload", name, maxregcount, LIFT_REV), build)


def traced_suite(maxregcount: int = DEFAULT_MAXREGCOUNT) -> dict[str, Workload]:
    """All traced workloads (traces on first call, memoized afterwards)."""
    return {n: build_traced_workload(n, maxregcount) for n in TRACED_NAMES}
