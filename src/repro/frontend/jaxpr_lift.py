"""Lift jax-traced computations into the PTX-like register IR.

`jax.make_jaxpr` gives us the real dataflow of the repo's kernels and model
layers; this module lowers that jaxpr into the asm DSL of `repro.core.ir` so
the whole LTRF compiler pipeline (interval formation, renumbering, prefetch
scheduling) and both simulator engines run on *real* programs instead of the
synthetic suite.  The lowering models one GPU thread's tiled slice of the
computation:

* each jaxpr value is a virtual register (its resident tile);
* operand materialization, `gather`/`dynamic_slice` and scan inputs become
  ``ld``; outputs and scatter-like updates become ``st``;
* ``dot_general`` expands into a 2x2 register-tiled inner loop over the
  contraction dimension (4 accumulators, the classic GPU inner kernel);
* reductions expand into an accumulate loop over the reduced extent;
* ``scan``/``while`` become labelled loops with finite trip counts (the
  simulator's branch model resolves them through the ``trips`` table) and
  loop-carried values get dedicated carry registers;
* ``cond`` becomes an if/else diamond with a predicated branch;
* call-like primitives (``pjit``, ``remat2``, ``custom_jvp_call``, ...) are
  inlined.

Virtual registers are unlimited; `repro.frontend.regalloc` lowers them to an
architectural budget afterwards.  Lifting is deterministic: the same function
and example shapes produce the identical program text.
"""
from __future__ import annotations

import re
from contextlib import contextmanager
from dataclasses import dataclass
from math import prod

from repro.core.ir import Program, parse_asm

# Bump when the lowering changes shape: keys the lift memo in
# `repro.core.plan_cache.cached_value` so stale lifts never replay.
LIFT_REV = 1

# Layout/dtype-only primitives: a register-to-register move of the tile.
_DATA_MOVEMENT = frozenset({
    "broadcast_in_dim", "reshape", "transpose", "squeeze", "expand_dims",
    "rev", "slice", "pad", "convert_element_type", "reduce_precision",
    "copy", "iota", "real", "imag",
})
# Primitives that vanish entirely (alias their operand).
_PASSTHROUGH = frozenset({"stop_gradient"})
# Long-latency reads / writes of off-chip data.
_MEM_READ = frozenset({"gather", "dynamic_slice", "take"})
_MEM_WRITE = frozenset({
    "scatter", "scatter-add", "scatter_add", "dynamic_update_slice",
})
# Reduction-style primitives -> (accumulate op) loops.
_REDUCE_OPS = {
    "reduce_sum": "add", "reduce_max": "max", "reduce_min": "min",
    "reduce_prod": "mul", "reduce_and": "and", "reduce_or": "or",
    "argmax": "max", "argmin": "min",
    "cumsum": "add", "cumprod": "mul", "cummax": "max", "cummin": "min",
    "cumlogsumexp": "add",
}
# Friendlier opcode spellings for a few primitives.
_RENAME = {"integer_pow": "pow", "select_n": "sel", "logistic": "sig",
           "square": "mul", "concatenate": "cat"}
# Opcodes with special IR semantics that an ALU op must never shadow.
_IR_RESERVED = frozenset({"ld", "st", "bra", "call", "exit", "ret", "set"})


def _literal_type():
    try:
        from jax.extend.core import Literal  # jax >= 0.4.34
        return Literal
    except ImportError:  # pragma: no cover - older jax
        from jax.core import Literal
        return Literal


def _opname(prim: str) -> str:
    op = _RENAME.get(prim)
    if op is None:
        op = re.sub(r"[^a-z]", "", prim.lower())
    if not op or op in _IR_RESERVED:
        op = "mov"
    return op


def _tile_trips(n) -> int:
    """Per-thread trip count for a tiled (data-parallel) extent of size n."""
    n = int(n) if n else 1
    if n <= 1:
        return 1
    return max(2, min(16, int(round(n ** 0.5))))


def _serial_trips(n) -> int:
    """Trip count for an inherently serial extent (scan/while iterations)."""
    n = int(n) if n else 1
    return max(1, min(12, n))


@dataclass(frozen=True)
class LiftedProgram:
    """A lifted computation: IR program + the trip table the simulator needs."""

    prog: Program
    trips: dict[str, int]
    num_virtual_regs: int


class _Emitter:
    def __init__(self, while_trips: int = 8) -> None:
        self.lines: list[str] = []
        self.trips: dict[str, int] = {}
        self.nreg = 0
        self.npred = 0
        self.nlab = 0
        self.while_trips = while_trips
        self.param_reg = self.fresh()  # base address of the operand space

    def fresh(self) -> int:
        r = self.nreg
        self.nreg += 1
        return r

    def pred(self) -> int:
        p = self.npred
        self.npred += 1
        return p

    def label(self, stem: str) -> str:
        self.nlab += 1
        return f"{stem}{self.nlab}"

    def emit(self, line: str) -> None:
        self.lines.append(line)

    def mov(self, dst: int, src: int | None = None, imm: int = 0) -> int:
        if src is None:
            self.emit(f"mov r{dst}, {imm}")
        else:
            self.emit(f"mov r{dst}, r{src}")
        return dst

    def load(self, addr: int | None = None) -> int:
        d = self.fresh()
        a = self.param_reg if addr is None else addr
        self.emit(f"ld r{d}, [r{a}]")
        return d

    def store(self, val: int, addr: int | None = None) -> None:
        a = self.param_reg if addr is None else addr
        self.emit(f"st r{val}, [r{a}]")

    @contextmanager
    def loop(self, trips: int):
        """Emit a counted loop; the label lands in the sim's trip table."""
        lab = self.label("T")
        ctr, bound = self.fresh(), self.fresh()
        self.mov(bound, imm=max(trips, 1))
        self.mov(ctr, imm=0)
        self.emit(f"{lab}: nop")
        self.trips[lab] = max(trips, 1)
        yield lab
        p = self.pred()
        self.emit(f"add r{ctr}, r{ctr}, 1")
        self.emit(f"set p{p}, r{ctr}, r{bound}")
        self.emit(f"@p{p} bra {lab}")


class _Lifter:
    def __init__(self, em: _Emitter) -> None:
        self.em = em
        self.Literal = _literal_type()

    # -- value plumbing ------------------------------------------------------
    def _src(self, env: dict, atom) -> int | None:
        if isinstance(atom, self.Literal):
            return None  # immediates are non-register operands
        return env[atom]

    def _srcs(self, env: dict, atoms) -> list[int | None]:
        return [self._src(env, a) for a in atoms]

    def _reg_or_mov(self, s: int | None) -> int:
        if s is not None:
            return s
        return self.em.mov(self.em.fresh())

    def _materialize(self, aval) -> int:
        """Bring an operand (kernel parameter / captured const) into registers."""
        if getattr(aval, "shape", ()) == ():
            return self.em.mov(self.em.fresh(), imm=1)  # scalar: immediate
        return self.em.load()

    def _bind_out(self, env: dict, outvars, regs) -> None:
        for v, r in zip(outvars, regs):
            env[v] = r

    # -- jaxpr traversal -----------------------------------------------------
    def lift_closed(self, closed, env_args: list[int]) -> list[int]:
        """Lift a ClosedJaxpr whose invars are bound to ``env_args``."""
        jaxpr = closed.jaxpr
        env: dict = {}
        for cv in jaxpr.constvars:
            env[cv] = self._materialize(cv.aval)
        for iv, r in zip(jaxpr.invars, env_args):
            env[iv] = r
        self.run(jaxpr, env)
        return [self._reg_or_mov(self._src(env, ov)) for ov in jaxpr.outvars]

    def run(self, jaxpr, env: dict) -> None:
        for eqn in jaxpr.eqns:
            self.eqn(env, eqn)

    def eqn(self, env: dict, eqn) -> None:
        em = self.em
        prim = eqn.primitive.name
        srcs = self._srcs(env, eqn.invars)

        if prim in _PASSTHROUGH and srcs and srcs[0] is not None:
            env[eqn.outvars[0]] = srcs[0]
            return
        sub = self._subjaxpr(eqn)
        if sub is not None:
            outs = self.lift_closed(_as_closed(sub),
                                    [self._reg_or_mov(s) for s in srcs])
            self._bind_out(env, eqn.outvars, outs)
            return
        if prim == "scan":
            self._scan(env, eqn, srcs)
            return
        if prim == "while":
            self._while(env, eqn, srcs)
            return
        if prim == "cond":
            self._cond(env, eqn, srcs)
            return
        if prim == "dot_general":
            env[eqn.outvars[0]] = self._dot(eqn, srcs)
            return
        if prim in _REDUCE_OPS:
            env[eqn.outvars[0]] = self._reduce(eqn, srcs, _REDUCE_OPS[prim])
            return
        if prim in _MEM_READ:
            addr = next((s for s in srcs if s is not None), None)
            d = em.load(addr)
            self._bind_out(env, eqn.outvars, [d] * len(eqn.outvars))
            return
        if prim in _MEM_WRITE:
            ref = self._reg_or_mov(srcs[0] if srcs else None)
            val = next((s for s in srcs[1:] if s is not None), ref)
            em.store(val, ref)
            d = em.mov(em.fresh(), ref)  # the updated aggregate
            self._bind_out(env, eqn.outvars, [d] * len(eqn.outvars))
            return

        # Default: data movement -> mov; anything else -> one ALU op.
        regs = [s for s in srcs if s is not None]
        d = em.fresh()
        if prim in _DATA_MOVEMENT or not regs:
            em.mov(d, regs[0] if regs else None)
        else:
            ops = ", ".join(f"r{s}" for s in regs[:3])
            em.emit(f"{_opname(prim)} r{d}, {ops}")
        self._bind_out(env, eqn.outvars, [d] * len(eqn.outvars))

    # -- structured primitives ----------------------------------------------
    def _subjaxpr(self, eqn):
        """The inner jaxpr of call-like primitives (inlined), else None."""
        if eqn.primitive.name in ("scan", "while", "cond"):
            return None
        for key in ("jaxpr", "call_jaxpr", "fun_jaxpr"):
            sub = eqn.params.get(key)
            if sub is not None:
                return sub
        return None

    def _dot(self, eqn, srcs) -> int:
        """dot_general -> register-tiled inner loop over the contraction.

        The register tile adapts to the problem: big output tiles with a deep
        contraction get the classic 4x4 blocking (16 accumulators — this is
        what makes real matmul/attention kernels register-sensitive), small
        ones the cheap 2x2.
        """
        em = self.em
        (lhs_c, _rhs_c), _ = eqn.params["dimension_numbers"]
        lhs_shape = eqn.invars[0].aval.shape
        k_extent = prod((lhs_shape[d] for d in lhs_c), start=1)
        out_extent = prod(eqn.outvars[0].aval.shape, start=1)
        t = 4 if (out_extent >= 1024 and k_extent >= 32) else 2
        a_addr = self._reg_or_mov(srcs[0] if srcs else None)
        b_addr = self._reg_or_mov(srcs[1] if len(srcs) > 1 else None)
        acc = [em.fresh() for _ in range(t * t)]
        for c in acc:
            em.mov(c, imm=0)
        with em.loop(_tile_trips(k_extent)):
            a_r = [em.load(a_addr) for _ in range(t)]
            b_r = [em.load(b_addr) for _ in range(t)]
            for i in range(t):
                for j in range(t):
                    c = acc[i * t + j]
                    em.emit(f"mad r{c}, r{a_r[i]}, r{b_r[j]}, r{c}")
        d = em.fresh()
        em.emit(f"add r{d}, r{acc[0]}, r{acc[1]}")
        for c in acc[2:]:
            em.emit(f"add r{d}, r{d}, r{c}")
        return d

    def _reduce(self, eqn, srcs, op: str) -> int:
        em = self.em
        shape = eqn.invars[0].aval.shape
        axes = eqn.params.get("axes")
        if axes is None:
            axis = eqn.params.get("axis")
            axes = (axis,) if axis is not None else tuple(range(len(shape)))
        extent = prod((shape[a] for a in axes), start=1) if shape else 1
        addr = self._reg_or_mov(srcs[0] if srcs else None)
        acc = em.mov(em.fresh(), imm=0)
        with em.loop(_tile_trips(extent)):
            t = em.load(addr)
            em.emit(f"{op} r{acc}, r{acc}, r{t}")
        return acc

    def _scan(self, env: dict, eqn, srcs) -> None:
        em = self.em
        p = eqn.params
        n_consts, n_carry = p["num_consts"], p["num_carry"]
        closed = p["jaxpr"]
        inner = closed.jaxpr
        const_srcs = srcs[:n_consts]
        carry_srcs = srcs[n_consts:n_consts + n_carry]
        xs_srcs = srcs[n_consts + n_carry:]

        inner_env: dict = {}
        for cv in inner.constvars:
            inner_env[cv] = self._materialize(cv.aval)
        const_regs = [self._reg_or_mov(s) for s in const_srcs]
        # dedicated loop-carried registers, written back each iteration
        carry_regs = [em.mov(em.fresh(), s) if s is not None
                      else em.mov(em.fresh()) for s in carry_srcs]
        for iv, r in zip(inner.invars[:n_consts], const_regs):
            inner_env[iv] = r
        for iv, r in zip(inner.invars[n_consts:n_consts + n_carry], carry_regs):
            inner_env[iv] = r
        xs_addr = [self._reg_or_mov(s) for s in xs_srcs]

        y_regs: list[int] = []
        with em.loop(_serial_trips(p.get("length", 1))):
            for iv, a in zip(inner.invars[n_consts + n_carry:], xs_addr):
                inner_env[iv] = em.load(a)  # per-iteration input slice
            self.run(inner, inner_env)
            outs = [self._reg_or_mov(self._src(inner_env, ov))
                    for ov in inner.outvars]
            for c, nc in zip(carry_regs, outs[:n_carry]):
                if c != nc:
                    em.mov(c, nc)
            y_regs = outs[n_carry:]
            for y in y_regs:
                em.store(y)  # stacked output writeback
        self._bind_out(env, eqn.outvars, carry_regs + y_regs)

    def _while(self, env: dict, eqn, srcs) -> None:
        em = self.em
        p = eqn.params
        cn, bn = p["cond_nconsts"], p["body_nconsts"]
        cond_consts = [self._reg_or_mov(s) for s in srcs[:cn]]
        body_consts = [self._reg_or_mov(s) for s in srcs[cn:cn + bn]]
        carry_regs = [em.mov(em.fresh(), s) if s is not None
                      else em.mov(em.fresh()) for s in srcs[cn + bn:]]
        with em.loop(em.while_trips):
            # the condition's compute happens every iteration too
            self.lift_closed(p["cond_jaxpr"], cond_consts + carry_regs)
            outs = self.lift_closed(p["body_jaxpr"], body_consts + carry_regs)
            for c, nc in zip(carry_regs, outs):
                if c != nc:
                    em.mov(c, nc)
        self._bind_out(env, eqn.outvars, carry_regs)

    def _cond(self, env: dict, eqn, srcs) -> None:
        em = self.em
        branches = eqn.params["branches"]
        idx = self._reg_or_mov(srcs[0] if srcs else None)
        operands = [self._reg_or_mov(s) for s in srcs[1:]]
        if len(branches) != 2:
            outs = self.lift_closed(branches[-1], operands)
            self._bind_out(env, eqn.outvars, outs)
            return
        n_out = len(eqn.outvars)
        out_regs = [em.fresh() for _ in range(n_out)]
        p = em.pred()
        else_l, join_l = em.label("E"), em.label("J")
        em.emit(f"set p{p}, r{idx}, r{idx}")
        em.emit(f"@!p{p} bra {else_l}")
        t_outs = self.lift_closed(branches[1], operands)
        for o, t in zip(out_regs, t_outs):
            em.mov(o, t)
        em.emit(f"bra {join_l}")
        em.emit(f"{else_l}: nop")
        f_outs = self.lift_closed(branches[0], operands)
        for o, f in zip(out_regs, f_outs):
            em.mov(o, f)
        em.emit(f"{join_l}: nop")
        self._bind_out(env, eqn.outvars, out_regs)


def _as_closed(jaxpr_like):
    """Normalize raw Jaxprs (e.g. remat2's param) to a ClosedJaxpr shape."""
    if hasattr(jaxpr_like, "jaxpr"):
        return jaxpr_like

    class _Shim:
        def __init__(self, j):
            self.jaxpr = j
            self.consts = ()

    return _Shim(jaxpr_like)


def lift_jaxpr(closed, name: str = "traced",
               while_trips: int = 8) -> LiftedProgram:
    """Lower a ClosedJaxpr (from `jax.make_jaxpr`) into the register IR."""
    em = _Emitter(while_trips=while_trips)
    lifter = _Lifter(em)
    em.emit(f"mov r{em.param_reg}, PARAMS")
    args = [lifter._materialize(iv.aval) for iv in closed.jaxpr.invars]
    outs = lifter.lift_closed(closed, args)
    for o in outs:
        em.store(o)
    em.emit("exit")
    prog = parse_asm("\n".join(em.lines), name=name)
    return LiftedProgram(prog=prog, trips=dict(em.trips),
                         num_virtual_regs=em.nreg)


def lift_fn(fn, example_args, name: str = "traced",
            while_trips: int = 8) -> LiftedProgram:
    """Trace ``fn`` at ``example_args`` (arrays or ShapeDtypeStructs) and lift.

    Tracing requires jax; callers in jax-free paths should go through
    `repro.frontend.workloads.build_traced_workload`, which memoizes lifts in
    the compile cache.
    """
    import jax

    closed = jax.make_jaxpr(fn)(*example_args)
    return lift_jaxpr(closed, name=name, while_trips=while_trips)
