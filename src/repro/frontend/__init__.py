"""Real-kernel frontend: lift jax computations into the register IR.

* `jaxpr_lift` — walk a `jax.make_jaxpr` trace and lower it to the asm IR
  (loops/diamonds for control flow, ld/st for operand traffic, tiled inner
  loops for dot/reduce) over unlimited virtual registers.
* `regalloc` — linear-scan virtual -> architectural assignment under a
  configurable ``maxregcount``, with shared-memory spill fallback; produces
  the ``regs_per_thread`` metadata the occupancy model needs.
* `workloads` — the traced-workload specs (in-repo kernel references + model
  layer slices) exposed to the suite registry as the ``traced`` suite.

Attribute access is lazy so importing `repro.frontend` (e.g. for
`TRACED_NAMES`) never drags in jax.
"""
from __future__ import annotations

__all__ = [
    "lift_fn", "lift_jaxpr", "LiftedProgram", "LIFT_REV",
    "allocate_registers", "AllocResult",
    "build_traced_workload", "traced_suite", "TRACED_NAMES", "TRACED_SPECS",
]

_HOMES = {
    "lift_fn": "jaxpr_lift", "lift_jaxpr": "jaxpr_lift",
    "LiftedProgram": "jaxpr_lift", "LIFT_REV": "jaxpr_lift",
    "allocate_registers": "regalloc", "AllocResult": "regalloc",
    "build_traced_workload": "workloads", "traced_suite": "workloads",
    "TRACED_NAMES": "workloads", "TRACED_SPECS": "workloads",
}


def __getattr__(name: str):
    home = _HOMES.get(name)
    if home is None:
        raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
    import importlib

    return getattr(importlib.import_module(f".{home}", __name__), name)
